"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


class TestGossipMix:
    @pytest.mark.parametrize("K", [2, 3, 6, 10])
    @pytest.mark.parametrize("M", [1000, 65536, 70000])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_sweep(self, K, M, dtype):
        nb = jax.random.normal(jax.random.key(K * M), (K, M), jnp.float32).astype(dtype)
        w = jax.random.dirichlet(jax.random.key(1), jnp.ones(K))
        got = ops.gossip_mix(nb, w)
        want = ref.gossip_mix_ref(nb, w)
        tol = 1e-5 if dtype == jnp.float32 else 1e-2
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol
        )


class TestQuantize:
    @pytest.mark.parametrize("R,C", [(1, 256), (8, 1024), (3, 4096)])
    def test_deterministic(self, R, C):
        x = jax.random.normal(jax.random.key(R * C), (R, C)) * 3.0
        c, s = ops.quantize(x)
        cr, sr = ref.quantize_ref(x)
        np.testing.assert_array_equal(np.asarray(c), np.asarray(cr))
        np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)

    def test_stochastic_matches_ref_bits(self):
        x = jax.random.normal(jax.random.key(0), (4, 512))
        noise = jax.random.uniform(jax.random.key(1), (4, 512))
        c, s = ops.quantize(x, noise)
        cr, sr = ref.quantize_ref(x, noise)
        np.testing.assert_array_equal(np.asarray(c), np.asarray(cr))

    def test_roundtrip_error_bounded(self):
        x = jax.random.normal(jax.random.key(2), (2, 2048))
        c, s = ops.quantize(x)
        y = ops.dequantize(c, s)
        assert float(jnp.max(jnp.abs(y - x))) <= float(jnp.max(s)) * 0.51

    def test_dequantize(self):
        c = jnp.array([[-127, 0, 64, 127]], jnp.int8)
        s = jnp.array([[0.01]], jnp.float32)
        np.testing.assert_allclose(
            np.asarray(ops.dequantize(c, s)), [[-1.27, 0.0, 0.64, 1.27]], rtol=1e-6
        )


class TestSecureMaskKernel:
    @pytest.mark.parametrize("K,M", [(1, 4096), (4, 65536), (7, 70001)])
    def test_sweep(self, K, M):
        x = jax.random.normal(jax.random.key(M), (M,))
        bits = jax.random.bits(jax.random.key(K), (K, M), jnp.uint32)
        signs = jnp.where(jnp.arange(K) % 2 == 0, 1.0, -1.0)
        got = ops.secure_mask_apply(x, bits, signs, 0.7)
        want = ref.secure_mask_apply_ref(x, bits, signs, 0.7)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)

    def test_pairwise_cancellation(self):
        """+mask and -mask from identical bits cancel exactly."""
        M = 10_000
        x = jax.random.normal(jax.random.key(0), (M,))
        bits = jax.random.bits(jax.random.key(1), (1, M), jnp.uint32)
        plus = ops.secure_mask_apply(x, bits, jnp.array([1.0]), 2.0)
        both = ops.secure_mask_apply(
            x, jnp.concatenate([bits, bits]), jnp.array([1.0, -1.0]), 2.0
        )
        np.testing.assert_allclose(np.asarray(both), np.asarray(x), atol=1e-6)
        assert float(jnp.abs(plus - x).mean()) > 0.5


class TestSparsify:
    @pytest.mark.parametrize("M", [50_000, 65536, 131072])
    def test_histogram_exact(self, M):
        x = jax.random.normal(jax.random.key(M), (M,))
        edges = jnp.exp(jnp.linspace(jnp.log(1e-6), jnp.log(6.0), 96))
        np.testing.assert_array_equal(
            np.asarray(ops.abs_histogram(x, edges)),
            np.asarray(ref.abs_histogram_ref(x, edges)),
        )

    def test_threshold_mask_exact(self):
        x = jax.random.normal(jax.random.key(5), (70_000,))
        vals, mask = ops.threshold_mask(x, 0.9)
        vr, mr = ref.threshold_mask_ref(x, 0.9)
        np.testing.assert_array_equal(np.asarray(mask), np.asarray(mr))
        np.testing.assert_allclose(np.asarray(vals), np.asarray(vr), rtol=1e-6)

    @pytest.mark.parametrize("k_frac", [0.01, 0.1, 0.3])
    def test_topk_approx_quality(self, k_frac):
        M = 100_000
        k = int(M * k_frac)
        x = jax.random.normal(jax.random.key(77), (M,))
        vals, mask, t = ops.topk_mask_approx(x, k)
        nsel = int(mask.sum())
        assert k <= nsel <= int(k * 1.35) + 8, (k, nsel)
        # everything selected must dominate everything dropped
        amin_sel = float(jnp.min(jnp.where(mask, jnp.abs(x), jnp.inf)))
        amax_drop = float(jnp.max(jnp.where(mask, 0.0, jnp.abs(x))))
        assert amin_sel >= amax_drop - 1e-6 or nsel == M


class TestScatterGossip:
    @pytest.mark.parametrize("N,P,K,k", [(4, 100, 3, 5), (8, 1000, 7, 11),
                                         (2, 65536 + 3, 2, 4)])
    def test_sweep(self, N, P, K, k):
        x = jax.random.normal(jax.random.key(N * P), (N, P))
        idx = jax.random.randint(jax.random.key(1), (N, K, k), 0, P)
        val = jax.random.normal(jax.random.key(2), (N, K, k))
        w = jax.random.uniform(jax.random.key(3), (N, K))
        got = ops.payload_mix_nodes(x, idx, val, w)
        want = ref.payload_mix_nodes_ref(x, idx, val, w)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)

    def test_duplicate_indices_accumulate(self):
        """Two operands landing on the same coordinate must both apply."""
        x = jnp.zeros((1, 8))
        idx = jnp.array([[[3], [3]]], jnp.int32)
        val = jnp.array([[[1.0], [2.0]]])
        w = jnp.array([[0.5, 0.25]])
        out = ops.payload_mix_nodes(x, idx, val, w)
        np.testing.assert_allclose(np.asarray(out[0, 3]), 0.5 * 1.0 + 0.25 * 2.0,
                                   rtol=1e-6)
        assert float(jnp.abs(out).sum()) == pytest.approx(1.0)


class TestSparsifyRows:
    @pytest.mark.parametrize("N,P", [(4, 1000), (7, 65536 + 5)])
    def test_histogram_rows_exact(self, N, P):
        x = jax.random.normal(jax.random.key(N * P), (N, P))
        edges = jnp.sort(
            jnp.abs(jax.random.normal(jax.random.key(1), (N, 48))), axis=1
        )
        np.testing.assert_array_equal(
            np.asarray(ops.abs_histogram_rows(x, edges)),
            np.asarray(ref.abs_histogram_rows_ref(x, edges)),
        )

    @pytest.mark.parametrize("k_frac", [0.01, 0.1])
    def test_topk_threshold_rows_quality(self, k_frac):
        N, P = 6, 20_000
        k = int(P * k_frac)
        x = jax.random.normal(jax.random.key(77), (N, P))
        t = ops.topk_threshold_rows(x, k)
        nsel = np.asarray((jnp.abs(x) >= t[:, None]).sum(1))
        assert (nsel >= k).all() and (nsel <= int(k * 1.35) + 8).all(), nsel

    def test_zero_rows(self):
        t = ops.topk_threshold_rows(jnp.zeros((3, 256)), 4)
        assert (np.asarray(t) == 0).all()  # all-zero row: everything survives


class TestThreefryKernel:
    @pytest.mark.parametrize("P", [1, 9, 100, 257, 70001])
    def test_counter_bits_bit_identical_to_jax(self, P):
        """The positional threefry expansion must reproduce
        jax.random.bits exactly — the property the in-kernel generation
        of secure masks rests on."""
        key = jax.random.fold_in(jax.random.key(3), 7)
        want = np.asarray(jax.random.bits(key, (P,), jnp.uint32))
        kd = jax.random.key_data(key)
        got = ref.counter_bits_ref(kd[0], kd[1], jnp.arange(P), P)
        np.testing.assert_array_equal(np.asarray(got), want)

    def test_keyed_kernel_bit_identical_to_bits_kernel(self):
        """secure_mask_apply_nodes_keyed(keys) == secure_mask_apply_nodes
        (pre-expanded jax.random bits) — bit-for-bit, odd M."""
        B, K, M = 3, 4, 333
        x = jax.random.normal(jax.random.key(7), (B, M))
        base = jax.random.key(9)
        ids = jnp.arange(B * K).reshape(B, K)
        keys = jax.vmap(jax.vmap(
            lambda i: jax.random.key_data(jax.random.fold_in(base, i))))(ids)
        bits = jax.vmap(jax.vmap(
            lambda i: jax.random.bits(jax.random.fold_in(base, i), (M,), jnp.uint32)
        ))(ids)
        signs = jnp.asarray(
            np.random.default_rng(0).choice([-1.0, 0.0, 1.0], (B, K)), jnp.float32
        )
        a = ops.secure_mask_apply_nodes(x, bits, signs, 0.9)
        b = ops.secure_mask_apply_nodes_keyed(x, keys, signs, 0.9)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    @pytest.mark.parametrize("B,K,M", [(2, 3, 128), (5, 2, 70001)])
    def test_keyed_kernel_matches_ref(self, B, K, M):
        x = jax.random.normal(jax.random.key(M), (B, M))
        keys = jax.random.bits(jax.random.key(1), (B, K, 2), jnp.uint32)
        signs = jnp.where(jnp.arange(K)[None, :] % 2 == 0, 1.0, -1.0) * jnp.ones((B, 1))
        got = ops.secure_mask_apply_nodes_keyed(x, keys, signs, 1.3)
        want = ref.secure_mask_apply_nodes_keyed_ref(x, keys, signs, 1.3)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)


class TestSSDChunk:
    @pytest.mark.parametrize("L,N,P,H", [(32, 16, 16, 2), (64, 32, 32, 4), (128, 64, 64, 2)])
    def test_sweep(self, L, N, P, H):
        G = 2
        key = jax.random.key(L * N)
        xdt = jax.random.normal(key, (G, L, H, P)) * 0.2
        Bc = jax.random.normal(jax.random.fold_in(key, 1), (G, L, N)) * 0.4
        Cc = jax.random.normal(jax.random.fold_in(key, 2), (G, L, N)) * 0.4
        cum = -jnp.cumsum(jax.random.uniform(jax.random.fold_in(key, 3), (G, L, H)) * 0.1, axis=1)
        y, st, dec = ops.ssd_chunk(xdt, Bc, Cc, cum)
        for g in range(G):
            yr, sr, dr = ref.ssd_chunk_ref(xdt[g], Bc[g], Cc[g], cum[g])
            np.testing.assert_allclose(np.asarray(y[g]), np.asarray(yr), rtol=3e-4, atol=2e-5)
            np.testing.assert_allclose(np.asarray(st[g]), np.asarray(sr), rtol=3e-4, atol=2e-5)
            np.testing.assert_allclose(np.asarray(dec[g]), np.asarray(dr), rtol=1e-5)

    def test_ssd_scan_equals_sequential_recurrence(self):
        B, nc, L, H, P, N = 1, 3, 16, 2, 8, 8
        key = jax.random.key(0)
        xdt = jax.random.normal(key, (B, nc, L, H, P)) * 0.2
        Bc = jax.random.normal(jax.random.fold_in(key, 1), (B, nc, L, N)) * 0.3
        Cc = jax.random.normal(jax.random.fold_in(key, 2), (B, nc, L, N)) * 0.3
        cum = -jnp.cumsum(jax.random.uniform(jax.random.fold_in(key, 3), (B, nc, L, H)) * 0.05, axis=2)
        yk = np.asarray(ops.ssd_scan(xdt, Bc, Cc, cum))
        S = nc * L
        xf = np.asarray(xdt).reshape(B, S, H, P)
        Bf = np.asarray(Bc).reshape(B, S, N)
        Cf = np.asarray(Cc).reshape(B, S, N)
        dA = np.diff(np.asarray(cum), axis=2, prepend=np.zeros((B, nc, 1, H))).reshape(B, S, H)
        h = np.zeros((B, H, N, P))
        ys = []
        for t in range(S):
            h = h * np.exp(dA[:, t])[:, :, None, None] + np.einsum(
                "bn,bhp->bhnp", Bf[:, t], xf[:, t]
            )
            ys.append(np.einsum("bn,bhnp->bhp", Cf[:, t], h))
        want = np.stack(ys, 1).reshape(B, nc, L, H, P)
        np.testing.assert_allclose(yk, want, rtol=3e-3, atol=1e-4)


class TestSWAAttention:
    @pytest.mark.parametrize("S,W,D", [(256, 128, 32), (512, 256, 64), (384, 128, 64)])
    def test_sweep(self, S, W, D):
        BH = 2
        key = jax.random.key(S + W)
        q = jax.random.normal(key, (BH, S, D))
        k = jax.random.normal(jax.random.fold_in(key, 1), (BH, S, D))
        v = jax.random.normal(jax.random.fold_in(key, 2), (BH, S, D))
        o = ops.swa_attention(q, k, v, W)
        for b in range(BH):
            want = ref.swa_attention_ref(q[b], k[b], v[b], W)
            np.testing.assert_allclose(np.asarray(o[b]), np.asarray(want), rtol=3e-4, atol=3e-5)

    def test_bf16(self):
        BH, S, W, D = 1, 256, 128, 32
        q = jax.random.normal(jax.random.key(0), (BH, S, D)).astype(jnp.bfloat16)
        k = jax.random.normal(jax.random.key(1), (BH, S, D)).astype(jnp.bfloat16)
        v = jax.random.normal(jax.random.key(2), (BH, S, D)).astype(jnp.bfloat16)
        o = ops.swa_attention(q, k, v, W)
        want = ref.swa_attention_ref(q[0], k[0], v[0], W)
        np.testing.assert_allclose(
            np.asarray(o[0], np.float32), np.asarray(want, np.float32), rtol=3e-2, atol=3e-2
        )
