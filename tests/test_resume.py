"""Checkpoint crash-resume: an engine restarted from a mid-run
checkpoint — in the same process or in a *fresh* process — must continue
the exact uninterrupted trajectory.

The determinism contract makes this exact, not approximate: batch draws
and gossip payload draws are keyed by the absolute round number, so
restoring (params, opt_state, share_state) and the round cursor replays
rounds [step, rounds) identically.
"""
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.core import DLConfig, RoundEngine
from repro.utils.pytree import tree_vector

def _engine(seed=11, rounds=8, sharing="full", **kw):
    from repro.data import NodeBatcher, make_dataset, sharding_partition
    from repro.models.mlp import mlp_apply, mlp_init
    from repro.models.api import cross_entropy
    from repro.optim import make_optimizer

    dl = DLConfig(n_nodes=8, topology="regular", degree=3, rounds=rounds,
                  eval_every=4, seed=seed, sharing=sharing, **kw)
    ds = make_dataset("cifar10", n_train=256, n_test=64, seed=7, sigma=4.0)
    parts = sharding_partition(ds.train_y, dl.n_nodes, 2, seed=dl.seed)
    batcher = NodeBatcher(ds.train_x, ds.train_y, parts, dl.batch_size,
                          seed=dl.seed)
    init = lambda k: mlp_init(k, hidden=8)  # noqa: E731

    def loss(p, x, y):
        return cross_entropy(mlp_apply(p, x), y)

    def acc(p, x, y):
        return (mlp_apply(p, x).argmax(-1) == y).mean()

    return RoundEngine(dl, init, loss, acc, make_optimizer("sgd", 0.05),
                       batcher)


def _X(eng):
    return np.asarray(jax.vmap(tree_vector)(eng.params))


@pytest.mark.parametrize("sharing", ["full", "topk"])
def test_save_load_roundtrip_continues_exactly(tmp_path, sharing):
    """In-process: 4 rounds + checkpoint + fresh engine + 4 more rounds
    == 8 uninterrupted rounds (bitwise, incl. stateful sharing state)."""
    kw = {"budget": 0.25} if sharing == "topk" else {}
    ref = _engine(sharing=sharing, **kw)
    ref.run(log=False)

    half = _engine(sharing=sharing, **kw)
    half.run(rounds=4, log=False)
    ckpt_dir = str(tmp_path / "ck")
    half.save_state(ckpt_dir)

    fresh = _engine(sharing=sharing, **kw)
    step = fresh.load_state(ckpt_dir)
    assert step == 4
    fresh.run(rounds=8, log=False)
    np.testing.assert_array_equal(_X(fresh), _X(ref))


def test_resume_in_fresh_process(tmp_path):
    """The crash-resume scenario proper: the checkpoint is restored by a
    *restarted process* (new PRNG state, new jit caches) and the
    trajectory still continues identically."""
    ref = _engine()
    ref.run(log=False)

    half = _engine()
    half.run(rounds=4, log=False)
    ckpt_dir = str(tmp_path / "ck")
    half.save_state(ckpt_dir)
    out_npy = str(tmp_path / "final_X.npy")

    script = textwrap.dedent(f"""
        import sys
        sys.path.insert(0, {os.path.dirname(__file__)!r})
        import numpy as np, jax
        from test_resume import _engine, _X
        eng = _engine()
        assert eng.load_state({ckpt_dir!r}) == 4
        eng.run(rounds=8, log=False)
        np.save({out_npy!r}, _X(eng))
    """)
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(os.path.dirname(__file__)), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=300)
    assert proc.returncode == 0, proc.stderr[-2000:]
    np.testing.assert_array_equal(np.load(out_npy), _X(ref))


def test_load_state_picks_named_step(tmp_path):
    eng = _engine()
    eng.run(rounds=3, log=False)
    eng.save_state(str(tmp_path), step=3)
    eng.run(rounds=6, log=False)
    eng.save_state(str(tmp_path), step=6)
    fresh = _engine()
    assert fresh.load_state(str(tmp_path), step=3) == 3
    assert fresh.load_state(str(tmp_path)) == 6  # latest wins by default


def test_save_state_rejects_async_semantics(tmp_path):
    eng = _engine(semantics="async", compute_time_s=0.01)
    with pytest.raises(ValueError, match="synchronous"):
        eng.save_state(str(tmp_path))
