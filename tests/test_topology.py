"""Graph module: constructors, MH weight invariants, dynamic sampler,
file I/O, runtime mutation."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.topology import Graph, PeerSampler, circulant_offsets


class TestConstructors:
    def test_ring(self):
        g = Graph.ring(8)
        assert (g.degrees() == 2).all() and g.is_connected()

    def test_fully(self):
        g = Graph.fully_connected(6)
        assert (g.degrees() == 5).all()

    def test_star(self):
        g = Graph.star(7)
        assert g.degrees()[0] == 6 and (g.degrees()[1:] == 1).all()

    @pytest.mark.parametrize("n,d", [(16, 5), (16, 4), (12, 2), (256, 5), (256, 9)])
    def test_regular_circulant(self, n, d):
        g = Graph.regular_circulant(n, d)
        assert (g.degrees() == d).all() and g.is_connected()

    @pytest.mark.parametrize("n,d", [(16, 5), (48, 5), (64, 3)])
    def test_random_regular(self, n, d):
        g = Graph.random_regular(n, d, seed=3)
        assert (g.degrees() == d).all()
        assert not g.adj.diagonal().any()
        assert (g.adj == g.adj.T).all()

    def test_random_regular_varies_with_seed(self):
        gs = [Graph.random_regular(24, 5, s).adj for s in range(4)]
        assert any((gs[0] != g).any() for g in gs[1:])


class TestMetropolisHastings:
    @given(st.integers(4, 64), st.integers(2, 6), st.integers(0, 10))
    @settings(max_examples=25, deadline=None)
    def test_doubly_stochastic(self, n, d, seed):
        d = min(d, n - 1)
        if n * d % 2:
            d -= 1
        if d < 1:
            return
        g = Graph.random_regular(n, d, seed) if d >= 2 else Graph.ring(n)
        W = g.metropolis_hastings()
        assert np.allclose(W.sum(0), 1.0) and np.allclose(W.sum(1), 1.0)
        assert (W >= -1e-12).all()
        assert np.allclose(W, W.T)
        # support = graph edges + diagonal
        off = W.copy()
        np.fill_diagonal(off, 0.0)
        assert ((off > 0) == g.adj).all()

    def test_spectral_gap_ordering(self):
        # denser graphs mix faster: fully > regular(5) > ring
        n = 32
        gaps = [
            Graph.ring(n).spectral_gap(),
            Graph.regular_circulant(n, 5).spectral_gap(),
            Graph.fully_connected(n).spectral_gap(),
        ]
        assert gaps[0] < gaps[1] < gaps[2] + 1e-12

    def test_uniform_weights_row_stochastic(self):
        g = Graph.random_regular(16, 5, 0)
        W = g.uniform_weights()
        assert np.allclose(W.sum(1), 1.0)


class TestDynamicAndIO:
    def test_peer_sampler_changes_every_round(self):
        ps = PeerSampler(32, 5, seed=1)
        g0, g1 = ps.round_graph(0), ps.round_graph(1)
        assert (g0.adj != g1.adj).any()
        assert (g0.degrees() == 5).all() and (g1.degrees() == 5).all()

    def test_edge_list_roundtrip(self, tmp_path):
        g = Graph.random_regular(16, 4, 7)
        p = str(tmp_path / "g.edges")
        g.to_edge_list(p)
        g2 = Graph.from_edge_list(p, 16)
        assert (g.adj == g2.adj).all()

    def test_adjacency_json(self, tmp_path):
        import json

        g = Graph.ring(6)
        d = {str(i): [int(j) for j in g.neighbors(i)] for i in range(6)}
        p = tmp_path / "g.json"
        p.write_text(json.dumps(d))
        g2 = Graph.from_adjacency_json(str(p))
        assert (g.adj == g2.adj).all()

    def test_runtime_mutation(self):
        g = Graph.ring(8)
        g.add_edge(0, 4)
        assert g.adj[0, 4] and g.adj[4, 0]
        g.remove_edge(0, 1)
        assert not g.adj[0, 1]

    def test_circulant_offsets_degree(self):
        assert circulant_offsets(16, 5) == [1, 2, 8]
        assert circulant_offsets(16, 4) == [1, 2]
