"""Message-level fault injection (core.faults): FaultPlan validation, the
chunk/gather-invariant draw chain, crash schedules, edge-loss renormalization
(rows stay stochastic under arbitrary masks), the gathered round-time form
under per-edge fault masks, and the engine-level counter conservation
invariant ``faults_injected == faults_detected + faults_survived`` across
sync / local / async semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import DLConfig, FaultPlan, RoundEngine
from repro.core import faults as faults_lib
from repro.core.network import (
    gathered_round_times,
    node_round_times,
    paper_testbed,
)
from repro.core.sharing import (
    edge_readmit_sparse,
    edge_reweight,
    edge_reweight_sparse,
)
from repro.core.topology import Graph, SparseTopology, neighbor_table
from repro.data import NodeBatcher, make_dataset, sharding_partition
from repro.optim import make_optimizer

SHAPE = (2, 2, 1)


def _loss(p, x, y):
    t = x.reshape(x.shape[0], -1).mean(0)
    return jnp.mean((p["w"].reshape(-1, t.shape[0]) - t) ** 2)


def _acc(p, x, y):
    return -_loss(p, x, y)


def _engine(p_dim: int = 8, **kw) -> RoundEngine:
    n = kw.setdefault("n_nodes", 12)
    ds = make_dataset("cifar10", n_train=256, n_test=32, shape=SHAPE, sigma=2.0)
    parts = sharding_partition(ds.train_y, n, 2, seed=0)
    batcher = NodeBatcher(ds.train_x, ds.train_y, parts, batch_size=4, seed=0)
    kw.setdefault("chunk_rounds", 4)
    kw.setdefault("eval_every", 4)
    kw.setdefault("topology", "regular")
    kw.setdefault("degree", 4)
    dl = DLConfig(local_steps=1, batch_size=4, **kw)
    init = lambda key: {"w": jax.random.normal(key, (p_dim,))}
    return RoundEngine(dl, init, _loss, _acc, make_optimizer("sgd", 0.05), batcher)


def _w(e):
    return np.asarray(jax.vmap(lambda p: p["w"])(e.params))


def _totals(e):
    return {k: float(v) for k, v in e.scheduler._fault_totals.items()}


def _assert_conserved(t):
    """The module invariant: no fault is silently dropped."""
    assert t["faults_injected"] == pytest.approx(
        t["faults_detected"] + t["faults_survived"], abs=1e-6
    )


# ---------------------------------------------------------------------------
# FaultPlan validation
# ---------------------------------------------------------------------------

class TestFaultPlanValidate:
    def test_defaults_valid(self):
        p = FaultPlan()
        assert p.validate() is p  # returns self, no raise
        FaultPlan(msg_loss=0.5, latency_spike_prob=0.1, corrupt_prob=0.01,
                  crashes=((0, 2, 5), (3, 1, -1))).validate()

    @pytest.mark.parametrize("kw", [
        dict(msg_loss=1.0),
        dict(msg_loss=-0.1),
        dict(latency_spike_prob=1.0),
        dict(latency_spike_factor=0.0),
        dict(corrupt_prob=1.5),
        dict(corrupt_mode="zap"),
        dict(retry_backoff_s=-1e-3),
        dict(retry_backoff_cap=-1),
        dict(crashes=((0, 2),)),           # wrong arity
        dict(crashes=((-1, 2, 5),)),       # bad node
        dict(crashes=((0, -2, 5),)),       # bad crash round
        dict(crashes=((0, 5, 5),)),        # restart <= crash
        dict(crashes=((0, 5, 2),)),
    ], ids=lambda kw: next(iter(kw)))
    def test_bad_plans_rejected(self, kw):
        with pytest.raises(ValueError, match="invalid FaultPlan"):
            FaultPlan(**kw).validate()

    def test_fault_axis_flags(self):
        assert not FaultPlan().any_faults
        assert FaultPlan(msg_loss=0.1).edge_faults
        assert FaultPlan(latency_spike_prob=0.1).edge_faults
        assert not FaultPlan(corrupt_prob=0.1).edge_faults
        assert FaultPlan(corrupt_prob=0.1).any_faults
        assert FaultPlan(crashes=((0, 1, 2),)).any_faults


# ---------------------------------------------------------------------------
# crash schedules
# ---------------------------------------------------------------------------

class TestCrashMask:
    PLAN = FaultPlan(crashes=((3, 2, 5), (7, 4, -1)))

    def test_windows(self):
        m = faults_lib.crash_mask(self.PLAN, 8, 0, 8)
        assert m.shape == (8, 8)
        # node 3 down for rounds [2, 5)
        np.testing.assert_array_equal(m[:, 3], [1, 1, 0, 0, 0, 1, 1, 1])
        # node 7 never restarts
        np.testing.assert_array_equal(m[:, 7], [1, 1, 1, 1, 0, 0, 0, 0])
        # everyone else untouched
        others = np.delete(m, [3, 7], axis=1)
        assert (others == 1).all()

    def test_chunk_slice_invariance(self):
        """Any chunking slices the same absolute-round schedule."""
        full = faults_lib.crash_mask(self.PLAN, 8, 0, 8)
        parts = np.vstack([
            faults_lib.crash_mask(self.PLAN, 8, 0, 3),
            faults_lib.crash_mask(self.PLAN, 8, 3, 5),
        ])
        np.testing.assert_array_equal(full, parts)


# ---------------------------------------------------------------------------
# the per-(round, node) draw chain
# ---------------------------------------------------------------------------

class TestEdgeDraws:
    PLAN = FaultPlan(msg_loss=0.3, latency_spike_prob=0.2, seed=7)

    def test_row_gather_invariance(self):
        """The realization is a pure function of (round, global node id):
        drawing for a row subset gives the bitwise rows of the full draw —
        what makes the cohort/gathered paths see the same faults."""
        key = faults_lib.fault_key(self.PLAN, 0)
        live, spike = faults_lib.edge_draws(key, 5, jnp.arange(16), 4, self.PLAN)
        rows = jnp.array([2, 9, 13])
        lsub, ssub = faults_lib.edge_draws(key, 5, rows, 4, self.PLAN)
        np.testing.assert_array_equal(np.asarray(live)[np.asarray(rows)], lsub)
        np.testing.assert_array_equal(np.asarray(spike)[np.asarray(rows)], ssub)

    def test_rounds_decorrelated(self):
        key = faults_lib.fault_key(self.PLAN, 0)
        a, _ = faults_lib.edge_draws(key, 1, jnp.arange(32), 6, self.PLAN)
        b, _ = faults_lib.edge_draws(key, 2, jnp.arange(32), 6, self.PLAN)
        assert not np.array_equal(np.asarray(a), np.asarray(b))

    def test_zero_rates_draw_nothing(self):
        plan = FaultPlan()
        key = faults_lib.fault_key(plan, 0)
        live, spike = faults_lib.edge_draws(key, 0, jnp.arange(8), 3, plan)
        assert (np.asarray(live) == 1).all() and (np.asarray(spike) == 0).all()

    def test_corruption_modes_are_nonfinite(self):
        X = jnp.ones((4, 6), jnp.float32)
        cmask = jnp.array([0.0, 1.0, 0.0, 1.0])
        for mode in ("nan", "bitflip"):
            bad = faults_lib.corrupt_rows(X, cmask, mode)
            det = np.asarray(faults_lib.nonfinite_rows(bad))
            np.testing.assert_array_equal(det, np.asarray(cmask))


# ---------------------------------------------------------------------------
# edge-loss renormalization: rows stay stochastic
# ---------------------------------------------------------------------------

class TestEdgeReweight:
    @settings(max_examples=20)
    @given(st.integers(min_value=0, max_value=10_000))
    def test_rows_stay_stochastic_under_arbitrary_masks(self, seed):
        """Property: for ANY {0,1} per-edge loss mask, the reweighted dense
        W keeps row sums == 1 with nonnegative entries, and surviving
        off-diagonal edges keep their weight."""
        rng = np.random.default_rng(seed)
        g = Graph.regular_circulant(12, 4)
        W = g.metropolis_hastings().astype(np.float32)
        live = (rng.random((12, 12)) > rng.random()).astype(np.float32)
        Wm = np.asarray(edge_reweight(jnp.asarray(W), jnp.asarray(live)))
        np.testing.assert_allclose(Wm.sum(1), 1.0, atol=1e-6)
        assert (Wm >= -1e-7).all()
        off = ~np.eye(12, dtype=bool)
        kept = off & (live > 0)
        np.testing.assert_allclose(Wm[kept], W[kept], atol=1e-7)
        assert (Wm[off & (live == 0)] == 0).all()

    @settings(max_examples=10)
    @given(st.integers(min_value=0, max_value=10_000))
    def test_sparse_matches_dense(self, seed):
        """edge_reweight_sparse under a slot mask == dense edge_reweight
        under the slot-scattered mask (the sync sparse path's oracle)."""
        rng = np.random.default_rng(seed)
        topo = SparseTopology.regular_circulant(10, 4)
        live_slots = (rng.random(topo.w.shape) > 0.4).astype(np.float32)
        tm = edge_reweight_sparse(topo, jnp.asarray(live_slots))
        dense_live = np.ones((10, 10), np.float32)
        valid = np.asarray(topo.w) > 0
        rows = np.repeat(np.arange(10), topo.dmax).reshape(valid.shape)
        dense_live[rows[valid], np.asarray(topo.nbr)[valid]] = live_slots[valid]
        Wm = edge_reweight(jnp.asarray(topo.to_dense()), jnp.asarray(dense_live))
        np.testing.assert_allclose(
            np.asarray(tm.to_dense()), np.asarray(Wm), atol=1e-6
        )


# ---------------------------------------------------------------------------
# re-admission restore: reweight -> readmit round-trips to pristine
# ---------------------------------------------------------------------------

class TestEdgeReadmitRoundTrip:
    @settings(max_examples=15)
    @given(st.integers(min_value=0, max_value=10_000))
    def test_arbitrary_dead_set_sequences_round_trip_bitwise(self, seed):
        """Property: for ANY sequence of node dead-sets (deaths and
        rejoins in arbitrary order), recomputing the effective topology
        from the pristine table + live mask is row-stochastic at every
        intermediate state, and the moment everyone is live again the
        result is the pristine topology — **bitwise**, w_self included
        (the last-ulp trap: pristine w_self comes from a float64
        accumulation that fp32 ``1 - w.sum(-1)`` cannot reproduce)."""
        rng = np.random.default_rng(seed)
        n = 12
        topo0 = SparseTopology.regular_circulant(n, 4)
        w0 = np.asarray(topo0.w)
        ws0 = np.asarray(topo0.w_self)
        nbr = np.asarray(topo0.nbr)
        # a random walk over dead-sets, ending with everyone alive
        n_steps = rng.integers(2, 6)
        dead_sets = [set(rng.choice(n, size=rng.integers(1, n // 2),
                                    replace=False))
                     for _ in range(n_steps)] + [set()]
        for dead in dead_sets:
            live_nodes = np.ones(n, np.float32)
            for v in dead:
                live_nodes[v] = 0.0
            eff = edge_readmit_sparse(topo0, jnp.asarray(live_nodes[nbr]))
            w = np.asarray(eff.w)
            ws = np.asarray(eff.w_self)
            # row-stochastic at every intermediate state
            np.testing.assert_allclose(ws + w.sum(-1), 1.0, atol=1e-6)
            assert (w >= 0).all()
            # surviving edges keep their pristine weight exactly
            kept = (live_nodes[nbr] > 0) & (w0 > 0)
            np.testing.assert_array_equal(w[kept], w0[kept])
            if not dead:
                # full recovery: the pristine object itself, bitwise
                assert eff is topo0
                np.testing.assert_array_equal(w, w0)
                np.testing.assert_array_equal(ws, ws0)

    def test_readmit_matches_reweight_when_dead_remain(self):
        topo0 = SparseTopology.regular_circulant(10, 4)
        nbr = np.asarray(topo0.nbr)
        live_nodes = np.ones(10, np.float32)
        live_nodes[3] = 0.0
        mask = jnp.asarray(live_nodes[nbr])
        a = edge_readmit_sparse(topo0, mask)
        b = edge_reweight_sparse(topo0, mask)
        np.testing.assert_array_equal(np.asarray(a.w), np.asarray(b.w))
        np.testing.assert_array_equal(
            np.asarray(a.w_self), np.asarray(b.w_self)
        )


# ---------------------------------------------------------------------------
# gathered round times under per-edge fault masks
# ---------------------------------------------------------------------------

class TestGatheredRoundTimes:
    @pytest.mark.parametrize("parallel", [False, True], ids=["serial", "nic"])
    def test_bitwise_row_slice_under_edge_masks(self, parallel):
        """The (C, D) gathered form stays the bitwise row-slice of the dense
        formula when edges are masked out by a per-edge fault mask."""
        n = 16
        g = Graph.regular_circulant(n, 5)
        nbr, valid = neighbor_table(g.adj)
        lat, gp = paper_testbed(n).matrices()
        plan = FaultPlan(msg_loss=0.4, seed=3)
        key = faults_lib.fault_key(plan, 0)
        live, _ = faults_lib.edge_draws(key, 2, jnp.arange(n), nbr.shape[1], plan)
        A = valid.astype(np.float32) * np.asarray(live)
        ct = np.linspace(0.01, 0.05, n).astype(np.float32)
        r = np.arange(n)[:, None]
        dense = node_round_times(A, lat[r, nbr], gp[r, nbr], 4e6, ct, parallel)
        rows = np.array([3, 7, 1, 11, 14])
        got = gathered_round_times(lat, gp, rows, nbr[rows], A[rows], 4e6,
                                   ct[rows], parallel)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(dense)[rows])

    def test_masked_edges_cost_nothing(self):
        n = 8
        g = Graph.ring(n)
        nbr, valid = neighbor_table(g.adj)
        lat, gp = paper_testbed(n).matrices()
        rows = np.arange(n)
        full = gathered_round_times(lat, gp, rows, nbr, valid.astype(np.float32),
                                    1e6, 0.0)
        none = gathered_round_times(lat, gp, rows, nbr, np.zeros_like(valid, np.float32),
                                    1e6, 0.0)
        assert (np.asarray(full) > 0).all()
        assert (np.asarray(none) == 0).all()


# ---------------------------------------------------------------------------
# engine-level fault injection: counters conserve in every scenario
# ---------------------------------------------------------------------------

class TestEngineFaults:
    def test_msg_loss_counters_and_divergence(self):
        plan = FaultPlan(msg_loss=0.3, seed=1)
        e = _engine(rounds=8, seed=3, faults=plan)
        e.run(log=False)
        t = _totals(e)
        _assert_conserved(t)
        assert t["faults_injected"] > 0
        # pure loss is absorbed by renormalization: survived-by-design
        assert t["faults_survived"] == t["faults_injected"]
        assert t["faults_detected"] == 0
        assert np.isfinite(_w(e)).all()
        clean = _engine(rounds=8, seed=3)
        clean.run(log=False)
        assert not np.allclose(_w(e), _w(clean))

    def test_msg_loss_dense_topology(self):
        """The dense-mixing branch uses the (N, N) edge_reweight path."""
        plan = FaultPlan(msg_loss=0.3, seed=1)
        e = _engine(rounds=8, seed=3, topology="fully", degree=0, faults=plan)
        e.run(log=False)
        t = _totals(e)
        _assert_conserved(t)
        assert t["faults_injected"] > 0
        assert np.isfinite(_w(e)).all()

    def test_faulty_trajectory_chunk_invariant(self):
        """Fault draws are pure functions of the absolute round, so the
        scan chunk length cannot change the trajectory."""
        plan = FaultPlan(msg_loss=0.25, latency_spike_prob=0.1, seed=5)
        e4 = _engine(rounds=8, seed=3, chunk_rounds=4, faults=plan)
        e4.run(log=False)
        e2 = _engine(rounds=8, seed=3, chunk_rounds=2, faults=plan)
        e2.run(log=False)
        np.testing.assert_allclose(_w(e4), _w(e2), rtol=2e-5, atol=1e-6)
        assert _totals(e4) == pytest.approx(_totals(e2))

    @pytest.mark.parametrize("mode", ["nan", "bitflip"])
    def test_corruption_detected_and_rolled_back(self, mode):
        plan = FaultPlan(corrupt_prob=0.2, corrupt_mode=mode, seed=2)
        e = _engine(rounds=8, seed=3, faults=plan)
        e.run(log=False)
        t = _totals(e)
        _assert_conserved(t)
        assert t["faults_injected"] > 0
        # both corruption modes are non-finite by construction: detection
        # is exact, and every detection rolls back to the snapshot
        assert t["faults_detected"] == t["faults_injected"]
        assert t["faults_recovered"] == t["faults_detected"]
        assert np.isfinite(_w(e)).all()

    def test_crash_schedule_counts_downtime(self):
        plan = FaultPlan(crashes=((3, 2, 5), (7, 4, -1)))
        e = _engine(rounds=8, seed=3, faults=plan)
        e.run(log=False)
        t = _totals(e)
        _assert_conserved(t)
        # node 3 down rounds [2,5) = 3, node 7 down rounds [4,8) = 4
        assert t["faults_injected"] == 7
        assert t["faults_survived"] == 7
        # crashed nodes freeze (churn machinery): run still converges finite
        assert np.isfinite(_w(e)).all()

    def test_latency_spikes_slow_the_clock(self):
        plan = FaultPlan(latency_spike_prob=0.5, latency_spike_factor=10.0,
                         seed=4)
        kw = dict(rounds=8, seed=3, network="lan", compute_time_s=0.01)
        e = _engine(faults=plan, **kw)
        e.run(log=False)
        clean = _engine(**kw)
        clean.run(log=False)
        t = _totals(e)
        _assert_conserved(t)
        assert t["faults_survived"] == t["faults_injected"] > 0
        # delivered-but-late: same trajectory, slower virtual clock
        np.testing.assert_allclose(_w(e), _w(clean), rtol=2e-5, atol=1e-6)
        assert e.sim_time_s > 1.5 * clean.sim_time_s

    def test_local_semantics_msg_loss_with_churn(self):
        plan = FaultPlan(msg_loss=0.2, seed=6)
        e = _engine(rounds=8, seed=3, semantics="local", participation=0.7,
                    network="lan", compute_time_s=0.01, faults=plan)
        e.run(log=False)
        t = _totals(e)
        _assert_conserved(t)
        assert t["faults_injected"] > 0
        assert np.isfinite(_w(e)).all()

    def test_async_neighborhood_msg_loss(self):
        plan = FaultPlan(msg_loss=0.2, seed=6)
        e = _engine(rounds=12, seed=3, semantics="async", network="lan",
                    compute_time_s=0.01, faults=plan)
        e.run(log=False)
        t = _totals(e)
        _assert_conserved(t)
        assert t["faults_injected"] > 0
        assert np.isfinite(_w(e)).all()

    def test_async_pairwise_retry_backoff(self):
        """Failed pairwise exchanges retry with exponential backoff on the
        virtual clock; a later success after >=1 failure counts recovered."""
        plan = FaultPlan(msg_loss=0.35, retry_backoff_s=1e-3, seed=8)
        e = _engine(rounds=24, seed=3, semantics="async",
                    async_gossip="pairwise", network="lan",
                    compute_time_s=0.01, faults=plan)
        e.run(log=False)
        t = _totals(e)
        _assert_conserved(t)
        assert t["retry_total"] > 0
        assert t["faults_detected"] == t["retry_total"]  # every loss detected
        assert t["faults_recovered"] > 0                  # some retries landed
        assert np.isfinite(_w(e)).all()

    def test_history_carries_fault_metrics(self):
        plan = FaultPlan(msg_loss=0.2, seed=1)
        e = _engine(rounds=8, seed=3, faults=plan)
        e.run(log=False)
        rec = e.history[-1]
        for k in faults_lib.STAT_KEYS:
            assert k in rec
        assert rec["faults_injected"] == int(round(_totals(e)["faults_injected"]))

    def test_fault_free_history_stays_clean(self):
        e = _engine(rounds=4, seed=3)
        e.run(log=False)
        assert "faults_injected" not in e.history[-1]
