"""Network emulation model: Fig. 3b mechanics (denser topologies take
longer per round) and deployment portability (LAN vs WAN by config swap)."""
import numpy as np
import pytest

from repro.core.network import (
    LAN,
    WAN,
    LinkSpec,
    Mapping,
    NetworkModel,
    paper_testbed,
    wan_deployment,
)
from repro.core.topology import Graph


class TestLinkSpec:
    def test_transfer_time(self):
        l = LinkSpec(bandwidth_bps=1e9, latency_s=1e-3)
        assert l.transfer_time(1e9 / 8) == pytest.approx(1.001)

    def test_drop_derates_goodput(self):
        clean = LinkSpec(1e9, 0.0)
        lossy = LinkSpec(1e9, 0.0, drop_rate=0.5)
        assert lossy.transfer_time(1e6) == pytest.approx(2 * clean.transfer_time(1e6))

    @pytest.mark.parametrize("bad", [1.0, 1.5, -0.1, 2.0])
    def test_drop_rate_domain_rejected(self, bad):
        """drop_rate >= 1 (or < 0) is a construction error now — the old
        goodput clamp silently modeled a near-dead link instead."""
        with pytest.raises(ValueError, match="drop_rate"):
            LinkSpec(1e9, 1e-3, drop_rate=bad)

    def test_drop_rate_boundary_values_ok(self):
        assert LinkSpec(1e9, 0.0, drop_rate=0.0).goodput_bps() == 1e9
        assert LinkSpec(1e9, 0.0, drop_rate=0.999).goodput_bps() == pytest.approx(1e6)


class TestMapping:
    def test_round_robin(self):
        m = Mapping(48, 16)
        assert m.machine(0) == m.machine(16) == m.machine(32)
        assert not m.same_machine(0, 1)


class TestRoundTime:
    def test_fully_connected_slower_per_round(self):
        """Paper Fig. 3b: same rounds, fully-connected takes several x the
        wall-clock of sparse topologies (uplink serialization)."""
        n = 32
        net = paper_testbed(n)
        nbytes = 4 * 100_000  # ~100k-param fp32 model
        t_ring = net.round_time(Graph.ring(n), nbytes, compute_time_s=0.01)
        t_reg = net.round_time(Graph.regular_circulant(n, 5), nbytes, compute_time_s=0.01)
        t_full = net.round_time(Graph.fully_connected(n), nbytes, compute_time_s=0.01)
        assert t_ring < t_reg < t_full
        assert t_full / t_reg > 2.5  # paper: ~3x

    def test_wan_slower_than_lan(self):
        n = 16
        g = Graph.regular_circulant(n, 5)
        nbytes = 4e6
        t_lan = paper_testbed(n).round_time(g, nbytes)
        t_wan = wan_deployment(n).round_time(g, nbytes)
        assert t_wan > 5 * t_lan

    def test_local_links_free_ish(self):
        """Nodes co-located on one machine talk over loopback."""
        n = 8
        g = Graph.ring(n)
        all_local = NetworkModel(Mapping(n, 1))
        all_remote = NetworkModel(Mapping(n, n))
        assert all_local.round_time(g, 1e7) < all_remote.round_time(g, 1e7) / 10

    def test_experiment_time_scales_with_rounds(self):
        n = 8
        g = Graph.ring(n)
        net = paper_testbed(n)
        assert net.experiment_time(g, 1e6, 0.01, 100) == pytest.approx(
            100 * net.round_time(g, 1e6, 0.01)
        )

    def test_parallel_sends_bounded_by_serialized(self):
        """Dedicated-NIC overlap: per-node comm is the max link time, so a
        d-regular round collapses to ~one link time instead of d."""
        n = 16
        g = Graph.regular_circulant(n, 4)
        net = NetworkModel(Mapping(n, n))  # all links identical (LAN)
        nbytes = 4e6
        t_ser = net.round_time(g, nbytes, parallel_sends=False)
        t_par = net.round_time(g, nbytes, parallel_sends=True)
        assert t_par <= t_ser
        assert t_ser == pytest.approx(4 * t_par)  # equal links: sum = d * max

    def test_parallel_equals_serialized_for_single_neighbor(self):
        g = Graph.ring(2)  # each node has exactly one neighbor
        net = NetworkModel(Mapping(2, 2))
        assert net.round_time(g, 1e6, parallel_sends=True) == pytest.approx(
            net.round_time(g, 1e6, parallel_sends=False)
        )

    def test_drop_rate_derates_round_time(self):
        n = 8
        g = Graph.ring(n)
        clean = NetworkModel(Mapping(n, n), remote=LinkSpec(1e9, 0.0))
        lossy = NetworkModel(Mapping(n, n), remote=LinkSpec(1e9, 0.0, drop_rate=0.5))
        assert lossy.round_time(g, 1e6) == pytest.approx(2 * clean.round_time(g, 1e6))

    def test_empty_neighbor_set_costs_compute_only(self):
        """A disconnected node sends nothing: round time = compute time."""
        n = 4
        g = Graph(np.zeros((n, n), bool))
        net = paper_testbed(n)
        assert net.round_time(g, 1e9, compute_time_s=0.25) == pytest.approx(0.25)
        assert net.round_time(g, 1e9) == 0.0


class TestHeterogeneousCompute:
    def test_per_node_compute_times_bind_via_max(self):
        n = 8
        g = Graph.regular_circulant(n, 4)
        net = paper_testbed(n)
        ct = np.full(n, 0.01)
        ct[3] = 1.0  # straggler
        t_het = net.round_time(g, 1e6, compute_time_s=ct)
        t_base = net.round_time(g, 1e6, compute_time_s=0.01)
        assert t_het == pytest.approx(t_base + (1.0 - 0.01))
        # per-node vector exposes who binds
        nt = net.node_times(g, 1e6, compute_time_s=ct)
        assert nt.argmax() == 3

    def test_model_level_compute_times(self):
        """compute_time_s promoted into the NetworkModel: round_time uses
        the model's per-node vector when no override is passed."""
        n = 4
        g = Graph.ring(n)
        net = paper_testbed(n)
        net.compute_time_s = np.array([0.0, 0.0, 0.5, 0.0])
        assert net.round_time(g, 0.0) == pytest.approx(
            net.round_time(g, 0.0, compute_time_s=net.compute_time_s)
        )
        assert net.round_time(g, 0.0) >= 0.5

    def test_straggler_distribution_helper(self):
        from repro.core.network import straggler_compute_times

        ct = straggler_compute_times(100, 0.1, factor=10.0, frac=0.2, seed=1)
        assert ct.shape == (100,)
        assert int(np.isclose(ct, 1.0).sum()) == 20
        assert int(np.isclose(ct, 0.1).sum()) == 80
        # seeded: same call -> same stragglers
        np.testing.assert_array_equal(
            ct, straggler_compute_times(100, 0.1, factor=10.0, frac=0.2, seed=1)
        )
        np.testing.assert_array_equal(
            straggler_compute_times(8, 0.2), np.full(8, 0.2, np.float32)
        )


class TestModelEngineEquivalence:
    """The Python NetworkModel and the engine's traced round time share one
    formula (network.node_round_times) — R rounds of the compiled scan must
    sum to R x the host model's round_time, so the two can't drift."""

    @pytest.mark.parametrize("parallel", [False, True], ids=["serial", "nic"])
    def test_traced_sim_time_matches_python_model(self, parallel):
        import jax
        import jax.numpy as jnp

        from repro.core import DLConfig, RoundEngine
        from repro.data import NodeBatcher, make_dataset, sharding_partition
        from repro.optim import make_optimizer

        n, rounds = 8, 3
        ds = make_dataset("cifar10", n_train=128, n_test=16, shape=(2, 2, 1),
                          sigma=2.0)
        parts = sharding_partition(ds.train_y, n, 2, seed=0)
        batcher = NodeBatcher(ds.train_x, ds.train_y, parts, batch_size=4, seed=0)

        def loss(p, x, y):
            t = x.reshape(x.shape[0], -1).mean(0)
            return jnp.mean((p["w"].reshape(-1, t.shape[0]) - t) ** 2)

        dl = DLConfig(n_nodes=n, topology="regular", degree=4, rounds=rounds,
                      eval_every=rounds - 1, network="lan", compute_time_s=0.02,
                      straggler_factor=5.0, straggler_frac=0.25,
                      parallel_sends=parallel, chunk_rounds=2)
        e = RoundEngine(dl, lambda k: {"w": jax.random.normal(k, (8,))}, loss,
                        lambda p, x, y: -loss(p, x, y),
                        make_optimizer("sgd", 0.05), batcher)
        e.run(log=False)
        bytes_per_edge = e.n_params * 4  # fp32 full sharing
        want = rounds * e.network_model.round_time(
            e.graph, bytes_per_edge, parallel_sends=parallel
        )
        assert e.sim_time_s == pytest.approx(want, rel=1e-4)


class TestLinkMatrices:
    def test_matrices_match_link(self):
        net = paper_testbed(6)
        lat, gp = net.matrices()
        assert lat.shape == gp.shape == (6, 6)
        for i in range(6):
            for j in range(6):
                spec = net.link(i, j)
                assert lat[i, j] == pytest.approx(spec.latency_s)
                assert gp[i, j] == pytest.approx(
                    spec.bandwidth_bps * max(1 - spec.drop_rate, 1e-3), rel=1e-6
                )
