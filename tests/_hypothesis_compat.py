"""Thin fallback shim for ``hypothesis`` so the tier-1 suite collects and
runs in environments without it (the container image does not ship it; see
requirements-dev.txt for the optional dev dependency).

When hypothesis is installed, this module re-exports the real
``given``/``settings``/``strategies``.  Otherwise it provides a minimal
deterministic stand-in: ``@given`` runs the test body over a fixed set of
samples (strategy bounds, midpoint, plus seeded random draws) — no
shrinking, no database, but the same property gets exercised.

Only the strategy surface the test suite actually uses is implemented
(``st.integers``); extend as needed.
"""
from __future__ import annotations

try:  # pragma: no cover - exercised implicitly when hypothesis is present
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import inspect
    import random

    HAVE_HYPOTHESIS = False

    class _Integers:
        def __init__(self, min_value, max_value):
            self.lo, self.hi = int(min_value), int(max_value)

        def samples(self, n, rng):
            base = [self.lo, self.hi, (self.lo + self.hi) // 2]
            while len(base) < n:
                base.append(rng.randint(self.lo, self.hi))
            return base[:n]

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Integers(min_value, max_value)

    st = _Strategies()

    def settings(max_examples=None, deadline=None, **_kw):
        def deco(fn):
            fn._shim_max_examples = max_examples
            return fn

        return deco

    def given(*strategies):
        def deco(fn):
            n = min(getattr(fn, "_shim_max_examples", None) or 8, 8)
            rng = random.Random(0)
            cases = list(
                zip(*(s.samples(n, rng) for s in strategies))
            )

            def runner(*args, **kwargs):
                for case in cases:
                    fn(*args, *case, **kwargs)

            runner.__name__ = fn.__name__
            runner.__doc__ = fn.__doc__
            # hypothesis binds positional strategies to the *rightmost*
            # parameters; hide those from pytest's fixture resolution.
            sig = inspect.signature(fn)
            params = list(sig.parameters.values())[: -len(strategies)]
            runner.__signature__ = sig.replace(parameters=params)
            return runner

        return deco
