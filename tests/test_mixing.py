"""Mixing strategies: all lowerings compute the same math; gossip
preserves the global average (the consensus invariant D-PSGD relies on)."""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.mixing import mix_circulant, mix_dense, mix_fully
from repro.core.topology import Graph


def _tree(key, n):
    k1, k2 = jax.random.split(key)
    return {
        "a": jax.random.normal(k1, (n, 7, 3)),
        "b": {"c": jax.random.normal(k2, (n, 11))},
    }


class TestDense:
    @given(st.integers(4, 32), st.integers(0, 5))
    @settings(max_examples=15, deadline=None)
    def test_preserves_mean(self, n, seed):
        g = Graph.regular_circulant(n, min(4, n - 1) // 2 * 2)
        W = jnp.asarray(g.metropolis_hastings(), jnp.float32)
        t = _tree(jax.random.key(seed), n)
        t2 = mix_dense(t, W)
        for l1, l2 in zip(jax.tree_util.tree_leaves(t), jax.tree_util.tree_leaves(t2)):
            np.testing.assert_allclose(l1.mean(0), l2.mean(0), rtol=2e-5, atol=2e-6)

    def test_identity_on_identity_w(self):
        t = _tree(jax.random.key(0), 8)
        t2 = mix_dense(t, jnp.eye(8))
        for l1, l2 in zip(jax.tree_util.tree_leaves(t), jax.tree_util.tree_leaves(t2)):
            np.testing.assert_allclose(l1, l2, rtol=1e-6)


class TestCirculantEquivalence:
    @pytest.mark.parametrize("n,degree", [(16, 2), (16, 4), (16, 5), (12, 3), (32, 5)])
    def test_matches_dense(self, n, degree):
        g = Graph.regular_circulant(n, degree)
        W = jnp.asarray(g.metropolis_hastings(), jnp.float32)
        t = _tree(jax.random.key(1), n)
        d = mix_dense(t, W)
        c = mix_circulant(t, n, degree)
        for l1, l2 in zip(jax.tree_util.tree_leaves(d), jax.tree_util.tree_leaves(c)):
            np.testing.assert_allclose(l1, l2, rtol=2e-5, atol=2e-6)

    def test_fully_is_mean(self):
        t = _tree(jax.random.key(2), 8)
        f = mix_fully(t)
        for l1, l2 in zip(jax.tree_util.tree_leaves(t), jax.tree_util.tree_leaves(f)):
            np.testing.assert_allclose(
                np.broadcast_to(l1.mean(0, keepdims=True), l1.shape), l2, rtol=1e-5
            )


SHMAP_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.core.mixing import mix_circulant, mix_circulant_shmap, mix_dense
    from repro.core.topology import Graph
    mesh = jax.make_mesh((8,), ("data",))
    n, degree = 8, 4
    t = {"a": jax.random.normal(jax.random.key(0), (n, 5, 3)),
         "b": jax.random.normal(jax.random.key(1), (n, 9))}
    W = jnp.asarray(Graph.regular_circulant(n, degree).metropolis_hastings(), jnp.float32)
    dense = mix_dense(t, W)
    sh = mix_circulant_shmap(t, mesh, ("data",), degree)
    for l1, l2 in zip(jax.tree_util.tree_leaves(dense), jax.tree_util.tree_leaves(sh)):
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=2e-5, atol=2e-6)
    print("SHMAP_OK")
""")


class TestShardMapPath:
    def test_collective_permute_path_matches_dense(self):
        """The ppermute lowering runs on an 8-fake-device mesh in a
        subprocess (device count is locked at jax init)."""
        r = subprocess.run(
            [sys.executable, "-c", SHMAP_SCRIPT], capture_output=True, text=True,
            timeout=300,
        )
        assert "SHMAP_OK" in r.stdout, r.stdout + r.stderr
