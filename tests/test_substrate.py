"""Substrate: optimizers, data pipeline, checkpointing, compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.checkpoint import latest_checkpoint, load_checkpoint, save_checkpoint
from repro.core.compression import (
    dequantize_int4,
    dequantize_int8,
    quantize_int4,
    quantize_int8,
    delta_decode_indices,
    delta_encode_indices,
)
from repro.data import NodeBatcher, iid_partition, make_dataset, sharding_partition
from repro.data.partition import classes_per_node
from repro.optim import make_optimizer
from repro.optim.optimizers import apply_updates, clip_by_global_norm, global_norm


class TestOptimizers:
    @pytest.mark.parametrize("name,kw", [("sgd", {}), ("momentum", {}), ("adamw", {})])
    def test_quadratic_convergence(self, name, kw):
        opt = make_optimizer(name, 0.1, **kw)
        params = {"x": jnp.array([3.0, -2.0])}
        state = opt.init(params)
        for _ in range(200):
            g = jax.grad(lambda p: jnp.sum(p["x"] ** 2))(params)
            u, state = opt.update(g, state, params)
            params = apply_updates(params, u)
        assert float(jnp.abs(params["x"]).max()) < 1e-2, name

    def test_clip_by_global_norm(self):
        g = {"a": jnp.full((10,), 10.0)}
        gc = clip_by_global_norm(g, 1.0)
        np.testing.assert_allclose(float(global_norm(gc)), 1.0, rtol=1e-5)
        g2 = {"a": jnp.full((10,), 1e-3)}
        gc2 = clip_by_global_norm(g2, 1.0)
        np.testing.assert_allclose(np.asarray(gc2["a"]), np.asarray(g2["a"]))


class TestPartition:
    @given(st.integers(2, 32), st.integers(1, 4), st.integers(0, 5))
    @settings(max_examples=20, deadline=None)
    def test_sharding_partition_covers_exactly(self, n_nodes, shards, seed):
        labels = np.random.default_rng(seed).integers(0, 10, 640)
        parts = sharding_partition(labels, n_nodes, shards, seed)
        allidx = np.concatenate(parts)
        assert len(allidx) == len(labels)
        assert len(np.unique(allidx)) == len(labels)

    def test_two_sharding_limits_classes(self):
        """Paper: 2-sharding caps classes/node (~4 for CIFAR-10 @ 256)."""
        labels = np.random.default_rng(0).integers(0, 10, 12800)
        parts = sharding_partition(labels, 64, 2, 0)
        cpn = classes_per_node(labels, parts)
        assert cpn.max() <= 4 and cpn.mean() <= 3.5

    def test_iid_covers(self):
        labels = np.arange(100) % 7
        parts = iid_partition(labels, 8, 0)
        assert len(np.unique(np.concatenate(parts))) == 100

    def test_batcher_deterministic(self):
        ds = make_dataset("cifar10", n_train=256, n_test=64)
        parts = iid_partition(ds.train_y, 4, 0)
        b = NodeBatcher(ds.train_x, ds.train_y, parts, 8, seed=3)
        x1, y1 = b.batch(5, 0)
        x2, y2 = b.batch(5, 0)
        np.testing.assert_array_equal(x1, x2)
        x3, _ = b.batch(6, 0)
        assert (x1 != x3).any()
        assert x1.shape == (4, 8, 32, 32, 3)


class TestDatasets:
    def test_images_learnable_structure(self):
        ds = make_dataset("cifar10", n_train=512, n_test=128, sigma=0.5)
        # nearest-prototype classification must beat chance by a lot
        protos = ds.prototypes.reshape(10, -1)
        x = ds.test_x.reshape(len(ds.test_x), -1)
        pred = ((x[:, None, :] - protos[None]) ** 2).sum(-1).argmin(1)
        acc = (pred == ds.test_y).mean()
        assert acc > 0.9

    def test_lm_stream_shapes(self):
        ds = make_dataset("lm", n_train=32, n_test=8, seq_len=16, vocab=64)
        assert ds.train_x.shape == (32, 16)
        assert ds.train_x.max() < 64


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {"layer": {"w": np.arange(6, dtype=np.float32).reshape(2, 3),
                          "b": np.zeros(3, np.float32)}}
        opt = {"mu": {"layer": {"w": np.ones((2, 3), np.float32)}}}
        save_checkpoint(str(tmp_path), 42, params=tree, opt_state=opt)
        assert latest_checkpoint(str(tmp_path)) == 42
        step, out = load_checkpoint(str(tmp_path))
        assert step == 42
        np.testing.assert_array_equal(out["params"]["layer"]["w"], tree["layer"]["w"])
        np.testing.assert_array_equal(out["opt_state"]["mu"]["layer"]["w"], 1.0)

    def test_multiple_steps(self, tmp_path):
        for s in (1, 5, 3):
            save_checkpoint(str(tmp_path), s, params={"w": np.zeros(2)})
        assert latest_checkpoint(str(tmp_path)) == 5


class TestCompression:
    @given(st.integers(0, 50))
    @settings(max_examples=15, deadline=None)
    def test_int8_roundtrip_bounded(self, seed):
        x = jax.random.normal(jax.random.key(seed), (4, 257)) * (seed + 1)
        c, s = quantize_int8(x)
        y = dequantize_int8(c, s)
        assert float(jnp.max(jnp.abs(y - x))) <= float(jnp.max(s)) * 0.51 + 1e-9

    def test_int4_roundtrip_bounded(self):
        x = jax.random.normal(jax.random.key(0), (2, 128))
        packed, s = quantize_int4(x)
        assert packed.shape == (2, 64)
        y = dequantize_int4(packed, s)
        assert float(jnp.max(jnp.abs(y - x))) <= float(jnp.max(s)) * 0.51 + 1e-9

    def test_stochastic_rounding_unbiased(self):
        x = jnp.full((1, 4096), 0.3)  # between quant levels
        outs = []
        for i in range(20):
            c, s = quantize_int8(x, key=jax.random.key(i))
            outs.append(np.asarray(dequantize_int8(c, s)).mean())
        assert abs(np.mean(outs) - 0.3) < 2e-3

    def test_delta_indices_roundtrip(self):
        idx = jnp.sort(jax.random.permutation(jax.random.key(0), 1000)[:64])[None]
        d = delta_encode_indices(idx)
        np.testing.assert_array_equal(np.asarray(delta_decode_indices(d)), np.asarray(idx))
