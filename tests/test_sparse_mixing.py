"""Sparse neighbor-indexed mixing: SparseTopology tables == dense W for
every sharing strategy, churn reweighting, the Pallas kernel backends, and
the engine end-to-end (dense path kept as the equivalence oracle)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import DLConfig, RoundEngine
from repro.core.mixing import apply_W, mix_dense, mix_sparse
from repro.core.secure import SecureAggregation
from repro.core.sharing import (
    make_sharing,
    participation_reweight,
    participation_reweight_sparse,
)
from repro.core.topology import (
    Graph,
    PeerSampler,
    SparseTopology,
    random_regular_neighbors,
)
from repro.data import NodeBatcher, make_dataset, sharding_partition
from repro.kernels import ops, ref
from repro.models.api import cross_entropy
from repro.models.mlp import mlp_apply, mlp_init
from repro.optim import make_optimizer


def _graphs(n=12):
    return {
        "ring": Graph.ring(n),
        "regular": Graph.regular_circulant(n, 4),
        "random-regular": Graph.random_regular(n, 4, seed=2),
    }


def _dev(st_):
    return SparseTopology(jnp.asarray(st_.nbr), jnp.asarray(st_.w), jnp.asarray(st_.w_self))


class TestSparseTopology:
    @pytest.mark.parametrize("name", ["ring", "regular", "random-regular"])
    def test_to_dense_matches_metropolis_hastings(self, name):
        g = _graphs()[name]
        st_ = SparseTopology.from_graph(g)
        np.testing.assert_allclose(st_.to_dense(), g.metropolis_hastings(), atol=1e-6)
        assert (np.asarray(st_.w_self) > 0).all()  # MH keeps diagonal mass

    def test_sampler_table_matches_graph(self):
        ps = PeerSampler(32, 5, seed=3)
        t = ps.round_table(9)
        np.testing.assert_allclose(t.to_dense(), ps.round_weights(9), atol=1e-6)

    def test_sparse_stack_shape_and_bytes(self):
        ps = PeerSampler(64, 6, seed=1)
        s = ps.sparse_stack(4, 5)
        assert s.nbr.shape == (5, 64, 6) and s.w.shape == (5, 64, 6)
        np.testing.assert_array_equal(s.nbr[3], ps.round_table(7).nbr)
        # O(N·d) staging: ~(2·d+1)/N of the (R, N, N) dense stack
        assert s.stage_bytes() < 0.25 * (5 * 64 * 64 * 4)

    def test_random_regular_neighbors_valid(self):
        n, d = 256, 6
        nbr = random_regular_neighbors(n, d, seed=11)
        assert nbr.shape == (n, d)
        rows = np.repeat(np.arange(n), d)
        assert (nbr != rows.reshape(n, d)).all()  # no self loops
        for r in range(0, n, 37):
            assert len(set(nbr[r])) == d  # no multi-edges
        # symmetry: i in nbr[j] iff j in nbr[i]
        edges = {(min(a, b), max(a, b)) for a, b in zip(rows, nbr.reshape(-1))}
        assert len(edges) == n * d // 2


class TestMixSparseEquivalence:
    @pytest.mark.parametrize("name", ["ring", "regular", "random-regular"])
    def test_pytree_matches_dense(self, name):
        g = _graphs()[name]
        st_ = _dev(SparseTopology.from_graph(g))
        W = jnp.asarray(g.metropolis_hastings(), jnp.float32)
        k1, k2 = jax.random.split(jax.random.key(0))
        t = {"a": jax.random.normal(k1, (g.n, 7, 3)),
             "b": jax.random.normal(k2, (g.n, 11))}
        d = mix_dense(t, W)
        s = mix_sparse(t, st_, use_pallas=False)
        for l1, l2 in zip(jax.tree_util.tree_leaves(d), jax.tree_util.tree_leaves(s)):
            np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                                       rtol=2e-5, atol=2e-6)

    def test_pallas_backend_matches_xla(self):
        g = Graph.regular_circulant(16, 5)
        st_ = _dev(SparseTopology.from_graph(g))
        t = {"a": jax.random.normal(jax.random.key(1), (16, 300))}
        a = mix_sparse(t, st_, use_pallas=False)["a"]
        b = mix_sparse(t, st_, use_pallas=True, interpret=True)["a"]
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5, atol=2e-6)

    @given(st.integers(0, 10))
    @settings(max_examples=8, deadline=None)
    def test_apply_w_preserves_mean(self, seed):
        g = Graph.random_regular(16, 4, seed)
        st_ = _dev(SparseTopology.from_graph(g))
        X = jax.random.normal(jax.random.key(seed), (16, 33))
        Y = apply_W(st_, X)
        np.testing.assert_allclose(np.asarray(Y.mean(0)), np.asarray(X.mean(0)),
                                   rtol=2e-5, atol=2e-6)


class TestSharingStrategiesSparse:
    """Every strategy's round must be W-representation agnostic."""

    @pytest.mark.parametrize("strategy,kw", [
        ("full", {}), ("randomk", {}), ("topk", {}),
        ("choco", {"gamma": 0.4}), ("quant", {}),
    ])
    @pytest.mark.parametrize("name", ["ring", "regular", "random-regular"])
    def test_round_matches_dense(self, strategy, kw, name):
        g = _graphs()[name]
        W = jnp.asarray(g.metropolis_hastings(), jnp.float32)
        st_ = _dev(SparseTopology.from_graph(g))
        X = jax.random.normal(jax.random.key(5), (g.n, 96))
        budget = 0.2 if strategy not in ("full", "quant") else None
        s = make_sharing(strategy, budget, **kw)
        key = jax.random.key(6)
        deg = float(g.degrees().mean())
        Xd, std, bd = s.round(X, W, s.init_state(X), key, deg, rnd=1)
        Xs, sts, bs = s.round(X, st_, s.init_state(X), key, deg, rnd=1)
        np.testing.assert_allclose(np.asarray(Xd), np.asarray(Xs),
                                   rtol=5e-5, atol=5e-6)
        assert float(bd) == pytest.approx(float(bs))
        for a, b in zip(jax.tree_util.tree_leaves(std), jax.tree_util.tree_leaves(sts)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=5e-5, atol=5e-6)

    @pytest.mark.parametrize("name", ["ring", "regular"])
    def test_secure_round_matches_dense(self, name):
        g = _graphs()[name]
        W = jnp.asarray(g.metropolis_hastings(), jnp.float32)
        st_ = _dev(SparseTopology.from_graph(g))
        X = jax.random.normal(jax.random.key(7), (g.n, 64))
        s = SecureAggregation(g.adj, mask_bound=1.5)
        key = jax.random.key(8)
        Xd, _, bd = s.round(X, W, (), key, degree=4.0, rnd=2)
        Xs, _, bs = s.round(X, st_, (), key, degree=4.0, rnd=2)
        # identical PRF bits either way; only the weight source differs
        np.testing.assert_allclose(np.asarray(Xd), np.asarray(Xs),
                                   rtol=2e-5, atol=2e-5)
        assert float(bd) == pytest.approx(float(bs))

    def test_secure_round_matches_reference_via_kernel(self):
        """The fused-kernel path keeps the reference oracle equivalence."""
        g = Graph.regular_circulant(10, 4)
        W = jnp.asarray(g.metropolis_hastings(), jnp.float32)
        X = jax.random.normal(jax.random.key(9), (10, 80))
        s = SecureAggregation(g.adj, mask_bound=2.0)
        key = jax.random.key(10)
        got, _, _ = s.round(X, W, (), key, degree=4.0, rnd=3)
        want, _, _ = s.round_reference(X, W, (), key, degree=4.0, rnd=3)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)


class TestSparseReweight:
    @given(st.integers(0, 15))
    @settings(max_examples=10, deadline=None)
    def test_matches_dense_reweight(self, seed):
        g = Graph.random_regular(12, 4, seed)
        W = jnp.asarray(g.metropolis_hastings(), jnp.float32)
        st_ = _dev(SparseTopology.from_graph(g))
        act = jnp.asarray(
            np.random.default_rng(seed).random(12) < 0.6, jnp.float32
        )
        Wd, degd = participation_reweight(W, act)
        ts, degs = participation_reweight_sparse(st_, act)
        dense_of_sparse = SparseTopology(
            np.asarray(ts.nbr), np.asarray(ts.w), np.asarray(ts.w_self)
        ).to_dense()
        np.testing.assert_allclose(dense_of_sparse, np.asarray(Wd), atol=1e-6)
        assert float(degd) == pytest.approx(float(degs), abs=1e-5)

    def test_down_rows_identity_no_dense_materialization(self):
        g = Graph.regular_circulant(8, 4)
        st_ = _dev(SparseTopology.from_graph(g))
        act = jnp.asarray([1, 1, 0, 1, 1, 0, 1, 1], jnp.float32)
        ts, _ = participation_reweight_sparse(st_, act)
        w, ws = np.asarray(ts.w), np.asarray(ts.w_self)
        for i in (2, 5):
            assert (w[i] == 0).all() and ws[i] == pytest.approx(1.0)
        # surviving rows stay stochastic
        np.testing.assert_allclose(w.sum(1) + ws, np.ones(8), atol=1e-6)


class TestBatchedKernels:
    @pytest.mark.parametrize("B,K,M", [(4, 3, 100), (16, 7, 1000), (2, 2, 65536 + 3)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_gossip_mix_nodes(self, B, K, M, dtype):
        nb = jax.random.normal(jax.random.key(B * M), (B, K, M), jnp.float32).astype(dtype)
        w = jax.random.uniform(jax.random.key(1), (B, K))
        got = ops.gossip_mix_nodes(nb, w)
        want = ref.gossip_mix_nodes_ref(nb, w)
        tol = 1e-5 if dtype == jnp.float32 else 1e-2
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32), rtol=tol, atol=tol)

    @pytest.mark.parametrize("B,K,M", [(5, 4, 120), (12, 6, 900)])
    def test_secure_mask_apply_nodes(self, B, K, M):
        x = jax.random.normal(jax.random.key(0), (B, M))
        bits = jax.random.bits(jax.random.key(1), (B, K, M), jnp.uint32)
        signs = jnp.asarray(
            np.random.default_rng(2).choice([-1.0, 0.0, 1.0], (B, K)), jnp.float32
        )
        got = ops.secure_mask_apply_nodes(x, bits, signs, 0.8)
        want = ref.secure_mask_apply_nodes_ref(x, bits, signs, 0.8)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)


def _engine(dl):
    ds = make_dataset("cifar10", n_train=256, n_test=64, sigma=0.8, shape=(8, 8, 3))
    parts = sharding_partition(ds.train_y, dl.n_nodes, 2, seed=0)
    batcher = NodeBatcher(ds.train_x, ds.train_y, parts, 8, seed=0)

    def loss_fn(p, x, y):
        return cross_entropy(mlp_apply(p, x), y)

    def acc_fn(p, x, y):
        return (mlp_apply(p, x).argmax(-1) == y).mean()

    init = lambda k: mlp_init(k, in_dim=8 * 8 * 3, hidden=16)
    return RoundEngine(dl, init, loss_fn, acc_fn, make_optimizer("sgd", 0.05), batcher)


def _flat(params):
    return np.concatenate([np.asarray(l).reshape(-1)
                           for l in jax.tree_util.tree_leaves(params)])


class TestEngineSparseVsDense:
    @pytest.mark.parametrize("cfg", [
        dict(topology="regular", degree=4),
        dict(topology="dynamic", degree=5),
        dict(topology="regular", degree=4, participation=0.6),
        dict(topology="regular", degree=4, sharing="topk", budget=0.2),
        dict(topology="regular", degree=4, secure=True),
    ], ids=["regular", "dynamic", "churn", "topk", "secure"])
    def test_trajectories_match(self, cfg):
        outs = {}
        for mixing in ("dense", "sparse"):
            dl = DLConfig(n_nodes=8, rounds=4, eval_every=3, chunk_rounds=2,
                          seed=2, mixing=mixing, **cfg)
            e = _engine(dl)
            assert e.mix_mode == mixing
            e.run(log=False)
            outs[mixing] = (_flat(e.params), e.bytes_sent, e.sim_time_s)
        pd, bd, _ = outs["dense"]
        ps, bs, _ = outs["sparse"]
        np.testing.assert_allclose(ps, pd, rtol=5e-4, atol=5e-5)
        assert bs == pytest.approx(bd, rel=1e-6)

    def test_round_time_matches_dense(self):
        times = {}
        for mixing in ("dense", "sparse"):
            dl = DLConfig(n_nodes=8, topology="regular", degree=4, rounds=3,
                          eval_every=2, network="lan", compute_time_s=0.01,
                          mixing=mixing)
            e = _engine(dl)
            e.run(log=False)
            times[mixing] = e.sim_time_s
        assert times["sparse"] == pytest.approx(times["dense"], rel=1e-5)

    def test_auto_mode_selection(self):
        for topo, want in [("regular", "sparse"), ("ring", "sparse"),
                           ("dynamic", "sparse"), ("fully", "dense"),
                           ("star", "dense")]:
            dl = DLConfig(n_nodes=8, topology=topo, degree=4, rounds=1)
            assert _engine(dl).mix_mode == want, topo

    def test_sparse_dynamic_keeps_full_chunks(self):
        """The (R, N, D) stack is exempt from the W-stack byte cap: chunks
        stay at the requested length and staging is O(N·d) per round."""
        dl = DLConfig(n_nodes=128, topology="dynamic", degree=5, rounds=4,
                      eval_every=10, chunk_rounds=4, mixing="sparse")
        e = _engine(dl)
        assert e.chunk == 4
        e.run(log=False)
        # 4 rounds of (N, D) int32+f32 tables + (N,) diagonals ≪ 4·R·N²
        assert e.topo_stage_bytes_peak < 4 * 128 * 128 * 4

    def test_unknown_mixing_rejected(self):
        dl = DLConfig(n_nodes=8, mixing="banana")
        with pytest.raises(ValueError):
            _engine(dl)


class TestPayloadEquivalence:
    """Payload-form compressed sharing == the dense-mask oracle: every
    sparsified strategy, both W representations, quantized wire, the
    histogram selector, and the engine end-to-end across topology/churn."""

    @pytest.mark.parametrize("strategy,kw", [
        ("randomk", {}),
        ("randomk", {"sampler": "strided"}),
        ("topk", {}),
        ("choco", {"gamma": 0.4}),
        ("choco", {"compressor": "randk"}),
        ("randomk", {"quantize": "int8"}),
        ("topk", {"quantize": "int8"}),
        ("randomk", {"sampler": "strided", "quantize": "int8"}),
        ("topk", {"selector": "hist"}),
    ], ids=["randomk", "randomk-strided", "topk", "choco", "choco-randk",
            "randomk-int8", "topk-int8", "strided-int8", "topk-hist"])
    @pytest.mark.parametrize("name", ["ring", "random-regular"])
    def test_round_payload_matches_masked(self, strategy, kw, name):
        g = _graphs()[name]
        W = jnp.asarray(g.metropolis_hastings(), jnp.float32)
        st_ = _dev(SparseTopology.from_graph(g))
        X = jax.random.normal(jax.random.key(5), (g.n, 96))
        key = jax.random.key(6)
        outs = {}
        for payload in (True, False):
            s = make_sharing(strategy, 0.2, payload=payload, **kw)
            for Wf, tag in ((W, "dense"), (st_, "sparse")):
                X2, stt, nb = s.round(X, Wf, s.init_state(X), key, 4.0, rnd=1)
                outs[(payload, tag)] = (np.asarray(X2), float(nb),
                                        jax.tree_util.tree_leaves(stt))
        x_ref, nb_ref, st_ref = outs[(False, "dense")]
        for k_, (x2, nb, stt) in outs.items():
            np.testing.assert_allclose(x2, x_ref, rtol=5e-5, atol=5e-6,
                                       err_msg=str(k_))
            assert nb == pytest.approx(nb_ref), k_
            for a, b in zip(stt, st_ref):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           rtol=5e-5, atol=5e-6)

    def test_quantized_payload_bytes_and_dtype(self):
        s = make_sharing("topk", 0.1, quantize="int8")
        X = jax.random.normal(jax.random.key(0), (8, 100))
        _, _, nb = s.round(X, jnp.eye(8), s.init_state(X), jax.random.key(1), 4.0)
        # k=10 coords: 4B index + 1B code each, + 4B per-node scale header
        assert float(nb) == pytest.approx(4.0 * (10 * 5 + 4))
        assert s.wire_dtype(np.float32) == np.dtype(np.int8)
        s32 = make_sharing("topk", 0.1)
        assert s32.wire_dtype(np.float32) == np.dtype(np.float32)

    def test_full_sharing_bytes_track_dtype(self):
        from repro.core.sharing import FullSharing

        s = FullSharing()
        Xb = jax.random.normal(jax.random.key(0), (4, 64)).astype(jnp.bfloat16)
        _, _, nb = s.round(Xb, jnp.eye(4), (), jax.random.key(1), 3.0)
        assert float(nb) == pytest.approx(3.0 * 64 * 2)  # bf16 = 2 bytes/val

    def test_make_sharing_rejects_unused_args(self):
        with pytest.raises(ValueError, match="does not apply"):
            make_sharing("full", 0.2)
        with pytest.raises(ValueError, match="does not apply"):
            make_sharing("quant", 0.2)
        with pytest.raises(ValueError, match="invalid kwargs"):
            make_sharing("topk", 0.2, banana=1)
        with pytest.raises(ValueError, match="invalid kwargs"):
            make_sharing("randomk", 0.2, gamma=0.5)
        # valid kwargs still forwarded
        assert make_sharing("quant", stochastic=False).stochastic is False
        assert make_sharing("randomk", 0.2, sampler="strided").sampler == "strided"

    def test_topk_quantized_error_feedback(self):
        """last_shared must record the *dequantized* wire value so the
        quantization residual stays in the delta and is re-shared."""
        from repro.core.compression import dequantize_int8, quantize_int8

        s = make_sharing("topk", 0.1, quantize="int8")
        X = jax.random.normal(jax.random.key(0), (6, 50))
        st0 = s.init_state(X)
        X1 = X.at[:, :5].add(100.0)
        _, st1, _ = s.round(X1, jnp.eye(6), st0, jax.random.key(1), 4.0)
        idx = np.asarray(jax.lax.top_k(jnp.abs(X1 - st0["last_shared"]), 5)[1])
        vals = np.take_along_axis(np.asarray(X1), idx, axis=1)
        codes, scale = quantize_int8(jnp.asarray(vals))
        want = np.asarray(dequantize_int8(codes, scale))
        got = np.take_along_axis(np.asarray(st1["last_shared"]), idx, axis=1)
        np.testing.assert_allclose(got, want, rtol=1e-6)
        assert (np.abs(got - vals) > 0).any()  # residual is really nonzero

    def test_hist_selector_selects_above_threshold(self):
        from repro.core.sharing import _topk_idx

        x = jnp.abs(jax.random.normal(jax.random.key(3), (6, 4000)))
        k = 40
        idx = _topk_idx(x, k, selector="hist")
        assert idx.shape == (6, k)
        picked = np.asarray(jnp.take_along_axis(x, idx, axis=1))
        for r in range(6):
            assert len(set(np.asarray(idx[r]))) == k  # distinct
        # every selected magnitude within one fine bin of the exact top-k
        exact = np.asarray(jax.lax.top_k(x, k)[0])
        assert (picked.min(1) >= exact.min(1) * 0.95).all()


def _run_engine_pair(cfg, seed=2, rounds=4, n_nodes=8):
    """Engine trajectories with payload on vs off; everything else equal."""
    outs = {}
    for payload in ("on", "off"):
        dl = DLConfig(n_nodes=n_nodes, rounds=rounds, eval_every=3,
                      chunk_rounds=2, seed=seed, payload=payload, **cfg)
        e = _engine(dl)
        e.run(log=False)
        outs[payload] = (_flat(e.params), e.bytes_sent, e.share_stage_bytes,
                         e.wire_dtype)
    return outs


class TestEnginePayload:
    """DLConfig.payload on == off (the dense-mask oracle) end-to-end for
    every sparsified strategy × {static ring, dynamic 5-regular} ×
    {churn on/off} (the 8-device axis lives in test_sharded_engine)."""

    @pytest.mark.parametrize("churn", [False, True], ids=["all-up", "churn"])
    @pytest.mark.parametrize("topo", [
        dict(topology="ring"), dict(topology="dynamic", degree=5),
    ], ids=["ring", "dynamic"])
    @pytest.mark.parametrize("sharing", [
        dict(sharing="randomk", budget=0.2),
        dict(sharing="randomk", budget=0.2, randk_sampler="strided"),
        dict(sharing="topk", budget=0.2),
        dict(sharing="choco", budget=0.2),
    ], ids=["randomk", "randomk-strided", "topk", "choco"])
    def test_trajectories_match(self, sharing, topo, churn):
        cfg = {**sharing, **topo}
        if churn:
            cfg["participation"] = 0.6
        outs = _run_engine_pair(cfg)
        p_on, b_on, stage_on, dt_on = outs["on"]
        p_off, b_off, stage_off, _ = outs["off"]
        np.testing.assert_allclose(p_on, p_off, rtol=5e-4, atol=5e-5)
        assert b_on == pytest.approx(b_off, rel=1e-6)
        if sharing["sharing"] != "choco":  # choco stages payloads either way
            assert stage_on < stage_off  # compact payloads vs (N, P) masks
        assert dt_on == "float32"

    def test_quantized_payload_trajectories(self):
        outs = _run_engine_pair(dict(sharing="topk", budget=0.2,
                                     topology="ring", payload_quant=True))
        np.testing.assert_allclose(outs["on"][0], outs["off"][0],
                                   rtol=5e-4, atol=5e-5)
        assert outs["on"][3] == "int8"

    def test_chunk_invariance(self):
        """Payload trajectories must not depend on the scan chunking."""
        base = dict(sharing="topk", budget=0.2, topology="dynamic", degree=5)
        flats = {}
        for chunk in (1, 3, 4):
            dl = DLConfig(n_nodes=8, rounds=4, eval_every=4, chunk_rounds=chunk,
                          seed=3, payload="on", **base)
            e = _engine(dl)
            e.run(log=False)
            flats[chunk] = (_flat(e.params), e.bytes_sent)
        for chunk in (3, 4):
            np.testing.assert_array_equal(flats[chunk][0], flats[1][0])
            assert flats[chunk][1] == pytest.approx(flats[1][1])

    def test_payload_on_requires_sparsified(self):
        dl = DLConfig(n_nodes=8, sharing="full", payload="on")
        with pytest.raises(ValueError, match="sparsified"):
            _engine(dl)
        dl = DLConfig(n_nodes=8, sharing="full", payload_quant=True)
        with pytest.raises(ValueError, match="payload_quant"):
            _engine(dl)
        dl = DLConfig(n_nodes=8, sharing="topk", payload="banana")
        with pytest.raises(ValueError, match="payload mode"):
            _engine(dl)
        for kw in (dict(payload="on"), dict(payload_quant=True),
                   dict(randk_sampler="strided")):
            dl = DLConfig(n_nodes=8, topology="regular", degree=4,
                          secure=True, **kw)
            with pytest.raises(ValueError, match="secure"):
                _engine(dl)


class TestBatchedParticipationMask:
    def _engine(self, participation=0.5, seed=4):
        dl = DLConfig(n_nodes=16, topology="regular", degree=4, rounds=2,
                      participation=participation, seed=seed)
        return _engine(dl)

    def test_chunk_boundary_invariance(self):
        e = self._engine()
        full = e._participation_mask(0, 12)
        np.testing.assert_array_equal(full[3:7], e._participation_mask(3, 4))
        np.testing.assert_array_equal(full[7:12], e._participation_mask(7, 5))

    def test_at_least_one_alive_and_rate(self):
        e = self._engine(participation=0.05, seed=1)
        m = e._participation_mask(0, 400)
        assert (m.sum(1) >= 1).all()
        e2 = self._engine(participation=0.5, seed=1)
        m2 = e2._participation_mask(0, 400)
        assert abs(m2.mean() - 0.5) < 0.03

    def test_seed_dependence(self):
        a = self._engine(seed=1)._participation_mask(0, 8)
        b = self._engine(seed=2)._participation_mask(0, 8)
        assert (a != b).any()
