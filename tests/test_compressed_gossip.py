"""Compressed circulant gossip (shard_map wire): correctness on a fake
8-device mesh in a subprocess (device count locks at jax init)."""
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.core.mixing import mix_compressed_circulant_shmap, mix_dense
    from repro.core.topology import Graph
    mesh = jax.make_mesh((8,), ("data",))
    n, degree = 8, 4
    t = {"a": jax.random.normal(jax.random.key(0), (n, 33, 5)),
         "b": jax.random.normal(jax.random.key(1), (n, 257))}
    specs = {"a": P("data", None, None), "b": P("data", None)}
    W = jnp.asarray(Graph.regular_circulant(n, degree).metropolis_hastings(), jnp.float32)
    dense = mix_dense(t, W)

    # budget=1.0 sparse == dense mixing exactly (all coords shared)
    full = mix_compressed_circulant_shmap(t, specs, mesh, ("data",), degree,
                                          budget=1.0, mode="sparse")
    for l1, l2 in zip(jax.tree_util.tree_leaves(dense), jax.tree_util.tree_leaves(full)):
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=2e-5, atol=2e-6)

    # quant mode ~ dense mixing within int8 quantization error
    q = mix_compressed_circulant_shmap(t, specs, mesh, ("data",), degree,
                                       budget=1.0, mode="quant")
    for l0, l1, l2 in zip(jax.tree_util.tree_leaves(t),
                          jax.tree_util.tree_leaves(dense),
                          jax.tree_util.tree_leaves(q)):
        err = float(jnp.max(jnp.abs(l1 - l2)))
        qstep = float(jnp.max(jnp.abs(l0))) / 127.0
        assert err <= qstep * 4 + 1e-6, (err, qstep)

    # sparse budget<1: kept coords move toward neighbors, others unchanged;
    # global mean preserved only for shared coords — check the contraction
    # property instead: consensus distance must shrink
    sp = mix_compressed_circulant_shmap(t, specs, mesh, ("data",), degree,
                                        budget=0.3, mode="sparse")
    for l0, l2 in zip(jax.tree_util.tree_leaves(t), jax.tree_util.tree_leaves(sp)):
        d0 = float(jnp.linalg.norm(l0 - l0.mean(0, keepdims=True)))
        d2 = float(jnp.linalg.norm(l2 - jnp.asarray(l2).mean(0, keepdims=True)))
        assert d2 < d0, (d0, d2)
    print("COMPRESSED_OK")
""")


def test_compressed_gossip_modes():
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, timeout=600)
    assert "COMPRESSED_OK" in r.stdout, r.stdout + r.stderr
