"""Real-network process backend (``repro.runtime``).

Three layers, cheapest first:

- wire codec roundtrips (pure numpy, no processes),
- launcher validation errors (no processes),
- end-to-end multi-process runs over localhost TCP: the equivalence
  oracle (process trajectory == simulator trajectory on a loss-free
  network with deterministic seeds) and the kill test (SIGKILL one
  worker mid-run; survivors detect it, reweight, and converge).

The oracle is the correctness anchor for the whole backend: the worker
processes call the *same* jitted aggregation functions as the simulator
on zero-padded full-size arrays, so full-sharing fp32 runs must match
bitwise and int8 payload runs to ~1 ulp of the dequantization.
"""
import dataclasses
import json
import os
import sys

import jax
import numpy as np
import pytest

from repro.core import DLConfig, RoundEngine
from repro.runtime import ProcessRunner, build_workload
from repro.runtime import transport as T
from repro.utils.io import atomic_write_json
from repro.utils.pytree import tree_vector

# small, fast workload shared by every process test (width=1 keeps the
# per-worker jit compile short; the wire format is size-independent)
WL = {"dataset": "cifar10", "model": "mlp", "width": 1,
      "n_train": 256, "n_test": 128, "lr": 0.05}


def _sim_final_X(dl, rounds):
    """Simulator trajectory for the same config/workload: final (N, P)."""
    dl_sim = dataclasses.replace(dl, backend="simulated", rounds=rounds)
    init, loss, acc, opt, batcher = build_workload(WL, dl_sim)
    eng = RoundEngine(dl_sim, init, loss, acc, opt, batcher)
    hist = eng.run(log=False)
    return np.asarray(jax.vmap(tree_vector)(eng.params)), hist


# ---------------------------------------------------------------------------
# wire codec: encode/decode roundtrip for every ROWS format
# ---------------------------------------------------------------------------

class TestWireCodec:
    def test_full_f32_roundtrip(self):
        rng = np.random.default_rng(0)
        ids = np.array([3, 7, 11], np.int32)
        rows = rng.standard_normal((3, 9)).astype(np.float32)
        body = T.encode_rows(5, 2, ids, T.FMT_FULL_F32, rows=rows)
        out = T.decode_rows(body)
        assert (out["round"], out["sender"], out["fmt"]) == (5, 2, T.FMT_FULL_F32)
        np.testing.assert_array_equal(out["ids"], ids)
        np.testing.assert_array_equal(out["rows"], rows)

    def test_payload_f32_roundtrip(self):
        rng = np.random.default_rng(1)
        ids = np.arange(4, dtype=np.int32)
        idx = rng.integers(0, 100, (4, 6)).astype(np.int32)
        val = rng.standard_normal((4, 6)).astype(np.float32)
        out = T.decode_rows(
            T.encode_rows(0, 0, ids, T.FMT_PAYLOAD_F32, idx=idx, val=val)
        )
        np.testing.assert_array_equal(out["idx"], idx)
        np.testing.assert_array_equal(out["val"], val)

    def test_payload_i8_roundtrip(self):
        rng = np.random.default_rng(2)
        ids = np.array([1, 5], np.int32)
        idx = rng.integers(0, 50, (2, 3)).astype(np.int32)
        codes = rng.integers(-127, 128, (2, 3)).astype(np.int8)
        scale = rng.random(2).astype(np.float32)
        out = T.decode_rows(
            T.encode_rows(9, 1, ids, T.FMT_PAYLOAD_I8,
                          idx=idx, codes=codes, scale=scale)
        )
        np.testing.assert_array_equal(out["idx"], idx)
        np.testing.assert_array_equal(out["codes"], codes)
        np.testing.assert_array_equal(out["scale"], scale)

    def test_truncated_body_rejected(self):
        ids = np.array([0], np.int32)
        body = T.encode_rows(0, 0, ids, T.FMT_FULL_F32,
                             rows=np.zeros((1, 4), np.float32))
        with pytest.raises((ValueError, Exception)):
            T.decode_rows(body[:-2])

    def test_trailing_garbage_rejected(self):
        ids = np.array([0], np.int32)
        body = T.encode_rows(0, 0, ids, T.FMT_FULL_F32,
                             rows=np.zeros((1, 4), np.float32))
        with pytest.raises(ValueError, match="length mismatch"):
            T.decode_rows(body + b"xx")

    def test_wid_roundtrip(self):
        assert T.decode_wid(T.encode_wid(13)) == 13

    def test_epoch_stamped_rows(self):
        """Every ROWS frame carries the sender's membership epoch; the
        default 0 keeps pre-rejoin encodings identical in meaning."""
        ids = np.array([1, 2], np.int32)
        rows = np.zeros((2, 3), np.float32)
        body = T.encode_rows(7, 1, ids, T.FMT_FULL_F32, rows=rows)
        assert T.decode_rows(body)["epoch"] == 0
        body = T.encode_rows(7, 1, ids, T.FMT_FULL_F32, epoch=3, rows=rows)
        out = T.decode_rows(body)
        assert (out["round"], out["sender"], out["epoch"]) == (7, 1, 3)

    def test_peer_and_json_roundtrip(self):
        assert T.decode_peer(T.encode_peer(13, 2)) == (13, 2)
        msg = {"phase": "hello", "worker": 3, "epoch": 1, "port": 4242}
        assert T.decode_json(T.encode_json(msg)) == msg


# ---------------------------------------------------------------------------
# launcher validation (no processes spawned)
# ---------------------------------------------------------------------------

class TestRunnerValidation:
    def test_rejects_simulated_backend(self):
        with pytest.raises(ValueError, match="backend='processes'"):
            ProcessRunner(DLConfig(n_nodes=8), WL, workers=2)

    def test_rejects_uneven_row_blocks(self):
        with pytest.raises(ValueError, match="divide evenly"):
            ProcessRunner(DLConfig(n_nodes=10, backend="processes"), WL,
                          workers=4)

    def test_kill_knobs_come_as_a_pair(self):
        dl = DLConfig(n_nodes=8, backend="processes")
        with pytest.raises(ValueError, match="pair"):
            ProcessRunner(dl, WL, workers=2, kill_worker=1)
        with pytest.raises(ValueError, match="out of range"):
            ProcessRunner(dl, WL, workers=2, kill_worker=5, kill_at_round=1)

    def test_chaos_plan_validation(self):
        dl = DLConfig(n_nodes=8, backend="processes")
        with pytest.raises(ValueError, match="out of range"):
            ProcessRunner(dl, WL, workers=2,
                          chaos_plan=[{"worker": 7, "kill_at_round": 1}])
        with pytest.raises(ValueError, match="kill_at_round"):
            ProcessRunner(dl, WL, workers=2,
                          chaos_plan=[{"worker": 1, "kill_at_round": -1}])

    def test_legacy_kill_pair_becomes_no_rejoin_entry(self):
        dl = DLConfig(n_nodes=8, backend="processes")
        r = ProcessRunner(dl, WL, workers=2, kill_worker=1, kill_at_round=2)
        assert r.chaos_plan == [
            {"worker": 1, "kill_at_round": 2, "rejoin": False}
        ]

    def test_chaos_plan_defaults_rejoin_true_and_sorts(self):
        dl = DLConfig(n_nodes=8, backend="processes")
        r = ProcessRunner(dl, WL, workers=2, chaos_plan=[
            {"worker": 1, "kill_at_round": 9},
            {"worker": 0, "kill_at_round": 2, "rejoin": False},
        ])
        assert [e["kill_at_round"] for e in r.chaos_plan] == [2, 9]
        assert r.chaos_plan[1]["rejoin"] is True


# ---------------------------------------------------------------------------
# atomic results writes (satellite: crash-safe benchmarks/common.save_results)
# ---------------------------------------------------------------------------

class TestAtomicWrite:
    def test_atomic_write_json_leaves_no_tmp(self, tmp_path):
        path = str(tmp_path / "sub" / "r.json")
        atomic_write_json(path, [{"a": 1}])
        atomic_write_json(path, [{"a": 2}])  # overwrite goes through replace
        with open(path) as f:
            assert json.load(f) == [{"a": 2}]
        assert os.listdir(tmp_path / "sub") == ["r.json"]

    def test_save_results_is_atomic(self, tmp_path, monkeypatch):
        sys.path.insert(0, os.path.dirname(os.path.dirname(__file__)))
        try:
            from benchmarks import common
        finally:
            sys.path.pop(0)
        monkeypatch.setattr(common, "RESULTS_DIR", str(tmp_path))
        common.save_results("smoke", [{"name": "x", "acc_mean": 0.5}])
        with open(tmp_path / "smoke.json") as f:
            recs = json.load(f)
        assert recs[0]["name"] == "x" and recs[-1]["name"] == "_memory"
        assert not any(p.endswith(".tmp") for p in os.listdir(tmp_path))


# ---------------------------------------------------------------------------
# end-to-end: real sockets, real processes
# ---------------------------------------------------------------------------

ROUNDS = 5


class TestProcessBackend:
    def test_equivalence_oracle_full_sharing(self):
        """Loss-free localhost, deterministic seeds: the K-process run
        must reproduce the simulator trajectory (fp32 full sharing is
        bitwise; we assert a tight fp32 tolerance)."""
        dl = DLConfig(n_nodes=16, topology="regular", degree=5,
                      rounds=ROUNDS, eval_every=2, backend="processes",
                      seed=3)
        r = ProcessRunner(dl, WL, workers=4, watchdog_s=120.0)
        hist = r.run(log=False)
        X_sim, hist_sim = _sim_final_X(dl, ROUNDS)
        assert r.final_X.shape == X_sim.shape
        np.testing.assert_allclose(r.final_X, X_sim, rtol=0, atol=1e-6)
        # eval records line up round-for-round
        sim_acc = {h["round"]: h["acc_mean"] for h in hist_sim}
        for h in hist:
            assert h["round"] in sim_acc
            assert abs(h["acc_mean"] - sim_acc[h["round"]]) < 1e-6
        assert r.bytes_sent > 0 and r.counters["faults_detected"] == 0
        assert r.wire_dtype == "float32"

    def test_equivalence_oracle_randomk_int8(self):
        """Sparsified int8 payload over the wire: trajectory matches the
        simulator's quantized path to ~1 ulp of the dequantization."""
        dl = DLConfig(n_nodes=16, topology="regular", degree=5,
                      sharing="randomk", budget=0.25, payload_quant=True,
                      rounds=ROUNDS, eval_every=ROUNDS, backend="processes",
                      seed=4)
        r = ProcessRunner(dl, WL, workers=4, watchdog_s=120.0)
        r.run(log=False)
        X_sim, _ = _sim_final_X(dl, ROUNDS)
        np.testing.assert_allclose(r.final_X, X_sim, rtol=0, atol=1e-5)
        assert r.wire_dtype == "int8"

    def test_kill_worker_detect_reweight_converge(self):
        """SIGKILL one worker mid-run: every survivor's heartbeat
        detector fires, its rows are reweighted away (surviving rows stay
        row-stochastic), and the run completes all rounds."""
        dl = DLConfig(n_nodes=16, topology="regular", degree=5,
                      rounds=8, eval_every=4, backend="processes", seed=5)
        r = ProcessRunner(dl, WL, workers=4, watchdog_s=120.0,
                          kill_worker=3, kill_at_round=2)
        hist = r.run(log=False)
        assert r.killed_at_round is not None
        assert r.counters["faults_detected"] >= 1
        assert r.reweight_row_err < 1e-5
        assert int(r.live_rows.sum()) == 12
        assert hist[-1]["round"] == 7  # survivors finished every round
        assert np.isfinite(r.final_X[r.live_rows]).all()
        assert np.isnan(r.final_X[~r.live_rows]).all()
        assert np.isfinite(r.consensus_error())

    def test_kill_rejoin_heals_the_mesh(self, tmp_path):
        """Elastic membership end-to-end: SIGKILL one worker, relaunch it
        with --rejoin — it catches up (checkpoint or donor STATE), the
        survivors re-admit it at a committed round with pristine edge
        weights, every round completes, detection/rejoin conservation
        holds on every worker, and the rejoiner's final row-block matches
        a survivor's view of it bitwise."""
        dl = DLConfig(n_nodes=16, topology="regular", degree=5,
                      rounds=30, eval_every=10, backend="processes", seed=7)
        r = ProcessRunner(
            dl, WL, workers=4, watchdog_s=120.0,
            chaos_plan=[{"worker": 2, "kill_at_round": 3, "rejoin": True}],
            ckpt_every=4, round_min_s=0.35, dump_view=True,
            keep_run_dir=True, run_dir=str(tmp_path),
        )
        hist = r.run(log=False)
        assert r.workers_rejoined == 1
        assert r.counters["rejoin_total"] >= 1
        assert r.conservation["ok"], r.conservation
        assert hist[-1]["round"] == 29            # nobody stalled
        assert hist[-1]["n_live_rows"] == 16      # all rows healed
        res = r.worker_results[2]
        assert res["rejoined"] and res["completed"]
        assert res["epoch"] == 1
        assert res["catchup_source"] is not None
        assert res["counters"]["catchup_bytes"] > 0
        views = r.verify_rejoin_views()
        assert views == {2: True}
        assert np.isfinite(r.final_X).all()       # no NaN rows remain
        assert np.isfinite(r.consensus_error())
