"""RoundEngine: chunk-size invariance, participation (churn) semantics,
heterogeneous per-node learning rates, secure-in-scan, simulated time."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DLConfig, DecentralizedRunner, RoundEngine, participation_reweight
from repro.core.topology import Graph
from repro.data import NodeBatcher, make_dataset, sharding_partition
from repro.models.api import cross_entropy
from repro.models.mlp import mlp_apply, mlp_init
from repro.optim import make_optimizer


def _setup(n_nodes=8, n_train=512, bs=8, hidden=32):
    ds = make_dataset("cifar10", n_train=n_train, n_test=128, sigma=0.8,
                      shape=(8, 8, 3))
    parts = sharding_partition(ds.train_y, n_nodes, 2, seed=0)
    batcher = NodeBatcher(ds.train_x, ds.train_y, parts, bs, seed=0)

    def loss_fn(p, x, y):
        return cross_entropy(mlp_apply(p, x), y)

    def acc_fn(p, x, y):
        return (mlp_apply(p, x).argmax(-1) == y).mean()

    init = lambda k: mlp_init(k, in_dim=8 * 8 * 3, hidden=hidden)
    return init, loss_fn, acc_fn, batcher


def _engine(dl, hlrs=None, opt=None):
    init, loss, acc, batcher = _setup(n_nodes=dl.n_nodes)
    return RoundEngine(dl, init, loss, acc, opt or make_optimizer("sgd", 0.05),
                       batcher, heterogeneous_lrs=hlrs)


def _flat(params):
    return np.concatenate([np.asarray(l).reshape(-1)
                           for l in jax.tree_util.tree_leaves(params)])


class TestChunkInvariance:
    def test_chunk_sizes_give_identical_trajectories(self):
        """Scanned execution is a pure re-batching of the same per-round
        program: chunk sizes 1, 3, 8 must produce identical params/bytes."""
        results = {}
        for chunk in (0, 1, 3, 8):  # 0 = legacy per-round dispatch
            dl = DLConfig(n_nodes=8, topology="regular", degree=4, rounds=8,
                          eval_every=4, local_steps=2, chunk_rounds=chunk)
            e = _engine(dl)
            e.run(log=False)
            results[chunk] = (_flat(e.params), e.bytes_sent)
        base, base_bytes = results[1]
        for chunk in (0, 3, 8):
            got, got_bytes = results[chunk]
            np.testing.assert_allclose(got, base, rtol=2e-5, atol=1e-6)
            assert got_bytes == pytest.approx(base_bytes, rel=1e-6)

    def test_history_cadence_matches_legacy(self):
        dl = DLConfig(n_nodes=8, rounds=11, eval_every=4, chunk_rounds=8)
        e = _engine(dl)
        hist = e.run(log=False)
        assert [h["round"] for h in hist] == [0, 4, 8, 10]


class TestParticipationReweight:
    def test_full_participation_is_identity_on_edges(self):
        g = Graph.regular_circulant(8, 4)
        W = jnp.asarray(g.metropolis_hastings(), jnp.float32)
        Wm, deg = participation_reweight(W, jnp.ones(8))
        np.testing.assert_allclose(np.asarray(Wm), np.asarray(W), atol=1e-6)
        assert float(deg) == pytest.approx(4.0)

    def test_down_nodes_become_identity_rows(self):
        g = Graph.regular_circulant(8, 4)
        W = jnp.asarray(g.metropolis_hastings(), jnp.float32)
        act = jnp.asarray([1, 1, 0, 1, 1, 0, 1, 1], jnp.float32)
        Wm, deg = participation_reweight(W, act)
        Wm = np.asarray(Wm)
        for i in (2, 5):
            want = np.zeros(8)
            want[i] = 1.0
            np.testing.assert_allclose(Wm[i], want, atol=1e-6)
            np.testing.assert_allclose(Wm[:, i], want, atol=1e-6)  # symmetric
        np.testing.assert_allclose(Wm.sum(1), np.ones(8), atol=1e-5)
        # effective degree only counts live-live edges, averaged over live nodes
        assert float(deg) < 4.0

    def test_churn_run_sends_fewer_bytes(self):
        accs = {}
        byts = {}
        for p in (1.0, 0.5):
            dl = DLConfig(n_nodes=8, topology="regular", degree=4, rounds=6,
                          eval_every=5, participation=p, seed=3)
            e = _engine(dl)
            e.run(log=False)
            byts[p] = e.bytes_sent
            accs[p] = e.history[-1]["acc_mean"]
        assert byts[0.5] < 0.7 * byts[1.0]
        assert accs[0.5] > 0.1  # still trains

    @pytest.mark.parametrize("sharing", ["full", "quant"])
    def test_down_node_params_frozen_through_round(self, sharing):
        """A node that never participates keeps its initial params — even
        for strategies like quant whose identity-row aggregation would
        otherwise hand it a lossy roundtrip of its own params."""
        dl = DLConfig(n_nodes=4, topology="fully", rounds=3, eval_every=2,
                      participation=0.5, seed=0, sharing=sharing)
        e = _engine(dl)
        p0 = jax.tree_util.tree_map(np.asarray, e.params)
        masks = e._participation_mask(0, 3)
        e.run(log=False)
        never_active = np.nonzero(~masks.any(0).astype(bool))[0]
        for i in never_active:
            for a, b in zip(jax.tree_util.tree_leaves(p0),
                            jax.tree_util.tree_leaves(e.params)):
                np.testing.assert_allclose(np.asarray(b)[i], a[i], atol=1e-6)

    def test_down_node_sharing_state_frozen(self):
        """A down node transmits nothing, so its sharing bookkeeping (TopK
        last_shared) must not advance for that round."""
        dl = DLConfig(n_nodes=4, topology="fully", rounds=3, eval_every=2,
                      participation=0.5, sharing="topk", budget=0.2, seed=0)
        e = _engine(dl)
        s0 = np.asarray(e.share_state["last_shared"]).copy()
        masks = e._participation_mask(0, 3)
        e.run(log=False)
        never_active = np.nonzero(~masks.any(0).astype(bool))[0]
        s1 = np.asarray(e.share_state["last_shared"])
        for i in never_active:
            np.testing.assert_allclose(s1[i], s0[i], atol=1e-6)

    def test_secure_plus_churn_rejected(self):
        dl = DLConfig(n_nodes=8, topology="regular", degree=4, secure=True,
                      participation=0.9)
        with pytest.raises(ValueError):
            _engine(dl)


class TestHeterogeneousLRs:
    def test_zero_scales_equal_zero_lr(self):
        dl = DLConfig(n_nodes=8, topology="regular", degree=4, rounds=4,
                      eval_every=3)
        e0 = _engine(dl, hlrs=np.zeros(8), opt=make_optimizer("sgd", 0.05))
        e0.run(log=False)
        e1 = _engine(dl, opt=make_optimizer("sgd", 0.0))
        e1.run(log=False)
        np.testing.assert_allclose(_flat(e0.params), _flat(e1.params),
                                   rtol=1e-6, atol=1e-7)

    def test_unit_scales_equal_default(self):
        dl = DLConfig(n_nodes=8, topology="regular", degree=4, rounds=4,
                      eval_every=3)
        e0 = _engine(dl, hlrs=np.ones(8))
        e0.run(log=False)
        e1 = _engine(dl)
        e1.run(log=False)
        np.testing.assert_allclose(_flat(e0.params), _flat(e1.params),
                                   rtol=1e-6, atol=1e-7)

    def test_runner_forwards_heterogeneous_lrs(self):
        init, loss, acc, batcher = _setup()
        dl = DLConfig(n_nodes=8, topology="regular", degree=4, rounds=2,
                      eval_every=1)
        r = DecentralizedRunner(dl, init, loss, acc, make_optimizer("sgd", 0.05),
                                batcher, heterogeneous_lrs=np.zeros(8))
        assert r.engine.lr_scales is not None
        r.run(log=False)

    def test_bad_shape_rejected(self):
        dl = DLConfig(n_nodes=8)
        with pytest.raises(AssertionError):
            _engine(dl, hlrs=np.ones(4))


class TestSecureInScan:
    def test_secure_runs_through_chunked_scan(self):
        """secure=True goes through the same compiled chunk path and keeps
        the paper's 3% byte overhead and the plain-MH trajectory."""
        hists = {}
        byts = {}
        for secure in (False, True):
            dl = DLConfig(n_nodes=8, topology="regular", degree=4, rounds=8,
                          eval_every=7, secure=secure, seed=5, chunk_rounds=4)
            e = _engine(dl)
            assert e.chunk == 4
            hists[secure] = e.run(log=False)
            byts[secure] = e.bytes_sent
        assert byts[True] == pytest.approx(1.03 * byts[False], rel=1e-6)
        assert abs(hists[True][-1]["acc_mean"] - hists[False][-1]["acc_mean"]) < 0.06


class TestSimulatedTime:
    def test_sim_time_collected_per_chunk(self):
        dl = DLConfig(n_nodes=8, topology="regular", degree=4, rounds=4,
                      eval_every=3, network="lan", compute_time_s=0.01)
        e = _engine(dl)
        hist = e.run(log=False)
        assert e.sim_time_s > 4 * 0.01  # at least compute time per round
        assert hist[-1]["sim_time_s"] == pytest.approx(e.sim_time_s)

    def test_denser_topology_takes_longer_simulated(self):
        """Paper Fig. 3b inside the engine: fully-connected rounds cost more
        simulated wall-clock than ring at equal round count."""
        times = {}
        for topo in ("ring", "fully"):
            dl = DLConfig(n_nodes=16, topology=topo, rounds=3, eval_every=2,
                          network="lan")
            e = _engine(dl)
            e.run(log=False)
            times[topo] = e.sim_time_s
        assert times["fully"] > 2.5 * times["ring"]

    def test_wan_slower_than_lan(self):
        times = {}
        for net in ("lan", "wan"):
            dl = DLConfig(n_nodes=8, topology="regular", degree=4, rounds=3,
                          eval_every=2, network=net)
            e = _engine(dl)
            e.run(log=False)
            times[net] = e.sim_time_s
        assert times["wan"] > 5 * times["lan"]


class TestLegacyPath:
    def test_legacy_dispatch_still_works(self):
        dl = DLConfig(n_nodes=8, topology="regular", degree=4, rounds=4,
                      eval_every=3, chunk_rounds=0)
        e = _engine(dl)
        assert e.chunk == 0
        hist = e.run(log=False)
        assert [h["round"] for h in hist] == [0, 3]
        assert e.bytes_sent > 0
