"""MoE dispatch: the sort/gather capacity dispatch must equal the naive
per-token dense evaluation when capacity is unconstrained, and respect
capacity when constrained."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ModelConfig
from repro.models.moe import moe_apply, moe_init


def _cfg(E=4, k=2, cap=8.0, shared=0):
    return ModelConfig(
        name="t", family="moe", d_model=32, d_ff=64, d_expert=48, n_experts=E,
        moe_top_k=k, n_shared_experts=shared, capacity_factor=cap, aux_loss_coef=0.01)


def _dense_reference(p, cfg, x):
    """Naive: every token through its top-k experts, no capacity."""
    B, S, D = x.shape
    xt = np.asarray(x.reshape(B * S, D), np.float32)
    logits = xt @ np.asarray(p["router"], np.float32)
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs /= probs.sum(-1, keepdims=True)
    out = np.zeros_like(xt)
    for t in range(xt.shape[0]):
        top = np.argsort(-probs[t])[: cfg.moe_top_k]
        gv = probs[t, top] / probs[t, top].sum()
        for e, g in zip(top, gv):
            pre = xt[t] @ np.asarray(p["w_gate"][e])
            h = pre / (1 + np.exp(-pre)) * (xt[t] @ np.asarray(p["w_up"][e]))
            out[t] += g * (h @ np.asarray(p["w_down"][e]))
    return out.reshape(B, S, D)


class TestMoEDispatch:
    @pytest.mark.parametrize("E,k", [(4, 1), (4, 2), (8, 3)])
    def test_matches_dense_reference(self, E, k):
        cfg = _cfg(E=E, k=k, cap=float(E))  # capacity >= T*k/E*E = no drops
        p = moe_init(jax.random.key(0), cfg)
        x = jax.random.normal(jax.random.key(1), (2, 8, cfg.d_model))
        got, aux = moe_apply(p, cfg, x)
        want = _dense_reference(p, cfg, x)
        np.testing.assert_allclose(np.asarray(got), want, rtol=2e-4, atol=2e-5)
        assert aux > 0

    def test_capacity_drops_tokens(self):
        """With capacity_factor << 1 some tokens must be dropped (their
        output contribution is smaller)."""
        cfg_lo = _cfg(cap=0.25)
        cfg_hi = _cfg(cap=8.0)
        p = moe_init(jax.random.key(0), cfg_lo)
        x = jax.random.normal(jax.random.key(1), (2, 32, cfg_lo.d_model))
        out_lo, _ = moe_apply(p, cfg_lo, x)
        out_hi, _ = moe_apply(p, cfg_hi, x)
        assert float(jnp.linalg.norm(out_lo)) < float(jnp.linalg.norm(out_hi))

    def test_shared_expert_added(self):
        cfg = _cfg(shared=1)
        p = moe_init(jax.random.key(0), cfg)
        x = jax.random.normal(jax.random.key(1), (1, 4, cfg.d_model))
        out, _ = moe_apply(p, cfg, x)
        # zero the routed experts: output must equal the shared path alone
        p2 = dict(p)
        p2["w_down"] = jnp.zeros_like(p["w_down"])
        out_shared, _ = moe_apply(p2, cfg, x)
        xt = x.reshape(4, cfg.d_model)
        sp = p["shared"]
        want = (jax.nn.silu(xt @ sp["w_gate"]) * (xt @ sp["w_up"])) @ sp["w_down"]
        np.testing.assert_allclose(
            np.asarray(out_shared.reshape(4, -1)), np.asarray(want), rtol=2e-4, atol=1e-5
        )

    def test_aux_loss_uniform_router_is_one_coef(self):
        """Perfectly uniform routing -> aux ~ coef (E * mean*frac = 1)."""
        cfg = _cfg(E=4, k=1)
        p = moe_init(jax.random.key(0), cfg)
        p = dict(p)
        p["router"] = jnp.zeros_like(p["router"])  # uniform probs
        x = jax.random.normal(jax.random.key(1), (2, 64, cfg.d_model))
        _, aux = moe_apply(p, cfg, x)
        np.testing.assert_allclose(float(aux), cfg.aux_loss_coef, rtol=0.05)

    def test_grad_flows(self):
        cfg = _cfg()
        p = moe_init(jax.random.key(0), cfg)
        x = jax.random.normal(jax.random.key(1), (1, 8, cfg.d_model))

        def loss(p):
            o, aux = moe_apply(p, cfg, x)
            return jnp.sum(o**2) + aux

        g = jax.grad(loss)(p)
        assert float(jnp.abs(g["router"]).sum()) > 0
        assert float(jnp.abs(g["w_gate"]).sum()) > 0
