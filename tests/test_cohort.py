"""Population-scale cohort activation (``DLConfig.cohort_capacity``):
the async scheduler's gather/scatter path must be *bitwise* equivalent to
the dense async oracle whenever the capacity covers every firing node
(C = N), across the scenario axes (stragglers, churn, network model,
pairwise gossip, dynamic topology); overflow-carry must defer — never
drop — excess firings so homogeneous nodes stay fair; the graph-free
circulant neighbor table must match the dense ``Graph`` constructor
bit-for-bit; the fp64 virtual-clock rebase must not perturb
trajectories; and the device-side per-node batch keying must draw the
same samples for any gathered row subset.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DLConfig, RoundEngine
from repro.core.topology import (
    Graph,
    SparseTopology,
    circulant_neighbor_table,
)
from repro.data import NodeBatcher, make_dataset, sharding_partition
from repro.data.loader import node_batch_indices
from repro.optim import make_optimizer

SHAPE = (2, 2, 1)


def _loss(p, x, y):
    t = x.reshape(x.shape[0], -1).mean(0)
    return jnp.mean((p["w"].reshape(-1, t.shape[0]) - t) ** 2)


def _acc(p, x, y):
    return -_loss(p, x, y)


def _engine(p_dim: int = 8, **kw) -> RoundEngine:
    n = kw.setdefault("n_nodes", 12)
    ds = make_dataset("cifar10", n_train=256, n_test=32, shape=SHAPE, sigma=2.0)
    parts = sharding_partition(ds.train_y, n, 2, seed=0)
    batcher = NodeBatcher(ds.train_x, ds.train_y, parts, batch_size=4, seed=0)
    kw.setdefault("chunk_rounds", 4)
    kw.setdefault("eval_every", 6)
    kw.setdefault("semantics", "async")
    kw.setdefault("compute_time_s", 1e-3)
    kw.setdefault("batch_keying", "node")
    dl = DLConfig(local_steps=1, batch_size=4, **kw)
    init = lambda key: {"w": jax.random.normal(key, (p_dim,))}
    return RoundEngine(dl, init, _loss, _acc, make_optimizer("sgd", 0.05), batcher)


def _w(e):
    return np.asarray(jax.vmap(lambda p: p["w"])(e.params))


# ---------------------------------------------------------------------------
# cohort == dense async oracle (bitwise) whenever C covers every firing node
# ---------------------------------------------------------------------------

SCENARIOS = {
    "base": dict(topology="regular", degree=4),
    "stragglers": dict(topology="regular", degree=4, straggler_frac=0.5,
                       straggler_factor=3.0),
    "churn": dict(topology="regular", degree=4, participation=0.7),
    "churn_lan": dict(topology="regular", degree=4, participation=0.7,
                      network="lan"),
    "pairwise_churn": dict(topology="regular", degree=4,
                           async_gossip="pairwise", participation=0.8),
    "dynamic": dict(topology="dynamic", degree=4),
}


class TestCohortEquivalence:
    @pytest.mark.parametrize("scenario", sorted(SCENARIOS), ids=sorted(SCENARIOS))
    def test_full_capacity_cohort_matches_dense_oracle(self, scenario):
        """C = N: every step's firing set fits the cohort, so the
        gather -> step -> scatter round trip must reproduce the dense
        (N, ...) path bit-for-bit — params, event counts, staleness,
        virtual clocks, bytes."""
        kw = SCENARIOS[scenario]
        dense = _engine(rounds=12, seed=3, **kw)
        coh = _engine(rounds=12, seed=3, cohort_capacity=12, **kw)
        dense.run(log=False)
        coh.run(log=False)
        np.testing.assert_array_equal(_w(dense), _w(coh))
        np.testing.assert_array_equal(np.asarray(dense.scheduler._events),
                                      np.asarray(coh.scheduler._events))
        assert coh.bytes_sent == dense.bytes_sent
        assert coh.sim_time_s == pytest.approx(dense.sim_time_s, rel=1e-9)
        md, mc = dense.history[-1], coh.history[-1]
        for k in ("events_total", "staleness_mean", "vclock_max_s",
                  "vclock_median_s"):
            assert mc[k] == pytest.approx(md[k], rel=1e-6), k

    def test_cohort_uses_node_batch_keying_samples(self):
        """Guard: the equivalence above is only meaningful because BOTH
        sides run batch_keying='node' — the dense oracle under 'stream'
        keying draws a different (equally valid) sample stream."""
        a = _engine(rounds=8, seed=0, topology="regular", degree=4)
        b = _engine(rounds=8, seed=0, topology="regular", degree=4,
                    batch_keying="stream")
        a.run(log=False)
        b.run(log=False)
        assert not np.array_equal(_w(a), _w(b))


# ---------------------------------------------------------------------------
# overflow-carry: capacity pressure defers firings, never drops them
# ---------------------------------------------------------------------------

class TestOverflowCarry:
    def test_homogeneous_nodes_stay_fair_under_capacity_pressure(self):
        """N=12 homogeneous nodes at C=4: every step 12 nodes tie on the
        virtual clock but only the 4 earliest fire; the other 8 keep
        their t_next and fire in later steps.  Over 12 steps each node
        must fire exactly 12*4/12 = 4 events — overflow carries, it does
        not starve."""
        e = _engine(rounds=12, seed=1, topology="regular", degree=4,
                    cohort_capacity=4)
        e.run(log=False)
        events = np.asarray(e.scheduler._events)
        np.testing.assert_array_equal(events, np.full(12, 4))
        m = e.scheduler.extra_metrics()
        assert m["cohort_occupancy_mean"] == pytest.approx(4.0)
        assert m["cohort_overflow_total"] > 0

    def test_overflow_preserves_event_conservation(self):
        """Total fired events under capacity pressure equals occupancy
        summed over steps (nothing double-fires, nothing is lost)."""
        e = _engine(rounds=12, seed=2, topology="regular", degree=4,
                    cohort_capacity=5, straggler_frac=0.25,
                    straggler_factor=4.0)
        e.run(log=False)
        m = e.scheduler.extra_metrics()
        assert m["events_total"] == int(np.asarray(e.scheduler._events).sum())
        assert m["events_total"] + m["cohort_overflow_total"] >= 12


# ---------------------------------------------------------------------------
# graph-free circulant table == dense Graph constructor, and 100k+ init
# ---------------------------------------------------------------------------

class TestPopulationTopology:
    @pytest.mark.parametrize("n,deg", [(12, 4), (13, 4), (16, 6), (9, 2),
                                       (8, 7)])
    def test_circulant_table_matches_dense_graph(self, n, deg):
        direct = circulant_neighbor_table(n, deg)
        via_graph = SparseTopology.from_graph(Graph.regular_circulant(n, deg))
        np.testing.assert_array_equal(direct, via_graph.nbr)

    @pytest.mark.parametrize("n,deg", [(12, 4), (13, 4), (16, 6)])
    def test_sparse_topology_direct_constructor_bitwise(self, n, deg):
        a = SparseTopology.regular_circulant(n, deg)
        b = SparseTopology.from_graph(Graph.regular_circulant(n, deg))
        np.testing.assert_array_equal(a.nbr, b.nbr)
        np.testing.assert_array_equal(a.w, b.w)
        np.testing.assert_array_equal(a.w_self, b.w_self)

    def test_population_engine_initializes_graph_free(self):
        """n_nodes above the dense-graph ceiling must construct via the
        O(N·d) circulant table and run a chunk to finite params."""
        n = 5000
        rng = np.random.default_rng(0)
        x = rng.normal(size=(n, *SHAPE)).astype(np.float32)
        y = rng.integers(0, 2, size=(n,)).astype(np.int32)
        parts = np.array_split(np.arange(n), n)
        dl = DLConfig(n_nodes=n, topology="regular", degree=4,
                      semantics="async", compute_time_s=1e-3,
                      cohort_capacity=64, batch_keying="node",
                      chunk_rounds=4, eval_every=10_000, batch_size=4,
                      local_steps=1, rounds=4)
        batcher = NodeBatcher(x, y, parts, dl.batch_size, seed=0)
        init = lambda key: {"w": jax.random.normal(key, (8,))}
        e = RoundEngine(dl, init, _loss, _acc, make_optimizer("sgd", 0.05),
                        batcher)
        e.scheduler.run_span(0, 4)
        jax.block_until_ready(e.params)
        assert np.isfinite(_w(e)).all()
        mm = e.scheduler.memory_model()
        assert mm["hot"]["total"] < mm["cold"]["total"]


# ---------------------------------------------------------------------------
# fp64 virtual-clock rebase: long-horizon time must not perturb anything
# ---------------------------------------------------------------------------

class TestClockRebase:
    def test_rebase_crossing_keeps_cohort_equal_to_dense(self):
        """compute_time_s large enough that the virtual clock crosses the
        rebase threshold mid-run: trajectories and the (rebased) clock
        metrics must stay identical between cohort and dense paths."""
        kw = dict(topology="regular", degree=4, compute_time_s=30_000.0,
                  straggler_frac=0.25, straggler_factor=2.0)
        dense = _engine(rounds=12, seed=5, **kw)
        coh = _engine(rounds=12, seed=5, cohort_capacity=12, **kw)
        dense.run(log=False)
        coh.run(log=False)
        np.testing.assert_array_equal(_w(dense), _w(coh))
        assert coh.sim_time_s == pytest.approx(dense.sim_time_s, rel=1e-12)
        assert dense.sim_time_s > 65536.0  # actually crossed the threshold


# ---------------------------------------------------------------------------
# device-side batch keying: subset-consistent, partition-respecting
# ---------------------------------------------------------------------------

class TestNodeBatchKeying:
    def _tables(self, n=12):
        ds = make_dataset("cifar10", n_train=256, n_test=32, shape=SHAPE,
                          sigma=2.0)
        parts = sharding_partition(ds.train_y, n, 2, seed=0)
        b = NodeBatcher(ds.train_x, ds.train_y, parts, batch_size=4, seed=0)
        return b, b.device_tables()

    def test_gathered_subset_draws_bitwise_same_samples(self):
        """The cohort-equivalence keystone: indices are a pure function of
        (key, round, global id, slot), so a gathered subset of rows draws
        exactly what those rows draw inside the full population."""
        _, (lens, pad) = self._tables()
        key = jax.random.key(7)
        full = np.asarray(node_batch_indices(key, 5, jnp.arange(12), lens,
                                             pad, 2, 4))
        ids = jnp.asarray([1, 3, 4, 9, 11])
        sub = np.asarray(node_batch_indices(key, 5, ids, lens, pad, 2, 4))
        np.testing.assert_array_equal(full[:, np.asarray(ids)], sub)

    def test_indices_stay_inside_each_nodes_partition(self):
        b, (lens, pad) = self._tables()
        key = jax.random.key(0)
        idx = np.asarray(node_batch_indices(key, 0, jnp.arange(12), lens,
                                            pad, 3, 4))
        for i, part in enumerate(b.parts):
            assert np.isin(idx[:, i], part).all()

    def test_rounds_draw_distinct_streams(self):
        _, (lens, pad) = self._tables()
        key = jax.random.key(0)
        a = np.asarray(node_batch_indices(key, 0, jnp.arange(12), lens, pad, 2, 4))
        c = np.asarray(node_batch_indices(key, 1, jnp.arange(12), lens, pad, 2, 4))
        assert not np.array_equal(a, c)


# ---------------------------------------------------------------------------
# DLConfig.validate: the cohort/batch-keying knob matrix
# ---------------------------------------------------------------------------

class TestCohortValidate:
    def _bad(self, match, **kw):
        with pytest.raises(ValueError, match=match):
            DLConfig(**kw).validate()

    def test_valid_cohort_config(self):
        DLConfig(semantics="async", topology="regular", cohort_capacity=4,
                 batch_keying="node", compute_time_s=0.1).validate()
        DLConfig(batch_keying="node").validate()

    def test_cohort_requires_async(self):
        self._bad("async", cohort_capacity=4, batch_keying="node")
        self._bad("async", semantics="local", cohort_capacity=4,
                  batch_keying="node")

    def test_cohort_capacity_domain(self):
        self._bad(">= 0", semantics="async", cohort_capacity=-1)
        self._bad("exceeds", semantics="async", n_nodes=8, cohort_capacity=9,
                  batch_keying="node")

    def test_cohort_needs_sparse_overlay(self):
        self._bad("sparse", semantics="async", topology="fully",
                  cohort_capacity=4, batch_keying="node")
        self._bad("sparse", semantics="async", topology="regular",
                  mixing="dense", cohort_capacity=4, batch_keying="node")

    def test_cohort_requires_node_batch_keying(self):
        self._bad("batch_keying='node'", semantics="async", cohort_capacity=4)

    def test_batch_keying_domain(self):
        self._bad("unknown batch_keying", batch_keying="host")
        self._bad("chunk", batch_keying="node", chunk_rounds=0)
        self._bad("single-host", batch_keying="node", shard_devices=2)


# ---------------------------------------------------------------------------
# hierarchical segment-min selection == the flat top_k oracle (bitwise)
# ---------------------------------------------------------------------------

class TestHierarchicalSelection:
    @pytest.mark.parametrize("scenario", sorted(SCENARIOS), ids=sorted(SCENARIOS))
    def test_hier_matches_flat_oracle_bitwise(self, scenario):
        """Under capacity pressure (C < N, real overflow-carry) the
        segment-min hierarchy must pick the bitwise-identical cohort —
        earliest deadline, same lowest-id tie-break — so the full
        trajectory (params, events, bytes, sim time) matches the flat
        selection run exactly, on every scenario axis."""
        kw = SCENARIOS[scenario]
        flat = _engine(rounds=12, seed=3, n_nodes=24, cohort_capacity=6,
                       selection="flat", **kw)
        hier = _engine(rounds=12, seed=3, n_nodes=24, cohort_capacity=6,
                       selection="hier", segment_size=4, **kw)
        flat.run(log=False)
        hier.run(log=False)
        np.testing.assert_array_equal(_w(flat), _w(hier))
        np.testing.assert_array_equal(np.asarray(flat.scheduler._events),
                                      np.asarray(hier.scheduler._events))
        assert hier.bytes_sent == flat.bytes_sent
        assert hier.sim_time_s == pytest.approx(flat.sim_time_s, rel=1e-12)
        mf, mh = flat.history[-1], hier.history[-1]
        for k in ("events_total", "staleness_mean", "vclock_max_s",
                  "cohort_occupancy_mean", "cohort_overflow_total"):
            assert mh[k] == pytest.approx(mf[k], rel=1e-6), k
        assert mh["cohort_selection"] == "hier"
        assert mf["cohort_selection"] == "flat"

    def test_wide_slice_takes_flat_fallback_and_stays_equal(self):
        """A slice window wide enough to span more than the top-K segments
        must route through the in-step flat fallback (counted in
        selection_fallback_total) and still reproduce the oracle
        bitwise."""
        kw = dict(topology="regular", degree=4, async_slice_s=1e9,
                  straggler_frac=0.5, straggler_factor=3.0)
        flat = _engine(rounds=10, seed=7, n_nodes=48, cohort_capacity=4,
                       selection="flat", **kw)
        hier = _engine(rounds=10, seed=7, n_nodes=48, cohort_capacity=4,
                       selection="hier", segment_size=4, **kw)
        flat.run(log=False)
        hier.run(log=False)
        np.testing.assert_array_equal(_w(flat), _w(hier))
        np.testing.assert_array_equal(np.asarray(flat.scheduler._events),
                                      np.asarray(hier.scheduler._events))
        assert hier.scheduler.extra_metrics()["selection_fallback_total"] > 0

    def test_hier_survives_clock_rebase(self):
        """The carried segment minima must stay exact across the fp32
        virtual-clock rebase (they are shifted by the same monotone
        subtraction as t_next)."""
        kw = dict(topology="regular", degree=4, compute_time_s=30_000.0,
                  straggler_frac=0.25, straggler_factor=2.0)
        flat = _engine(rounds=12, seed=5, n_nodes=24, cohort_capacity=6,
                       selection="flat", **kw)
        hier = _engine(rounds=12, seed=5, n_nodes=24, cohort_capacity=6,
                       selection="hier", segment_size=4, **kw)
        flat.run(log=False)
        hier.run(log=False)
        np.testing.assert_array_equal(_w(flat), _w(hier))
        assert hier.sim_time_s == pytest.approx(flat.sim_time_s, rel=1e-12)
        assert hier.sim_time_s > 65536.0  # actually crossed the threshold
        smin = np.asarray(hier.scheduler._seg_min)
        t = np.asarray(hier.scheduler._t_next)
        seg = hier.scheduler._seg
        expect = [t[i:i + seg].min() for i in range(0, t.shape[0], seg)]
        np.testing.assert_array_equal(smin, np.asarray(expect, np.float32))

    def test_auto_selection_resolves_flat_at_small_n(self):
        e = _engine(rounds=2, n_nodes=12, cohort_capacity=4,
                    topology="regular", degree=4)
        assert e.scheduler._selection == "flat"

    def test_odd_population_padding_segments(self):
        """N not divisible by the segment size: the last segment's padding
        rows must never enter a cohort (they are masked to +inf)."""
        flat = _engine(rounds=10, seed=2, n_nodes=23, cohort_capacity=5,
                       topology="regular", degree=4, selection="flat")
        hier = _engine(rounds=10, seed=2, n_nodes=23, cohort_capacity=5,
                       topology="regular", degree=4, selection="hier",
                       segment_size=4)
        flat.run(log=False)
        hier.run(log=False)
        np.testing.assert_array_equal(_w(flat), _w(hier))
        np.testing.assert_array_equal(np.asarray(flat.scheduler._events),
                                      np.asarray(hier.scheduler._events))

    def test_compute_spread_deties_the_clock(self):
        """compute_spread draws a seeded continuous per-node multiplier in
        [1, 1+spread] on top of the straggler distribution — all-distinct
        times (no lattice ties), reproducible, bounded."""
        from repro.core.engine import compute_time_vector
        cfg = DLConfig(n_nodes=64, topology="regular", degree=4,
                       compute_time_s=1e-3, compute_spread=15.0, seed=9)
        ct = compute_time_vector(cfg)
        assert ct.shape == (64,) and ct.dtype == np.float32
        assert np.unique(ct).size == 64  # continuous draw: no ties
        assert np.all(ct >= 1e-3) and np.all(ct <= 16e-3 * (1 + 1e-6))
        np.testing.assert_array_equal(ct, compute_time_vector(cfg))
        base = compute_time_vector(
            DLConfig(n_nodes=64, topology="regular", degree=4,
                     compute_time_s=1e-3, seed=9))
        np.testing.assert_array_equal(base, np.full(64, 1e-3, np.float32))
        with pytest.raises(ValueError, match="compute_spread"):
            DLConfig(n_nodes=4, topology="regular", degree=2,
                     compute_spread=-0.1, compute_time_s=1e-3).validate()
        with pytest.raises(ValueError, match="compute_spread"):
            DLConfig(n_nodes=4, topology="regular", degree=2,
                     compute_spread=1.0).validate()

    def test_hier_prunes_under_continuous_spread_and_stays_equal(self):
        """The regime the hierarchy is built for: a continuous
        heterogeneous clock (compute_spread) with a slice sized for
        ~0.8*C occupancy.  The segment filter must actually prune
        (fallbacks strictly below the step count) and still reproduce
        the flat oracle bitwise."""
        # slice for ~0.8*C steady occupancy at rate N*ln(1+s)/(base*s)
        n, c, spread = 96, 8, 15.0
        sl = 0.8 * c * (1e-3 * spread) / (n * np.log1p(spread))
        kw = dict(topology="regular", degree=4, compute_spread=spread,
                  async_slice_s=float(sl))
        flat = _engine(rounds=12, seed=11, n_nodes=n, cohort_capacity=c,
                       selection="flat", **kw)
        hier = _engine(rounds=12, seed=11, n_nodes=n, cohort_capacity=c,
                       selection="hier", segment_size=4, **kw)
        flat.run(log=False)
        hier.run(log=False)
        np.testing.assert_array_equal(_w(flat), _w(hier))
        np.testing.assert_array_equal(np.asarray(flat.scheduler._events),
                                      np.asarray(hier.scheduler._events))
        m = hier.scheduler.extra_metrics()
        assert m["selection_fallback_total"] < 12
        assert hier.scheduler._n_seg > hier.scheduler._seg_k  # prunable


# ---------------------------------------------------------------------------
# quantized cold population state (DLConfig.cold_dtype)
# ---------------------------------------------------------------------------

class TestColdDtype:
    def test_bf16_roundtrip_exact_for_representable_values(self):
        """decode(encode(x)) is bitwise x for every bf16-representable
        fp32 value — the codec contract the engine's masked-row scatter
        relies on."""
        from repro.core import compression as comp
        x = jnp.asarray(np.float32([0.0, -0.0, 1.0, -2.5, 0.15625, 2.0 ** -20,
                                    65536.0, -1.9921875]))
        tree = {"w": jnp.tile(x, (4, 1))}
        out = comp.decode_cold(comp.encode_cold(tree, "bf16"), "bf16")
        np.testing.assert_array_equal(np.asarray(out["w"]),
                                      np.asarray(tree["w"]))
        assert out["w"].dtype == jnp.float32

    def test_int8_codec_error_bound_and_reencode_stability(self):
        from repro.core import compression as comp
        rng = np.random.default_rng(0)
        a = jnp.asarray(rng.normal(size=(16, 7)).astype(np.float32))
        enc = comp.quantize_rows(a)
        dec = comp.dequantize_rows(enc)
        scale = np.abs(np.asarray(a)).max(axis=1) / 127.0
        err = np.abs(np.asarray(dec) - np.asarray(a))
        assert (err <= scale[:, None] * 0.5 + 1e-12).all()
        # re-encoding a decoded row reproduces its codes exactly — the
        # stability that keeps untouched gathered rows drift-free
        enc2 = comp.quantize_rows(dec)
        np.testing.assert_array_equal(np.asarray(enc2.q), np.asarray(enc.q))

    def test_int_leaves_pass_through_raw(self):
        from repro.core import compression as comp
        tree = {"t": jnp.arange(6, dtype=jnp.int32),
                "w": jnp.ones((6, 3), jnp.float32)}
        for mode in ("bf16", "int8"):
            enc = comp.encode_cold(tree, mode)
            assert enc["t"].dtype == jnp.int32
            dec = comp.decode_cold(enc, mode)
            np.testing.assert_array_equal(np.asarray(dec["t"]),
                                          np.asarray(tree["t"]))

    @pytest.mark.parametrize("cold", ["bf16", "int8"])
    def test_compressed_cold_tracks_fp32_trajectory(self, cold):
        """Consensus/accuracy tolerance oracle: the quantized cold store
        is lossy per gather/scatter cycle but must track the fp32
        trajectory closely on a real run (and eval through the decoded
        params must work end to end)."""
        f32 = _engine(rounds=12, seed=3, n_nodes=24, cohort_capacity=24,
                      topology="regular", degree=4)
        q = _engine(rounds=12, seed=3, n_nodes=24, cohort_capacity=24,
                    topology="regular", degree=4, cold_dtype=cold)
        f32.run(log=False)
        q.run(log=False)
        wq = np.asarray(jax.vmap(lambda p: p["w"])(q.scheduler.eval_params()))
        wf = _w(f32)
        rel = np.abs(wq - wf).max() / (np.abs(wf).max() + 1e-12)
        assert rel < 5e-2, rel
        # same event schedule: compression touches values, never the clock
        np.testing.assert_array_equal(np.asarray(f32.scheduler._events),
                                      np.asarray(q.scheduler._events))
        assert q.history[-1]["acc_mean"] == pytest.approx(
            f32.history[-1]["acc_mean"], abs=0.05
        )

    def test_memory_model_reports_compressed_cold_bytes(self):
        e8 = _engine(rounds=2, n_nodes=24, cohort_capacity=8, p_dim=64,
                     topology="regular", degree=4, cold_dtype="int8")
        m = e8.scheduler.memory_model()
        assert m["cold_dtype"] == "int8"
        # codes (1 B/elt) + one fp32 scale per row per leaf
        assert m["cold"]["population_params_bytes"] == 24 * 64 + 24 * 4
        assert m["cold"]["population_params_fp32_bytes"] == 24 * 64 * 4
        assert m["cold"]["total"] < m["cold"]["total_fp32"]

    def test_cold_dtype_validate_rules(self):
        with pytest.raises(ValueError, match="cold_dtype"):
            DLConfig(cold_dtype="fp16").validate()
        with pytest.raises(ValueError, match="cohort_capacity"):
            DLConfig(cold_dtype="int8").validate()
        with pytest.raises(ValueError, match="cohort_capacity"):
            DLConfig(selection="hier").validate()
        with pytest.raises(ValueError, match="selection"):
            DLConfig(selection="tree").validate()
        with pytest.raises(ValueError, match="segment_size"):
            DLConfig(segment_size=-1).validate()


# ---------------------------------------------------------------------------
# int32-boundary scale: 2^20-node tables, > 2^31 event totals
# ---------------------------------------------------------------------------

class TestInt32BoundaryScale:
    N_BIG = (1 << 20) + 4

    def test_circulant_table_correct_at_2_20_nodes(self):
        n, d = self.N_BIG, 4
        nbr = circulant_neighbor_table(n, d)
        assert nbr.dtype == np.int32 and nbr.shape == (n, d)
        assert nbr.min() >= 0 and nbr.max() == n - 1
        rng = np.random.default_rng(0)
        rows = np.concatenate([[0, 1, n - 2, n - 1],
                               rng.integers(0, n, 64)])
        for i in rows:
            expect = sorted({(i + o) % n for o in (-2, -1, 1, 2)})
            np.testing.assert_array_equal(nbr[i], np.asarray(expect))

    def test_gather_rows_correct_at_2_20_nodes(self):
        from repro.core.topology import gather_rows
        n, d = self.N_BIG, 4
        topo = SparseTopology.regular_circulant(n, d)
        rows = jnp.asarray([0, 5, n // 2, n - 1], jnp.int32)
        sub = gather_rows(topo, rows)
        nbr = np.asarray(sub.nbr)
        for k, i in enumerate(np.asarray(rows)):
            expect = sorted({(int(i) + o) % n for o in (-2, -1, 1, 2)})
            np.testing.assert_array_equal(nbr[k], np.asarray(expect))
        w = np.asarray(sub.w)
        assert w.shape == (4, d) and (w > 0).all()

    def test_event_totals_survive_past_int32(self):
        """The per-node int32 counters are summed in int64 on the host:
        a population total past 2^31 must stay exact."""
        e = _engine(rounds=2, n_nodes=12, cohort_capacity=4,
                    topology="regular", degree=4)
        e.run(log=False)
        big = 1 << 28
        e.scheduler._events = jnp.full((12,), big, jnp.int32)
        e.scheduler._fired_total = 12 * big
        e.scheduler._overflow_total = 6 * big
        m = e.scheduler.extra_metrics()
        assert m["events_total"] == 12 * big      # 3.2e9 > 2^31
        assert m["events_total"] > 2 ** 31
        assert m["cohort_overflow_ratio"] == pytest.approx(0.5)
