"""Population-scale cohort activation (``DLConfig.cohort_capacity``):
the async scheduler's gather/scatter path must be *bitwise* equivalent to
the dense async oracle whenever the capacity covers every firing node
(C = N), across the scenario axes (stragglers, churn, network model,
pairwise gossip, dynamic topology); overflow-carry must defer — never
drop — excess firings so homogeneous nodes stay fair; the graph-free
circulant neighbor table must match the dense ``Graph`` constructor
bit-for-bit; the fp64 virtual-clock rebase must not perturb
trajectories; and the device-side per-node batch keying must draw the
same samples for any gathered row subset.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DLConfig, RoundEngine
from repro.core.topology import (
    Graph,
    SparseTopology,
    circulant_neighbor_table,
)
from repro.data import NodeBatcher, make_dataset, sharding_partition
from repro.data.loader import node_batch_indices
from repro.optim import make_optimizer

SHAPE = (2, 2, 1)


def _loss(p, x, y):
    t = x.reshape(x.shape[0], -1).mean(0)
    return jnp.mean((p["w"].reshape(-1, t.shape[0]) - t) ** 2)


def _acc(p, x, y):
    return -_loss(p, x, y)


def _engine(p_dim: int = 8, **kw) -> RoundEngine:
    n = kw.setdefault("n_nodes", 12)
    ds = make_dataset("cifar10", n_train=256, n_test=32, shape=SHAPE, sigma=2.0)
    parts = sharding_partition(ds.train_y, n, 2, seed=0)
    batcher = NodeBatcher(ds.train_x, ds.train_y, parts, batch_size=4, seed=0)
    kw.setdefault("chunk_rounds", 4)
    kw.setdefault("eval_every", 6)
    kw.setdefault("semantics", "async")
    kw.setdefault("compute_time_s", 1e-3)
    kw.setdefault("batch_keying", "node")
    dl = DLConfig(local_steps=1, batch_size=4, **kw)
    init = lambda key: {"w": jax.random.normal(key, (p_dim,))}
    return RoundEngine(dl, init, _loss, _acc, make_optimizer("sgd", 0.05), batcher)


def _w(e):
    return np.asarray(jax.vmap(lambda p: p["w"])(e.params))


# ---------------------------------------------------------------------------
# cohort == dense async oracle (bitwise) whenever C covers every firing node
# ---------------------------------------------------------------------------

SCENARIOS = {
    "base": dict(topology="regular", degree=4),
    "stragglers": dict(topology="regular", degree=4, straggler_frac=0.5,
                       straggler_factor=3.0),
    "churn": dict(topology="regular", degree=4, participation=0.7),
    "churn_lan": dict(topology="regular", degree=4, participation=0.7,
                      network="lan"),
    "pairwise_churn": dict(topology="regular", degree=4,
                           async_gossip="pairwise", participation=0.8),
    "dynamic": dict(topology="dynamic", degree=4),
}


class TestCohortEquivalence:
    @pytest.mark.parametrize("scenario", sorted(SCENARIOS), ids=sorted(SCENARIOS))
    def test_full_capacity_cohort_matches_dense_oracle(self, scenario):
        """C = N: every step's firing set fits the cohort, so the
        gather -> step -> scatter round trip must reproduce the dense
        (N, ...) path bit-for-bit — params, event counts, staleness,
        virtual clocks, bytes."""
        kw = SCENARIOS[scenario]
        dense = _engine(rounds=12, seed=3, **kw)
        coh = _engine(rounds=12, seed=3, cohort_capacity=12, **kw)
        dense.run(log=False)
        coh.run(log=False)
        np.testing.assert_array_equal(_w(dense), _w(coh))
        np.testing.assert_array_equal(np.asarray(dense.scheduler._events),
                                      np.asarray(coh.scheduler._events))
        assert coh.bytes_sent == dense.bytes_sent
        assert coh.sim_time_s == pytest.approx(dense.sim_time_s, rel=1e-9)
        md, mc = dense.history[-1], coh.history[-1]
        for k in ("events_total", "staleness_mean", "vclock_max_s",
                  "vclock_median_s"):
            assert mc[k] == pytest.approx(md[k], rel=1e-6), k

    def test_cohort_uses_node_batch_keying_samples(self):
        """Guard: the equivalence above is only meaningful because BOTH
        sides run batch_keying='node' — the dense oracle under 'stream'
        keying draws a different (equally valid) sample stream."""
        a = _engine(rounds=8, seed=0, topology="regular", degree=4)
        b = _engine(rounds=8, seed=0, topology="regular", degree=4,
                    batch_keying="stream")
        a.run(log=False)
        b.run(log=False)
        assert not np.array_equal(_w(a), _w(b))


# ---------------------------------------------------------------------------
# overflow-carry: capacity pressure defers firings, never drops them
# ---------------------------------------------------------------------------

class TestOverflowCarry:
    def test_homogeneous_nodes_stay_fair_under_capacity_pressure(self):
        """N=12 homogeneous nodes at C=4: every step 12 nodes tie on the
        virtual clock but only the 4 earliest fire; the other 8 keep
        their t_next and fire in later steps.  Over 12 steps each node
        must fire exactly 12*4/12 = 4 events — overflow carries, it does
        not starve."""
        e = _engine(rounds=12, seed=1, topology="regular", degree=4,
                    cohort_capacity=4)
        e.run(log=False)
        events = np.asarray(e.scheduler._events)
        np.testing.assert_array_equal(events, np.full(12, 4))
        m = e.scheduler.extra_metrics()
        assert m["cohort_occupancy_mean"] == pytest.approx(4.0)
        assert m["cohort_overflow_total"] > 0

    def test_overflow_preserves_event_conservation(self):
        """Total fired events under capacity pressure equals occupancy
        summed over steps (nothing double-fires, nothing is lost)."""
        e = _engine(rounds=12, seed=2, topology="regular", degree=4,
                    cohort_capacity=5, straggler_frac=0.25,
                    straggler_factor=4.0)
        e.run(log=False)
        m = e.scheduler.extra_metrics()
        assert m["events_total"] == int(np.asarray(e.scheduler._events).sum())
        assert m["events_total"] + m["cohort_overflow_total"] >= 12


# ---------------------------------------------------------------------------
# graph-free circulant table == dense Graph constructor, and 100k+ init
# ---------------------------------------------------------------------------

class TestPopulationTopology:
    @pytest.mark.parametrize("n,deg", [(12, 4), (13, 4), (16, 6), (9, 2),
                                       (8, 7)])
    def test_circulant_table_matches_dense_graph(self, n, deg):
        direct = circulant_neighbor_table(n, deg)
        via_graph = SparseTopology.from_graph(Graph.regular_circulant(n, deg))
        np.testing.assert_array_equal(direct, via_graph.nbr)

    @pytest.mark.parametrize("n,deg", [(12, 4), (13, 4), (16, 6)])
    def test_sparse_topology_direct_constructor_bitwise(self, n, deg):
        a = SparseTopology.regular_circulant(n, deg)
        b = SparseTopology.from_graph(Graph.regular_circulant(n, deg))
        np.testing.assert_array_equal(a.nbr, b.nbr)
        np.testing.assert_array_equal(a.w, b.w)
        np.testing.assert_array_equal(a.w_self, b.w_self)

    def test_population_engine_initializes_graph_free(self):
        """n_nodes above the dense-graph ceiling must construct via the
        O(N·d) circulant table and run a chunk to finite params."""
        n = 5000
        rng = np.random.default_rng(0)
        x = rng.normal(size=(n, *SHAPE)).astype(np.float32)
        y = rng.integers(0, 2, size=(n,)).astype(np.int32)
        parts = np.array_split(np.arange(n), n)
        dl = DLConfig(n_nodes=n, topology="regular", degree=4,
                      semantics="async", compute_time_s=1e-3,
                      cohort_capacity=64, batch_keying="node",
                      chunk_rounds=4, eval_every=10_000, batch_size=4,
                      local_steps=1, rounds=4)
        batcher = NodeBatcher(x, y, parts, dl.batch_size, seed=0)
        init = lambda key: {"w": jax.random.normal(key, (8,))}
        e = RoundEngine(dl, init, _loss, _acc, make_optimizer("sgd", 0.05),
                        batcher)
        e.scheduler.run_span(0, 4)
        jax.block_until_ready(e.params)
        assert np.isfinite(_w(e)).all()
        mm = e.scheduler.memory_model()
        assert mm["hot"]["total"] < mm["cold"]["total"]


# ---------------------------------------------------------------------------
# fp64 virtual-clock rebase: long-horizon time must not perturb anything
# ---------------------------------------------------------------------------

class TestClockRebase:
    def test_rebase_crossing_keeps_cohort_equal_to_dense(self):
        """compute_time_s large enough that the virtual clock crosses the
        rebase threshold mid-run: trajectories and the (rebased) clock
        metrics must stay identical between cohort and dense paths."""
        kw = dict(topology="regular", degree=4, compute_time_s=30_000.0,
                  straggler_frac=0.25, straggler_factor=2.0)
        dense = _engine(rounds=12, seed=5, **kw)
        coh = _engine(rounds=12, seed=5, cohort_capacity=12, **kw)
        dense.run(log=False)
        coh.run(log=False)
        np.testing.assert_array_equal(_w(dense), _w(coh))
        assert coh.sim_time_s == pytest.approx(dense.sim_time_s, rel=1e-12)
        assert dense.sim_time_s > 65536.0  # actually crossed the threshold


# ---------------------------------------------------------------------------
# device-side batch keying: subset-consistent, partition-respecting
# ---------------------------------------------------------------------------

class TestNodeBatchKeying:
    def _tables(self, n=12):
        ds = make_dataset("cifar10", n_train=256, n_test=32, shape=SHAPE,
                          sigma=2.0)
        parts = sharding_partition(ds.train_y, n, 2, seed=0)
        b = NodeBatcher(ds.train_x, ds.train_y, parts, batch_size=4, seed=0)
        return b, b.device_tables()

    def test_gathered_subset_draws_bitwise_same_samples(self):
        """The cohort-equivalence keystone: indices are a pure function of
        (key, round, global id, slot), so a gathered subset of rows draws
        exactly what those rows draw inside the full population."""
        _, (lens, pad) = self._tables()
        key = jax.random.key(7)
        full = np.asarray(node_batch_indices(key, 5, jnp.arange(12), lens,
                                             pad, 2, 4))
        ids = jnp.asarray([1, 3, 4, 9, 11])
        sub = np.asarray(node_batch_indices(key, 5, ids, lens, pad, 2, 4))
        np.testing.assert_array_equal(full[:, np.asarray(ids)], sub)

    def test_indices_stay_inside_each_nodes_partition(self):
        b, (lens, pad) = self._tables()
        key = jax.random.key(0)
        idx = np.asarray(node_batch_indices(key, 0, jnp.arange(12), lens,
                                            pad, 3, 4))
        for i, part in enumerate(b.parts):
            assert np.isin(idx[:, i], part).all()

    def test_rounds_draw_distinct_streams(self):
        _, (lens, pad) = self._tables()
        key = jax.random.key(0)
        a = np.asarray(node_batch_indices(key, 0, jnp.arange(12), lens, pad, 2, 4))
        c = np.asarray(node_batch_indices(key, 1, jnp.arange(12), lens, pad, 2, 4))
        assert not np.array_equal(a, c)


# ---------------------------------------------------------------------------
# DLConfig.validate: the cohort/batch-keying knob matrix
# ---------------------------------------------------------------------------

class TestCohortValidate:
    def _bad(self, match, **kw):
        with pytest.raises(ValueError, match=match):
            DLConfig(**kw).validate()

    def test_valid_cohort_config(self):
        DLConfig(semantics="async", topology="regular", cohort_capacity=4,
                 batch_keying="node", compute_time_s=0.1).validate()
        DLConfig(batch_keying="node").validate()

    def test_cohort_requires_async(self):
        self._bad("async", cohort_capacity=4, batch_keying="node")
        self._bad("async", semantics="local", cohort_capacity=4,
                  batch_keying="node")

    def test_cohort_capacity_domain(self):
        self._bad(">= 0", semantics="async", cohort_capacity=-1)
        self._bad("exceeds", semantics="async", n_nodes=8, cohort_capacity=9,
                  batch_keying="node")

    def test_cohort_needs_sparse_overlay(self):
        self._bad("sparse", semantics="async", topology="fully",
                  cohort_capacity=4, batch_keying="node")
        self._bad("sparse", semantics="async", topology="regular",
                  mixing="dense", cohort_capacity=4, batch_keying="node")

    def test_cohort_requires_node_batch_keying(self):
        self._bad("batch_keying='node'", semantics="async", cohort_capacity=4)

    def test_batch_keying_domain(self):
        self._bad("unknown batch_keying", batch_keying="host")
        self._bad("chunk", batch_keying="node", chunk_rounds=0)
        self._bad("single-host", batch_keying="node", shard_devices=2)
