"""Sharing strategies: sparse-aggregation algebra, CHOCO consensus,
byte accounting."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core.sharing import (
    ChocoSGD,
    FullSharing,
    RandomKSharing,
    TopKSharing,
    make_sharing,
    sparse_aggregate,
)
from repro.core.topology import Graph


def _setup(n=8, p=64, seed=0):
    X = jax.random.normal(jax.random.key(seed), (n, p))
    g = Graph.regular_circulant(n, 4)
    W = jnp.asarray(g.metropolis_hastings(), jnp.float32)
    return X, W, g


class TestSparseAggregate:
    @given(st.integers(0, 20))
    @settings(max_examples=10, deadline=None)
    def test_matches_loop_reference(self, seed):
        n, p = 6, 16
        X, W, _ = _setup(n, p, seed)
        M = jax.random.bernoulli(jax.random.key(seed + 100), 0.3, (n, p))
        got = sparse_aggregate(X, W, M)
        # reference: x_i'[c] = sum_j W_ij (m_j[c] x_j[c] + (1-m_j[c]) x_i[c])
        Xn, Wn, Mn = np.asarray(X), np.asarray(W), np.asarray(M, np.float32)
        want = np.zeros_like(Xn)
        for i in range(n):
            for c in range(p):
                want[i, c] = sum(
                    Wn[i, j] * (Mn[j, c] * Xn[j, c] + (1 - Mn[j, c]) * Xn[i, c])
                    for j in range(n)
                )
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=1e-5)

    def test_full_mask_equals_full_sharing(self):
        X, W, _ = _setup()
        M = jnp.ones_like(X, bool)
        np.testing.assert_allclose(
            sparse_aggregate(X, W, M), W @ X, rtol=2e-5, atol=1e-6
        )

    def test_empty_mask_is_identity(self):
        X, W, _ = _setup()
        M = jnp.zeros_like(X, bool)
        np.testing.assert_allclose(sparse_aggregate(X, W, M), X, rtol=1e-6)


class TestStrategies:
    def test_full_sharing_is_w_matmul(self):
        X, W, g = _setup()
        s = FullSharing()
        X2, _, nbytes = s.round(X, W, s.init_state(X), jax.random.key(0), 4.0)
        np.testing.assert_allclose(X2, W @ X, rtol=2e-5, atol=1e-6)
        assert nbytes == 4.0 * X.shape[1] * 4

    def test_randomk_budget_bytes(self):
        X, W, _ = _setup(p=1000)
        s = RandomKSharing(0.1)
        _, _, nbytes = s.round(X, W, s.init_state(X), jax.random.key(0), 4.0)
        assert nbytes == 4.0 * 100 * 8  # k=100, idx+val

    def test_topk_shares_biggest_changes(self):
        X, W, _ = _setup(n=6, p=50)
        s = TopKSharing(0.2)
        st_ = s.init_state(X)
        # change only 5 coords massively; they must be selected
        X2 = X.at[:, :5].add(100.0)
        _, st2, _ = s.round(X2, jnp.eye(6), st_, jax.random.key(0), 4.0)
        changed = np.asarray(st2["last_shared"] != st_["last_shared"])
        assert changed[:, :5].all()

    def test_choco_consensus(self):
        """Pure gossip (no gradients): CHOCO must drive all nodes toward the
        initial mean."""
        X, W, _ = _setup(n=8, p=32, seed=3)
        s = ChocoSGD(budget=0.3, gamma=0.5)
        state = s.init_state(X)
        target = np.asarray(X).mean(0)
        d0 = float(jnp.linalg.norm(X - target))
        Xc = X
        for r in range(60):
            Xc, state, _ = s.round(Xc, W, state, jax.random.fold_in(jax.random.key(9), r), 4.0)
        d1 = float(jnp.linalg.norm(Xc - target))
        assert d1 < 0.15 * d0, (d0, d1)
        np.testing.assert_allclose(np.asarray(Xc).mean(0), target, rtol=5e-2, atol=5e-2)

    def test_factory(self):
        assert isinstance(make_sharing("full"), FullSharing)
        assert isinstance(make_sharing("randomk", 0.2), RandomKSharing)
        assert isinstance(make_sharing("topk", 0.2), TopKSharing)
        assert isinstance(make_sharing("choco", 0.2, gamma=0.1), ChocoSGD)


class TestQuantizedSharing:
    def test_matches_full_within_quant_error(self):
        from repro.core.sharing import QuantizedSharing

        X, W, _ = _setup(n=8, p=256, seed=4)
        s = QuantizedSharing(stochastic=False)
        X2, _, nbytes = s.round(X, W, (), jax.random.key(0), 4.0)
        full = W @ X
        step = float(jnp.max(jnp.abs(X), axis=1).max()) / 127.0
        assert float(jnp.max(jnp.abs(X2 - full))) <= step * 1.01
        assert nbytes == 4.0 * (256 + 4)

    def test_runner_integration(self):
        from repro.core.sharing import QuantizedSharing, make_sharing

        assert isinstance(make_sharing("int8"), QuantizedSharing)
