"""Scheduler layer (execution semantics): ``semantics="sync"`` must be
trajectory-equivalent to the per-round oracle across every scenario axis,
``"local"`` keeps sync's trajectories on per-node neighborhood-barrier
clocks, and ``"async"`` runs event-driven (AD-PSGD-style) gossip on a
virtual clock — reducing to sync under homogeneous time + full activation,
matching uniform-neighbor mixing in expectation for the pairwise sampler,
and exposing staleness / per-node wall-clock / event-count metrics.  Also:
machine-correlated churn masks and the centralized ``DLConfig.validate()``.

(8-device coverage of the sync scheduler — sharded == single across the
same scenario axes, incl. heterogeneous compute times and machine churn —
lives in tests/test_sharded_engine.py, which relaunches itself with
emulated devices under the plain tier-1 run.)
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DLConfig, RoundEngine
from repro.core.faults import FaultPlan
from repro.core.mixing import gossip_pair_avg
from repro.core.topology import Graph, SparseTopology, sample_neighbor_slots
from repro.data import NodeBatcher, make_dataset, sharding_partition
from repro.optim import make_optimizer

SHAPE = (2, 2, 1)


def _loss(p, x, y):
    # consensus workload (cheapest possible round program): pull every
    # 4-wide row of the state toward the local batch mean
    t = x.reshape(x.shape[0], -1).mean(0)
    return jnp.mean((p["w"].reshape(-1, t.shape[0]) - t) ** 2)


def _acc(p, x, y):
    return -_loss(p, x, y)


def _engine(p_dim: int = 8, **kw) -> RoundEngine:
    n = kw.setdefault("n_nodes", 12)
    ds = make_dataset("cifar10", n_train=256, n_test=32, shape=SHAPE, sigma=2.0)
    parts = sharding_partition(ds.train_y, n, 2, seed=0)
    batcher = NodeBatcher(ds.train_x, ds.train_y, parts, batch_size=4, seed=0)
    kw.setdefault("chunk_rounds", 4)
    kw.setdefault("eval_every", 4)
    dl = DLConfig(local_steps=1, batch_size=4, **kw)
    init = lambda key: {"w": jax.random.normal(key, (p_dim,))}
    return RoundEngine(dl, init, _loss, _acc, make_optimizer("sgd", 0.05), batcher)


def _w(e):
    return np.asarray(jax.vmap(lambda p: p["w"])(e.params))


# ---------------------------------------------------------------------------
# sync: the refactored scheduler must reproduce the per-round oracle
# ---------------------------------------------------------------------------

SCENARIOS = {
    "dense": dict(topology="fully"),
    "sparse": dict(topology="regular", degree=4),
    "payload": dict(topology="regular", degree=4, sharing="randomk",
                    budget=0.25, payload="on"),
    "secure": dict(topology="regular", degree=4, secure=True),
    "churn": dict(topology="regular", degree=4, participation=0.6),
}


class TestSyncEquivalence:
    @pytest.mark.parametrize("scenario", sorted(SCENARIOS), ids=sorted(SCENARIOS))
    def test_sync_scheduler_matches_per_round_oracle(self, scenario):
        """The scheduler-layer scan (semantics='sync', explicit) must give
        the legacy per-round dispatch's trajectories — the pre-refactor
        engine's round program, preserved verbatim as chunk_rounds=0 —
        for every scenario axis."""
        kw = SCENARIOS[scenario]
        e_scan = _engine(semantics="sync", rounds=8, seed=3, **kw)
        e_scan.run(log=False)
        e_oracle = _engine(chunk_rounds=0, rounds=8, seed=3, **kw)
        e_oracle.run(log=False)
        np.testing.assert_allclose(_w(e_scan), _w(e_oracle), rtol=2e-5, atol=1e-6)
        assert e_scan.bytes_sent == pytest.approx(e_oracle.bytes_sent, rel=1e-6)

    def test_sync_is_the_default(self):
        assert _engine(rounds=1).dl.semantics == "sync"
        assert type(_engine(rounds=1).scheduler).__name__ == "SyncScheduler"


# ---------------------------------------------------------------------------
# local: same trajectories, per-node neighborhood-barrier clocks
# ---------------------------------------------------------------------------

class TestLocalSemantics:
    def _pair(self, **kw):
        out = {}
        for sem in ("sync", "local"):
            e = _engine(semantics=sem, **kw)
            e.run(log=False)
            out[sem] = e
        return out

    def test_trajectories_identical_to_sync(self):
        es = self._pair(topology="regular", degree=4, rounds=8, seed=1,
                        network="lan", compute_time_s=0.01)
        np.testing.assert_array_equal(_w(es["sync"]), _w(es["local"]))
        assert es["local"].bytes_sent == pytest.approx(es["sync"].bytes_sent)

    def test_local_clock_bounded_by_sync_barrier(self):
        """No global barrier: the max per-node clock can never exceed the
        sum of per-round maxima, and with a constant straggler the *median*
        node finishes far earlier (only its neighborhood waits — the delay
        propagates one ring hop per round, so after 6 rounds a single
        straggler on a 32-ring has dragged 13 of 32 clocks)."""
        es = self._pair(topology="ring", n_nodes=32, rounds=6, seed=0,
                        network="lan", compute_time_s=0.05,
                        straggler_factor=10.0, straggler_frac=0.03)
        sync_t, local_t = es["sync"].sim_time_s, es["local"].sim_time_s
        assert local_t <= sync_t * (1 + 1e-6)
        rec = es["local"].history[-1]
        assert rec["semantics"] == "local"
        assert rec["vclock_median_s"] < 0.5 * sync_t
        assert rec["vclock_max_s"] == pytest.approx(local_t)

    def test_local_clock_advances_without_network_model(self):
        """No network model: comm is free but per-node compute time still
        drives the virtual clocks (regression: the clocks used to stay at
        zero, silently ignoring compute_time_s unlike async)."""
        e = _engine(semantics="local", topology="regular", degree=4,
                    n_nodes=12, rounds=6, eval_every=5, compute_time_s=0.1,
                    straggler_factor=10.0, straggler_frac=0.1)
        e.run(log=False)
        # the straggler (1.0 s/round, never waits) binds the max clock
        assert e.sim_time_s == pytest.approx(6 * 1.0, rel=1e-5)
        assert e.history[-1]["vclock_min_s"] >= 6 * 0.1 - 1e-6

    def test_local_with_churn_runs(self):
        es = self._pair(topology="regular", degree=4, rounds=8, seed=2,
                        participation=0.6, network="lan", compute_time_s=0.01)
        np.testing.assert_array_equal(_w(es["sync"]), _w(es["local"]))
        assert es["local"].sim_time_s <= es["sync"].sim_time_s * (1 + 1e-6)


# ---------------------------------------------------------------------------
# async: event-driven gossip on the virtual clock
# ---------------------------------------------------------------------------

class TestAsyncSemantics:
    def test_homogeneous_full_activation_reduces_to_sync(self):
        """With homogeneous compute times and full participation every
        event cohort is exactly one synchronous round (all nodes tie on
        the virtual clock and fire together), so neighborhood-async
        trajectories coincide with sync."""
        out = {}
        for sem in ("sync", "async"):
            e = _engine(semantics=sem, topology="regular", degree=4,
                        rounds=8, seed=4, compute_time_s=0.1)
            e.run(log=False)
            out[sem] = e
        np.testing.assert_allclose(_w(out["sync"]), _w(out["async"]),
                                   rtol=1e-6, atol=1e-7)
        assert out["async"].bytes_sent == pytest.approx(out["sync"].bytes_sent,
                                                        rel=1e-5)
        rec = out["async"].history[-1]
        assert rec["semantics"] == "async"
        assert rec["events_min"] == rec["events_max"] == 8  # lockstep cohorts
        assert rec["staleness_mean"] == pytest.approx(0.0)  # no lag anywhere

    def test_pairwise_expectation_matches_uniform_neighbor_mixing(self):
        """Seeded statistical test: averaged over the partner draw, the
        pairwise AD-PSGD update equals the uniform-neighbor mixing row
        0.5·x_i + 0.5·mean_{j~i} x_j."""
        g = Graph.regular_circulant(12, 4)
        st = jax.tree_util.tree_map(jnp.asarray, SparseTopology.from_graph(g))
        X = jax.random.normal(jax.random.key(0), (12, 6))
        S = 2048
        keys = jax.vmap(jax.random.key)(jnp.arange(S))
        Xs = jax.vmap(lambda k: gossip_pair_avg(st, X, k)[0])(keys)  # (S, N, P)
        emp = np.asarray(Xs.mean(0), np.float64)
        A = g.adj / g.degrees()[:, None]
        want = 0.5 * np.asarray(X) + 0.5 * (A @ np.asarray(X))
        stderr = np.asarray(Xs.std(0), np.float64) / np.sqrt(S)
        assert np.all(np.abs(emp - want) < 6 * stderr + 1e-5)

    def test_pairwise_partner_sampling_uniform(self):
        g = Graph.regular_circulant(16, 4)
        st = jax.tree_util.tree_map(jnp.asarray, SparseTopology.from_graph(g))
        counts = np.zeros((16, st.nbr.shape[1]))
        for s in range(400):
            slot = np.asarray(sample_neighbor_slots(jax.random.key(s), st))
            counts[np.arange(16), slot] += 1
        freq = counts / 400.0
        np.testing.assert_allclose(freq, 0.25, atol=0.08)  # 4 slots each

    def test_stragglers_fire_fewer_events(self):
        e = _engine(semantics="async", topology="regular", degree=4,
                    n_nodes=16, rounds=40, eval_every=40, seed=5,
                    compute_time_s=0.1, straggler_factor=10.0,
                    straggler_frac=0.25)
        e.run(log=False)
        ct = e._compute_node_np
        events = np.asarray(e.scheduler._events)
        slow, fast = events[ct > 0.5], events[ct < 0.5]
        assert slow.max() < fast.min() / 2  # ~10x fewer events
        rec = e.history[-1]
        assert rec["staleness_mean"] > 0.5   # fast nodes read lagging rows
        assert rec["vclock_max_s"] > rec["vclock_min_s"]
        assert rec["events_total"] == int(events.sum())

    def test_async_virtual_time_beats_sync_barrier_under_stragglers(self):
        """The headline property (benchmarked at N=1024 in bench_engine):
        per step of progress, the async virtual clock advances at the fast
        nodes' pace while the sync barrier pays the straggler every
        round."""
        out = {}
        for sem in ("sync", "async"):
            e = _engine(semantics=sem, topology="regular", degree=4,
                        n_nodes=16, rounds=24, eval_every=24, seed=6,
                        compute_time_s=0.05, straggler_factor=10.0,
                        straggler_frac=0.1, network="lan")
            e.run(log=False)
            out[sem] = e
        assert out["async"].sim_time_s < 0.5 * out["sync"].sim_time_s
        # ... while still making training progress
        assert out["async"].history[-1]["acc_mean"] > 2 * out[
            "sync"
        ].history[0]["acc_mean"]  # acc = -loss: losses shrink

    def test_pairwise_runs_and_records(self):
        e = _engine(semantics="async", async_gossip="pairwise",
                    topology="regular", degree=4, rounds=16, eval_every=8,
                    seed=7, compute_time_s=0.05, straggler_factor=4.0,
                    straggler_frac=0.25, network="lan")
        h = e.run(log=False)
        assert e.bytes_sent > 0
        assert h[-1]["acc_mean"] > h[0]["acc_mean"] - 0.05  # converging-ish
        assert h[-1]["semantics"] == "async"
        assert h[-1]["staleness_mean"] >= 0.0
        assert np.isfinite(_w(e)).all()

    def test_down_nodes_rejoin_with_stale_model(self):
        """A node that never fires an active event keeps its initial
        params bit-for-bit — churn freezes, never reweights away."""
        e = _engine(semantics="async", topology="regular", degree=4,
                    n_nodes=8, rounds=6, eval_every=5, seed=0,
                    participation=0.5, compute_time_s=0.1)
        p0 = _w(e).copy()
        masks = e._participation_mask(0, 6)
        e.run(log=False)
        never = np.nonzero(~masks.any(0).astype(bool))[0]
        p1 = _w(e)
        for i in never:
            np.testing.assert_array_equal(p1[i], p0[i])

    def test_dynamic_topology_async(self):
        e = _engine(semantics="async", topology="dynamic", degree=4,
                    rounds=8, seed=1, compute_time_s=0.05,
                    straggler_factor=3.0, straggler_frac=0.25)
        h = e.run(log=False)
        assert np.isfinite(_w(e)).all()
        assert h[-1]["events_total"] > 0


# ---------------------------------------------------------------------------
# machine-correlated churn
# ---------------------------------------------------------------------------

class TestMachineChurn:
    def test_nodes_on_one_machine_fail_together(self):
        e = _engine(n_nodes=16, participation=0.6, churn_machines=4, rounds=1)
        m = e._participation_mask(0, 64)  # (R, 16)
        # every node on a machine carries the machine's draw (round-robin:
        # node n -> machine n % 4)
        for k in range(4):
            col = m[:, np.arange(16) % 4 == k]
            np.testing.assert_array_equal(col, np.tile(col[:, :1], (1, 4)))
        # and distinct machines are NOT correlated with each other
        assert not np.array_equal(m[:, 0], m[:, 1])

    def test_iid_masks_unchanged_by_default(self):
        """churn_machines=0 must reproduce the original per-node draw
        bit-for-bit (chunk-boundary-invariant splitmix)."""
        e = _engine(n_nodes=8, participation=0.7, rounds=1, seed=9)
        full = e._participation_mask(0, 12)
        np.testing.assert_array_equal(full[3:7], e._participation_mask(3, 4))
        assert 0.4 < full.mean() < 0.95

    def test_machine_churn_runs_end_to_end(self):
        e = _engine(n_nodes=12, topology="regular", degree=4, rounds=6,
                    eval_every=5, participation=0.7, churn_machines=3, seed=2)
        e.run(log=False)
        assert e.bytes_sent > 0
        assert np.isfinite(_w(e)).all()

    def test_machine_churn_correlates_across_local_semantics(self):
        e1 = _engine(n_nodes=12, topology="regular", degree=4, rounds=6,
                     eval_every=5, participation=0.7, churn_machines=3,
                     seed=2, semantics="local", network="lan",
                     compute_time_s=0.01)
        e1.run(log=False)
        e2 = _engine(n_nodes=12, topology="regular", degree=4, rounds=6,
                     eval_every=5, participation=0.7, churn_machines=3, seed=2)
        e2.run(log=False)
        np.testing.assert_array_equal(_w(e1), _w(e2))


# ---------------------------------------------------------------------------
# DLConfig.validate: the centralized knob-compatibility matrix
# ---------------------------------------------------------------------------

class TestValidate:
    def _bad(self, match, **kw):
        with pytest.raises(ValueError, match=match):
            DLConfig(**kw).validate()

    def test_valid_defaults(self):
        assert DLConfig().validate() is not None
        DLConfig(semantics="local").validate()
        DLConfig(semantics="async", compute_time_s=0.1).validate()
        DLConfig(semantics="async", async_gossip="pairwise").validate()
        DLConfig(participation=0.5, churn_machines=4).validate()
        DLConfig(straggler_factor=10.0, straggler_frac=0.1,
                 compute_time_s=0.05).validate()

    def test_straggler_knobs_without_compute_time_rejected(self):
        self._bad("no-op", straggler_factor=10.0, straggler_frac=0.1)

    def test_processes_backend_knobs(self):
        # the real-network backend accepts its supported surface ...
        DLConfig(backend="processes").validate()
        DLConfig(backend="processes", sharing="randomk",
                 payload_quant=True).validate()
        self._bad("unknown backend", backend="threads")
        # ... and rejects simulated-only knobs with actionable messages
        self._bad("shard_devices", backend="processes", shard_devices=2)
        self._bad("synchronous", backend="processes", semantics="async",
                  compute_time_s=0.1)
        self._bad("synchronous", backend="processes", semantics="local")
        self._bad("secure", backend="processes", secure=True)
        self._bad("FaultPlan", backend="processes",
                  faults=FaultPlan(msg_loss=0.1))
        self._bad("killing workers", backend="processes", participation=0.5)
        self._bad("killing workers", backend="processes", churn_machines=2)
        self._bad("population-scale", backend="processes",
                  batch_keying="node")
        self._bad("sparse", backend="processes", topology="fully")
        self._bad("sparse", backend="processes", mixing="dense")
        self._bad("static graph", backend="processes", topology="dynamic")
        self._bad("stateful/unsupported", backend="processes",
                  sharing="topk")
        self._bad("stateful/unsupported", backend="processes",
                  sharing="choco")
        self._bad("uniform", backend="processes", sharing="randomk",
                  randk_sampler="strided")

    def test_engine_refuses_processes_backend(self):
        eng = _engine(n_nodes=8)  # reuse a built engine's batcher
        with pytest.raises(ValueError, match="ProcessRunner"):
            RoundEngine(
                DLConfig(n_nodes=8, backend="processes"),
                lambda k: {"w": jnp.zeros((2,))},
                _loss, _acc,
                make_optimizer("sgd", 0.05), eng.batcher,
            )

    def test_unknown_semantics(self):
        self._bad("unknown semantics", semantics="eventual")

    def test_async_rejects_secure(self):
        self._bad("secure", semantics="async", secure=True)

    def test_async_rejects_stateful_sharing(self):
        self._bad("one-sided stale reads", semantics="async", sharing="topk")
        self._bad("one-sided stale reads", semantics="async", sharing="choco")

    def test_async_pairwise_rejects_dense(self):
        self._bad("pairwise", semantics="async", async_gossip="pairwise",
                  topology="fully")
        self._bad("pairwise", semantics="async", async_gossip="pairwise",
                  mixing="dense")

    def test_non_sync_needs_scan_path(self):
        self._bad("chunk_rounds", semantics="async", chunk_rounds=0)
        self._bad("chunk_rounds", semantics="local", chunk_rounds=0)

    def test_non_sync_rejects_sharding(self):
        self._bad("single-host", semantics="local", shard_devices=2)
        self._bad("single-host", semantics="async", shard_devices=2)

    def test_secure_under_churn_needs_recovery(self):
        """secure=True under churn is no longer a flat rejection: it needs
        the Bonawitz seed-recovery pass (secure_recovery=True)."""
        self._bad("secure_recovery", secure=True, participation=0.9)
        self._bad("secure_recovery", secure=True, churn_machines=4,
                  participation=0.9)
        self._bad("static graph", secure=True, topology="dynamic")
        # the recovery knob unlocks both churn axes
        DLConfig(secure=True, participation=0.9,
                 secure_recovery=True).validate()
        DLConfig(secure=True, participation=0.9, churn_machines=4,
                 secure_recovery=True).validate()

    def test_secure_recovery_needs_secure(self):
        self._bad("needs secure=True", secure_recovery=True)

    def test_fault_plan_cross_knobs(self):
        """FaultPlan composes with churn/secure/async but not with the
        legacy dispatch, sharding, or the cohort body."""
        from repro.core import FaultPlan

        plan = FaultPlan(msg_loss=0.1)
        DLConfig(faults=plan).validate()
        DLConfig(faults=plan, participation=0.5).validate()
        DLConfig(faults=plan, semantics="async",
                 async_gossip="pairwise").validate()
        # secure composes with corruption/spikes/crashes, not per-edge loss
        DLConfig(secure=True, faults=FaultPlan(corrupt_prob=0.1,
                                               crashes=((0, 1, 2),),
                                               latency_spike_prob=0.1),
                 secure_recovery=True, participation=0.9).validate()
        self._bad("per-edge", secure=True, faults=plan)
        # crash schedules are churn: secure needs the recovery pass
        self._bad("secure_recovery", secure=True,
                  faults=FaultPlan(crashes=((0, 1, 2),)))
        self._bad("chunk_rounds", faults=plan, chunk_rounds=0)
        self._bad("single-host", faults=plan, shard_devices=2)
        self._bad("cohort_capacity", faults=plan, semantics="async",
                  async_gossip="pairwise", cohort_capacity=8)
        self._bad("out of range", faults=FaultPlan(crashes=((99, 0, 2),)))
        self._bad("invalid FaultPlan", faults=FaultPlan(msg_loss=1.5))

    def test_secure_rejects_payload_knobs(self):
        self._bad("secure", secure=True, payload="on")
        self._bad("secure", secure=True, payload_quant=True)
        self._bad("secure", secure=True, randk_sampler="strided")

    def test_payload_knob_compat(self):
        self._bad("sparsified", payload="on", sharing="full")
        self._bad("payload_quant", payload_quant=True, sharing="quant")
        self._bad("randk_sampler", randk_sampler="strided", sharing="topk")
        self._bad("unknown payload", payload="maybe")
        self._bad("unknown randk_sampler", randk_sampler="fourier")

    def test_scalar_domains(self):
        self._bad("participation", participation=0.0)
        self._bad("participation", participation=1.5)
        self._bad("churn_machines", churn_machines=-1)
        self._bad("straggler_frac", straggler_frac=1.5)
        self._bad("straggler_factor", straggler_factor=0.0)
        self._bad("compute_time_s", compute_time_s=-1.0)
        self._bad("unknown mixing", mixing="banana")
        self._bad("unknown shard_backend", shard_backend="teleport")

    def test_engine_calls_validate(self):
        with pytest.raises(ValueError, match="secure"):
            _engine(semantics="async", secure=True, rounds=1)
