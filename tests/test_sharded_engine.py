"""Multi-device execution: the node-sharded RoundEngine must reproduce the
single-device engine's trajectories for every scenario axis (dense, sparse,
churn, secure, payload-form compressed sharing — where the ppermute backend
exchanges (B, k) idx/val payloads instead of (B, P) rows), and the
permutation decomposition behind the collective_permute gossip must
round-trip exactly.

The sharded tests need 8 devices.  Under the plain tier-1 run (one CPU
device — conftest deliberately does not force a device count) a launcher
test re-executes this module in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``; CI's multi-device
step runs the module directly with the flag set, where the launcher skips
and the real tests run in-process.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.topology import (
    Graph,
    SparseTopology,
    build_permute_schedule,
    decompose_slot_permutations,
)

MULTIDEV = jax.device_count() >= 8


# ---------------------------------------------------------------------------
# permutation decomposition (host-side, no devices needed)
# ---------------------------------------------------------------------------

class TestDecomposition:
    @pytest.mark.parametrize(
        "graph",
        [
            Graph.ring(12),
            Graph.regular_circulant(16, 4),
            Graph.regular_circulant(16, 5),
            Graph.random_regular(64, 6, seed=3),
            Graph.star(8),  # irregular: padding self-edges make it D-regular
        ],
        ids=["ring12", "circ16d4", "circ16d5", "rr64d6", "star8"],
    )
    def test_columns_are_permutations_and_dense_roundtrip(self, graph):
        st = SparseTopology.from_graph(graph)
        dec = decompose_slot_permutations(st)
        assert dec is not None
        assert dec.nbr.shape == st.nbr.shape
        for s in range(dec.nbr.shape[1]):
            assert np.array_equal(np.sort(dec.nbr[:, s]), np.arange(graph.n))
        # same edges, same weights — only the slot placement moved
        np.testing.assert_array_equal(dec.to_dense(), st.to_dense())

    def test_non_decomposable_returns_none(self):
        # asymmetric hand-built table: node 0 is everyone's neighbor but
        # has out-degree towards node 1 only — in-counts can't balance
        nbr = np.array([[1, 1], [0, 0], [0, 0], [0, 0]], np.int32)
        w = np.full(nbr.shape, 0.25, np.float32)
        topo = SparseTopology(nbr, w, np.full((4,), 0.5, np.float32))
        assert decompose_slot_permutations(topo) is None

    def test_schedule_roundtrip(self):
        """Host emulation of the rotation-grouped transfers reproduces the
        slot permutation exactly."""
        st = SparseTopology.from_graph(Graph.random_regular(32, 5, seed=7))
        dec = decompose_slot_permutations(st)
        ndev = 8
        b = 32 // ndev
        sched = build_permute_schedule(dec.nbr, ndev)
        x = np.random.default_rng(0).normal(size=(32, 3)).astype(np.float32)
        for s, slots in enumerate(sched):
            out = np.zeros_like(x)
            for r, (send_idx, recv_pos) in slots.items():
                for d in range(ndev):
                    e = (d + r) % ndev
                    payload = x[d * b:(d + 1) * b][send_idx[d]]
                    for j, p in enumerate(recv_pos[e]):
                        if p < b:
                            out[e * b + p] = payload[j]
            np.testing.assert_array_equal(out, x[dec.nbr[:, s]])


# ---------------------------------------------------------------------------
# 8-device tests
# ---------------------------------------------------------------------------

def _consensus_loss(p, x, y):
    t = x.reshape(x.shape[0], -1).mean(0)
    return jnp.mean((p["w"].reshape(-1, t.shape[0]) - t) ** 2)


def _consensus_acc(p, x, y):
    return -_consensus_loss(p, x, y)


def _engine(**kw):
    from repro.core import DLConfig, RoundEngine
    from repro.data import NodeBatcher, make_dataset, sharding_partition
    from repro.optim import make_optimizer

    ds = make_dataset("cifar10", n_train=256, n_test=32, shape=(2, 2, 1), sigma=2.0)
    n = kw.setdefault("n_nodes", 16)
    parts = sharding_partition(ds.train_y, n, 2, seed=0)
    batcher = NodeBatcher(ds.train_x, ds.train_y, parts, batch_size=4, seed=0)
    kw.setdefault("chunk_rounds", 4)
    dl = DLConfig(eval_every=4, local_steps=1, batch_size=4, **kw)
    init = lambda key: {"w": jax.random.normal(key, (16,))}
    return RoundEngine(
        dl, init, _consensus_loss, _consensus_acc, make_optimizer("sgd", 0.05),
        batcher,
    )


def _assert_equivalent(rounds=8, **kw):
    """Sharded (8 devices) == single-device trajectories: final params,
    per-eval accuracies, byte accounting, simulated time.  Gather-backend
    paths are bit-identical in practice; the tolerance below covers the
    documented float-reassociation of the slot-decomposed ppermute path
    and of per-receiver sums over rebalanced slot orders."""
    e1 = _engine(**kw)
    h1 = e1.run(rounds=rounds, log=False)
    e2 = _engine(shard_devices=8, **kw)
    h2 = e2.run(rounds=rounds, log=False)
    p1 = np.asarray(jax.vmap(lambda p: p["w"])(e1.params))
    p2 = np.asarray(jax.vmap(lambda p: p["w"])(e2.params))
    np.testing.assert_allclose(p1, p2, rtol=2e-5, atol=1e-6)
    for r1, r2 in zip(h1, h2):
        assert r1["round"] == r2["round"]
        np.testing.assert_allclose(r1["acc_mean"], r2["acc_mean"], rtol=2e-4, atol=1e-6)
    np.testing.assert_allclose(e1.bytes_sent, e2.bytes_sent, rtol=1e-6)
    np.testing.assert_allclose(e1.sim_time_s, e2.sim_time_s, rtol=1e-4, atol=1e-9)


@pytest.mark.skipif(not MULTIDEV, reason="needs 8 devices (run via launcher)")
class TestShardedEngine:
    def test_sparse_static_gather(self):
        _assert_equivalent(topology="regular", degree=5)

    def test_sparse_static_ppermute(self):
        _assert_equivalent(topology="regular", degree=5, shard_backend="ppermute")

    def test_dynamic_sparse(self):
        _assert_equivalent(topology="dynamic", degree=5)

    def test_dense_fully(self):
        _assert_equivalent(topology="fully")

    def test_churn(self):
        _assert_equivalent(topology="regular", degree=5, participation=0.6)

    def test_churn_network_time(self):
        _assert_equivalent(topology="regular", degree=5, participation=0.6,
                           network="lan")

    def test_secure(self):
        _assert_equivalent(topology="regular", degree=5, secure=True)

    def test_secure_ppermute(self):
        _assert_equivalent(topology="regular", degree=5, secure=True,
                           shard_backend="ppermute")

    def test_secure_churn_recovery(self):
        """secure=True under churn via the Bonawitz seed-recovery pass:
        the sharded recovery schedule (canonical tables gathered at this
        device's rows) must reproduce the single-device trajectory."""
        _assert_equivalent(topology="regular", degree=5, secure=True,
                           participation=0.6, secure_recovery=True)

    def test_secure_churn_recovery_machine_correlated(self):
        _assert_equivalent(topology="regular", degree=5, secure=True,
                           participation=0.6, churn_machines=4,
                           secure_recovery=True)

    def test_randomk_per_node_keys(self):
        _assert_equivalent(topology="regular", degree=5, sharing="randomk")

    def test_choco(self):
        _assert_equivalent(topology="regular", degree=5, sharing="choco")

    # --- payload wire format: sharded == single-device, both gossip
    # lowerings; the ppermute backend exchanges (B, k) idx/val payloads ---
    def test_payload_randomk(self):
        _assert_equivalent(topology="regular", degree=5, sharing="randomk",
                           payload="on")

    def test_payload_randomk_strided_ppermute(self):
        _assert_equivalent(topology="regular", degree=5, sharing="randomk",
                           randk_sampler="strided", payload="on",
                           shard_backend="ppermute")

    def test_payload_topk_ppermute(self):
        _assert_equivalent(topology="regular", degree=5, sharing="topk",
                           payload="on", shard_backend="ppermute")

    def test_payload_topk_dynamic(self):
        _assert_equivalent(topology="dynamic", degree=5, sharing="topk",
                           payload="on")

    def test_payload_churn(self):
        _assert_equivalent(topology="regular", degree=5, sharing="randomk",
                           payload="on", participation=0.6)

    def test_payload_choco(self):
        _assert_equivalent(topology="regular", degree=5, sharing="choco",
                           payload="on")

    def test_payload_quant_ppermute(self):
        _assert_equivalent(topology="regular", degree=5, sharing="topk",
                           payload="on", payload_quant=True,
                           shard_backend="ppermute")

    def test_payload_topk_churn_ppermute(self):
        _assert_equivalent(topology="regular", degree=5, sharing="topk",
                           payload="on", participation=0.6,
                           shard_backend="ppermute")

    def test_payload_strided_dynamic_churn(self):
        _assert_equivalent(topology="dynamic", degree=5, sharing="randomk",
                           randk_sampler="strided", payload="on",
                           participation=0.6)

    def test_heterogeneous_compute_time(self):
        """Per-node (N,) compute times slice correctly into device row
        blocks inside the traced round-time formula."""
        _assert_equivalent(topology="regular", degree=5, network="lan",
                           compute_time_s=0.01, straggler_factor=10.0,
                           straggler_frac=0.25)

    def test_machine_correlated_churn(self):
        _assert_equivalent(topology="regular", degree=5, participation=0.6,
                           churn_machines=4)

    def test_non_sync_semantics_rejected(self):
        with pytest.raises(ValueError, match="single-host"):
            _engine(topology="regular", degree=5, shard_devices=8,
                    semantics="async")

    def test_uneven_nodes_rejected(self):
        with pytest.raises(ValueError, match="divide evenly"):
            _engine(n_nodes=12, topology="regular", degree=5, shard_devices=8)

    def test_legacy_dispatch_rejected(self):
        with pytest.raises(ValueError, match="chunk_rounds"):
            _engine(topology="regular", degree=5, shard_devices=8, chunk_rounds=0)

    def test_ppermute_needs_static_sparse(self):
        with pytest.raises(ValueError, match="static sparse"):
            _engine(topology="dynamic", degree=5, shard_devices=8,
                    shard_backend="ppermute")


@pytest.mark.skipif(not MULTIDEV, reason="needs 8 devices (run via launcher)")
class TestMixSparseShmap:
    @pytest.mark.parametrize("backend", ["ppermute", "gather"])
    def test_matches_single_device(self, backend):
        from repro.core.mixing import mix_sparse, mix_sparse_shmap

        mesh = jax.sharding.Mesh(np.asarray(jax.devices()[:8]), ("data",))
        for n, d in [(8, 4), (32, 5)]:
            g = Graph.random_regular(n, d, seed=1)
            st = SparseTopology.from_graph(g)
            t = {"a": jax.random.normal(jax.random.key(0), (n, 5, 3)),
                 "b": jax.random.normal(jax.random.key(1), (n, 9))}
            ref = mix_sparse(t, jax.tree_util.tree_map(jnp.asarray, st),
                             use_pallas=False)
            out = jax.jit(
                lambda x: mix_sparse_shmap(x, st, mesh, ("data",), backend=backend)
            )(t)
            for l1, l2 in zip(jax.tree_util.tree_leaves(ref),
                              jax.tree_util.tree_leaves(out)):
                np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                                           rtol=2e-5, atol=2e-6)


@pytest.mark.skipif(MULTIDEV, reason="already running with 8 devices")
def test_sharded_suite_in_subprocess():
    """Tier-1 entry point: run this module's 8-device tests in a subprocess
    with the emulated device count (it locks at first jax init)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8"
    ).strip()
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(root, "src"), env.get("PYTHONPATH", "")]
    ).rstrip(os.pathsep)
    r = subprocess.run(
        [sys.executable, "-m", "pytest", "-x", "-q", os.path.abspath(__file__)],
        capture_output=True, text=True, env=env, timeout=1800,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert r.returncode == 0, r.stdout + r.stderr
