"""Elastic membership in isolation (``repro.runtime.membership``).

The failure detector and the rejoin admission state machine, with no
sockets and no full run: silence past ``dead_timeout_s`` and retry-budget
exhaustion each declare dead exactly once; a re-JOIN from a declared-dead
peer at a newer epoch clears the dead mark (at its committed admission
round); epoch-stamped frame admission rejects zombies and ignores
not-yet-announced future incarnations.
"""
import pytest

from repro.runtime.membership import (
    Membership,
    RUNTIME_COUNTER_KEYS,
    zero_counters,
)


def mk(n=4, wid=0, timeout=3.0):
    return Membership(n, wid, timeout)


class TestCounterSchema:
    def test_schema_extends_pr7(self):
        for k in ("faults_detected", "retry_total", "leaves",
                  "rejoin_total", "stale_frames_dropped", "catchup_bytes"):
            assert k in RUNTIME_COUNTER_KEYS

    def test_zero_counters(self):
        c = zero_counters()
        assert set(c) == set(RUNTIME_COUNTER_KEYS)
        assert all(v == 0 for v in c.values())


class TestFailureDetector:
    def test_silence_past_timeout_declares_dead_exactly_once(self):
        m = mk(timeout=3.0)
        m.heartbeat(1, 0, now=10.0)
        assert not m.silent_too_long(1, now=12.9)
        assert m.silent_too_long(1, now=13.1)
        # the declare is idempotent: one detection per incarnation,
        # however many silence checks fire afterwards
        assert m.declare_dead(1) is True
        assert m.declare_dead(1) is False
        assert m.declare_dead(1) is False
        assert not m.is_live(1)
        # a dead peer no longer trips the silence check at all
        assert not m.silent_too_long(1, now=99.0)

    def test_retry_exhaustion_same_declare_path(self):
        # send-retry exhaustion calls the same declare_dead: the second
        # path (e.g. silence after the retry fault) must be a no-op
        m = mk()
        assert m.declare_dead(2) is True   # retry budget exhausted
        assert m.declare_dead(2) is False  # silence detector fires later
        assert m.dead == {2}

    def test_never_heard_is_not_silent(self):
        m = mk()
        assert not m.silent_too_long(1, now=1e9)

    def test_graceful_leave_is_not_a_fault(self):
        m = mk()
        assert m.declare_left(3) is True
        assert m.declare_left(3) is False
        assert m.declare_dead(3) is False  # already gone, not a new fault
        assert m.left == {3} and m.dead == set()

    def test_zombie_heartbeat_does_not_refresh(self):
        m = mk()
        m.heartbeat(1, 0, now=1.0)
        m.declare_dead(1)
        m.hello(1, 1)  # new incarnation announced
        assert m.heartbeat(1, 0, now=50.0) == "stale"  # the corpse beacons
        assert m.last_seen[1] == 1.0
        assert m.heartbeat(1, 1, now=51.0) == "ok"
        assert m.last_seen[1] == 51.0


class TestFrameAdmission:
    def test_live_current_epoch_ok(self):
        m = mk()
        assert m.frame_status(1, 0) == "ok"

    def test_dead_sender_is_stale_even_at_current_epoch(self):
        m = mk()
        m.declare_dead(1)
        assert m.frame_status(1, 0) == "stale"

    def test_left_sender_is_stale(self):
        m = mk()
        m.declare_left(1)
        assert m.frame_status(1, 0) == "stale"

    def test_older_epoch_is_stale_newer_is_future(self):
        m = mk()
        m.declare_dead(1)
        m.hello(1, 2)
        assert m.frame_status(1, 1) == "stale"   # pre-crash zombie
        assert m.frame_status(1, 2) == "ok"      # mid-rejoin incarnation
        assert m.frame_status(1, 3) == "future"  # JOIN not yet seen

    def test_unknown_worker_is_stale(self):
        m = mk(n=4)
        assert m.frame_status(17, 0) == "stale"


class TestRejoin:
    def test_rejoin_clears_dead_mark_at_admission(self):
        m = mk()
        m.declare_dead(1)
        assert m.hello(1, 1) == "rejoin"
        assert not m.is_live(1)            # not live until the start round
        assert 1 in m.beacon_targets()     # but beaconed while pending
        assert m.schedule_admit(1, 1, start_round=10, cur_round=5)
        assert m.due_admissions(9) == []
        assert m.due_admissions(10) == [1]
        assert m.admit(1) is True          # was dead -> counts rejoin_total
        assert m.is_live(1)
        assert m.dead == set() and not m._pending(1)

    def test_hello_at_stale_epoch_rejected(self):
        m = mk()
        m.declare_dead(1)
        assert m.hello(1, 0) == "stale"    # zombie JOIN at the old epoch
        assert not m._pending(1)
        assert m.hello(1, 1) == "rejoin"

    def test_hello_from_live_peer_at_newer_epoch(self):
        # the supervisor relaunched the peer before this worker noticed
        # the death — the caller retires the old incarnation first, then
        # hello returns 'ok' for a live peer
        m = mk()
        assert m.hello(1, 1) == "ok"
        assert m.epochs[1] == 1

    def test_admit_requires_safe_future_round(self):
        m = mk()
        m.declare_dead(1)
        m.hello(1, 1)
        # cur_round + 1's barrier may already be in flight
        assert not m.schedule_admit(1, 1, start_round=6, cur_round=5)
        assert m.schedule_admit(1, 1, start_round=7, cur_round=5)

    def test_admit_requires_matching_epoch(self):
        m = mk()
        m.declare_dead(1)
        m.hello(1, 2)
        assert not m.schedule_admit(1, 1, start_round=10, cur_round=0)
        assert m.schedule_admit(1, 2, start_round=10, cur_round=0)

    def test_second_death_after_rejoin_counts_again(self):
        # detection/rejoin conservation across two full cycles
        m = mk()
        detected = rejoined = 0
        for ep in (1, 2):
            detected += int(m.declare_dead(1))
            assert m.hello(1, ep) == "rejoin"
            assert m.schedule_admit(1, ep, start_round=10 * ep, cur_round=0)
            rejoined += int(m.admit(1))
        assert detected == 2 and rejoined == 2
        assert detected == len(m.dead) + rejoined

    def test_pending_cleared_by_new_death(self):
        m = mk()
        m.declare_dead(1)
        m.hello(1, 1)
        m.schedule_admit(1, 1, start_round=10, cur_round=0)
        m.admit(1)
        # the rejoined incarnation dies too, while nothing is pending
        assert m.declare_dead(1) is True
        assert m.due_admissions(99) == []

    def test_snapshot_shape(self):
        m = mk()
        m.declare_dead(1)
        m.hello(1, 1)
        s = m.snapshot()
        assert s["dead"] == [1] and s["pending"] == [1]
        assert s["epochs"][1] == 1
