"""Prefill-then-decode must match the teacher-forced forward — the
serving-path integration property."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ModelConfig
from repro.models.api import decode_step, forward, init_params, prefill

B, S, V = 2, 16, 64

CFGS = {
    "dense": ModelConfig(name="d", family="dense", n_layers=2, d_model=64,
                         n_heads=4, n_kv_heads=2, d_ff=128, vocab=V, qk_norm=True),
    "mla": ModelConfig(name="m", family="dense", n_layers=2, d_model=64,
                       n_heads=4, n_kv_heads=4, d_ff=128, vocab=V, mla=True,
                       kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16),
    "moe-alt": ModelConfig(name="e", family="moe", n_layers=4, d_model=64,
                           n_heads=4, n_kv_heads=2, d_ff=128, vocab=V, n_experts=4,
                           moe_top_k=2, d_expert=64, moe_every=2,
                           capacity_factor=8.0),
}


@pytest.mark.parametrize("name", list(CFGS))
def test_prefill_then_decode_matches_forward(name):
    cfg = CFGS[name]
    params = init_params(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, V)
    full, _ = forward(params, cfg, {"tokens": toks, "labels": toks})

    S0 = S // 2
    logits0, cache = prefill(params, cfg, {"tokens": toks[:, :S0]}, max_len=S)
    np.testing.assert_allclose(
        np.asarray(logits0), np.asarray(full[:, S0 - 1]), rtol=2e-3, atol=2e-3
    )
    for t in range(S0, S):
        logits, cache = decode_step(params, cfg, cache, toks[:, t : t + 1], jnp.int32(t))
        np.testing.assert_allclose(
            np.asarray(logits[:, 0]), np.asarray(full[:, t]), rtol=2e-3, atol=2e-3
        )


def test_vlm_mrope_decode_matches_forward():
    """VLM (M-RoPE) decode consistency: text-mode embeddings make the
    forward and the token decode comparable."""
    from repro.models.api import init_cache

    cfg = ModelConfig(name="v", family="vlm", n_layers=2, d_model=64, n_heads=4,
                      n_kv_heads=2, d_ff=128, vocab=V, mrope_sections=(4, 2, 2),
                      stub_frontend=True)
    params = init_params(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, V)
    emb = params["embed"][toks]
    pos3 = jnp.broadcast_to(jnp.arange(S)[None, None], (3, B, S))
    full, _ = forward(params, cfg, {"embeddings": emb, "positions": pos3,
                                    "labels": toks})
    cache = init_cache(cfg, B, S)
    for t in range(S):
        logits, cache = decode_step(params, cfg, cache, toks[:, t : t + 1], jnp.int32(t))
        np.testing.assert_allclose(
            np.asarray(logits[:, 0]), np.asarray(full[:, t]), rtol=2e-3, atol=2e-3
        )
