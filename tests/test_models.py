"""Per-architecture smoke tests (deliverable f): each assigned arch's
REDUCED config runs one forward + one train step on CPU with correct
shapes and no NaNs; decode runs for every supported decode shape."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_smoke_config, supports_shape
from repro.models.api import (
    decode_step,
    forward,
    init_cache,
    init_params,
    loss_fn,
    param_count,
    param_specs,
)
from repro.optim import sgd
from repro.optim.optimizers import apply_updates

B, S = 2, 32


def _batch(cfg, key):
    tok = jax.random.randint(key, (B, S), 0, cfg.vocab)
    if cfg.family == "cnn":
        return {
            "images": jax.random.normal(key, (B, 32, 32, 3)),
            "labels": jnp.zeros((B,), jnp.int32),
        }
    if cfg.family == "encdec":
        return {
            "frames": jax.random.normal(key, (B, cfg.enc_seq, cfg.d_model)),
            "tokens": tok,
            "labels": tok,
        }
    if cfg.family == "vlm":
        return {
            "embeddings": jax.random.normal(key, (B, S, cfg.d_model)),
            "positions": jnp.broadcast_to(jnp.arange(S)[None, None], (3, B, S)),
            "labels": tok,
        }
    return {"tokens": tok, "labels": tok}


@pytest.mark.parametrize("arch", ARCHS)
class TestArchSmoke:
    def test_forward_shapes_and_finite(self, arch):
        cfg = get_smoke_config(arch)
        params = init_params(cfg, jax.random.key(0))
        batch = _batch(cfg, jax.random.key(1))
        logits, aux = forward(params, cfg, batch)
        if cfg.family == "cnn":
            assert logits.shape == (B, cfg.vocab)
        else:
            assert logits.shape == (B, S, cfg.vocab)
        assert bool(jnp.isfinite(logits).all()), arch
        assert bool(jnp.isfinite(aux)), arch

    def test_train_step_no_nan(self, arch):
        cfg = get_smoke_config(arch)
        params = init_params(cfg, jax.random.key(0))
        batch = _batch(cfg, jax.random.key(1))
        opt = sgd(1e-2)

        @jax.jit
        def step(p, b):
            l, g = jax.value_and_grad(loss_fn)(p, cfg, b)
            u, _ = opt.update(g, opt.init(p))
            return apply_updates(p, u), l

        p2, loss = step(params, batch)
        assert bool(jnp.isfinite(loss)), arch
        for leaf in jax.tree_util.tree_leaves(p2):
            assert bool(jnp.isfinite(leaf).all()), arch
        # the step must actually change parameters
        changed = any(
            bool(jnp.any(a != b))
            for a, b in zip(jax.tree_util.tree_leaves(params), jax.tree_util.tree_leaves(p2))
        )
        assert changed, arch

    def test_decode_step(self, arch):
        cfg = get_smoke_config(arch)
        if cfg.family == "cnn":
            pytest.skip("no decode for CNN classifier")
        params = init_params(cfg, jax.random.key(0))
        cache = init_cache(cfg, B, 64)
        toks = jnp.ones((B, 1), jnp.int32)
        logits, cache2 = decode_step(params, cfg, cache, toks, jnp.int32(5))
        assert logits.shape == (B, 1, cfg.vocab)
        assert bool(jnp.isfinite(logits).all()), arch
        # cache must change somewhere
        changed = any(
            bool(jnp.any(a != b))
            for a, b in zip(jax.tree_util.tree_leaves(cache), jax.tree_util.tree_leaves(cache2))
        )
        assert changed, arch

    def test_param_specs_cover_tree(self, arch):
        cfg = get_smoke_config(arch)
        specs = param_specs(cfg)
        shapes = jax.eval_shape(lambda k: init_params(cfg, k), jax.random.key(0))
        assert jax.tree_util.tree_structure(specs) == jax.tree_util.tree_structure(shapes)

    def test_shape_support_table(self, arch):
        for shape in ("train_4k", "prefill_32k", "decode_32k", "long_500k"):
            ok, reason = supports_shape(arch, shape)
            assert ok or reason, (arch, shape)
