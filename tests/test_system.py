"""End-to-end behaviour tests: the paper's system-level claims at mini
scale, the runners, the serving engine, and the HLO roofline parser."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DLConfig, DecentralizedRunner, FLConfig, FederatedRunner
from repro.data import NodeBatcher, make_dataset, sharding_partition
from repro.launch.roofline import Roofline, parse_collective_bytes
from repro.models.api import cross_entropy
from repro.models.mlp import mlp_apply, mlp_init
from repro.optim import make_optimizer


def _mlp_setup(n_nodes=8, n_train=1024, bs=16):
    ds = make_dataset("cifar10", n_train=n_train, n_test=256, sigma=0.8)
    parts = sharding_partition(ds.train_y, n_nodes, 2, seed=0)
    batcher = NodeBatcher(ds.train_x, ds.train_y, parts, bs, seed=0)

    def loss_fn(p, x, y):
        return cross_entropy(mlp_apply(p, x), y)

    def acc_fn(p, x, y):
        return (mlp_apply(p, x).argmax(-1) == y).mean()

    init = lambda k: mlp_init(k, hidden=64)
    return init, loss_fn, acc_fn, batcher


class TestDecentralizedRunner:
    def test_dpsgd_learns(self):
        init, loss, acc, batcher = _mlp_setup()
        dl = DLConfig(n_nodes=8, topology="regular", degree=4, rounds=30,
                      eval_every=29, local_steps=1)
        r = DecentralizedRunner(dl, init, loss, acc, make_optimizer("sgd", 0.05), batcher)
        hist = r.run(log=False)
        assert hist[-1]["acc_mean"] > 0.5, hist

    def test_denser_topology_not_worse(self):
        """Paper Fig. 3a ordering at mini scale: fully >= ring after equal
        rounds (non-IID)."""
        accs = {}
        for topo in ("ring", "fully"):
            init, loss, acc, batcher = _mlp_setup()
            dl = DLConfig(n_nodes=8, topology=topo, rounds=25, eval_every=24,
                          local_steps=1, seed=2)
            r = DecentralizedRunner(dl, init, loss, acc, make_optimizer("sgd", 0.05), batcher)
            accs[topo] = r.run(log=False)[-1]["acc_mean"]
        assert accs["fully"] >= accs["ring"] - 0.02, accs

    def test_bytes_accounting_scales_with_degree(self):
        init, loss, acc, batcher = _mlp_setup()
        byt = {}
        for topo, deg in (("ring", 2), ("fully", 7)):
            dl = DLConfig(n_nodes=8, topology=topo, rounds=3, eval_every=2)
            r = DecentralizedRunner(dl, init, loss, acc, make_optimizer("sgd", 0.05), batcher)
            r.run(log=False)
            byt[topo] = r.bytes_sent
        assert byt["fully"] / byt["ring"] == pytest.approx(7 / 2, rel=1e-6)

    def test_dynamic_topology_runs(self):
        init, loss, acc, batcher = _mlp_setup()
        dl = DLConfig(n_nodes=8, topology="dynamic", degree=3, rounds=5, eval_every=4)
        r = DecentralizedRunner(dl, init, loss, acc, make_optimizer("sgd", 0.05), batcher)
        hist = r.run(log=False)
        assert len(hist) >= 1

    def test_sparsified_sharing_runs_and_saves_bytes(self):
        init, loss, acc, batcher = _mlp_setup()
        dl_full = DLConfig(n_nodes=8, topology="regular", degree=4, rounds=4, eval_every=3)
        dl_rk = DLConfig(n_nodes=8, topology="regular", degree=4, rounds=4,
                         eval_every=3, sharing="randomk", budget=0.1)
        rf = DecentralizedRunner(dl_full, init, loss, acc, make_optimizer("sgd", 0.05), batcher)
        rf.run(log=False)
        rk = DecentralizedRunner(dl_rk, init, loss, acc, make_optimizer("sgd", 0.05), batcher)
        rk.run(log=False)
        assert rk.bytes_sent < 0.25 * rf.bytes_sent

    def test_secure_agg_matches_plain_accuracy_trajectory(self):
        init, loss, acc, batcher = _mlp_setup()
        dl_p = DLConfig(n_nodes=8, topology="regular", degree=4, rounds=10,
                        eval_every=9, seed=5)
        dl_s = DLConfig(n_nodes=8, topology="regular", degree=4, rounds=10,
                        eval_every=9, seed=5, secure=True)
        rp = DecentralizedRunner(dl_p, init, loss, acc, make_optimizer("sgd", 0.05), batcher)
        hp = rp.run(log=False)
        rs = DecentralizedRunner(dl_s, init, loss, acc, make_optimizer("sgd", 0.05), batcher)
        hs = rs.run(log=False)
        assert abs(hp[-1]["acc_mean"] - hs[-1]["acc_mean"]) < 0.06
        assert rs.bytes_sent == pytest.approx(1.03 * rp.bytes_sent, rel=1e-6)

    def test_results_json_written(self, tmp_path):
        init, loss, acc, batcher = _mlp_setup()
        dl = DLConfig(n_nodes=8, rounds=2, eval_every=1, results_dir=str(tmp_path))
        r = DecentralizedRunner(dl, init, loss, acc, make_optimizer("sgd", 0.05), batcher)
        r.run(log=False)
        assert (tmp_path / "results.json").exists()


class TestFederatedRunner:
    def test_fedavg_learns(self):
        init, loss, acc, batcher = _mlp_setup()
        fl = FLConfig(n_clients=8, clients_per_round=4, rounds=40, eval_every=39)
        r = FederatedRunner(fl, init, loss, acc, make_optimizer("sgd", 0.05), batcher)
        hist = r.run(log=False)
        assert hist[-1]["acc"] > 0.5


class TestServingEngine:
    def test_generate(self):
        from repro.models import ModelConfig
        from repro.models.api import init_params
        from repro.serving import ServeConfig, ServingEngine

        cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=32,
                          n_heads=2, n_kv_heads=2, d_ff=64, vocab=64)
        params = init_params(cfg, jax.random.key(0))
        eng = ServingEngine(cfg, ServeConfig(batch=2, max_len=32, eos_id=0), params)
        prompts = jax.random.randint(jax.random.key(1), (2, 4), 1, 64)
        out = eng.generate(prompts, max_new=6)
        assert out.shape == (2, 6)
        assert bool((out >= 0).all())


class TestRooflineParser:
    def test_parse_known_collectives(self):
        """Compile a module with a known psum + ppermute and check the
        parser finds the right byte counts."""
        import subprocess, sys, textwrap

        script = textwrap.dedent("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
            import jax, jax.numpy as jnp
            from jax.sharding import PartitionSpec as P
            from repro.launch.roofline import parse_collective_bytes
            from repro.utils.compat import shard_map
            mesh = jax.make_mesh((4,), ("d",))
            def f(x):
                y = jax.lax.psum(x, "d")
                z = jax.lax.ppermute(x, "d", [(i, (i+1) % 4) for i in range(4)])
                return y + z
            fn = shard_map(f, mesh=mesh, in_specs=P(None), out_specs=P(None),
                               check_vma=False)
            x = jax.ShapeDtypeStruct((1024,), jnp.float32)
            hlo = jax.jit(fn).lower(x).compile().as_text()
            c = parse_collective_bytes(hlo)
            assert c["all-reduce"] == 4096, c
            assert c["collective-permute"] == 4096, c
            assert c["count"] >= 2
            print("PARSE_OK")
        """)
        r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                           text=True, timeout=300)
        assert "PARSE_OK" in r.stdout, r.stdout + r.stderr

    def test_roofline_terms(self):
        r = Roofline(
            arch="a", shape="s", mesh="16x16", flops_dev=197e12,
            hbm_bytes_dev=819e9, coll_bytes_dev=50e9, coll_breakdown={},
            model_flops_total=197e12 * 256, n_chips=256,
        )
        assert r.t_compute == pytest.approx(1.0)
        assert r.t_memory == pytest.approx(1.0)
        assert r.t_collective == pytest.approx(1.0)
        assert r.useful_flops_ratio == pytest.approx(1.0)
        r2 = Roofline(arch="a", shape="s", mesh="16x16", flops_dev=1e12,
                      hbm_bytes_dev=819e9 * 5, coll_bytes_dev=1e9,
                      coll_breakdown={}, model_flops_total=1e12, n_chips=256)
        assert r2.bottleneck == "memory"
