"""Secure aggregation: mask cancellation exactness, privacy of individual
messages, byte accounting."""
import jax
import jax.numpy as jnp
import numpy as np

import pytest

from repro.core.secure import SecureAggregation
from repro.core.topology import Graph


def _setup(n=8, p=128, degree=4, seed=0):
    g = Graph.regular_circulant(n, degree)
    X = jax.random.normal(jax.random.key(seed), (n, p))
    W = jnp.asarray(g.metropolis_hastings(), jnp.float32)
    return g, X, W


class TestSecureAggregation:
    def test_aggregate_equals_plain(self):
        """Masks cancel: secure aggregate == plain MH aggregate (fp32 tol —
        the paper's 'precision loss' is this rounding)."""
        g, X, W = _setup()
        s = SecureAggregation(g.adj, mask_bound=1.0)
        X2, _, _ = s.round(X, W, (), jax.random.key(1), degree=4.0, rnd=0)
        np.testing.assert_allclose(np.asarray(X2), np.asarray(W @ X), rtol=5e-4, atol=5e-5)

    def test_messages_look_masked(self):
        """Each individual message must differ substantially from the raw
        model (one-time pad), even though aggregates match."""
        g, X, W = _setup()
        s = SecureAggregation(g.adj, mask_bound=5.0)
        msgs = s.messages(X, jax.random.key(2), 0)
        for (i, r), m in list(msgs.items())[:8]:
            diff = float(jnp.linalg.norm(m - X[i]) / jnp.linalg.norm(X[i]))
            assert diff > 0.5, (i, r, diff)

    def test_masks_differ_per_round(self):
        g, X, W = _setup()
        s = SecureAggregation(g.adj)
        m0 = s.messages(X, jax.random.key(3), 0)
        m1 = s.messages(X, jax.random.key(3), 1)
        k = next(iter(m0))
        assert not np.allclose(np.asarray(m0[k]), np.asarray(m1[k]))

    def test_byte_overhead_three_percent(self):
        g, X, W = _setup(p=1000)
        s = SecureAggregation(g.adj)
        _, _, nbytes = s.round(X, W, (), jax.random.key(0), degree=4.0, rnd=0)
        plain = 4.0 * 1000 * 4
        assert abs(nbytes / plain - 1.03) < 1e-6

    def test_mean_preserved(self):
        g, X, W = _setup(n=12, degree=5, p=64)
        s = SecureAggregation(g.adj)
        X2, _, _ = s.round(X, W, (), jax.random.key(4), degree=5.0, rnd=7)
        np.testing.assert_allclose(
            np.asarray(X2).mean(0), np.asarray(X).mean(0), rtol=1e-3, atol=1e-4
        )


class TestVectorizedEquivalence:
    """The jittable masked path must equal both the Python-scheduled
    reference and plain (unmasked) MH mixing to fp32 tolerance."""

    @pytest.mark.parametrize("topo,degree", [("ring", 2), ("5-regular", 5)])
    def test_vectorized_equals_unmasked_mh(self, topo, degree):
        n, p = 12, 256
        g = Graph.ring(n) if topo == "ring" else Graph.regular_circulant(n, 5)
        X = jax.random.normal(jax.random.key(8), (n, p))
        W = jnp.asarray(g.metropolis_hastings(), jnp.float32)
        s = SecureAggregation(g.adj, mask_bound=1.0)
        X2, _, _ = s.round(X, W, (), jax.random.key(9), degree=float(degree), rnd=3)
        np.testing.assert_allclose(np.asarray(X2), np.asarray(W @ X),
                                   rtol=5e-4, atol=5e-5)

    @pytest.mark.parametrize("topo", ["ring", "5-regular"])
    def test_vectorized_equals_reference(self, topo):
        n, p = 10, 128
        g = Graph.ring(n) if topo == "ring" else Graph.regular_circulant(n, 5)
        X = jax.random.normal(jax.random.key(10), (n, p))
        W = jnp.asarray(g.metropolis_hastings(), jnp.float32)
        s = SecureAggregation(g.adj, mask_bound=2.0)
        key = jax.random.key(11)
        got, _, nb_v = s.round(X, W, (), key, degree=float(g.degrees().mean()), rnd=5)
        want, _, nb_r = s.round_reference(X, W, (), key,
                                          degree=float(g.degrees().mean()), rnd=5)
        # identical PRF keying -> identical masks; only summation order differs
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)
        assert float(nb_v) == pytest.approx(float(nb_r), rel=1e-6)

    def test_vectorized_round_is_jittable_with_traced_round_index(self):
        g, X, W = _setup(n=8, degree=4)
        s = SecureAggregation(g.adj)

        @jax.jit
        def f(X, W, key, rnd):
            X2, _, nb = s.round(X, W, (), key, degree=4.0, rnd=rnd)
            return X2, nb

        X2, nb = f(X, W, jax.random.key(12), jnp.int32(4))
        ref, _, _ = s.round_reference(X, W, (), jax.random.key(12), degree=4.0, rnd=4)
        np.testing.assert_allclose(np.asarray(X2), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)
