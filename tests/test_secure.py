"""Secure aggregation: mask cancellation exactness, privacy of individual
messages, byte accounting."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.secure import SecureAggregation
from repro.core.topology import Graph


def _setup(n=8, p=128, degree=4, seed=0):
    g = Graph.regular_circulant(n, degree)
    X = jax.random.normal(jax.random.key(seed), (n, p))
    W = jnp.asarray(g.metropolis_hastings(), jnp.float32)
    return g, X, W


class TestSecureAggregation:
    def test_aggregate_equals_plain(self):
        """Masks cancel: secure aggregate == plain MH aggregate (fp32 tol —
        the paper's 'precision loss' is this rounding)."""
        g, X, W = _setup()
        s = SecureAggregation(g.adj, mask_bound=1.0)
        X2, _, _ = s.round(X, W, (), jax.random.key(1), degree=4.0, rnd=0)
        np.testing.assert_allclose(np.asarray(X2), np.asarray(W @ X), rtol=5e-4, atol=5e-5)

    def test_messages_look_masked(self):
        """Each individual message must differ substantially from the raw
        model (one-time pad), even though aggregates match."""
        g, X, W = _setup()
        s = SecureAggregation(g.adj, mask_bound=5.0)
        msgs = s.messages(X, jax.random.key(2), 0)
        for (i, r), m in list(msgs.items())[:8]:
            diff = float(jnp.linalg.norm(m - X[i]) / jnp.linalg.norm(X[i]))
            assert diff > 0.5, (i, r, diff)

    def test_masks_differ_per_round(self):
        g, X, W = _setup()
        s = SecureAggregation(g.adj)
        m0 = s.messages(X, jax.random.key(3), 0)
        m1 = s.messages(X, jax.random.key(3), 1)
        k = next(iter(m0))
        assert not np.allclose(np.asarray(m0[k]), np.asarray(m1[k]))

    def test_byte_overhead_three_percent(self):
        g, X, W = _setup(p=1000)
        s = SecureAggregation(g.adj)
        _, _, nbytes = s.round(X, W, (), jax.random.key(0), degree=4.0, rnd=0)
        plain = 4.0 * 1000 * 4
        assert abs(nbytes / plain - 1.03) < 1e-6

    def test_mean_preserved(self):
        g, X, W = _setup(n=12, degree=5, p=64)
        s = SecureAggregation(g.adj)
        X2, _, _ = s.round(X, W, (), jax.random.key(4), degree=5.0, rnd=7)
        np.testing.assert_allclose(
            np.asarray(X2).mean(0), np.asarray(X).mean(0), rtol=1e-3, atol=1e-4
        )
