"""Secure aggregation: mask cancellation exactness, privacy of individual
messages, byte accounting, and the Bonawitz-style seed-recovery pass that
keeps cancellation exact under churn."""
import jax
import jax.numpy as jnp
import numpy as np

import pytest

from _hypothesis_compat import given, settings, st

from repro.core.secure import SEED_SHARE_BYTES, SecureAggregation
from repro.core.sharing import (
    participation_reweight,
    participation_reweight_sparse,
)
from repro.core.topology import Graph, SparseTopology


def _setup(n=8, p=128, degree=4, seed=0):
    g = Graph.regular_circulant(n, degree)
    X = jax.random.normal(jax.random.key(seed), (n, p))
    W = jnp.asarray(g.metropolis_hastings(), jnp.float32)
    return g, X, W


class TestSecureAggregation:
    def test_aggregate_equals_plain(self):
        """Masks cancel: secure aggregate == plain MH aggregate (fp32 tol —
        the paper's 'precision loss' is this rounding)."""
        g, X, W = _setup()
        s = SecureAggregation(g.adj, mask_bound=1.0)
        X2, _, _ = s.round(X, W, (), jax.random.key(1), degree=4.0, rnd=0)
        np.testing.assert_allclose(np.asarray(X2), np.asarray(W @ X), rtol=5e-4, atol=5e-5)

    def test_messages_look_masked(self):
        """Each individual message must differ substantially from the raw
        model (one-time pad), even though aggregates match."""
        g, X, W = _setup()
        s = SecureAggregation(g.adj, mask_bound=5.0)
        msgs = s.messages(X, jax.random.key(2), 0)
        for (i, r), m in list(msgs.items())[:8]:
            diff = float(jnp.linalg.norm(m - X[i]) / jnp.linalg.norm(X[i]))
            assert diff > 0.5, (i, r, diff)

    def test_masks_differ_per_round(self):
        g, X, W = _setup()
        s = SecureAggregation(g.adj)
        m0 = s.messages(X, jax.random.key(3), 0)
        m1 = s.messages(X, jax.random.key(3), 1)
        k = next(iter(m0))
        assert not np.allclose(np.asarray(m0[k]), np.asarray(m1[k]))

    def test_byte_overhead_three_percent(self):
        g, X, W = _setup(p=1000)
        s = SecureAggregation(g.adj)
        _, _, nbytes = s.round(X, W, (), jax.random.key(0), degree=4.0, rnd=0)
        plain = 4.0 * 1000 * 4
        assert abs(nbytes / plain - 1.03) < 1e-6

    def test_mean_preserved(self):
        g, X, W = _setup(n=12, degree=5, p=64)
        s = SecureAggregation(g.adj)
        X2, _, _ = s.round(X, W, (), jax.random.key(4), degree=5.0, rnd=7)
        np.testing.assert_allclose(
            np.asarray(X2).mean(0), np.asarray(X).mean(0), rtol=1e-3, atol=1e-4
        )


class TestVectorizedEquivalence:
    """The jittable masked path must equal both the Python-scheduled
    reference and plain (unmasked) MH mixing to fp32 tolerance."""

    @pytest.mark.parametrize("topo,degree", [("ring", 2), ("5-regular", 5)])
    def test_vectorized_equals_unmasked_mh(self, topo, degree):
        n, p = 12, 256
        g = Graph.ring(n) if topo == "ring" else Graph.regular_circulant(n, 5)
        X = jax.random.normal(jax.random.key(8), (n, p))
        W = jnp.asarray(g.metropolis_hastings(), jnp.float32)
        s = SecureAggregation(g.adj, mask_bound=1.0)
        X2, _, _ = s.round(X, W, (), jax.random.key(9), degree=float(degree), rnd=3)
        np.testing.assert_allclose(np.asarray(X2), np.asarray(W @ X),
                                   rtol=5e-4, atol=5e-5)

    @pytest.mark.parametrize("topo", ["ring", "5-regular"])
    def test_vectorized_equals_reference(self, topo):
        n, p = 10, 128
        g = Graph.ring(n) if topo == "ring" else Graph.regular_circulant(n, 5)
        X = jax.random.normal(jax.random.key(10), (n, p))
        W = jnp.asarray(g.metropolis_hastings(), jnp.float32)
        s = SecureAggregation(g.adj, mask_bound=2.0)
        key = jax.random.key(11)
        got, _, nb_v = s.round(X, W, (), key, degree=float(g.degrees().mean()), rnd=5)
        want, _, nb_r = s.round_reference(X, W, (), key,
                                          degree=float(g.degrees().mean()), rnd=5)
        # identical PRF keying -> identical masks; only summation order differs
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)
        assert float(nb_v) == pytest.approx(float(nb_r), rel=1e-6)

    def test_vectorized_round_is_jittable_with_traced_round_index(self):
        g, X, W = _setup(n=8, degree=4)
        s = SecureAggregation(g.adj)

        @jax.jit
        def f(X, W, key, rnd):
            X2, _, nb = s.round(X, W, (), key, degree=4.0, rnd=rnd)
            return X2, nb

        X2, nb = f(X, W, jax.random.key(12), jnp.int32(4))
        ref, _, _ = s.round_reference(X, W, (), jax.random.key(12), degree=4.0, rnd=4)
        np.testing.assert_allclose(np.asarray(X2), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)


class TestSeedRecovery:
    """Bonawitz seed recovery: with ``recovery=True`` and the participation
    mask passed as ``act``, the corrected masked aggregate must equal the
    churn-reweighted plain aggregate at fp32 tolerance — dropped senders'
    uncancelled pair masks are re-derived by surviving co-neighbors and
    subtracted (core/secure.py recovery pass)."""

    def _act(self, n, seed):
        """A churn mask with at least one down and one live node."""
        rng = np.random.default_rng(seed)
        act = (rng.random(n) > 0.4).astype(np.float32)
        act[rng.integers(n)] = 0.0
        act[rng.integers(n)] = 1.0
        return jnp.asarray(act)

    @settings(max_examples=8)
    @given(st.integers(min_value=0, max_value=10_000))
    def test_dense_recovery_equals_churn_reweighted(self, seed):
        g, X, W = _setup(n=12, degree=4, p=64, seed=seed % 97)
        act = self._act(12, seed)
        Wm, _ = participation_reweight(W, act)
        s = SecureAggregation(g.adj, mask_bound=1.0, recovery=True)
        X2, _, _ = s.round(X, Wm, (), jax.random.key(seed), degree=4.0,
                           rnd=seed % 13, act=act)
        want = np.asarray(Wm @ X)
        live = np.asarray(act) > 0
        np.testing.assert_allclose(np.asarray(X2)[live], want[live],
                                   rtol=5e-4, atol=5e-5)

    @settings(max_examples=8)
    @given(st.integers(min_value=0, max_value=10_000))
    def test_sparse_recovery_matches_dense_oracle(self, seed):
        g, X, W = _setup(n=12, degree=4, p=64, seed=seed % 89)
        act = self._act(12, seed)
        Wm, _ = participation_reweight(W, act)
        topo, _ = participation_reweight_sparse(SparseTopology.from_graph(g), act)
        s = SecureAggregation(g.adj, mask_bound=1.0, recovery=True)
        X2, _, _ = s.round(X, topo, (), jax.random.key(seed), degree=4.0,
                           rnd=seed % 11, act=act)
        live = np.asarray(act) > 0
        np.testing.assert_allclose(np.asarray(X2)[live], np.asarray(Wm @ X)[live],
                                   rtol=5e-4, atol=5e-5)

    def test_without_recovery_masks_do_not_cancel(self):
        """Negative control: skipping the recovery pass under churn leaves
        the dropped pairs' PRF masks in the aggregate."""
        g, X, W = _setup(n=12, degree=4, p=64, seed=0)
        act = self._act(12, 3)
        Wm, _ = participation_reweight(W, act)
        s = SecureAggregation(g.adj, mask_bound=1.0, recovery=True)
        X2, _, _ = s.round(X, Wm, (), jax.random.key(5), degree=4.0, rnd=2)
        live = np.asarray(act) > 0
        err = np.abs(np.asarray(X2)[live] - np.asarray(Wm @ X)[live]).max()
        assert err > 1e-2

    def test_recovery_doubles_stage_bytes(self):
        g, _, _ = _setup()
        plain = SecureAggregation(g.adj)
        rec = SecureAggregation(g.adj, recovery=True)
        assert rec.stage_bytes_per_round(8, 128) == 2 * plain.stage_bytes_per_round(8, 128)

    def test_full_participation_recovery_is_a_noop(self):
        """With everyone live the recovery pass subtracts nothing: same
        result as the plain secure round."""
        g, X, W = _setup(n=8, degree=4, p=64)
        act = jnp.ones((8,), jnp.float32)
        s = SecureAggregation(g.adj, mask_bound=1.0, recovery=True)
        a, _, _ = s.round(X, W, (), jax.random.key(7), degree=4.0, rnd=1, act=act)
        b, _, _ = SecureAggregation(g.adj, mask_bound=1.0).round(
            X, W, (), jax.random.key(7), degree=4.0, rnd=1)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5,
                                   atol=2e-5)


class TestSeedRecoveryEngine:
    """End-to-engine: secure=True now runs under churn (and crash
    schedules) with secure_recovery=True, matching the plain engine's
    trajectory at fp32 tolerance on a single device (the 8-emulated-device
    equivalence lives in tests/test_sharded_engine.py)."""

    def _engine(self, **kw):
        from repro.core import DLConfig, RoundEngine
        from repro.data import NodeBatcher, make_dataset, sharding_partition
        from repro.optim import make_optimizer

        n = kw.setdefault("n_nodes", 12)
        ds = make_dataset("cifar10", n_train=256, n_test=32, shape=(2, 2, 1),
                          sigma=2.0)
        parts = sharding_partition(ds.train_y, n, 2, seed=0)
        batcher = NodeBatcher(ds.train_x, ds.train_y, parts, batch_size=4, seed=0)
        kw.setdefault("chunk_rounds", 4)
        kw.setdefault("eval_every", 4)
        kw.setdefault("topology", "regular")
        kw.setdefault("degree", 4)
        dl = DLConfig(local_steps=1, batch_size=4, **kw)

        def loss(p, x, y):
            t = x.reshape(x.shape[0], -1).mean(0)
            return jnp.mean((p["w"].reshape(-1, t.shape[0]) - t) ** 2)

        init = lambda key: {"w": jax.random.normal(key, (8,))}
        return RoundEngine(dl, init, loss, lambda p, x, y: -loss(p, x, y),
                           make_optimizer("sgd", 0.05), batcher)

    def _w(self, e):
        return np.asarray(jax.vmap(lambda p: p["w"])(e.params))

    def test_secure_churn_matches_plain_trajectory(self):
        kw = dict(rounds=8, seed=3, participation=0.6)
        es = self._engine(secure=True, secure_recovery=True, **kw)
        es.run(log=False)
        ep = self._engine(**kw)
        ep.run(log=False)
        np.testing.assert_allclose(self._w(es), self._w(ep), rtol=1e-3,
                                   atol=1e-4)

    def test_secure_crash_schedule_matches_plain_trajectory(self):
        from repro.core import FaultPlan

        plan = FaultPlan(crashes=((2, 1, 4), (9, 3, -1)))
        kw = dict(rounds=8, seed=3, faults=plan)
        es = self._engine(secure=True, secure_recovery=True, **kw)
        es.run(log=False)
        ep = self._engine(**kw)
        ep.run(log=False)
        np.testing.assert_allclose(self._w(es), self._w(ep), rtol=1e-3,
                                   atol=1e-4)

    def test_recovery_bytes_accounted(self):
        e = self._engine(rounds=8, seed=3, secure=True, secure_recovery=True,
                         participation=0.6)
        e.run(log=False)
        rb = float(e.scheduler._fault_totals["recovery_bytes"])
        assert rb > 0
        assert rb % SEED_SHARE_BYTES == 0
        assert e.history[-1]["recovery_bytes"] == pytest.approx(rb)
        # recovery traffic is part of the wire-byte account
        clean = self._engine(rounds=8, seed=3, participation=0.6)
        clean.run(log=False)
        assert e.bytes_sent > clean.bytes_sent
