"""Decode-vs-forward consistency: token-by-token decode through the KV /
state cache must reproduce the teacher-forced forward logits at every
position — the strongest correctness property of the serving path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ModelConfig
from repro.models.api import decode_step, forward, init_cache, init_params

B, S, V = 2, 16, 64

CFGS = {
    "dense-gqa": ModelConfig(
        name="d", family="dense", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=V, qk_norm=True, qkv_bias=True),
    "mla": ModelConfig(
        name="m", family="dense", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=V, mla=True, kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8,
        v_head_dim=16),
    "moe": ModelConfig(
        name="e", family="moe", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=V, n_experts=4, moe_top_k=2, n_shared_experts=1,
        d_expert=64, capacity_factor=8.0),  # high capacity: no token drops
    "ssm": ModelConfig(
        name="s", family="ssm", n_layers=2, d_model=64, vocab=V, ssm_state=16,
        ssm_headdim=16, ssm_chunk=8),
    "hybrid": ModelConfig(
        name="h", family="hybrid", n_layers=5, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=V, ssm_state=16, ssm_headdim=16, ssm_chunk=8, attn_every=2),
    "swa": ModelConfig(
        name="w", family="dense", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=V, sliding_window=8),
}


@pytest.mark.parametrize("name", list(CFGS))
def test_decode_matches_forward(name):
    cfg = CFGS[name]
    params = init_params(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, V)
    full_logits, _ = forward(params, cfg, {"tokens": toks, "labels": toks})

    cache = init_cache(cfg, B, S)
    step = jax.jit(lambda c, t, i: decode_step(params, cfg, c, t, i))
    for t in range(S):
        logits, cache = step(cache, toks[:, t : t + 1], jnp.int32(t))
        np.testing.assert_allclose(
            np.asarray(logits[:, 0]), np.asarray(full_logits[:, t]),
            rtol=2e-3, atol=2e-3,
        ), (name, t)


def test_encdec_decode_matches_forward():
    cfg = ModelConfig(
        name="ed", family="encdec", n_layers=2, n_enc_layers=2, enc_seq=8,
        d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=V, stub_frontend=True)
    params = init_params(cfg, jax.random.key(0))
    frames = jax.random.normal(jax.random.key(2), (B, 8, 64))
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, V)
    full_logits, _ = forward(params, cfg, {"frames": frames, "tokens": toks, "labels": toks})

    from repro.models.encdec import encdec_cache_init
    from repro.models.transformer import lm_head
    from repro.models.encdec import encdec_decode

    cache = encdec_cache_init(params, cfg, frames, B, S)
    for t in range(S):
        x = params["embed"][toks[:, t : t + 1]]
        h, cache = encdec_decode(params, cfg, cache, x, jnp.int32(t))
        logits = lm_head(params, cfg, h)
        np.testing.assert_allclose(
            np.asarray(logits[:, 0]), np.asarray(full_logits[:, t]),
            rtol=2e-3, atol=2e-3,
        )


def test_swa_ring_buffer_long_sequence():
    """Decode far past the window: ring buffer must keep only the last W
    keys (logits from decode equal forward over a long sequence)."""
    cfg = CFGS["swa"]
    W = cfg.sliding_window
    S2 = 3 * W
    params = init_params(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(3), (B, S2), 0, V)
    full_logits, _ = forward(params, cfg, {"tokens": toks, "labels": toks})
    cache = init_cache(cfg, B, S2)  # capped to W internally
    step = jax.jit(lambda c, t, i: decode_step(params, cfg, c, t, i))
    for t in range(S2):
        logits, cache = step(cache, toks[:, t : t + 1], jnp.int32(t))
        np.testing.assert_allclose(
            np.asarray(logits[:, 0]), np.asarray(full_logits[:, t]),
            rtol=2e-3, atol=2e-3,
        ), t


@pytest.mark.parametrize("window", [None, 8])
def test_chunked_attention_matches_naive(window):
    """attn_impl='chunked' (flash-style scan) must equal the naive path."""
    base = dict(family="dense", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                d_ff=128, vocab=V, sliding_window=window)
    cfg_n = ModelConfig(name="n", **base)
    cfg_c = ModelConfig(name="c", attn_impl="chunked", attn_chunk=8, **base)
    params = init_params(cfg_n, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, V)
    ln, _ = forward(params, cfg_n, {"tokens": toks, "labels": toks})
    lc, _ = forward(params, cfg_c, {"tokens": toks, "labels": toks})
    np.testing.assert_allclose(np.asarray(ln), np.asarray(lc), rtol=2e-4, atol=2e-4)
    # gradients must match too (training path)
    from repro.models.api import loss_fn
    gn = jax.grad(lambda p: loss_fn(p, cfg_n, {"tokens": toks, "labels": toks}))(params)
    gc = jax.grad(lambda p: loss_fn(p, cfg_c, {"tokens": toks, "labels": toks}))(params)
    for a, b in zip(jax.tree_util.tree_leaves(gn), jax.tree_util.tree_leaves(gc)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=5e-3, atol=5e-4)
