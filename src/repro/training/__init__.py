from repro.training.trainer import TrainConfig, make_train_step, make_node_train_step
