"""Large-model D-PSGD trainer: gossip as a first-class feature of a
tensor-parallel training step.

``make_train_step`` builds the jittable per-round function for N emulated
DL nodes stacked on the leading axis:

    grads   = vmap(grad(loss))          # local step, zero cross-node flops
    params  = optimizer(params, grads)
    params  = gossip(params)            # ring/regular/fully/dense mixing

This is the function the multi-pod dry-run lowers: node axis sharded over
('pod','data'), model tensor-parallel over 'model'.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.mixing import (
    mix_circulant,
    mix_circulant_shmap,
    mix_compressed_circulant_shmap,
    mix_dense,
    mix_fully,
)
from repro.models.api import loss_fn as model_loss_fn
from repro.models.config import ModelConfig
from repro.optim import Optimizer
from repro.optim.optimizers import apply_updates, clip_by_global_norm


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    n_nodes: int = 16
    topology: str = "regular"       # ring | regular | fully | dense (traced W)
    degree: int = 5
    mixing_impl: str = "roll"        # roll | shard_map | dense |
    #                                  sparse | quant | sparse+quant (shard_map,
    #                                  compressed wire — paper's Sharing module)
    budget: float = 0.1              # compression budget for sparse mixing
    grad_clip: Optional[float] = 1.0
    gossip_every: int = 1            # rounds between gossip (local SGD steps)
    gossip_in_fp32: bool = True


def _gossip(params, tc: TrainConfig, mesh=None, node_axes=("data",), W=None,
            pspecs=None):
    if tc.topology == "fully":
        return mix_fully(params)
    if tc.mixing_impl == "dense" or tc.topology == "dense":
        assert W is not None, "dense mixing needs a (traced) W"
        return mix_dense(params, W)
    degree = 2 if tc.topology == "ring" else tc.degree
    if tc.mixing_impl in ("sparse", "quant", "sparse+quant"):
        assert mesh is not None and pspecs is not None
        return mix_compressed_circulant_shmap(
            params, pspecs, mesh, node_axes, degree,
            budget=tc.budget, mode=tc.mixing_impl,
        )
    if tc.mixing_impl == "shard_map":
        assert mesh is not None
        return mix_circulant_shmap(params, mesh, node_axes, degree, pspecs=pspecs)
    return mix_circulant(params, tc.n_nodes, degree)


def make_node_train_step(cfg: ModelConfig, optimizer: Optimizer, tc: TrainConfig):
    """Single-node local step (no gossip) — reused by FL and tests."""

    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(model_loss_fn)(params, cfg, batch)
        if tc.grad_clip:
            grads = clip_by_global_norm(grads, tc.grad_clip)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        return apply_updates(params, updates), opt_state, loss

    return step


def make_train_step(
    cfg: ModelConfig,
    optimizer: Optimizer,
    tc: TrainConfig,
    mesh=None,
    node_axes=("data",),
    pspecs=None,
):
    """Node-stacked D-PSGD round.  batch leaves have shape (N, ...)."""

    node_step = make_node_train_step(cfg, optimizer, tc)

    def train_step(params, opt_state, batch, W=None):
        params, opt_state, losses = jax.vmap(node_step)(params, opt_state, batch)
        mixed = _gossip(params, tc, mesh=mesh, node_axes=node_axes, W=W,
                        pspecs=pspecs)
        return mixed, opt_state, losses.mean()

    return train_step
