"""Compression module (paper §2.2): general-purpose codecs for float/int
lists carried in gossip messages.  Pure-jnp reference; the TPU hot path is
``kernels/quantize.py`` (Pallas), validated against these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x, key=None):
    """Per-row symmetric int8 quantization, optionally stochastic rounding.

    x: (..., P) float -> (codes int8, scale (..., 1) float32).
    """
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    y = xf / scale
    if key is not None:
        y = jnp.floor(y + jax.random.uniform(key, y.shape))
    else:
        y = jnp.round(y)
    return jnp.clip(y, -127, 127).astype(jnp.int8), scale


def dequantize_int8(codes, scale):
    return codes.astype(jnp.float32) * scale


def quantize_int4(x, key=None):
    """Packed int4 symmetric quantization. Returns (packed uint8 (..., P/2), scale)."""
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf), axis=-1, keepdims=True) / 7.0
    scale = jnp.maximum(scale, 1e-12)
    y = xf / scale
    if key is not None:
        y = jnp.floor(y + jax.random.uniform(key, y.shape))
    else:
        y = jnp.round(y)
    q = jnp.clip(y, -7, 7).astype(jnp.int8) + 8  # [1, 15] biased
    lo, hi = q[..., 0::2], q[..., 1::2]
    return (lo.astype(jnp.uint8) | (hi.astype(jnp.uint8) << 4)), scale


def dequantize_int4(packed, scale):
    lo = (packed & 0xF).astype(jnp.int32) - 8
    hi = (packed >> 4).astype(jnp.int32) - 8
    q = jnp.stack([lo, hi], axis=-1).reshape(*packed.shape[:-1], -1)
    return q.astype(jnp.float32) * scale


def delta_encode_indices(idx):
    """Sorted-index delta encoding (smaller varint-able ints on the wire)."""
    idx = jnp.sort(idx, axis=-1)
    return jnp.diff(idx, axis=-1, prepend=jnp.zeros_like(idx[..., :1]))


def delta_decode_indices(deltas):
    return jnp.cumsum(deltas, axis=-1)
