"""Compression module (paper §2.2): general-purpose codecs for float/int
lists carried in gossip messages.  Pure-jnp reference; the TPU hot path is
``kernels/quantize.py`` (Pallas), validated against these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x, key=None):
    """Per-row symmetric int8 quantization, optionally stochastic rounding.

    x: (..., P) float -> (codes int8, scale (..., 1) float32).
    """
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    y = xf / scale
    if key is not None:
        y = jnp.floor(y + jax.random.uniform(key, y.shape))
    else:
        y = jnp.round(y)
    return jnp.clip(y, -127, 127).astype(jnp.int8), scale


def dequantize_int8(codes, scale):
    return codes.astype(jnp.float32) * scale


def quantize_int4(x, key=None):
    """Packed int4 symmetric quantization. Returns (packed uint8 (..., P/2), scale)."""
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf), axis=-1, keepdims=True) / 7.0
    scale = jnp.maximum(scale, 1e-12)
    y = xf / scale
    if key is not None:
        y = jnp.floor(y + jax.random.uniform(key, y.shape))
    else:
        y = jnp.round(y)
    q = jnp.clip(y, -7, 7).astype(jnp.int8) + 8  # [1, 15] biased
    lo, hi = q[..., 0::2], q[..., 1::2]
    return (lo.astype(jnp.uint8) | (hi.astype(jnp.uint8) << 4)), scale


def dequantize_int4(packed, scale):
    lo = (packed & 0xF).astype(jnp.int32) - 8
    hi = (packed >> 4).astype(jnp.int32) - 8
    q = jnp.stack([lo, hi], axis=-1).reshape(*packed.shape[:-1], -1)
    return q.astype(jnp.float32) * scale


# ---------------------------------------------------------------------------
# cold population-row codec (AsyncScheduler ``DLConfig.cold_dtype``)
# ---------------------------------------------------------------------------
# The cohort engine's cold (N, P) population state is only ever touched by
# row gathers/scatters, so it can live compressed: ``encode_cold`` maps a
# node-stacked pytree (every float leaf (N, ...)) into its stored form and
# ``decode_cold`` maps a (full or gathered) stored tree back to fp32.
#
# * ``bf16`` — per-leaf bitcast truncation; ``decode(encode(x)) == x``
#   bitwise for every bf16-representable fp32 value (the upcast pads the
#   mantissa with zeros), so values that survive one round-trip are fixed
#   points of all further round-trips.
# * ``int8`` — per-*row* symmetric :func:`quantize_int8` over the leaf's
#   trailing dims: codes keep the leaf's shape at 1 byte/elt plus one (N,)
#   fp32 scale per leaf (:class:`QuantRows`).  Lossy (~0.4% relative per
#   row); re-encoding a decoded row reproduces its codes exactly (the row
#   max decodes to ±127·scale, so the re-derived scale matches to rounding
#   and every |code| <= 127 re-rounds to itself), which makes untouched
#   gathered rows stable across gather/scatter cycles.
#
# Non-float leaves (int event counters, step counts) pass through raw in
# both modes.

COLD_DTYPES = ("fp32", "bf16", "int8")


@jax.tree_util.register_pytree_node_class
class QuantRows:
    """int8-quantized node-stacked leaf: ``q`` int8 codes with the original
    leaf's shape, ``s`` (N,) fp32 per-row scales.  Registered as a pytree
    so row gathers/scatters (``tree_map(take/at[].set)``) descend into both
    fields untouched."""

    __slots__ = ("q", "s")

    def __init__(self, q, s):
        self.q = q
        self.s = s

    def tree_flatten(self):
        return (self.q, self.s), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    def __repr__(self):  # pragma: no cover - debugging aid
        return f"QuantRows(q={self.q.shape}, s={self.s.shape})"


def _is_quant(x):
    return isinstance(x, QuantRows)


def quantize_rows(a) -> QuantRows:
    """(N, ...) float leaf -> :class:`QuantRows` (row-flattened int8)."""
    flat = a.reshape(a.shape[0], -1)
    q, s = quantize_int8(flat)
    return QuantRows(q.reshape(a.shape), s[:, 0])


def dequantize_rows(enc: QuantRows, dtype=jnp.float32):
    q = enc.q
    flat = q.reshape(q.shape[0], -1).astype(jnp.float32) * enc.s[:, None]
    return flat.reshape(q.shape).astype(dtype)


def encode_cold(tree, mode: str):
    """Node-stacked pytree -> its ``cold_dtype`` stored form ('fp32' is the
    identity).  Float leaves only; everything else passes through."""
    if mode == "fp32":
        return tree
    if mode not in COLD_DTYPES:
        raise ValueError(f"unknown cold_dtype {mode!r} ({'|'.join(COLD_DTYPES)})")

    def enc(a):
        if not jnp.issubdtype(jnp.asarray(a).dtype, jnp.floating):
            return a
        if mode == "bf16":
            return jnp.asarray(a, jnp.bfloat16)
        return quantize_rows(jnp.asarray(a))

    return jax.tree_util.tree_map(enc, tree)


def decode_cold(tree, mode: str):
    """Stored form (full tree or a row-gathered subtree) -> fp32 pytree."""
    if mode == "fp32":
        return tree

    def dec(x):
        if isinstance(x, QuantRows):
            return dequantize_rows(x)
        if jnp.issubdtype(x.dtype, jnp.floating) and x.dtype == jnp.bfloat16:
            return x.astype(jnp.float32)
        return x

    return jax.tree_util.tree_map(dec, tree, is_leaf=_is_quant)


def cold_leaf_bytes(leaf) -> int:
    """Stored bytes of one cold leaf (codes + scales for QuantRows)."""
    if isinstance(leaf, QuantRows):
        return int(leaf.q.nbytes + leaf.s.nbytes)
    return int(leaf.nbytes)


def cold_leaf_fp32_bytes(leaf) -> int:
    """fp32-equivalent bytes of one cold leaf (the uncompressed baseline)."""
    if isinstance(leaf, QuantRows):
        return int(leaf.q.size * 4)
    if jnp.issubdtype(leaf.dtype, jnp.floating):
        return int(leaf.size * 4)
    return int(leaf.nbytes)


def cold_tree_bytes(tree):
    """(stored, fp32-equivalent) byte totals of a cold pytree."""
    leaves = jax.tree_util.tree_leaves(tree, is_leaf=_is_quant)
    return (
        sum(cold_leaf_bytes(l) for l in leaves),
        sum(cold_leaf_fp32_bytes(l) for l in leaves),
    )


def delta_encode_indices(idx):
    """Sorted-index delta encoding (smaller varint-able ints on the wire)."""
    idx = jnp.sort(idx, axis=-1)
    return jnp.diff(idx, axis=-1, prepend=jnp.zeros_like(idx[..., :1]))


def delta_decode_indices(deltas):
    return jnp.cumsum(deltas, axis=-1)
