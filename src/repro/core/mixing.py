"""Communication/aggregation strategies — the gossip "wire".

Four interchangeable lowerings of the same math
x_i' = sum_j W_ij x_j  (W = Metropolis-Hastings weights of the overlay):

* ``mix_dense``      — W @ X einsum; W is a *traced* argument, so dynamic
                       per-round topologies never recompile.  Lowers to
                       all-gather + local matmul under GSPMD.  Works for any
                       graph (the paper's ZeroMQ generality); O(N²·P).
* ``mix_sparse``     — neighbor-indexed gather + weighted segment sum over
                       a ``SparseTopology``'s padded (N, D) tables:
                       O(N·D·P) FLOPs, the execution form for sparse graphs
                       (d ≪ N).  Optionally routes the fused K-way merge
                       through the ``kernels/gossip_mix`` Pallas kernel
                       (compiled on TPU, interpret elsewhere).  This is
                       also the neighbor-indexed form multi-host
                       `collective_permute` gossip shards over.
* ``mix_circulant``  — static circulant d-regular graphs; neighbor exchange
                       by index shift.  ``roll`` variant works everywhere
                       (CPU emulation); ``shard_map`` variant lowers each
                       offset to one `collective_permute` on the TPU mesh —
                       the TPU-native analogue of point-to-point sends.
* ``mix_fully``      — fully-connected topology = plain mean (all-reduce).
* ``mix_sparse_shmap`` — node-sharded ``mix_sparse``: the table is
                       slot-rebalanced into permutation columns and each
                       slot becomes rotation-grouped `collective_permute`s
                       (gather fallback otherwise) — the multi-device
                       generalization of ``mix_circulant_shmap`` the
                       sharded RoundEngine builds on (see the
                       ShardedTopology/ShardedDense section below).

All operate on node-stacked pytrees (leading axis N).  ``apply_W`` is the
strategy-facing primitive: one W @ Y that accepts either a dense (N, N)
matrix or a ``SparseTopology`` so every sharing strategy supports both.

``mix_payload`` is the *compressed* wire primitive: sparsified sharing
strategies hand it per-node (idx, val) payloads instead of masked (N, P)
matrices and it applies the missing-coordinate rule in one gather +
scatter-accumulate pass — O(N·d·k) compute and, on the sharded ppermute
backend, O(D·B·k) wire.  ``mix_payload_masked`` is its dense-mask oracle.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.topology import (
    Graph,
    SparseTopology,
    build_permute_schedule,
    circulant_offsets,
    decompose_slot_permutations,
    sample_neighbor_slots,
)
from repro.utils.compat import shard_map


# ---------------------------------------------------------------------------
# node-sharded gossip: the distributed backends of mix_sparse / apply_W
# ---------------------------------------------------------------------------
#
# Inside a `shard_map` body the node axis is block-sharded: each device holds
# B = N/ndev consecutive node rows of every node-stacked tensor.  The two
# wrapper types below are what strategy code sees in place of the dense W /
# SparseTopology mixing operand — `apply_W` dispatches on them, so every
# sharing strategy (full, randk, topk, choco, secure) runs distributed
# without code changes:
#
# * ``ShardedTopology`` — local (B, D) neighbor tables plus, when the table
#   decomposes into per-slot permutations (topology.decompose_slot_
#   permutations), a static `PermuteSchedule`: slot s's permutation column is
#   applied as a handful of rotation-grouped `collective_permute`s carrying
#   only the rows that cross devices — O(D·B·P) wire per mix instead of
#   all-gather's O(N·P) (with one node per device this is literally one
#   ppermute per slot, the generalization of mix_circulant_shmap to
#   arbitrary sparse graphs).  Tables that don't decompose (or per-round
#   dynamic tables, whose schedule can't be static) fall back to
#   all-gather + local neighbor gather — bit-identical to the single-device
#   path because each row's arithmetic is unchanged.
# * ``ShardedDense`` — local (B, N) W rows; all-gather + local matmul.


@dataclasses.dataclass(eq=False, frozen=True)
class NodeShard:
    """Static description of the node-axis sharding inside a shard_map body.

    axis: mesh axis name (or tuple of names) forming the node dimension;
    sizes: matching mesh axis sizes; block: rows per device (B = N/ndev).
    """

    axis: object            # str | tuple[str, ...]
    sizes: tuple
    block: int

    @property
    def ndev(self) -> int:
        n = 1
        for s in self.sizes:
            n *= s
        return n

    @property
    def n(self) -> int:
        return self.ndev * self.block

    def dev(self):
        """Linear device index along the node axis (traced)."""
        axes = (self.axis,) if isinstance(self.axis, str) else tuple(self.axis)
        idx = jnp.int32(0)
        for a, s in zip(axes, self.sizes):
            idx = idx * s + jax.lax.axis_index(a)
        return idx

    def rows(self):
        """Global node ids of this device's block, (B,) int32 (traced)."""
        return self.dev() * self.block + jnp.arange(self.block, dtype=jnp.int32)

    def gather(self, x):
        """all-gather the node axis: (B, ...) -> (N, ...)."""
        return jax.lax.all_gather(x, self.axis, axis=0, tiled=True)

    def local(self, x):
        """Slice this device's (B, ...) row block out of a replicated
        (N, ...) array (for closure-captured per-node constants)."""
        return jax.lax.dynamic_slice_in_dim(x, self.dev() * self.block, self.block, 0)

    def psum(self, x):
        return jax.lax.psum(x, self.axis)

    def pmax(self, x):
        return jax.lax.pmax(x, self.axis)


@dataclasses.dataclass(eq=False)
class PermuteSchedule:
    """Static rotation-grouped transfer tables for per-slot permutation
    gossip (see topology.build_permute_schedule).  Identity-hashed: engines
    build one per static topology and reuse it across traces."""

    slots: list  # per slot: {rotation: (send_idx (ndev, K), recv_pos (ndev, K))}

    @staticmethod
    def from_table(nbr_perm, ndev: int) -> "PermuteSchedule":
        return PermuteSchedule(build_permute_schedule(nbr_perm, ndev))


def _permute_block(x, slot_sched, shard: NodeShard):
    """Apply one global node permutation to a block-sharded (B, ...) array:
    out[i] = x_global[src[global_row(i)]], via one `collective_permute` per
    device rotation that actually carries traffic (rotation 0 is a local
    move).  Padded lanes scatter out of range and are dropped."""
    dev = shard.dev()
    ndev, b = shard.ndev, shard.block
    out = jnp.zeros_like(x)
    for r in sorted(slot_sched):
        send_idx, recv_pos = slot_sched[r]
        si = jax.lax.dynamic_index_in_dim(jnp.asarray(send_idx), dev, 0, keepdims=False)
        rp = jax.lax.dynamic_index_in_dim(jnp.asarray(recv_pos), dev, 0, keepdims=False)
        payload = jnp.take(x, si, axis=0)
        if r != 0:
            axes = (shard.axis,) if isinstance(shard.axis, str) else shard.axis
            axis = axes[0] if len(axes) == 1 else tuple(axes)
            pairs = [(d, (d + r) % ndev) for d in range(ndev)]
            payload = jax.lax.ppermute(payload, axis, pairs)
        out = out.at[rp].set(payload, mode="drop")
    return out


@dataclasses.dataclass(eq=False)
class ShardedTopology:
    """Node-sharded view of a SparseTopology inside a shard_map body.

    topo: this device's (B, D) row block of the (rebalanced, when ``sched``
    is set) neighbor/weight tables — traced leaves, so churn reweighting
    updates the weights per round while the communication schedule stays
    static.  Registered as a pytree (shard/sched are static aux data).
    """

    topo: SparseTopology
    shard: NodeShard
    sched: Optional[PermuteSchedule] = None

    @property
    def rows(self):
        return self.shard.rows()

    @property
    def w(self):
        return self.topo.w

    def neighbor_stack(self, Y):
        """(B, D, ...) stack of each local receiver's neighbor rows of the
        node-stacked Y — slot-permutation exchange when the schedule exists,
        all-gather + local gather otherwise."""
        if self.sched is not None:
            return jnp.stack(
                [_permute_block(Y, s, self.shard) for s in self.sched.slots], axis=1
            )
        return jnp.take(self.shard.gather(Y), self.topo.nbr, axis=0)

    def apply(self, Yf):
        """Row-block of W @ Y_global for local rows; Yf: (B, ...) float32."""
        w = self.topo.w.astype(jnp.float32)
        w_self = self.topo.w_self.astype(jnp.float32).reshape(
            (Yf.shape[0],) + (1,) * (Yf.ndim - 1)
        )
        if self.sched is None:
            g = jnp.take(self.shard.gather(Yf), self.topo.nbr, axis=0)
            return w_self * Yf + jnp.einsum("nd,nd...->n...", w, g)
        acc = w_self * Yf
        for s, slot_sched in enumerate(self.sched.slots):
            xs = _permute_block(Yf, slot_sched, self.shard)
            ws = w[:, s].reshape((Yf.shape[0],) + (1,) * (Yf.ndim - 1))
            acc = acc + ws * xs
        return acc


@dataclasses.dataclass(eq=False)
class ShardedDense:
    """Node-sharded dense mixing operand: this device's (B, N) W rows."""

    W: jax.Array
    shard: NodeShard

    @property
    def rows(self):
        return self.shard.rows()

    def apply(self, Yf):
        return jnp.einsum(
            "bn,n...->b...", self.W.astype(jnp.float32), self.shard.gather(Yf)
        )


jax.tree_util.register_pytree_node(
    ShardedTopology,
    lambda t: ((t.topo,), (t.shard, t.sched)),
    lambda aux, leaves: ShardedTopology(leaves[0], *aux),
)
jax.tree_util.register_pytree_node(
    ShardedDense,
    lambda t: ((t.W,), (t.shard,)),
    lambda aux, leaves: ShardedDense(leaves[0], *aux),
)


def mix_dense(stacked, W):
    """x_i' = sum_j W_ij x_j per leaf; W (N, N) may be traced."""
    W = W.astype(jnp.float32)

    def f(a):
        return jnp.einsum("ij,j...->i...", W, a.astype(jnp.float32)).astype(a.dtype)

    return jax.tree_util.tree_map(f, stacked)


def apply_W(W, Y):
    """Row-stochastic mix Y' = W @ Y, fp32 accumulate, any trailing dims.

    W: dense (N, N) array (possibly traced) *or* a ``SparseTopology``.
    The sparse form gathers each node's D neighbor rows and contracts the
    slot axis — O(N·D·prod(trailing)) instead of O(N²·prod(trailing)) —
    without ever materializing an (N, N) matrix.
    """
    Yf = Y.astype(jnp.float32)
    if isinstance(W, (ShardedTopology, ShardedDense)):
        return W.apply(Yf)  # inside a shard_map body: Y is this device's rows
    if isinstance(W, SparseTopology):
        g = jnp.take(Yf, W.nbr, axis=0)  # (N, D, ...)
        mixed = jnp.einsum("nd,nd...->n...", W.w.astype(jnp.float32), g)
        w_self = W.w_self.astype(jnp.float32).reshape(
            (Yf.shape[0],) + (1,) * (Yf.ndim - 1)
        )
        return w_self * Yf + mixed
    return jnp.einsum("ij,j...->i...", W.astype(jnp.float32), Yf)


def mix_sparse(stacked, topo: SparseTopology, *, use_pallas: Optional[bool] = None,
               interpret: Optional[bool] = None):
    """Neighbor-indexed gossip over a pytree: x_i' = w_self_i x_i +
    sum_k w[i,k] x_nbr[i,k] per leaf — O(N·D·P).

    use_pallas: route the fused (D+1)-way weighted merge through the
    ``kernels.gossip_mix`` Pallas kernel (one HBM pass per operand);
    default: compiled kernel on TPU, plain XLA gather+einsum elsewhere.
    interpret: force Pallas interpret mode (CPU emulation of the TPU
    program); defaults to interpret off-TPU.
    """
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"

    def f(a):
        af = a.astype(jnp.float32)
        if not use_pallas:
            return apply_W(topo, af).astype(a.dtype)
        from repro.kernels.gossip_mix import gossip_mix_nodes

        n = af.shape[0]
        flat = af.reshape(n, -1)
        xs = jnp.concatenate(
            [flat[:, None, :], jnp.take(flat, topo.nbr, axis=0)], axis=1
        )  # (N, 1 + D, P)
        ws = jnp.concatenate(
            [topo.w_self.astype(jnp.float32)[:, None], topo.w.astype(jnp.float32)],
            axis=1,
        )
        it = (jax.default_backend() != "tpu") if interpret is None else interpret
        out = gossip_mix_nodes(xs, ws, interpret=it)
        return out.reshape(af.shape).astype(a.dtype)

    return jax.tree_util.tree_map(f, stacked)


# ---------------------------------------------------------------------------
# payload-indexed aggregation: the compressed-sharing wire primitive
# ---------------------------------------------------------------------------
#
# Sparsified sharing strategies emit compact per-node payloads instead of
# masked (N, P) matrices: ``idx`` (N, k) int32 coordinate indices and
# ``val`` (N, k) wire values (possibly dequantized int8).  ``mix_payload``
# applies DecentralizePy's missing-coordinate rule
#
#     x_i'[c] = x_i[c] + sum_j W_ij * m_j[c] * (v_j[c] - x_i[c])
#
# in one gather + scatter-accumulate pass over neighbor payloads — O(N·d·k)
# compute and wire instead of the dense-mask form's two full apply_W
# passes at O(N·d·P).  The self slot rides along with weight w_self (it
# cancels exactly when val == x[idx], and reproduces the dense rule's
# self-roundtrip when values are quantized).  ``mix_payload_masked`` is the
# dense-mask oracle — identical math through scattered (N, P) masks and
# two apply_W passes — that the payload path is property-tested against
# (and the ``DLConfig.payload="off"`` execution path).


def _payload_operands(W, idx, valf, include_self: bool):
    """(idx_ops, val_ops, w_ops) stacked (rows, S, k)/(rows, S) operand
    payloads for each receiver — the neighbor slots of the mixing operand
    (exchanged via collective permutes when W is a scheduled
    ShardedTopology), preceded by the self slot when ``include_self``.

    The self slot's contribution w_self * (val_i - x_i[idx_i]) is exactly
    zero when payload values are the sender's own coordinates (val == x at
    idx, bit-for-bit), so callers skip it unless the wire codec perturbs
    values (int8 quantization), where the dense rule's self-roundtrip term
    must be reproduced."""
    if isinstance(W, ShardedTopology):
        idx_nbr = W.neighbor_stack(idx)                       # (B, D, k)
        val_nbr = W.neighbor_stack(valf)
        w, w_self = W.topo.w, W.topo.w_self
    else:  # SparseTopology
        idx_nbr = jnp.take(idx, W.nbr, axis=0)                # (N, D, k)
        val_nbr = jnp.take(valf, W.nbr, axis=0)
        w, w_self = W.w, W.w_self
    if not include_self:
        return idx_nbr, val_nbr, w.astype(jnp.float32)
    idx_ops = jnp.concatenate([idx[:, None, :], idx_nbr], axis=1)
    val_ops = jnp.concatenate([valf[:, None, :], val_nbr], axis=1)
    w_ops = jnp.concatenate(
        [w_self.astype(jnp.float32)[:, None], w.astype(jnp.float32)], axis=1
    )
    return idx_ops, val_ops, w_ops


def _payload_scatter(Xf, idx_ops, val_ops, w_ops):
    """out = Xf + sum over operand slots of w * (val - Xf[idx]) scattered
    at idx — the XLA lowering (take_along_axis + at[].add)."""
    n = Xf.shape[0]
    s, k = idx_ops.shape[1], idx_ops.shape[2]
    fid = idx_ops.reshape(n, s * k)
    own = jnp.take_along_axis(Xf, fid, axis=1)
    contrib = (val_ops.reshape(n, s * k) - own) * jnp.repeat(w_ops, k, axis=1)
    delta = jnp.zeros_like(Xf).at[jnp.arange(n)[:, None], fid].add(contrib)
    return Xf + delta


def mix_payload(W, idx, val, X, *, exact_values: bool = True,
                use_pallas: Optional[bool] = None,
                interpret: Optional[bool] = None):
    """Payload-indexed sparse aggregation: X' from per-node payloads.

    W: dense (N, N), ``SparseTopology``, or the sharded wrappers
    (``ShardedTopology``/``ShardedDense`` inside a shard_map body — payload
    exchange then rides the same per-slot `collective_permute` schedule as
    plain gossip, carrying (B, k) indices + values: O(D·B·k) wire).
    idx: (N, k) int32; val: (N, k) wire values; X: (N, P).  Returns fp32.

    exact_values: promise that ``val`` is bit-for-bit the sender's own
    coordinates (no lossy wire codec) — the self slot's correction is then
    exactly zero and is skipped; pass False for quantized payloads so the
    dense rule's self-roundtrip term is reproduced.

    Sparse/sharded forms run the gather + scatter-accumulate pass
    (optionally through the fused ``kernels.scatter_gossip`` Pallas kernel:
    compiled on TPU, XLA scatter elsewhere); a dense (N, N) W — the
    all-pairs oracle regime — falls back to :func:`mix_payload_masked`.
    """
    Xf = X.astype(jnp.float32)
    valf = val.astype(jnp.float32)
    if isinstance(W, ShardedDense):
        idx_g, val_g = W.shard.gather(idx), W.shard.gather(valf)
        MX = _scatter_rows(idx_g, val_g, (idx_g.shape[0], Xf.shape[1]))
        M = _scatter_rows(idx_g, jnp.ones_like(val_g), MX.shape)
        return Xf + W.apply(MX) - Xf * W.apply(M)
    if isinstance(W, (ShardedTopology, SparseTopology)):
        idx_ops, val_ops, w_ops = _payload_operands(
            W, idx, valf, include_self=not exact_values
        )
        if use_pallas is None:
            use_pallas = jax.default_backend() == "tpu"
        if use_pallas:
            from repro.kernels.scatter_gossip import payload_mix_nodes

            it = (jax.default_backend() != "tpu") if interpret is None else interpret
            return payload_mix_nodes(
                Xf, idx_ops, val_ops, w_ops, interpret=it
            ).astype(jnp.float32)
        return _payload_scatter(Xf, idx_ops, val_ops, w_ops)
    return mix_payload_masked(W, idx, valf, Xf)


def mix_payload_strided(W, phase, val, X, *, exact_values: bool = True):
    """Strided-payload aggregation — the windowed-scatter fast path for
    ``RandomKSharing(sampler='strided')``.

    The P axis is split into k equal cells of width ``stride`` (the caller
    pads P up to k·stride); sender n's payload is its value at offset
    ``phase[n]`` of *every* cell: idx = i·stride + phase_n.  Because one
    offset addresses a whole k-vector, a receiver applies neighbor s's
    payload as a single k-wide column update of its (k, stride) cell view
    — the scatter indexes N·D rows instead of N·D·k elements, which XLA
    vectorizes (each scattered window is a contiguous k-vector), so the
    receive runs at O(N·d·k) vector speed with no dense (N, P) mask.

    phase: (N,) int32 in [0, stride); val: (N, k); X: (N, k·stride).
    Dense (N, N) W falls back to the masked oracle on reconstructed
    indices.  exact_values as in :func:`mix_payload`.
    """
    Xf = X.astype(jnp.float32)
    valf = val.astype(jnp.float32)
    n, p = Xf.shape
    k = valf.shape[1]
    stride = p // k
    if isinstance(W, ShardedDense) or not isinstance(
        W, (ShardedTopology, SparseTopology)
    ):
        idx = jnp.arange(k, dtype=jnp.int32)[None, :] * stride + phase[:, None]
        if isinstance(W, ShardedDense):
            idx_g, val_g = W.shard.gather(idx), W.shard.gather(valf)
            MX = _scatter_rows(idx_g, val_g, (idx_g.shape[0], p))
            M = _scatter_rows(idx_g, jnp.ones_like(val_g), MX.shape)
            return Xf + W.apply(MX) - Xf * W.apply(M)
        return mix_payload_masked(W, idx, valf, Xf)
    if isinstance(W, ShardedTopology):
        ph_ops = W.neighbor_stack(phase)                   # (B, D)
        val_ops = W.neighbor_stack(valf)                   # (B, D, k)
        w_ops = W.topo.w.astype(jnp.float32)
        w_self = W.topo.w_self
    else:
        ph_ops = jnp.take(phase, W.nbr, axis=0)            # (N, D)
        val_ops = jnp.take(valf, W.nbr, axis=0)            # (N, D, k)
        w_ops = W.w.astype(jnp.float32)
        w_self = W.w_self
    if not exact_values:
        ph_ops = jnp.concatenate([phase[:, None], ph_ops], axis=1)
        val_ops = jnp.concatenate([valf[:, None, :], val_ops], axis=1)
        w_ops = jnp.concatenate(
            [w_self.astype(jnp.float32)[:, None], w_ops], axis=1
        )
    cells_t = jnp.moveaxis(Xf.reshape(n, k, stride), 1, 2)  # (N, stride, k)
    own = jnp.take_along_axis(cells_t, ph_ops[:, :, None], axis=1)  # (N, D, k)
    contrib = w_ops[:, :, None] * (val_ops - own)
    delta_t = jnp.zeros_like(cells_t).at[
        jnp.arange(n)[:, None], ph_ops, :
    ].add(contrib)
    return Xf + jnp.moveaxis(delta_t, 1, 2).reshape(n, p)


def _scatter_rows(idx, val, shape):
    """Dense (N, P) scatter of per-row payloads (payload indices are unique
    per row, so set == add)."""
    return jnp.zeros(shape, jnp.float32).at[
        jnp.arange(shape[0])[:, None], idx
    ].set(val.astype(jnp.float32))


def mix_payload_masked(W, idx, val, X):
    """Dense-mask oracle of :func:`mix_payload`: scatter the payload into
    (N, P) value/mask matrices and apply the missing-coordinate rule as
    X' = X + W@(M*V) - X*(W@M) — two full apply_W passes, O(N·d·P).  With
    val gathered from X this is bit-for-bit the legacy ``sparse_aggregate``
    dense-mask path; it stays as the equivalence oracle and the
    ``payload="off"`` execution mode."""
    Xf = X.astype(jnp.float32)
    MX = _scatter_rows(idx, val, Xf.shape)
    M = _scatter_rows(idx, jnp.ones_like(val, jnp.float32), Xf.shape)
    return Xf + apply_W(W, MX) - Xf * apply_W(W, M)


def gossip_pair_avg(topo: SparseTopology, X, key, *, fire=None, act=None,
                    rows=None):
    """One event-cohort of *pairwise* asynchronous gossip — the AD-PSGD
    update (Lian et al. 2018) in one-sided-read form.  This IS the
    execution path of ``AsyncScheduler`` with ``async_gossip="pairwise"``
    (not just a reference implementation).

    Each node draws one uniformly-random neighbor slot from its
    ``SparseTopology`` table (``topology.sample_neighbor_slots`` — the
    per-event sampling primitive) and averages with that partner's
    current — possibly stale — row:

        x_i' = (x_i + x_{j(i)}) / 2      for fired nodes i (partner up)
        x_i' = x_i                       otherwise

    fire: optional (N,) {0,1} mask of nodes whose event fires this cohort
    (None = everyone).  act: optional (N,) {0,1} churn mask — a sampled
    partner that is down blocks the exchange (the node keeps its local
    step and retries at its next event).  The read is one-sided: partner
    j's row is read but not written, so concurrent events never conflict
    — the write-locked symmetric exchange of the original algorithm is
    modeled in expectation (each direction of an edge fires as its
    endpoint's event).  In expectation over the partner draw the
    fired-row update equals the uniform-neighbor mixing matrix row
    (0.5 self + 0.5/deg per neighbor) — seeded-statistically tested in
    tests/test_scheduler.py.

    Returns (X', partner, ok): partner the (N,) global partner ids (a
    node's own id where no exchange happened), ok the (N,) {0,1} mask of
    exchanges that actually fired — for staleness/comm accounting by the
    caller.
    """
    Xf = X.astype(jnp.float32)
    slot = sample_neighbor_slots(key, topo, rows=rows)
    partner = jnp.take_along_axis(topo.nbr, slot[:, None], axis=1)[:, 0]
    ok = jnp.ones(partner.shape[0], jnp.float32)
    if fire is not None:
        ok = ok * fire
    if act is not None:
        ok = ok * jnp.take(act, partner)
    X2 = 0.5 * (Xf + jnp.take(Xf, partner, axis=0))
    m = ok.reshape((-1,) + (1,) * (Xf.ndim - 1))
    X2 = jnp.where(m > 0, X2, Xf)
    partner = jnp.where(ok > 0, partner, jnp.arange(partner.shape[0]))
    return X2.astype(X.dtype), partner, ok


def mix_fully(stacked):
    """Fully-connected with uniform MH weights == mean over nodes."""

    def f(a):
        return jnp.broadcast_to(
            a.astype(jnp.float32).mean(0, keepdims=True), a.shape
        ).astype(a.dtype)

    return jax.tree_util.tree_map(f, stacked)


def mix_circulant(stacked, n: int, degree: int, weights: Optional[jax.Array] = None):
    """Static circulant d-regular gossip via roll (emulation / GSPMD path).

    weights: optional (1 + n_offsets,) traced [w_self, w_off1, ...];
    defaults to uniform MH 1/(degree+1).
    """
    offs = circulant_offsets(n, degree)
    if weights is None:
        weights = jnp.full((1 + len(offs),), 1.0 / (degree + 1), jnp.float32)

    def f(a):
        acc = weights[0] * a.astype(jnp.float32)
        for k, o in enumerate(offs):
            contrib = jnp.roll(a, -o, 0).astype(jnp.float32)
            if 2 * o % n != 0:  # antipodal offset has a single neighbor
                contrib = contrib + jnp.roll(a, o, 0).astype(jnp.float32)
            acc = acc + weights[1 + k] * contrib
        return acc.astype(a.dtype)

    return jax.tree_util.tree_map(f, stacked)


def mix_circulant_shmap(stacked, mesh, node_axes, degree: int,
                        weights: Optional[jax.Array] = None, pspecs=None):
    """Circulant gossip with explicit `collective_permute` per offset.

    node_axes: mesh axis name(s) forming the node dimension, e.g.
    ('data',) or ('pod', 'data').  Requires N == prod(mesh sizes of axes)
    and every leaf's leading dim == N.

    pspecs: optional PartitionSpec pytree matching ``stacked`` — REQUIRED
    when leaves are tensor-parallel-sharded, otherwise shard_map would
    reshard (replicate) them across the model axis and the wire would pay
    the full unsharded model per send (measured 16x inflation).
    """
    n = 1
    for ax in node_axes:
        n *= mesh.shape[ax]
    offs = circulant_offsets(n, degree)
    if weights is None:
        weights = jnp.full((1 + len(offs),), 1.0 / (degree + 1), jnp.float32)
    axis = tuple(node_axes) if len(node_axes) > 1 else node_axes[0]

    def local(w, *leaves):
        out = []
        for a in leaves:
            # Pin the wire dtype: XLA canonicalizes convert∘permute into
            # permute∘convert, which would ship fp32 (2x bytes) for bf16
            # params.  Permuting the *bitcast integer* view makes that
            # rewrite impossible — the interconnect carries exactly
            # param-dtype bytes.
            int_dt = {2: jnp.uint16, 4: jnp.uint32, 1: jnp.uint8}[a.dtype.itemsize]
            a_wire = jax.lax.bitcast_convert_type(a, int_dt)
            unwire = lambda t: jax.lax.bitcast_convert_type(t, a.dtype).astype(jnp.float32)
            acc = w[0] * a.astype(jnp.float32)
            for k, o in enumerate(offs):
                fwd = [(i, (i + o) % n) for i in range(n)]
                contrib = unwire(jax.lax.ppermute(a_wire, axis, fwd))
                if 2 * o % n != 0:
                    bwd = [(i, (i - o) % n) for i in range(n)]
                    contrib = contrib + unwire(jax.lax.ppermute(a_wire, axis, bwd))
                acc = acc + w[1 + k] * contrib
            out.append(acc.astype(a.dtype))
        return tuple(out)

    leaves, treedef = jax.tree_util.tree_flatten(stacked)
    if pspecs is not None:
        spec_leaves = jax.tree_util.tree_flatten(pspecs)[0]
    else:
        spec_leaves = [P(node_axes, *((None,) * (l.ndim - 1))) for l in leaves]
    in_specs = (P(),) + tuple(spec_leaves)
    out_specs = tuple(spec_leaves)
    fn = shard_map(local, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                       check_vma=False)
    mixed = fn(weights, *leaves)
    return jax.tree_util.tree_unflatten(treedef, mixed)


def mix_sparse_shmap(stacked, topo: SparseTopology, mesh, node_axes, *,
                     pspecs=None, backend: str = "auto"):
    """Distributed neighbor-indexed gossip: x_i' = w_self_i x_i +
    sum_k w[i,k] x_nbr[i,k] with the node axis sharded over ``mesh``.

    Generalizes ``mix_circulant_shmap`` from circulant offsets to any
    static ``SparseTopology``: the padded (N, D) table is slot-rebalanced
    into D permutation columns (topology.decompose_slot_permutations), and
    each column lowers to rotation-grouped `collective_permute`s — exactly
    one ppermute per slot when N equals the device count.  Tables that
    don't decompose (or backend="gather") use all-gather + local gather.

    node_axes: mesh axis name(s) forming the node dimension; N must be a
    multiple of the product of their sizes, and every leaf's leading dim N.
    backend: "auto" (ppermute when decomposable) | "ppermute" | "gather".
    """
    if backend not in ("auto", "ppermute", "gather"):
        raise ValueError(f"unknown backend {backend!r} (auto|ppermute|gather)")
    sizes = tuple(mesh.shape[a] for a in node_axes)
    ndev = 1
    for s in sizes:
        ndev *= s
    n = topo.n
    assert n % ndev == 0, f"N={n} must divide over {ndev} devices"
    axis = tuple(node_axes) if len(node_axes) > 1 else node_axes[0]
    shard = NodeShard(axis, sizes, n // ndev)
    table, sched = topo, None
    if backend != "gather":
        dec = decompose_slot_permutations(topo)
        if dec is not None:
            table = dec
            sched = PermuteSchedule.from_table(dec.nbr, ndev)
        elif backend == "ppermute":
            raise ValueError("topology does not decompose into per-slot "
                             "permutations; use backend='gather'")
    tables = jax.tree_util.tree_map(jnp.asarray, table)

    def local(nbr, w, w_self, *leaves):
        st = ShardedTopology(SparseTopology(nbr, w, w_self), shard, sched)
        return tuple(st.apply(a.astype(jnp.float32)).astype(a.dtype) for a in leaves)

    leaves, treedef = jax.tree_util.tree_flatten(stacked)
    if pspecs is not None:
        spec_leaves = jax.tree_util.tree_flatten(pspecs)[0]
    else:
        spec_leaves = [P(node_axes, *((None,) * (l.ndim - 1))) for l in leaves]
    tspecs = (P(node_axes, None), P(node_axes, None), P(node_axes))
    fn = shard_map(
        local, mesh=mesh, in_specs=tspecs + tuple(spec_leaves),
        out_specs=tuple(spec_leaves), check_vma=False,
    )
    mixed = fn(tables.nbr, tables.w, tables.w_self, *leaves)
    return jax.tree_util.tree_unflatten(treedef, mixed)


def mix_compressed_circulant_shmap(
    stacked,
    pspecs,
    mesh,
    node_axes,
    degree: int,
    *,
    budget: float = 0.1,
    mode: str = "sparse",  # 'sparse' | 'quant' | 'sparse+quant'
    weights: Optional[jax.Array] = None,
):
    """Compressed circulant gossip — the paper's sparsification/compression
    modules on the TPU wire, for the tensor-parallel trainer
    (``training/trainer.py`` ``mixing_impl='sparse'/'quant'``).

    Per mesh-shard: select the top-``budget`` fraction of the *local* block
    by magnitude ('sparse'), optionally int8-quantize the values ('quant',
    via ``compression.quantize_int8`` — the same codec every quantized wire
    uses), `collective_permute` only the compressed payload, and
    scatter-merge at the receiver with DecentralizePy's missing-coordinate
    semantics

        x_i' = x_i + sum_nbr w * scatter(idx_nbr, vals_nbr - x_i[idx_nbr]).

    Wire bytes drop from P*dtype to ~budget*P*(4+payload) ('sparse') or
    P*1 ('quant') — visible directly in the dry-run's collective-permute
    operand bytes.  The general engine path does the same thing for
    arbitrary sparse overlays through payload-emitting sharing strategies +
    :func:`mix_payload` (``DLConfig.payload``); this circulant form remains
    only where gossip composes with tensor-parallel model shards (pspecs).
    """
    n = 1
    for ax in node_axes:
        n *= mesh.shape[ax]
    offs = circulant_offsets(n, degree)
    if weights is None:
        w_nbr = 1.0 / (degree + 1)
    axis = tuple(node_axes) if len(node_axes) > 1 else node_axes[0]

    def perms(o, rev=False):
        if rev:
            return [(i, (i - o) % n) for i in range(n)]
        return [(i, (i + o) % n) for i in range(n)]

    ROW = 1 << 20  # top-k row block: keeps indices int32 even for >2^31 leaves

    def _quant(v32):
        from repro.core.compression import quantize_int8

        return quantize_int8(v32)

    def per_leaf(leaf, spec):
        def local(x):
            shape = x.shape
            flat = x.reshape(-1)
            size = flat.size
            R = min(ROW, size)
            pad = (-size) % R
            rows = jnp.pad(flat, (0, pad)).reshape(-1, R)  # (nr, R)
            f32 = rows.astype(jnp.float32)
            if "sparse" in mode:
                k = max(1, int(budget * R))
                _, idx = jax.lax.top_k(jnp.abs(f32), k)       # (nr, k) int32
                vals = jnp.take_along_axis(f32, idx, axis=-1)  # (nr, k)
            else:
                idx, vals = None, f32
            if "quant" in mode:
                payload, scale = _quant(vals)
            else:
                payload, scale = vals, None
            delta = jnp.zeros_like(f32)
            for o in offs:
                dirs = [False] if (2 * o) % n == 0 else [False, True]
                for rev in dirs:
                    pp = lambda t: jax.lax.ppermute(t, axis, perms(o, rev))
                    r_payload = pp(payload)
                    r_scale = pp(scale) if scale is not None else None
                    r_idx = pp(idx) if idx is not None else None
                    r_vals = (r_payload.astype(jnp.float32) * r_scale
                              if r_scale is not None else r_payload)
                    if r_idx is not None:
                        own = jnp.take_along_axis(f32, r_idx, axis=-1)
                        delta = delta.at[
                            jnp.arange(f32.shape[0])[:, None], r_idx
                        ].add(w_nbr * (r_vals - own))
                    else:
                        delta = delta + w_nbr * (r_vals - f32)
            return (f32 + delta).reshape(-1)[:size].reshape(shape).astype(x.dtype)

        fn = shard_map(local, mesh=mesh, in_specs=(spec,), out_specs=spec,
                           check_vma=False)
        return fn(leaf)

    return jax.tree_util.tree_map(per_leaf, stacked, pspecs)


def mixing_bytes_per_node(graph: Graph, n_params: int, bytes_per_param: int = 4) -> float:
    """Average bytes *sent* per node per round under full sharing (the
    paper's cumulative-bytes metric)."""
    return float(graph.degrees().mean()) * n_params * bytes_per_param
