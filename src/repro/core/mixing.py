"""Communication/aggregation strategies — the gossip "wire".

Four interchangeable lowerings of the same math
x_i' = sum_j W_ij x_j  (W = Metropolis-Hastings weights of the overlay):

* ``mix_dense``      — W @ X einsum; W is a *traced* argument, so dynamic
                       per-round topologies never recompile.  Lowers to
                       all-gather + local matmul under GSPMD.  Works for any
                       graph (the paper's ZeroMQ generality); O(N²·P).
* ``mix_sparse``     — neighbor-indexed gather + weighted segment sum over
                       a ``SparseTopology``'s padded (N, D) tables:
                       O(N·D·P) FLOPs, the execution form for sparse graphs
                       (d ≪ N).  Optionally routes the fused K-way merge
                       through the ``kernels/gossip_mix`` Pallas kernel
                       (compiled on TPU, interpret elsewhere).  This is
                       also the neighbor-indexed form multi-host
                       `collective_permute` gossip shards over.
* ``mix_circulant``  — static circulant d-regular graphs; neighbor exchange
                       by index shift.  ``roll`` variant works everywhere
                       (CPU emulation); ``shard_map`` variant lowers each
                       offset to one `collective_permute` on the TPU mesh —
                       the TPU-native analogue of point-to-point sends.
* ``mix_fully``      — fully-connected topology = plain mean (all-reduce).

All operate on node-stacked pytrees (leading axis N).  ``apply_W`` is the
strategy-facing primitive: one W @ Y that accepts either a dense (N, N)
matrix or a ``SparseTopology`` so every sharing strategy supports both.
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.topology import Graph, SparseTopology, circulant_offsets
from repro.utils.compat import shard_map


def mix_dense(stacked, W):
    """x_i' = sum_j W_ij x_j per leaf; W (N, N) may be traced."""
    W = W.astype(jnp.float32)

    def f(a):
        return jnp.einsum("ij,j...->i...", W, a.astype(jnp.float32)).astype(a.dtype)

    return jax.tree_util.tree_map(f, stacked)


def apply_W(W, Y):
    """Row-stochastic mix Y' = W @ Y, fp32 accumulate, any trailing dims.

    W: dense (N, N) array (possibly traced) *or* a ``SparseTopology``.
    The sparse form gathers each node's D neighbor rows and contracts the
    slot axis — O(N·D·prod(trailing)) instead of O(N²·prod(trailing)) —
    without ever materializing an (N, N) matrix.
    """
    Yf = Y.astype(jnp.float32)
    if isinstance(W, SparseTopology):
        g = jnp.take(Yf, W.nbr, axis=0)  # (N, D, ...)
        mixed = jnp.einsum("nd,nd...->n...", W.w.astype(jnp.float32), g)
        w_self = W.w_self.astype(jnp.float32).reshape(
            (Yf.shape[0],) + (1,) * (Yf.ndim - 1)
        )
        return w_self * Yf + mixed
    return jnp.einsum("ij,j...->i...", W.astype(jnp.float32), Yf)


def mix_sparse(stacked, topo: SparseTopology, *, use_pallas: Optional[bool] = None,
               interpret: Optional[bool] = None):
    """Neighbor-indexed gossip over a pytree: x_i' = w_self_i x_i +
    sum_k w[i,k] x_nbr[i,k] per leaf — O(N·D·P).

    use_pallas: route the fused (D+1)-way weighted merge through the
    ``kernels.gossip_mix`` Pallas kernel (one HBM pass per operand);
    default: compiled kernel on TPU, plain XLA gather+einsum elsewhere.
    interpret: force Pallas interpret mode (CPU emulation of the TPU
    program); defaults to interpret off-TPU.
    """
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"

    def f(a):
        af = a.astype(jnp.float32)
        if not use_pallas:
            return apply_W(topo, af).astype(a.dtype)
        from repro.kernels.gossip_mix import gossip_mix_nodes

        n = af.shape[0]
        flat = af.reshape(n, -1)
        xs = jnp.concatenate(
            [flat[:, None, :], jnp.take(flat, topo.nbr, axis=0)], axis=1
        )  # (N, 1 + D, P)
        ws = jnp.concatenate(
            [topo.w_self.astype(jnp.float32)[:, None], topo.w.astype(jnp.float32)],
            axis=1,
        )
        it = (jax.default_backend() != "tpu") if interpret is None else interpret
        out = gossip_mix_nodes(xs, ws, interpret=it)
        return out.reshape(af.shape).astype(a.dtype)

    return jax.tree_util.tree_map(f, stacked)


def mix_fully(stacked):
    """Fully-connected with uniform MH weights == mean over nodes."""

    def f(a):
        return jnp.broadcast_to(
            a.astype(jnp.float32).mean(0, keepdims=True), a.shape
        ).astype(a.dtype)

    return jax.tree_util.tree_map(f, stacked)


def mix_circulant(stacked, n: int, degree: int, weights: Optional[jax.Array] = None):
    """Static circulant d-regular gossip via roll (emulation / GSPMD path).

    weights: optional (1 + n_offsets,) traced [w_self, w_off1, ...];
    defaults to uniform MH 1/(degree+1).
    """
    offs = circulant_offsets(n, degree)
    if weights is None:
        weights = jnp.full((1 + len(offs),), 1.0 / (degree + 1), jnp.float32)

    def f(a):
        acc = weights[0] * a.astype(jnp.float32)
        for k, o in enumerate(offs):
            contrib = jnp.roll(a, -o, 0).astype(jnp.float32)
            if 2 * o % n != 0:  # antipodal offset has a single neighbor
                contrib = contrib + jnp.roll(a, o, 0).astype(jnp.float32)
            acc = acc + weights[1 + k] * contrib
        return acc.astype(a.dtype)

    return jax.tree_util.tree_map(f, stacked)


def mix_circulant_shmap(stacked, mesh, node_axes, degree: int,
                        weights: Optional[jax.Array] = None, pspecs=None):
    """Circulant gossip with explicit `collective_permute` per offset.

    node_axes: mesh axis name(s) forming the node dimension, e.g.
    ('data',) or ('pod', 'data').  Requires N == prod(mesh sizes of axes)
    and every leaf's leading dim == N.

    pspecs: optional PartitionSpec pytree matching ``stacked`` — REQUIRED
    when leaves are tensor-parallel-sharded, otherwise shard_map would
    reshard (replicate) them across the model axis and the wire would pay
    the full unsharded model per send (measured 16x inflation).
    """
    n = 1
    for ax in node_axes:
        n *= mesh.shape[ax]
    offs = circulant_offsets(n, degree)
    if weights is None:
        weights = jnp.full((1 + len(offs),), 1.0 / (degree + 1), jnp.float32)
    axis = tuple(node_axes) if len(node_axes) > 1 else node_axes[0]

    def local(w, *leaves):
        out = []
        for a in leaves:
            # Pin the wire dtype: XLA canonicalizes convert∘permute into
            # permute∘convert, which would ship fp32 (2x bytes) for bf16
            # params.  Permuting the *bitcast integer* view makes that
            # rewrite impossible — the interconnect carries exactly
            # param-dtype bytes.
            int_dt = {2: jnp.uint16, 4: jnp.uint32, 1: jnp.uint8}[a.dtype.itemsize]
            a_wire = jax.lax.bitcast_convert_type(a, int_dt)
            unwire = lambda t: jax.lax.bitcast_convert_type(t, a.dtype).astype(jnp.float32)
            acc = w[0] * a.astype(jnp.float32)
            for k, o in enumerate(offs):
                fwd = [(i, (i + o) % n) for i in range(n)]
                contrib = unwire(jax.lax.ppermute(a_wire, axis, fwd))
                if 2 * o % n != 0:
                    bwd = [(i, (i - o) % n) for i in range(n)]
                    contrib = contrib + unwire(jax.lax.ppermute(a_wire, axis, bwd))
                acc = acc + w[1 + k] * contrib
            out.append(acc.astype(a.dtype))
        return tuple(out)

    leaves, treedef = jax.tree_util.tree_flatten(stacked)
    if pspecs is not None:
        spec_leaves = jax.tree_util.tree_flatten(pspecs)[0]
    else:
        spec_leaves = [P(node_axes, *((None,) * (l.ndim - 1))) for l in leaves]
    in_specs = (P(),) + tuple(spec_leaves)
    out_specs = tuple(spec_leaves)
    fn = shard_map(local, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                       check_vma=False)
    mixed = fn(weights, *leaves)
    return jax.tree_util.tree_unflatten(treedef, mixed)


def mix_compressed_circulant_shmap(
    stacked,
    pspecs,
    mesh,
    node_axes,
    degree: int,
    *,
    budget: float = 0.1,
    mode: str = "sparse",  # 'sparse' | 'quant' | 'sparse+quant'
    weights: Optional[jax.Array] = None,
):
    """Compressed circulant gossip — the paper's sparsification/compression
    modules on the TPU wire.

    Per mesh-shard: select the top-``budget`` fraction of the *local* block
    by magnitude ('sparse'), optionally int8-quantize the values ('quant'),
    `collective_permute` only the compressed payload, and scatter-merge at
    the receiver with DecentralizePy's missing-coordinate semantics

        x_i' = x_i + sum_nbr w * scatter(idx_nbr, vals_nbr - x_i[idx_nbr]).

    Wire bytes drop from P*dtype to ~budget*P*(4+payload) ('sparse') or
    P*1 ('quant') — visible directly in the dry-run's collective-permute
    operand bytes.  Per-shard top-k is a local decision (no cross-shard
    sort), exactly like DecentralizePy nodes compress their own serialized
    model.
    """
    n = 1
    for ax in node_axes:
        n *= mesh.shape[ax]
    offs = circulant_offsets(n, degree)
    if weights is None:
        w_nbr = 1.0 / (degree + 1)
    axis = tuple(node_axes) if len(node_axes) > 1 else node_axes[0]

    def perms(o, rev=False):
        if rev:
            return [(i, (i - o) % n) for i in range(n)]
        return [(i, (i + o) % n) for i in range(n)]

    ROW = 1 << 20  # top-k row block: keeps indices int32 even for >2^31 leaves

    def _quant(v32):
        scale = jnp.maximum(jnp.max(jnp.abs(v32), axis=-1, keepdims=True) / 127.0, 1e-12)
        codes = jnp.clip(jnp.round(v32 / scale), -127, 127).astype(jnp.int8)
        return codes, scale

    def per_leaf(leaf, spec):
        def local(x):
            shape = x.shape
            flat = x.reshape(-1)
            size = flat.size
            R = min(ROW, size)
            pad = (-size) % R
            rows = jnp.pad(flat, (0, pad)).reshape(-1, R)  # (nr, R)
            f32 = rows.astype(jnp.float32)
            if "sparse" in mode:
                k = max(1, int(budget * R))
                _, idx = jax.lax.top_k(jnp.abs(f32), k)       # (nr, k) int32
                vals = jnp.take_along_axis(f32, idx, axis=-1)  # (nr, k)
            else:
                idx, vals = None, f32
            if "quant" in mode:
                payload, scale = _quant(vals)
            else:
                payload, scale = vals, None
            delta = jnp.zeros_like(f32)
            for o in offs:
                dirs = [False] if (2 * o) % n == 0 else [False, True]
                for rev in dirs:
                    pp = lambda t: jax.lax.ppermute(t, axis, perms(o, rev))
                    r_payload = pp(payload)
                    r_scale = pp(scale) if scale is not None else None
                    r_idx = pp(idx) if idx is not None else None
                    r_vals = (r_payload.astype(jnp.float32) * r_scale
                              if r_scale is not None else r_payload)
                    if r_idx is not None:
                        own = jnp.take_along_axis(f32, r_idx, axis=-1)
                        delta = delta.at[
                            jnp.arange(f32.shape[0])[:, None], r_idx
                        ].add(w_nbr * (r_vals - own))
                    else:
                        delta = delta + w_nbr * (r_vals - f32)
            return (f32 + delta).reshape(-1)[:size].reshape(shape).astype(x.dtype)

        fn = shard_map(local, mesh=mesh, in_specs=(spec,), out_specs=spec,
                           check_vma=False)
        return fn(leaf)

    return jax.tree_util.tree_map(per_leaf, stacked, pspecs)


def mixing_bytes_per_node(graph: Graph, n_params: int, bytes_per_param: int = 4) -> float:
    """Average bytes *sent* per node per round under full sharing (the
    paper's cumulative-bytes metric)."""
    return float(graph.degrees().mean()) * n_params * bytes_per_param
