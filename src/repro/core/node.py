"""Node + runner (paper §2.2 *Node*): the skeleton that instantiates the
other modules and drives the DL loop — in DecentralizePy, one node is one
OS process on some machine; here one node is one slot of the stacked/vmapped
node axis (one mesh slot on TPU, emulated slots on CPU).

The per-round program is exactly Fig. 2 of the paper:

    for round:                      # DecentralizedRunner.run
        trainer.train(dataset)      #   local SGD steps      (vmap over nodes)
        to_send = sharing.get()     #   sharing strategy     (core/sharing.py)
        comm.send/recv              #   gossip               (core/mixing.py)
        sharing.aggregate()         #   MH-weighted merge
        dataset.test(model)         #   per-node eval
"""
from __future__ import annotations

import dataclasses
import functools
import json
import os
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sharing as sharing_lib
from repro.core.secure import SecureAggregation
from repro.core.topology import Graph, PeerSampler
from repro.optim import Optimizer
from repro.optim.optimizers import apply_updates
from repro.utils.pytree import tree_unvector, tree_vector


@dataclasses.dataclass
class DLConfig:
    """Experiment specification (paper Fig. 1 'specifications' input)."""

    n_nodes: int = 16
    topology: str = "regular"  # ring | regular | fully | star | dynamic | file:<path>
    degree: int = 5
    sharing: str = "full"      # full | randomk | topk | choco
    budget: float = 0.1        # sparsification budget
    choco_gamma: float = 0.3
    secure: bool = False       # secure aggregation (masked full sharing)
    local_steps: int = 1
    batch_size: int = 8
    rounds: int = 100
    eval_every: int = 10
    seed: int = 0
    results_dir: Optional[str] = None


def build_graph(cfg: DLConfig) -> Optional[Graph]:
    t = cfg.topology
    if t == "ring":
        return Graph.ring(cfg.n_nodes)
    if t == "regular":
        return Graph.regular_circulant(cfg.n_nodes, cfg.degree)
    if t == "random-regular":
        return Graph.random_regular(cfg.n_nodes, cfg.degree, cfg.seed)
    if t == "fully":
        return Graph.fully_connected(cfg.n_nodes)
    if t == "star":
        return Graph.star(cfg.n_nodes)
    if t == "dynamic":
        return None  # per-round via PeerSampler
    if t.startswith("file:"):
        return Graph.from_edge_list(t[5:], cfg.n_nodes)
    raise ValueError(f"unknown topology {t!r}")


class DecentralizedRunner:
    """Emulates N DL nodes with node-stacked state and a jitted round.

    loss_fn(params, batch_x, batch_y) -> scalar    (single node)
    acc_fn(params, batch_x, batch_y) -> scalar     (single node)
    """

    def __init__(
        self,
        dl: DLConfig,
        init_params_fn: Callable[[jax.Array], Any],
        loss_fn: Callable,
        acc_fn: Callable,
        optimizer: Optimizer,
        batcher,
        heterogeneous_lrs: Optional[np.ndarray] = None,
    ):
        self.dl = dl
        self.loss_fn = loss_fn
        self.acc_fn = acc_fn
        self.opt = optimizer
        self.batcher = batcher
        key = jax.random.key(dl.seed)
        keys = jax.random.split(key, dl.n_nodes)
        # fully-decentralized: every node initializes its *own* model
        self.params = jax.vmap(init_params_fn)(keys)
        self.opt_state = jax.vmap(self.opt.init)(self.params)
        self.template = jax.tree_util.tree_map(lambda a: a[0], self.params)
        self.graph = build_graph(dl)
        self.sampler = PeerSampler(dl.n_nodes, dl.degree, dl.seed) if dl.topology == "dynamic" else None
        if dl.secure:
            assert self.graph is not None, "secure aggregation needs a static graph"
            self.sharing = SecureAggregation(self.graph.adj)
        else:
            kw = {"gamma": dl.choco_gamma} if dl.sharing.startswith("choco") else {}
            self.sharing = sharing_lib.make_sharing(dl.sharing, dl.budget, **kw)
        X0 = jax.vmap(tree_vector)(self.params)
        self.share_state = self.sharing.init_state(X0)
        self.n_params = int(X0.shape[1])
        self.history: List[Dict] = []
        self.bytes_sent = 0.0
        self._round_jit = jax.jit(self._round)
        self._eval_jit = jax.jit(self._eval)

    # ------------------------------------------------------------------
    def _degree(self, graph: Graph) -> float:
        return float(graph.degrees().mean())

    def _round(self, params, opt_state, share_state, bx, by, W, key):
        """One DL round: local_steps SGD steps then gossip. bx: (L,N,B,...)."""

        def node_grad(p, x, y):
            return jax.grad(self.loss_fn)(p, x, y)

        def local_step(carry, batch):
            params, opt_state = carry
            x, y = batch
            grads = jax.vmap(node_grad)(params, x, y)
            updates, opt_state = jax.vmap(self.opt.update)(grads, opt_state, params)
            return (apply_updates(params, updates), opt_state), ()

        (params, opt_state), _ = jax.lax.scan(local_step, (params, opt_state), (bx, by))

        X = jax.vmap(tree_vector)(params)
        X2, share_state, nbytes = self.sharing.round(
            X, W, share_state, key, degree=float(self._cur_degree)
        )
        params = jax.vmap(lambda v: tree_unvector(v, self.template))(X2)
        return params, opt_state, share_state, nbytes

    def _eval(self, params, tx, ty):
        return jax.vmap(lambda p: self.acc_fn(p, tx, ty))(params)

    # ------------------------------------------------------------------
    def run(self, rounds: Optional[int] = None, log: bool = True) -> List[Dict]:
        dl = self.dl
        rounds = rounds if rounds is not None else dl.rounds
        tx, ty = self.batcher.test_batch()
        tx, ty = jnp.asarray(tx), jnp.asarray(ty)
        t0 = time.time()
        for rnd in range(rounds):
            graph = self.sampler.round_graph(rnd) if self.sampler else self.graph
            W = jnp.asarray(graph.metropolis_hastings(), jnp.float32)
            self._cur_degree = self._degree(graph)
            bxs, bys = [], []
            for s in range(dl.local_steps):
                x, y = self.batcher.batch(rnd, s)
                bxs.append(x)
                bys.append(y)
            bx = jnp.asarray(np.stack(bxs))
            by = jnp.asarray(np.stack(bys))
            key = jax.random.fold_in(jax.random.key(dl.seed + 17), rnd)
            if isinstance(self.sharing, SecureAggregation):
                # masked path is python-scheduled (static pair program)
                self.params, self.opt_state, self.share_state, nbytes = self._secure_round(
                    bx, by, W, key, rnd
                )
            else:
                self.params, self.opt_state, self.share_state, nbytes = self._round_jit(
                    self.params, self.opt_state, self.share_state, bx, by, W, key
                )
            self.bytes_sent += float(nbytes)
            if rnd % dl.eval_every == 0 or rnd == rounds - 1:
                accs = np.asarray(self._eval_jit(self.params, tx, ty))
                rec = {
                    "round": rnd,
                    "acc_mean": float(accs.mean()),
                    "acc_std": float(accs.std()),
                    "bytes_per_node": self.bytes_sent,
                    "wall_s": time.time() - t0,
                }
                self.history.append(rec)
                if log:
                    print(
                        f"[{dl.topology}/{type(self.sharing).__name__}] round {rnd:4d} "
                        f"acc {rec['acc_mean']:.4f}±{rec['acc_std']:.4f} "
                        f"MB/node {self.bytes_sent / 1e6:.1f}"
                    )
        self._dump_results()
        return self.history

    def _secure_round(self, bx, by, W, key, rnd):
        def node_grad(p, x, y):
            return jax.grad(self.loss_fn)(p, x, y)

        params, opt_state = self.params, self.opt_state
        for s in range(bx.shape[0]):
            grads = jax.vmap(node_grad)(params, bx[s], by[s])
            updates, opt_state = jax.vmap(self.opt.update)(grads, opt_state, params)
            params = apply_updates(params, updates)
        X = jax.vmap(tree_vector)(params)
        X2, st, nbytes = self.sharing.round(
            X, W, self.share_state, key, degree=self._cur_degree, rnd=rnd
        )
        params = jax.vmap(lambda v: tree_unvector(v, self.template))(X2)
        return params, opt_state, st, nbytes

    def _dump_results(self):
        """Per-node JSON results, DecentralizePy-style (aggregated later)."""
        if not self.dl.results_dir:
            return
        os.makedirs(self.dl.results_dir, exist_ok=True)
        with open(os.path.join(self.dl.results_dir, "results.json"), "w") as f:
            json.dump({"config": dataclasses.asdict(self.dl), "history": self.history}, f, indent=1)
