"""Node + runner (paper §2.2 *Node*): the skeleton that instantiates the
other modules and drives the DL loop — in DecentralizePy, one node is one
OS process on some machine; here one node is one slot of the stacked/vmapped
node axis (one mesh slot on TPU, emulated slots on CPU).

The per-round program is exactly Fig. 2 of the paper:

    for round:                      # RoundEngine.run
        trainer.train(dataset)      #   local SGD steps      (vmap over nodes)
        to_send = sharing.get()     #   sharing strategy     (core/sharing.py)
        comm.send/recv              #   gossip               (core/mixing.py)
        sharing.aggregate()         #   MH-weighted merge
        dataset.test(model)         #   per-node eval

Execution now lives in three layers: ``core/steps.py`` (the pure jittable
per-round functions — local SGD, share/mix, per-node round time),
``core/scheduler.py`` (time and activation semantics:
``DLConfig.semantics`` selects the synchronous barrier, per-node
neighborhood-barrier clocks, or event-driven AD-PSGD-style gossip on a
virtual clock), and ``core/engine.py`` (resources + the run loop; see its
module docstring).  ``DecentralizedRunner`` is kept as a thin wrapper so
all existing entry points — examples, benchmarks, tests — keep working
unchanged.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional

import numpy as np

# Re-exported for backwards compatibility: these historically lived here.
from repro.core.engine import DLConfig, RoundEngine, build_graph, build_network  # noqa: F401
from repro.optim import Optimizer


class DecentralizedRunner:
    """Thin wrapper over :class:`repro.core.engine.RoundEngine`.

    loss_fn(params, batch_x, batch_y) -> scalar    (single node)
    acc_fn(params, batch_x, batch_y) -> scalar     (single node)
    heterogeneous_lrs: optional (N,) per-node learning-rate multipliers.
    """

    def __init__(
        self,
        dl: DLConfig,
        init_params_fn: Optional[Callable] = None,
        loss_fn: Optional[Callable] = None,
        acc_fn: Optional[Callable] = None,
        optimizer: Optional[Optimizer] = None,
        batcher=None,
        heterogeneous_lrs: Optional[np.ndarray] = None,
        workload: Optional[Dict] = None,
        **runner_kw,
    ):
        self.dl = dl
        if dl.backend == "processes":
            # real-network backend: callables can't cross the process
            # boundary — workers rebuild the experiment from a declarative
            # workload spec (repro.runtime.runner.build_workload)
            if workload is None:
                raise ValueError(
                    "backend='processes' rebuilds the experiment inside "
                    "each worker process; pass workload={'dataset': ..., "
                    "'model': ..., 'lr': ...} instead of callables"
                )
            from repro.runtime import ProcessRunner

            self.engine = ProcessRunner(dl, workload, **runner_kw)
        else:
            assert not runner_kw, (
                f"unknown kwargs for the simulated backend: {runner_kw}"
            )
            self.engine = RoundEngine(
                dl, init_params_fn, loss_fn, acc_fn, optimizer, batcher,
                heterogeneous_lrs=heterogeneous_lrs,
            )

    def run(self, rounds: Optional[int] = None, log: bool = True) -> List[Dict]:
        return self.engine.run(rounds, log)

    # -- state/metrics live on the engine; expose the historical surface ----
    @property
    def params(self):
        return self.engine.params

    @property
    def opt_state(self):
        return self.engine.opt_state

    @property
    def share_state(self):
        return self.engine.share_state

    @property
    def history(self) -> List[Dict]:
        return self.engine.history

    @property
    def bytes_sent(self) -> float:
        return self.engine.bytes_sent

    @property
    def sim_time_s(self) -> float:
        return self.engine.sim_time_s

    @property
    def sharing(self):
        return self.engine.sharing

    @property
    def graph(self):
        return self.engine.graph

    @property
    def template(self):
        return self.engine.template

    @property
    def n_params(self) -> int:
        return self.engine.n_params
