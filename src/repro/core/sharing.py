"""Sharing module — message content + aggregation (paper §2.2 *Sharing*).

Strategies operate on the node-stacked flat parameter matrix X (N, P)
(DecentralizePy serializes the model into one message; ``utils.tree_vector``
is our serializer).  Each returns the post-gossip X' plus the bytes each
node sent this round, the paper's communication metric.

Sparsified strategies (random-k, top-k, CHOCO) emit compact per-node
*payloads* — ``idx`` (N, k) int32 coordinate indices and ``val`` (N, k)
wire values (the payload wire format; optionally int8-quantized through
``core.compression.quantize_int8``) — and aggregate them with
DecentralizePy's missing-coordinate rule: weights of coordinates absent
from a payload fall back to the receiver's own value,

    x_i'[c] = x_i[c] + sum_j W_ij * m_j[c] * (v_j[c] - x_i[c]),

applied in one gather + scatter-accumulate pass by
:func:`repro.core.mixing.mix_payload` — O(N·d·k) compute, O(N·d·k) wire.
With ``payload=False`` the same payload is scattered into dense (N, P)
mask/value matrices and aggregated as X' = X + W@(M*V) - X*(W@M)
(:func:`mix_payload_masked`, two full apply_W passes) — the legacy
masked-matrix form, kept as the equivalence oracle the payload path is
property-tested against.  Coordinate selection (exact ``lax.top_k`` or the
histogram-threshold kernel, see ``_topk_idx``) is shared by both forms, so
trajectories agree to fp32 reassociation tolerance.

Every strategy's ``round`` accepts ``degree`` as either a Python float or a
traced scalar: the RoundEngine scans whole chunks of rounds, so the degree
(and with participation churn, the *effective* degree) is a per-round
traced value and byte accounting happens on device.  Byte accounting
derives from the actual wire dtype (``wire_dtype``/itemsize — int8 codes
count 1 byte, bf16 params 2), not a hardcoded fp32.  ``round`` also takes
the (possibly traced) round index ``rnd`` — used by PRF-keyed strategies
such as secure aggregation, ignored by the rest — so the engine can call
every strategy uniformly from inside the scan.

``W`` may be a dense (N, N) matrix *or* a neighbor-indexed
``SparseTopology`` (padded (N, D) tables): every W-product below goes
through :func:`repro.core.mixing.apply_W` / ``mix_payload``, so each
strategy costs O(N·D·P) — O(N·D·k) in payload form — on sparse overlays
without code changes.  With churn, the sparse reweight
(:func:`participation_reweight_sparse`) masks neighbor slots and returns
the freed mass to the diagonal without ever materializing W.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compression import dequantize_int8, quantize_int8
from repro.core.mixing import (
    NodeShard,
    ShardedDense,
    ShardedTopology,
    apply_W,
    mix_payload,
    mix_payload_masked,
    mix_payload_strided,
)
from repro.core.topology import SparseTopology

BYTES_VAL = 4   # legacy fp32 wire-value size (kept for external callers;
#                 strategies now derive bytes from the actual wire dtype)
BYTES_IDX = 4   # int32 index on the wire


def _topk_idx(x_abs, k: int, selector: str = "auto"):
    """(N, k) int32 indices of (approximately) the k largest-|.| coords per
    row — the single selection rule both the payload path and the
    dense-mask oracle use, so their trajectories stay comparable.

    selector: 'exact' — ``lax.top_k`` (a per-row sort); 'hist' — the
    histogram-threshold kernel (``kernels.sparsify.topk_threshold_rows``):
    per-row threshold t with #{|x| >= t} >= k within one fine bin, then the
    first k survivors in index order (every kept coordinate is >= t, i.e.
    dominates every dropped sub-threshold one).  'auto' picks 'hist' on
    TPU, where a histogram pass beats the sort, and 'exact' elsewhere.
    """
    if selector == "auto":
        selector = "hist" if jax.default_backend() == "tpu" else "exact"
    if selector == "exact":
        return jax.lax.top_k(x_abs, k)[1]
    if selector != "hist":
        raise ValueError(f"unknown selector {selector!r} (auto|exact|hist)")
    from repro.kernels import ops as kernel_ops

    n, p = x_abs.shape
    t = kernel_ops.topk_threshold_rows(x_abs, k)
    mask = x_abs >= t[:, None]
    pos = jnp.cumsum(mask, axis=1) - 1
    tgt = jnp.where(mask & (pos < k), pos, k)  # k == out of range -> dropped
    cols = jnp.broadcast_to(jnp.arange(p, dtype=jnp.int32)[None, :], (n, p))
    return jnp.zeros((n, k), jnp.int32).at[
        jnp.arange(n)[:, None], tgt
    ].set(cols, mode="drop")


def _wire(val, quantize: Optional[str], x_dtype):
    """Wire-form payload values: what the receivers reconstruct.

    val: (N, k) selected values -> (valf fp32 after the wire round-trip,
    bytes per value on the wire, per-node header bytes).  ``quantize``
    'int8' routes through ``compression.quantize_int8`` (1 byte/value +
    one fp32 scale per node); otherwise values ship in the parameter dtype.
    """
    if quantize in (None, "none"):
        item = jnp.dtype(x_dtype).itemsize
        return val.astype(x_dtype).astype(jnp.float32), item, 0
    if quantize == "int8":
        codes, scale = quantize_int8(val.astype(jnp.float32))
        return dequantize_int8(codes, scale), 1, 4
    raise ValueError(f"unknown payload quantization {quantize!r} (int8|none)")


def _node_keys(key, n_rows: int, rows=None):
    """(n_rows,) per-node PRNG keys: fold_in of each node's *global* id.

    Per-node keying (instead of one (N, P) draw from a single key) is what
    lets a node-sharded engine reproduce the single-device randomness: each
    device derives exactly the draws of the node rows it owns.  ``rows``
    (traced global ids, from the sharded mixing operand) defaults to
    arange — the unsharded node axis.
    """
    ids = jnp.arange(n_rows) if rows is None else rows
    return jax.vmap(lambda i: jax.random.fold_in(key, i))(ids)


def _randk_idx(key, shape, k: int, rows=None):
    """(N, k) indices of k random coords per row via top-k of iid uniforms
    (no replacement); draws are per-node keyed (see _node_keys)."""
    keys = _node_keys(key, shape[0], rows)
    u = jax.vmap(lambda kk: jax.random.uniform(kk, shape[1:]))(keys)
    return jax.lax.top_k(u, k)[1]


def _strided_phase(key, n: int, stride: int, rows=None):
    """(N,) random phases in [0, stride) — the strided sampler's only
    randomness: node n shares coordinates {i·stride + phase_n} (one per
    stride-wide cell).  Uniform k/P marginal coverage, exact-k payloads,
    O(N) selection (no (N, P) uniform draw, no top-k sort), and a wire
    format of one ⌈log2 stride⌉-bit offset per message — the payload hot
    path's sampler.  Per-node keyed like ``_randk_idx``."""
    keys = _node_keys(key, n, rows)
    u = jax.vmap(lambda kk: jax.random.uniform(kk, ()))(keys)
    return jnp.floor(u * stride).astype(jnp.int32)


def _mix_rows(W):
    """Global node ids of W's rows: traced block ids for sharded operands
    inside a shard_map body, None (= arange) otherwise."""
    return W.rows if isinstance(W, (ShardedTopology, ShardedDense)) else None


def sparse_aggregate(X, W, M):
    """Masked gossip with missing-coordinate fallback (see module doc).
    W: dense (N, N) or SparseTopology — both products go through apply_W."""
    Xf, Mf = X.astype(jnp.float32), M.astype(jnp.float32)
    return (Xf + apply_W(W, Mf * Xf) - Xf * apply_W(W, Mf)).astype(X.dtype)


def participation_reweight(W, active, *, shard: Optional[NodeShard] = None):
    """Reweight a row-stochastic mixing matrix for a per-round node
    participation mask (churn / straggler dropout), fully traceable.

    active: (N,) {0,1} — 0 means the node is down this round: it neither
    sends nor receives, so every edge touching it is removed and the freed
    mass returns to each surviving row's diagonal (keeping rows stochastic;
    for symmetric W the result stays symmetric, hence doubly stochastic on
    the active subgraph).  A down node's row becomes e_i, i.e. it keeps its
    own parameters unchanged through the gossip step.

    shard: inside a shard_map body, the node-axis sharding — W is then this
    device's (B, N) row block and ``active`` its (B,) block; the column
    mask is all-gathered and the edge/alive counts psum'd so deg_eff is the
    same global scalar on every device.

    Returns (W', deg_eff) where deg_eff is the mean number of live outgoing
    edges per *active* node — the traced degree the byte accounting uses.
    """
    Wf = W.astype(jnp.float32)
    m = active.astype(jnp.float32)
    n = Wf.shape[1] if shard is not None else Wf.shape[0]
    if shard is not None:
        m_col = shard.gather(m)
        diag = (jnp.arange(n)[None, :] == shard.rows()[:, None]).astype(jnp.float32)
    else:
        m_col = m
        diag = jnp.eye(n, dtype=jnp.float32)
    off = Wf * (1.0 - diag) * m[:, None] * m_col[None, :]
    Wm = off + diag * (1.0 - off.sum(1, keepdims=True))
    edges = jnp.sum((off > 0).astype(jnp.float32))
    alive = m.sum()
    if shard is not None:
        edges, alive = shard.psum(edges), shard.psum(alive)
    deg_eff = edges / jnp.maximum(alive, 1.0)
    return Wm, deg_eff


def participation_reweight_sparse(topo: SparseTopology, active, *,
                                  shard: Optional[NodeShard] = None):
    """Sparse-form :func:`participation_reweight`: mask neighbor *slots*
    whose endpoint (either side) is down and return the freed mass to the
    surviving diagonal — O(N·D), no (N, N) matrix ever materialized.

    A down node's row becomes the identity (w row 0, w_self 1), exactly
    like the dense reweight's e_i rows; ``to_dense`` of the result equals
    the dense reweight of ``to_dense(topo)`` (property-tested).

    shard: inside a shard_map body — topo/active are this device's row
    blocks; the neighbor-endpoint mask is gathered and counts psum'd.

    Returns (SparseTopology, deg_eff) with deg_eff as in the dense form.
    """
    m = active.astype(jnp.float32)
    m_nbr = shard.gather(m) if shard is not None else m
    pair = m[:, None] * jnp.take(m_nbr, topo.nbr, axis=0)    # (N, D)
    w = topo.w.astype(jnp.float32) * pair
    w_self = 1.0 - w.sum(-1)                                 # down row -> 1.0
    edges = jnp.sum((w > 0).astype(jnp.float32))
    alive = m.sum()
    if shard is not None:
        edges, alive = shard.psum(edges), shard.psum(alive)
    deg_eff = edges / jnp.maximum(alive, 1.0)
    return SparseTopology(topo.nbr, w, w_self), deg_eff


def edge_reweight(W, live):
    """Renormalize a row-stochastic mixing matrix for a per-edge {0,1}
    live mask (message-level faults): every off-diagonal entry whose
    directed message was lost is removed and the freed mass returns to the
    receiver's diagonal — rows stay stochastic (property-tested), so
    gossip under loss degrades to a weaker average instead of a biased
    one.  Composes with :func:`participation_reweight` (sequential
    renormalizations each preserve row-stochasticity).

    live: (N, N) {0,1} — live[i, j] = 0 drops the message j -> i.
    """
    Wf = W.astype(jnp.float32)
    n = Wf.shape[0]
    diag = jnp.eye(n, dtype=jnp.float32)
    off = Wf * (1.0 - diag) * live.astype(jnp.float32)
    return off + diag * (1.0 - off.sum(1, keepdims=True))


def edge_reweight_sparse(topo: SparseTopology, live):
    """Sparse-form :func:`edge_reweight`: mask neighbor *slots* whose
    message was lost and return the freed mass to the diagonal — O(N·D).
    ``to_dense`` of the result equals the dense reweight of
    ``to_dense(topo)`` under the slot-scattered mask (property-tested).

    live: (N, D) {0,1} over the padded neighbor slots.
    """
    w = topo.w.astype(jnp.float32) * live.astype(jnp.float32)
    return SparseTopology(topo.nbr, w, 1.0 - w.sum(-1))


def edge_readmit_sparse(topo0: SparseTopology, live):
    """Re-admission restore — the exact inverse of
    :func:`edge_reweight_sparse` against the *pristine* table ``topo0``:
    recompute the effective topology from the original weights and the
    current live mask, so clearing a slot's dead mark returns its edge
    mass from the receiver's diagonal bitwise.

    When every slot is live again the pristine topology object itself is
    returned: ``w_self`` tables are built in float64 before the fp32 cast
    (``mh_weight_table``), so recomputing ``1 - w.sum(-1)`` in fp32 could
    differ from the pristine diagonal in the last ulp — the round-trip
    guarantee (property-tested in ``tests/test_faults.py``) must be
    exact, not within-a-ulp.

    live: (N, D) {0,1} over the padded neighbor slots.
    """
    if bool(np.all(np.asarray(live) == 1.0)):
        return topo0
    return edge_reweight_sparse(topo0, live)


def participation_deg_eff(topo: SparseTopology, active):
    """The ``deg_eff`` scalar of :func:`participation_reweight_sparse`
    alone — same counting expressions, no reweighted table built.  The
    cohort gather/scatter path reweights only its gathered rows
    (:func:`participation_reweight_rows`) but byte accounting needs the
    same *global* live-edges-per-active-node scalar as the dense oracle;
    O(N·D), no P factor."""
    m = active.astype(jnp.float32)
    pair = m[:, None] * jnp.take(m, topo.nbr, axis=0)
    w = topo.w.astype(jnp.float32) * pair
    edges = jnp.sum((w > 0).astype(jnp.float32))
    alive = m.sum()
    return edges / jnp.maximum(alive, 1.0)


def participation_reweight_rows(topo_rows: SparseTopology, active, rows):
    """Row-subset :func:`participation_reweight_sparse`: churn-reweight a
    gathered (C, D) cohort view (``topology.gather_rows``) whose ``nbr``
    entries are global ids into the full (N,) ``active`` mask.  Each row's
    arithmetic is the expression-for-expression gather of the dense
    reweight's row, so the result is its bitwise (C,)-row slice.  Returns
    the reweighted view only — for the global ``deg_eff`` scalar use
    :func:`participation_deg_eff` (it must count *all* live edges, not the
    cohort's)."""
    m = active.astype(jnp.float32)
    m_r = jnp.take(m, rows)
    pair = m_r[:, None] * jnp.take(m, topo_rows.nbr, axis=0)   # (C, D)
    w = topo_rows.w.astype(jnp.float32) * pair
    w_self = 1.0 - w.sum(-1)                                   # down row -> 1.0
    return SparseTopology(topo_rows.nbr, w, w_self)


# ---------------------------------------------------------------------------
# strategies
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FullSharing:
    """Baseline: serialize the full parameter vector (D-PSGD)."""

    def init_state(self, X):
        return ()

    def round(self, X, W, state, key, degree, rnd=0):
        X2 = apply_W(W, X).astype(X.dtype)
        return X2, state, degree * X.shape[1] * jnp.dtype(X.dtype).itemsize

    def wire_dtype(self, x_dtype):
        return np.dtype(x_dtype)

    def stage_bytes_per_round(self, n: int, p: int) -> int:
        return n * p * 4  # the fp32 mixing operand itself


@dataclasses.dataclass(frozen=True)
class _PayloadSharing:
    """Shared machinery of the payload-emitting sparsified strategies.

    payload: aggregate via the indexed O(N·d·k) ``mix_payload`` pass
    (True, the wire-faithful default) or the dense-mask oracle
    (False: scattered (N, P) masks + two apply_W passes — the legacy form,
    kept property-tested equal).  quantize: optional wire codec for the
    payload values ('int8' -> ``compression.quantize_int8`` + fp32 scale
    header).  selector: top-k rule for magnitude-based strategies
    (see ``_topk_idx``).
    """

    budget: float  # fraction of parameters shared (paper: 0.10)
    payload: bool = True
    quantize: Optional[str] = None  # None | 'int8'
    selector: str = "auto"          # auto | exact | hist

    def _k(self, X) -> int:
        return max(1, int(self.budget * X.shape[1]))

    def _aggregate(self, X, W, idx, valf):
        if self.payload:
            return mix_payload(
                W, idx, valf, X, exact_values=self.quantize is None
            ).astype(X.dtype)
        return mix_payload_masked(W, idx, valf, X).astype(X.dtype)

    def _nbytes(self, degree, k: int, item: int, header: int,
                idx_bytes: int = BYTES_IDX):
        return degree * (k * (idx_bytes + item) + header)

    def wire_dtype(self, x_dtype):
        return np.dtype(np.int8) if self.quantize == "int8" else np.dtype(x_dtype)

    def _static_idx_bytes(self, p: int) -> int:
        return BYTES_IDX

    def stage_bytes_per_round(self, n: int, p: int) -> int:
        """Bytes of message tensors the sharing stage materializes per
        round: (idx, val) payloads, vs scattered (N, P) fp32 value + byte
        mask matrices on the dense-mask oracle path."""
        k = max(1, int(self.budget * p))
        item = 1 if self.quantize == "int8" else 4
        header = 4 if self.quantize == "int8" else 0
        if self.payload:
            return n * (k * (self._static_idx_bytes(p) + item) + header)
        return n * p * (4 + 1)


@dataclasses.dataclass(frozen=True)
class RandomKSharing(_PayloadSharing):
    """Random sampling sparsification (paper Fig. 4): k random coords,
    emitted as an (idx, val) payload (per-node keyed draws).

    sampler: 'uniform' — iid k-subset via top-k of (N, P) uniforms (the
    paper-literal rule; indexed payload, int32 coords on the wire);
    'strided' — a random-phase strided grid: the columns split into k
    cells of width ⌈P/k⌉ and node n shares {i·stride + phase_n}.  Same
    k/P marginal coverage, exact-k payloads, O(N) selection (no (N, P)
    draw, no sort), one narrow offset per message on the wire, and a
    vectorizable windowed-scatter receive (``mixing.mix_payload_strided``)
    — the payload hot path's sampler.  Coordinates within one node's
    payload are grid-correlated (fresh phase per round decorrelates across
    rounds).
    """

    sampler: str = "uniform"  # uniform | strided

    def init_state(self, X):
        return ()

    def _static_idx_bytes(self, p: int) -> int:
        if self.sampler != "strided":
            return BYTES_IDX
        # one phase offset per message, amortized over the k values
        stride = -(-p // max(1, int(self.budget * p)))
        return (1 if stride <= 256 else (2 if stride <= 65536 else 4)) / max(
            1, int(self.budget * p)
        )

    def round(self, X, W, state, key, degree, rnd=0):
        k = self._k(X)
        if self.sampler == "strided":
            return self._round_strided(X, W, state, key, degree, k)
        if self.sampler != "uniform":
            raise ValueError(
                f"unknown sampler {self.sampler!r} (uniform|strided)"
            )
        idx = _randk_idx(key, X.shape, k, rows=_mix_rows(W))
        val = jnp.take_along_axis(X, idx, axis=1)
        valf, item, header = _wire(val, self.quantize, X.dtype)
        X2 = self._aggregate(X, W, idx, valf)
        return X2, state, self._nbytes(degree, k, item, header)

    def _round_strided(self, X, W, state, key, degree, k: int):
        """Strided-grid round: pad P up to k·stride so every cell has full
        width (phantom pad coordinates are identically zero for every node
        — they contribute w·(0-0) = 0 and are sliced off), draw one phase
        per node, and aggregate via the windowed-scatter fast path
        (payload) or the masked oracle on reconstructed global indices."""
        n, p = X.shape
        stride = -(-p // k)
        ppad = k * stride
        Xp = jnp.pad(X, ((0, 0), (0, ppad - p)))
        phase = _strided_phase(key, n, stride, rows=_mix_rows(W))
        idx = jnp.arange(k, dtype=jnp.int32)[None, :] * stride + phase[:, None]
        val = jnp.take_along_axis(Xp, idx, axis=1)
        valf, item, header = _wire(val, self.quantize, X.dtype)
        if self.payload:
            X2p = mix_payload_strided(
                W, phase, valf, Xp, exact_values=self.quantize is None
            )
        else:
            X2p = mix_payload_masked(W, idx, valf, Xp)
        phase_bytes = 1 if stride <= 256 else (2 if stride <= 65536 else 4)
        nbytes = degree * (k * item + phase_bytes + header)
        return X2p[:, :p].astype(X.dtype), state, nbytes


@dataclasses.dataclass(frozen=True)
class TopKSharing(_PayloadSharing):
    """TopK sparsification [Alistarh et al. '18]: share the k coords whose
    *accumulated change* since last share is largest; residual accumulation
    stored in the Model-module extra state (paper §2.2 *Model*).  The
    payload update touches only the k shared slots of ``last_shared``
    (O(N·k) bookkeeping, no (N, P) select)."""

    def init_state(self, X):
        return {"last_shared": X.astype(jnp.float32)}

    def round(self, X, W, state, key, degree, rnd=0):
        k = self._k(X)
        Xf = X.astype(jnp.float32)
        delta = Xf - state["last_shared"]
        idx = _topk_idx(jnp.abs(delta), k, self.selector)
        val = jnp.take_along_axis(X, idx, axis=1)
        valf, item, header = _wire(val, self.quantize, X.dtype)
        X2 = self._aggregate(X, W, idx, valf)
        # error feedback: record what receivers actually reconstructed (the
        # wire round-trip valf), so a quantization residual v - v̂ stays in
        # the delta and is re-shared; identical to the raw value bit-for-bit
        # on the unquantized wire
        new_last = state["last_shared"].at[
            jnp.arange(X.shape[0])[:, None], idx
        ].set(valf)
        return X2, {"last_shared": new_last}, self._nbytes(degree, k, item, header)


@dataclasses.dataclass(frozen=True)
class ChocoSGD(_PayloadSharing):
    """CHOCO-SGD [Koloskova et al. '19]: gossip on compressed *differences*
    to a public copy x̂, with consensus step size gamma.

        q_i  = C(x_i - x̂_i)          (top-k or random-k compressor)
        x̂_i += q_i                    (all nodes track the same x̂'s)
        x_i += gamma * sum_j W_ij (x̂_j - x̂_i)

    The wire carries the (idx, val) payload of q; the x̂ update is an
    O(N·k) scatter-add.  The consensus step mixes the locally-tracked
    dense x̂ copies (inherent to CHOCO — not wire traffic).
    """

    gamma: float = 0.3
    compressor: str = "topk"  # 'topk' | 'randk'

    def init_state(self, X):
        return {"xhat": jnp.zeros_like(X, jnp.float32)}

    def stage_bytes_per_round(self, n: int, p: int) -> int:
        # the q compression is payload-form in both modes (the x̂ update is
        # an O(N·k) scatter either way); the dense x̂ consensus mix is
        # CHOCO-inherent local state, not staged message content
        k = max(1, int(self.budget * p))
        item = 1 if self.quantize == "int8" else 4
        header = 4 if self.quantize == "int8" else 0
        return n * (k * (BYTES_IDX + item) + header)

    def round(self, X, W, state, key, degree, rnd=0):
        k = self._k(X)
        Xf = X.astype(jnp.float32)
        diff = Xf - state["xhat"]
        if self.compressor == "topk":
            idx = _topk_idx(jnp.abs(diff), k, self.selector)
        else:
            idx = _randk_idx(key, X.shape, k, rows=_mix_rows(W))
        val = jnp.take_along_axis(diff, idx, axis=1)
        valf, item, header = _wire(val, self.quantize, jnp.float32)
        xhat = state["xhat"].at[jnp.arange(X.shape[0])[:, None], idx].add(valf)
        X2 = Xf + self.gamma * (apply_W(W, xhat) - xhat)
        return X2.astype(X.dtype), {"xhat": xhat}, self._nbytes(degree, k, item, header)


@dataclasses.dataclass(frozen=True)
class QuantizedSharing:
    """Full sharing through the Compression module: int8 codes + per-node
    scale on the wire (4x fewer bytes than fp32), dequantized before the
    MH aggregation.  Accuracy cost is bounded by the quantization step
    (see tests/test_substrate.py int8 roundtrip bounds)."""

    stochastic: bool = True

    def init_state(self, X):
        return ()

    def round(self, X, W, state, key, degree, rnd=0):
        if self.stochastic:
            keys = _node_keys(key, X.shape[0], _mix_rows(W))
            codes, scale = jax.vmap(lambda x, kk: quantize_int8(x, key=kk))(X, keys)
        else:
            codes, scale = quantize_int8(X)
        Xq = dequantize_int8(codes, scale)  # what the receivers reconstruct
        X2 = apply_W(W, Xq).astype(X.dtype)
        # int8 codes + the fp32 scale header, from the wire dtype itemsize
        return X2, state, degree * (X.shape[1] * 1 + 4)

    def wire_dtype(self, x_dtype):
        return np.dtype(np.int8)

    def stage_bytes_per_round(self, n: int, p: int) -> int:
        return n * (p * 1 + 4)


_FULL_NAMES = ("full", "fullsharing", "d-psgd")
_QUANT_NAMES = ("quant", "quantized", "int8")
_RANDK_NAMES = ("randomk", "random")
_CHOCO_NAMES = ("choco", "choco-sgd", "chocosgd")


def strategy_takes_budget(name: str) -> bool:
    """Whether ``name`` is a sparsified strategy parameterized by a
    sharing budget (the engine only forwards ``DLConfig.budget`` to
    these — full/quantized sharing share every coordinate)."""
    return name.lower() not in _FULL_NAMES + _QUANT_NAMES


def is_full_sharing(name: str) -> bool:
    """Whether ``name`` aliases plain full sharing (D-PSGD) — the only
    strategy the async scheduler's one-sided stale reads are modeled for
    (``DLConfig.validate()`` gates on this predicate, so alias lists stay
    in one module)."""
    return name.lower() in _FULL_NAMES


def make_sharing(name: str, budget: Optional[float] = None, **kw):
    """Build a sharing strategy by name.

    Every keyword is forwarded to the strategy constructor; unknown or
    inapplicable ones raise (no more silently-dropped ``budget``/kwargs).
    ``budget`` defaults to the paper's 0.1 for sparsified strategies and is
    rejected for full/quantized sharing, which share every coordinate.
    """
    name_l = name.lower()

    def build(cls, **kwargs):
        try:
            return cls(**kwargs)
        except TypeError as e:
            raise ValueError(
                f"invalid kwargs for sharing strategy {name!r}: {e}"
            ) from None

    if name_l in _FULL_NAMES + _QUANT_NAMES:
        if budget is not None:
            raise ValueError(
                f"sharing strategy {name!r} shares every coordinate; "
                "'budget' does not apply"
            )
        return build(FullSharing if name_l in _FULL_NAMES else QuantizedSharing, **kw)
    b = 0.1 if budget is None else budget
    if name_l in _RANDK_NAMES:
        return build(RandomKSharing, budget=b, **kw)
    if name_l == "topk":
        return build(TopKSharing, budget=b, **kw)
    if name_l in _CHOCO_NAMES:
        return build(ChocoSGD, budget=b, **kw)
    raise ValueError(f"unknown sharing strategy {name!r}")
