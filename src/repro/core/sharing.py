"""Sharing module — message content + aggregation (paper §2.2 *Sharing*).

Strategies operate on the node-stacked flat parameter matrix X (N, P)
(DecentralizePy serializes the model into one message; ``utils.tree_vector``
is our serializer).  Each returns the post-gossip X' plus the bytes each
node sent this round, the paper's communication metric.

Sparse aggregation follows DecentralizePy: weights of *missing* coordinates
fall back to the receiver's own value,

    x_i'[c] = x_i[c] + sum_j W_ij * m_j[c] * (x_j[c] - x_i[c])

which in matrix form is  X' = X + W@(M*X) - X*(W@M).

Every strategy's ``round`` accepts ``degree`` as either a Python float or a
traced scalar: the RoundEngine scans whole chunks of rounds, so the degree
(and with participation churn, the *effective* degree) is a per-round
traced value and byte accounting happens on device.  ``round`` also takes
the (possibly traced) round index ``rnd`` — used by PRF-keyed strategies
such as secure aggregation, ignored by the rest — so the engine can call
every strategy uniformly from inside the scan.

``W`` may be a dense (N, N) matrix *or* a neighbor-indexed
``SparseTopology`` (padded (N, D) tables): every W-product below goes
through :func:`repro.core.mixing.apply_W`, so each strategy costs
O(N·D·P) on sparse overlays without code changes.  With churn, the sparse
reweight (:func:`participation_reweight_sparse`) masks neighbor slots and
returns the freed mass to the diagonal without ever materializing W.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.mixing import NodeShard, ShardedDense, ShardedTopology, apply_W
from repro.core.topology import SparseTopology

BYTES_VAL = 4   # fp32 value on the wire
BYTES_IDX = 4   # int32 index on the wire


def _topk_mask(x_abs, k: int):
    """Boolean mask of the k largest-|.| coords per row. x_abs: (N, P)."""
    _, idx = jax.lax.top_k(x_abs, k)
    return jnp.zeros_like(x_abs, bool).at[jnp.arange(x_abs.shape[0])[:, None], idx].set(True)


def _node_keys(key, n_rows: int, rows=None):
    """(n_rows,) per-node PRNG keys: fold_in of each node's *global* id.

    Per-node keying (instead of one (N, P) draw from a single key) is what
    lets a node-sharded engine reproduce the single-device randomness: each
    device derives exactly the draws of the node rows it owns.  ``rows``
    (traced global ids, from the sharded mixing operand) defaults to
    arange — the unsharded node axis.
    """
    ids = jnp.arange(n_rows) if rows is None else rows
    return jax.vmap(lambda i: jax.random.fold_in(key, i))(ids)


def _randk_mask(key, shape, k: int, rows=None):
    """k random coords per row via top-k of iid uniforms (no replacement);
    draws are per-node keyed (see _node_keys)."""
    keys = _node_keys(key, shape[0], rows)
    u = jax.vmap(lambda kk: jax.random.uniform(kk, shape[1:]))(keys)
    return _topk_mask(u, k)


def _mix_rows(W):
    """Global node ids of W's rows: traced block ids for sharded operands
    inside a shard_map body, None (= arange) otherwise."""
    return W.rows if isinstance(W, (ShardedTopology, ShardedDense)) else None


def sparse_aggregate(X, W, M):
    """Masked gossip with missing-coordinate fallback (see module doc).
    W: dense (N, N) or SparseTopology — both products go through apply_W."""
    Xf, Mf = X.astype(jnp.float32), M.astype(jnp.float32)
    return (Xf + apply_W(W, Mf * Xf) - Xf * apply_W(W, Mf)).astype(X.dtype)


def participation_reweight(W, active, *, shard: Optional[NodeShard] = None):
    """Reweight a row-stochastic mixing matrix for a per-round node
    participation mask (churn / straggler dropout), fully traceable.

    active: (N,) {0,1} — 0 means the node is down this round: it neither
    sends nor receives, so every edge touching it is removed and the freed
    mass returns to each surviving row's diagonal (keeping rows stochastic;
    for symmetric W the result stays symmetric, hence doubly stochastic on
    the active subgraph).  A down node's row becomes e_i, i.e. it keeps its
    own parameters unchanged through the gossip step.

    shard: inside a shard_map body, the node-axis sharding — W is then this
    device's (B, N) row block and ``active`` its (B,) block; the column
    mask is all-gathered and the edge/alive counts psum'd so deg_eff is the
    same global scalar on every device.

    Returns (W', deg_eff) where deg_eff is the mean number of live outgoing
    edges per *active* node — the traced degree the byte accounting uses.
    """
    Wf = W.astype(jnp.float32)
    m = active.astype(jnp.float32)
    n = Wf.shape[1] if shard is not None else Wf.shape[0]
    if shard is not None:
        m_col = shard.gather(m)
        diag = (jnp.arange(n)[None, :] == shard.rows()[:, None]).astype(jnp.float32)
    else:
        m_col = m
        diag = jnp.eye(n, dtype=jnp.float32)
    off = Wf * (1.0 - diag) * m[:, None] * m_col[None, :]
    Wm = off + diag * (1.0 - off.sum(1, keepdims=True))
    edges = jnp.sum((off > 0).astype(jnp.float32))
    alive = m.sum()
    if shard is not None:
        edges, alive = shard.psum(edges), shard.psum(alive)
    deg_eff = edges / jnp.maximum(alive, 1.0)
    return Wm, deg_eff


def participation_reweight_sparse(topo: SparseTopology, active, *,
                                  shard: Optional[NodeShard] = None):
    """Sparse-form :func:`participation_reweight`: mask neighbor *slots*
    whose endpoint (either side) is down and return the freed mass to the
    surviving diagonal — O(N·D), no (N, N) matrix ever materialized.

    A down node's row becomes the identity (w row 0, w_self 1), exactly
    like the dense reweight's e_i rows; ``to_dense`` of the result equals
    the dense reweight of ``to_dense(topo)`` (property-tested).

    shard: inside a shard_map body — topo/active are this device's row
    blocks; the neighbor-endpoint mask is gathered and counts psum'd.

    Returns (SparseTopology, deg_eff) with deg_eff as in the dense form.
    """
    m = active.astype(jnp.float32)
    m_nbr = shard.gather(m) if shard is not None else m
    pair = m[:, None] * jnp.take(m_nbr, topo.nbr, axis=0)    # (N, D)
    w = topo.w.astype(jnp.float32) * pair
    w_self = 1.0 - w.sum(-1)                                 # down row -> 1.0
    edges = jnp.sum((w > 0).astype(jnp.float32))
    alive = m.sum()
    if shard is not None:
        edges, alive = shard.psum(edges), shard.psum(alive)
    deg_eff = edges / jnp.maximum(alive, 1.0)
    return SparseTopology(topo.nbr, w, w_self), deg_eff


# ---------------------------------------------------------------------------
# strategies
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FullSharing:
    """Baseline: serialize the full parameter vector (D-PSGD)."""

    def init_state(self, X):
        return ()

    def round(self, X, W, state, key, degree, rnd=0):
        X2 = apply_W(W, X).astype(X.dtype)
        return X2, state, degree * X.shape[1] * BYTES_VAL


@dataclasses.dataclass(frozen=True)
class RandomKSharing:
    """Random sampling sparsification (paper Fig. 4): k random coords."""

    budget: float  # fraction of parameters shared (paper: 0.10)

    def init_state(self, X):
        return ()

    def round(self, X, W, state, key, degree, rnd=0):
        k = max(1, int(self.budget * X.shape[1]))
        M = _randk_mask(key, X.shape, k, rows=_mix_rows(W))
        X2 = sparse_aggregate(X, W, M)
        return X2, state, degree * k * (BYTES_VAL + BYTES_IDX)


@dataclasses.dataclass(frozen=True)
class TopKSharing:
    """TopK sparsification [Alistarh et al. '18]: share the k coords whose
    *accumulated change* since last share is largest; residual accumulation
    stored in the Model-module extra state (paper §2.2 *Model*)."""

    budget: float

    def init_state(self, X):
        return {"last_shared": X.astype(jnp.float32)}

    def round(self, X, W, state, key, degree, rnd=0):
        k = max(1, int(self.budget * X.shape[1]))
        delta = X.astype(jnp.float32) - state["last_shared"]
        M = _topk_mask(jnp.abs(delta), k)
        X2 = sparse_aggregate(X, W, M)
        new_last = jnp.where(M, X.astype(jnp.float32), state["last_shared"])
        return X2, {"last_shared": new_last}, degree * k * (BYTES_VAL + BYTES_IDX)


@dataclasses.dataclass(frozen=True)
class ChocoSGD:
    """CHOCO-SGD [Koloskova et al. '19]: gossip on compressed *differences*
    to a public copy x̂, with consensus step size gamma.

        q_i  = C(x_i - x̂_i)          (top-k or random-k compressor)
        x̂_i += q_i                    (all nodes track the same x̂'s)
        x_i += gamma * sum_j W_ij (x̂_j - x̂_i)
    """

    budget: float
    gamma: float = 0.3
    compressor: str = "topk"  # 'topk' | 'randk'

    def init_state(self, X):
        return {"xhat": jnp.zeros_like(X, jnp.float32)}

    def round(self, X, W, state, key, degree, rnd=0):
        k = max(1, int(self.budget * X.shape[1]))
        Xf = X.astype(jnp.float32)
        diff = Xf - state["xhat"]
        if self.compressor == "topk":
            M = _topk_mask(jnp.abs(diff), k)
        else:
            M = _randk_mask(key, X.shape, k, rows=_mix_rows(W))
        q = jnp.where(M, diff, 0.0)
        xhat = state["xhat"] + q
        X2 = Xf + self.gamma * (apply_W(W, xhat) - xhat)
        return X2.astype(X.dtype), {"xhat": xhat}, degree * k * (BYTES_VAL + BYTES_IDX)


@dataclasses.dataclass(frozen=True)
class QuantizedSharing:
    """Full sharing through the Compression module: int8 codes + per-node
    scale on the wire (4x fewer bytes than fp32), dequantized before the
    MH aggregation.  Accuracy cost is bounded by the quantization step
    (see tests/test_substrate.py int8 roundtrip bounds)."""

    stochastic: bool = True

    def init_state(self, X):
        return ()

    def round(self, X, W, state, key, degree, rnd=0):
        from repro.core.compression import dequantize_int8, quantize_int8

        if self.stochastic:
            keys = _node_keys(key, X.shape[0], _mix_rows(W))
            codes, scale = jax.vmap(lambda x, kk: quantize_int8(x, key=kk))(X, keys)
        else:
            codes, scale = quantize_int8(X)
        Xq = dequantize_int8(codes, scale)  # what the receivers reconstruct
        X2 = apply_W(W, Xq).astype(X.dtype)
        return X2, state, degree * (X.shape[1] * 1 + 4)  # int8 + scale


def make_sharing(name: str, budget: float = 0.1, **kw):
    name = name.lower()
    if name in ("full", "fullsharing", "d-psgd"):
        return FullSharing()
    if name in ("randomk", "random"):
        return RandomKSharing(budget)
    if name == "topk":
        return TopKSharing(budget)
    if name in ("choco", "choco-sgd", "chocosgd"):
        return ChocoSGD(budget, **kw)
    if name in ("quant", "quantized", "int8"):
        return QuantizedSharing()
    raise ValueError(f"unknown sharing strategy {name!r}")
