"""Specialized nodes (paper Fig. 1): an FL server and a parameter server
can be built from the same modules — the node role is just who aggregates.

``FederatedRunner`` = FedAvg: the server broadcasts the global model, a
client subset trains locally, the server averages the returned models.
Equivalent in our algebra to star-topology gossip with full participation,
but implemented as a distinct runner because the paper calls out FL
emulation as a Node specialization.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import Optimizer
from repro.optim.optimizers import apply_updates


@dataclasses.dataclass
class FLConfig:
    n_clients: int = 16
    clients_per_round: int = 8
    local_steps: int = 1
    rounds: int = 100
    eval_every: int = 10
    seed: int = 0


class FederatedRunner:
    def __init__(self, fl: FLConfig, init_params_fn, loss_fn, acc_fn,
                 optimizer: Optimizer, batcher):
        self.fl = fl
        self.loss_fn, self.acc_fn, self.opt = loss_fn, acc_fn, optimizer
        self.batcher = batcher
        self.params = init_params_fn(jax.random.key(fl.seed))  # ONE global model
        self.history: List[dict] = []

        def client_update(params, bx, by):
            opt_state = self.opt.init(params)

            def step(carry, batch):
                p, s = carry
                g = jax.grad(self.loss_fn)(p, *batch)
                u, s = self.opt.update(g, s, p)
                return (apply_updates(p, u), s), ()

            (params, _), _ = jax.lax.scan(step, (params, opt_state), (bx, by))
            return params

        def round_fn(params, bx, by):
            # bx: (M, L, B, ...) — M participating clients
            client_params = jax.vmap(client_update, in_axes=(None, 0, 0))(params, bx, by)
            return jax.tree_util.tree_map(lambda a: a.mean(0).astype(a.dtype), client_params)

        self._round = jax.jit(round_fn)
        self._eval = jax.jit(lambda p, tx, ty: self.acc_fn(p, tx, ty))

    def run(self, rounds: Optional[int] = None, log: bool = True):
        fl = self.fl
        rounds = rounds if rounds is not None else fl.rounds
        tx, ty = self.batcher.test_batch()
        tx, ty = jnp.asarray(tx), jnp.asarray(ty)
        rng = np.random.default_rng(fl.seed)
        for rnd in range(rounds):
            sel = rng.choice(fl.n_clients, fl.clients_per_round, replace=False)
            bxs, bys = [], []
            for s in range(fl.local_steps):
                x, y = self.batcher.batch(rnd, s)
                bxs.append(x[sel])
                bys.append(y[sel])
            bx = jnp.asarray(np.stack(bxs, axis=1))  # (M, L, B, ...)
            by = jnp.asarray(np.stack(bys, axis=1))
            self.params = self._round(self.params, bx, by)
            if rnd % fl.eval_every == 0 or rnd == rounds - 1:
                acc = float(self._eval(self.params, tx, ty))
                self.history.append({"round": rnd, "acc": acc})
                if log:
                    print(f"[fedavg] round {rnd:4d} acc {acc:.4f}")
        return self.history
