"""Secure aggregation for DL (paper §3.4, after Bonawitz et al. CCS'17 and
the DecentralizePy secure-aggregation node).

Every *receiver* r aggregates the models of its neighbor set N(r) with equal
weights.  Each ordered sender pair (i, j) in N(r), i < j, shares a seed; i
adds +PRF(seed), j adds -PRF(seed) to the copy each sends to r, so the sum
over N(r) is exactly the unmasked sum while every individual message is a
one-time-padded blob.  Receiver r's own model never leaves r.

    y_r = (1 - w·|N(r)|) x_r + w * sum_{i in N(r)} msg_{i->r}
        = MH-weighted aggregate (masks cancel exactly).

The PRF is JAX's threefry counter PRNG keyed by fold_in(round, i, j, r) —
uniform in [-b, b].  Masks are float32, so cancellation is exact in real
arithmetic but the *aggregate* suffers bounded rounding noise — the paper's
reported ~3% accuracy cost on CIFAR-10; we property-test the cancellation
to fp32 tolerance.

Two implementations of the same math:

* ``round``            — vectorized and fully jittable: the ragged neighbor
  sets become a padded ``(N, dmax)`` neighbor table (topology.neighbor_table),
  the per-receiver mask sum is a vmap over receivers x message slots with a
  fori_loop over co-neighbor pairs, and the round index is a *traced* value
  (fold_in accepts tracers) — so ``secure=True`` runs inside the engine's
  lax.scan chunk like any other sharing strategy.  Work is O(N·d²·P) like
  the reference, without the O(N·d) Python dict of messages.
* ``round_reference``  — the original Python dict-of-messages schedule, kept
  as the oracle the vectorized path is equivalence-tested against.

Communication: each edge carries the P masked values plus a 24-byte
metadata record (pair seeds + round) — the paper's ≈3% overhead is
metadata+framing; we account 3% to match its cost model.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.topology import neighbor_table

BYTES_VAL = 4
METADATA_OVERHEAD = 0.03  # paper: ~3% extra bytes (seeds, framing)


def _pair_mask_from(kround, i, j, r, shape, bound: float):
    """PRF mask for ordered pair (i, j) at receiver r, from a key already
    folded with the round — the single definition of the mask PRF chain
    (all indices may be tracers)."""
    k = jax.random.fold_in(kround, i)
    k = jax.random.fold_in(k, j)
    k = jax.random.fold_in(k, r)
    return jax.random.uniform(k, shape, jnp.float32, -bound, bound)


def _pair_mask(key, rnd, i, j, r, shape, bound: float):
    return _pair_mask_from(jax.random.fold_in(key, rnd), i, j, r, shape, bound)


@dataclasses.dataclass(frozen=True)
class SecureAggregation:
    """Drop-in sharing strategy: masked full sharing over a *static* graph.

    adj: (N, N) bool numpy adjacency (static — the mask schedule, i.e. the
    neighbor table, must be known at trace time; dynamic graphs would
    re-key every round anyway).
    """

    adj: np.ndarray
    mask_bound: float = 1.0

    def __post_init__(self):
        nbr, valid = neighbor_table(np.asarray(self.adj))
        object.__setattr__(self, "_nbr", nbr)
        object.__setattr__(self, "_valid", valid)

    def init_state(self, X):
        return ()

    def messages(self, X, key, rnd):
        """Masked message from i to r for every edge (i, r). Returns a dict
        {(i, r): vector} — reference schedule, materialized only for
        emulation-scale N (and for the privacy tests)."""
        N, P = X.shape
        out = {}
        for r in range(N):
            nbrs = [int(i) for i in np.nonzero(self.adj[r])[0]]
            for i in nbrs:
                msg = X[i].astype(jnp.float32)
                for j in nbrs:
                    if j == i:
                        continue
                    a, b = (i, j) if i < j else (j, i)
                    sign = 1.0 if i < j else -1.0
                    msg = msg + sign * _pair_mask(key, rnd, a, b, r, (P,), self.mask_bound)
                out[(i, r)] = msg
        return out

    def round(self, X, W, state, key, degree, rnd=0):
        """Vectorized, jittable masked aggregation.  W must give equal
        weight w to all of a receiver's neighbors (true for MH on regular
        graphs); ``degree`` and ``rnd`` may be traced scalars."""
        N, P = X.shape
        Xf = X.astype(jnp.float32)
        Wf = W.astype(jnp.float32)
        nbr = jnp.asarray(self._nbr)
        valid = jnp.asarray(self._valid, jnp.float32)
        kr = jax.random.fold_in(key, rnd)
        D = nbr.shape[1]
        bound = self.mask_bound

        def receiver(r, nbr_r, valid_r, w_row):
            w = w_row[nbr_r[0]]  # equal-weight assumption per receiver

            def slot_msg(ii):
                i = nbr_r[ii]

                def add_mask(jj, acc):
                    j = nbr_r[jj]
                    a, b = jnp.minimum(i, j), jnp.maximum(i, j)
                    m = _pair_mask_from(kr, a, b, r, (P,), bound)
                    sign = (
                        jnp.where(i < j, 1.0, -1.0)
                        * valid_r[jj]
                        * jnp.where(jj == ii, 0.0, 1.0)
                    )
                    return acc + sign * m

                return jax.lax.fori_loop(0, D, add_mask, Xf[i])

            msgs = jax.vmap(slot_msg)(jnp.arange(D))  # (D, P)
            deg_r = valid_r.sum()
            acc = (1.0 - w * deg_r) * Xf[r] + w * jnp.sum(msgs * valid_r[:, None], 0)
            return jnp.where(deg_r > 0, acc, Xf[r])

        X2 = jax.vmap(receiver)(jnp.arange(N), nbr, valid, Wf)
        bytes_sent = degree * P * BYTES_VAL * (1.0 + METADATA_OVERHEAD)
        return X2.astype(X.dtype), state, bytes_sent

    def round_reference(self, X, W, state, key, degree: float, rnd: int = 0):
        """Python-scheduled reference: aggregate the dict of masked
        messages.  Oracle for the vectorized ``round``."""
        N, P = X.shape
        Xf = X.astype(jnp.float32)
        msgs = self.messages(Xf, key, rnd)
        rows = []
        Wn = np.asarray(W)
        for r in range(N):
            nbrs = [int(i) for i in np.nonzero(self.adj[r])[0]]
            w = float(Wn[r, nbrs[0]]) if nbrs else 0.0
            acc = (1.0 - w * len(nbrs)) * Xf[r]
            for i in nbrs:
                acc = acc + w * msgs[(i, r)]
            rows.append(acc)
        X2 = jnp.stack(rows).astype(X.dtype)
        bytes_sent = degree * P * BYTES_VAL * (1.0 + METADATA_OVERHEAD)
        return X2, state, bytes_sent
