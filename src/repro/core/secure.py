"""Secure aggregation for DL (paper §3.4, after Bonawitz et al. CCS'17 and
the DecentralizePy secure-aggregation node).

Every *receiver* r aggregates the models of its neighbor set N(r) with equal
weights.  Each ordered sender pair (i, j) in N(r), i < j, shares a seed; i
adds +PRF(seed), j adds -PRF(seed) to the copy each sends to r, so the sum
over N(r) is exactly the unmasked sum while every individual message is a
one-time-padded blob.  Receiver r's own model never leaves r.

    y_r = (1 - w·|N(r)|) x_r + w * sum_{i in N(r)} msg_{i->r}
        = MH-weighted aggregate (masks cancel exactly).

The PRF is JAX's threefry counter PRNG keyed by fold_in(round, i, j, r) —
uniform in [-b, b].  Masks are float32, so cancellation is exact in real
arithmetic but the *aggregate* suffers bounded rounding noise — the paper's
reported ~3% accuracy cost on CIFAR-10; we property-test the cancellation
to fp32 tolerance.

Two implementations of the same math:

* ``round``            — vectorized and fully jittable: the ragged neighbor
  sets become a padded ``(N, dmax)`` neighbor table (topology.neighbor_table),
  batched vmap passes derive the per-pair threefry PRF *keys* (one sender
  slot at a time via lax.map — O(N·d) key words staged, the bit tensors
  never materialize; round index a *traced* value), and the fused
  ``kernels/secure_mask`` keyed Pallas kernel (compiled on TPU, interpret
  mode on CPU) runs the threefry counter expansion in-body, maps
  bits→uniform, and applies all signed masks in one HBM pass — bit-identical
  to expanding ``jax.random.bits`` per pair.
  So ``secure=True`` runs inside the engine's lax.scan chunk like any other
  sharing strategy; work is O(N·d²·P) like the reference, without the
  O(N·d) Python dict of messages or the former per-slot fori_loop.
* ``round_reference``  — the original Python dict-of-messages schedule, kept
  as the oracle the vectorized path is equivalence-tested against.  Both
  paths derive masks from the same threefry bits via the same
  ``kernels.ref.mask_bits_to_uniform`` mapping, so masks are bit-identical
  and only summation order differs.

``W`` may be the dense (N, N) matrix or a neighbor-indexed
``SparseTopology`` — only the per-receiver scalar weight is read from it,
so the sparse engine path threads its (N, D) tables straight through.

Communication: each edge carries the P masked values plus a 24-byte
metadata record (pair seeds + round) — the paper's ≈3% overhead is
metadata+framing; we account 3% to match its cost model.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.mixing import ShardedDense, ShardedTopology
from repro.core.topology import SparseTopology, neighbor_table
from repro.kernels import ops as kernel_ops
from repro.kernels.ref import mask_bits_to_uniform

BYTES_VAL = 4
METADATA_OVERHEAD = 0.03  # paper: ~3% extra bytes (seeds, framing)
# one revealed Shamir/seed share on the recovery round: the co-neighbor
# re-sends the (dropped pair, receiver) key-chain material — a 32-byte
# record (pair seed + ids + round), after Bonawitz et al. CCS'17 §5
SEED_SHARE_BYTES = 32


def _pair_key_from(kround, i, j, r):
    """PRF key for ordered pair (i, j) at receiver r, from a key already
    folded with the round — the single definition of the mask PRF chain
    (all indices may be tracers)."""
    k = jax.random.fold_in(kround, i)
    k = jax.random.fold_in(k, j)
    return jax.random.fold_in(k, r)


def _pair_bits_from(kround, i, j, r, shape):
    """Threefry PRF bits for ordered pair (i, j) at receiver r — the
    reference expansion of :func:`_pair_key_from` (the fused kernel
    generates the same bits in-body from the key words alone)."""
    return jax.random.bits(_pair_key_from(kround, i, j, r), shape, jnp.uint32)


def _pair_mask_from(kround, i, j, r, shape, bound: float):
    """PRF mask in [-bound, bound): bits -> uniform via the same mapping the
    Pallas kernel uses (kernels.ref.mask_bits_to_uniform), so the reference
    schedule and the fused kernel agree bit-exactly."""
    return mask_bits_to_uniform(_pair_bits_from(kround, i, j, r, shape), bound)


def _pair_mask(key, rnd, i, j, r, shape, bound: float):
    return _pair_mask_from(jax.random.fold_in(key, rnd), i, j, r, shape, bound)


@dataclasses.dataclass(frozen=True)
class SecureAggregation:
    """Drop-in sharing strategy: masked full sharing over a *static* graph.

    adj: (N, N) bool numpy adjacency (static — the mask schedule, i.e. the
    neighbor table, must be known at trace time; dynamic graphs would
    re-key every round anyway).

    recovery: enable the Bonawitz-style seed-recovery pass so masked
    aggregation stays correct under churn (``DLConfig.secure_recovery``).
    Dropped senders leave their pair masks uncancelled in every live
    co-neighbor's message; surviving co-neighbors re-derive the dropped
    pairs' PRF masks from the shared key chain (``_pair_key_from`` — the
    receiver learns only mask material it could already compute) and the
    receiver subtracts them in a second traced mask pass, then aggregates
    the *live* neighbor set only.  The corrected aggregate equals the
    churn-reweighted plain aggregate exactly (masks over live pairs still
    cancel pairwise; property-tested).  The recovery round's seed-share
    traffic is accounted per (live receiver, live sender, dropped
    co-neighbor) triple at ``SEED_SHARE_BYTES`` each — see
    ``steps.RoundSteps._secure_recovery_bytes``.
    """

    adj: np.ndarray
    mask_bound: float = 1.0
    recovery: bool = False

    def __post_init__(self):
        nbr, valid = neighbor_table(np.asarray(self.adj))
        object.__setattr__(self, "_nbr", nbr)
        object.__setattr__(self, "_valid", valid)

    def init_state(self, X):
        return ()

    @property
    def needs_act(self) -> bool:
        """The step layer passes the participation mask into :meth:`round`
        (``act=``) when recovery is on — the receiver must know which
        senders dropped to run the seed-recovery pass."""
        return self.recovery

    def messages(self, X, key, rnd):
        """Masked message from i to r for every edge (i, r). Returns a dict
        {(i, r): vector} — reference schedule, materialized only for
        emulation-scale N (and for the privacy tests)."""
        N, P = X.shape
        out = {}
        for r in range(N):
            nbrs = [int(i) for i in np.nonzero(self.adj[r])[0]]
            for i in nbrs:
                msg = X[i].astype(jnp.float32)
                for j in nbrs:
                    if j == i:
                        continue
                    a, b = (i, j) if i < j else (j, i)
                    sign = 1.0 if i < j else -1.0
                    msg = msg + sign * _pair_mask(key, rnd, a, b, r, (P,), self.mask_bound)
                out[(i, r)] = msg
        return out

    def round(self, X, W, state, key, degree, rnd=0, act=None):
        """Vectorized, jittable masked aggregation.  W (dense (N, N) or
        SparseTopology) must give equal weight w to all of a receiver's
        neighbors (true for MH on regular graphs); ``degree`` and ``rnd``
        may be traced scalars.  ``act`` is the (N,) participation mask
        (recovery mode only): dropped senders are excised via the
        seed-recovery pass and the live neighbor set is aggregated with
        the churn-reweighted weights W already carries.

        Pipeline, per sender slot (lax.map over the D slots): (1) a batched
        vmap pass derives the threefry *pair keys* of every (receiver,
        co-neighbor) mask for that slot's messages — O(N·d) key words, not
        O(N·d·P) bit tensors; keys are built from the *sorted* node pair so
        the +1 and -1 occurrences expand identical bits and cancel exactly;
        (2) the fused Pallas kernel (``secure_mask_apply_nodes_keyed``)
        runs the threefry counter expansion in-body per parameter block,
        maps bits -> uniform[-b, b), and applies all signed masks to the
        slot's N messages in one HBM pass.  Finally each receiver sums its
        valid masked messages with weight w.
        """
        if isinstance(W, (ShardedTopology, ShardedDense)):
            return self._round_sharded(X, W, state, key, degree, rnd, act)
        N, P = X.shape
        Xf = X.astype(jnp.float32)
        nbr = jnp.asarray(self._nbr)                      # (N, D)
        validf = jnp.asarray(self._valid, jnp.float32)
        if isinstance(W, SparseTopology):
            # the secure contract requires equal weights across a receiver's
            # neighbors, so any live slot's weight works: row max skips
            # w=0 padding (and any zeroed slot), where slot 0 alone would not
            wvec = jnp.max(W.w.astype(jnp.float32), axis=1)
        else:
            Wg = jnp.take_along_axis(W.astype(jnp.float32), nbr, axis=1)
            wvec = jnp.max(Wg * validf, axis=1)
        Xnbr = jnp.take(Xf, nbr, axis=0)                   # (N, D, P)
        act_nbr = None if act is None else jnp.take(act, nbr, axis=0)
        return self._masked_aggregate(
            Xf, Xnbr, nbr, validf, wvec, jnp.arange(N), key, rnd, degree,
            X.dtype, state, act_nbr,
        )

    def _round_sharded(self, X, W, state, key, degree, rnd, act=None):
        """Node-sharded masked aggregation (inside a shard_map body): X is
        this device's (B, P) row block, W the sharded mixing operand.  The
        co-neighbor messages arrive through ``W.neighbor_stack`` — the same
        per-slot `collective_permute` permutations (or the all-gather
        fallback) the plain gossip path uses — and the pair-PRF bits are
        keyed by *global* node ids, so every mask pair still cancels
        exactly as in the single-device schedule.  Recovery mode
        (``act`` given) uses the *canonical* neighbor table gathered at
        this device's rows: the rebalanced table's churn-zeroed weights
        can't be told apart from static padding, and recovery must see
        exactly the schedule the masks were keyed over."""
        B, P = X.shape
        Xf = X.astype(jnp.float32)
        act_g = None if act is None else W.shard.gather(act)
        if isinstance(W, ShardedTopology) and act is None:
            nbr = W.topo.nbr                               # (B, D), rebalanced order
            validf = (W.topo.w > 0).astype(jnp.float32)
            # equal-weight assumption (regular graphs): row max skips the
            # w=0 padding slots the rebalanced table interleaves
            wvec = jnp.max(W.topo.w.astype(jnp.float32), axis=1)
            Xnbr = W.neighbor_stack(Xf)                    # (B, D, P)
        else:
            rows = W.rows
            nbr = jnp.take(jnp.asarray(self._nbr), rows, axis=0)
            validf = jnp.take(jnp.asarray(self._valid, jnp.float32), rows, axis=0)
            if isinstance(W, ShardedTopology):
                wvec = jnp.max(W.topo.w.astype(jnp.float32), axis=1)
            else:
                Wg = jnp.take_along_axis(W.W.astype(jnp.float32), nbr, axis=1)
                wvec = jnp.max(Wg * validf, axis=1)
            Xnbr = jnp.take(W.shard.gather(Xf), nbr, axis=0)
        act_nbr = None if act_g is None else jnp.take(act_g, nbr, axis=0)
        return self._masked_aggregate(
            Xf, Xnbr, nbr, validf, wvec, W.rows, key, rnd, degree, X.dtype,
            state, act_nbr,
        )

    def _masked_aggregate(self, Xf, Xnbr, nbr, validf, wvec, rows, key, rnd,
                          degree, dtype, state, act_nbr=None):
        """Shared core of the vectorized path: per-slot PRF bits + fused
        mask apply + weighted receiver sum.  ``rows`` are the global node
        ids of the local receiver rows (arange unsharded).

        Recovery (``act_nbr`` — the neighbor slots' participation, (N, D)):
        pass 1 applies exactly the masks the senders transmitted (senders
        don't know who dropped, so they mask against *every* valid
        co-neighbor); pass 2 re-derives the (live sender, dropped
        co-neighbor) pair masks from the same key chain and subtracts
        them.  The surviving mask set then cancels pairwise over live
        pairs, and the receiver aggregates the live slots only — equal to
        the churn-reweighted plain aggregate."""
        P = Xf.shape[1]
        D = nbr.shape[1]
        kr = jax.random.fold_in(key, rnd)
        i_mat = nbr[:, :, None]                            # sender node
        j_mat = nbr[:, None, :]                            # co-neighbor node
        signs = (
            jnp.where(i_mat < j_mat, 1.0, -1.0)
            * validf[:, None, :]
            * (1.0 - jnp.eye(D, dtype=jnp.float32))
        )                                                  # (N, D, D)

        def slot_pass(base, signs_all):
            def slot_msgs(ii):
                def receiver_keys(r, nbr_r):
                    i = nbr_r[ii]

                    def pair(j):
                        a, b = jnp.minimum(i, j), jnp.maximum(i, j)
                        return jax.random.key_data(_pair_key_from(kr, a, b, r))

                    return jax.vmap(pair)(nbr_r)           # (D, 2)

                keys = jax.vmap(receiver_keys)(rows, nbr)  # (N, D, 2) uint32
                return kernel_ops.secure_mask_apply_nodes_keyed(
                    jnp.take(base, ii, axis=1),
                    keys,
                    jnp.take(signs_all, ii, axis=1),
                    self.mask_bound,
                )                                          # (N, P)

            return jnp.moveaxis(
                jax.lax.map(slot_msgs, jnp.arange(D)), 0, 1
            )                                              # (N, D, P)

        msgs = slot_pass(Xnbr, signs)
        validf_live = validf
        if act_nbr is not None:
            down = validf * (1.0 - act_nbr)                # dropped co-nbrs
            msgs = slot_pass(msgs, -signs * down[:, None, :])
            validf_live = validf * act_nbr
        deg_r = validf_live.sum(1)
        acc = (1.0 - wvec * deg_r)[:, None] * Xf + wvec[:, None] * jnp.sum(
            msgs * validf_live[:, :, None], axis=1
        )
        X2 = jnp.where((deg_r > 0)[:, None], acc, Xf)
        item = jnp.dtype(dtype).itemsize
        bytes_sent = degree * P * item * (1.0 + METADATA_OVERHEAD)
        return X2.astype(dtype), state, bytes_sent

    def wire_dtype(self, x_dtype):
        return np.dtype(x_dtype)

    def stage_bytes_per_round(self, n: int, p: int) -> int:
        # recovery stages a second full mask pass over the neighbor stack
        return n * p * 4 * (2 if self.recovery else 1)

    def round_reference(self, X, W, state, key, degree: float, rnd: int = 0):
        """Python-scheduled reference: aggregate the dict of masked
        messages.  Oracle for the vectorized ``round``."""
        N, P = X.shape
        Xf = X.astype(jnp.float32)
        msgs = self.messages(Xf, key, rnd)
        rows = []
        Wn = np.asarray(W)
        for r in range(N):
            nbrs = [int(i) for i in np.nonzero(self.adj[r])[0]]
            w = float(Wn[r, nbrs[0]]) if nbrs else 0.0
            acc = (1.0 - w * len(nbrs)) * Xf[r]
            for i in nbrs:
                acc = acc + w * msgs[(i, r)]
            rows.append(acc)
        X2 = jnp.stack(rows).astype(X.dtype)
        bytes_sent = degree * P * jnp.dtype(X.dtype).itemsize * (1.0 + METADATA_OVERHEAD)
        return X2, state, bytes_sent
