"""Secure aggregation for DL (paper §3.4, after Bonawitz et al. CCS'17 and
the DecentralizePy secure-aggregation node).

Every *receiver* r aggregates the models of its neighbor set N(r) with equal
weights.  Each ordered sender pair (i, j) in N(r), i < j, shares a seed; i
adds +PRF(seed), j adds -PRF(seed) to the copy each sends to r, so the sum
over N(r) is exactly the unmasked sum while every individual message is a
one-time-padded blob.  Receiver r's own model never leaves r.

    y_r = (1 - w·|N(r)|) x_r + w * sum_{i in N(r)} msg_{i->r}
        = MH-weighted aggregate (masks cancel exactly).

The PRF is JAX's threefry counter PRNG keyed by fold_in(round, i, j, r) —
uniform in [-b, b].  Masks are float32, so cancellation is exact in real
arithmetic but the *aggregate* suffers bounded rounding noise — the paper's
reported ~3% accuracy cost on CIFAR-10; we property-test the cancellation
to fp32 tolerance.

Communication: each edge carries the P masked values plus a 24-byte
metadata record (pair seeds + round) — the paper's ≈3% overhead is
metadata+framing; we account 3% to match its cost model.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

BYTES_VAL = 4
METADATA_OVERHEAD = 0.03  # paper: ~3% extra bytes (seeds, framing)


def _pair_mask(key, rnd, i, j, r, shape, bound: float):
    k = jax.random.fold_in(key, rnd)
    k = jax.random.fold_in(k, i)
    k = jax.random.fold_in(k, j)
    k = jax.random.fold_in(k, r)
    return jax.random.uniform(k, shape, jnp.float32, -bound, bound)


@dataclasses.dataclass(frozen=True)
class SecureAggregation:
    """Drop-in sharing strategy: masked full sharing over a *static* graph.

    adj: (N, N) bool numpy adjacency (static — mask schedule must be static
    python control flow; dynamic graphs would re-key every round anyway).
    """

    adj: np.ndarray
    mask_bound: float = 1.0

    def init_state(self, X):
        return ()

    def messages(self, X, key, rnd):
        """Masked message from i to r for every edge (i, r). Returns a dict
        {(i, r): vector} — materialized only for emulation-scale N."""
        N, P = X.shape
        out = {}
        for r in range(N):
            nbrs = [int(i) for i in np.nonzero(self.adj[r])[0]]
            for i in nbrs:
                msg = X[i].astype(jnp.float32)
                for j in nbrs:
                    if j == i:
                        continue
                    a, b = (i, j) if i < j else (j, i)
                    sign = 1.0 if i < j else -1.0
                    msg = msg + sign * _pair_mask(key, rnd, a, b, r, (P,), self.mask_bound)
                out[(i, r)] = msg
        return out

    def round(self, X, W, state, key, degree: float, rnd: int = 0):
        """Aggregate with masks. W must give equal weight w to all of a
        receiver's neighbors (true for MH on regular graphs)."""
        N, P = X.shape
        Xf = X.astype(jnp.float32)
        msgs = self.messages(Xf, key, rnd)
        rows = []
        Wn = np.asarray(W)
        for r in range(N):
            nbrs = [int(i) for i in np.nonzero(self.adj[r])[0]]
            w = float(Wn[r, nbrs[0]]) if nbrs else 0.0
            acc = (1.0 - w * len(nbrs)) * Xf[r]
            for i in nbrs:
                acc = acc + w * msgs[(i, r)]
            rows.append(acc)
        X2 = jnp.stack(rows).astype(X.dtype)
        bytes_sent = degree * P * BYTES_VAL * (1.0 + METADATA_OVERHEAD)
        return X2, state, bytes_sent
