"""RoundEngine — the compiled multi-round execution core of the DL
emulator (paper Fig. 2 loop, compiled R rounds at a time).

## Execution model

Execution is layered (the pluggable-semantics split):

* **Step layer** (``core/steps.py``): the pure jittable per-round
  functions — local-SGD step, share/mix step through the configured
  sharing strategy, per-node simulated round time — identical inside a
  ``lax.scan`` body, a legacy per-round jit, or a ``shard_map`` block.
* **Scheduler layer** (``core/scheduler.py``): time and activation
  semantics, selected by ``DLConfig.semantics``:

  - ``"sync"`` — the synchronous round barrier (chunks of R rounds in one
    ``lax.scan``; the bit-for-bit equivalence oracle, and the only
    semantics the legacy ``chunk_rounds=0`` dispatch and the node-sharded
    ``shard_map`` chunk run under),
  - ``"local"`` — identical trajectories, per-node virtual clocks with a
    neighborhood barrier (stragglers delay only their graph
    neighborhood),
  - ``"async"`` — event-driven gossip on a first-class virtual clock
    (the AD-PSGD family): per-node next-event times driven by the
    heterogeneous per-node ``compute_time_s`` vector, scanned event
    cohorts, pairwise or neighborhood averaging against possibly-stale
    neighbor params, with staleness / per-node wall-clock / event counts
    as traced outputs.

* **Engine** (this module): resources and the run loop — node-stacked
  state, device-resident data, topology/network/sharing construction,
  eval cadence, history, results.

The mechanics the layers inherit from the earlier engine generations are
unchanged and still property-tested: batches pre-stacked on device with
per-chunk index tensors; sparse neighbor-indexed mixing with traced
per-round (R, N, D) topology stacks (``mixing="auto"|"sparse"|"dense"``);
payload-form compressed sharing (``payload``); jittable secure
aggregation; per-round participation masks for churn — now iid *or*
machine-correlated (``churn_machines``); metrics as traced scan outputs
synced once per chunk; and the node-sharded chunk over a device mesh
(``shard_devices``/``shard_backend``) with collective_permute or
all-gather gossip.  Chunk boundaries align to the eval cadence, so the
recorded history is identical to per-round execution.

Heterogeneous time is a first-class axis: ``compute_time_s`` is the base
per-node local compute, and ``straggler_factor``/``straggler_frac`` mark
a seeded fraction of nodes as stragglers (``network.straggler_compute_
times``); the (N,) vector feeds the traced round-time formula — one
implementation, ``network.node_round_times``, shared with the host
``NetworkModel`` so the Python model and the compiled model cannot drift.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import faults as faults_lib
from repro.core import sharing as sharing_lib
from repro.core.faults import FaultPlan
from repro.core.mixing import NodeShard, PermuteSchedule
from repro.core.network import (
    NetworkModel,
    paper_testbed,
    straggler_compute_times,
    wan_deployment,
)
from repro.core.scheduler import make_scheduler
from repro.core.secure import SecureAggregation
from repro.core.steps import RoundSteps
from repro.core.topology import (
    Graph,
    PeerSampler,
    SparseTopology,
    decompose_slot_permutations,
)
from repro.optim import Optimizer
from repro.utils.pytree import tree_vector

# cap on the (R, N, N) mixing-matrix stack a single *dense-path* chunk
# materializes; dense chunks shrink automatically at very large N.  The
# sparse path stages O(N·d) tables per round and is exempt.
_W_STACK_BYTES_CAP = 64 * 1024 * 1024

# above this node count, circulant topologies (ring / regular) skip the
# dense (N, N) Graph object entirely and build the sparse neighbor table
# directly (topology.circulant_neighbor_table, O(N·d)) — the adjacency of
# a 100k-node overlay alone would be 10 GB.  Tables are bitwise-identical
# either way (property-tested), so the threshold only moves memory.
_DENSE_GRAPH_MAX_N = 4096


@dataclasses.dataclass
class DLConfig:
    """Experiment specification (paper Fig. 1 'specifications' input)."""

    n_nodes: int = 16
    # execution backend: 'simulated' — the in-process RoundEngine (every
    # node a slot of the stacked node axis, time simulated); 'processes' —
    # the real-network runtime (repro.runtime): K OS processes each owning
    # a row-block of nodes, gossiping the payload wire format over real
    # TCP sockets on real clocks (failure detection, retry/backoff,
    # graceful degradation on peer death)
    backend: str = "simulated"  # simulated | processes
    topology: str = "regular"  # ring | regular | fully | star | dynamic | file:<path>
    degree: int = 5
    sharing: str = "full"      # full | randomk | topk | choco | quant
    budget: float = 0.1        # sparsification budget
    choco_gamma: float = 0.3
    # payload wire format for sparsified strategies: 'on' emits compact
    # (idx, val) per-node payloads aggregated in one O(N·d·k) gather +
    # scatter pass (mixing.mix_payload); 'off' runs the dense-mask oracle
    # (scattered (N, P) masks + two apply_W passes — the legacy form, kept
    # property-tested equal); 'auto' = on for randomk/topk/choco.
    payload: str = "auto"      # auto | on | off
    payload_quant: bool = False  # int8-quantize payload values on the wire
    randk_sampler: str = "uniform"  # randomk coord sampler: uniform | strided
    secure: bool = False       # secure aggregation (masked full sharing)
    local_steps: int = 1
    batch_size: int = 8
    rounds: int = 100
    eval_every: int = 10
    seed: int = 0
    results_dir: Optional[str] = None
    # --- engine (scan) execution ------------------------------------------
    chunk_rounds: int = 8      # rounds per compiled lax.scan chunk; 0 = legacy
    mixing: str = "auto"       # auto | sparse (neighbor tables) | dense (N,N W)
    # --- execution semantics (scheduler layer) -----------------------------
    # 'sync'  — synchronous round barrier (the paper's default; oracle)
    # 'local' — same trajectories, per-node clocks w/ neighborhood barrier
    # 'async' — event-driven gossip on a virtual clock (AD-PSGD family)
    semantics: str = "sync"
    async_gossip: str = "neighborhood"  # neighborhood | pairwise (AD-PSGD)
    async_slice_s: float = 0.0  # event-cohort window on the virtual clock
    # population-scale cohort activation (async only): >0 bounds each event
    # step to a gathered hot set of C rows — O(C·(d+1)·P) per step instead
    # of O(N·P) — with overflow-carry for in-slice nodes beyond capacity.
    # 0 = the dense oracle (every step computes over all N rows).
    cohort_capacity: int = 0
    # cohort selection layer: 'flat' = the O(N) min+top_k oracle; 'hier' =
    # carried segment-minimum hierarchy — top-K segments of the (S,)
    # per-segment minima, then top_k inside their gathered clock union —
    # O(S + K·seg) per step with bitwise-identical cohorts (slices
    # spanning more than K segments fall back to the flat oracle inside
    # the step); 'auto' = hier above ~260k nodes.
    selection: str = "auto"    # auto | flat | hier
    segment_size: int = 0      # hier segment length; 0 = auto ~ sqrt(N/C)
    # cold population storage (cohort path): the (N, P) params and float
    # opt-state moments live compressed on device — 'bf16' truncates
    # (round-trip exact for bf16-representable values), 'int8' per-row
    # symmetric quantization (codes + one fp32 scale per row per leaf,
    # ~0.26x fp32 bytes; lossy, gated by a tolerance oracle) — decoded on
    # cohort gather, re-encoded on scatter.
    cold_dtype: str = "fp32"   # fp32 | bf16 | int8
    # batch-index derivation: 'stream' = per-round numpy PCG64 host staging
    # (the original path); 'node' = per-(round, node) jax PRNG keying,
    # derived on device for exactly the rows a step touches — required by
    # cohort_capacity (staging (R, L, N, B) host indices would reintroduce
    # the O(N) per-step cost the cohort path removes).  The two keyings
    # draw different (equally valid) sample streams.
    batch_keying: str = "stream"  # stream | node
    # --- multi-device execution -------------------------------------------
    shard_devices: int = 0     # shard the node axis over this many devices
    shard_backend: str = "auto"  # auto | ppermute (slot collective_permutes) | gather
    # --- scenario axes -----------------------------------------------------
    participation: float = 1.0  # P(node active in a round); <1 models churn
    churn_machines: int = 0    # >0: correlated churn — machines fail, not nodes
    # message-level fault injection (core.faults.FaultPlan): per-edge loss,
    # crash/restart schedules, latency spikes, payload corruption — None
    # disables the fault axis entirely (zero overhead in the scanned body)
    faults: Optional[FaultPlan] = None
    # Bonawitz seed recovery: lets secure=True run under churn — surviving
    # co-neighbors reveal dropped pairs' seed material so the receiver can
    # subtract the uncancelled PRF masks (costs a second mask pass plus
    # SEED_SHARE_BYTES per dropped-pair triple)
    secure_recovery: bool = False
    network: str = "none"       # simulated network: none | lan | wan
    compute_time_s: float = 0.0  # base per-node local compute in the time model
    straggler_factor: float = 1.0  # stragglers run at factor x compute_time_s
    straggler_frac: float = 0.0    # seeded fraction of straggler nodes
    # continuous per-node heterogeneity: node i runs at compute_time_s *
    # U(1, 1 + compute_spread), seeded — de-ties the event clock so the
    # population's t_next is spread instead of lattice-valued (the regime
    # where hierarchical cohort selection can prune segments)
    compute_spread: float = 0.0
    parallel_sends: bool = False  # overlap a node's sends (dedicated NICs)

    # ------------------------------------------------------------------
    def validate(self) -> "DLConfig":
        """Centralized knob validation — every cross-knob constraint lives
        here (the engine calls it first; tests exercise it directly).
        Raises ValueError on the first violation; returns self."""
        def bad(msg):
            raise ValueError(f"invalid DLConfig: {msg}")

        if self.semantics not in ("sync", "local", "async"):
            bad(f"unknown semantics {self.semantics!r} (sync|local|async)")
        if self.backend not in ("simulated", "processes"):
            bad(f"unknown backend {self.backend!r} (simulated|processes)")
        # -- real-network process backend ----------------------------------
        if self.backend == "processes":
            if self.shard_devices > 0:
                bad("backend='processes' shards nodes over OS processes; "
                    "shard_devices is the simulated backend's device mesh — "
                    "drop one of the two")
            if self.semantics != "sync":
                bad(f"backend='processes' implements the synchronous round "
                    f"barrier only for now (got semantics={self.semantics!r});"
                    " use the simulated backend for local/async semantics")
            if self.secure:
                bad("backend='processes' does not run secure aggregation "
                    "over the socket transport yet; set secure=False or use "
                    "the simulated backend")
            if self.faults is not None:
                bad("FaultPlan injects faults into the *simulated* step; the "
                    "processes backend takes real faults (kill a worker, see "
                    "examples/processes.py) — drop the FaultPlan")
            if self.participation < 1.0 or self.churn_machines > 0:
                bad("simulated churn masks (participation/churn_machines) "
                    "don't apply to real processes; model churn by killing "
                    "workers instead")
            if self.cohort_capacity > 0 or self.batch_keying != "stream":
                bad("cohort_capacity/batch_keying='node' are async "
                    "population-scale knobs of the simulated backend")
            if self.topology in ("fully", "star") or self.mixing == "dense":
                bad("processes workers gossip over sparse neighbor tables; "
                    "fully|star topologies / mixing='dense' have no bounded "
                    "per-peer send set — use a sparse overlay")
            if self.topology == "dynamic":
                bad("backend='processes' needs a static graph to derive "
                    "its per-peer send/receive sets; topology='dynamic' "
                    "re-draws them every round")
            if self.sharing.lower() not in ("full", "randomk", "random"):
                bad(f"backend='processes' serializes sharing='full' rows or "
                    f"sharing='randomk' (idx, val) payloads on the wire; "
                    f"{self.sharing!r} is stateful/unsupported there — use "
                    "the simulated backend")
            if self.randk_sampler != "uniform":
                bad("backend='processes' wires the uniform randomk payload "
                    "only (strided phases are a simulated fast path)")
        if self.async_gossip not in ("neighborhood", "pairwise"):
            bad(f"unknown async_gossip {self.async_gossip!r} "
                "(neighborhood|pairwise)")
        if self.payload not in ("auto", "on", "off"):
            bad(f"unknown payload mode {self.payload!r} (auto|on|off)")
        if self.mixing not in ("auto", "sparse", "dense"):
            bad(f"unknown mixing mode {self.mixing!r} (auto|sparse|dense)")
        if self.shard_backend not in ("auto", "ppermute", "gather"):
            bad(f"unknown shard_backend {self.shard_backend!r} "
                "(auto|ppermute|gather)")
        if self.randk_sampler not in ("uniform", "strided"):
            bad(f"unknown randk_sampler {self.randk_sampler!r} "
                "(uniform|strided)")
        if not 0.0 < self.participation <= 1.0:
            bad(f"participation must be in (0, 1], got {self.participation}")
        if self.churn_machines < 0:
            bad("churn_machines must be >= 0")
        if not 0.0 <= self.straggler_frac <= 1.0:
            bad(f"straggler_frac must be in [0, 1], got {self.straggler_frac}")
        if self.straggler_factor <= 0:
            bad("straggler_factor must be > 0")
        if self.compute_time_s < 0 or self.async_slice_s < 0:
            bad("compute_time_s / async_slice_s must be >= 0")
        if (
            self.straggler_frac > 0
            and self.straggler_factor != 1.0
            and self.compute_time_s == 0
        ):
            bad("straggler_factor/straggler_frac scale compute_time_s, "
                "which is 0 — the straggler distribution would be a silent "
                "no-op; set a base compute_time_s")
        if self.compute_spread < 0:
            bad(f"compute_spread must be >= 0, got {self.compute_spread}")
        if self.compute_spread > 0 and self.compute_time_s == 0:
            bad("compute_spread scales compute_time_s, which is 0 — the "
                "spread would be a silent no-op; set a base compute_time_s")
        # (churn_machines with participation=1.0 is permitted: sweeps use
        # p=1.0 as the no-churn baseline row)
        # -- sharing-strategy knob compatibility ---------------------------
        sparsified = sharing_lib.strategy_takes_budget(self.sharing)
        if self.secure:
            if self.topology == "dynamic":
                bad("secure=True needs a static graph (the pairwise-mask "
                    "PRF schedule is per-edge); topology='dynamic' has none")
            crashes = self.faults is not None and bool(self.faults.crashes)
            if (
                self.participation < 1.0 or self.churn_machines > 0 or crashes
            ) and not self.secure_recovery:
                bad("secure=True under churn (participation < 1, "
                    "churn_machines > 0, or FaultPlan crash schedules) "
                    "needs secure_recovery=True: without the Bonawitz "
                    "seed-recovery pass a dropped node's pairwise masks "
                    "would not cancel")
            if self.payload == "on" or self.payload_quant or self.randk_sampler != "uniform":
                bad("payload/payload_quant/randk_sampler do not compose "
                    "with secure=True (masked messages are full fp32 "
                    "vectors; compressing them would break mask "
                    "cancellation)")
        else:
            if self.payload == "on" and not sparsified:
                bad(f"payload='on' needs a sparsified sharing strategy "
                    f"(randomk/topk/choco), not {self.sharing!r}")
            if self.payload_quant and not sparsified:
                bad("payload_quant applies to payload-emitting strategies "
                    "(randomk/topk/choco); use sharing='quant' for "
                    "quantized full sharing")
            if self.randk_sampler != "uniform" and self.sharing.lower() not in (
                "randomk", "random"
            ):
                bad("randk_sampler applies to sharing='randomk' only")
        # -- fault injection -------------------------------------------------
        if self.secure_recovery and not self.secure:
            bad("secure_recovery=True is the seed-recovery pass of secure "
                "aggregation; it needs secure=True")
        if self.faults is not None:
            self.faults.validate()
            for node, _, _ in self.faults.crashes:
                if node >= self.n_nodes:
                    bad(f"FaultPlan crash node {node} out of range for "
                        f"n_nodes={self.n_nodes}")
            if self.chunk_rounds <= 0:
                bad("faults run on the scanned chunk path only "
                    "(chunk_rounds > 0); the legacy per-round dispatch "
                    "predates the fault axis")
            if self.shard_devices > 0:
                bad("faults are single-host for now (per-edge draws and "
                    "the rollback guard are not distributed); drop "
                    "shard_devices or the FaultPlan")
            if self.cohort_capacity > 0:
                bad("faults do not compose with cohort_capacity yet (the "
                    "gather/scatter cohort body has no fault hooks); use "
                    "the dense async path")
            if self.secure and self.faults.msg_loss > 0:
                bad("secure=True with FaultPlan.msg_loss > 0 is not "
                    "modeled: per-edge loss would need per-edge mask "
                    "recovery (secure_recovery covers node-level churn "
                    "and crashes; latency spikes and corruption compose)")
        # -- multi-device constraints --------------------------------------
        if self.shard_devices > 0:
            if self.chunk_rounds <= 0:
                bad("shard_devices requires the scanned chunk path "
                    "(chunk_rounds > 0); the legacy per-round dispatch is "
                    "single-device only")
            if self.n_nodes % self.shard_devices:
                bad(f"n_nodes={self.n_nodes} must divide evenly over "
                    f"shard_devices={self.shard_devices}")
        # -- execution-semantics constraints -------------------------------
        if self.semantics != "sync":
            if self.chunk_rounds <= 0:
                bad(f"semantics={self.semantics!r} runs on the scanned "
                    "chunk path only (chunk_rounds > 0); the legacy "
                    "per-round dispatch is synchronous by construction")
            if self.shard_devices > 0:
                bad(f"semantics={self.semantics!r} is single-host for now "
                    "(the virtual clock is not yet distributed); use "
                    "semantics='sync' with shard_devices")
        if self.semantics == "async":
            if self.secure:
                bad("semantics='async' rejects secure=True until masked "
                    "asynchronous rounds are modeled (pairwise masks "
                    "assume all co-neighbors mix in the same round)")
            if not sharing_lib.is_full_sharing(self.sharing):
                bad("semantics='async' models one-sided stale reads for "
                    f"sharing='full' only (got {self.sharing!r}); "
                    "compressed/stateful strategies assume a synchronous "
                    "exchange")
            if self.async_gossip == "pairwise" and (
                self.mixing == "dense" or self.topology in ("fully", "star")
            ):
                bad("async_gossip='pairwise' samples partners from sparse "
                    "neighbor tables; use async_gossip='neighborhood' for "
                    "dense mixing / fully|star topologies")
        # -- population-scale cohort activation -----------------------------
        if self.batch_keying not in ("stream", "node"):
            bad(f"unknown batch_keying {self.batch_keying!r} (stream|node)")
        if self.batch_keying == "node":
            if self.chunk_rounds <= 0:
                bad("batch_keying='node' derives indices inside the scanned "
                    "chunk (chunk_rounds > 0); the legacy per-round dispatch "
                    "stages host batches")
            if self.shard_devices > 0:
                bad("batch_keying='node' is single-host for now; the "
                    "shard_map chunk stages 'stream' batches per shard")
        if self.cohort_capacity < 0:
            bad(f"cohort_capacity must be >= 0, got {self.cohort_capacity}")
        if self.cohort_capacity > 0:
            if self.semantics != "async":
                bad("cohort_capacity is the async cohort gather/scatter "
                    f"path; set semantics='async' (got {self.semantics!r})")
            if self.cohort_capacity > self.n_nodes:
                bad(f"cohort_capacity={self.cohort_capacity} exceeds "
                    f"n_nodes={self.n_nodes}")
            if self.mixing == "dense" or self.topology in ("fully", "star"):
                bad("cohort_capacity gathers neighbor rows from sparse "
                    "(N, D) tables; dense mixing / fully|star topologies "
                    "have no bounded neighbor set to gather")
            if self.batch_keying != "node":
                bad("cohort_capacity requires batch_keying='node': host "
                    "staging of (R, L, N, B) sample indices is O(N·B) per "
                    "step — the population-scale cost the cohort path "
                    "exists to remove")
        if self.selection not in ("auto", "flat", "hier"):
            bad(f"unknown selection {self.selection!r} (auto|flat|hier)")
        if self.segment_size < 0:
            bad(f"segment_size must be >= 0, got {self.segment_size}")
        if self.cold_dtype not in ("fp32", "bf16", "int8"):
            bad(f"unknown cold_dtype {self.cold_dtype!r} (fp32|bf16|int8)")
        if self.cohort_capacity == 0:
            if self.selection == "hier" or self.segment_size > 0:
                bad("selection='hier'/segment_size tune the cohort "
                    "selection layer; set cohort_capacity > 0")
            if self.cold_dtype != "fp32":
                bad("cold_dtype compresses the cohort path's cold "
                    "population state; set cohort_capacity > 0")
        return self


def build_graph(cfg: DLConfig) -> Optional[Graph]:
    t = cfg.topology
    if t == "ring":
        return Graph.ring(cfg.n_nodes)
    if t == "regular":
        return Graph.regular_circulant(cfg.n_nodes, cfg.degree)
    if t == "random-regular":
        return Graph.random_regular(cfg.n_nodes, cfg.degree, cfg.seed)
    if t == "fully":
        return Graph.fully_connected(cfg.n_nodes)
    if t == "star":
        return Graph.star(cfg.n_nodes)
    if t == "dynamic":
        return None  # per-round via PeerSampler
    if t.startswith("file:"):
        return Graph.from_edge_list(t[5:], cfg.n_nodes)
    raise ValueError(f"unknown topology {t!r}")


def compute_time_vector(cfg: DLConfig) -> np.ndarray:
    """THE per-node (N,) compute-time vector of a config — the single
    derivation (including the straggler draw's seed offset) shared by the
    host ``NetworkModel`` and the engine's traced step/scheduler layers,
    so the two cannot disagree about who the stragglers are."""
    ct = straggler_compute_times(
        cfg.n_nodes, cfg.compute_time_s, cfg.straggler_factor,
        cfg.straggler_frac, seed=cfg.seed + 31,
    )
    if cfg.compute_spread > 0:
        # continuous multiplier on top of the (possibly bimodal) straggler
        # draw — distinct seed stream so toggling stragglers does not
        # reshuffle the spread
        rng = np.random.default_rng(cfg.seed + 47)
        ct = (ct * (1.0 + cfg.compute_spread
                    * rng.random(cfg.n_nodes, dtype=np.float32))
              ).astype(np.float32)
    return ct


def build_network(cfg: DLConfig) -> Optional[NetworkModel]:
    if cfg.network in (None, "", "none"):
        return None
    if cfg.network == "lan":
        net = paper_testbed(cfg.n_nodes)
    elif cfg.network == "wan":
        net = wan_deployment(cfg.n_nodes)
    else:
        raise ValueError(f"unknown network model {cfg.network!r} (none|lan|wan)")
    # promote the config's (possibly heterogeneous) compute times into the
    # model, so the host-side NetworkModel and the traced engine agree
    net.compute_time_s = compute_time_vector(cfg)
    return net


class RoundEngine:
    """Emulates N DL nodes with node-stacked state and scanned rounds.

    loss_fn(params, batch_x, batch_y) -> scalar    (single node)
    acc_fn(params, batch_x, batch_y) -> scalar     (single node)
    heterogeneous_lrs: optional (N,) per-node learning-rate multipliers
    applied to each node's optimizer updates (system heterogeneity axis).
    """

    def __init__(
        self,
        dl: DLConfig,
        init_params_fn: Callable[[jax.Array], Any],
        loss_fn: Callable,
        acc_fn: Callable,
        optimizer: Optimizer,
        batcher,
        heterogeneous_lrs: Optional[np.ndarray] = None,
    ):
        dl.validate()
        if dl.backend == "processes":
            raise ValueError(
                "RoundEngine is the simulated backend; backend='processes' "
                "runs K real OS processes — construct "
                "repro.runtime.ProcessRunner(dl, workload) directly, or pass "
                "workload= to DecentralizedRunner and it will dispatch"
            )
        self.dl = dl
        self.loss_fn = loss_fn
        self.acc_fn = acc_fn
        self.opt = optimizer
        self.batcher = batcher
        if heterogeneous_lrs is not None:
            lrs = np.asarray(heterogeneous_lrs, np.float32)
            assert lrs.shape == (dl.n_nodes,), "heterogeneous_lrs must be (n_nodes,)"
            self.lr_scales = jnp.asarray(lrs)
        else:
            self.lr_scales = None
        key = jax.random.key(dl.seed)
        keys = jax.random.split(key, dl.n_nodes)
        # fully-decentralized: every node initializes its *own* model
        self.params = jax.vmap(init_params_fn)(keys)
        self.opt_state = jax.vmap(self.opt.init)(self.params)
        self.template = jax.tree_util.tree_map(lambda a: a[0], self.params)
        # population scale: circulant overlays above the dense-graph cap go
        # straight to (N, d) tables — no (N, N) adjacency is ever built
        self._circulant_direct = (
            dl.topology in ("ring", "regular")
            and dl.n_nodes > _DENSE_GRAPH_MAX_N
            and not dl.secure
            and dl.mixing != "dense"
        )
        self.graph = None if self._circulant_direct else build_graph(dl)
        self.sampler = PeerSampler(dl.n_nodes, dl.degree, dl.seed) if dl.topology == "dynamic" else None
        if dl.secure:
            assert self.graph is not None, "secure aggregation needs a static graph"
            self.sharing = SecureAggregation(
                self.graph.adj, recovery=dl.secure_recovery
            )
        else:
            sparsified = sharing_lib.strategy_takes_budget(dl.sharing)
            kw = {"gamma": dl.choco_gamma} if dl.sharing.startswith("choco") else {}
            if sparsified:
                kw["budget"] = dl.budget
                kw["payload"] = dl.payload != "off"
                if dl.payload_quant:
                    kw["quantize"] = "int8"
                if dl.sharing.lower() in ("randomk", "random"):
                    kw["sampler"] = dl.randk_sampler
            self.sharing = sharing_lib.make_sharing(dl.sharing, **kw)
        X0 = jax.vmap(tree_vector)(self.params)
        self.share_state = self.sharing.init_state(X0)
        self.n_params = int(X0.shape[1])
        # per-round wire format metrics: the dtype values ship in, and the
        # bytes of message tensors the sharing stage materializes per round
        # ((idx, val) payloads vs scattered (N, P) mask matrices)
        self.wire_dtype = str(np.dtype(self.sharing.wire_dtype(X0.dtype)))
        self.share_stage_bytes = int(
            self.sharing.stage_bytes_per_round(dl.n_nodes, self.n_params)
        )
        self.mix_mode = self._resolve_mix_mode()
        if (
            dl.semantics == "async"
            and dl.async_gossip == "pairwise"
            and self.mix_mode != "sparse"
        ):
            raise ValueError(
                "async_gossip='pairwise' needs sparse neighbor tables; this "
                "topology resolved to dense mixing — use "
                "async_gossip='neighborhood'"
            )
        if dl.cohort_capacity > 0 and self.mix_mode != "sparse":
            raise ValueError(
                "cohort_capacity gathers neighbor rows from sparse (N, D) "
                "tables; this topology resolved to dense mixing — drop "
                "cohort_capacity or use a sparse overlay"
            )
        # --- node-axis sharding (multi-device execution) -------------------
        self.sharded = dl.shard_devices > 0
        self._shard: Optional[NodeShard] = None
        self._perm_sched: Optional[PermuteSchedule] = None
        if self.sharded:
            from repro.launch.mesh import make_node_mesh

            self._mesh = make_node_mesh(dl.shard_devices)
            self._shard = NodeShard(
                "nodes", (dl.shard_devices,), dl.n_nodes // dl.shard_devices
            )
            self._shard_backend = self._resolve_shard_backend()
        # peak host->device bytes staged per chunk (or once, if static) for
        # the mixing topology — O(N·d) sparse vs 4·N² dense; the perf gate
        # benchmarks record it
        self.topo_stage_bytes_peak = 0
        if self.graph is not None:
            self._mean_degree = float(self.graph.degrees().mean())
            # static topology: the mixing operand is a captured device
            # constant of the scan, not a per-chunk host transfer
            if self.mix_mode == "sparse":
                # never materialize the (N, N) W on the sparse path
                st = SparseTopology.from_graph(self.graph)
                if self.sharded and self._shard_backend == "ppermute":
                    # slot-rebalance the table so each column is a
                    # permutation lowering to collective_permutes
                    dec = decompose_slot_permutations(st)
                    if dec is None:
                        raise ValueError(
                            "topology does not decompose into per-slot "
                            "permutations; use shard_backend='gather'"
                        )
                    st = dec
                    self._perm_sched = PermuteSchedule.from_table(
                        dec.nbr, dl.shard_devices
                    )
                self._mix_static = SparseTopology(
                    jnp.asarray(st.nbr), jnp.asarray(st.w), jnp.asarray(st.w_self)
                )
                self.topo_stage_bytes_peak = st.stage_bytes()
            else:
                W_np = self.graph.metropolis_hastings().astype(np.float32)
                self._mix_static = jnp.asarray(W_np)
                self.topo_stage_bytes_peak = int(W_np.nbytes)
        elif self._circulant_direct:
            if self.sharded and self._shard_backend == "ppermute":
                raise ValueError(
                    "shard_backend='ppermute' builds its slot schedule from "
                    f"the dense graph, capped at n_nodes={_DENSE_GRAPH_MAX_N}; "
                    "use shard_backend='gather' at population scale"
                )
            deg = 2 if dl.topology == "ring" else dl.degree
            st = SparseTopology.regular_circulant(dl.n_nodes, deg)
            self._mean_degree = float(st.dmax)  # circulants are regular
            self._mix_static = SparseTopology(
                jnp.asarray(st.nbr), jnp.asarray(st.w), jnp.asarray(st.w_self)
            )
            self.topo_stage_bytes_peak = st.stage_bytes()
        else:
            self._mix_static = None
            self._mean_degree = float(dl.degree)  # PeerSampler is d-regular
        self.network_model = build_network(dl)
        if self.network_model is not None:
            lat, gp = self.network_model.matrices()
            self._lat = jnp.asarray(lat)
            self._goodput = jnp.asarray(gp)
        else:
            self._lat = self._goodput = None
        # heterogeneous per-node compute times — the (N,) vector both the
        # traced round-time formula and the async event clock consume;
        # reuse the network model's copy so both sides see one derivation
        self._compute_node_np = (
            self.network_model.compute_time_s
            if self.network_model is not None
            else compute_time_vector(dl)
        )
        self._compute_node = jnp.asarray(self._compute_node_np)
        # device-resident dataset for in-scan batch gathers
        self._dev_x = jnp.asarray(batcher.x)
        self._dev_y = jnp.asarray(batcher.y)
        self._base_key = jax.random.key(dl.seed + 17)
        if dl.batch_keying == "node":
            # per-(round, node) keyed sampling: partition tables live on
            # device; the batch key is folded off the engine stream so
            # batch draws never collide with sharing/gossip draws
            self._dev_lens, self._dev_parts_pad = batcher.device_tables()
            self._batch_key = jax.random.fold_in(self._base_key, 0x0BA7)
        else:
            self._dev_lens = self._dev_parts_pad = self._batch_key = None
        n = dl.n_nodes
        if dl.chunk_rounds <= 0:
            self.chunk = 0
        elif self.sampler is not None and self.mix_mode == "dense":
            # dense dynamic topologies stage an (R, N, N) W stack per chunk;
            # bound it.  (The sparse path stages (R, N, D) — no cap needed,
            # chunks stay full-length at N=1024+.)
            self.chunk = max(1, min(dl.chunk_rounds, _W_STACK_BYTES_CAP // (4 * n * n)))
        else:
            self.chunk = dl.chunk_rounds
        # --- the two execution layers --------------------------------------
        self._fault_key = (
            faults_lib.fault_key(dl.faults, dl.seed)
            if dl.faults is not None else None
        )
        self.steps = RoundSteps(
            loss_fn=loss_fn,
            opt=optimizer,
            sharing=self.sharing,
            template=self.template,
            base_key=self._base_key,
            mean_degree=self._mean_degree,
            compute_node=self._compute_node,
            parallel_sends=dl.parallel_sends,
            lr_scales=self.lr_scales,
            lat=self._lat,
            goodput=self._goodput,
            faults=dl.faults,
            fault_key=self._fault_key,
        )
        self.scheduler = make_scheduler(self)
        self.history: List[Dict] = []
        self.bytes_sent = 0.0
        self.sim_time_s = 0.0
        # crash-resume cursor: load_state() advances it so run() continues
        # from the checkpointed round instead of round 0
        self._start_round = 0
        self.rounds_done = 0
        self._eval_jit = jax.jit(self._eval)

    def _resolve_shard_backend(self) -> str:
        """Distributed gossip lowering: 'ppermute' decomposes the static
        neighbor table into per-slot permutations, each applied as
        rotation-grouped `collective_permute`s (O(D·B·P) wire — the mesh-
        native path); 'gather' all-gathers the node axis and reuses the
        single-device neighbor gather (any table, incl. per-round dynamic
        ones whose schedule cannot be static).  'auto' picks ppermute on
        TPU interconnects and gather on CPU emulation, where host-emulated
        collectives cost more than the bytes they save."""
        b = self.dl.shard_backend
        static_sparse = self.sampler is None and self.mix_mode == "sparse"
        if b == "ppermute":
            if not static_sparse:
                raise ValueError(
                    "shard_backend='ppermute' needs a static sparse "
                    "topology (dynamic tables have no static schedule; "
                    "dense mixing all-gathers by construction)"
                )
            return b
        if b == "auto" and static_sparse and jax.default_backend() == "tpu":
            return "ppermute"
        return "gather"

    def _resolve_mix_mode(self) -> str:
        """'sparse' (neighbor-indexed O(N·d·P) gossip) for sparse overlays,
        'dense' (W @ X) where the graph is effectively complete."""
        m = self.dl.mixing
        if m != "auto":
            return m
        if self.dl.topology in ("fully", "star"):
            return "dense"  # D ~ N: padded tables would be the dense matrix
        if self.graph is not None and int(self.graph.degrees().max()) >= self.dl.n_nodes - 1:
            return "dense"
        return "sparse"

    # ------------------------------------------------------------------
    # back-compat shims (tests and external callers poke these)
    # ------------------------------------------------------------------
    def _participation_mask(self, start: int, n_rounds: int) -> np.ndarray:
        return self.scheduler.participation_mask(start, n_rounds)

    def _eval(self, params, tx, ty):
        return jax.vmap(lambda p: self.acc_fn(p, tx, ty))(params)

    # ------------------------------------------------------------------
    def _record(self, rnd: int, tx, ty, t0: float, log: bool):
        # eval through the scheduler hook: the quantized-cold async path
        # stores self.params compressed and decodes them here
        accs = np.asarray(self._eval_jit(self.scheduler.eval_params(), tx, ty))
        rec = {
            "round": rnd,
            "acc_mean": float(accs.mean()),
            "acc_std": float(accs.std()),
            "bytes_per_node": self.bytes_sent,
            "wall_s": time.time() - t0,
            "sim_time_s": self.sim_time_s,
            "wire_dtype": self.wire_dtype,
        }
        rec.update(self.scheduler.extra_metrics())
        self.history.append(rec)
        if log:
            print(
                f"[{self.dl.topology}/{type(self.sharing).__name__}] round {rnd:4d} "
                f"acc {rec['acc_mean']:.4f}±{rec['acc_std']:.4f} "
                f"MB/node {self.bytes_sent / 1e6:.1f}"
                + (f" sim {self.sim_time_s:.1f}s" if self.network_model else "")
            )

    def run(self, rounds: Optional[int] = None, log: bool = True) -> List[Dict]:
        """Execute ``rounds`` scheduler steps (synchronous rounds, or event
        cohorts under ``semantics='async'``) with evals every
        ``eval_every``."""
        dl = self.dl
        rounds = rounds if rounds is not None else dl.rounds
        tx, ty = self.batcher.test_batch()
        tx, ty = jnp.asarray(tx), jnp.asarray(ty)
        ev = max(dl.eval_every, 1)
        t0 = time.time()
        if self.chunk == 0:  # legacy per-round dispatch (sync only)
            for rnd in range(self._start_round, rounds):
                self.scheduler.run_legacy_round(rnd)
                if rnd % ev == 0 or rnd == rounds - 1:
                    self._record(rnd, tx, ty, t0, log)
        else:
            rnd = self._start_round
            while rnd < rounds:
                nxt = -(-rnd // ev) * ev  # next eval round >= rnd
                if nxt >= rounds:
                    nxt = rounds - 1
                end = nxt + 1
                while rnd < end:
                    r = min(self.chunk, end - rnd)
                    self.scheduler.run_span(rnd, r)
                    rnd += r
                self._record(nxt, tx, ty, t0, log)
        self.rounds_done = max(rounds, self._start_round)
        self._dump_results()
        return self.history

    # ------------------------------------------------------------------
    # crash-resume: checkpoint/ integration.  Batches are keyed by absolute
    # round and gossip/sharing draws by fold_in(base_key, rnd), so a
    # restarted process that restores (params, opt_state, share_state) and
    # continues from the saved round reproduces the uninterrupted
    # trajectory exactly (test_resume.py pins this across a real process
    # restart).
    # ------------------------------------------------------------------
    def save_state(self, path: str, step: Optional[int] = None) -> str:
        """Checkpoint the node-stacked engine state plus the round cursor
        into ``path`` (directory).  Returns the checkpoint file path."""
        if self.dl.semantics != "sync":
            raise ValueError(
                "save_state captures the synchronous barrier state only; "
                "the local/async virtual clocks are not checkpointed yet"
            )
        from repro.checkpoint import save_checkpoint

        step = self.rounds_done if step is None else step
        return save_checkpoint(
            path, step, params=self.params, opt_state=self.opt_state,
            share_state=self.share_state,
        )

    def load_state(self, path: str, step: Optional[int] = None) -> int:
        """Restore a ``save_state`` checkpoint (latest in ``path`` unless
        ``step`` names one) and position ``run()`` to continue from it."""
        from repro.checkpoint import load_checkpoint, restore_tree

        step, trees = load_checkpoint(path, step)
        self.params = restore_tree(self.params, trees.get("params"))
        self.opt_state = restore_tree(self.opt_state, trees.get("opt_state"))
        self.share_state = restore_tree(
            self.share_state, trees.get("share_state")
        )
        self._start_round = self.rounds_done = int(step)
        return int(step)

    def _dump_results(self):
        """Per-node JSON results, DecentralizePy-style (aggregated later)."""
        if not self.dl.results_dir:
            return
        os.makedirs(self.dl.results_dir, exist_ok=True)
        with open(os.path.join(self.dl.results_dir, "results.json"), "w") as f:
            json.dump({"config": dataclasses.asdict(self.dl), "history": self.history}, f, indent=1)
