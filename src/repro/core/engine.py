"""RoundEngine — the compiled multi-round execution core of the DL
emulator (paper Fig. 2 loop, compiled R rounds at a time).

## Execution model

The engine executes rounds in **chunks of R rounds compiled into a single
``lax.scan``** instead of one host-driven jit dispatch per round:

* **Batches are pre-stacked on device.**  The full (synthetic) dataset is
  resident on the device; the host only produces a tiny ``(R, L, N, B)``
  int32 index tensor per chunk (``NodeBatcher.chunk_indices``) and each
  scanned round gathers its batch with one ``take``.  No per-round
  host->device batch transfer, no per-round ``np.stack``.
* **Mixing topologies are traced scan inputs — sparse by default.**  For
  sparse overlays (ring, d-regular, the paper's dynamic 5-regular: d ≪ N)
  the round program mixes in neighbor-indexed form: a ``SparseTopology``
  of padded (N, D) neighbor + weight tables, gathered and contracted in
  O(N·D·P) instead of the dense O(N²·P) ``W @ X``.  Dynamic topologies
  stage an (R, N, D) per-chunk table stack (``PeerSampler.sparse_stack``,
  O(N·d) per round) instead of the (R, N, N) ``weights_stack``, so chunk
  length no longer shrinks under the W-stack byte cap at N=1024+.  The
  dense path survives behind ``mixing="dense"`` — the right lowering for
  ``fully``/``star`` (D ≈ N) and the equivalence oracle the sparse path is
  property-tested against; ``mixing="auto"`` (default) picks per topology.
  Either way the per-round mixing operand is a traced scan input, so
  dynamic topologies never recompile, and the mean degree used for byte
  accounting is a traced per-round scalar.
* **Metrics are traced per-round outputs.**  Bytes-sent and (when a
  ``NetworkModel`` is configured) the simulated synchronous-round
  wall-clock are collected by the scan as ``(R,)`` arrays and synced to the
  host once per chunk, not once per round.
* **Sparsified sharing runs in payload form.**  With ``payload`` on
  (default for randomk/topk/choco), strategies emit compact per-node
  ``(idx, val)`` payloads inside the scanned round and aggregate them via
  ``mixing.mix_payload``'s gather + scatter-accumulate pass — O(N·d·k)
  instead of the dense-mask form's two O(N·d·P) ``apply_W`` passes; in the
  sharded chunk the ppermute backend then exchanges (B, k) payloads
  (O(D·B·k) wire).  ``payload="off"`` forces the dense-mask oracle, kept
  property-tested equal; byte accounting and the ``wire_dtype`` /
  ``share_stage_bytes`` metrics derive from the actual wire dtype.
* **Secure aggregation runs inside the scan.**  ``core/secure.py``'s
  vectorized masked-mixing path is jittable (padded neighbor tables +
  traced round index for the PRF), so ``secure=True`` uses the same scanned
  loop as every other sharing strategy.
* **Participation masks (churn / stragglers).**  An ``(R, N)`` per-round
  activity mask is threaded through the scan; down nodes skip their local
  update and are cut out of the mixing operand on the fly
  (``sharing.participation_reweight`` dense, ``participation_reweight_sparse``
  for neighbor tables — slot masking, freed mass back to the diagonal),
  with byte accounting following the effective degree.  Masks come from a
  single batched counter-based draw per chunk (splitmix64 over (seed,
  absolute round, node)), so they are chunk-boundary invariant without a
  per-round ``default_rng`` host loop.

* **The chunk shards over a device mesh.**  With ``shard_devices=K`` the
  same scanned chunk runs under ``shard_map`` on a 1-D node mesh
  (``launch.mesh.make_node_mesh``): every node-stacked carry and scan
  input — params stack, optimizer state, sharing state, per-chunk batches,
  participation masks, mixing tables — is row-block sharded over the node
  axis (B = N/K rows per device), local training stays embarrassingly
  parallel, and only the gossip crosses devices.  Two distributed gossip
  lowerings (``shard_backend``): ``'ppermute'`` slot-rebalances a static
  ``SparseTopology`` into D permutation columns
  (``topology.decompose_slot_permutations``) and applies each as
  rotation-grouped `collective_permute`s — O(D·B·P) wire, the
  interconnect-native path, generalizing the circulant shard_map mixer to
  arbitrary sparse graphs; ``'gather'`` all-gathers the node axis and
  reuses the single-device neighbor gather (any table, incl. per-round
  dynamic stacks).  Per-round scalar metrics (effective degree, bytes,
  simulated round time) are psum/pmax-reduced so every device carries the
  same global values, per-node PRNG draws are keyed by global node id
  (``sharing._node_keys``) so sharded trajectories reproduce the
  single-device ones (bit-identical on the gather path; within fp32
  reassociation tolerance where slot rebalancing reorders per-receiver
  sums), and secure aggregation exchanges its masked messages along the
  same permutations.  Testable on CPU via
  ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
  (tests/test_sharded_engine.py).

Chunk boundaries are aligned to the eval cadence, so the recorded history
is identical to per-round execution; distinct chunk lengths (full chunks
vs the remainder before an eval round) each compile once and are cached.
``chunk_rounds=0`` selects the legacy per-round dispatch path (host-stacked
batches, one jit call and one host sync per round) — kept as the baseline
``benchmarks/bench_engine.py`` measures against.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import sharing as sharing_lib
from repro.core.mixing import (
    NodeShard,
    PermuteSchedule,
    ShardedDense,
    ShardedTopology,
)
from repro.core.network import NetworkModel, paper_testbed, wan_deployment
from repro.core.secure import SecureAggregation
from repro.core.sharing import participation_reweight, participation_reweight_sparse
from repro.core.topology import (
    Graph,
    PeerSampler,
    SparseTopology,
    decompose_slot_permutations,
)
from repro.optim import Optimizer
from repro.optim.optimizers import apply_updates
from repro.utils.compat import shard_map
from repro.utils.pytree import tree_unvector, tree_vector

# cap on the (R, N, N) mixing-matrix stack a single *dense-path* chunk
# materializes; dense chunks shrink automatically at very large N.  The
# sparse path stages O(N·d) tables per round and is exempt.
_W_STACK_BYTES_CAP = 64 * 1024 * 1024
# cap on the pre-gathered (R, L, N, B, ...) batch stack; above it the scan
# falls back to gathering each round's batch inside the loop body.
_BATCH_STACK_BYTES_CAP = 256 * 1024 * 1024


@dataclasses.dataclass
class DLConfig:
    """Experiment specification (paper Fig. 1 'specifications' input)."""

    n_nodes: int = 16
    topology: str = "regular"  # ring | regular | fully | star | dynamic | file:<path>
    degree: int = 5
    sharing: str = "full"      # full | randomk | topk | choco | quant
    budget: float = 0.1        # sparsification budget
    choco_gamma: float = 0.3
    # payload wire format for sparsified strategies: 'on' emits compact
    # (idx, val) per-node payloads aggregated in one O(N·d·k) gather +
    # scatter pass (mixing.mix_payload); 'off' runs the dense-mask oracle
    # (scattered (N, P) masks + two apply_W passes — the legacy form, kept
    # property-tested equal); 'auto' = on for randomk/topk/choco.
    payload: str = "auto"      # auto | on | off
    payload_quant: bool = False  # int8-quantize payload values on the wire
    randk_sampler: str = "uniform"  # randomk coord sampler: uniform | strided
    secure: bool = False       # secure aggregation (masked full sharing)
    local_steps: int = 1
    batch_size: int = 8
    rounds: int = 100
    eval_every: int = 10
    seed: int = 0
    results_dir: Optional[str] = None
    # --- engine (scan) execution ------------------------------------------
    chunk_rounds: int = 8      # rounds per compiled lax.scan chunk; 0 = legacy
    mixing: str = "auto"       # auto | sparse (neighbor tables) | dense (N,N W)
    # --- multi-device execution -------------------------------------------
    shard_devices: int = 0     # shard the node axis over this many devices
    shard_backend: str = "auto"  # auto | ppermute (slot collective_permutes) | gather
    # --- scenario axes -----------------------------------------------------
    participation: float = 1.0  # P(node active in a round); <1 models churn
    network: str = "none"       # simulated network: none | lan | wan
    compute_time_s: float = 0.0  # per-round local compute in the time model
    parallel_sends: bool = False  # overlap a node's sends (dedicated NICs)


def build_graph(cfg: DLConfig) -> Optional[Graph]:
    t = cfg.topology
    if t == "ring":
        return Graph.ring(cfg.n_nodes)
    if t == "regular":
        return Graph.regular_circulant(cfg.n_nodes, cfg.degree)
    if t == "random-regular":
        return Graph.random_regular(cfg.n_nodes, cfg.degree, cfg.seed)
    if t == "fully":
        return Graph.fully_connected(cfg.n_nodes)
    if t == "star":
        return Graph.star(cfg.n_nodes)
    if t == "dynamic":
        return None  # per-round via PeerSampler
    if t.startswith("file:"):
        return Graph.from_edge_list(t[5:], cfg.n_nodes)
    raise ValueError(f"unknown topology {t!r}")


def build_network(cfg: DLConfig) -> Optional[NetworkModel]:
    if cfg.network in (None, "", "none"):
        return None
    if cfg.network == "lan":
        return paper_testbed(cfg.n_nodes)
    if cfg.network == "wan":
        return wan_deployment(cfg.n_nodes)
    raise ValueError(f"unknown network model {cfg.network!r} (none|lan|wan)")


class RoundEngine:
    """Emulates N DL nodes with node-stacked state and scanned rounds.

    loss_fn(params, batch_x, batch_y) -> scalar    (single node)
    acc_fn(params, batch_x, batch_y) -> scalar     (single node)
    heterogeneous_lrs: optional (N,) per-node learning-rate multipliers
    applied to each node's optimizer updates (system heterogeneity axis).
    """

    def __init__(
        self,
        dl: DLConfig,
        init_params_fn: Callable[[jax.Array], Any],
        loss_fn: Callable,
        acc_fn: Callable,
        optimizer: Optimizer,
        batcher,
        heterogeneous_lrs: Optional[np.ndarray] = None,
    ):
        self.dl = dl
        self.loss_fn = loss_fn
        self.acc_fn = acc_fn
        self.opt = optimizer
        self.batcher = batcher
        if heterogeneous_lrs is not None:
            lrs = np.asarray(heterogeneous_lrs, np.float32)
            assert lrs.shape == (dl.n_nodes,), "heterogeneous_lrs must be (n_nodes,)"
            self.lr_scales = jnp.asarray(lrs)
        else:
            self.lr_scales = None
        key = jax.random.key(dl.seed)
        keys = jax.random.split(key, dl.n_nodes)
        # fully-decentralized: every node initializes its *own* model
        self.params = jax.vmap(init_params_fn)(keys)
        self.opt_state = jax.vmap(self.opt.init)(self.params)
        self.template = jax.tree_util.tree_map(lambda a: a[0], self.params)
        self.graph = build_graph(dl)
        self.sampler = PeerSampler(dl.n_nodes, dl.degree, dl.seed) if dl.topology == "dynamic" else None
        if dl.secure:
            assert self.graph is not None, "secure aggregation needs a static graph"
            if dl.participation < 1.0:
                raise ValueError(
                    "secure=True is incompatible with participation < 1: a "
                    "dropped node's pairwise masks would not cancel (seed "
                    "recovery is not modeled); run churn without secure."
                )
            if dl.payload == "on" or dl.payload_quant or dl.randk_sampler != "uniform":
                raise ValueError(
                    "payload/payload_quant/randk_sampler do not compose with "
                    "secure=True (masked messages are full fp32 vectors; "
                    "compressing them would break mask cancellation)"
                )
            self.sharing = SecureAggregation(self.graph.adj)
        else:
            if dl.payload not in ("auto", "on", "off"):
                raise ValueError(f"unknown payload mode {dl.payload!r} (auto|on|off)")
            sparsified = sharing_lib.strategy_takes_budget(dl.sharing)
            if dl.payload == "on" and not sparsified:
                raise ValueError(
                    f"payload='on' needs a sparsified sharing strategy "
                    f"(randomk/topk/choco), not {dl.sharing!r}"
                )
            kw = {"gamma": dl.choco_gamma} if dl.sharing.startswith("choco") else {}
            if sparsified:
                kw["budget"] = dl.budget
                kw["payload"] = dl.payload != "off"
                if dl.payload_quant:
                    kw["quantize"] = "int8"
                if dl.sharing.lower() in ("randomk", "random"):
                    kw["sampler"] = dl.randk_sampler
                elif dl.randk_sampler != "uniform":
                    raise ValueError(
                        "randk_sampler applies to sharing='randomk' only"
                    )
            elif dl.payload_quant:
                raise ValueError(
                    "payload_quant applies to payload-emitting strategies "
                    "(randomk/topk/choco); use sharing='quant' for "
                    "quantized full sharing"
                )
            self.sharing = sharing_lib.make_sharing(dl.sharing, **kw)
        X0 = jax.vmap(tree_vector)(self.params)
        self.share_state = self.sharing.init_state(X0)
        self.n_params = int(X0.shape[1])
        # per-round wire format metrics: the dtype values ship in, and the
        # bytes of message tensors the sharing stage materializes per round
        # ((idx, val) payloads vs scattered (N, P) mask matrices)
        self.wire_dtype = str(np.dtype(self.sharing.wire_dtype(X0.dtype)))
        self.share_stage_bytes = int(
            self.sharing.stage_bytes_per_round(dl.n_nodes, self.n_params)
        )
        self.mix_mode = self._resolve_mix_mode()
        # --- node-axis sharding (multi-device execution) -------------------
        self.sharded = dl.shard_devices > 0
        self._shard: Optional[NodeShard] = None
        self._perm_sched: Optional[PermuteSchedule] = None
        if self.sharded:
            if dl.chunk_rounds <= 0:
                raise ValueError(
                    "shard_devices requires the scanned chunk path "
                    "(chunk_rounds > 0); the legacy per-round dispatch is "
                    "single-device only"
                )
            if dl.n_nodes % dl.shard_devices:
                raise ValueError(
                    f"n_nodes={dl.n_nodes} must divide evenly over "
                    f"shard_devices={dl.shard_devices}"
                )
            from repro.launch.mesh import make_node_mesh

            self._mesh = make_node_mesh(dl.shard_devices)
            self._shard = NodeShard(
                "nodes", (dl.shard_devices,), dl.n_nodes // dl.shard_devices
            )
            self._shard_backend = self._resolve_shard_backend()
            self._shard_jit_cache: Dict = {}
        # peak host->device bytes staged per chunk (or once, if static) for
        # the mixing topology — O(N·d) sparse vs 4·N² dense; the perf gate
        # benchmarks record it
        self.topo_stage_bytes_peak = 0
        if self.graph is not None:
            self._mean_degree = float(self.graph.degrees().mean())
            # static topology: the mixing operand is a captured device
            # constant of the scan, not a per-chunk host transfer
            if self.mix_mode == "sparse":
                # never materialize the (N, N) W on the sparse path
                st = SparseTopology.from_graph(self.graph)
                if self.sharded and self._shard_backend == "ppermute":
                    # slot-rebalance the table so each column is a
                    # permutation lowering to collective_permutes
                    dec = decompose_slot_permutations(st)
                    if dec is None:
                        raise ValueError(
                            "topology does not decompose into per-slot "
                            "permutations; use shard_backend='gather'"
                        )
                    st = dec
                    self._perm_sched = PermuteSchedule.from_table(
                        dec.nbr, dl.shard_devices
                    )
                self._mix_static = SparseTopology(
                    jnp.asarray(st.nbr), jnp.asarray(st.w), jnp.asarray(st.w_self)
                )
                self.topo_stage_bytes_peak = st.stage_bytes()
            else:
                W_np = self.graph.metropolis_hastings().astype(np.float32)
                self._mix_static = jnp.asarray(W_np)
                self.topo_stage_bytes_peak = int(W_np.nbytes)
        else:
            self._mix_static = None
            self._mean_degree = float(dl.degree)  # PeerSampler is d-regular
        self.network_model = build_network(dl)
        if self.network_model is not None:
            lat, gp = self.network_model.matrices()
            self._lat = jnp.asarray(lat)
            self._goodput = jnp.asarray(gp)
        else:
            self._lat = self._goodput = None
        # device-resident dataset for in-scan batch gathers
        self._dev_x = jnp.asarray(batcher.x)
        self._dev_y = jnp.asarray(batcher.y)
        self._base_key = jax.random.key(dl.seed + 17)
        n = dl.n_nodes
        if dl.chunk_rounds <= 0:
            self.chunk = 0
        elif self.sampler is not None and self.mix_mode == "dense":
            # dense dynamic topologies stage an (R, N, N) W stack per chunk;
            # bound it.  (The sparse path stages (R, N, D) — no cap needed,
            # chunks stay full-length at N=1024+.)
            self.chunk = max(1, min(dl.chunk_rounds, _W_STACK_BYTES_CAP // (4 * n * n)))
        else:
            self.chunk = dl.chunk_rounds
        self.history: List[Dict] = []
        self.bytes_sent = 0.0
        self.sim_time_s = 0.0
        self._chunk_jit = jax.jit(self._chunk_fn)
        self._legacy_jit = jax.jit(self._legacy_round)
        self._eval_jit = jax.jit(self._eval)

    def _resolve_shard_backend(self) -> str:
        """Distributed gossip lowering: 'ppermute' decomposes the static
        neighbor table into per-slot permutations, each applied as
        rotation-grouped `collective_permute`s (O(D·B·P) wire — the mesh-
        native path); 'gather' all-gathers the node axis and reuses the
        single-device neighbor gather (any table, incl. per-round dynamic
        ones whose schedule cannot be static).  'auto' picks ppermute on
        TPU interconnects and gather on CPU emulation, where host-emulated
        collectives cost more than the bytes they save."""
        b = self.dl.shard_backend
        if b not in ("auto", "ppermute", "gather"):
            raise ValueError(
                f"unknown shard_backend {b!r} (auto|ppermute|gather)"
            )
        static_sparse = self.sampler is None and self.mix_mode == "sparse"
        if b == "ppermute":
            if not static_sparse:
                raise ValueError(
                    "shard_backend='ppermute' needs a static sparse "
                    "topology (dynamic tables have no static schedule; "
                    "dense mixing all-gathers by construction)"
                )
            return b
        if b == "auto" and static_sparse and jax.default_backend() == "tpu":
            return "ppermute"
        return "gather"

    def _resolve_mix_mode(self) -> str:
        """'sparse' (neighbor-indexed O(N·d·P) gossip) for sparse overlays,
        'dense' (W @ X) where the graph is effectively complete."""
        m = self.dl.mixing
        if m not in ("auto", "sparse", "dense"):
            raise ValueError(f"unknown mixing mode {m!r} (auto|sparse|dense)")
        if m != "auto":
            return m
        if self.dl.topology in ("fully", "star"):
            return "dense"  # D ~ N: padded tables would be the dense matrix
        if self.graph is not None and int(self.graph.degrees().max()) >= self.dl.n_nodes - 1:
            return "dense"
        return "sparse"

    # ------------------------------------------------------------------
    # traced round program (shared by scan body and legacy dispatch)
    # ------------------------------------------------------------------
    def _node_scale(self, tree, scale):
        """Multiply every node-stacked leaf by a per-node (N,) factor."""

        def f(a):
            return a * scale.reshape((scale.shape[0],) + (1,) * (a.ndim - 1))

        return jax.tree_util.tree_map(f, tree)

    def _node_where(self, mask, new, old):
        """Per-node select between two node-stacked pytrees."""

        def f(n, o):
            m = mask.reshape((mask.shape[0],) + (1,) * (n.ndim - 1))
            return jnp.where(m > 0, n, o)

        return jax.tree_util.tree_map(f, new, old)

    def _local_train(self, params, opt_state, bx, by, active, shard=None):
        def node_grad(p, x, y):
            return jax.grad(self.loss_fn)(p, x, y)

        if self.lr_scales is not None:
            # sharded: slice this device's block of the per-node multipliers
            lrs = shard.local(self.lr_scales) if shard is not None else self.lr_scales
        # local_steps is small and static: unroll instead of nesting a scan
        for s in range(bx.shape[0]):
            grads = jax.vmap(node_grad)(params, bx[s], by[s])
            updates, new_opt = jax.vmap(self.opt.update)(grads, opt_state, params)
            if self.lr_scales is not None:
                updates = self._node_scale(updates, lrs)
            if active is not None:
                # down nodes do no local work: zero update, frozen opt state
                updates = self._node_scale(updates, active)
                new_opt = self._node_where(active, new_opt, opt_state)
            params, opt_state = apply_updates(params, updates), new_opt
        return params, opt_state

    def _round_time(self, Wm, active, nbytes, deg_eff, shard=None):
        """Simulated synchronous-round wall-clock, traced (network.py's
        round_time vectorized over the reweighted mixing operand).  For a
        SparseTopology the per-edge latency/goodput are gathered through the
        neighbor table — O(N·D) — instead of masking (N, N) matrices.
        Sharded: rows are this device's block (global ids index the
        replicated latency/goodput matrices) and the synchronous-round max
        is a pmax over the node axis."""
        per_edge = jnp.where(deg_eff > 0, nbytes / jnp.maximum(deg_eff, 1e-9), 0.0)
        if isinstance(Wm, ShardedTopology):
            topo, rows = Wm.topo, Wm.rows[:, None]
            A = (topo.w > 0).astype(jnp.float32)
            t_edge = (
                self._lat[rows, topo.nbr]
                + per_edge * 8.0 / self._goodput[rows, topo.nbr]
            )
        elif isinstance(Wm, ShardedDense):
            rows = Wm.rows
            offdiag = (jnp.arange(Wm.W.shape[1])[None, :] != rows[:, None]).astype(
                jnp.float32
            )
            A = (Wm.W * offdiag > 0).astype(jnp.float32)
            t_edge = (
                jnp.take(self._lat, rows, axis=0)
                + per_edge * 8.0 / jnp.take(self._goodput, rows, axis=0)
            )
        elif isinstance(Wm, SparseTopology):
            rows = jnp.arange(Wm.nbr.shape[0])[:, None]
            A = (Wm.w > 0).astype(jnp.float32)  # live edge slots post-reweight
            t_edge = (
                self._lat[rows, Wm.nbr]
                + per_edge * 8.0 / self._goodput[rows, Wm.nbr]
            )
        else:
            n = Wm.shape[0]
            offdiag = 1.0 - jnp.eye(n, dtype=jnp.float32)
            A = (Wm * offdiag > 0).astype(jnp.float32)
            t_edge = self._lat + per_edge * 8.0 / self._goodput
        if self.dl.parallel_sends:
            comm = jnp.max(A * t_edge, axis=1)
        else:
            comm = jnp.sum(A * t_edge, axis=1)
        node_t = self.dl.compute_time_s + comm
        if active is not None:
            node_t = active * node_t
        t = jnp.max(node_t)
        return shard.pmax(t) if shard is not None else t

    def _train_and_mix(self, params, opt_state, share_state, bx, by, W, active,
                       rnd, shard=None):
        """One round.  ``active`` is None for full participation (statically
        skips masking/reweighting: W flows through untouched and the degree
        stays a Python float, exactly like per-round dispatch did).
        ``shard`` is the node-axis sharding inside a shard_map body (all
        node-stacked operands are then this device's row blocks)."""
        key = jax.random.fold_in(self._base_key, rnd)
        params, opt_state = self._local_train(params, opt_state, bx, by, active, shard)
        if active is not None:
            if isinstance(W, ShardedTopology):
                t2, deg_eff = participation_reweight_sparse(
                    W.topo, active, shard=W.shard
                )
                Wm = ShardedTopology(t2, W.shard, W.sched)
            elif isinstance(W, ShardedDense):
                W2, deg_eff = participation_reweight(W.W, active, shard=W.shard)
                Wm = ShardedDense(W2, W.shard)
            elif isinstance(W, SparseTopology):
                Wm, deg_eff = participation_reweight_sparse(W, active)
            else:
                Wm, deg_eff = participation_reweight(W, active)
        else:
            Wm, deg_eff = W, self._mean_degree
        X = jax.vmap(tree_vector)(params)
        X2, new_share, nbytes = self.sharing.round(
            X, Wm, share_state, key, degree=deg_eff, rnd=rnd
        )
        if active is not None:
            # a down node transmitted nothing: its sharing bookkeeping
            # (TopK last_shared, CHOCO xhat — node-stacked leaves) must not
            # record this round's payload as sent
            share_state = self._node_where(active, new_share, share_state)
        else:
            share_state = new_share
        new_params = jax.vmap(lambda v: tree_unvector(v, self.template))(X2)
        if active is not None:
            # don't trust each strategy's W-row-identity property for down
            # nodes (e.g. QuantizedSharing would hand them the int8
            # roundtrip of their own params): freeze them explicitly
            params = self._node_where(active, new_params, params)
        else:
            params = new_params
        nbytes = jnp.asarray(nbytes, jnp.float32)
        if self._lat is not None:
            sim_t = self._round_time(Wm, active, nbytes, deg_eff, shard)
        else:
            sim_t = jnp.float32(0.0)
        return params, opt_state, share_state, nbytes, sim_t

    def _chunk_fn(self, params, opt_state, share_state, xs):
        """R rounds in one lax.scan.  ``xs`` is a dict of per-round scan
        inputs: always idx (R,L,N,B) int32 and rnd (R,) int32; plus, for
        dynamic topologies, ``mix`` — an (R,N,N) f32 W stack (dense mode)
        or an (R,N,D) SparseTopology table stack (sparse mode); static
        topologies capture one device-constant mixing operand.  ``act``
        (R,N) f32 rides along when participation < 1."""

        def body(carry, xs_r):
            params, opt_state, share_state = carry
            W = xs_r["mix"] if "mix" in xs_r else self._mix_static
            act = xs_r.get("act")
            if "bx" in xs_r:  # chunk batches pre-gathered on device
                bx, by = xs_r["bx"], xs_r["by"]
            else:  # oversized chunk: gather (L, N, B, ...) per round
                bx = jnp.take(self._dev_x, xs_r["idx"], axis=0)
                by = jnp.take(self._dev_y, xs_r["idx"], axis=0)
            params, opt_state, share_state, nbytes, sim_t = self._train_and_mix(
                params, opt_state, share_state, bx, by, W, act, xs_r["rnd"]
            )
            return (params, opt_state, share_state), (nbytes, sim_t)

        carry, (nbytes, times) = jax.lax.scan(
            body, (params, opt_state, share_state), xs
        )
        return carry + (nbytes, times)

    # ------------------------------------------------------------------
    # node-sharded chunk execution (shard_map over the device mesh)
    # ------------------------------------------------------------------
    def _wrap_mix(self, mix):
        """Sharded mixing operand for one round inside the shard body.

        ``mix`` is the scanned per-round operand (this device's row block,
        cut by the in_specs) or None for static topologies — those capture
        the full replicated tables and slice the local block by device
        index, keeping the wrapper shapes identical either way."""
        shard = self._shard
        if mix is None:
            if self.mix_mode == "sparse":
                st = self._mix_static
                topo_l = SparseTopology(
                    shard.local(st.nbr), shard.local(st.w), shard.local(st.w_self)
                )
                return ShardedTopology(topo_l, shard, self._perm_sched)
            return ShardedDense(shard.local(self._mix_static), shard)
        if isinstance(mix, SparseTopology):
            return ShardedTopology(mix, shard, None)
        return ShardedDense(mix, shard)

    def _chunk_fn_sharded(self, params, opt_state, share_state, xs):
        """The scanned chunk, run inside shard_map: every node-stacked
        carry/input is this device's (B, ...) row block; gossip crosses
        devices through the sharded mixing operand (collective_permute
        slots or all-gather — see mixing.ShardedTopology) and the per-round
        scalar metrics are psum/pmax-reduced so each device returns the
        same global values."""

        def body(carry, xs_r):
            params, opt_state, share_state = carry
            W = self._wrap_mix(xs_r.get("mix"))
            act = xs_r.get("act")
            if "bx" in xs_r:
                bx, by = xs_r["bx"], xs_r["by"]
            else:  # oversized chunk: gather this block's batches per round
                bx = jnp.take(self._dev_x, xs_r["idx"], axis=0)
                by = jnp.take(self._dev_y, xs_r["idx"], axis=0)
            params, opt_state, share_state, nbytes, sim_t = self._train_and_mix(
                params, opt_state, share_state, bx, by, W, act, xs_r["rnd"],
                shard=self._shard,
            )
            return (params, opt_state, share_state), (nbytes, sim_t)

        carry, (nbytes, times) = jax.lax.scan(
            body, (params, opt_state, share_state), xs
        )
        return carry + (nbytes, times)

    def _xs_pspec(self, xs):
        """Per-leaf PartitionSpecs for the scan-input dict: the node axis of
        every leaf maps to the mesh 'nodes' axis, everything else is
        replicated."""

        def spec(path, leaf):
            key = path[0].key
            if key == "rnd":
                return P()
            if key in ("bx", "by", "idx"):  # (R, L, N, B, ...)
                return P(None, None, "nodes", *((None,) * (leaf.ndim - 3)))
            if key == "act":                # (R, N)
                return P(None, "nodes")
            if key == "mix":                # (R, N, N) W or (R, N, D)/(R, N) tables
                return P(None, "nodes", *((None,) * (leaf.ndim - 2)))
            raise KeyError(f"unknown scan input {key!r}")

        return jax.tree_util.tree_map_with_path(spec, xs)

    def _node_pspec(self, tree):
        return jax.tree_util.tree_map(
            lambda l: P("nodes", *((None,) * (l.ndim - 1))), tree
        )

    def _sharded_chunk_call(self, xs):
        """shard_map-wrap + jit the chunk for this xs structure (cached —
        structures recur: full chunks and the pre-eval remainder)."""
        leaves, treedef = jax.tree_util.tree_flatten(xs)
        key = (treedef, tuple(l.ndim for l in leaves))
        fn = self._shard_jit_cache.get(key)
        if fn is None:
            state_specs = (
                self._node_pspec(self.params),
                self._node_pspec(self.opt_state),
                self._node_pspec(self.share_state),
            )
            fn = jax.jit(
                shard_map(
                    self._chunk_fn_sharded,
                    mesh=self._mesh,
                    in_specs=state_specs + (self._xs_pspec(xs),),
                    out_specs=state_specs + (P(), P()),
                    check_vma=False,
                )
            )
            self._shard_jit_cache[key] = fn
        return fn(self.params, self.opt_state, self.share_state, xs)

    def _legacy_round(self, params, opt_state, share_state, bx, by, W, active, rnd):
        return self._train_and_mix(params, opt_state, share_state, bx, by, W, active, rnd)

    def _eval(self, params, tx, ty):
        return jax.vmap(lambda p: self.acc_fn(p, tx, ty))(params)

    # ------------------------------------------------------------------
    # host-side chunk staging
    # ------------------------------------------------------------------
    def _round_mix(self, rnd: int):
        """Device mixing operand for one round (legacy per-round dispatch):
        dense (N, N) W or SparseTopology neighbor tables, matching the mode
        the scanned path uses so both execute the identical workload."""
        if self.sampler is None:
            return self._mix_static
        if self.mix_mode == "sparse":
            t = self.sampler.round_table(rnd)
            return SparseTopology(
                jnp.asarray(t.nbr), jnp.asarray(t.w), jnp.asarray(t.w_self)
            )
        return jnp.asarray(self.sampler.round_weights(rnd).astype(np.float32))

    def _participation_mask(self, start: int, n_rounds: int) -> np.ndarray:
        """(R, N) {0,1} activity masks for rounds [start, start+n_rounds).

        One batched counter-based draw (splitmix64 hash over (seed,
        absolute round, node)) — each round's randomness is a pure function
        of its absolute index, so masks are chunk-boundary invariant, with
        no per-round ``default_rng`` host loop.  Column n holds each
        round's fallback draw: if every node sampled down, one node
        (uniform via that draw) is kept alive.
        """
        n = self.dl.n_nodes
        if self.dl.participation >= 1.0:
            return np.ones((n_rounds, n), np.float32)
        with np.errstate(over="ignore"):  # uint64 wraparound is the point
            x = (
                np.uint64(self.dl.seed * 1_000_003 + 7_919)
                * np.uint64(0x9E3779B97F4A7C15)
                + np.arange(start, start + n_rounds, dtype=np.uint64)[:, None]
                * np.uint64(0xBF58476D1CE4E5B9)
                + np.arange(n + 1, dtype=np.uint64)[None, :]
                * np.uint64(0x94D049BB133111EB)
            )
            x ^= x >> np.uint64(30)
            x *= np.uint64(0xBF58476D1CE4E5B9)
            x ^= x >> np.uint64(27)
            x *= np.uint64(0x94D049BB133111EB)
            x ^= x >> np.uint64(31)
        u = (x >> np.uint64(11)).astype(np.float64) * (2.0 ** -53)
        m = u[:, :n] < self.dl.participation
        dead = ~m.any(1)
        if dead.any():  # keep at least one node alive per round
            m[dead, (u[dead, n] * n).astype(np.int64)] = True
        return m.astype(np.float32)

    def _run_chunk(self, start: int, n_rounds: int):
        dl = self.dl
        idx = self.batcher.chunk_indices(start, n_rounds, dl.local_steps)
        xs = {"rnd": jnp.asarray(np.arange(start, start + n_rounds, dtype=np.int32))}
        item_bytes = self._dev_x.nbytes // max(self._dev_x.shape[0], 1)
        if idx.size * item_bytes <= _BATCH_STACK_BYTES_CAP:
            # pre-stack the whole chunk's batches on device: one gather per
            # chunk instead of one per scanned round
            idx_dev = jnp.asarray(idx)
            xs["bx"] = jnp.take(self._dev_x, idx_dev, axis=0)  # (R, L, N, B, ...)
            xs["by"] = jnp.take(self._dev_y, idx_dev, axis=0)
        else:
            xs["idx"] = jnp.asarray(idx)
        if self.sampler is not None:
            if self.mix_mode == "sparse":
                st = self.sampler.sparse_stack(start, n_rounds)  # (R, N, D)
                xs["mix"] = SparseTopology(
                    jnp.asarray(st.nbr), jnp.asarray(st.w), jnp.asarray(st.w_self)
                )
                staged = st.stage_bytes()
            else:
                Wst = self.sampler.weights_stack(start, n_rounds)  # (R, N, N)
                xs["mix"] = jnp.asarray(Wst)
                staged = int(Wst.nbytes)
            self.topo_stage_bytes_peak = max(self.topo_stage_bytes_peak, staged)
        if dl.participation < 1.0:
            xs["act"] = jnp.asarray(self._participation_mask(start, n_rounds))
        if self.sharded:
            out = self._sharded_chunk_call(xs)
        else:
            out = self._chunk_jit(self.params, self.opt_state, self.share_state, xs)
        self.params, self.opt_state, self.share_state, nbytes, times = out
        # ONE host sync per chunk for all per-round metrics
        self.bytes_sent += float(np.asarray(nbytes, np.float64).sum())
        self.sim_time_s += float(np.asarray(times, np.float64).sum())

    def _run_legacy_round(self, rnd: int):
        """Per-round dispatch baseline: host-gathered full batches, one jit
        call and one metric sync per round.  Samples the same round_indices
        as the scanned path so both execute the identical workload."""
        dl = self.dl
        idx = self.batcher.round_indices(rnd, dl.local_steps)  # (L, N, B)
        bx = jnp.asarray(self.batcher.x[idx])
        by = jnp.asarray(self.batcher.y[idx])
        W = self._round_mix(rnd)
        act = (
            jnp.asarray(self._participation_mask(rnd, 1)[0])
            if dl.participation < 1.0 else None
        )
        out = self._legacy_jit(
            self.params, self.opt_state, self.share_state, bx, by, W, act,
            jnp.int32(rnd),
        )
        self.params, self.opt_state, self.share_state, nbytes, sim_t = out
        self.bytes_sent += float(nbytes)
        self.sim_time_s += float(sim_t)

    # ------------------------------------------------------------------
    def _record(self, rnd: int, tx, ty, t0: float, log: bool):
        accs = np.asarray(self._eval_jit(self.params, tx, ty))
        rec = {
            "round": rnd,
            "acc_mean": float(accs.mean()),
            "acc_std": float(accs.std()),
            "bytes_per_node": self.bytes_sent,
            "wall_s": time.time() - t0,
            "sim_time_s": self.sim_time_s,
            "wire_dtype": self.wire_dtype,
        }
        self.history.append(rec)
        if log:
            print(
                f"[{self.dl.topology}/{type(self.sharing).__name__}] round {rnd:4d} "
                f"acc {rec['acc_mean']:.4f}±{rec['acc_std']:.4f} "
                f"MB/node {self.bytes_sent / 1e6:.1f}"
                + (f" sim {self.sim_time_s:.1f}s" if self.network_model else "")
            )

    def run(self, rounds: Optional[int] = None, log: bool = True) -> List[Dict]:
        dl = self.dl
        rounds = rounds if rounds is not None else dl.rounds
        tx, ty = self.batcher.test_batch()
        tx, ty = jnp.asarray(tx), jnp.asarray(ty)
        ev = max(dl.eval_every, 1)
        t0 = time.time()
        if self.chunk == 0:  # legacy per-round dispatch
            for rnd in range(rounds):
                self._run_legacy_round(rnd)
                if rnd % ev == 0 or rnd == rounds - 1:
                    self._record(rnd, tx, ty, t0, log)
        else:
            rnd = 0
            while rnd < rounds:
                nxt = -(-rnd // ev) * ev  # next eval round >= rnd
                if nxt >= rounds:
                    nxt = rounds - 1
                end = nxt + 1
                while rnd < end:
                    r = min(self.chunk, end - rnd)
                    self._run_chunk(rnd, r)
                    rnd += r
                self._record(nxt, tx, ty, t0, log)
        self._dump_results()
        return self.history

    def _dump_results(self):
        """Per-node JSON results, DecentralizePy-style (aggregated later)."""
        if not self.dl.results_dir:
            return
        os.makedirs(self.dl.results_dir, exist_ok=True)
        with open(os.path.join(self.dl.results_dir, "results.json"), "w") as f:
            json.dump({"config": dataclasses.asdict(self.dl), "history": self.history}, f, indent=1)
