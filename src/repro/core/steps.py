"""Step layer — the pure, jittable per-round functions of the engine.

The execution model splits into two layers (see ``core/scheduler.py`` for
the other half):

* **steps** (this module): what one node-stacked round *does* — the local
  SGD step (``RoundSteps.local_train``), the share/mix step through the
  configured sharing strategy (``RoundSteps.train_and_mix``), and the
  simulated per-node round time (``RoundSteps.round_time``).  Every
  function is pure in its traced arguments and runs identically inside a
  ``lax.scan`` body, a legacy per-round jit, or a ``shard_map`` block
  (``shard`` carries the node-axis sharding when present).
* **scheduler**: when those steps fire and what time means — the
  synchronous round barrier, per-node local clocks, or event-driven
  cohorts on a virtual clock.

``RoundSteps`` is a plain container of the static experiment pieces
(loss/optimizer/sharing, per-node compute times, link matrices); it holds
no mutable state — params/opt/sharing state are threaded by the caller.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import faults as faults_lib
from repro.core.mixing import ShardedDense, ShardedTopology
from repro.core.network import gathered_round_times, node_round_times
from repro.core.sharing import (
    edge_reweight,
    edge_reweight_sparse,
    participation_reweight,
    participation_reweight_sparse,
)
from repro.core.topology import SparseTopology
from repro.optim.optimizers import apply_updates
from repro.utils.pytree import tree_unvector, tree_vector


def node_scale(tree, scale):
    """Multiply every node-stacked leaf by a per-node (N,) factor."""

    def f(a):
        return a * scale.reshape((scale.shape[0],) + (1,) * (a.ndim - 1))

    return jax.tree_util.tree_map(f, tree)


def node_where(mask, new, old):
    """Per-node select between two node-stacked pytrees."""

    def f(n, o):
        m = mask.reshape((mask.shape[0],) + (1,) * (n.ndim - 1))
        return jnp.where(m > 0, n, o)

    return jax.tree_util.tree_map(f, new, old)


@dataclasses.dataclass(eq=False)
class RoundSteps:
    """The traced per-round step functions, shared by every scheduler.

    compute_node: (N,) float32 per-node local compute seconds (the
    heterogeneous-time axis — a straggler is simply a large entry).
    lat/goodput: (N, N) link matrices of the simulated network, or None.
    """

    loss_fn: Callable
    opt: Any
    sharing: Any
    template: Any
    base_key: jax.Array
    mean_degree: float
    compute_node: jnp.ndarray
    parallel_sends: bool
    lr_scales: Optional[jnp.ndarray] = None
    lat: Optional[jnp.ndarray] = None
    goodput: Optional[jnp.ndarray] = None
    # fault injection (core/faults.py): the declarative plan plus its PRF
    # root key — None disables every fault branch statically
    faults: Optional[Any] = None
    fault_key: Optional[jax.Array] = None

    # ------------------------------------------------------------------
    def local_train(self, params, opt_state, bx, by, active, shard=None,
                    rows=None):
        """``rows`` (traced global node ids) marks a gathered row subset —
        the cohort path's (C, ...) hot set — and redirects the per-node
        static vectors (lr_scales) through the same gather; every other
        operand is already row-stacked by the caller."""
        def node_grad(p, x, y):
            return jax.grad(self.loss_fn)(p, x, y)

        if self.lr_scales is not None:
            if rows is not None:
                lrs = jnp.take(self.lr_scales, rows)
            elif shard is not None:
                # sharded: this device's block of the per-node multipliers
                lrs = shard.local(self.lr_scales)
            else:
                lrs = self.lr_scales
        # local_steps is small and static: unroll instead of nesting a scan
        for s in range(bx.shape[0]):
            grads = jax.vmap(node_grad)(params, bx[s], by[s])
            updates, new_opt = jax.vmap(self.opt.update)(grads, opt_state, params)
            if self.lr_scales is not None:
                updates = node_scale(updates, lrs)
            if active is not None:
                # down nodes do no local work: zero update, frozen opt state
                updates = node_scale(updates, active)
                new_opt = node_where(active, new_opt, opt_state)
            params, opt_state = apply_updates(params, updates), new_opt
        return params, opt_state

    # ------------------------------------------------------------------
    def round_time(self, Wm, active, nbytes, deg_eff, shard=None, *,
                   reduce: str = "max", lat_mult=None):
        """Simulated round wall-clock, traced — the same compute+comm
        formula as ``NetworkModel.round_time`` (both call
        ``network.node_round_times``; an equivalence test pins them
        together).  For a SparseTopology the per-edge latency/goodput are
        gathered through the neighbor table — O(N·D) — instead of masking
        (N, N) matrices.  Sharded: rows are this device's block (global ids
        index the replicated latency/goodput matrices) and the synchronous
        round max is a pmax over the node axis.

        reduce: 'max' — the synchronous-barrier scalar (every node waits
        for the slowest); 'none' — the per-node (N,) time vector, for
        schedulers that own their own clock semantics (local / async).
        """
        per_edge = jnp.where(deg_eff > 0, nbytes / jnp.maximum(deg_eff, 1e-9), 0.0)
        if isinstance(Wm, ShardedTopology):
            topo, rows = Wm.topo, Wm.rows[:, None]
            A = (topo.w > 0).astype(jnp.float32)
            lat = self.lat[rows, topo.nbr]
            gp = self.goodput[rows, topo.nbr]
        elif isinstance(Wm, ShardedDense):
            rows = Wm.rows
            offdiag = (jnp.arange(Wm.W.shape[1])[None, :] != rows[:, None]).astype(
                jnp.float32
            )
            A = (Wm.W * offdiag > 0).astype(jnp.float32)
            lat = jnp.take(self.lat, rows, axis=0)
            gp = jnp.take(self.goodput, rows, axis=0)
        elif isinstance(Wm, SparseTopology):
            rows = jnp.arange(Wm.nbr.shape[0])[:, None]
            A = (Wm.w > 0).astype(jnp.float32)  # live edge slots post-reweight
            lat = self.lat[rows, Wm.nbr]
            gp = self.goodput[rows, Wm.nbr]
        else:
            n = Wm.shape[0]
            offdiag = 1.0 - jnp.eye(n, dtype=jnp.float32)
            A = (Wm * offdiag > 0).astype(jnp.float32)
            lat, gp = self.lat, self.goodput
        if lat_mult is not None:
            # per-edge latency surges (fault injection): lat_mult is
            # aligned with A's edge layout (neighbor slots or dense)
            lat = lat * lat_mult
        ct = shard.local(self.compute_node) if shard is not None else self.compute_node
        node_t = node_round_times(A, lat, gp, per_edge, ct, self.parallel_sends)
        if active is not None:
            node_t = active * node_t
        if reduce == "none":
            return node_t
        t = jnp.max(node_t)
        return shard.pmax(t) if shard is not None else t

    # ------------------------------------------------------------------
    def cohort_comm_time(self, rows, nbr, live, nbytes, deg_eff):
        """Per-event comm seconds for a *gathered cohort* — the (C,)-row
        slice of ``round_time(..., reduce='none') - compute_node`` that the
        dense async path computes over all N rows, replicated expression
        for expression (per-edge bytes, the (ct + comm) - ct roundtrip) so
        the cohort trajectory matches the dense oracle bitwise.

        rows: (C,) global node ids; nbr: their (C, D) global neighbor ids;
        live: (C, D) {0,1} live-edge mask (post churn reweight).
        """
        per_edge = jnp.where(deg_eff > 0, nbytes / jnp.maximum(deg_eff, 1e-9), 0.0)
        ct = jnp.take(self.compute_node, rows)
        node_t = gathered_round_times(
            self.lat, self.goodput, rows, nbr, live, per_edge, ct,
            self.parallel_sends,
        )
        return node_t - ct  # caller adds compute back, like the dense path

    # ------------------------------------------------------------------
    def _secure_recovery_bytes(self, active, shard=None):
        """Wire bytes of the Bonawitz seed-recovery pass under churn: one
        revealed seed share per (live receiver, live sender, dropped
        co-neighbor) triple of the secure-aggregation neighbor table —
        the surviving co-neighbors re-send the dropped pair's key-chain
        material so the receiver can subtract its PRF masks.  Sharded:
        counted over this device's receiver rows, psum'd to the global
        scalar every device returns."""
        from repro.core.secure import SEED_SHARE_BYTES

        nbr = jnp.asarray(self.sharing._nbr)
        valid = jnp.asarray(self.sharing._valid, jnp.float32)
        if shard is not None:
            nbr, valid = shard.local(nbr), shard.local(valid)
            act_g = shard.gather(active)
        else:
            act_g = active
        a = jnp.take(act_g.astype(jnp.float32), nbr, axis=0)   # (B, D)
        live, dead = valid * a, valid * (1.0 - a)
        pairs = jnp.sum(active * live.sum(1) * dead.sum(1))
        if shard is not None:
            pairs = shard.psum(pairs)
        return pairs * SEED_SHARE_BYTES

    # ------------------------------------------------------------------
    def train_and_mix(self, params, opt_state, share_state, bx, by, W, active,
                      rnd, shard=None, *, time_reduce: str = "max"):
        """One round: local step, then the share/mix step through the
        configured sharing strategy.  ``active`` is None for full
        participation (statically skips masking/reweighting: W flows
        through untouched and the degree stays a Python float, exactly
        like per-round dispatch did).  ``shard`` is the node-axis sharding
        inside a shard_map body (all node-stacked operands are then this
        device's row blocks).  ``time_reduce`` is forwarded to
        :meth:`round_time` — 'max' for the synchronous barrier scalar,
        'none' for the per-node vector.

        With ``self.faults`` set (a ``core.faults.FaultPlan``), the round
        additionally injects message-level faults: per-edge message loss
        renormalizes the mixing operand (``edge_reweight``) while wire
        bytes and link time are still spent (the sender does not know);
        latency spikes multiply the affected edges' latency in the traced
        round time; payload corruption hits post-mix rows and the
        self-healing guard rolls detected (non-finite) rows back to the
        start-of-round snapshot.  Returns a 6-tuple ``(params, opt_state,
        share_state, nbytes, sim_t, fstats)`` where ``fstats`` is the
        static-schema fault-counter dict (``faults.STAT_KEYS``)."""
        plan = self.faults
        fstats = faults_lib.zero_stats()
        guard = plan is not None and plan.corrupt_prob > 0
        if guard:
            snap = (params, opt_state, share_state)  # last-good snapshot
        key = jax.random.fold_in(self.base_key, rnd)
        params, opt_state = self.local_train(params, opt_state, bx, by, active, shard)
        if active is not None:
            if isinstance(W, ShardedTopology):
                t2, deg_eff = participation_reweight_sparse(
                    W.topo, active, shard=W.shard
                )
                Wm = ShardedTopology(t2, W.shard, W.sched)
            elif isinstance(W, ShardedDense):
                W2, deg_eff = participation_reweight(W.W, active, shard=W.shard)
                Wm = ShardedDense(W2, W.shard)
            elif isinstance(W, SparseTopology):
                Wm, deg_eff = participation_reweight_sparse(W, active)
            else:
                Wm, deg_eff = participation_reweight(W, active)
        else:
            Wm, deg_eff = W, self.mean_degree
        # --- message-level edge faults (single-host; validated) ------------
        # the *mixing* operand drops lost edges (renormalized), but wire
        # bytes and simulated link time are charged on the churn-level
        # operand Wm: the sender transmitted, the network just lost it
        Wm_mix, lat_mult = Wm, None
        if plan is not None and plan.edge_faults:
            if isinstance(Wm, SparseTopology):
                n_rows, d = Wm.nbr.shape
                live, spike = faults_lib.edge_draws(
                    self.fault_key, rnd, jnp.arange(n_rows), d, plan
                )
                sent = (Wm.w > 0).astype(jnp.float32)
                Wm_mix = edge_reweight_sparse(Wm, live)
            else:
                n = Wm.shape[0]
                live, spike = faults_lib.edge_draws(
                    self.fault_key, rnd, jnp.arange(n), n, plan
                )
                sent = (
                    Wm * (1.0 - jnp.eye(n, dtype=jnp.float32)) > 0
                ).astype(jnp.float32)
                Wm_mix = edge_reweight(Wm, live)
            dropped = jnp.sum(sent * (1.0 - live))
            spiked = jnp.sum(sent * spike)
            if plan.latency_spike_prob > 0:
                lat_mult = 1.0 + spike * (plan.latency_spike_factor - 1.0)
            # drops are absorbed by renormalization, spikes by late
            # delivery: survived by design, never silently lost
            fstats["faults_injected"] += dropped + spiked
            fstats["faults_survived"] += dropped + spiked
        X = jax.vmap(tree_vector)(params)
        share_kw = {}
        if getattr(self.sharing, "needs_act", False) and active is not None:
            share_kw["act"] = active
        X2, new_share, nbytes = self.sharing.round(
            X, Wm_mix, share_state, key, degree=deg_eff, rnd=rnd, **share_kw
        )
        if share_kw:
            rec = self._secure_recovery_bytes(active, shard)
            nbytes = nbytes + rec
            fstats["recovery_bytes"] += rec
        # --- payload corruption (post-mix, in flight) ----------------------
        if guard:
            cmask = faults_lib.corruption_mask(
                self.fault_key, rnd, jnp.arange(X2.shape[0]), plan
            )
            if active is not None:
                cmask = cmask * active  # a down node received nothing
            X2 = faults_lib.corrupt_rows(X2, cmask, plan.corrupt_mode)
            fstats["faults_injected"] += jnp.sum(cmask)
        if active is not None:
            # a down node transmitted nothing: its sharing bookkeeping
            # (TopK last_shared, CHOCO xhat — node-stacked leaves) must not
            # record this round's payload as sent
            share_state = node_where(active, new_share, share_state)
        else:
            share_state = new_share
        new_params = jax.vmap(lambda v: tree_unvector(v, self.template))(X2)
        if active is not None:
            # don't trust each strategy's W-row-identity property for down
            # nodes (e.g. QuantizedSharing would hand them the int8
            # roundtrip of their own params): freeze them explicitly
            params = node_where(active, new_params, params)
        else:
            params = new_params
        # --- self-healing step guard: roll back non-finite rows ------------
        if guard:
            bad = faults_lib.nonfinite_rows(X2)
            if active is not None:
                bad = bad * active
            good = 1.0 - bad
            p0, o0, s0 = snap
            params = node_where(good, params, p0)
            opt_state = node_where(good, opt_state, o0)
            share_state = node_where(good, share_state, s0)
            nbad = jnp.sum(bad)
            fstats["faults_detected"] += nbad
            fstats["faults_recovered"] += nbad
        nbytes = jnp.asarray(nbytes, jnp.float32)
        if self.lat is not None:
            sim_t = self.round_time(Wm, active, nbytes, deg_eff, shard,
                                    reduce=time_reduce, lat_mult=lat_mult)
        elif time_reduce == "none":
            # no network model: comm is free but per-node compute time still
            # drives the virtual clocks (matching the async scheduler, whose
            # event cadence is compute-only without a network)
            node_t = self.compute_node
            if active is not None:
                node_t = active * node_t
            sim_t = node_t
        else:
            sim_t = jnp.float32(0.0)
        return params, opt_state, share_state, nbytes, sim_t, fstats
