"""Graph module — the overlay topology (paper §2.2 *Graph*).

Supports the paper's topologies (ring, d-regular, fully-connected, star),
dynamic per-round regular graphs via a ``PeerSampler``, Metropolis–Hastings
mixing weights, and graph-file I/O (edge list / adjacency list) so external
generators can be plugged in, exactly like DecentralizePy's graph files.
"""
from __future__ import annotations

import dataclasses
import json
from typing import List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class Graph:
    """Undirected overlay graph over ``n`` nodes; adjacency as a bool matrix
    (no self loops stored; every node implicitly talks to itself)."""

    adj: np.ndarray  # (n, n) bool, symmetric, zero diagonal

    # -- constructors -------------------------------------------------------
    @staticmethod
    def ring(n: int) -> "Graph":
        adj = np.zeros((n, n), bool)
        idx = np.arange(n)
        adj[idx, (idx + 1) % n] = True
        adj[(idx + 1) % n, idx] = True
        return Graph(adj)

    @staticmethod
    def fully_connected(n: int) -> "Graph":
        adj = np.ones((n, n), bool)
        np.fill_diagonal(adj, False)
        return Graph(adj)

    @staticmethod
    def star(n: int, center: int = 0) -> "Graph":
        adj = np.zeros((n, n), bool)
        adj[center, :] = True
        adj[:, center] = True
        adj[center, center] = False
        return Graph(adj)

    @staticmethod
    def regular_circulant(n: int, degree: int) -> "Graph":
        """d-regular circulant graph: neighbors at fixed offsets ±1,±2,…
        (plus n/2 if degree is odd and n even).  These are the graphs whose
        gossip lowers to `collective_permute` on TPU (static offsets)."""
        assert 0 < degree < n
        adj = np.zeros((n, n), bool)
        idx = np.arange(n)
        offs = circulant_offsets(n, degree)
        for o in offs:
            adj[idx, (idx + o) % n] = True
            adj[(idx + o) % n, idx] = True
        return Graph(adj)

    @staticmethod
    def random_regular(n: int, degree: int, seed: int) -> "Graph":
        """Random d-regular graph — the paper's dynamic 5-regular per-round
        topology.  Start from the circulant d-regular graph and apply many
        random degree-preserving double-edge swaps (always yields a simple
        graph; mixes to near-uniform)."""
        assert 0 < degree < n and n * degree % 2 == 0, "n*degree must be even"
        rng = np.random.default_rng(seed)
        g = Graph.regular_circulant(n, degree)
        adj = g.adj
        edges = [tuple(e) for e in np.argwhere(np.triu(adj))]
        swaps = 0
        target = 10 * len(edges)
        for _ in range(100 * target):
            if swaps >= target:
                break
            i, j = rng.integers(0, len(edges), 2)
            if i == j:
                continue
            (a, b), (c, d) = edges[i], edges[j]
            if rng.random() < 0.5:
                c, d = d, c
            if len({a, b, c, d}) < 4 or adj[a, c] or adj[b, d]:
                continue
            adj[a, b] = adj[b, a] = adj[c, d] = adj[d, c] = False
            adj[a, c] = adj[c, a] = adj[b, d] = adj[d, b] = True
            edges[i], edges[j] = (a, c), (b, d)
            swaps += 1
        return Graph(adj)

    # -- file I/O (paper: 'topology specification' files) -------------------
    @staticmethod
    def from_edge_list(path: str, n: int) -> "Graph":
        adj = np.zeros((n, n), bool)
        with open(path) as f:
            for line in f:
                line = line.strip()
                if not line or line.startswith("#"):
                    continue
                a, b = map(int, line.split()[:2])
                adj[a, b] = adj[b, a] = True
        np.fill_diagonal(adj, False)
        return Graph(adj)

    @staticmethod
    def from_adjacency_json(path: str) -> "Graph":
        with open(path) as f:
            d = json.load(f)
        n = len(d)
        adj = np.zeros((n, n), bool)
        for k, nbrs in d.items():
            for j in nbrs:
                adj[int(k), int(j)] = adj[int(j), int(k)] = True
        np.fill_diagonal(adj, False)
        return Graph(adj)

    def to_edge_list(self, path: str) -> None:
        with open(path, "w") as f:
            for a, b in zip(*np.nonzero(np.triu(self.adj))):
                f.write(f"{a} {b}\n")

    # -- properties ----------------------------------------------------------
    @property
    def n(self) -> int:
        return self.adj.shape[0]

    def degrees(self) -> np.ndarray:
        return self.adj.sum(1)

    def neighbors(self, i: int) -> np.ndarray:
        return np.nonzero(self.adj[i])[0]

    def is_connected(self) -> bool:
        seen = {0}
        frontier = [0]
        while frontier:
            i = frontier.pop()
            for j in np.nonzero(self.adj[i])[0]:
                if j not in seen:
                    seen.add(int(j))
                    frontier.append(int(j))
        return len(seen) == self.n

    # -- runtime mutation (paper: graph modifiable at run time) --------------
    def add_edge(self, a: int, b: int) -> None:
        if a != b:
            self.adj[a, b] = self.adj[b, a] = True

    def remove_edge(self, a: int, b: int) -> None:
        self.adj[a, b] = self.adj[b, a] = False

    def neighbor_table(self) -> Tuple[np.ndarray, np.ndarray]:
        """Padded fixed-width neighbor lists — the static schedule the
        jittable secure-aggregation path and other vectorized per-receiver
        programs index with.  See :func:`neighbor_table`."""
        return neighbor_table(self.adj)

    # -- mixing weights -------------------------------------------------------
    def metropolis_hastings(self) -> np.ndarray:
        """Symmetric doubly-stochastic mixing matrix W (Xiao–Boyd):
        W_ij = 1 / (1 + max(deg_i, deg_j)) for edges, diagonal = residual."""
        deg = self.degrees()
        n = self.n
        W = np.zeros((n, n))
        ii, jj = np.nonzero(self.adj)
        W[ii, jj] = 1.0 / (1.0 + np.maximum(deg[ii], deg[jj]))
        W[np.arange(n), np.arange(n)] = 1.0 - W.sum(1)
        return W

    def uniform_weights(self) -> np.ndarray:
        """W_ij = 1/(deg_i+1) — row-stochastic equal-neighbor weights."""
        n = self.n
        W = self.adj / (self.degrees()[:, None] + 1.0)
        W[np.arange(n), np.arange(n)] = 1.0 / (self.degrees() + 1.0)
        return W

    def spectral_gap(self) -> float:
        w = np.linalg.eigvalsh(self.metropolis_hastings())
        return 1.0 - max(abs(w[0]), abs(w[-2]))


def neighbor_table(adj: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """(nbr (N, dmax) int32, valid (N, dmax) bool) padded neighbor lists.

    Rows shorter than dmax are padded with the node's own index (a harmless
    gather target) and marked invalid.  This rectangular form is what lets
    per-receiver programs (e.g. the vectorized secure-aggregation mask sum)
    run under vmap instead of Python loops over ragged neighbor sets.
    """
    n = adj.shape[0]
    dmax = max(int(adj.sum(1).max()) if n else 0, 1)
    nbr = np.tile(np.arange(n, dtype=np.int32)[:, None], (1, dmax))
    valid = np.zeros((n, dmax), bool)
    for r in range(n):
        ns = np.nonzero(adj[r])[0]
        nbr[r, : len(ns)] = ns
        valid[r, : len(ns)] = True
    return nbr, valid


def circulant_offsets(n: int, degree: int) -> List[int]:
    """Offsets of the d-regular circulant graph used by CirculantMixing."""
    offs = []
    for k in range(1, degree // 2 + 1):
        offs.append(k)
    if degree % 2 == 1:
        assert n % 2 == 0, "odd degree needs even n (antipodal offset)"
        offs.append(n // 2)
    return offs


@dataclasses.dataclass
class PeerSampler:
    """Centralized peer sampler (paper §3.2): instantiates a new random
    d-regular topology every round and hands each node its neighbor list."""

    n: int
    degree: int
    seed: int = 0

    def round_graph(self, round_idx: int) -> Graph:
        return Graph.random_regular(self.n, self.degree, self.seed * 100003 + round_idx)

    def round_weights(self, round_idx: int) -> np.ndarray:
        return self.round_graph(round_idx).metropolis_hastings()

    def weights_stack(self, start: int, n_rounds: int) -> np.ndarray:
        """(R, N, N) float32 stack of per-round mixing matrices for rounds
        [start, start + n_rounds) — pre-generated on the host so a whole
        scan chunk threads W as a traced value (no per-round recompiles)."""
        return np.stack(
            [self.round_weights(start + r) for r in range(n_rounds)]
        ).astype(np.float32)
