"""Graph module — the overlay topology (paper §2.2 *Graph*).

Supports the paper's topologies (ring, d-regular, fully-connected, star),
dynamic per-round regular graphs via a ``PeerSampler``, Metropolis–Hastings
mixing weights, and graph-file I/O (edge list / adjacency list) so external
generators can be plugged in, exactly like DecentralizePy's graph files.

Two representations coexist:

* :class:`Graph` — dense (N, N) boolean adjacency.  Convenient for file
  I/O, runtime mutation, and spectral analysis; O(N²) memory.
* :class:`SparseTopology` — padded (N, D) neighbor + weight tables, D the
  max degree.  This is the form sparse graphs (ring, d-regular, the
  paper's dynamic 5-regular) are *executed* in: neighbor-indexed gossip is
  O(N·D·P) instead of O(N²·P), and a chunk of R dynamic rounds stages
  (R, N, D) tables instead of (R, N, N) matrices.  It is registered as a
  jax pytree so engines thread it straight through jit/scan.
"""
from __future__ import annotations

import dataclasses
import json
from typing import List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class Graph:
    """Undirected overlay graph over ``n`` nodes; adjacency as a bool matrix
    (no self loops stored; every node implicitly talks to itself)."""

    adj: np.ndarray  # (n, n) bool, symmetric, zero diagonal

    # -- constructors -------------------------------------------------------
    @staticmethod
    def ring(n: int) -> "Graph":
        adj = np.zeros((n, n), bool)
        idx = np.arange(n)
        adj[idx, (idx + 1) % n] = True
        adj[(idx + 1) % n, idx] = True
        return Graph(adj)

    @staticmethod
    def fully_connected(n: int) -> "Graph":
        adj = np.ones((n, n), bool)
        np.fill_diagonal(adj, False)
        return Graph(adj)

    @staticmethod
    def star(n: int, center: int = 0) -> "Graph":
        adj = np.zeros((n, n), bool)
        adj[center, :] = True
        adj[:, center] = True
        adj[center, center] = False
        return Graph(adj)

    @staticmethod
    def regular_circulant(n: int, degree: int) -> "Graph":
        """d-regular circulant graph: neighbors at fixed offsets ±1,±2,…
        (plus n/2 if degree is odd and n even).  These are the graphs whose
        gossip lowers to `collective_permute` on TPU (static offsets)."""
        assert 0 < degree < n
        adj = np.zeros((n, n), bool)
        idx = np.arange(n)
        offs = circulant_offsets(n, degree)
        for o in offs:
            adj[idx, (idx + o) % n] = True
            adj[(idx + o) % n, idx] = True
        return Graph(adj)

    @staticmethod
    def random_regular(n: int, degree: int, seed: int) -> "Graph":
        """Random d-regular graph — the paper's dynamic 5-regular per-round
        topology.  Vectorized configuration-model sampler (see
        :func:`random_regular_neighbors`); O(N·d) work, no Python edge loop."""
        nbr = random_regular_neighbors(n, degree, seed)
        adj = np.zeros((n, n), bool)
        adj[np.repeat(np.arange(n), degree), nbr.reshape(-1)] = True
        return Graph(adj)

    # -- file I/O (paper: 'topology specification' files) -------------------
    @staticmethod
    def from_edge_list(path: str, n: int) -> "Graph":
        adj = np.zeros((n, n), bool)
        with open(path) as f:
            for line in f:
                line = line.strip()
                if not line or line.startswith("#"):
                    continue
                a, b = map(int, line.split()[:2])
                adj[a, b] = adj[b, a] = True
        np.fill_diagonal(adj, False)
        return Graph(adj)

    @staticmethod
    def from_adjacency_json(path: str) -> "Graph":
        with open(path) as f:
            d = json.load(f)
        n = len(d)
        adj = np.zeros((n, n), bool)
        for k, nbrs in d.items():
            for j in nbrs:
                adj[int(k), int(j)] = adj[int(j), int(k)] = True
        np.fill_diagonal(adj, False)
        return Graph(adj)

    def to_edge_list(self, path: str) -> None:
        with open(path, "w") as f:
            for a, b in zip(*np.nonzero(np.triu(self.adj))):
                f.write(f"{a} {b}\n")

    # -- properties ----------------------------------------------------------
    @property
    def n(self) -> int:
        return self.adj.shape[0]

    def degrees(self) -> np.ndarray:
        return self.adj.sum(1)

    def neighbors(self, i: int) -> np.ndarray:
        return np.nonzero(self.adj[i])[0]

    def is_connected(self) -> bool:
        seen = {0}
        frontier = [0]
        while frontier:
            i = frontier.pop()
            for j in np.nonzero(self.adj[i])[0]:
                if j not in seen:
                    seen.add(int(j))
                    frontier.append(int(j))
        return len(seen) == self.n

    # -- runtime mutation (paper: graph modifiable at run time) --------------
    def add_edge(self, a: int, b: int) -> None:
        if a != b:
            self.adj[a, b] = self.adj[b, a] = True

    def remove_edge(self, a: int, b: int) -> None:
        self.adj[a, b] = self.adj[b, a] = False

    def neighbor_table(self) -> Tuple[np.ndarray, np.ndarray]:
        """Padded fixed-width neighbor lists — the static schedule the
        jittable secure-aggregation path and other vectorized per-receiver
        programs index with.  See :func:`neighbor_table`."""
        return neighbor_table(self.adj)

    # -- mixing weights -------------------------------------------------------
    def metropolis_hastings(self) -> np.ndarray:
        """Symmetric doubly-stochastic mixing matrix W (Xiao–Boyd):
        W_ij = 1 / (1 + max(deg_i, deg_j)) for edges, diagonal = residual."""
        deg = self.degrees()
        n = self.n
        W = np.zeros((n, n))
        ii, jj = np.nonzero(self.adj)
        W[ii, jj] = 1.0 / (1.0 + np.maximum(deg[ii], deg[jj]))
        W[np.arange(n), np.arange(n)] = 1.0 - W.sum(1)
        return W

    def uniform_weights(self) -> np.ndarray:
        """W_ij = 1/(deg_i+1) — row-stochastic equal-neighbor weights."""
        n = self.n
        W = self.adj / (self.degrees()[:, None] + 1.0)
        W[np.arange(n), np.arange(n)] = 1.0 / (self.degrees() + 1.0)
        return W

    def spectral_gap(self) -> float:
        w = np.linalg.eigvalsh(self.metropolis_hastings())
        return 1.0 - max(abs(w[0]), abs(w[-2]))


def neighbor_table(adj: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """(nbr (N, dmax) int32, valid (N, dmax) bool) padded neighbor lists.

    Rows shorter than dmax are padded with the node's own index (a harmless
    gather target) and marked invalid.  This rectangular form is what lets
    per-receiver programs (e.g. the vectorized secure-aggregation mask sum)
    run under vmap instead of Python loops over ragged neighbor sets.
    """
    n = adj.shape[0]
    dmax = max(int(adj.sum(1).max()) if n else 0, 1)
    nbr = np.tile(np.arange(n, dtype=np.int32)[:, None], (1, dmax))
    valid = np.zeros((n, dmax), bool)
    for r in range(n):
        ns = np.nonzero(adj[r])[0]
        nbr[r, : len(ns)] = ns
        valid[r, : len(ns)] = True
    return nbr, valid


def circulant_offsets(n: int, degree: int) -> List[int]:
    """Offsets of the d-regular circulant graph used by CirculantMixing."""
    offs = []
    for k in range(1, degree // 2 + 1):
        offs.append(k)
    if degree % 2 == 1:
        assert n % 2 == 0, "odd degree needs even n (antipodal offset)"
        offs.append(n // 2)
    return offs


def circulant_neighbor_table(n: int, degree: int) -> np.ndarray:
    """(N, degree) int32 neighbor table of the d-regular circulant graph,
    built directly from the offsets — O(N·d) work and memory, never the
    (N, N) adjacency.  Rows are sorted ascending, exactly the order
    :func:`neighbor_table` produces from ``Graph.regular_circulant(n, d)``
    (bitwise-equal tables; property-tested), which is what lets the
    population-scale engine instantiate 100k+-node overlays that the dense
    ``Graph`` constructor cannot hold.  Offsets are applied in int64 and
    the table narrows to int32 at the end, so node ids stay exact up to
    the int32 ceiling (property-tested at N >= 2^20)."""
    assert 0 < degree < n
    assert n <= np.iinfo(np.int32).max, "node ids are int32 on device"
    idx = np.arange(n, dtype=np.int64)[:, None]
    cols = []
    for o in circulant_offsets(n, degree):
        cols.append((idx + o) % n)
        if (2 * o) % n != 0:  # the antipodal offset is its own inverse
            cols.append((idx - o) % n)
    nbr = np.concatenate(cols, axis=1)
    nbr.sort(axis=1)
    return nbr.astype(np.int32)


def random_regular_neighbors(n: int, degree: int, seed: int) -> np.ndarray:
    """(N, degree) int32 neighbor table of a random simple d-regular graph.

    Vectorized configuration-model sampler: pair all N·d stubs at once,
    then repair self-loops/multi-edges by re-shuffling the offending stubs
    together with a batch of randomly chosen good edges (batched swap
    proposals) until the graph is simple.  Typically converges in a handful
    of numpy passes — this replaces the former Python double-edge-swap loop
    (~10 ms/round at N=256) that made dynamic topologies host-bound.

    Near-complete graphs (d approaching n-1) can defeat random re-pairing;
    after the repair budget the sampler falls back to the deterministic
    circulant + double-edge-swap walk (cheap at the small n·d where this
    regime occurs).  Same seed -> same graph either way.
    """
    assert 0 < degree < n and n * degree % 2 == 0, "n*degree must be even"
    assert n <= np.iinfo(np.int32).max, "node ids are int32 on device"
    # edge keys are a*n + b with a, b < n: int64 keeps them exact for any
    # int32-range n (a*n alone overflows int32 beyond n ~ 46341)
    rng = np.random.default_rng(seed)
    stubs = np.repeat(np.arange(n, dtype=np.int64), degree)
    rng.shuffle(stubs)
    e = stubs.reshape(-1, 2)
    for _ in range(500):
        a, b = e.min(1), e.max(1)
        key = a * n + b
        order = np.argsort(key, kind="stable")
        dup_sorted = np.zeros(key.shape, bool)
        sk = key[order]
        dup_sorted[1:] = sk[1:] == sk[:-1]  # 2nd+ copies of a repeated edge
        bad = a == b
        bad[order] |= dup_sorted
        n_bad = int(bad.sum())
        if n_bad == 0:
            src = np.concatenate([a, b])
            dst = np.concatenate([b, a])
            o = np.argsort(src, kind="stable")
            return dst[o].reshape(n, degree).astype(np.int32)
        good = np.nonzero(~bad)[0]
        k = min(good.size, max(2 * n_bad, 8))
        pool = np.concatenate([np.nonzero(bad)[0], rng.choice(good, k, replace=False)])
        mixed = e[pool].reshape(-1)
        rng.shuffle(mixed)
        e[pool] = mixed.reshape(-1, 2)
    return _random_regular_swaps(n, degree, rng)


def _random_regular_swaps(n: int, degree: int, rng) -> np.ndarray:
    """(N, degree) neighbor table via circulant start + random
    degree-preserving double-edge swaps — always yields a simple graph.
    Python loop; only the dense-small fallback of the vectorized sampler."""
    adj = Graph.regular_circulant(n, degree).adj
    edges = [tuple(e) for e in np.argwhere(np.triu(adj))]
    swaps, target = 0, 10 * len(edges)
    for _ in range(100 * target):
        if swaps >= target:
            break
        i, j = rng.integers(0, len(edges), 2)
        if i == j:
            continue
        (a, b), (c, d) = edges[i], edges[j]
        if rng.random() < 0.5:
            c, d = d, c
        if len({a, b, c, d}) < 4 or adj[a, c] or adj[b, d]:
            continue
        adj[a, b] = adj[b, a] = adj[c, d] = adj[d, c] = False
        adj[a, c] = adj[c, a] = adj[b, d] = adj[d, b] = True
        edges[i], edges[j] = (a, c), (b, d)
        swaps += 1
    ii, jj = np.nonzero(adj)
    return jj.reshape(n, degree).astype(np.int32)


def mh_weight_table(nbr: np.ndarray, valid: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Metropolis–Hastings (Xiao–Boyd) weights in neighbor-slot form.

    Returns (w (N, D) float32, w_self (N,) float32): w[i, k] is the weight
    node i gives its k-th neighbor (0 on padding slots), w_self the
    diagonal residual — the same W as ``Graph.metropolis_hastings`` without
    ever materializing (N, N).
    """
    deg = valid.sum(1).astype(np.float64)
    w = np.where(valid, 1.0 / (1.0 + np.maximum(deg[:, None], deg[nbr])), 0.0)
    w_self = 1.0 - w.sum(1)
    return w.astype(np.float32), w_self.astype(np.float32)


@dataclasses.dataclass(eq=False)
class SparseTopology:
    """Neighbor-indexed mixing topology: padded (N, D) tables, O(N·D).

    ``nbr[i, k]`` is node i's k-th neighbor (padded with i itself),
    ``w[i, k]`` its mixing weight (0 on padding — ``w > 0`` doubles as the
    validity mask since MH weights are strictly positive on edges), and
    ``w_self[i]`` the diagonal weight.  Leaves may carry extra *leading*
    axes — ``PeerSampler.sparse_stack`` stacks R rounds into (R, N, D)
    tables a scan chunk threads as traced values.  Registered as a jax
    pytree (see module bottom) so it can be passed through jit/scan.
    """

    nbr: np.ndarray     # (..., N, D) int32
    w: np.ndarray       # (..., N, D) float32
    w_self: np.ndarray  # (..., N) float32

    @property
    def n(self) -> int:
        return self.nbr.shape[-2]

    @property
    def dmax(self) -> int:
        return self.nbr.shape[-1]

    def stage_bytes(self) -> int:
        """Host->device bytes this representation stages (vs 4·N² dense)."""
        return int(self.nbr.nbytes + self.w.nbytes + self.w_self.nbytes)

    @staticmethod
    def from_graph(g: "Graph") -> "SparseTopology":
        """MH-weighted sparse form of a static graph."""
        nbr, valid = neighbor_table(g.adj)
        w, w_self = mh_weight_table(nbr, valid)
        return SparseTopology(nbr, w, w_self)

    @staticmethod
    def regular_circulant(n: int, degree: int) -> "SparseTopology":
        """MH-weighted d-regular circulant overlay built without the (N, N)
        adjacency — bitwise-equal to ``from_graph(Graph.regular_circulant)``
        but O(N·d), the population-scale (100k+ node) constructor."""
        nbr = circulant_neighbor_table(n, degree)
        w, w_self = mh_weight_table(nbr, np.ones(nbr.shape, bool))
        return SparseTopology(nbr, w, w_self)

    @staticmethod
    def from_neighbors(nbr: np.ndarray, valid: Optional[np.ndarray] = None) -> "SparseTopology":
        """MH-weighted sparse form from a padded neighbor table alone."""
        if valid is None:
            valid = np.ones(nbr.shape, bool)
        w, w_self = mh_weight_table(np.asarray(nbr), np.asarray(valid))
        return SparseTopology(np.asarray(nbr, np.int32), w, w_self)

    def to_dense(self) -> np.ndarray:
        """(N, N) float32 W — the equivalence oracle for the sparse path."""
        n, d = self.n, self.dmax
        W = np.zeros((n, n), np.float32)
        np.add.at(
            W,
            (np.repeat(np.arange(n), d), np.asarray(self.nbr).reshape(-1)),
            np.asarray(self.w).reshape(-1),
        )
        W[np.arange(n), np.arange(n)] += np.asarray(self.w_self)
        return W


def decompose_slot_permutations(topo: "SparseTopology") -> Optional["SparseTopology"]:
    """Slot-rebalance a padded (N, D) neighbor table so every *column* is a
    permutation of range(N) — the form multi-device gossip wants, because a
    permutation column lowers to one `collective_permute` per slot (one node
    per device) or a handful of device-rotation permutes (block-sharded).

    Raw tables don't have this property: node j may appear twice in column
    k (two receivers both keep j in slot k).  But the padded table *is*
    decomposable whenever the underlying graph is symmetric: counting the
    padding self-edges (nbr[i, k] = i, w = 0), every node appears exactly D
    times as a destination (D slots per row) and exactly D times as a
    source (deg(j) real occurrences + D - deg(j) self-pads), so the
    directed-edge bipartite multigraph is D-regular and König's edge-coloring
    theorem splits it into D perfect matchings.  Each matching becomes one
    rebalanced slot; weights (and the w=0 padding markers) travel with
    their edge, so ``to_dense`` of the result equals ``to_dense(topo)``
    exactly.

    Returns a new SparseTopology with the same (N, D) shape and the
    permutation-column property, or None when no perfect matching exists
    (asymmetric / irregular hand-built tables) — callers fall back to
    gather-based gossip.
    """
    nbr = np.asarray(topo.nbr)
    w = np.asarray(topo.w)
    if nbr.ndim != 2:
        return None
    n, d = nbr.shape
    import sys

    limit = sys.getrecursionlimit()
    sys.setrecursionlimit(max(limit, 8 * n + 100))
    try:
        # dst -> list of (src, slot) edges still unassigned
        adj: List[List[Tuple[int, int]]] = [
            [(int(nbr[i, k]), k) for k in range(d)] for i in range(n)
        ]
        new_nbr = np.empty_like(nbr)
        new_w = np.empty_like(w)
        for s in range(d):
            # Kuhn's augmenting-path perfect matching of dst -> src over the
            # remaining edges (multigraph: parallel edges are distinct entries).
            match_src = -np.ones(n, np.int64)   # src node -> dst it serves
            match_edge = np.zeros(n, np.int64)  # src node -> slot of that edge

            def try_assign(i, seen):
                for src, k in adj[i]:
                    if seen[src]:
                        continue
                    seen[src] = True
                    if match_src[src] < 0 or try_assign(int(match_src[src]), seen):
                        match_src[src] = i
                        match_edge[src] = k
                        return True
                return False

            for i in range(n):
                if not try_assign(i, np.zeros(n, bool)):
                    return None
            for src in range(n):
                i, k = int(match_src[src]), int(match_edge[src])
                new_nbr[i, s] = src
                new_w[i, s] = w[i, k]
                adj[i].remove((src, k))
        return SparseTopology(new_nbr, new_w, np.asarray(topo.w_self).copy())
    finally:
        sys.setrecursionlimit(limit)


def gather_rows(topo: "SparseTopology", rows) -> "SparseTopology":
    """Cohort row view of a padded topology: gather the (C, D) nbr/w and
    (C,) w_self rows of ``rows`` (traced global node ids).  ``nbr`` entries
    stay *global* ids — the cohort path resolves them against the full
    population state — so this is a view change, not a re-indexing.
    Traced/jittable (the population-scale hot-set gather)."""
    import jax.numpy as jnp

    return SparseTopology(
        jnp.take(topo.nbr, rows, axis=0),
        jnp.take(topo.w, rows, axis=0),
        jnp.take(topo.w_self, rows, axis=0),
    )


def sample_neighbor_slots(key, topo: "SparseTopology", rows=None):
    """(N,) int32 — one uniformly-random *valid* neighbor slot per node,
    the per-event sampling primitive of asynchronous (AD-PSGD-style)
    gossip: each fired node draws a single partner from its neighbor table
    for this event.

    Valid slots are ``w > 0`` (MH weights are strictly positive on real
    edges, zero on padding).  Draws are per-node keyed (fold_in of the
    global node id, like ``sharing._node_keys``) so sharded engines could
    reproduce them; ``rows`` overrides the ids (defaults to arange).  A
    node with no valid neighbor gets slot 0, whose padded entry is the
    node itself — a harmless self-gossip.  Traced/jittable.
    """
    import jax
    import jax.numpy as jnp

    valid = topo.w > 0                              # (N, D)
    deg = valid.sum(1)                              # (N,)
    ids = jnp.arange(valid.shape[0]) if rows is None else rows
    keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(ids)
    u = jax.vmap(lambda kk: jax.random.uniform(kk, ()))(keys)
    # target rank among the valid slots, then the slot holding that rank
    t = jnp.floor(u * jnp.maximum(deg, 1)).astype(jnp.int32)
    pos = jnp.cumsum(valid, axis=1) - 1             # rank of each valid slot
    hit = valid & (pos == t[:, None])
    return jnp.argmax(hit, axis=1).astype(jnp.int32)


def build_permute_schedule(nbr_perm: np.ndarray, ndev: int):
    """Per-slot rotation-grouped send/recv index tables for block-sharded
    permutation gossip.

    nbr_perm: (N, S) rebalanced table (every column a permutation — see
    :func:`decompose_slot_permutations`).  With N nodes block-sharded over
    ``ndev`` devices (B = N/ndev rows each), applying column s's permutation
    means device e must receive, from each device d, the rows x[src] with
    src on d and destination on e.  Grouping those transfers by the device
    *rotation* r = (e - d) mod ndev makes each group one
    `collective_permute` with the static pairing d -> (d + r) % ndev.

    Returns a list over slots of ``{r: (send_idx, recv_pos)}`` where
    send_idx[d] holds the *local* row indices device d sends under rotation
    r (padded with 0) and recv_pos[e] the local destination rows on the
    receiving device, padded with B so padded lanes scatter out of range
    (dropped via ``mode='drop'``).  Only rotations with traffic appear —
    a circulant overlay touches 1-2 rotations per slot, a random graph up
    to ndev (total payload per slot stays one block either way, which is
    the O(D·B·P) — instead of all-gather's O(N·P) — wire win).
    """
    n, s_slots = nbr_perm.shape
    assert n % ndev == 0, "node count must divide evenly across devices"
    b = n // ndev
    out = []
    for s in range(s_slots):
        src = nbr_perm[:, s].astype(np.int64)
        dst = np.arange(n, dtype=np.int64)
        rot = ((dst // b) - (src // b)) % ndev
        sched = {}
        for r in np.unique(rot):
            counts = []
            pairs = []
            for d in range(ndev):
                sel = (rot == r) & (src // b == d)
                i_sel = dst[sel]  # ascending — both sides enumerate this order
                pairs.append((src[sel] % b, i_sel % b))
                counts.append(i_sel.size)
            k = max(counts)
            if k == 0:
                continue
            send_idx = np.zeros((ndev, k), np.int32)
            recv_pos = np.full((ndev, k), b, np.int32)  # b == out of range
            for d, (s_loc, d_loc) in enumerate(pairs):
                send_idx[d, : s_loc.size] = s_loc
                e = (d + int(r)) % ndev
                recv_pos[e, : d_loc.size] = d_loc
            sched[int(r)] = (send_idx, recv_pos)
        out.append(sched)
    return out


@dataclasses.dataclass
class PeerSampler:
    """Centralized peer sampler (paper §3.2): instantiates a new random
    d-regular topology every round and hands each node its neighbor list."""

    n: int
    degree: int
    seed: int = 0

    def round_graph(self, round_idx: int) -> Graph:
        return Graph.random_regular(self.n, self.degree, self.seed * 100003 + round_idx)

    def round_weights(self, round_idx: int) -> np.ndarray:
        return self.round_graph(round_idx).metropolis_hastings()

    def weights_stack(self, start: int, n_rounds: int) -> np.ndarray:
        """(R, N, N) float32 stack of per-round mixing matrices for rounds
        [start, start + n_rounds) — the *dense* chunk form, kept for the
        ``mixing="dense"`` oracle path.  O(R·N²); prefer ``sparse_stack``."""
        return np.stack(
            [self.round_weights(start + r) for r in range(n_rounds)]
        ).astype(np.float32)

    def round_table(self, round_idx: int) -> SparseTopology:
        """Sparse (N, D) table for one round — same graph as ``round_graph``
        (identical seed chain), built without the (N, N) adjacency.  On a
        d-regular graph every MH weight is 1/(d+1)."""
        nbr = random_regular_neighbors(
            self.n, self.degree, self.seed * 100003 + round_idx
        )
        w = np.full(nbr.shape, 1.0 / (self.degree + 1.0), np.float32)
        w_self = np.full((self.n,), 1.0 / (self.degree + 1.0), np.float32)
        return SparseTopology(nbr, w, w_self)

    def sparse_stack(self, start: int, n_rounds: int) -> SparseTopology:
        """(R, N, D) sparse per-round topology stack for rounds
        [start, start + n_rounds) — O(R·N·d) staging, which is what lets
        scan chunks stay full-length at N=1024 (no W-stack byte cap)."""
        ts = [self.round_table(start + r) for r in range(n_rounds)]
        return SparseTopology(
            np.stack([t.nbr for t in ts]),
            np.stack([t.w for t in ts]),
            np.stack([t.w_self for t in ts]),
        )


# Register SparseTopology as a jax pytree so jit/scan thread it as a traced
# value (leaves: nbr, w, w_self).  Lazy-guarded: this module stays importable
# in numpy-only contexts.
try:  # pragma: no cover - exercised indirectly by every engine test
    import jax.tree_util as _jtu

    _jtu.register_pytree_node(
        SparseTopology,
        lambda t: ((t.nbr, t.w, t.w_self), None),
        lambda _, leaves: SparseTopology(*leaves),
    )
except Exception:  # pragma: no cover
    pass
