"""Fault injection + recovery — message-level faults for the scanned engine.

The paper's pitch is emulating *practical* network behaviors; before this
module the fault axis was coarse: node-level participation masks (churn)
plus a static goodput derating.  ``FaultPlan`` adds a declarative
message-level fault model that composes with churn inside the compiled
scan:

* **message loss** (``msg_loss``): each directed message i->j is lost
  independently with probability p, per round.  Lost edges are removed
  from the mixing operand and the freed weight renormalizes back to the
  receiver's diagonal (``sharing.edge_reweight`` /
  ``edge_reweight_sparse`` — rows stay stochastic, property-tested), so
  gossip degrades gracefully instead of corrupting the average.  The
  sender does not know the message was dropped: wire bytes and simulated
  link time are still spent.
* **crash/restart schedules** (``crashes``): declarative
  ``(node, crash_round, restart_round)`` windows compiled to host-side
  per-round (N,) availability masks that AND into the churn participation
  mask — a crashed node behaves exactly like a churn-down node (frozen
  state, rejoin-with-stale-model) but deterministically.
* **latency spikes** (``latency_spike_prob`` / ``latency_spike_factor``):
  per-edge, per-round multiplicative latency surges fed into the traced
  round-time formula (delivered messages just arrive late — survived by
  design, but the virtual clock pays).
* **payload corruption** (``corrupt_prob`` / ``corrupt_mode``): a node's
  post-mix parameter vector is corrupted in flight — ``"nan"`` overwrites
  with NaN, ``"bitflip"`` saturates the fp32 exponent bits (a burst flip;
  both are guaranteed non-finite, so the step guard's detection is
  exact).  The self-healing guard rolls detected rows back to the
  last-good (start-of-round) snapshot of params/opt/sharing state.

Every random draw is a pure function of ``(fault seed, absolute round,
global node id)`` through the jax threefry chain (the ``_node_keys``
idiom), so fault realizations are chunk-boundary invariant, identical
under any scan length, and — for per-edge masks — bitwise row-gatherable.

**Counters** (traced scan outputs, surfaced into ``history``):
``faults_injected`` (lost + spiked + corrupted), ``faults_detected``
(guard detections + failed async exchanges), ``faults_survived``
(absorbed by renormalization / late delivery), ``faults_recovered``
(rollbacks + successful retries), ``retry_total``, ``recovery_bytes``
(Bonawitz seed-recovery traffic, see ``core/secure.py``).  The
conservation invariant ``injected == detected + survived`` holds in every
scenario — no fault is silently dropped.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

# fold_in tags separating the independent per-(round, node) draw families
_TAG_EDGE = 0x10      # per-edge message-loss draws
_TAG_SPIKE = 0x11     # per-edge latency-spike draws
_TAG_CORRUPT = 0x12   # per-node payload-corruption draws

# the uniform fstats schema every scheduler emits per scanned step — a
# static pytree structure, so scan bodies and shard_map out_specs can be
# built without knowing which fault axes are active
STAT_KEYS = (
    "faults_injected",
    "faults_detected",
    "faults_survived",
    "faults_recovered",
    "retry_total",
    "recovery_bytes",
)


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Declarative fault-injection specification (``DLConfig.faults``).

    crashes: tuple of ``(node, crash_round, restart_round)`` — the node is
    down for rounds ``[crash_round, restart_round)``; a negative
    restart_round means it never comes back.
    """

    msg_loss: float = 0.0
    crashes: Tuple = ()
    latency_spike_prob: float = 0.0
    latency_spike_factor: float = 10.0
    corrupt_prob: float = 0.0
    corrupt_mode: str = "nan"   # nan | bitflip
    retry_backoff_s: float = 1e-3
    retry_backoff_cap: int = 6
    seed: int = 0

    # ------------------------------------------------------------------
    def validate(self) -> "FaultPlan":
        def bad(msg):
            raise ValueError(f"invalid FaultPlan: {msg}")

        if not 0.0 <= self.msg_loss < 1.0:
            bad(f"msg_loss must be in [0, 1), got {self.msg_loss}")
        if not 0.0 <= self.latency_spike_prob < 1.0:
            bad("latency_spike_prob must be in [0, 1), got "
                f"{self.latency_spike_prob}")
        if self.latency_spike_factor <= 0:
            bad("latency_spike_factor must be > 0")
        if not 0.0 <= self.corrupt_prob < 1.0:
            bad(f"corrupt_prob must be in [0, 1), got {self.corrupt_prob}")
        if self.corrupt_mode not in ("nan", "bitflip"):
            bad(f"unknown corrupt_mode {self.corrupt_mode!r} (nan|bitflip)")
        if self.retry_backoff_s < 0:
            bad("retry_backoff_s must be >= 0")
        if self.retry_backoff_cap < 0:
            bad("retry_backoff_cap must be >= 0")
        for c in self.crashes:
            if len(c) != 3:
                bad(f"crash entries are (node, crash_round, restart_round), "
                    f"got {c!r}")
            node, down, up = c
            if node < 0:
                bad(f"crash node must be >= 0, got {node}")
            if down < 0:
                bad(f"crash_round must be >= 0, got {down}")
            if 0 <= up <= down:
                bad(f"restart_round must be > crash_round (or < 0 for "
                    f"never), got {c!r}")
        return self

    # ------------------------------------------------------------------
    @property
    def edge_faults(self) -> bool:
        """Any per-edge fault axis active (loss or latency spikes)."""
        return self.msg_loss > 0 or self.latency_spike_prob > 0

    @property
    def any_faults(self) -> bool:
        return (
            self.edge_faults or self.corrupt_prob > 0 or bool(self.crashes)
        )


# ---------------------------------------------------------------------------
# key chain
# ---------------------------------------------------------------------------

def fault_key(plan: FaultPlan, engine_seed: int):
    """The plan's PRF root key — folded off its own seed plus the engine
    seed, so fault draws never collide with gossip/batch draws."""
    return jax.random.fold_in(jax.random.key(plan.seed + 0xFA11), engine_seed)


def _row_keys(key, tag: int, rnd, rows):
    """Per-(round, global node id) keys for one draw family — the pure
    function of (tag, round, id) that makes fault realizations chunk- and
    gather-invariant (``rnd`` and ``rows`` may be traced)."""
    k = jax.random.fold_in(jax.random.fold_in(key, tag), rnd)
    return jax.vmap(lambda i: jax.random.fold_in(k, i))(rows)


# ---------------------------------------------------------------------------
# crash schedules (host-side, staged like the churn participation mask)
# ---------------------------------------------------------------------------

def crash_mask(plan: FaultPlan, n: int, start: int, n_rounds: int) -> np.ndarray:
    """(R, N) {0,1} availability from the declarative crash schedule for
    absolute rounds [start, start + n_rounds) — a pure function of the
    absolute round index, so any chunking slices the same schedule."""
    m = np.ones((n_rounds, n), np.float32)
    r = np.arange(start, start + n_rounds)
    for node, down, up in plan.crashes:
        dead = (r >= down) if up < 0 else (r >= down) & (r < up)
        m[dead, node] = 0.0
    return m


# ---------------------------------------------------------------------------
# traced per-round draws
# ---------------------------------------------------------------------------

def edge_draws(key, rnd, rows, d: int, plan: FaultPlan):
    """Per-edge fault draws for the given receiver rows: ``(live, spike)``
    both (len(rows), d) float32 {0,1} — ``live[i, s]`` is 1 when the
    message on row i's slot s arrives, ``spike[i, s]`` 1 when its latency
    spikes.  Keyed per (round, receiver id): the realization is a pure
    function of global coordinates (bitwise row-gatherable)."""
    ids = jnp.asarray(rows)
    ul = jax.vmap(lambda k_: jax.random.uniform(k_, (d,)))(
        _row_keys(key, _TAG_EDGE, rnd, ids)
    )
    us = jax.vmap(lambda k_: jax.random.uniform(k_, (d,)))(
        _row_keys(key, _TAG_SPIKE, rnd, ids)
    )
    live = (ul >= plan.msg_loss).astype(jnp.float32)
    spike = (us < plan.latency_spike_prob).astype(jnp.float32)
    return live, spike


def corruption_mask(key, rnd, rows, plan: FaultPlan):
    """(len(rows),) float32 {0,1} — 1 marks a node whose post-mix payload
    is corrupted this round."""
    ids = jnp.asarray(rows)
    u = jax.vmap(lambda k_: jax.random.uniform(k_, ()))(
        _row_keys(key, _TAG_CORRUPT, rnd, ids)
    )
    return (u < plan.corrupt_prob).astype(jnp.float32)


def corrupt_rows(X2, cmask, mode: str):
    """Inject payload corruption into the masked rows of the post-mix
    (N, P) matrix.  Both modes produce non-finite values, so the step
    guard's non-finite detection is exact (detected == corrupted)."""
    if mode == "nan":
        bad = jnp.full_like(X2, jnp.nan)
    else:  # bitflip: a burst flip saturating the exponent -> inf/nan
        u = jax.lax.bitcast_convert_type(X2.astype(jnp.float32), jnp.uint32)
        bad = jax.lax.bitcast_convert_type(
            u | jnp.uint32(0x7F800000), jnp.float32
        ).astype(X2.dtype)
    return jnp.where(cmask[:, None] > 0, bad, X2)


def nonfinite_rows(X2):
    """(N,) float32 {0,1} — 1 marks rows containing any non-finite value
    (the step guard's detection pass)."""
    return 1.0 - jnp.all(jnp.isfinite(X2), axis=1).astype(jnp.float32)


def zero_stats():
    """The all-zero fstats record — the static per-step schema every
    scheduler emits (see ``STAT_KEYS``)."""
    return {k: jnp.float32(0.0) for k in STAT_KEYS}


def retry_backoff_delay(retries, base_s: float, cap: int):
    """Seconds to wait before retry number ``retries``: base·2^min(k, cap).

    THE retry policy, shared by both clocks: the async scheduler charges it
    on the *virtual* clock after a lost pairwise exchange (``retries`` is a
    traced per-node float32 vector there), and the real-network runtime
    (``repro.runtime``) sleeps it on the *wall* clock between socket send
    attempts (``retries`` is a host int).  One formula, so the simulated
    and measured retry behaviors cannot drift apart.
    """
    if isinstance(retries, jax.Array):
        return base_s * 2.0 ** jnp.minimum(
            retries.astype(jnp.float32), jnp.float32(cap)
        )
    return base_s * 2.0 ** min(int(retries), int(cap))
