# The paper's primary contribution: decentralized learning as a composable
# JAX feature — overlay topologies, gossip mixing, sparsified sharing,
# secure aggregation, and the node/runner that ties them together.
from repro.core.topology import (
    Graph,
    PeerSampler,
    SparseTopology,
    build_permute_schedule,
    circulant_neighbor_table,
    circulant_offsets,
    decompose_slot_permutations,
    gather_rows,
    mh_weight_table,
    neighbor_table,
    random_regular_neighbors,
    sample_neighbor_slots,
)
from repro.core.mixing import (
    NodeShard,
    PermuteSchedule,
    ShardedDense,
    ShardedTopology,
    apply_W,
    gossip_pair_avg,
    mix_dense,
    mix_payload,
    mix_payload_masked,
    mix_payload_strided,
    mix_sparse,
    mix_sparse_shmap,
    mix_fully,
    mix_circulant,
    mix_circulant_shmap,
    mixing_bytes_per_node,
)
from repro.core.sharing import (
    FullSharing,
    RandomKSharing,
    TopKSharing,
    ChocoSGD,
    QuantizedSharing,
    edge_reweight,
    edge_reweight_sparse,
    make_sharing,
    participation_deg_eff,
    participation_reweight,
    participation_reweight_rows,
    participation_reweight_sparse,
    sparse_aggregate,
)
from repro.core.faults import FaultPlan
from repro.core.network import (
    LinkSpec,
    Mapping,
    NetworkModel,
    gathered_round_times,
    node_round_times,
    paper_testbed,
    straggler_compute_times,
    wan_deployment,
)
from repro.core.secure import SecureAggregation
from repro.core.engine import RoundEngine, build_network
from repro.core.steps import RoundSteps
from repro.core.scheduler import (
    AsyncScheduler,
    LocalScheduler,
    Scheduler,
    SyncScheduler,
    make_scheduler,
)
from repro.core.node import DLConfig, DecentralizedRunner, build_graph
from repro.core.federated import FLConfig, FederatedRunner
