"""Network emulation (paper §2.1 'network bandwidth, latency, and packet
drop' + §2.2 *Mapping* + Fig. 3b wall-clock axis; Kollaps-style shaping is
the paper's declared future work — this is the built-in model).

DecentralizePy's one-node-one-process design makes per-node network
emulation natural; here the per-round *simulated wall-clock* is computed
from a declarative model:

  round_time(node) = compute_time
                   + sum_over_neighbors(message_bytes / link_bw + latency)
  round_time       = max over nodes (synchronous rounds, stragglers bind)

Links are classified by the Mapping (same machine -> loopback, different
machine -> LAN/WAN), so the same experiment can be 'deployed' on a 16-host
LAN or a WAN by swapping the NetworkModel — the paper's portability claim.
Packet drop is modeled as goodput derating (TCP retransmission).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.core.topology import Graph


@dataclasses.dataclass(frozen=True)
class LinkSpec:
    bandwidth_bps: float    # payload bandwidth
    latency_s: float
    drop_rate: float = 0.0  # fraction; derates goodput ~1/(1-p)

    def goodput_bps(self) -> float:
        """Payload goodput after drop-rate derating (TCP retransmission)."""
        return self.bandwidth_bps * max(1.0 - self.drop_rate, 1e-3)

    def transfer_time(self, nbytes: float) -> float:
        return self.latency_s + nbytes * 8.0 / self.goodput_bps()


LOOPBACK = LinkSpec(bandwidth_bps=20e9, latency_s=20e-6)
LAN = LinkSpec(bandwidth_bps=1e9, latency_s=200e-6)          # paper's cluster
WAN = LinkSpec(bandwidth_bps=100e6, latency_s=30e-3, drop_rate=0.001)


@dataclasses.dataclass
class Mapping:
    """Node -> machine assignment (paper §2.2 Mapping).  Default: the
    paper's round-robin over 16 machines."""

    n_nodes: int
    n_machines: int = 16

    def machine(self, node: int) -> int:
        return node % self.n_machines

    def same_machine(self, a: int, b: int) -> bool:
        return self.machine(a) == self.machine(b)


@dataclasses.dataclass
class NetworkModel:
    mapping: Mapping
    local: LinkSpec = LOOPBACK
    remote: LinkSpec = LAN

    def link(self, a: int, b: int) -> LinkSpec:
        return self.local if self.mapping.same_machine(a, b) else self.remote

    def matrices(self) -> "tuple[np.ndarray, np.ndarray]":
        """(latency_s, goodput_bps) as (N, N) float32 matrices over all
        ordered node pairs — the dense form the RoundEngine closes over so
        per-round simulated wall-clock is a *traced* output of the scanned
        chunk instead of a per-round host computation."""
        n = self.mapping.n_nodes
        machines = np.array([self.mapping.machine(i) for i in range(n)])
        same = machines[:, None] == machines[None, :]
        lat = np.where(same, self.local.latency_s, self.remote.latency_s)
        gp = np.where(same, self.local.goodput_bps(), self.remote.goodput_bps())
        return lat.astype(np.float32), gp.astype(np.float32)

    def round_time(
        self,
        graph: Graph,
        bytes_per_edge: float,
        compute_time_s: float = 0.0,
        parallel_sends: bool = False,
    ) -> float:
        """Simulated synchronous-round wall-clock.

        bytes_per_edge: serialized message size one node sends one neighbor.
        parallel_sends: True models per-link dedicated NICs (sends overlap);
        False (default) serializes a node's sends on its uplink, which is
        what makes fully-connected rounds take ~degree x longer (Fig. 3b).
        """
        n = graph.n
        times = np.zeros(n)
        for i in range(n):
            sends = [
                self.link(i, int(j)).transfer_time(bytes_per_edge)
                for j in graph.neighbors(i)
            ]
            if not sends:
                comm = 0.0
            elif parallel_sends:
                comm = max(sends)
            else:
                comm = sum(sends)
            times[i] = compute_time_s + comm
        return float(times.max())

    def experiment_time(self, graph: Graph, bytes_per_edge: float,
                        compute_time_s: float, rounds: int) -> float:
        return rounds * self.round_time(graph, bytes_per_edge, compute_time_s)


def paper_testbed(n_nodes: int) -> NetworkModel:
    """The paper's 16-machine LAN cluster."""
    return NetworkModel(Mapping(n_nodes, 16), LOOPBACK, LAN)


def wan_deployment(n_nodes: int) -> NetworkModel:
    """Geo-distributed deployment (every node its own machine, WAN links)."""
    return NetworkModel(Mapping(n_nodes, n_nodes), LOOPBACK, WAN)
