"""Network emulation (paper §2.1 'network bandwidth, latency, and packet
drop' + §2.2 *Mapping* + Fig. 3b wall-clock axis; Kollaps-style shaping is
the paper's declared future work — this is the built-in model).

DecentralizePy's one-node-one-process design makes per-node network
emulation natural; here the per-round *simulated wall-clock* is computed
from a declarative model:

  round_time(node) = compute_time
                   + sum_over_neighbors(message_bytes / link_bw + latency)
  round_time       = max over nodes (synchronous rounds, stragglers bind)

Links are classified by the Mapping (same machine -> loopback, different
machine -> LAN/WAN), so the same experiment can be 'deployed' on a 16-host
LAN or a WAN by swapping the NetworkModel — the paper's portability claim.
Packet drop is modeled as goodput derating (TCP retransmission).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Union

import numpy as np

from repro.core.topology import Graph


def node_round_times(A, lat, goodput, per_edge_bytes, compute_time,
                     parallel_sends: bool = False):
    """Per-node round time — THE compute+comm formula, shared by the host
    ``NetworkModel.round_time`` (numpy) and the engine's traced step layer
    (jax; ``steps.RoundSteps.round_time``), so the Python model and the
    compiled model cannot drift (equivalence-tested in tests/test_network.py).

        t_edge  = latency + bytes * 8 / goodput          per live edge
        comm_i  = sum_j t_edge[i,j]   (serialized uplink sends)
                | max_j t_edge[i,j]   (parallel_sends: dedicated NICs)
        time_i  = compute_time_i + comm_i

    A: (N, E) {0,1} live-edge mask; lat/goodput: matching link matrices
    (dense (N, N) or neighbor-gathered (N, D)); per_edge_bytes: scalar
    message size; compute_time: scalar or per-node (N,) seconds.  Works on
    numpy and jax arrays alike (pure operator arithmetic).
    """
    t_edge = lat + per_edge_bytes * 8.0 / goodput
    masked = A * t_edge
    comm = masked.max(axis=1) if parallel_sends else masked.sum(axis=1)
    return compute_time + comm


def gathered_round_times(lat, goodput, rows, nbr, A, per_edge_bytes,
                         compute_time, parallel_sends: bool = False):
    """:func:`node_round_times` for a *gathered row subset* — the cohort
    form of the per-node time draw.  ``rows`` are (C,) global node ids,
    ``nbr`` their (C, D) global neighbor ids: the (C, D) link submatrices
    are gathered as ``lat[rows[:, None], nbr]`` — elementwise-identical to
    indexing the full (N, D) gather at those rows, so the result is the
    bitwise (C,)-row slice of the dense formula (equivalence-tested).

    A: (C, D) {0,1} live-edge mask over the gathered slots; compute_time:
    (C,) gathered per-node compute seconds.
    """
    r = rows[:, None]
    return node_round_times(
        A, lat[r, nbr], goodput[r, nbr], per_edge_bytes, compute_time,
        parallel_sends,
    )


def straggler_compute_times(
    n: int,
    base_s: float,
    factor: float = 1.0,
    frac: float = 0.0,
    seed: int = 0,
) -> np.ndarray:
    """Heterogeneous per-node compute times: a seeded ``frac`` fraction of
    nodes are stragglers running at ``factor`` x the base compute time —
    the paper's missing system-heterogeneity axis (and the distribution the
    async-vs-sync benchmark gate runs under).  Returns (N,) float32."""
    ct = np.full((n,), base_s, np.float32)
    k = int(round(frac * n))
    if k > 0 and factor != 1.0:
        idx = np.random.default_rng(seed).choice(n, size=k, replace=False)
        ct[idx] = base_s * factor
    return ct


@dataclasses.dataclass(frozen=True)
class LinkSpec:
    bandwidth_bps: float    # payload bandwidth
    latency_s: float
    drop_rate: float = 0.0  # fraction; derates goodput ~1/(1-p)

    def __post_init__(self):
        if not 0.0 <= self.drop_rate < 1.0:
            raise ValueError(
                f"LinkSpec.drop_rate must be in [0, 1), got {self.drop_rate}: "
                "a drop rate of 1 means the link never delivers — model a "
                "dead link by removing the edge (or a lossy one via "
                "FaultPlan.msg_loss)"
            )

    def goodput_bps(self) -> float:
        """Payload goodput after drop-rate derating (TCP retransmission)."""
        return self.bandwidth_bps * (1.0 - self.drop_rate)

    def transfer_time(self, nbytes: float) -> float:
        return self.latency_s + nbytes * 8.0 / self.goodput_bps()


LOOPBACK = LinkSpec(bandwidth_bps=20e9, latency_s=20e-6)
LAN = LinkSpec(bandwidth_bps=1e9, latency_s=200e-6)          # paper's cluster
WAN = LinkSpec(bandwidth_bps=100e6, latency_s=30e-3, drop_rate=0.001)


@dataclasses.dataclass
class Mapping:
    """Node -> machine assignment (paper §2.2 Mapping).  Default: the
    paper's round-robin over 16 machines."""

    n_nodes: int
    n_machines: int = 16

    def machine(self, node: int) -> int:
        return node % self.n_machines

    def same_machine(self, a: int, b: int) -> bool:
        return self.machine(a) == self.machine(b)


@dataclasses.dataclass
class NetworkModel:
    mapping: Mapping
    local: LinkSpec = LOOPBACK
    remote: LinkSpec = LAN
    # per-node local compute seconds, (N,) — the heterogeneous-time axis
    # (stragglers = heavy-tailed entries).  None means homogeneous zero;
    # a scalar passed to round_time overrides/broadcasts as before.
    compute_time_s: Optional[np.ndarray] = None
    # calibrated per-round runtime overhead (framing, syscalls, barrier
    # slack) fitted by ``runtime.calibrate`` — added once per round in
    # :meth:`round_time`, never in :meth:`node_times`, so a default-0
    # model is unchanged everywhere (including the traced-time oracle)
    overhead_s: float = 0.0

    def link(self, a: int, b: int) -> LinkSpec:
        return self.local if self.mapping.same_machine(a, b) else self.remote

    def matrices(self, dtype=np.float32) -> "tuple[np.ndarray, np.ndarray]":
        """(latency_s, goodput_bps) as (N, N) matrices over all ordered
        node pairs — the dense form the RoundEngine closes over so
        per-round simulated wall-clock is a *traced* output of the scanned
        chunk instead of a per-round host computation."""
        n = self.mapping.n_nodes
        machines = np.array([self.mapping.machine(i) for i in range(n)])
        same = machines[:, None] == machines[None, :]
        lat = np.where(same, self.local.latency_s, self.remote.latency_s)
        gp = np.where(same, self.local.goodput_bps(), self.remote.goodput_bps())
        return lat.astype(dtype), gp.astype(dtype)

    def node_times(
        self,
        graph: Graph,
        bytes_per_edge: float,
        compute_time_s: Union[float, np.ndarray, None] = None,
        parallel_sends: bool = False,
    ) -> np.ndarray:
        """(N,) per-node round times through the shared
        :func:`node_round_times` formula (float64 host arithmetic).
        compute_time_s: scalar or (N,) array; None uses the model's
        per-node ``compute_time_s`` (or 0)."""
        if compute_time_s is None:
            compute_time_s = (
                0.0 if self.compute_time_s is None
                else np.asarray(self.compute_time_s, np.float64)
            )
        lat, gp = self.matrices(dtype=np.float64)
        A = graph.adj.astype(np.float64)
        return node_round_times(
            A, lat, gp, float(bytes_per_edge), compute_time_s, parallel_sends
        )

    def round_time(
        self,
        graph: Graph,
        bytes_per_edge: float,
        compute_time_s: Union[float, np.ndarray, None] = None,
        parallel_sends: bool = False,
    ) -> float:
        """Simulated synchronous-round wall-clock: the max of
        :meth:`node_times` (the round barrier — stragglers bind).

        bytes_per_edge: serialized message size one node sends one neighbor.
        parallel_sends: True models per-link dedicated NICs (sends overlap);
        False (default) serializes a node's sends on its uplink, which is
        what makes fully-connected rounds take ~degree x longer (Fig. 3b).
        """
        return float(
            self.node_times(graph, bytes_per_edge, compute_time_s,
                            parallel_sends).max()
        ) + self.overhead_s

    def experiment_time(self, graph: Graph, bytes_per_edge: float,
                        compute_time_s, rounds: int) -> float:
        return rounds * self.round_time(graph, bytes_per_edge, compute_time_s)


def paper_testbed(n_nodes: int) -> NetworkModel:
    """The paper's 16-machine LAN cluster."""
    return NetworkModel(Mapping(n_nodes, 16), LOOPBACK, LAN)


def wan_deployment(n_nodes: int) -> NetworkModel:
    """Geo-distributed deployment (every node its own machine, WAN links)."""
    return NetworkModel(Mapping(n_nodes, n_nodes), LOOPBACK, WAN)


def localhost_deployment(n_nodes: int) -> NetworkModel:
    """Every node on ONE machine, all links loopback — the modeled twin of
    the ``backend='processes'`` localhost runs.  ``runtime.calibrate``
    compares this model's :meth:`NetworkModel.round_time` against measured
    per-round wall-clock, which is what makes the simulated bench gates
    defensible as predictions rather than definitions."""
    return NetworkModel(Mapping(n_nodes, 1), LOOPBACK, LOOPBACK)


def load_calibration_fit(path: str = "results/calibration.json"
                         ) -> "Optional[dict]":
    """The ``fit`` block ``runtime.calibrate`` recorded (``alpha_s`` per-
    round constant, ``beta_s_per_byte`` residual slope), or None when no
    sweep has been run on this machine."""
    import json
    import os

    if not os.path.exists(path):
        return None
    try:
        with open(path) as f:
            return json.load(f).get("fit")
    except (OSError, ValueError):
        return None


def calibrated_localhost(n_nodes: int,
                         path: str = "results/calibration.json"
                         ) -> NetworkModel:
    """:func:`localhost_deployment` with the measured per-round overhead
    constant folded in (identity when no calibration file exists)."""
    fit = load_calibration_fit(path)
    model = localhost_deployment(n_nodes)
    if fit:
        model.overhead_s = float(fit.get("alpha_s", 0.0))
    return model
