"""Scheduler layer — who fires when, and what time means.

The engine's execution model is split in two (``core/steps.py`` holds the
other half): the **step layer** defines what one round does (local SGD,
share/mix, per-node round time) as pure jittable functions, and this
**scheduler layer** owns time and activation semantics.  Three schedulers
implement ``DLConfig.semantics``:

* ``sync`` (:class:`SyncScheduler`) — the synchronous round barrier:
  every node mixes in lockstep, the simulated round time is the max over
  nodes (stragglers bind the whole network).  This is bit-for-bit the
  pre-split engine — the equivalence oracle the other semantics are
  tested against — including the legacy per-round dispatch
  (``chunk_rounds=0``) and the node-sharded ``shard_map`` chunk.
* ``local`` (:class:`LocalScheduler`) — same lockstep *trajectories* (the
  mixing math is identical, property-tested), but time is a per-node
  virtual clock with a **neighborhood barrier**: node i starts round r
  when it and its live neighbors have finished round r-1, so non-adjacent
  stragglers no longer bind each other.  Simulated experiment time is the
  max final clock — a lower bound pairing with sync's global barrier.
* ``async`` (:class:`AsyncScheduler`) — event-driven gossip on a virtual
  clock (the AD-PSGD family, Lian et al. 2018).  Each node's next event
  completes at ``t_next[i]``; every scanned step executes one event
  *cohort* (all nodes whose events land in the earliest time slice).  A
  fired node takes a local step, then gossip-averages against
  possibly-stale neighbor params — pairwise (one sampled partner,
  ``mixing.gossip_pair_avg``) or neighborhood (its whole W row through
  the sharing strategy) — and reschedules at
  ``t_next[i] += compute_time[i] + comm_time[i]``.  Staleness
  (event-count gap of the rows read), per-node virtual wall-clock, and
  event counts are traced scan outputs surfaced via
  :meth:`extra_metrics` into ``history`` / ``results.json``.

Activation masks are also owned here: iid per-node participation (the
original churn axis), **machine-correlated failures** (all nodes mapped
to a down machine drop together, ``DLConfig.churn_machines``), and the
rejoin-with-stale-model rule — a down node freezes its params/optimizer/
sharing state and re-enters with them (no silent reweight-away); under
``async`` its pending events burn their time slots while it is down.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.mixing import ShardedDense, ShardedTopology, gossip_pair_avg
from repro.core.sharing import participation_reweight, participation_reweight_sparse
from repro.core.steps import node_where
from repro.core.topology import SparseTopology
from repro.utils.compat import shard_map
from repro.utils.pytree import tree_unvector, tree_vector

# cap on the pre-gathered (R, L, N, B, ...) batch stack; above it the scan
# falls back to gathering each round's batch inside the loop body.
_BATCH_STACK_BYTES_CAP = 256 * 1024 * 1024


def _live_edges(W, act):
    """Live off-diagonal edges of a mixing operand, pruned by a churn mask.

    Returns ``(live, gather)``: ``live`` is the {True} edge mask — (N, D)
    over neighbor slots for a ``SparseTopology``, (N, N) for a dense W —
    and ``gather(v)`` aligns a per-node (N,) vector with it (neighbor
    gather / row broadcast).  One derivation of edge liveness shared by
    the local scheduler's neighborhood barrier and the async scheduler's
    staleness accounting."""
    if isinstance(W, SparseTopology):
        live = W.w > 0
        if act is not None:
            live = live & (act[:, None] > 0) & (jnp.take(act, W.nbr, axis=0) > 0)
        return live, lambda v: jnp.take(v, W.nbr, axis=0)
    n = W.shape[0]
    live = W * (1.0 - jnp.eye(n, dtype=W.dtype)) > 0
    if act is not None:
        live = live & (act[:, None] > 0) & (act[None, :] > 0)
    return live, lambda v: jnp.broadcast_to(v[None, :], (n, n))


class Scheduler:
    """Base: host-side chunk staging + activation-mask machinery shared by
    every semantics.  ``eng`` is the owning RoundEngine — the scheduler
    reads its static resources (batcher, topology operands, steps) and
    writes its running metrics (bytes_sent, sim_time_s)."""

    semantics = "sync"

    def __init__(self, eng):
        self.eng = eng

    # ------------------------------------------------------------------
    # activation masks (churn)
    # ------------------------------------------------------------------
    def participation_mask(self, start: int, n_rounds: int) -> np.ndarray:
        """(R, N) {0,1} activity masks for rounds [start, start+n_rounds).

        One batched counter-based draw (splitmix64 hash over (seed,
        absolute round, unit)) — each round's randomness is a pure function
        of its absolute index, so masks are chunk-boundary invariant, with
        no per-round ``default_rng`` host loop.  The draw unit is the node
        (iid churn) or, with ``churn_machines=M`` set, the *machine*: all
        nodes round-robin-mapped to a down machine drop together —
        correlated machine-level failures.  The final column holds each
        round's fallback draw: if every unit sampled down, one (uniform
        via that draw) is kept alive.
        """
        dl = self.eng.dl
        n = dl.n_nodes
        if dl.participation >= 1.0:
            return np.ones((n_rounds, n), np.float32)
        m_units = dl.churn_machines if dl.churn_machines > 0 else n
        with np.errstate(over="ignore"):  # uint64 wraparound is the point
            x = (
                np.uint64(dl.seed * 1_000_003 + 7_919)
                * np.uint64(0x9E3779B97F4A7C15)
                + np.arange(start, start + n_rounds, dtype=np.uint64)[:, None]
                * np.uint64(0xBF58476D1CE4E5B9)
                + np.arange(m_units + 1, dtype=np.uint64)[None, :]
                * np.uint64(0x94D049BB133111EB)
            )
            x ^= x >> np.uint64(30)
            x *= np.uint64(0xBF58476D1CE4E5B9)
            x ^= x >> np.uint64(27)
            x *= np.uint64(0x94D049BB133111EB)
            x ^= x >> np.uint64(31)
        u = (x >> np.uint64(11)).astype(np.float64) * (2.0 ** -53)
        up = u[:, :m_units] < dl.participation
        dead = ~up.any(1)
        if dead.any():  # keep at least one unit alive per round
            up[dead, (u[dead, m_units] * m_units).astype(np.int64)] = True
        if dl.churn_machines > 0:
            # broadcast machine up/down to its round-robin node set
            up = up[:, np.arange(n) % dl.churn_machines]
        return up.astype(np.float32)

    # ------------------------------------------------------------------
    # host-side chunk staging (shared)
    # ------------------------------------------------------------------
    def _stage_xs(self, start: int, n_rounds: int) -> Dict:
        """Per-round scan inputs for rounds [start, start+n_rounds): always
        ``rnd`` (R,) int32 and the chunk's batches — pre-gathered ``bx``/
        ``by`` under the byte cap, raw ``idx`` above it; plus ``mix`` for
        dynamic topologies ((R,N,N) W stack in dense mode, (R,N,D)
        SparseTopology stack in sparse mode) and ``act`` (R,N) with
        churn."""
        eng = self.eng
        dl = eng.dl
        idx = eng.batcher.chunk_indices(start, n_rounds, dl.local_steps)
        xs = {"rnd": jnp.asarray(np.arange(start, start + n_rounds, dtype=np.int32))}
        item_bytes = eng._dev_x.nbytes // max(eng._dev_x.shape[0], 1)
        if idx.size * item_bytes <= _BATCH_STACK_BYTES_CAP:
            # pre-stack the whole chunk's batches on device: one gather per
            # chunk instead of one per scanned round
            idx_dev = jnp.asarray(idx)
            xs["bx"] = jnp.take(eng._dev_x, idx_dev, axis=0)  # (R, L, N, B, ...)
            xs["by"] = jnp.take(eng._dev_y, idx_dev, axis=0)
        else:
            xs["idx"] = jnp.asarray(idx)
        if eng.sampler is not None:
            if eng.mix_mode == "sparse":
                st = eng.sampler.sparse_stack(start, n_rounds)  # (R, N, D)
                xs["mix"] = SparseTopology(
                    jnp.asarray(st.nbr), jnp.asarray(st.w), jnp.asarray(st.w_self)
                )
                staged = st.stage_bytes()
            else:
                Wst = eng.sampler.weights_stack(start, n_rounds)  # (R, N, N)
                xs["mix"] = jnp.asarray(Wst)
                staged = int(Wst.nbytes)
            eng.topo_stage_bytes_peak = max(eng.topo_stage_bytes_peak, staged)
        if dl.participation < 1.0:
            xs["act"] = jnp.asarray(self.participation_mask(start, n_rounds))
        return xs

    def _round_batch(self, xs_r):
        """One round's (L, N, B, ...) batches inside a scan body: the
        pre-gathered slice, or an in-loop gather for oversized chunks."""
        if "bx" in xs_r:
            return xs_r["bx"], xs_r["by"]
        bx = jnp.take(self.eng._dev_x, xs_r["idx"], axis=0)
        by = jnp.take(self.eng._dev_y, xs_r["idx"], axis=0)
        return bx, by

    # ------------------------------------------------------------------
    def run_span(self, start: int, n_rounds: int) -> None:
        raise NotImplementedError

    def run_legacy_round(self, rnd: int) -> None:
        raise ValueError(
            f"legacy per-round dispatch (chunk_rounds=0) supports "
            f"semantics='sync' only, not {self.semantics!r}"
        )

    def extra_metrics(self) -> Dict:
        """Semantics-specific metrics merged into each history record."""
        return {}


class SyncScheduler(Scheduler):
    """The synchronous round barrier — today's scanned chunk, verbatim:
    every node mixes each round, per-round simulated time is the max over
    nodes, and metrics accumulate as sums.  Also owns the legacy per-round
    dispatch and the node-sharded shard_map chunk."""

    semantics = "sync"

    def __init__(self, eng):
        super().__init__(eng)
        self._chunk_jit = jax.jit(self._chunk_fn)
        self._legacy_jit = jax.jit(self._legacy_round)
        self._shard_jit_cache: Dict = {}

    # -- scan bodies ----------------------------------------------------
    def _chunk_fn(self, params, opt_state, share_state, xs):
        """R rounds in one lax.scan.  ``xs`` is a dict of per-round scan
        inputs (see ``_stage_xs``); static topologies capture one
        device-constant mixing operand."""
        eng = self.eng

        def body(carry, xs_r):
            params, opt_state, share_state = carry
            W = xs_r["mix"] if "mix" in xs_r else eng._mix_static
            act = xs_r.get("act")
            bx, by = self._round_batch(xs_r)
            params, opt_state, share_state, nbytes, sim_t = eng.steps.train_and_mix(
                params, opt_state, share_state, bx, by, W, act, xs_r["rnd"]
            )
            return (params, opt_state, share_state), (nbytes, sim_t)

        carry, (nbytes, times) = jax.lax.scan(
            body, (params, opt_state, share_state), xs
        )
        return carry + (nbytes, times)

    def _legacy_round(self, params, opt_state, share_state, bx, by, W, active, rnd):
        return self.eng.steps.train_and_mix(
            params, opt_state, share_state, bx, by, W, active, rnd
        )

    # -- node-sharded chunk (shard_map over the device mesh) -------------
    def _wrap_mix(self, mix):
        """Sharded mixing operand for one round inside the shard body.

        ``mix`` is the scanned per-round operand (this device's row block,
        cut by the in_specs) or None for static topologies — those capture
        the full replicated tables and slice the local block by device
        index, keeping the wrapper shapes identical either way."""
        eng = self.eng
        shard = eng._shard
        if mix is None:
            if eng.mix_mode == "sparse":
                st = eng._mix_static
                topo_l = SparseTopology(
                    shard.local(st.nbr), shard.local(st.w), shard.local(st.w_self)
                )
                return ShardedTopology(topo_l, shard, eng._perm_sched)
            return ShardedDense(shard.local(eng._mix_static), shard)
        if isinstance(mix, SparseTopology):
            return ShardedTopology(mix, shard, None)
        return ShardedDense(mix, shard)

    def _chunk_fn_sharded(self, params, opt_state, share_state, xs):
        """The scanned chunk, run inside shard_map: every node-stacked
        carry/input is this device's (B, ...) row block; gossip crosses
        devices through the sharded mixing operand (collective_permute
        slots or all-gather — see mixing.ShardedTopology) and the per-round
        scalar metrics are psum/pmax-reduced so each device returns the
        same global values."""
        eng = self.eng

        def body(carry, xs_r):
            params, opt_state, share_state = carry
            W = self._wrap_mix(xs_r.get("mix"))
            act = xs_r.get("act")
            bx, by = self._round_batch(xs_r)
            params, opt_state, share_state, nbytes, sim_t = eng.steps.train_and_mix(
                params, opt_state, share_state, bx, by, W, act, xs_r["rnd"],
                shard=eng._shard,
            )
            return (params, opt_state, share_state), (nbytes, sim_t)

        carry, (nbytes, times) = jax.lax.scan(
            body, (params, opt_state, share_state), xs
        )
        return carry + (nbytes, times)

    def _xs_pspec(self, xs):
        """Per-leaf PartitionSpecs for the scan-input dict: the node axis of
        every leaf maps to the mesh 'nodes' axis, everything else is
        replicated."""

        def spec(path, leaf):
            key = path[0].key
            if key == "rnd":
                return P()
            if key in ("bx", "by", "idx"):  # (R, L, N, B, ...)
                return P(None, None, "nodes", *((None,) * (leaf.ndim - 3)))
            if key == "act":                # (R, N)
                return P(None, "nodes")
            if key == "mix":                # (R, N, N) W or (R, N, D)/(R, N) tables
                return P(None, "nodes", *((None,) * (leaf.ndim - 2)))
            raise KeyError(f"unknown scan input {key!r}")

        return jax.tree_util.tree_map_with_path(spec, xs)

    def _node_pspec(self, tree):
        return jax.tree_util.tree_map(
            lambda l: P("nodes", *((None,) * (l.ndim - 1))), tree
        )

    def _sharded_chunk_call(self, xs):
        """shard_map-wrap + jit the chunk for this xs structure (cached —
        structures recur: full chunks and the pre-eval remainder)."""
        eng = self.eng
        leaves, treedef = jax.tree_util.tree_flatten(xs)
        key = (treedef, tuple(l.ndim for l in leaves))
        fn = self._shard_jit_cache.get(key)
        if fn is None:
            state_specs = (
                self._node_pspec(eng.params),
                self._node_pspec(eng.opt_state),
                self._node_pspec(eng.share_state),
            )
            fn = jax.jit(
                shard_map(
                    self._chunk_fn_sharded,
                    mesh=eng._mesh,
                    in_specs=state_specs + (self._xs_pspec(xs),),
                    out_specs=state_specs + (P(), P()),
                    check_vma=False,
                )
            )
            self._shard_jit_cache[key] = fn
        return fn(eng.params, eng.opt_state, eng.share_state, xs)

    # -- host-side dispatch ----------------------------------------------
    def run_span(self, start: int, n_rounds: int) -> None:
        eng = self.eng
        xs = self._stage_xs(start, n_rounds)
        if eng.sharded:
            out = self._sharded_chunk_call(xs)
        else:
            out = self._chunk_jit(eng.params, eng.opt_state, eng.share_state, xs)
        eng.params, eng.opt_state, eng.share_state, nbytes, times = out
        # ONE host sync per chunk for all per-round metrics
        eng.bytes_sent += float(np.asarray(nbytes, np.float64).sum())
        eng.sim_time_s += float(np.asarray(times, np.float64).sum())

    def _round_mix(self, rnd: int):
        """Device mixing operand for one round (legacy per-round dispatch):
        dense (N, N) W or SparseTopology neighbor tables, matching the mode
        the scanned path uses so both execute the identical workload."""
        eng = self.eng
        if eng.sampler is None:
            return eng._mix_static
        if eng.mix_mode == "sparse":
            t = eng.sampler.round_table(rnd)
            return SparseTopology(
                jnp.asarray(t.nbr), jnp.asarray(t.w), jnp.asarray(t.w_self)
            )
        return jnp.asarray(eng.sampler.round_weights(rnd).astype(np.float32))

    def run_legacy_round(self, rnd: int) -> None:
        """Per-round dispatch baseline: host-gathered full batches, one jit
        call and one metric sync per round.  Samples the same round_indices
        as the scanned path so both execute the identical workload."""
        eng = self.eng
        dl = eng.dl
        idx = eng.batcher.round_indices(rnd, dl.local_steps)  # (L, N, B)
        bx = jnp.asarray(eng.batcher.x[idx])
        by = jnp.asarray(eng.batcher.y[idx])
        W = self._round_mix(rnd)
        act = (
            jnp.asarray(self.participation_mask(rnd, 1)[0])
            if dl.participation < 1.0 else None
        )
        out = self._legacy_jit(
            eng.params, eng.opt_state, eng.share_state, bx, by, W, act,
            jnp.int32(rnd),
        )
        eng.params, eng.opt_state, eng.share_state, nbytes, sim_t = out
        eng.bytes_sent += float(nbytes)
        eng.sim_time_s += float(sim_t)


class LocalScheduler(Scheduler):
    """Neighborhood-barrier semantics: trajectories identical to sync (the
    mixing math is untouched), but each node runs on its own virtual
    clock — node i starts round r once it and its *live neighbors* have
    finished round r-1 (a gossip exchange needs both endpoints), then adds
    its own compute+comm time.  No global barrier: stragglers only delay
    their graph neighborhood, so the simulated experiment time (max final
    clock) lower-bounds sync's ``sum of per-round maxima``.  Down (churn)
    nodes stall their clock and rejoin where they left off."""

    semantics = "local"

    def __init__(self, eng):
        super().__init__(eng)
        self._clock = jnp.zeros((eng.dl.n_nodes,), jnp.float32)
        self._chunk_jit = jax.jit(self._chunk_fn)

    def _nbr_clock_max(self, W, act, clock):
        """Per-node max of live-neighbor clocks (-inf when none)."""
        live, gather = _live_edges(W, act)
        return jnp.max(jnp.where(live, gather(clock), -jnp.inf), axis=1)

    def _chunk_fn(self, params, opt_state, share_state, clock, xs):
        eng = self.eng

        def body(carry, xs_r):
            params, opt_state, share_state, clock = carry
            W = xs_r["mix"] if "mix" in xs_r else eng._mix_static
            act = xs_r.get("act")
            bx, by = self._round_batch(xs_r)
            params, opt_state, share_state, nbytes, node_t = eng.steps.train_and_mix(
                params, opt_state, share_state, bx, by, W, act, xs_r["rnd"],
                time_reduce="none",
            )
            # neighborhood barrier: wait for the live neighbors' previous
            # round, then run this one (node_t is 0 for down nodes, whose
            # clocks stall until they rejoin)
            ready = jnp.maximum(clock, self._nbr_clock_max(W, act, clock))
            if act is not None:
                clock = jnp.where(act > 0, ready + node_t, clock)
            else:
                clock = ready + node_t
            return (params, opt_state, share_state, clock), (nbytes, jnp.max(clock))

        carry, (nbytes, times) = jax.lax.scan(
            body, (params, opt_state, share_state, clock), xs
        )
        return carry + (nbytes, times)

    def run_span(self, start: int, n_rounds: int) -> None:
        eng = self.eng
        xs = self._stage_xs(start, n_rounds)
        out = self._chunk_jit(
            eng.params, eng.opt_state, eng.share_state, self._clock, xs
        )
        eng.params, eng.opt_state, eng.share_state, self._clock, nbytes, times = out
        eng.bytes_sent += float(np.asarray(nbytes, np.float64).sum())
        # the virtual clock is a running maximum, not a per-round sum
        eng.sim_time_s = float(np.asarray(times)[-1])

    def extra_metrics(self) -> Dict:
        clock = np.asarray(self._clock, np.float64)
        return {
            "semantics": "local",
            "vclock_min_s": float(clock.min()),
            "vclock_median_s": float(np.median(clock)),
            "vclock_max_s": float(clock.max()),
        }


class AsyncScheduler(Scheduler):
    """Event-driven asynchronous gossip on a virtual clock (AD-PSGD
    family).  One scanned step = one event *cohort*: the nodes whose next
    event completes inside the earliest ``async_slice_s`` window all fire
    — each takes a local step on that cohort's batch row, gossips against
    possibly-stale neighbor rows, and reschedules its next event at
    ``+compute_time[i] + comm_time[i]`` on its own clock.  Nodes with
    equal event durations therefore stay in lockstep cohorts (with
    homogeneous times and full participation, every cohort is exactly one
    synchronous round — the reduction the equivalence tests pin), while a
    10x straggler fires ~10x fewer events per unit of virtual time.

    Gossip forms (``DLConfig.async_gossip``):

    * ``"neighborhood"`` — the fired node reads its whole (churn-pruned) W
      row through the configured sharing strategy; non-fired rows are
      frozen (one-sided read, no write conflicts).
    * ``"pairwise"`` — classic AD-PSGD: one uniformly-sampled partner per
      event (``topology.sample_neighbor_slots``), ``x_i' = (x_i+x_j)/2``;
      a sampled partner that is churn-down blocks the exchange (the node
      keeps its local step and retries at its next event).

    Down (churn) nodes burn their event slots — virtual time passes, no
    work happens, params freeze — and rejoin with their stale model.
    Traced per-cohort outputs: bytes, the cohort's virtual time (max
    completion among fired events), fired-event count, and the staleness
    (event-count gap receiver-minus-sender over the rows read) sum/max —
    aggregated into :meth:`extra_metrics` for ``history``/results.
    """

    semantics = "async"

    def __init__(self, eng):
        super().__init__(eng)
        n = eng.dl.n_nodes
        # completion time of each node's next local step (first event =
        # one local compute; each event's comm delays the one after it)
        self._t_next = jnp.asarray(eng._compute_node, jnp.float32)
        self._vclock = jnp.zeros((n,), jnp.float32)   # last fired completion
        self._events = jnp.zeros((n,), jnp.int32)     # model version counter
        self._stale_sum = 0.0
        self._stale_n = 0.0
        self._stale_max = 0.0
        self._fired_total = 0.0
        self._chunk_jit = jax.jit(self._chunk_fn)

    # -- traced cohort helpers -------------------------------------------
    def _pair_comm(self, partner, ok):
        """Per-event comm seconds of a pairwise exchange (one message of
        the full parameter vector from the sampled partner)."""
        eng = self.eng
        if eng.steps.lat is None:
            return jnp.zeros_like(ok)
        rows = jnp.arange(partner.shape[0])
        nbytes = eng.n_params * jnp.dtype(jnp.float32).itemsize
        t = (
            eng.steps.lat[rows, partner]
            + nbytes * 8.0 / eng.steps.goodput[rows, partner]
        )
        return ok * t

    def _cohort(self, carry, xs_r):
        eng = self.eng
        dl = eng.dl
        params, opt_state, share_state, t_next, vclock, events = carry
        W = xs_r["mix"] if "mix" in xs_r else eng._mix_static
        act = xs_r.get("act")
        rnd = xs_r["rnd"]
        # --- cohort membership on the virtual clock ----------------------
        t_min = jnp.min(t_next)
        fire = (t_next <= t_min + dl.async_slice_s).astype(jnp.float32)
        actv = fire * act if act is not None else fire  # fired AND up
        # --- local step (down/unfired nodes frozen) ----------------------
        bx, by = self._round_batch(xs_r)
        params, opt_state = eng.steps.local_train(
            params, opt_state, bx, by, actv
        )
        X = jax.vmap(tree_vector)(params)
        key = jax.random.fold_in(eng.steps.base_key, rnd)
        ev_f = events.astype(jnp.float32)
        if dl.async_gossip == "pairwise":
            X2, partner, ok = gossip_pair_avg(W, X, key, fire=actv, act=act)
            share_state_new = share_state
            stale_i = ok * jnp.maximum(ev_f - jnp.take(ev_f, partner), 0.0)
            n_reads = ok
            msg = jnp.float32(eng.n_params * np.dtype(np.float32).itemsize)
            nbytes = jnp.sum(ok) * msg / dl.n_nodes
            comm = self._pair_comm(partner, ok)
        else:  # neighborhood: the full (churn-pruned) W row, stale reads
            if act is not None:
                if isinstance(W, SparseTopology):
                    Wm, deg_eff = participation_reweight_sparse(W, act)
                else:
                    Wm, deg_eff = participation_reweight(W, act)
            else:
                Wm, deg_eff = W, eng.steps.mean_degree
            X2_all, share_state_new, nbytes_rate = eng.sharing.round(
                X, Wm, share_state, key, degree=deg_eff, rnd=rnd
            )
            X2 = jnp.where(actv[:, None] > 0, X2_all, X)
            # staleness over the rows actually read: the same live-edge
            # derivation the local scheduler's barrier uses (the churn
            # reweight above zeroes exactly these down-endpoint slots)
            live_b, gather = _live_edges(W, act)
            live = live_b.astype(jnp.float32)
            gap = jnp.maximum(ev_f[:, None] - gather(ev_f), 0.0)
            cnt = jnp.maximum(live.sum(1), 1.0)
            stale_i = actv * (live * gap).sum(1) / cnt
            n_reads = actv
            # only fired nodes' exchanges hit the wire this cohort
            nbytes = jnp.asarray(nbytes_rate, jnp.float32) * jnp.sum(actv) / dl.n_nodes
            if eng.steps.lat is not None:
                comm = eng.steps.round_time(
                    Wm, None, jnp.asarray(nbytes_rate, jnp.float32), deg_eff,
                    reduce="none",
                )
                comm = comm - eng.steps.compute_node  # compute added below
            else:
                comm = jnp.zeros((dl.n_nodes,), jnp.float32)
        share_state = node_where(actv, share_state_new, share_state)
        new_params = jax.vmap(lambda v: tree_unvector(v, eng.template))(
            X2.astype(X.dtype)
        )
        params = node_where(actv, new_params, params)
        # --- clock advance ------------------------------------------------
        dur = eng.steps.compute_node + comm
        vclock = jnp.where(fire > 0, t_next, vclock)
        t_next = t_next + fire * dur  # down-but-scheduled slots burn time too
        events = events + actv.astype(jnp.int32)
        out = (
            nbytes,
            jnp.max(vclock),
            jnp.sum(actv),
            jnp.sum(stale_i),
            jnp.sum(n_reads),
            jnp.max(stale_i),
        )
        return (params, opt_state, share_state, t_next, vclock, events), out

    def _chunk_fn(self, params, opt_state, share_state, t_next, vclock, events, xs):
        carry, outs = jax.lax.scan(
            self._cohort, (params, opt_state, share_state, t_next, vclock, events), xs
        )
        return carry + outs

    # -- host-side dispatch ----------------------------------------------
    def run_span(self, start: int, n_rounds: int) -> None:
        eng = self.eng
        xs = self._stage_xs(start, n_rounds)
        out = self._chunk_jit(
            eng.params, eng.opt_state, eng.share_state,
            self._t_next, self._vclock, self._events, xs,
        )
        (eng.params, eng.opt_state, eng.share_state,
         self._t_next, self._vclock, self._events,
         nbytes, t_virt, fired, stale_sum, stale_n, stale_max) = out
        eng.bytes_sent += float(np.asarray(nbytes, np.float64).sum())
        # the virtual clock is a running maximum, not a per-cohort sum
        eng.sim_time_s = float(np.asarray(t_virt)[-1])
        self._fired_total += float(np.asarray(fired, np.float64).sum())
        self._stale_sum += float(np.asarray(stale_sum, np.float64).sum())
        self._stale_n += float(np.asarray(stale_n, np.float64).sum())
        self._stale_max = max(self._stale_max, float(np.asarray(stale_max).max()))

    def extra_metrics(self) -> Dict:
        events = np.asarray(self._events, np.float64)
        vclock = np.asarray(self._vclock, np.float64)
        return {
            "semantics": "async",
            "events_total": int(events.sum()),
            "events_min": int(events.min()),
            "events_max": int(events.max()),
            "vclock_min_s": float(vclock.min()),
            "vclock_median_s": float(np.median(vclock)),
            "vclock_max_s": float(vclock.max()),
            "staleness_mean": self._stale_sum / max(self._stale_n, 1.0),
            "staleness_max": self._stale_max,
        }


def make_scheduler(eng) -> Scheduler:
    sem = eng.dl.semantics
    if sem == "sync":
        return SyncScheduler(eng)
    if sem == "local":
        return LocalScheduler(eng)
    if sem == "async":
        return AsyncScheduler(eng)
    raise ValueError(f"unknown semantics {sem!r} (sync|local|async)")
