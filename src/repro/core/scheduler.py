"""Scheduler layer — who fires when, and what time means.

The engine's execution model is split in two (``core/steps.py`` holds the
other half): the **step layer** defines what one round does (local SGD,
share/mix, per-node round time) as pure jittable functions, and this
**scheduler layer** owns time and activation semantics.  Three schedulers
implement ``DLConfig.semantics``:

* ``sync`` (:class:`SyncScheduler`) — the synchronous round barrier:
  every node mixes in lockstep, the simulated round time is the max over
  nodes (stragglers bind the whole network).  This is bit-for-bit the
  pre-split engine — the equivalence oracle the other semantics are
  tested against — including the legacy per-round dispatch
  (``chunk_rounds=0``) and the node-sharded ``shard_map`` chunk.
* ``local`` (:class:`LocalScheduler`) — same lockstep *trajectories* (the
  mixing math is identical, property-tested), but time is a per-node
  virtual clock with a **neighborhood barrier**: node i starts round r
  when it and its live neighbors have finished round r-1, so non-adjacent
  stragglers no longer bind each other.  Simulated experiment time is the
  max final clock — a lower bound pairing with sync's global barrier.
* ``async`` (:class:`AsyncScheduler`) — event-driven gossip on a virtual
  clock (the AD-PSGD family, Lian et al. 2018).  Each node's next event
  completes at ``t_next[i]``; every scanned step executes one event
  *cohort* (all nodes whose events land in the earliest time slice).  A
  fired node takes a local step, then gossip-averages against
  possibly-stale neighbor params — pairwise (one sampled partner,
  ``mixing.gossip_pair_avg``) or neighborhood (its whole W row through
  the sharing strategy) — and reschedules at
  ``t_next[i] += compute_time[i] + comm_time[i]``.  Staleness
  (event-count gap of the rows read), per-node virtual wall-clock, and
  event counts are traced scan outputs surfaced via
  :meth:`extra_metrics` into ``history`` / ``results.json``.

Activation masks are also owned here: iid per-node participation (the
original churn axis), **machine-correlated failures** (all nodes mapped
to a down machine drop together, ``DLConfig.churn_machines``), and the
rejoin-with-stale-model rule — a down node freezes its params/optimizer/
sharing state and re-enters with them (no silent reweight-away); under
``async`` its pending events burn their time slots while it is down.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import compression as compression_lib
from repro.core import faults as faults_lib
from repro.core.mixing import ShardedDense, ShardedTopology, gossip_pair_avg
from repro.data.loader import node_batch_indices
from repro.core.sharing import (
    edge_reweight,
    edge_reweight_sparse,
    participation_deg_eff,
    participation_reweight,
    participation_reweight_rows,
    participation_reweight_sparse,
)
from repro.core.steps import node_where
from repro.core.topology import SparseTopology, gather_rows, sample_neighbor_slots
from repro.utils.compat import shard_map
from repro.utils.pytree import tree_unvector, tree_vector

# cap on the pre-gathered (R, L, N, B, ...) batch stack; above it the scan
# falls back to gathering each round's batch inside the loop body.
_BATCH_STACK_BYTES_CAP = 256 * 1024 * 1024

# virtual-clock rebase threshold (cohort-path fp32 hygiene): once every
# pending event time exceeds this, the async scheduler subtracts a common
# fp32 shift from t_next/vclock on device and carries it in a float64 host
# offset.  fp32 *running maxima* over the clock are exact (max never
# rounds), but the clock itself advances by running sums — at t ~ 2^16 s
# the fp32 ulp is ~2^-7 s, so millisecond-scale event durations start to
# be absorbed; rebasing keeps the accumulating magnitudes small.  The
# threshold is far above any existing test horizon, so trajectories below
# it are untouched bitwise.
_REBASE_T_S = 65536.0

# selection='auto' switches the cohort path from the flat O(N) min+top_k
# selection to the hierarchical segment-minimum selection above this node
# count: below it the flat scan over t_next is already cheap next to the
# O(C·(d+1)·P) gossip, above it the O(N) selection layer starts to bind
# (the million-node regime the hierarchy exists for).
_HIER_AUTO_MIN_N = 1 << 18


def _live_edges(W, act):
    """Live off-diagonal edges of a mixing operand, pruned by a churn mask.

    Returns ``(live, gather)``: ``live`` is the {True} edge mask — (N, D)
    over neighbor slots for a ``SparseTopology``, (N, N) for a dense W —
    and ``gather(v)`` aligns a per-node (N,) vector with it (neighbor
    gather / row broadcast).  One derivation of edge liveness shared by
    the local scheduler's neighborhood barrier and the async scheduler's
    staleness accounting."""
    if isinstance(W, SparseTopology):
        live = W.w > 0
        if act is not None:
            live = live & (act[:, None] > 0) & (jnp.take(act, W.nbr, axis=0) > 0)
        return live, lambda v: jnp.take(v, W.nbr, axis=0)
    n = W.shape[0]
    live = W * (1.0 - jnp.eye(n, dtype=W.dtype)) > 0
    if act is not None:
        live = live & (act[:, None] > 0) & (act[None, :] > 0)
    return live, lambda v: jnp.broadcast_to(v[None, :], (n, n))


class Scheduler:
    """Base: host-side chunk staging + activation-mask machinery shared by
    every semantics.  ``eng`` is the owning RoundEngine — the scheduler
    reads its static resources (batcher, topology operands, steps) and
    writes its running metrics (bytes_sent, sim_time_s)."""

    semantics = "sync"

    def __init__(self, eng):
        self.eng = eng
        # 'node' batch keying: indices are a device-side pure function of
        # (seed, round, global id) — no host staging, no (R, L, N, B) stack
        self._node_keying = eng.dl.batch_keying == "node"
        # host-side float64 fault-counter totals (every scanned step emits
        # the static fstats schema; zeros when no fault axis is active)
        self._fault_totals = {k: 0.0 for k in faults_lib.STAT_KEYS}
        self._track_faults = eng.dl.faults is not None or (
            eng.dl.secure and eng.dl.secure_recovery
        )

    # ------------------------------------------------------------------
    # activation masks (churn)
    # ------------------------------------------------------------------
    def participation_mask(self, start: int, n_rounds: int) -> np.ndarray:
        """(R, N) {0,1} activity masks for rounds [start, start+n_rounds).

        One batched counter-based draw (splitmix64 hash over (seed,
        absolute round, unit)) — each round's randomness is a pure function
        of its absolute index, so masks are chunk-boundary invariant, with
        no per-round ``default_rng`` host loop.  The draw unit is the node
        (iid churn) or, with ``churn_machines=M`` set, the *machine*: all
        nodes round-robin-mapped to a down machine drop together —
        correlated machine-level failures.  The final column holds each
        round's fallback draw: if every unit sampled down, one (uniform
        via that draw) is kept alive.
        """
        dl = self.eng.dl
        n = dl.n_nodes
        if dl.participation >= 1.0:
            return np.ones((n_rounds, n), np.float32)
        m_units = dl.churn_machines if dl.churn_machines > 0 else n
        with np.errstate(over="ignore"):  # uint64 wraparound is the point
            x = (
                np.uint64(dl.seed * 1_000_003 + 7_919)
                * np.uint64(0x9E3779B97F4A7C15)
                + np.arange(start, start + n_rounds, dtype=np.uint64)[:, None]
                * np.uint64(0xBF58476D1CE4E5B9)
                + np.arange(m_units + 1, dtype=np.uint64)[None, :]
                * np.uint64(0x94D049BB133111EB)
            )
            x ^= x >> np.uint64(30)
            x *= np.uint64(0xBF58476D1CE4E5B9)
            x ^= x >> np.uint64(27)
            x *= np.uint64(0x94D049BB133111EB)
            x ^= x >> np.uint64(31)
        u = (x >> np.uint64(11)).astype(np.float64) * (2.0 ** -53)
        up = u[:, :m_units] < dl.participation
        dead = ~up.any(1)
        if dead.any():  # keep at least one unit alive per round
            up[dead, (u[dead, m_units] * m_units).astype(np.int64)] = True
        if dl.churn_machines > 0:
            # broadcast machine up/down to its round-robin node set
            up = up[:, np.arange(n) % dl.churn_machines]
        return up.astype(np.float32)

    # ------------------------------------------------------------------
    # host-side chunk staging (shared)
    # ------------------------------------------------------------------
    def _stage_xs(self, start: int, n_rounds: int) -> Dict:
        """Per-round scan inputs for rounds [start, start+n_rounds): always
        ``rnd`` (R,) int32 and the chunk's batches — pre-gathered ``bx``/
        ``by`` under the byte cap, raw ``idx`` above it; plus ``mix`` for
        dynamic topologies ((R,N,N) W stack in dense mode, (R,N,D)
        SparseTopology stack in sparse mode) and ``act`` (R,N) with
        churn."""
        eng = self.eng
        dl = eng.dl
        xs = {"rnd": jnp.asarray(np.arange(start, start + n_rounds, dtype=np.int32))}
        if not self._node_keying:
            idx = eng.batcher.chunk_indices(start, n_rounds, dl.local_steps)
            item_bytes = eng._dev_x.nbytes // max(eng._dev_x.shape[0], 1)
            if idx.size * item_bytes <= _BATCH_STACK_BYTES_CAP:
                # pre-stack the whole chunk's batches on device: one gather
                # per chunk instead of one per scanned round
                idx_dev = jnp.asarray(idx)
                xs["bx"] = jnp.take(eng._dev_x, idx_dev, axis=0)  # (R, L, N, B, ...)
                xs["by"] = jnp.take(eng._dev_y, idx_dev, axis=0)
            else:
                xs["idx"] = jnp.asarray(idx)
        # ('node' keying stages nothing: each scan step derives its rows'
        # indices from (rnd, id) in-body — see _node_indices)
        if eng.sampler is not None:
            if eng.mix_mode == "sparse":
                st = eng.sampler.sparse_stack(start, n_rounds)  # (R, N, D)
                xs["mix"] = SparseTopology(
                    jnp.asarray(st.nbr), jnp.asarray(st.w), jnp.asarray(st.w_self)
                )
                staged = st.stage_bytes()
            else:
                Wst = eng.sampler.weights_stack(start, n_rounds)  # (R, N, N)
                xs["mix"] = jnp.asarray(Wst)
                staged = int(Wst.nbytes)
            eng.topo_stage_bytes_peak = max(eng.topo_stage_bytes_peak, staged)
        plan = dl.faults
        crashes = plan is not None and bool(plan.crashes)
        if dl.participation < 1.0 or crashes:
            m = self.participation_mask(start, n_rounds)
            if crashes:
                # declarative crash/restart windows AND into the churn
                # draw: a crashed node is exactly a churn-down node, but
                # deterministic (both masks are pure functions of the
                # absolute round, so chunking stays invariant)
                cm = faults_lib.crash_mask(plan, dl.n_nodes, start, n_rounds)
                m = m * cm
                # crash downtime counts as injected faults absorbed by the
                # participation machinery (frozen state, reweighted mixing)
                down = float((1.0 - cm).sum())
                self._fault_totals["faults_injected"] += down
                self._fault_totals["faults_survived"] += down
            xs["act"] = jnp.asarray(m)
        return xs

    def _node_indices(self, rnd, ids):
        """(L, |ids|, B) sample indices for the given global node ids under
        'node' keying — a traced pure function of (round, id), so a
        gathered cohort samples bitwise what the dense oracle samples."""
        eng = self.eng
        return node_batch_indices(
            eng._batch_key, rnd, ids, eng._dev_lens, eng._dev_parts_pad,
            eng.dl.local_steps, eng.dl.batch_size,
        )

    def _round_batch(self, xs_r):
        """One round's (L, N, B, ...) batches inside a scan body: the
        pre-gathered slice, an in-loop gather for oversized chunks, or an
        in-body derivation under 'node' keying."""
        if "bx" in xs_r:
            return xs_r["bx"], xs_r["by"]
        if self._node_keying:
            idx = self._node_indices(
                xs_r["rnd"], jnp.arange(self.eng.dl.n_nodes)
            )
        else:
            idx = xs_r["idx"]
        bx = jnp.take(self.eng._dev_x, idx, axis=0)
        by = jnp.take(self.eng._dev_y, idx, axis=0)
        return bx, by

    # ------------------------------------------------------------------
    def run_span(self, start: int, n_rounds: int) -> None:
        raise NotImplementedError

    def run_legacy_round(self, rnd: int) -> None:
        raise ValueError(
            f"legacy per-round dispatch (chunk_rounds=0) supports "
            f"semantics='sync' only, not {self.semantics!r}"
        )

    def _accum_faults(self, fstats) -> None:
        """Fold one dispatch's fstats (dict of (R,) stacked arrays, or
        scalars from the legacy path) into the host float64 totals."""
        for k in faults_lib.STAT_KEYS:
            self._fault_totals[k] += float(
                np.asarray(fstats[k], np.float64).sum()
            )

    def eval_params(self):
        """The params tree evaluation should run on.  Identity for every
        semantics except the quantized-cold async path, which stores
        ``eng.params`` compressed and decodes here."""
        return self.eng.params

    def extra_metrics(self) -> Dict:
        """Semantics-specific metrics merged into each history record.
        The base contributes the running fault counters whenever a fault
        axis (FaultPlan or secure recovery) is active."""
        if not self._track_faults:
            return {}
        t = self._fault_totals
        m = {k: int(round(t[k])) for k in faults_lib.STAT_KEYS
             if k != "recovery_bytes"}
        m["recovery_bytes"] = t["recovery_bytes"]
        return m


class SyncScheduler(Scheduler):
    """The synchronous round barrier — today's scanned chunk, verbatim:
    every node mixes each round, per-round simulated time is the max over
    nodes, and metrics accumulate as sums.  Also owns the legacy per-round
    dispatch and the node-sharded shard_map chunk."""

    semantics = "sync"

    def __init__(self, eng):
        super().__init__(eng)
        self._chunk_jit = jax.jit(self._chunk_fn)
        self._legacy_jit = jax.jit(self._legacy_round)
        self._shard_jit_cache: Dict = {}

    # -- scan bodies ----------------------------------------------------
    def _chunk_fn(self, params, opt_state, share_state, xs):
        """R rounds in one lax.scan.  ``xs`` is a dict of per-round scan
        inputs (see ``_stage_xs``); static topologies capture one
        device-constant mixing operand."""
        eng = self.eng

        def body(carry, xs_r):
            params, opt_state, share_state = carry
            W = xs_r["mix"] if "mix" in xs_r else eng._mix_static
            act = xs_r.get("act")
            bx, by = self._round_batch(xs_r)
            params, opt_state, share_state, nbytes, sim_t, fstats = (
                eng.steps.train_and_mix(
                    params, opt_state, share_state, bx, by, W, act, xs_r["rnd"]
                )
            )
            return (params, opt_state, share_state), (nbytes, sim_t, fstats)

        carry, (nbytes, times, fstats) = jax.lax.scan(
            body, (params, opt_state, share_state), xs
        )
        return carry + (nbytes, times, fstats)

    def _legacy_round(self, params, opt_state, share_state, bx, by, W, active, rnd):
        return self.eng.steps.train_and_mix(
            params, opt_state, share_state, bx, by, W, active, rnd
        )

    # -- node-sharded chunk (shard_map over the device mesh) -------------
    def _wrap_mix(self, mix):
        """Sharded mixing operand for one round inside the shard body.

        ``mix`` is the scanned per-round operand (this device's row block,
        cut by the in_specs) or None for static topologies — those capture
        the full replicated tables and slice the local block by device
        index, keeping the wrapper shapes identical either way."""
        eng = self.eng
        shard = eng._shard
        if mix is None:
            if eng.mix_mode == "sparse":
                st = eng._mix_static
                topo_l = SparseTopology(
                    shard.local(st.nbr), shard.local(st.w), shard.local(st.w_self)
                )
                return ShardedTopology(topo_l, shard, eng._perm_sched)
            return ShardedDense(shard.local(eng._mix_static), shard)
        if isinstance(mix, SparseTopology):
            return ShardedTopology(mix, shard, None)
        return ShardedDense(mix, shard)

    def _chunk_fn_sharded(self, params, opt_state, share_state, xs):
        """The scanned chunk, run inside shard_map: every node-stacked
        carry/input is this device's (B, ...) row block; gossip crosses
        devices through the sharded mixing operand (collective_permute
        slots or all-gather — see mixing.ShardedTopology) and the per-round
        scalar metrics are psum/pmax-reduced so each device returns the
        same global values."""
        eng = self.eng

        def body(carry, xs_r):
            params, opt_state, share_state = carry
            W = self._wrap_mix(xs_r.get("mix"))
            act = xs_r.get("act")
            bx, by = self._round_batch(xs_r)
            params, opt_state, share_state, nbytes, sim_t, fstats = (
                eng.steps.train_and_mix(
                    params, opt_state, share_state, bx, by, W, act, xs_r["rnd"],
                    shard=eng._shard,
                )
            )
            return (params, opt_state, share_state), (nbytes, sim_t, fstats)

        carry, (nbytes, times, fstats) = jax.lax.scan(
            body, (params, opt_state, share_state), xs
        )
        return carry + (nbytes, times, fstats)

    def _xs_pspec(self, xs):
        """Per-leaf PartitionSpecs for the scan-input dict: the node axis of
        every leaf maps to the mesh 'nodes' axis, everything else is
        replicated."""

        def spec(path, leaf):
            key = path[0].key
            if key == "rnd":
                return P()
            if key in ("bx", "by", "idx"):  # (R, L, N, B, ...)
                return P(None, None, "nodes", *((None,) * (leaf.ndim - 3)))
            if key == "act":                # (R, N)
                return P(None, "nodes")
            if key == "mix":                # (R, N, N) W or (R, N, D)/(R, N) tables
                return P(None, "nodes", *((None,) * (leaf.ndim - 2)))
            raise KeyError(f"unknown scan input {key!r}")

        return jax.tree_util.tree_map_with_path(spec, xs)

    def _node_pspec(self, tree):
        return jax.tree_util.tree_map(
            lambda l: P("nodes", *((None,) * (l.ndim - 1))), tree
        )

    def _sharded_chunk_call(self, xs):
        """shard_map-wrap + jit the chunk for this xs structure (cached —
        structures recur: full chunks and the pre-eval remainder)."""
        eng = self.eng
        leaves, treedef = jax.tree_util.tree_flatten(xs)
        key = (treedef, tuple(l.ndim for l in leaves))
        fn = self._shard_jit_cache.get(key)
        if fn is None:
            state_specs = (
                self._node_pspec(eng.params),
                self._node_pspec(eng.opt_state),
                self._node_pspec(eng.share_state),
            )
            # fstats scalars are replicated by construction (either zeros
            # or psum-reduced, like nbytes/times)
            fstats_specs = {k: P() for k in faults_lib.STAT_KEYS}
            fn = jax.jit(
                shard_map(
                    self._chunk_fn_sharded,
                    mesh=eng._mesh,
                    in_specs=state_specs + (self._xs_pspec(xs),),
                    out_specs=state_specs + (P(), P(), fstats_specs),
                    check_vma=False,
                )
            )
            self._shard_jit_cache[key] = fn
        return fn(eng.params, eng.opt_state, eng.share_state, xs)

    # -- host-side dispatch ----------------------------------------------
    def run_span(self, start: int, n_rounds: int) -> None:
        eng = self.eng
        xs = self._stage_xs(start, n_rounds)
        if eng.sharded:
            out = self._sharded_chunk_call(xs)
        else:
            out = self._chunk_jit(eng.params, eng.opt_state, eng.share_state, xs)
        eng.params, eng.opt_state, eng.share_state, nbytes, times, fstats = out
        # ONE host sync per chunk for all per-round metrics
        eng.bytes_sent += float(np.asarray(nbytes, np.float64).sum())
        eng.sim_time_s += float(np.asarray(times, np.float64).sum())
        self._accum_faults(fstats)

    def _round_mix(self, rnd: int):
        """Device mixing operand for one round (legacy per-round dispatch):
        dense (N, N) W or SparseTopology neighbor tables, matching the mode
        the scanned path uses so both execute the identical workload."""
        eng = self.eng
        if eng.sampler is None:
            return eng._mix_static
        if eng.mix_mode == "sparse":
            t = eng.sampler.round_table(rnd)
            return SparseTopology(
                jnp.asarray(t.nbr), jnp.asarray(t.w), jnp.asarray(t.w_self)
            )
        return jnp.asarray(eng.sampler.round_weights(rnd).astype(np.float32))

    def run_legacy_round(self, rnd: int) -> None:
        """Per-round dispatch baseline: host-gathered full batches, one jit
        call and one metric sync per round.  Samples the same round_indices
        as the scanned path so both execute the identical workload."""
        eng = self.eng
        dl = eng.dl
        idx = eng.batcher.round_indices(rnd, dl.local_steps)  # (L, N, B)
        bx = jnp.asarray(eng.batcher.x[idx])
        by = jnp.asarray(eng.batcher.y[idx])
        W = self._round_mix(rnd)
        act = (
            jnp.asarray(self.participation_mask(rnd, 1)[0])
            if dl.participation < 1.0 else None
        )
        out = self._legacy_jit(
            eng.params, eng.opt_state, eng.share_state, bx, by, W, act,
            jnp.int32(rnd),
        )
        eng.params, eng.opt_state, eng.share_state, nbytes, sim_t, fstats = out
        eng.bytes_sent += float(nbytes)
        eng.sim_time_s += float(sim_t)
        self._accum_faults(fstats)


class LocalScheduler(Scheduler):
    """Neighborhood-barrier semantics: trajectories identical to sync (the
    mixing math is untouched), but each node runs on its own virtual
    clock — node i starts round r once it and its *live neighbors* have
    finished round r-1 (a gossip exchange needs both endpoints), then adds
    its own compute+comm time.  No global barrier: stragglers only delay
    their graph neighborhood, so the simulated experiment time (max final
    clock) lower-bounds sync's ``sum of per-round maxima``.  Down (churn)
    nodes stall their clock and rejoin where they left off."""

    semantics = "local"

    def __init__(self, eng):
        super().__init__(eng)
        self._clock = jnp.zeros((eng.dl.n_nodes,), jnp.float32)
        self._chunk_jit = jax.jit(self._chunk_fn)

    def _nbr_clock_max(self, W, act, clock):
        """Per-node max of live-neighbor clocks (-inf when none)."""
        live, gather = _live_edges(W, act)
        return jnp.max(jnp.where(live, gather(clock), -jnp.inf), axis=1)

    def _chunk_fn(self, params, opt_state, share_state, clock, xs):
        eng = self.eng

        def body(carry, xs_r):
            params, opt_state, share_state, clock = carry
            W = xs_r["mix"] if "mix" in xs_r else eng._mix_static
            act = xs_r.get("act")
            bx, by = self._round_batch(xs_r)
            params, opt_state, share_state, nbytes, node_t, fstats = (
                eng.steps.train_and_mix(
                    params, opt_state, share_state, bx, by, W, act, xs_r["rnd"],
                    time_reduce="none",
                )
            )
            # neighborhood barrier: wait for the live neighbors' previous
            # round, then run this one (node_t is 0 for down nodes, whose
            # clocks stall until they rejoin)
            ready = jnp.maximum(clock, self._nbr_clock_max(W, act, clock))
            if act is not None:
                clock = jnp.where(act > 0, ready + node_t, clock)
            else:
                clock = ready + node_t
            return (params, opt_state, share_state, clock), (
                nbytes, jnp.max(clock), fstats
            )

        carry, (nbytes, times, fstats) = jax.lax.scan(
            body, (params, opt_state, share_state, clock), xs
        )
        return carry + (nbytes, times, fstats)

    def run_span(self, start: int, n_rounds: int) -> None:
        eng = self.eng
        xs = self._stage_xs(start, n_rounds)
        out = self._chunk_jit(
            eng.params, eng.opt_state, eng.share_state, self._clock, xs
        )
        (eng.params, eng.opt_state, eng.share_state, self._clock,
         nbytes, times, fstats) = out
        eng.bytes_sent += float(np.asarray(nbytes, np.float64).sum())
        # the virtual clock is a running maximum, not a per-round sum
        eng.sim_time_s = float(np.asarray(times)[-1])
        self._accum_faults(fstats)

    def extra_metrics(self) -> Dict:
        clock = np.asarray(self._clock, np.float64)
        return {
            "semantics": "local",
            "vclock_min_s": float(clock.min()),
            "vclock_median_s": float(np.median(clock)),
            "vclock_max_s": float(clock.max()),
            **super().extra_metrics(),
        }


class AsyncScheduler(Scheduler):
    """Event-driven asynchronous gossip on a virtual clock (AD-PSGD
    family).  One scanned step = one event *cohort*: the nodes whose next
    event completes inside the earliest ``async_slice_s`` window all fire
    — each takes a local step on that cohort's batch row, gossips against
    possibly-stale neighbor rows, and reschedules its next event at
    ``+compute_time[i] + comm_time[i]`` on its own clock.  Nodes with
    equal event durations therefore stay in lockstep cohorts (with
    homogeneous times and full participation, every cohort is exactly one
    synchronous round — the reduction the equivalence tests pin), while a
    10x straggler fires ~10x fewer events per unit of virtual time.

    Gossip forms (``DLConfig.async_gossip``):

    * ``"neighborhood"`` — the fired node reads its whole (churn-pruned) W
      row through the configured sharing strategy; non-fired rows are
      frozen (one-sided read, no write conflicts).
    * ``"pairwise"`` — classic AD-PSGD: one uniformly-sampled partner per
      event (``topology.sample_neighbor_slots``), ``x_i' = (x_i+x_j)/2``;
      a sampled partner that is churn-down blocks the exchange (the node
      keeps its local step and retries at its next event).

    Down (churn) nodes burn their event slots — virtual time passes, no
    work happens, params freeze — and rejoin with their stale model.
    Traced per-cohort outputs: bytes, the cohort's virtual time (max
    completion among fired events), fired-event count, and the staleness
    (event-count gap receiver-minus-sender over the rows read) sum/max —
    aggregated into :meth:`extra_metrics` for ``history``/results.

    **Population-scale cohort activation** (``DLConfig.cohort_capacity=C``
    > 0): each scanned step selects the top-C earliest-``t_next`` nodes
    inside the time slice (ties by lowest id), **gathers** only those C
    rows of params/opt state plus their neighbor rows from the padded
    ``SparseTopology`` table, runs the identical local-step + one-sided
    gossip on the (C, ...) slice, and **scatters** the results back into
    the cold device-resident (N, ...) population state — O(C·(d+1)·P) per
    event step instead of O(N·P).  In-slice nodes beyond capacity are
    *overflow-carried*: their ``t_next`` is untouched, so they stay inside
    the (monotone) next slice and fire in earliest-deadline order — no
    event is dropped, only deferred (which is when timing semantics can
    differ from the dense oracle; with C >= every fire-count the
    trajectory is the dense one, property-tested).  Per-step cohort
    occupancy and overflow counts are traced outputs.

    Accumulator hygiene at population scale: host-side event totals
    accumulate as Python ints / int64 (int32 wraps at ~2.1e9 events —
    hours of a 100k-node run); ``sim_time_s`` and the vclock metrics are
    fp32 running *maxima* of the device clock, which are exact (max
    selects, never rounds — unlike sums, which lose ulps at every add),
    plus the float64 ``_t_offset`` rebase carry (see ``_REBASE_T_S``).
    """

    semantics = "async"

    def __init__(self, eng):
        super().__init__(eng)
        n = eng.dl.n_nodes
        # completion time of each node's next local step (first event =
        # one local compute; each event's comm delays the one after it)
        self._t_next = jnp.asarray(eng._compute_node, jnp.float32)
        self._vclock = jnp.zeros((n,), jnp.float32)   # last fired completion
        self._events = jnp.zeros((n,), jnp.int32)     # model version counter
        # consecutive failed pairwise exchanges (drives the exponential
        # backoff under a FaultPlan; stays all-zero without one)
        self._retries = jnp.zeros((n,), jnp.int32)
        self._stale_sum = 0.0
        self._stale_n = 0.0
        self._stale_max = 0.0
        self._fired_total = 0          # int: exact at any population scale
        self._t_offset = 0.0           # float64 rebase carry (virtual secs)
        self._cohort_c = int(eng.dl.cohort_capacity)
        self._occ_sum = 0.0
        self._occ_steps = 0
        self._overflow_total = 0
        # --- cohort selection layer (flat oracle vs segment-min hierarchy)
        sel = eng.dl.selection
        if sel == "auto":
            sel = "hier" if (
                self._cohort_c > 0 and n >= _HIER_AUTO_MIN_N
            ) else "flat"
        self._selection = sel
        self._fallback_total = 0
        if sel == "hier":
            seg = int(eng.dl.segment_size)
            if seg <= 0:
                # minimize the per-step selection cost S + C·seg + K·seg
                # (segment scan + seg_min refresh + union gather):
                # seg ~ sqrt(N/C), clamped to sane block sizes
                seg = int(np.clip(
                    round(np.sqrt(n / max(self._cohort_c, 1))), 4, 128
                ))
            self._seg = min(seg, n)
            self._n_seg = -(-n // self._seg)
            # candidate segments per step: at least C, because under
            # uncorrelated (continuous heterogeneous) event times the
            # in-slice nodes land in ~one segment each, plus twice what
            # a cohort of dense segments needs for the clustered/tied
            # case; a slice spanning more segments than this falls back
            # to the flat oracle inside the step (counted in
            # selection_fallback_total).  Union size stays K*seg ~
            # sqrt(N*C) at the auto segment size — sublinear in N.
            self._seg_k = min(
                self._n_seg,
                max(self._cohort_c,
                    2 * (-(-self._cohort_c // self._seg)), 8),
            )
            self._seg_min = self._build_seg_min(self._t_next)
        else:
            self._seg = self._n_seg = self._seg_k = 0
            self._seg_min = None
        # --- cold population storage (DLConfig.cold_dtype) ----------------
        # the (N, P) params / opt moments live compressed; every cohort
        # gather decodes C rows to fp32 and every scatter re-encodes them
        self._cold_dtype = eng.dl.cold_dtype
        if self._cold_dtype != "fp32":
            eng.params = compression_lib.encode_cold(
                eng.params, self._cold_dtype
            )
            eng.opt_state = compression_lib.encode_cold(
                eng.opt_state, self._cold_dtype
            )
        self._chunk_jit = jax.jit(self._chunk_fn)

    def eval_params(self):
        return compression_lib.decode_cold(self.eng.params, self._cold_dtype)

    # -- hierarchical selection state -------------------------------------
    def _build_seg_min(self, t_next):
        """(S,) exact per-segment minima of ``t_next`` — the carried
        selection index.  O(N); init/rebase only (the scan body refreshes
        just the segments its scatter touched)."""
        n = self.eng.dl.n_nodes
        seg, S = self._seg, self._n_seg
        rows = (
            jnp.arange(S, dtype=jnp.int32)[:, None] * seg
            + jnp.arange(seg, dtype=jnp.int32)[None, :]
        )
        vals = jnp.where(
            rows < n, jnp.take(t_next, jnp.minimum(rows, n - 1)), jnp.inf
        )
        return jnp.min(vals, axis=1)

    # -- traced cohort helpers -------------------------------------------
    def _pair_comm(self, partner, ok, rows=None):
        """Per-event comm seconds of a pairwise exchange (one message of
        the full parameter vector from the sampled partner).  ``rows``
        overrides the receiver ids for a gathered cohort (defaults to
        arange — the full node axis)."""
        eng = self.eng
        if eng.steps.lat is None:
            return jnp.zeros_like(ok)
        rows = jnp.arange(partner.shape[0]) if rows is None else rows
        nbytes = eng.n_params * jnp.dtype(jnp.float32).itemsize
        t = (
            eng.steps.lat[rows, partner]
            + nbytes * 8.0 / eng.steps.goodput[rows, partner]
        )
        return ok * t

    def _cohort(self, carry, xs_r):
        eng = self.eng
        dl = eng.dl
        plan = eng.steps.faults
        params, opt_state, share_state, t_next, vclock, events, retries = carry
        W = xs_r["mix"] if "mix" in xs_r else eng._mix_static
        act = xs_r.get("act")
        rnd = xs_r["rnd"]
        fstats = faults_lib.zero_stats()
        guard = plan is not None and plan.corrupt_prob > 0
        if guard:
            snap = (params, opt_state)  # last-good snapshot for rollbacks
        # --- cohort membership on the virtual clock ----------------------
        t_min = jnp.min(t_next)
        fire = (t_next <= t_min + dl.async_slice_s).astype(jnp.float32)
        actv = fire * act if act is not None else fire  # fired AND up
        # --- local step (down/unfired nodes frozen) ----------------------
        bx, by = self._round_batch(xs_r)
        params, opt_state = eng.steps.local_train(
            params, opt_state, bx, by, actv
        )
        X = jax.vmap(tree_vector)(params)
        key = jax.random.fold_in(eng.steps.base_key, rnd)
        ev_f = events.astype(jnp.float32)
        backoff = None
        if dl.async_gossip == "pairwise":
            X2, partner, ok = gossip_pair_avg(W, X, key, fire=actv, act=act)
            share_state_new = share_state
            ok_eff = ok
            comm = self._pair_comm(partner, ok)
            if plan is not None and plan.edge_faults:
                # one exchange per event: per-(round, node) loss/spike draws
                lv, sp = faults_lib.edge_draws(
                    eng.steps.fault_key, rnd, jnp.arange(dl.n_nodes), 1, plan
                )
                live, spike = lv[:, 0], sp[:, 0]
                lost = ok * (1.0 - live)        # exchange hit a dead edge
                ok_eff = ok * live
                X2 = jnp.where(lost[:, None] > 0, X, X2)  # keep local step
                spiked = ok * spike
                comm = comm * (1.0 + spike * (plan.latency_spike_factor - 1.0))
                # retry at the next event, after an exponential backoff on
                # this node's virtual clock (capped) — the same policy the
                # real-network runtime sleeps on the wall clock
                backoff = lost * faults_lib.retry_backoff_delay(
                    retries, plan.retry_backoff_s, plan.retry_backoff_cap
                )
                recovered = ok_eff * (retries > 0).astype(jnp.float32)
                retries = jnp.where(
                    lost > 0, retries + 1,
                    jnp.where(ok_eff > 0, 0, retries),
                )
                fstats["faults_injected"] += jnp.sum(lost) + jnp.sum(spiked)
                fstats["faults_detected"] += jnp.sum(lost)
                fstats["faults_survived"] += jnp.sum(spiked)
                fstats["faults_recovered"] += jnp.sum(recovered)
                fstats["retry_total"] += jnp.sum(lost)
            stale_i = ok_eff * jnp.maximum(ev_f - jnp.take(ev_f, partner), 0.0)
            n_reads = ok_eff
            msg = jnp.float32(eng.n_params * np.dtype(np.float32).itemsize)
            # bytes at pre-loss ok: the sender transmitted either way
            nbytes = jnp.sum(ok) * msg / dl.n_nodes
        else:  # neighborhood: the full (churn-pruned) W row, stale reads
            if act is not None:
                if isinstance(W, SparseTopology):
                    Wm, deg_eff = participation_reweight_sparse(W, act)
                else:
                    Wm, deg_eff = participation_reweight(W, act)
            else:
                Wm, deg_eff = W, eng.steps.mean_degree
            # message-level edge faults: the mixing operand drops lost
            # edges (renormalized — survived by design) while bytes/time
            # still run on the churn-level operand, like the sync path
            Wm_mix, lat_mult = Wm, None
            if plan is not None and plan.edge_faults:
                if isinstance(Wm, SparseTopology):
                    lv, sp = faults_lib.edge_draws(
                        eng.steps.fault_key, rnd,
                        jnp.arange(Wm.nbr.shape[0]), Wm.nbr.shape[1], plan,
                    )
                    sent = (Wm.w > 0).astype(jnp.float32)
                    Wm_mix = edge_reweight_sparse(Wm, lv)
                else:
                    n = Wm.shape[0]
                    lv, sp = faults_lib.edge_draws(
                        eng.steps.fault_key, rnd, jnp.arange(n), n, plan
                    )
                    sent = (
                        Wm * (1.0 - jnp.eye(n, dtype=jnp.float32)) > 0
                    ).astype(jnp.float32)
                    Wm_mix = edge_reweight(Wm, lv)
                dropped = jnp.sum(sent * (1.0 - lv))
                spiked = jnp.sum(sent * sp)
                if plan.latency_spike_prob > 0:
                    lat_mult = 1.0 + sp * (plan.latency_spike_factor - 1.0)
                fstats["faults_injected"] += dropped + spiked
                fstats["faults_survived"] += dropped + spiked
            X2_all, share_state_new, nbytes_rate = eng.sharing.round(
                X, Wm_mix, share_state, key, degree=deg_eff, rnd=rnd
            )
            X2 = jnp.where(actv[:, None] > 0, X2_all, X)
            # staleness over the rows actually read: the same live-edge
            # derivation the local scheduler's barrier uses (the churn
            # reweight above zeroes exactly these down-endpoint slots)
            live_b, gather = _live_edges(W, act)
            live = live_b.astype(jnp.float32)
            gap = jnp.maximum(ev_f[:, None] - gather(ev_f), 0.0)
            cnt = jnp.maximum(live.sum(1), 1.0)
            stale_i = actv * (live * gap).sum(1) / cnt
            n_reads = actv
            # only fired nodes' exchanges hit the wire this cohort
            nbytes = jnp.asarray(nbytes_rate, jnp.float32) * jnp.sum(actv) / dl.n_nodes
            if eng.steps.lat is not None:
                comm = eng.steps.round_time(
                    Wm, None, jnp.asarray(nbytes_rate, jnp.float32), deg_eff,
                    reduce="none", lat_mult=lat_mult,
                )
                comm = comm - eng.steps.compute_node  # compute added below
            else:
                comm = jnp.zeros((dl.n_nodes,), jnp.float32)
        # --- payload corruption + rollback guard --------------------------
        actv_w = actv  # state-write mask (excludes rolled-back rows)
        if guard:
            cmask = actv * faults_lib.corruption_mask(
                eng.steps.fault_key, rnd, jnp.arange(dl.n_nodes), plan
            )
            X2 = faults_lib.corrupt_rows(X2, cmask, plan.corrupt_mode)
            bad = actv * faults_lib.nonfinite_rows(X2)
            actv_w = actv * (1.0 - bad)
            fstats["faults_injected"] += jnp.sum(cmask)
            fstats["faults_detected"] += jnp.sum(bad)
            fstats["faults_recovered"] += jnp.sum(bad)
        share_state = node_where(actv_w, share_state_new, share_state)
        new_params = jax.vmap(lambda v: tree_unvector(v, eng.template))(
            X2.astype(X.dtype)
        )
        params = node_where(actv_w, new_params, params)
        if guard:
            # rolled-back rows discard the local step too: back to the
            # last-good (start-of-event) snapshot
            p0, o0 = snap
            params = node_where(1.0 - bad, params, p0)
            opt_state = node_where(1.0 - bad, opt_state, o0)
        # --- clock advance ------------------------------------------------
        dur = eng.steps.compute_node + comm
        if backoff is not None:
            dur = dur + backoff
        vclock = jnp.where(fire > 0, t_next, vclock)
        t_next = t_next + fire * dur  # down-but-scheduled slots burn time too
        events = events + actv_w.astype(jnp.int32)
        out = (
            nbytes,
            jnp.max(vclock),
            jnp.sum(actv),
            jnp.sum(stale_i),
            jnp.sum(n_reads),
            jnp.max(stale_i),
            fstats,
        )
        return (
            params, opt_state, share_state, t_next, vclock, events, retries
        ), out

    # -- traced cohort selection ------------------------------------------
    def _select_flat(self, t_next, t_min=None):
        """The flat selection oracle: top-C earliest ``t_next`` inside the
        slice over the full (N,) clock — O(N) per step.  Returns
        ``(cids, cmask, occupancy, overflow)`` with ``cids`` sorted
        ascending."""
        dl = self.eng.dl
        C = self._cohort_c
        if t_min is None:
            t_min = jnp.min(t_next)
        in_slice = t_next <= t_min + dl.async_slice_s
        neg, cand = jax.lax.top_k(jnp.where(in_slice, -t_next, -jnp.inf), C)
        pad = jnp.isfinite(neg).astype(jnp.float32)    # (C,) real-vs-pad
        occupancy = jnp.sum(pad)
        overflow = (
            jnp.sum(in_slice.astype(jnp.int32)) - occupancy.astype(jnp.int32)
        )
        cids, cmask = jax.lax.sort_key_val(cand, pad)  # ascending ids
        return cids, cmask, occupancy, overflow

    def _select_hier(self, t_next, seg_min):
        """Hierarchical segment-min selection: pick the K earliest-min
        segments from the carried (S,) ``seg_min``, gather their (K·seg,)
        clock union, and run the slice mask + ``top_k`` inside it — no
        O(N) op on the step.  Exactness: ``min(seg_min) == min(t_next)``
        (each entry is an exact fp32 min), and whenever every in-slice
        segment is among the top K (the ``covered`` predicate), the
        union's masked candidate set equals the flat oracle's and the
        union rows ascend in global id (segments sorted, rows contiguous),
        so ``top_k`` reproduces the flat pick *and* its lowest-id
        tie-break bitwise.  Slices spanning more than K segments take a
        ``lax.cond`` branch into :meth:`_select_flat` (rare; counted).
        Capacity-padding slots may carry out-of-range ids (the union's
        tail rows past N): gathers clip them and scatters drop them, the
        same masked no-op contract in-range pad ids already satisfy."""
        dl = self.eng.dl
        C = self._cohort_c
        n = dl.n_nodes
        seg, K = self._seg, self._seg_k
        t_min = jnp.min(seg_min)
        theta = t_min + dl.async_slice_s
        covered = jnp.sum((seg_min <= theta).astype(jnp.int32)) <= K

        def hier_branch(operand):
            t_next, seg_min = operand
            _, seg_sel = jax.lax.top_k(-seg_min, K)
            seg_sel = jnp.sort(seg_sel)        # union rows ascend globally
            rows = (
                seg_sel[:, None] * seg
                + jnp.arange(seg, dtype=seg_sel.dtype)[None, :]
            ).reshape(-1)                      # (K·seg,) global ids
            u_t = jnp.where(
                rows < n, jnp.take(t_next, jnp.minimum(rows, n - 1)), jnp.inf
            )
            in_sl = u_t <= theta
            neg, pos = jax.lax.top_k(jnp.where(in_sl, -u_t, -jnp.inf), C)
            pad = jnp.isfinite(neg).astype(jnp.float32)
            occupancy = jnp.sum(pad)
            overflow = (
                jnp.sum(in_sl.astype(jnp.int32)) - occupancy.astype(jnp.int32)
            )
            cand = jnp.take(rows, pos).astype(jnp.int32)
            cids, cmask = jax.lax.sort_key_val(cand, pad)
            return cids, cmask, occupancy, overflow

        def flat_branch(operand):
            t_next, _ = operand
            return self._select_flat(t_next, t_min=t_min)

        cids, cmask, occupancy, overflow = jax.lax.cond(
            covered, hier_branch, flat_branch, (t_next, seg_min)
        )
        return cids, cmask, occupancy, overflow, 1 - covered.astype(jnp.int32)

    def _cohort_gs(self, carry, xs_r):
        """Population-scale cohort body: the semantics of :meth:`_cohort`
        executed on a gathered (C, ...) hot set.  Selection is top-C
        earliest ``t_next`` inside the slice (ties by lowest id — the
        ``lax.top_k`` tie-break), either flat over the (N,) clock
        (:meth:`_select_flat`, the oracle) or through the carried
        segment-minimum hierarchy (:meth:`_select_hier`, bitwise the same
        cohort with no O(N) op); unselected in-slice nodes keep their
        ``t_next`` untouched (overflow-carry: the slice window is
        monotone, so they remain inside the next one and fire in
        earliest-deadline order).  Under a compressed ``cold_dtype`` the
        cold population rows decode to fp32 at the gather and re-encode
        at the scatter below.  Capacity padding slots carry
        ``cmask=0``: their gathered rows run through the same masked ops
        as churn-down nodes and scatter back bit-unchanged.  The dense
        oracle reads post-local-step rows of same-step peers, so neighbor
        reads resolve through a slot map — rows inside this cohort read
        the fresh (C, P) slice, rows outside read the cold population —
        which keeps the trajectory bitwise without a second (C, P)
        scatter on the hot path.  Cohort ids are re-sorted ascending
        after selection (membership, per-row math and the scattered
        state are order-invariant) so every gather/scatter below runs
        with sorted unique indices."""
        eng = self.eng
        dl = eng.dl
        C = self._cohort_c
        cold = self._cold_dtype
        hier = self._selection == "hier"
        if hier:
            (params, opt_state, share_state, t_next, vclock, events, vmax,
             seg_min) = carry
        else:
            params, opt_state, share_state, t_next, vclock, events, vmax = carry
        W = xs_r["mix"] if "mix" in xs_r else eng._mix_static
        act = xs_r.get("act")
        rnd = xs_r["rnd"]
        # --- cohort selection on the virtual clock ------------------------
        if hier:
            cids, cmask, occupancy, overflow, fb = self._select_hier(
                t_next, seg_min
            )
        else:
            cids, cmask, occupancy, overflow = self._select_flat(t_next)
            fb = jnp.int32(0)

        def take_rows(tree):
            return jax.tree_util.tree_map(
                lambda a: jnp.take(a, cids, axis=0), tree
            )

        def put_rows(tree, sub):
            return jax.tree_util.tree_map(
                lambda a, s: a.at[cids].set(
                    s, indices_are_sorted=True, unique_indices=True
                ),
                tree, sub,
            )

        # global id -> cohort slot (-1 outside): how neighbor/partner reads
        # find this step's fresh rows without scattering them first.  A
        # sorted-membership probe on the (C,) sorted cids — O(M·log C) per
        # M-row lookup, replacing the former full-(N,) scatter map
        def slot_lookup(ids):
            pos = jnp.minimum(
                jnp.searchsorted(cids, ids).astype(jnp.int32), C - 1
            )
            return jnp.where(jnp.take(cids, pos) == ids, pos, -1)

        act_c = jnp.take(act, cids) if act is not None else None
        actv_c = cmask * act_c if act is not None else cmask  # fired AND up
        # --- local step on the hot slice ----------------------------------
        # gathered rows decode to fp32 (identity under cold_dtype='fp32');
        # the encoded gather is kept so masked rows scatter back bit-exact
        enc_p, enc_o = take_rows(params), take_rows(opt_state)
        p_c = compression_lib.decode_cold(enc_p, cold)
        o_c = compression_lib.decode_cold(enc_o, cold)
        idx_c = self._node_indices(rnd, cids)                 # (L, C, B)
        bx = jnp.take(eng._dev_x, idx_c, axis=0)
        by = jnp.take(eng._dev_y, idx_c, axis=0)
        p_c, o_c = eng.steps.local_train(p_c, o_c, bx, by, actv_c, rows=cids)
        X_c = jax.vmap(tree_vector)(p_c)                      # (C, P)

        def fresh_rows(ids, X_cold):
            """Post-local-step values for global ``ids``: the fresh hot
            slice where ``ids`` is in this cohort, ``X_cold`` otherwise."""
            s = slot_lookup(ids)
            X_f = jnp.take(X_c, jnp.clip(s, 0), axis=0)
            return jnp.where((s >= 0)[..., None], X_f, X_cold)

        key = jax.random.fold_in(eng.steps.base_key, rnd)
        # event counters are gathered as int32 and widened after the
        # gather — an O(N) astype per step would rival the gossip itself
        ev_c = jnp.take(events, cids).astype(jnp.float32)
        topo_c = gather_rows(W, cids)                         # (C, D) view
        if dl.async_gossip == "pairwise":
            slot = sample_neighbor_slots(key, topo_c, rows=cids)
            partner = jnp.take_along_axis(topo_c.nbr, slot[:, None], axis=1)[:, 0]
            ok = actv_c
            if act is not None:
                ok = ok * jnp.take(act, partner)
            p_partner = compression_lib.decode_cold(
                jax.tree_util.tree_map(
                    lambda a: jnp.take(a, partner, axis=0), params
                ),
                cold,
            )
            X_p = fresh_rows(partner, jax.vmap(tree_vector)(p_partner))
            X2_c = jnp.where(ok[:, None] > 0, 0.5 * (X_c + X_p), X_c)
            stale_c = ok * jnp.maximum(
                ev_c - jnp.take(events, partner).astype(jnp.float32), 0.0
            )
            n_reads_c = ok
            msg = jnp.float32(eng.n_params * np.dtype(np.float32).itemsize)
            nbytes = jnp.sum(ok) * msg / dl.n_nodes
            comm = self._pair_comm(partner, ok, rows=cids)
        else:  # neighborhood: the gathered (churn-pruned) W rows
            if act is not None:
                Wm_c = participation_reweight_rows(topo_c, act, cids)
                deg_eff = participation_deg_eff(W, act)
            else:
                Wm_c, deg_eff = topo_c, eng.steps.mean_degree
            nbr_flat = Wm_c.nbr.reshape(-1)                   # (C·D,)
            p_n = compression_lib.decode_cold(
                jax.tree_util.tree_map(
                    lambda a: jnp.take(a, nbr_flat, axis=0), params
                ),
                cold,
            )
            Xn = fresh_rows(nbr_flat, jax.vmap(tree_vector)(p_n)).reshape(
                X_c.shape[0], -1, X_c.shape[1]
            )                                                  # (C, D, P)
            mixed = jnp.einsum("cd,cdp->cp", Wm_c.w.astype(jnp.float32), Xn)
            X2_all = Wm_c.w_self.astype(jnp.float32)[:, None] * X_c + mixed
            X2_c = jnp.where(actv_c[:, None] > 0, X2_all, X_c)
            live_c = topo_c.w > 0
            if act is not None:
                live_c = live_c & (act_c[:, None] > 0) & (
                    jnp.take(act, topo_c.nbr, axis=0) > 0
                )
            live = live_c.astype(jnp.float32)
            gap = jnp.maximum(
                ev_c[:, None]
                - jnp.take(events, topo_c.nbr, axis=0).astype(jnp.float32),
                0.0,
            )
            cnt = jnp.maximum(live.sum(1), 1.0)
            stale_c = actv_c * (live * gap).sum(1) / cnt
            n_reads_c = actv_c
            nbytes_rate = jnp.asarray(
                deg_eff * X_c.shape[1] * jnp.dtype(X_c.dtype).itemsize,
                jnp.float32,
            )
            nbytes = nbytes_rate * jnp.sum(actv_c) / dl.n_nodes
            if eng.steps.lat is not None:
                comm = eng.steps.cohort_comm_time(
                    cids, Wm_c.nbr, (Wm_c.w > 0).astype(jnp.float32),
                    nbytes_rate, deg_eff,
                )
            else:
                comm = jnp.zeros((C,), jnp.float32)
        # (share_state is untouched: semantics='async' is validated to
        # full sharing, whose state is the empty pytree)
        p2_c = jax.vmap(lambda v: tree_unvector(v, eng.template))(
            X2_c.astype(X_c.dtype)
        )
        p2_c = node_where(actv_c, p2_c, p_c)
        # the one (C, P)-scale scatter of the step: post-mix params (which
        # are the post-local params on masked rows) and opt state together.
        # Compressed cold rows re-encode first, and masked rows scatter
        # the *original* encoded gather back — int8 re-encode wobbles the
        # per-row scale by ulps, so untouched rows stay bit-exact by
        # construction, not by codec luck
        if cold == "fp32":
            params = put_rows(params, p2_c)
            opt_state = put_rows(opt_state, o_c)
        else:
            params = put_rows(params, node_where(
                actv_c, compression_lib.encode_cold(p2_c, cold), enc_p
            ))
            opt_state = put_rows(opt_state, node_where(
                actv_c, compression_lib.encode_cold(o_c, cold), enc_o
            ))
        # --- clock advance on the gathered rows ---------------------------
        dur_c = jnp.take(eng.steps.compute_node, cids) + comm
        t_c = jnp.take(t_next, cids)
        vclock = vclock.at[cids].set(
            jnp.where(cmask > 0, t_c, jnp.take(vclock, cids)),
            indices_are_sorted=True, unique_indices=True,
        )
        t_next = t_next.at[cids].add(
            cmask * dur_c, indices_are_sorted=True, unique_indices=True
        )
        events = events.at[cids].add(
            actv_c.astype(jnp.int32),
            indices_are_sorted=True, unique_indices=True,
        )
        # running vclock max carried as a scalar: identical to
        # jnp.max(vclock) (max is exact) without the O(N) reduce per step
        vmax = jnp.maximum(
            vmax, jnp.max(jnp.where(cmask > 0, t_c, -jnp.inf))
        )
        if hier:
            # refresh the carried segment minima for exactly the segments
            # this scatter touched: gather each one's (seg,) clock block
            # and rewrite its exact min — O(C·seg).  Duplicate segments
            # write identical values; out-of-range pad ids clamp into the
            # last segment, whose (unchanged) min is simply recomputed
            n = dl.n_nodes
            seg = self._seg
            segs = jnp.minimum(cids, n - 1) // seg
            rows2 = (
                segs[:, None] * seg
                + jnp.arange(seg, dtype=jnp.int32)[None, :]
            )
            vals = jnp.where(
                rows2 < n,
                jnp.take(t_next, jnp.minimum(rows2, n - 1)),
                jnp.inf,
            )
            seg_min = seg_min.at[segs].set(jnp.min(vals, axis=1))
        out = (
            nbytes,
            vmax,
            jnp.sum(actv_c),
            jnp.sum(stale_c),
            jnp.sum(n_reads_c),
            jnp.max(stale_c),
            occupancy,
            overflow,
            fb,
        )
        state = (params, opt_state, share_state, t_next, vclock, events, vmax)
        if hier:
            state = state + (seg_min,)
        return state, out

    def _chunk_fn(self, params, opt_state, share_state, t_next, vclock, events,
                  retries, seg_min, xs):
        if self._cohort_c > 0:
            # the cohort gather/scatter path runs fault-free (validated):
            # retries pass through untouched, no fstats emitted
            init = (params, opt_state, share_state, t_next, vclock, events,
                    jnp.max(vclock))
            if self._selection == "hier":
                init = init + (seg_min,)
            carry, outs = jax.lax.scan(self._cohort_gs, init, xs)
            seg_out = carry[7] if self._selection == "hier" else None
            return carry[:6] + (retries, seg_out) + outs
        carry, outs = jax.lax.scan(
            self._cohort,
            (params, opt_state, share_state, t_next, vclock, events, retries),
            xs,
        )
        return carry + (None,) + outs

    # -- host-side dispatch ----------------------------------------------
    def run_span(self, start: int, n_rounds: int) -> None:
        eng = self.eng
        xs = self._stage_xs(start, n_rounds)
        out = self._chunk_jit(
            eng.params, eng.opt_state, eng.share_state,
            self._t_next, self._vclock, self._events, self._retries,
            self._seg_min, xs,
        )
        (eng.params, eng.opt_state, eng.share_state,
         self._t_next, self._vclock, self._events, self._retries) = out[:7]
        self._seg_min = out[7]
        nbytes, t_virt, fired, stale_sum, stale_n, stale_max = out[8:14]
        eng.bytes_sent += float(np.asarray(nbytes, np.float64).sum())
        # the virtual clock is a running maximum, not a per-cohort sum —
        # fp32-exact (max selects, never rounds) — plus the rebase offset
        eng.sim_time_s = float(np.asarray(t_virt)[-1]) + self._t_offset
        self._fired_total += int(np.asarray(fired, np.float64).sum())
        self._stale_sum += float(np.asarray(stale_sum, np.float64).sum())
        self._stale_n += float(np.asarray(stale_n, np.float64).sum())
        self._stale_max = max(self._stale_max, float(np.asarray(stale_max).max()))
        if self._cohort_c > 0:
            occ = np.asarray(out[14], np.float64)
            self._occ_sum += float(occ.sum())
            self._occ_steps += int(occ.shape[0])
            self._overflow_total += int(np.asarray(out[15], np.int64).sum())
            self._fallback_total += int(np.asarray(out[16], np.int64).sum())
        else:
            self._accum_faults(out[14])
        self._maybe_rebase()

    def _maybe_rebase(self) -> None:
        """fp32 virtual-clock magnitude hygiene.  ``t_next`` advances by
        running *sums* (``+= dur``), which — unlike the running maxima the
        metrics take — lose precision as the clock grows: at t ~ 2^16 s
        the fp32 ulp is ~2^-7 s and sub-ms event durations are absorbed.
        Once every pending event is past ``_REBASE_T_S``, subtract one
        fp32-representable shift from ``t_next``/``vclock`` on device and
        carry it in the float64 ``_t_offset`` (added back in
        ``sim_time_s``/metrics).  Below the threshold nothing changes —
        trajectories there are bitwise identical to the unrebased code."""
        t_min = float(np.asarray(self._t_next).min())
        if t_min < _REBASE_T_S:
            return
        shift = float(np.float32(t_min))
        self._t_offset += shift
        s = jnp.float32(shift)
        self._t_next = self._t_next - s
        self._vclock = self._vclock - s
        if self._seg_min is not None:
            # x - s is monotone in x (fp rounding preserves order), so each
            # segment's min element stays its min and seg_min - s rounds to
            # exactly the shifted t_next entry it mirrors
            self._seg_min = self._seg_min - s

    # -- population-scale memory accounting --------------------------------
    def memory_model(self) -> Dict:
        """Analytic bytes of the async hot/cold memory split — the
        recorded, N-independence-checkable quantity behind the
        ``bench_population`` gate.  Hot = the per-step working set the
        cohort path touches (O(C·(d+1)·P) gossip operands + the (L, C, B)
        batch slice); cold = the device-resident population state
        (O(N·P) params + O(N) clocks) that is only gathered/scattered."""
        eng = self.eng
        dl = eng.dl
        n, p = dl.n_nodes, eng.n_params
        c = self._cohort_c if self._cohort_c > 0 else n
        topo = eng._mix_static
        if isinstance(topo, SparseTopology):
            d = int(topo.dmax)
            topo_bytes = int(
                topo.nbr.nbytes + topo.w.nbytes + topo.w_self.nbytes
            )
        elif topo is None:  # dynamic: (N, degree) tables staged per round
            d = int(dl.degree)
            topo_bytes = n * d * 8 + n * 4
        else:  # dense (N, N) W — the cohort path rejects this at validate
            d = n
            topo_bytes = 4 * n * n
        feat_bytes = int(eng._dev_x.nbytes // max(eng._dev_x.shape[0], 1)) + int(
            eng._dev_y.nbytes // max(eng._dev_y.shape[0], 1)
        )
        hot = {
            "gossip_gather_bytes": c * (1 + d) * p * 4,  # X_c + neighbor rows
            "work_vectors_bytes": 2 * c * p * 4,         # X2 + scatter temp
            "batch_bytes": dl.local_steps * c * dl.batch_size * feat_bytes,
            "topology_rows_bytes": c * (d * 8 + 4),      # nbr+w rows, w_self
        }
        hot["total"] = int(sum(hot.values()))
        # population params/opt bytes come from the *stored* trees — under a
        # compressed cold_dtype that is codes+scales, not N·P·4 — alongside
        # the fp32-equivalent baseline the compression gate divides by
        pop_b, pop_fp32 = compression_lib.cold_tree_bytes(
            (eng.params, eng.opt_state)
        )
        seg_min_bytes = self._n_seg * 4 if self._selection == "hier" else 0
        cold = {
            "population_params_bytes": int(pop_b),
            "clock_bytes": n * (4 + 4 + 4) + seg_min_bytes,
            "topology_bytes": topo_bytes,
        }
        cold["total"] = int(sum(cold.values()))
        cold["population_params_fp32_bytes"] = int(pop_fp32)
        cold["total_fp32"] = int(cold["total"] - pop_b + pop_fp32)
        # the selection layer's per-step working set: O(S + K·seg) for the
        # hierarchy (clock union + segment minima) vs O(N) flat.  Reported
        # separately from `hot`, which stays the N-independent-at-fixed-C
        # gossip working set the bench independence check pins
        if self._selection == "hier":
            selection = {
                "mode": "hier",
                "segment": self._seg,
                "n_segments": self._n_seg,
                "segments_topk": self._seg_k,
                "per_step_bytes": self._seg_k * self._seg * 12
                + self._n_seg * 4,
            }
        else:
            selection = {"mode": "flat", "per_step_bytes": n * 12}
        return {
            "cohort_capacity": c,
            "n_nodes": n,
            "n_params": p,
            "dmax": d,
            "cold_dtype": self._cold_dtype,
            "selection": selection,
            "hot": hot,
            "cold": cold,
        }

    def extra_metrics(self) -> Dict:
        # int64 host totals: the int32 per-node counters are safe (no node
        # fires 2^31 events) but their *population sum* overflows int32 at
        # N >= 100k over long horizons
        events = np.asarray(self._events, np.int64)
        vclock = np.asarray(self._vclock, np.float64) + self._t_offset
        m = {
            "semantics": "async",
            "events_total": int(events.sum()),
            "events_min": int(events.min()),
            "events_max": int(events.max()),
            "vclock_min_s": float(vclock.min()),
            "vclock_median_s": float(np.median(vclock)),
            "vclock_max_s": float(vclock.max()),
            "staleness_mean": self._stale_sum / max(self._stale_n, 1.0),
            "staleness_max": self._stale_max,
        }
        if self._cohort_c > 0:
            m["cohort_capacity"] = self._cohort_c
            m["cohort_occupancy_mean"] = self._occ_sum / max(self._occ_steps, 1)
            m["cohort_overflow_total"] = self._overflow_total
            # overflow per selected event: how often an in-slice node had
            # to carry to a later step — the raw counter's denominator
            m["cohort_overflow_ratio"] = (
                self._overflow_total / max(self._fired_total, 1)
            )
            m["cohort_selection"] = self._selection
            if self._selection == "hier":
                m["selection_fallback_total"] = self._fallback_total
        m.update(super().extra_metrics())
        return m


def make_scheduler(eng) -> Scheduler:
    sem = eng.dl.semantics
    if sem == "sync":
        return SyncScheduler(eng)
    if sem == "local":
        return LocalScheduler(eng)
    if sem == "async":
        return AsyncScheduler(eng)
    raise ValueError(f"unknown semantics {sem!r} (sync|local|async)")
