from repro.serving.engine import ServeConfig, make_serve_step, ServingEngine
