"""Batched serving runtime.

``make_serve_step`` builds the one-token decode function the decode-shape
dry-runs lower (KV cache of seq_len, one new token per request).
``ServingEngine`` drives it: batched requests, greedy/temperature sampling,
EOS tracking — a small but real continuous-decode loop.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.api import decode_step, init_cache, prefill
from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    batch: int = 8
    max_len: int = 1024
    temperature: float = 0.0  # 0 = greedy
    eos_id: int = 0


def make_serve_step(cfg: ModelConfig):
    """(params, cache, tokens (B,1), index) -> (logits (B,1,V), new_cache)."""

    def serve_step(params, cache, tokens, index):
        return decode_step(params, cfg, cache, tokens, index)

    return serve_step


class ServingEngine:
    def __init__(self, cfg: ModelConfig, sc: ServeConfig, params):
        self.cfg, self.sc, self.params = cfg, sc, params
        self._step = jax.jit(make_serve_step(cfg))

    def generate(self, prompts, max_new: int = 32, key=None):
        """prompts: (B, S0) int32 (right-aligned, no padding support needed
        for the demo engine). Returns (B, max_new) generated ids."""
        sc = self.sc
        B, S0 = prompts.shape
        if self.cfg.family in ("dense", "moe", "vlm"):
            # one-shot prefill: full pass populates the cache
            last, cache = jax.jit(
                lambda p, t: prefill(p, self.cfg, {"tokens": t}, sc.max_len)
            )(self.params, prompts)
            logits = last[:, None, :]
        else:
            # recurrent-state families: token-by-token prefill
            cache = init_cache(self.cfg, B, sc.max_len)
            for i in range(S0):
                logits, cache = self._step(
                    self.params, cache, prompts[:, i : i + 1], jnp.int32(i)
                )
        out = []
        done = jnp.zeros((B,), bool)
        if key is None:
            key = jax.random.key(0)
        for t in range(max_new):
            if sc.temperature > 0:
                key, sub = jax.random.split(key)
                nxt = jax.random.categorical(sub, logits[:, -1] / sc.temperature)[:, None]
            else:
                nxt = logits[:, -1].argmax(-1)[:, None]
            nxt = jnp.where(done[:, None], sc.eos_id, nxt).astype(jnp.int32)
            out.append(nxt)
            done = done | (nxt[:, 0] == sc.eos_id)
            logits, cache = self._step(self.params, cache, nxt, jnp.int32(S0 + t))
        return jnp.concatenate(out, axis=1)
