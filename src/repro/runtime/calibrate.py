"""Simulator calibration: measured wall-clock vs ``NetworkModel``.

Every bench gate in this repo quotes *simulated* time from the
``NetworkModel``; this module is the receipt that makes those numbers
defensible: it runs a real ``backend='processes'`` localhost experiment,
measures per-round wall-clock at the sync barrier (max over workers,
compile warm-up excluded), and records measured-vs-modeled into
``results/calibration.json`` — the modeled side being
``network.localhost_deployment`` through the same
``NetworkModel.round_time`` formula the engine traces.

The residual (``implied_compute_s``) is the part the network model does
not claim to predict — local SGD compute plus serialization/python
overhead — reported separately so the comparison is honest about what is
communication and what is not.

CLI:  PYTHONPATH=src python -m repro.runtime.calibrate \
          --nodes 16 --workers 4 --rounds 12
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Dict, Optional

import numpy as np

from repro.utils.io import atomic_write_json

DEFAULT_OUT = "results/calibration.json"


def run_calibration(
    n_nodes: int = 16,
    workers: int = 4,
    rounds: int = 12,
    *,
    degree: int = 5,
    sharing: str = "full",
    budget: float = 0.1,
    workload: Optional[Dict] = None,
    warmup_rounds: int = 2,
    out_path: str = DEFAULT_OUT,
    watchdog_s: float = 120.0,
    log: bool = True,
) -> Dict:
    from repro.core.engine import DLConfig, build_graph
    from repro.core.network import localhost_deployment
    from repro.runtime.runner import ProcessRunner

    dl = DLConfig(
        n_nodes=n_nodes, topology="regular", degree=degree, sharing=sharing,
        budget=budget, rounds=rounds, eval_every=max(rounds, 1),
        backend="processes",
    )
    wl = workload or {
        "dataset": "cifar10", "model": "mlp", "width": 2,
        "n_train": 512, "n_test": 256, "lr": 0.05,
    }
    runner = ProcessRunner(dl, wl, workers=workers, watchdog_s=watchdog_s)
    t0 = time.time()
    runner.run(rounds=rounds, log=log)
    wall_total = time.time() - t0
    measured = np.asarray(runner.round_wall_s, np.float64)
    steady = (
        measured[warmup_rounds:] if len(measured) > warmup_rounds else measured
    )
    # bytes one node sends one neighbor per round, matching the simulator's
    # accounting (FullSharing: P values in the wire dtype; randomk payload:
    # k (idx, val) pairs)
    if sharing.lower() in ("randomk", "random"):
        k = max(1, int(budget * runner.n_params))
        item = 1 if dl.payload_quant else 4
        bytes_per_edge = k * (4 + item) + (4 if dl.payload_quant else 0)
    else:
        bytes_per_edge = runner.n_params * 4
    graph = build_graph(dl)
    net = localhost_deployment(n_nodes)
    modeled_comm_s = net.round_time(graph, bytes_per_edge, compute_time_s=0.0)
    med = float(np.median(steady))
    record = {
        "config": {
            "n_nodes": n_nodes, "workers": workers, "rounds": rounds,
            "degree": degree, "sharing": sharing, "budget": budget,
            "dl": dataclasses.asdict(dl), "workload": wl,
        },
        "n_params": int(runner.n_params),
        "bytes_per_edge": float(bytes_per_edge),
        "measured_round_s": {
            "min": float(steady.min()),
            "median": med,
            "mean": float(steady.mean()),
            "max": float(steady.max()),
            "warmup_excluded": int(min(warmup_rounds, len(measured))),
        },
        "modeled_round_s": float(modeled_comm_s),
        # what the model does not claim: compute + framing/python overhead
        "implied_compute_s": float(med - modeled_comm_s),
        "ratio_measured_over_modeled": float(med / max(modeled_comm_s, 1e-12)),
        "per_round_wall_s": [float(x) for x in measured],
        "total_wall_s": float(wall_total),
        "wire_bytes_per_node": float(runner.bytes_sent),
        "counters": runner.counters,
    }
    atomic_write_json(out_path, record)
    if log:
        print(
            f"[calibrate] N={n_nodes} K={workers} median round "
            f"{med * 1e3:.1f}ms vs modeled comm "
            f"{modeled_comm_s * 1e3:.3f}ms "
            f"(implied compute {record['implied_compute_s'] * 1e3:.1f}ms) "
            f"-> {out_path}",
            flush=True,
        )
    return record


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--nodes", type=int, default=16)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=12)
    ap.add_argument("--degree", type=int, default=5)
    ap.add_argument("--sharing", default="full")
    ap.add_argument("--budget", type=float, default=0.1)
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--watchdog", type=float, default=120.0)
    ap.add_argument("--smoke", action="store_true",
                    help="small fast run for CI (8 rounds, tiny model)")
    args = ap.parse_args(argv)
    rounds = 8 if args.smoke else args.rounds
    run_calibration(
        args.nodes, args.workers, rounds, degree=args.degree,
        sharing=args.sharing, budget=args.budget, out_path=args.out,
        watchdog_s=args.watchdog,
    )


if __name__ == "__main__":
    main()
