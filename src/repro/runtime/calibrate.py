"""Simulator calibration: measured wall-clock vs ``NetworkModel``.

Every bench gate in this repo quotes *simulated* time from the
``NetworkModel``; this module is the receipt that makes those numbers
defensible: it runs real ``backend='processes'`` localhost experiments,
measures per-round wall-clock at the sync barrier (max over workers,
compile warm-up excluded), and records measured-vs-modeled into
``results/calibration.json`` — the modeled side being
``network.localhost_deployment`` through the same
``NetworkModel.round_time`` formula the engine traces.

The residual (``implied_compute_s``) is the part the network model does
not claim to predict — local SGD compute plus serialization/python
overhead.  The **sweep** (``run_sweep``) measures that residual across
(N, K, payload format) points and fits it as

    residual ≈ alpha + beta * bytes_per_round

by least squares: ``alpha`` is the per-round constant overhead (framing,
syscalls, barrier slack — what ``NetworkModel.overhead_s`` consumes via
``network.calibrated_localhost``), ``beta`` the per-byte serialization
cost the loopback link model underestimates.  The fit lands in the
``"fit"`` block of ``calibration.json``.

CLI:  PYTHONPATH=src python -m repro.runtime.calibrate \
          --nodes 16 --workers 4 --rounds 12        # one point
      PYTHONPATH=src python -m repro.runtime.calibrate --sweep
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.utils.io import atomic_write_json

DEFAULT_OUT = "results/calibration.json"

#: (n_nodes, workers, sharing, payload_quant) sweep grid — small enough
#: for CI, wide enough to separate the constant from the per-byte term.
DEFAULT_SWEEP: Tuple[Tuple[int, int, str, bool], ...] = (
    (16, 4, "full", False),
    (16, 4, "randomk", False),
    (16, 4, "randomk", True),
    (32, 4, "full", False),
    (16, 8, "full", False),
)


def run_calibration(
    n_nodes: int = 16,
    workers: int = 4,
    rounds: int = 12,
    *,
    degree: int = 5,
    sharing: str = "full",
    budget: float = 0.1,
    payload_quant: bool = False,
    workload: Optional[Dict] = None,
    warmup_rounds: int = 2,
    out_path: Optional[str] = DEFAULT_OUT,
    watchdog_s: float = 120.0,
    log: bool = True,
) -> Dict:
    """Measure one (N, K, sharing) point; ``out_path=None`` skips the
    write (the sweep collects points and writes once)."""
    from repro.core.engine import DLConfig, build_graph
    from repro.core.network import localhost_deployment
    from repro.runtime.runner import ProcessRunner

    dl = DLConfig(
        n_nodes=n_nodes, topology="regular", degree=degree, sharing=sharing,
        budget=budget, payload_quant=payload_quant, rounds=rounds,
        eval_every=max(rounds, 1), backend="processes",
    )
    wl = workload or {
        "dataset": "cifar10", "model": "mlp", "width": 2,
        "n_train": 512, "n_test": 256, "lr": 0.05,
    }
    runner = ProcessRunner(dl, wl, workers=workers, watchdog_s=watchdog_s)
    t0 = time.time()
    runner.run(rounds=rounds, log=log)
    wall_total = time.time() - t0
    measured = np.asarray(runner.round_wall_s, np.float64)
    steady = (
        measured[warmup_rounds:] if len(measured) > warmup_rounds else measured
    )
    # bytes one node sends one neighbor per round, matching the simulator's
    # accounting (FullSharing: P values in the wire dtype; randomk payload:
    # k (idx, val) pairs)
    if sharing.lower() in ("randomk", "random"):
        k = max(1, int(budget * runner.n_params))
        item = 1 if dl.payload_quant else 4
        bytes_per_edge = k * (4 + item) + (4 if dl.payload_quant else 0)
    else:
        bytes_per_edge = runner.n_params * 4
    graph = build_graph(dl)
    net = localhost_deployment(n_nodes)
    modeled_comm_s = net.round_time(graph, bytes_per_edge, compute_time_s=0.0)
    med = float(np.median(steady))
    record = {
        "config": {
            "n_nodes": n_nodes, "workers": workers, "rounds": rounds,
            "degree": degree, "sharing": sharing, "budget": budget,
            "payload_quant": payload_quant,
            "dl": dataclasses.asdict(dl), "workload": wl,
        },
        "n_params": int(runner.n_params),
        "bytes_per_edge": float(bytes_per_edge),
        "bytes_per_round": float(bytes_per_edge) * degree * n_nodes,
        "measured_round_s": {
            "min": float(steady.min()),
            "median": med,
            "mean": float(steady.mean()),
            "max": float(steady.max()),
            "warmup_excluded": int(min(warmup_rounds, len(measured))),
        },
        "modeled_round_s": float(modeled_comm_s),
        # what the model does not claim: compute + framing/python overhead
        "implied_compute_s": float(med - modeled_comm_s),
        "ratio_measured_over_modeled": float(med / max(modeled_comm_s, 1e-12)),
        "per_round_wall_s": [float(x) for x in measured],
        "total_wall_s": float(wall_total),
        "wire_bytes_per_node": float(runner.bytes_sent),
        "counters": runner.counters,
    }
    if out_path:
        atomic_write_json(out_path, record)
    if log:
        print(
            f"[calibrate] N={n_nodes} K={workers} {sharing}"
            f"{'/int8' if payload_quant else ''} median round "
            f"{med * 1e3:.1f}ms vs modeled comm "
            f"{modeled_comm_s * 1e3:.3f}ms "
            f"(implied compute {record['implied_compute_s'] * 1e3:.1f}ms)",
            flush=True,
        )
    return record


def fit_overhead(points: Sequence[Dict]) -> Dict:
    """Least-squares ``residual ≈ alpha + beta * bytes_per_round`` over
    the sweep points.  With too few points (or a rank-deficient design,
    e.g. every point the same payload size) the slope is pinned to zero
    and ``alpha`` is the median residual — a constant is always
    identifiable from one point."""
    resid = np.array([p["implied_compute_s"] for p in points], np.float64)
    nbytes = np.array([p["bytes_per_round"] for p in points], np.float64)
    alpha, beta = float(np.median(resid)), 0.0
    if len(points) >= 2 and np.ptp(nbytes) > 0:
        A = np.stack([np.ones_like(nbytes), nbytes], axis=1)
        sol, _, rank, _ = np.linalg.lstsq(A, resid, rcond=None)
        if rank == 2:
            alpha, beta = float(sol[0]), float(sol[1])
    pred = alpha + beta * nbytes
    return {
        "alpha_s": alpha,
        "beta_s_per_byte": beta,
        "n_points": len(points),
        "residual_rms_s": float(np.sqrt(np.mean((resid - pred) ** 2))),
    }


def run_sweep(
    grid: Sequence[Tuple[int, int, str, bool]] = DEFAULT_SWEEP,
    *,
    rounds: int = 12,
    out_path: str = DEFAULT_OUT,
    log: bool = True,
    **kw,
) -> Dict:
    """Measure every (N, K, sharing, quant) grid point, fit the per-round
    constant, and record sweep + fit into ``out_path``.  The top level
    keeps the first point's fields so single-point consumers read the
    same schema as before."""
    points: List[Dict] = []
    for n_nodes, workers, sharing, quant in grid:
        points.append(run_calibration(
            n_nodes, workers, rounds, sharing=sharing, payload_quant=quant,
            out_path=None, log=log, **kw,
        ))
    fit = fit_overhead(points)
    record = dict(points[0])
    record["sweep"] = points
    record["fit"] = fit
    atomic_write_json(out_path, record)
    if log:
        print(
            f"[calibrate] sweep fit over {fit['n_points']} points: "
            f"alpha {fit['alpha_s'] * 1e3:.1f}ms/round, beta "
            f"{fit['beta_s_per_byte'] * 1e9:.3f}ns/byte, residual rms "
            f"{fit['residual_rms_s'] * 1e3:.1f}ms -> {out_path}",
            flush=True,
        )
    return record


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--nodes", type=int, default=16)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=12)
    ap.add_argument("--degree", type=int, default=5)
    ap.add_argument("--sharing", default="full")
    ap.add_argument("--budget", type=float, default=0.1)
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--watchdog", type=float, default=120.0)
    ap.add_argument("--sweep", action="store_true",
                    help="run the (N, K, payload) grid and fit the "
                         "per-round overhead constant")
    ap.add_argument("--smoke", action="store_true",
                    help="small fast run for CI (8 rounds; with --sweep, "
                         "a 3-point grid)")
    args = ap.parse_args(argv)
    rounds = 8 if args.smoke else args.rounds
    if args.sweep:
        grid = DEFAULT_SWEEP[:3] if args.smoke else DEFAULT_SWEEP
        run_sweep(grid, rounds=rounds, out_path=args.out,
                  watchdog_s=args.watchdog)
    else:
        run_calibration(
            args.nodes, args.workers, rounds, degree=args.degree,
            sharing=args.sharing, budget=args.budget, out_path=args.out,
            watchdog_s=args.watchdog,
        )


if __name__ == "__main__":
    main()
