"""Socket transport for the processes backend: framing + wire codec +
rendezvous.

## Frame protocol

Every message is one length-prefixed binary frame on a TCP stream:

    !BI   frame type (uint8), body length (uint32)   then the body.

Types: ``ROWS`` (a round's gossip payload for a set of node rows),
``HEARTBEAT`` (the failure detector's liveness beacon), ``BYE`` (graceful
leave — the join/leave protocol's clean half; a SIGKILL'd worker never
sends one, which is exactly how the two are told apart), and the elastic
membership control plane: ``JOIN`` (a relaunched incarnation announces
itself — hello phase carries its new endpoint, commit phase the round it
will rejoin at), ``WELCOME`` (a survivor's reply: its current round and
epoch, plus the ack/nack of a commit), ``STATE_REQ``/``STATE`` (cold
catch-up: a rejoiner with no checkpoint pulls a live donor's current
row-block — the STATE body reuses the ROWS codec verbatim).

Every frame is stamped with the sender's **membership epoch** (its
incarnation number: 0 at first launch, +1 per supervisor relaunch), so
a receiver can reject a pre-crash zombie's stale frames with one integer
compare — see ``runtime.membership``.

## ROWS body — the PR 4 payload wire format, serialized

    !IHHHBI round, sender worker id, sender epoch, n_rows, fmt, k_or_p
    ids     (n_rows,) int32 global node ids

then per format:

* ``FMT_FULL_F32``    — (n_rows, P) fp32 parameter rows (D-PSGD).
* ``FMT_PAYLOAD_F32`` — (n_rows, k) int32 coordinate indices +
  (n_rows, k) fp32 values (the randomk (idx, val) payload).
* ``FMT_PAYLOAD_I8``  — (n_rows,) fp32 scale header + (n_rows, k) int32
  indices + (n_rows, k) int8 codes (``compression.quantize_int8`` on the
  wire: 1 byte/value + one fp32 scale per node).

Encode/decode are plain numpy ``tobytes``/``frombuffer`` — no pickling,
so a corrupt or truncated frame fails loudly at a struct/length check.

## Rendezvous

A newline-delimited-JSON registry (hosted by the launcher): each worker
connects, registers ``{worker, host, port}`` for its listening socket,
and blocks until the server broadcasts the full peer map once all K
workers are in.  Late (re)connections get the map immediately.
"""
from __future__ import annotations

import asyncio
import json
import socket
import struct
import threading
from typing import Dict, Optional, Tuple

import numpy as np

MSG_ROWS = 1
MSG_HEARTBEAT = 2
MSG_BYE = 3
MSG_JOIN = 4
MSG_WELCOME = 5
MSG_STATE_REQ = 6
MSG_STATE = 7

FMT_FULL_F32 = 0
FMT_PAYLOAD_F32 = 1
FMT_PAYLOAD_I8 = 2

_FRAME = struct.Struct("!BI")
_ROWS_HDR = struct.Struct("!IHHHBI")
_WID = struct.Struct("!H")
_PEER = struct.Struct("!HH")

MAX_FRAME_BYTES = 1 << 30  # sanity bound: a longer length prefix is garbage


# ----------------------------------------------------------------------
# frame codec
# ----------------------------------------------------------------------
def encode_rows(rnd: int, sender: int, ids: np.ndarray, fmt: int,
                *, epoch: int = 0,
                rows: Optional[np.ndarray] = None,
                idx: Optional[np.ndarray] = None,
                val: Optional[np.ndarray] = None,
                codes: Optional[np.ndarray] = None,
                scale: Optional[np.ndarray] = None) -> bytes:
    """ROWS frame body for ``ids`` (global node ids).  ``rows`` is the
    (n, P) fp32 matrix for FMT_FULL_F32; ``idx``/``val`` the (n, k)
    payload for FMT_PAYLOAD_F32; ``idx``/``codes``/``scale`` for
    FMT_PAYLOAD_I8.  ``epoch`` is the sender's membership epoch."""
    ids = np.ascontiguousarray(ids, np.int32)
    n = len(ids)
    if fmt == FMT_FULL_F32:
        rows = np.ascontiguousarray(rows, np.float32)
        kp, tail = rows.shape[1], rows.tobytes()
    elif fmt == FMT_PAYLOAD_F32:
        idx = np.ascontiguousarray(idx, np.int32)
        val = np.ascontiguousarray(val, np.float32)
        kp, tail = idx.shape[1], idx.tobytes() + val.tobytes()
    elif fmt == FMT_PAYLOAD_I8:
        idx = np.ascontiguousarray(idx, np.int32)
        codes = np.ascontiguousarray(codes, np.int8)
        scale = np.ascontiguousarray(scale, np.float32).reshape(n)
        kp = idx.shape[1]
        tail = scale.tobytes() + idx.tobytes() + codes.tobytes()
    else:
        raise ValueError(f"unknown ROWS fmt {fmt}")
    return (_ROWS_HDR.pack(rnd, sender, epoch, n, fmt, kp)
            + ids.tobytes() + tail)


def decode_rows(body: bytes) -> Dict:
    """Inverse of :func:`encode_rows`; raises on a malformed body."""
    rnd, sender, epoch, n, fmt, kp = _ROWS_HDR.unpack_from(body)
    off = _ROWS_HDR.size
    ids = np.frombuffer(body, np.int32, n, off)
    off += 4 * n
    out = {"round": rnd, "sender": sender, "epoch": epoch, "ids": ids,
           "fmt": fmt}
    if fmt == FMT_FULL_F32:
        out["rows"] = np.frombuffer(body, np.float32, n * kp, off).reshape(n, kp)
        off += 4 * n * kp
    elif fmt == FMT_PAYLOAD_F32:
        out["idx"] = np.frombuffer(body, np.int32, n * kp, off).reshape(n, kp)
        off += 4 * n * kp
        out["val"] = np.frombuffer(body, np.float32, n * kp, off).reshape(n, kp)
        off += 4 * n * kp
    elif fmt == FMT_PAYLOAD_I8:
        out["scale"] = np.frombuffer(body, np.float32, n, off)
        off += 4 * n
        out["idx"] = np.frombuffer(body, np.int32, n * kp, off).reshape(n, kp)
        off += 4 * n * kp
        out["codes"] = np.frombuffer(body, np.int8, n * kp, off).reshape(n, kp)
        off += n * kp
    else:
        raise ValueError(f"unknown ROWS fmt {fmt}")
    if off != len(body):
        raise ValueError(
            f"ROWS frame length mismatch: decoded {off} of {len(body)} bytes"
        )
    return out


def encode_wid(wid: int) -> bytes:
    return _WID.pack(wid)


def decode_wid(body: bytes) -> int:
    return _WID.unpack(body)[0]


def encode_peer(wid: int, epoch: int) -> bytes:
    """HEARTBEAT/BYE body: (worker id, membership epoch)."""
    return _PEER.pack(wid, epoch)


def decode_peer(body: bytes) -> Tuple[int, int]:
    return _PEER.unpack(body)


def encode_json(obj: Dict) -> bytes:
    """JOIN/WELCOME/STATE_REQ control-plane body (low-rate, so JSON)."""
    return json.dumps(obj).encode()


def decode_json(body: bytes) -> Dict:
    return json.loads(body.decode())


async def write_frame(writer: asyncio.StreamWriter, ftype: int, body: bytes):
    writer.write(_FRAME.pack(ftype, len(body)) + body)
    await writer.drain()


async def read_frame(reader: asyncio.StreamReader) -> Tuple[int, bytes]:
    """(type, body) of the next frame; raises IncompleteReadError on EOF."""
    hdr = await reader.readexactly(_FRAME.size)
    ftype, ln = _FRAME.unpack(hdr)
    if ln > MAX_FRAME_BYTES:
        raise ValueError(f"frame length {ln} exceeds sanity bound")
    return ftype, await reader.readexactly(ln)


async def open_with_retry(host: str, port: int, *, attempts: int = 40,
                          delay_s: float = 0.1) -> Tuple[
                              asyncio.StreamReader, asyncio.StreamWriter]:
    """Dial a peer that may not be listening yet (slow joiner): flat retry
    during the join window — exponential backoff is for mid-run failures
    (``faults.retry_backoff_delay``), not for startup races."""
    last: Optional[Exception] = None
    for _ in range(attempts):
        try:
            return await asyncio.open_connection(host, port)
        except OSError as e:
            last = e
            await asyncio.sleep(delay_s)
    raise ConnectionError(f"could not reach {host}:{port}: {last}")


# ----------------------------------------------------------------------
# rendezvous registry
# ----------------------------------------------------------------------
class RendezvousServer:
    """Launcher-hosted peer registry on its own event-loop thread.

    Workers register their listening endpoint; once all K are in, every
    registered (and any later) connection receives the full peer map.
    The server stays up for the whole run so a reconnecting worker can
    re-fetch the map."""

    def __init__(self, n_workers: int, host: str = "127.0.0.1"):
        self.n = n_workers
        self.host = host
        self.port: Optional[int] = None
        self._peers: Dict[int, Tuple[str, int]] = {}
        self._waiting = []
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._thread: Optional[threading.Thread] = None
        self._ready = threading.Event()

    def start(self) -> Tuple[str, int]:
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()
        if not self._ready.wait(timeout=10):
            raise RuntimeError("rendezvous server failed to start")
        return self.host, self.port

    def stop(self):
        if self._loop is not None:
            self._loop.call_soon_threadsafe(self._loop.stop)
        if self._thread is not None:
            self._thread.join(timeout=5)

    # -- loop thread ----------------------------------------------------
    def _run(self):
        loop = asyncio.new_event_loop()
        self._loop = loop
        asyncio.set_event_loop(loop)

        async def boot():
            server = await asyncio.start_server(self._serve, self.host, 0)
            self.port = server.sockets[0].getsockname()[1]
            self._ready.set()

        loop.run_until_complete(boot())
        loop.run_forever()

    def _peer_map(self) -> bytes:
        m = {str(w): [h, p] for w, (h, p) in self._peers.items()}
        return (json.dumps({"peers": m}) + "\n").encode()

    async def _serve(self, reader: asyncio.StreamReader,
                     writer: asyncio.StreamWriter):
        try:
            line = await reader.readline()
            if not line:
                return
            msg = json.loads(line)
            self._peers[int(msg["worker"])] = (msg["host"], int(msg["port"]))
            if len(self._peers) >= self.n:
                for w in self._waiting:
                    try:
                        w.write(self._peer_map())
                        await w.drain()
                    except OSError:
                        pass
                self._waiting.clear()
                writer.write(self._peer_map())
                await writer.drain()
            else:
                self._waiting.append(writer)
                return  # keep open; broadcast resolves it
        except (json.JSONDecodeError, KeyError, ValueError, OSError):
            pass


async def rendezvous_register(host: str, port: int, worker: int,
                              my_host: str, my_port: int, *,
                              timeout_s: float = 30.0,
                              ) -> Dict[int, Tuple[str, int]]:
    """Register this worker's endpoint and block until the registry
    responds with the full peer map (all K workers joined)."""
    reader, writer = await open_with_retry(host, port)
    writer.write((json.dumps(
        {"worker": worker, "host": my_host, "port": my_port}) + "\n").encode())
    await writer.drain()
    line = await asyncio.wait_for(reader.readline(), timeout=timeout_s)
    writer.close()
    if not line:
        raise ConnectionError("rendezvous closed before the peer map arrived")
    peers = json.loads(line)["peers"]
    return {int(w): (h, int(p)) for w, (h, p) in peers.items()}


def free_port(host: str = "127.0.0.1") -> int:
    """An OS-assigned free TCP port (for tests that need one up front)."""
    with socket.socket() as s:
        s.bind((host, 0))
        return s.getsockname()[1]
