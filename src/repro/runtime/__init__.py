"""Real-network execution backend (``DLConfig.backend="processes"``).

The simulated engine emulates N nodes inside one process on a virtual
clock; this package runs the same experiment as K real OS processes
gossiping over real TCP sockets on real clocks — the paper's *emulation*
claim made measurable:

* ``transport``  — length-prefixed frame protocol carrying the payload
  wire format (full fp32 rows, or (idx, val) payloads with optional
  int8 + scale header), the JOIN/WELCOME/STATE rejoin control plane,
  plus the rendezvous registry protocol.  Every frame is stamped with
  the sender's membership epoch.
* ``membership`` — one worker's epoch-stamped view of the mesh: the
  failure detector's bookkeeping, zombie-frame rejection, and the
  two-phase rejoin admission state machine (socket-free, tested in
  isolation).
* ``peer``       — one worker process owning a contiguous row-block of
  nodes: asyncio gossip with heartbeat failure detection, send retry
  with the shared exponential-backoff policy, graceful degradation
  (dead peers' edges reweighted via ``sharing.edge_reweight_sparse`` so
  surviving rows stay row-stochastic), and crash-rejoin: checkpoint or
  donor-STATE catch-up plus pristine edge-weight restoration on
  re-admission (``sharing.edge_readmit_sparse``).
* ``runner``     — ``ProcessRunner``: spawns/monitors/kills workers,
  hosts the rendezvous, supervises crash-relaunch (``chaos_plan``,
  ``supervise=True``), merges per-worker results into an engine-shaped
  history, and checks the detection/rejoin conservation invariant.
* ``calibrate``  — measured per-round wall-clock vs ``NetworkModel``
  predictions over an (N, K, payload) sweep, with a fitted per-round
  overhead constant recorded into ``results/calibration.json``.
"""
from repro.runtime.membership import (  # noqa: F401
    Membership,
    RUNTIME_COUNTER_KEYS,
    zero_counters,
)
from repro.runtime.runner import ProcessRunner, build_workload  # noqa: F401
