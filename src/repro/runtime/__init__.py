"""Real-network execution backend (``DLConfig.backend="processes"``).

The simulated engine emulates N nodes inside one process on a virtual
clock; this package runs the same experiment as K real OS processes
gossiping over real TCP sockets on real clocks — the paper's *emulation*
claim made measurable:

* ``transport``  — length-prefixed frame protocol carrying the payload
  wire format (full fp32 rows, or (idx, val) payloads with optional
  int8 + scale header), plus the rendezvous registry protocol.
* ``peer``       — one worker process owning a contiguous row-block of
  nodes: asyncio gossip with heartbeat failure detection, send retry
  with the shared exponential-backoff policy, and graceful degradation
  (dead peers' edges reweighted via ``sharing.edge_reweight_sparse`` so
  surviving rows stay row-stochastic).
* ``runner``     — ``ProcessRunner``: spawns/monitors/kills workers,
  hosts the rendezvous, merges per-worker results into an engine-shaped
  history.
* ``calibrate``  — measured per-round wall-clock vs ``NetworkModel``
  predictions, recorded into ``results/calibration.json``.
"""
from repro.runtime.runner import ProcessRunner, build_workload  # noqa: F401
