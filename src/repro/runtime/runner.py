"""ProcessRunner — launch, monitor, kill/relaunch, and merge.

The launcher side of the processes backend: hosts the rendezvous
registry, spawns K ``repro.runtime.peer`` worker processes (real
``subprocess`` children — killable with a real SIGKILL, which is what
the kill test is about), watches their crash-consistent progress files,
and merges the per-worker results into the engine-shaped history every
existing entry point understands.

The launcher doubles as the elastic-membership **supervisor**: a
``chaos_plan`` entry ``{"worker": w, "kill_at_round": r, "rejoin": bool}``
SIGKILLs worker w once its progress reaches round r and — when
``rejoin`` — immediately relaunches it with ``--rejoin --epoch E`` (the
epoch bumps by one per relaunch, so survivors reject the corpse's stale
frames by integer compare).  With ``supervise=True`` the same relaunch
also fires on an *unplanned* death: a worker that exits without results,
or whose progress file goes stale past ``stall_timeout_s``.  A relaunch
re-arms a worker's next chaos entry only after its *new* incarnation
writes progress (mtime gating), so a pre-crash progress value cannot
double-trigger.

Workers rebuild the experiment from a *declarative* workload spec
(:func:`build_workload`) — callables cannot cross a process boundary —
and the launcher's oracle tests use the same builder, so the simulator
and the workers cannot construct different experiments.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import signal
import subprocess
import sys
import tempfile
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.utils.io import atomic_write_json


def build_workload(wl: Dict, dl) -> Tuple[Callable, Callable, Callable, Any, Any]:
    """(init_params_fn, loss_fn, acc_fn, optimizer, batcher) from a
    declarative workload spec — the same construction as
    ``benchmarks/common.dl_experiment`` (dataset seed 7, label-sharded
    partitions, per-config seeds), shared by the worker processes and the
    launcher-side equivalence oracle."""
    from repro.data import NodeBatcher, make_dataset, sharding_partition
    from repro.models.api import cross_entropy
    from repro.optim import make_optimizer

    dataset = wl.get("dataset", "cifar10")
    kw = {} if dataset in ("teacher", "cifar10-hard", "lm") else {
        "sigma": wl.get("sigma", 4.0)
    }
    ds = make_dataset(
        dataset, n_train=wl.get("n_train", 1024),
        n_test=wl.get("n_test", 512), seed=wl.get("data_seed", 7), **kw,
    )
    parts = sharding_partition(
        ds.train_y, dl.n_nodes, wl.get("shards_per_node", 2), seed=dl.seed
    )
    batcher = NodeBatcher(
        ds.train_x, ds.train_y, parts, dl.batch_size, seed=dl.seed
    )
    model, width = wl.get("model", "mlp"), wl.get("width", 16)
    if model == "cnn":
        from repro.models.cnn import cnn_apply, cnn_init

        init = lambda k: cnn_init(k, width=width)  # noqa: E731
        apply = cnn_apply
    else:
        from repro.models.mlp import mlp_apply, mlp_init

        init = lambda k: mlp_init(k, hidden=8 * width)  # noqa: E731
        apply = mlp_apply

    def loss_fn(p, x, y):
        return cross_entropy(apply(p, x), y)

    def acc_fn(p, x, y):
        return (apply(p, x).argmax(-1) == y).mean()

    opt = make_optimizer(wl.get("optimizer", "sgd"), wl.get("lr", 0.05))
    return init, loss_fn, acc_fn, opt, batcher


def _src_root() -> str:
    import repro

    # repro is a namespace package (no __init__.py): locate it via __path__
    return os.path.dirname(os.path.abspath(list(repro.__path__)[0]))


class ProcessRunner:
    """Run a ``backend='processes'`` experiment as K worker processes.

    kill_worker/kill_at_round: SIGKILL that worker once its progress file
    reaches the given round — the built-in fault injector for the real
    backend (the simulated backend's ``FaultPlan`` does not apply here).
    """

    def __init__(
        self,
        dl,
        workload: Dict,
        *,
        workers: int = 4,
        run_dir: Optional[str] = None,
        hb_interval_s: float = 0.25,
        dead_timeout_s: float = 3.0,
        watchdog_s: float = 60.0,
        send_timeout_s: float = 10.0,
        join_timeout_s: float = 60.0,
        retry_backoff_s: float = 0.05,
        retry_backoff_cap: int = 5,
        kill_worker: Optional[int] = None,
        kill_at_round: Optional[int] = None,
        chaos_plan: Optional[List[Dict]] = None,
        supervise: bool = False,
        stall_timeout_s: Optional[float] = None,
        max_relaunches: int = 2,
        ckpt_every: int = 0,
        round_min_s: float = 0.0,
        dump_view: bool = False,
        timeout_s: Optional[float] = None,
        keep_run_dir: bool = False,
    ):
        dl.validate()
        if dl.backend != "processes":
            raise ValueError(
                "ProcessRunner is the backend='processes' launcher; set "
                f"DLConfig.backend='processes' (got {dl.backend!r})"
            )
        if workers < 1:
            raise ValueError("workers must be >= 1")
        if dl.n_nodes % workers:
            raise ValueError(
                f"n_nodes={dl.n_nodes} must divide evenly over "
                f"workers={workers} (each worker owns a row-block)"
            )
        if (kill_worker is None) != (kill_at_round is None):
            raise ValueError(
                "kill_worker and kill_at_round come as a pair"
            )
        if kill_worker is not None and not 0 <= kill_worker < workers:
            raise ValueError(f"kill_worker {kill_worker} out of range")
        # normalize the legacy kill pair into a one-entry chaos plan
        self.chaos_plan = [dict(e) for e in (chaos_plan or [])]
        if kill_worker is not None:
            self.chaos_plan.append({
                "worker": kill_worker, "kill_at_round": kill_at_round,
                "rejoin": False,
            })
        for e in self.chaos_plan:
            w = e.get("worker")
            if not isinstance(w, int) or not 0 <= w < workers:
                raise ValueError(f"chaos_plan worker {w!r} out of range")
            r = e.get("kill_at_round")
            if not isinstance(r, int) or r < 0:
                raise ValueError(
                    f"chaos_plan kill_at_round {r!r} must be an int >= 0"
                )
            e["rejoin"] = bool(e.get("rejoin", True))
        self.chaos_plan.sort(key=lambda e: (e["kill_at_round"], e["worker"]))
        self.dl = dl
        self.workload = dict(workload)
        self.workers = workers
        self.kill_worker = kill_worker
        self.kill_at_round = kill_at_round
        self.supervise = supervise
        self.stall_timeout_s = stall_timeout_s
        self.max_relaunches = int(max_relaunches)
        self.keep_run_dir = keep_run_dir
        self._cfg = dict(
            hb_interval_s=hb_interval_s, dead_timeout_s=dead_timeout_s,
            watchdog_s=watchdog_s, send_timeout_s=send_timeout_s,
            join_timeout_s=join_timeout_s, retry_backoff_s=retry_backoff_s,
            retry_backoff_cap=retry_backoff_cap, ckpt_every=int(ckpt_every),
            round_min_s=float(round_min_s), dump_view=bool(dump_view),
        )
        self.timeout_s = (
            timeout_s if timeout_s is not None
            else join_timeout_s + 2 * watchdog_s
            + (2.0 + round_min_s) * dl.rounds + 120.0
        )
        self.run_dir = run_dir
        # engine-shaped surface
        self.history: List[Dict] = []
        self.bytes_sent = 0.0
        self.sim_time_s = 0.0
        self.round_wall_s: List[float] = []
        self.n_params: Optional[int] = None
        self.counters: Dict[str, int] = {}
        self.worker_results: Dict[int, Dict] = {}
        self.final_X: Optional[np.ndarray] = None
        self.live_rows: Optional[np.ndarray] = None
        self.killed_at_round: Optional[int] = None
        self.epochs: Dict[int, int] = {w: 0 for w in range(workers)}
        self.kill_events: List[Dict] = []
        self.workers_rejoined = 0
        self.conservation: Dict[str, Any] = {}
        self.reweight_row_err = 0.0
        self.wire_dtype = (
            "int8" if (dl.payload_quant and dl.sharing.lower() in
                       ("randomk", "random")) else "float32"
        )

    # ------------------------------------------------------------------
    def _progress(self, wid: int) -> int:
        try:
            with open(os.path.join(self.run_dir, f"w{wid}.progress")) as f:
                return int(f.read().strip() or -1)
        except (OSError, ValueError):
            return -1

    @staticmethod
    def _tail(path: str, n: int = 20) -> str:
        try:
            with open(path, errors="replace") as f:
                return "".join(f.readlines()[-n:])
        except OSError:
            return "<no log>"

    def run(self, rounds: Optional[int] = None, log: bool = True) -> List[Dict]:
        from repro.runtime.transport import RendezvousServer

        rounds = rounds if rounds is not None else self.dl.rounds
        own_dir = self.run_dir is None
        if own_dir:
            self.run_dir = tempfile.mkdtemp(prefix="repro-procs-")
        os.makedirs(self.run_dir, exist_ok=True)
        rdv = RendezvousServer(self.workers)
        host, port = rdv.start()
        spec = {
            "dl": dataclasses.asdict(self.dl),
            "workload": self.workload,
            "workers": self.workers,
            "rounds": rounds,
            "rendezvous": [host, port],
            "run_dir": self.run_dir,
            **self._cfg,
        }
        spec_path = os.path.join(self.run_dir, "spec.json")
        atomic_write_json(spec_path, spec)
        env = dict(os.environ)
        env["PYTHONPATH"] = _src_root() + os.pathsep + env.get("PYTHONPATH", "")
        procs: Dict[int, subprocess.Popen] = {}
        logs = {w: os.path.join(self.run_dir, f"w{w}.log")
                for w in range(self.workers)}
        armed_after: Dict[int, float] = {}
        relaunches = {w: 0 for w in range(self.workers)}
        gone_for_good: set = set()  # killed with no relaunch coming
        plan = list(self.chaos_plan)

        def _launch(w: int, *, rejoin: bool = False):
            cmd = [sys.executable, "-m", "repro.runtime.peer",
                   "--spec", spec_path, "--worker", str(w),
                   "--epoch", str(self.epochs[w])]
            if rejoin:
                cmd.append("--rejoin")
            with open(logs[w], "a") as lf:
                procs[w] = subprocess.Popen(
                    cmd, stdout=lf, stderr=subprocess.STDOUT, env=env
                )
            armed_after[w] = time.time()

        def _relaunch(w: int, why: str):
            self.epochs[w] += 1
            relaunches[w] += 1
            _launch(w, rejoin=True)
            if log:
                print(f"[runner] relaunch worker {w} epoch "
                      f"{self.epochs[w]} ({why})", flush=True)

        def _progress_fresh(w: int) -> bool:
            # only the *current* incarnation's progress arms a trigger —
            # a pre-crash progress value must not double-fire
            try:
                return os.path.getmtime(os.path.join(
                    self.run_dir, f"w{w}.progress")) > armed_after[w]
            except OSError:
                return False

        try:
            for w in range(self.workers):
                _launch(w)
            deadline = time.time() + self.timeout_s
            while any(p.poll() is None for p in procs.values()):
                # planned chaos kills
                for e in list(plan):
                    w = e["worker"]
                    if w in gone_for_good or procs[w].poll() is not None:
                        continue
                    if (_progress_fresh(w)
                            and self._progress(w) >= e["kill_at_round"]):
                        rnd = self._progress(w)
                        os.kill(procs[w].pid, signal.SIGKILL)
                        procs[w].wait()
                        self.kill_events.append({
                            "worker": w, "round": rnd,
                            "rejoin": e["rejoin"],
                            "epoch": self.epochs[w], "cause": "chaos",
                        })
                        if self.killed_at_round is None:
                            self.killed_at_round = rnd
                        if log:
                            print(f"[runner] SIGKILL worker {w} after "
                                  f"round {rnd}"
                                  + (" (rejoin)" if e["rejoin"] else ""),
                                  flush=True)
                        plan.remove(e)
                        if e["rejoin"]:
                            _relaunch(w, "chaos kill")
                        else:
                            gone_for_good.add(w)
                # unplanned deaths / stalls (supervision)
                if self.supervise:
                    for w in range(self.workers):
                        if (w in gone_for_good
                                or relaunches[w] >= self.max_relaunches):
                            continue
                        p = procs[w]
                        res = os.path.join(
                            self.run_dir, f"worker_{w}.json")
                        if p.poll() is not None and not os.path.exists(res):
                            self.kill_events.append({
                                "worker": w, "round": self._progress(w),
                                "rejoin": True, "epoch": self.epochs[w],
                                "cause": f"exit {p.returncode}",
                            })
                            _relaunch(w, f"unexpected exit "
                                         f"{p.returncode}")
                        elif (self.stall_timeout_s is not None
                                and p.poll() is None):
                            try:
                                mt = os.path.getmtime(os.path.join(
                                    self.run_dir, f"w{w}.progress"))
                            except OSError:
                                mt = armed_after[w]
                            last = max(mt, armed_after[w])
                            if time.time() - last > self.stall_timeout_s:
                                os.kill(p.pid, signal.SIGKILL)
                                p.wait()
                                self.kill_events.append({
                                    "worker": w,
                                    "round": self._progress(w),
                                    "rejoin": True,
                                    "epoch": self.epochs[w],
                                    "cause": "stall",
                                })
                                _relaunch(w, "progress stall")
                if time.time() > deadline:
                    for p in procs.values():
                        if p.poll() is None:
                            p.kill()
                    tails = "\n".join(
                        f"--- worker {w} ---\n{self._tail(logs[w])}"
                        for w in range(self.workers)
                    )
                    raise RuntimeError(
                        f"processes-backend run exceeded {self.timeout_s}s; "
                        f"killed all workers.\n{tails}"
                    )
                time.sleep(0.02)
        finally:
            rdv.stop()
        # --- collect ----------------------------------------------------
        for w in range(self.workers):
            path = os.path.join(self.run_dir, f"worker_{w}.json")
            if os.path.exists(path):
                with open(path) as f:
                    self.worker_results[w] = json.load(f)
            elif w not in gone_for_good and procs[w].returncode != 0:
                raise RuntimeError(
                    f"worker {w} exited {procs[w].returncode} without "
                    f"results:\n{self._tail(logs[w])}"
                )
        if not self.worker_results:
            raise RuntimeError(
                "no worker produced results:\n"
                + "\n".join(self._tail(p) for p in logs.values())
            )
        self._merge(log)
        if self.dl.results_dir:
            atomic_write_json(
                os.path.join(self.dl.results_dir, "results.json"),
                {"config": dataclasses.asdict(self.dl),
                 "history": self.history},
            )
        if own_dir and not self.keep_run_dir:
            shutil.rmtree(self.run_dir, ignore_errors=True)
        return self.history

    # ------------------------------------------------------------------
    def _merge(self, log: bool):
        n = self.dl.n_nodes
        res = self.worker_results
        self.n_params = next(iter(res.values()))["n_params"]
        self.live_rows = np.zeros(n, bool)
        self.final_X = np.full((n, self.n_params), np.nan, np.float32)
        for w, r in res.items():
            lo, hi = r["rows"]
            self.live_rows[lo:hi] = True
            xp = os.path.join(self.run_dir, f"worker_{w}_X.npy")
            if os.path.exists(xp):
                self.final_X[lo:hi] = np.load(xp)
            self.reweight_row_err = max(
                self.reweight_row_err, r["reweight_row_err"]
            )
        # per-round wall: elementwise max over workers (the sync barrier)
        walls = [r["round_wall_s"] for r in res.values()]
        for i in range(max(len(ws) for ws in walls)):
            self.round_wall_s.append(
                max(ws[i] for ws in walls if i < len(ws))
            )
        from repro.runtime.membership import RUNTIME_COUNTER_KEYS

        for key in RUNTIME_COUNTER_KEYS:
            self.counters[key] = sum(
                r["counters"].get(key, 0) for r in res.values()
            )
        self.workers_rejoined = sum(
            1 for r in res.values() if r.get("rejoined")
        )
        # per-worker conservation: every detection either stays dead or
        # was re-admitted (the chaos gate's bookkeeping invariant)
        per_worker = {}
        for w, r in res.items():
            c = r["counters"]
            per_worker[str(w)] = {
                "detected": int(c.get("faults_detected", 0)),
                "still_dead": len(r.get("dead_peers", [])),
                "rejoined": int(c.get("rejoin_total", 0)),
            }
        self.conservation = {
            "per_worker": per_worker,
            "ok": all(
                d["detected"] == d["still_dead"] + d["rejoined"]
                for d in per_worker.values()
            ),
        }
        by_round: Dict[int, List[Dict]] = {}
        for r in res.values():
            for rec in r["history"]:
                by_round.setdefault(rec["round"], []).append(rec)
        for rnd in sorted(by_round):
            recs = by_round[rnd]
            accs = np.concatenate([np.asarray(r["accs"]) for r in recs])
            total_bytes = float(sum(r["bytes_wire"] for r in recs))
            rec = {
                "round": rnd,
                "acc_mean": float(accs.mean()),
                "acc_std": float(accs.std()),
                "bytes_per_node": total_bytes / n,
                "wall_s": max(r["wall_s"] for r in recs),
                "sim_time_s": 0.0,
                "wire_dtype": self.wire_dtype,
                "n_live_rows": int(len(accs)),
                "workers_reporting": len(recs),
                "faults_detected": sum(r["faults_detected"] for r in recs),
                "retry_total": sum(r["retry_total"] for r in recs),
            }
            self.history.append(rec)
            if log:
                print(
                    f"[processes/{self.workers}w] round {rnd:4d} "
                    f"acc {rec['acc_mean']:.4f}±{rec['acc_std']:.4f} "
                    f"MB/node {rec['bytes_per_node'] / 1e6:.2f} "
                    f"rows {rec['n_live_rows']}/{n}",
                    flush=True,
                )
        self.bytes_sent = (
            self.history[-1]["bytes_per_node"] if self.history else 0.0
        )

    # ------------------------------------------------------------------
    def verify_rejoin_views(self) -> Dict[int, bool]:
        """Bitwise post-catch-up check (full sharing, ``dump_view=True``,
        ``keep_run_dir=True``): for every rejoined worker v, a surviving
        worker's final view of v's rows must equal — byte for byte — the
        rows v last put on the wire.  Proves the rejoiner was genuinely
        re-admitted into the final barrier, not merely reweighted back in
        approximately."""
        out: Dict[int, bool] = {}
        res = self.worker_results
        for v, rv in res.items():
            if not rv.get("rejoined") or not rv.get("completed"):
                continue
            sent_p = os.path.join(self.run_dir, f"worker_{v}_sent.npy")
            if not os.path.exists(sent_p):
                raise RuntimeError(
                    "verify_rejoin_views needs dump_view=True and "
                    "keep_run_dir=True"
                )
            sent = np.load(sent_p)
            lo = rv["rows"][0]
            ok = None
            for s, rs in res.items():
                if s == v or not rs.get("completed"):
                    continue
                ids = rs.get("need_from", {}).get(str(v), [])
                if not ids:
                    continue
                view = np.load(os.path.join(
                    self.run_dir, f"worker_{s}_view.npy"))
                ids = np.asarray(ids, np.int64)
                same = np.array_equal(view[ids], sent[ids - lo])
                ok = same if ok is None else (ok and same)
            out[v] = bool(ok) if ok is not None else False
        return out

    # ------------------------------------------------------------------
    def consensus_error(self) -> float:
        """mean_i ||x_i - x̄|| / (||x̄|| + eps) over surviving rows — the
        disagreement metric the examples print."""
        X = self.final_X[self.live_rows]
        xbar = X.mean(0)
        denom = np.linalg.norm(xbar) + 1e-12
        return float(
            np.mean(np.linalg.norm(X - xbar[None, :], axis=1)) / denom
        )
