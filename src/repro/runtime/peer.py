"""One worker process of the real-network backend.

A worker owns a contiguous row-block of ``B = N/K`` nodes and runs the
synchronous semantics for them on real clocks:

    every round:  local SGD on own rows (jax, in a thread so the event
                  loop keeps pumping heartbeats) -> serialize the payload
                  wire format for exactly the rows each peer's nodes
                  neighbor -> TCP send (per-message timeout, shared
                  exponential-backoff retry) -> barrier-gather peer
                  payloads -> mix through the *same* aggregation code as
                  the simulator (``mixing.apply_W`` / ``mix_payload``)
                  and keep own rows.

Determinism mirrors the engine exactly — params init from
``jax.random.key(seed)`` split over all N nodes (sliced to the block),
batches from the ``NodeBatcher`` PCG64 stream keyed by absolute round,
payload coordinate draws per-node keyed by *global* id
(``sharing._randk_idx(rows=...)``), gossip key ``fold_in(base_key, rnd)``
— which is what makes the loss-free-localhost equivalence oracle
(process trajectory == simulator trajectory at fp32 tolerance) hold.

## Join/leave protocol and failure detection

Workers discover each other through the rendezvous registry, then hold a
full mesh of directed TCP connections.  A heartbeat beacon doubles as
the failure detector: a peer silent for ``dead_timeout_s`` (or whose
sends exhaust the retry budget) is declared dead, its nodes' edges are
reweighted away via ``sharing.edge_reweight_sparse`` — surviving rows
stay row-stochastic, training completes on the survivors.  A graceful
leave announces itself with a BYE frame (counted as a leave, not a
fault); a SIGKILL'd worker never says goodbye, so its silence is counted
in ``faults_detected``.  A per-round watchdog bounds any socket wait so
a hung transport fails fast instead of stalling forever.

## Elastic membership: crash-rejoin

All liveness/epoch bookkeeping lives in :class:`runtime.membership.Membership`;
this module wires it to the sockets.  A supervisor-relaunched worker
(``--rejoin --epoch E``) restores its row-block from its newest
checkpoint (``run_dir/ckpt_w{wid}``, written every ``ckpt_every`` rounds
*before* the progress marker so visible progress implies a durable
checkpoint) or, with no checkpoint, cold-syncs a live donor's current
block over ``STATE_REQ``/``STATE`` frames.  It then runs the two-phase
JOIN handshake: *hello* (announce the new endpoint + epoch; survivors
reply WELCOME with their current round) and *commit* (pick a start round
safely past every survivor's current round; each survivor schedules the
re-admission for the top of exactly that round).  At admission the
survivor clears the dead mark and rebuilds its effective topology from
the pristine table (``sharing.edge_readmit_sparse`` — with everyone live
again this *is* the pristine object, so the fault-free mixing matrix is
restored bitwise).  Every frame carries the sender's epoch; frames from
dead/left senders or older incarnations are dropped — never enqueued —
and counted under ``stale_frames_dropped``.
"""
from __future__ import annotations

import argparse
import asyncio
import json
import os
import re
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.faults import retry_backoff_delay
from repro.runtime.membership import Membership, zero_counters
from repro.utils.io import atomic_write_json

HB_TAG = "hb"


class PeerWorker:
    def __init__(self, spec: Dict, wid: int, *, epoch: int = 0,
                 rejoin: bool = False):
        # jax / engine imports live here so the module is importable (for
        # the CLI --help and tests) before jax initializes
        import jax
        import jax.numpy as jnp

        from repro.core import mixing, sharing as sharing_lib
        from repro.core.engine import DLConfig, build_graph
        from repro.core.topology import SparseTopology
        from repro.runtime.runner import build_workload
        from repro.utils.pytree import tree_unvector, tree_vector

        self.jax, self.jnp = jax, jnp
        self.spec = spec
        self.wid = wid
        self.epoch = int(epoch)
        self.rejoin = bool(rejoin)
        dl = DLConfig(**spec["dl"])
        dl.validate()
        assert dl.backend == "processes"
        self.dl = dl
        self.K = int(spec["workers"])
        n = dl.n_nodes
        self.B = n // self.K
        self.lo, self.hi = wid * self.B, (wid + 1) * self.B
        self.own_ids = np.arange(self.lo, self.hi)
        self.rounds = int(spec.get("rounds", dl.rounds))
        self.ev = max(dl.eval_every, 1)
        # timeouts / retry policy (PR 7's backoff, now on the wall clock)
        self.hb_interval_s = float(spec.get("hb_interval_s", 0.25))
        self.dead_timeout_s = float(spec.get("dead_timeout_s", 3.0))
        self.watchdog_s = float(spec.get("watchdog_s", 60.0))
        self.send_timeout_s = float(spec.get("send_timeout_s", 10.0))
        self.backoff_s = float(spec.get("retry_backoff_s", 0.05))
        self.backoff_cap = int(spec.get("retry_backoff_cap", 5))
        # elastic-membership knobs: checkpoint cadence (0 = off), a round
        # floor so rejoin lands mid-run instead of after round 500 of 500
        # finished in 2s, and the bitwise view dump the chaos gate reads
        self.ckpt_every = int(spec.get("ckpt_every", 0))
        self.round_min_s = float(spec.get("round_min_s", 0.0))
        self.dump_view = bool(spec.get("dump_view", False))
        self.run_dir = spec["run_dir"]
        self.rdv = tuple(spec["rendezvous"])

        # --- experiment state (identical derivations to RoundEngine) ----
        init_fn, loss_fn, acc_fn, opt, batcher = build_workload(
            spec["workload"], dl
        )
        self.batcher = batcher
        keys = jax.random.split(jax.random.key(dl.seed), n)
        params_all = jax.vmap(init_fn)(keys)
        self.params = jax.tree_util.tree_map(
            lambda a: a[self.lo:self.hi], params_all
        )
        self.opt_state = jax.vmap(opt.init)(self.params)
        self.template = jax.tree_util.tree_map(lambda a: a[0], self.params)
        X_own = np.asarray(jax.vmap(tree_vector)(self.params), np.float32)
        self.P = X_own.shape[1]
        self.X_view = np.zeros((n, self.P), np.float32)
        self.X_view[self.lo:self.hi] = X_own
        self._base_key = jax.random.key(dl.seed + 17)

        graph = build_graph(dl)
        topo = SparseTopology.from_graph(graph)
        self.nbr = np.asarray(topo.nbr)
        self.w0 = np.asarray(topo.w, np.float32)
        self.w_self0 = np.asarray(topo.w_self, np.float32)
        self._topo_cls = SparseTopology
        self.live_nodes = np.ones(n, np.float32)
        self.topo_eff = SparseTopology(
            jnp.asarray(self.nbr), jnp.asarray(self.w0),
            jnp.asarray(self.w_self0),
        )
        # per-peer send/need sets from the genuine-edge mask (w > 0)
        valid = self.w0 > 0
        need = np.zeros((self.K, n), bool)  # need[v, i]: worker v reads row i
        owner = np.arange(n) // self.B
        for j in range(n):
            need[owner[j], self.nbr[j, valid[j]]] = True
        self.send_to = {
            v: np.array([i for i in self.own_ids if need[v, i]], np.int32)
            for v in range(self.K) if v != wid
        }
        self.need_from = {
            v: np.array(
                [j for j in range(v * self.B, (v + 1) * self.B)
                 if need[wid, j]], np.int32)
            for v in range(self.K) if v != wid
        }

        # --- sharing strategy: full rows or randomk payloads ------------
        self.payload = dl.sharing.lower() in ("randomk", "random")
        self.quantize = self.payload and dl.payload_quant
        self.k = max(1, int(dl.budget * self.P)) if self.payload else 0

        # --- jitted step/mix functions (engine-identical math) ----------
        L, bs = dl.local_steps, dl.batch_size

        def node_grad(p, x, y):
            return jax.grad(loss_fn)(p, x, y)

        def local(params, opt_state, bx, by):
            from repro.optim.optimizers import apply_updates

            for s in range(L):
                grads = jax.vmap(node_grad)(params, bx[s], by[s])
                updates, new_opt = jax.vmap(opt.update)(
                    grads, opt_state, params
                )
                params, opt_state = apply_updates(params, updates), new_opt
            return params, opt_state, jax.vmap(tree_vector)(params)

        self._local = jax.jit(local)

        def mix_full(topo_e, Xv):
            return mixing.apply_W(topo_e, Xv)[self.lo:self.hi]

        def mix_pay(topo_e, idx, val, Xv):
            return mixing.mix_payload(
                topo_e, idx, val, Xv, exact_values=not self.quantize
            )[self.lo:self.hi]

        self._mix_full = jax.jit(mix_full)
        self._mix_pay = jax.jit(mix_pay)

        if self.payload:
            own_rows = jnp.asarray(self.own_ids)

            def emit(key, Xo):
                idx = sharing_lib._randk_idx(
                    key, (self.B, self.P), self.k, rows=own_rows
                )
                return idx, jnp.take_along_axis(Xo, idx, axis=1)

            self._emit = jax.jit(emit)
            if self.quantize:
                from repro.core.compression import (
                    dequantize_int8, quantize_int8,
                )

                self._quant = jax.jit(quantize_int8)
                self._dequant = dequantize_int8

        def unvec(X2):
            return jax.vmap(lambda v: tree_unvector(v, self.template))(X2)

        self._unvec = jax.jit(unvec)
        self._vec = jax.jit(lambda p: jax.vmap(tree_vector)(p))
        self._opt_init = jax.jit(lambda p: jax.vmap(opt.init)(p))
        self._eval = jax.jit(
            lambda p, tx, ty: jax.vmap(lambda q: acc_fn(q, tx, ty))(p)
        )

        # --- runtime state ----------------------------------------------
        self.mem = Membership(self.K, wid, self.dead_timeout_s)
        self.peers: Dict[int, Tuple[str, int]] = {}
        self.conns: Dict[int, Tuple] = {}
        self.inbox: Dict[int, asyncio.Queue] = {}
        self._pending_bye: set = set()
        self._ctrl_q: asyncio.Queue = asyncio.Queue()
        self._state_q: asyncio.Queue = asyncio.Queue()
        self.wire_bytes = 0.0
        self.counters = zero_counters()
        self.detect_rounds: Dict[str, int] = {}
        self.admit_rounds: Dict[str, int] = {}
        self.reweight_row_err = 0.0
        self.round_wall: List[float] = []
        self.records: List[Dict] = []
        self.cur_round = -1
        self.start_round = 0
        self.rejoined = False
        self.completed = False
        self.catchup_source: Optional[str] = None
        self._last_sent: Optional[np.ndarray] = None

    # back-compat views (tests and the runner read these)
    @property
    def dead(self) -> set:
        return self.mem.dead

    @property
    def left(self) -> set:
        return self.mem.left

    # ------------------------------------------------------------------
    def _warmup(self):
        """Compile every jitted function before joining the mesh, so no
        peer mistakes our compile stall for death and the steady-state
        round walls that calibration records exclude compilation."""
        jnp = self.jnp
        idx = self.batcher.round_indices(0, self.dl.local_steps)
        bx = jnp.asarray(self.batcher.x[idx[:, self.lo:self.hi]])
        by = jnp.asarray(self.batcher.y[idx[:, self.lo:self.hi]])
        p, o, Xo = self._local(self.params, self.opt_state, bx, by)
        Xv = jnp.asarray(self.X_view)
        if self.payload:
            key = self.jax.random.fold_in(self._base_key, 0)
            i, v = self._emit(key, Xo)
            if self.quantize:
                c, s = self._quant(v)
                v = self._dequant(c, s)
            zi = jnp.zeros((self.dl.n_nodes, self.k), jnp.int32)
            zv = jnp.zeros((self.dl.n_nodes, self.k), jnp.float32)
            zi = zi.at[self.lo:self.hi].set(i)
            zv = zv.at[self.lo:self.hi].set(v)
            X2 = self._mix_pay(self.topo_eff, zi, zv, Xv)
        else:
            X2 = self._mix_full(self.topo_eff, Xv)
        self._unvec(X2)
        tx, ty = self.batcher.test_batch()
        np.asarray(self._eval(p, jnp.asarray(tx), jnp.asarray(ty)))

    # ------------------------------------------------------------------
    # membership
    # ------------------------------------------------------------------
    def _rows_of(self, v: int) -> np.ndarray:
        return np.arange(v * self.B, (v + 1) * self.B)

    def _recompute_topo(self):
        """Effective topology from the *pristine* table and the current
        live mask: the reweight on deaths, the exact (bitwise, when all
        rows are live again) restore on re-admissions."""
        from repro.core.sharing import edge_readmit_sparse

        jnp = self.jnp
        base = self._topo_cls(
            jnp.asarray(self.nbr), jnp.asarray(self.w0),
            jnp.asarray(self.w_self0),
        )
        self.topo_eff = edge_readmit_sparse(
            base, jnp.asarray(self.live_nodes[self.nbr])
        )

    def _purge_inbox(self, v: int):
        q = self.inbox.get(v)
        if q is None:
            return
        while not q.empty():
            q.get_nowait()
            self.counters["stale_frames_dropped"] += 1

    def _mark_gone(self, v: int, rnd: int, *, fault: bool):
        """Graceful-degradation path: drop worker v's nodes and return
        their edge mass to the surviving receivers' diagonals
        (``edge_reweight_sparse`` — the PR 7 reweight, reused on real
        deaths), so surviving rows stay row-stochastic.  Already-queued
        frames from v are purged (and counted stale) — a corpse's rows
        must not feed a later barrier."""
        if not self.mem.is_live(v):
            return
        if fault:
            self.mem.declare_dead(v)
            self.counters["faults_detected"] += 1
        else:
            self.mem.declare_left(v)
            self.counters["leaves"] += 1
        self.live_nodes[self._rows_of(v)] = 0.0
        self._recompute_topo()
        w = np.asarray(self.topo_eff.w)
        ws = np.asarray(self.topo_eff.w_self)
        rows = slice(self.lo, self.hi)
        err = float(np.abs(ws[rows] + w[rows].sum(-1) - 1.0).max())
        self.reweight_row_err = max(self.reweight_row_err, err)
        self.detect_rounds[str(v)] = rnd
        self.conns.pop(v, None)
        self._purge_inbox(v)

    def _process_admissions(self, rnd: int):
        """Top-of-round hook: re-admit every peer whose committed start
        round has arrived — clear the dead mark, restore the pristine
        edge weights, and resume expecting its rows this very round."""
        for v in self.mem.due_admissions(rnd):
            was_dead = self.mem.admit(v)
            self.live_nodes[self._rows_of(v)] = 1.0
            self._recompute_topo()
            if was_dead:
                self.counters["rejoin_total"] += 1
            self.mem.last_seen[v] = time.monotonic()
            self.admit_rounds[str(v)] = rnd

    def _live_peers(self) -> List[int]:
        return self.mem.live_peers()

    # ------------------------------------------------------------------
    # transport
    # ------------------------------------------------------------------
    async def _handle_conn(self, reader, writer):
        from repro.runtime import transport as tp

        try:
            while True:
                ftype, body = await tp.read_frame(reader)
                if ftype == tp.MSG_ROWS:
                    msg = tp.decode_rows(body)
                    v = msg["sender"]
                    st = self.mem.frame_status(v, msg["epoch"])
                    if st == "ok":
                        self.mem.last_seen[v] = time.monotonic()
                        self.inbox[v].put_nowait(msg)
                    elif st == "stale":
                        self.counters["stale_frames_dropped"] += 1
                elif ftype == tp.MSG_HEARTBEAT:
                    v, ep = tp.decode_peer(body)
                    if self.mem.heartbeat(
                            v, ep, time.monotonic()) == "stale":
                        self.counters["stale_frames_dropped"] += 1
                elif ftype == tp.MSG_BYE:
                    # graceful leave: the barrier stops expecting rows from
                    # v (same reweight as a death, counted as a leave)
                    v, ep = tp.decode_peer(body)
                    if self.mem.frame_status(v, ep) == "ok":
                        self._pending_bye.add(v)
                    else:
                        self.counters["stale_frames_dropped"] += 1
                elif ftype == tp.MSG_JOIN:
                    await self._on_join(tp.decode_json(body))
                elif ftype == tp.MSG_WELCOME:
                    msg = tp.decode_json(body)
                    v = int(msg["worker"])
                    # a WELCOME teaches the joiner the survivor's epoch
                    # (a survivor may itself be a prior rejoiner, and the
                    # joiner's fresh view starts everyone at epoch 0)
                    self.mem.epochs[v] = max(
                        self.mem.epochs.get(v, 0), int(msg["epoch"])
                    )
                    self.mem.last_seen[v] = time.monotonic()
                    self._ctrl_q.put_nowait(msg)
                elif ftype == tp.MSG_STATE_REQ:
                    await self._on_state_req(tp.decode_json(body))
                elif ftype == tp.MSG_STATE:
                    self._state_q.put_nowait((tp.decode_rows(body), len(body)))
        except (asyncio.IncompleteReadError, ConnectionError, OSError,
                ValueError):
            return
        finally:
            writer.close()

    async def _send_ctrl(self, v: int, ftype: int, body: bytes) -> bool:
        """Best-effort control-plane send (JOIN/WELCOME/STATE*), reusing
        (or re-dialing) the data-plane connection to v."""
        from repro.runtime import transport as tp

        try:
            if v not in self.conns:
                self.conns[v] = await asyncio.wait_for(
                    asyncio.open_connection(*self.peers[v]), timeout=2.0
                )
            await asyncio.wait_for(
                tp.write_frame(self.conns[v][1], ftype, body),
                timeout=self.send_timeout_s,
            )
            self.wire_bytes += len(body) + 5
            return True
        except (OSError, asyncio.TimeoutError, KeyError):
            self.conns.pop(v, None)
            return False

    async def _on_join(self, msg: Dict):
        """Survivor side of the two-phase rejoin handshake."""
        from repro.runtime import transport as tp

        v, ep = int(msg["worker"]), int(msg["epoch"])
        phase = msg.get("phase")
        if phase == "hello":
            if self.mem.is_live(v) and ep > self.mem.epochs[v]:
                # the supervisor relaunched v before we ever noticed the
                # death: retire the old incarnation first so detection
                # and re-admission stay paired (conservation invariant)
                self._mark_gone(v, self.cur_round, fault=True)
            st = self.mem.hello(v, ep)
            if st == "stale":
                self.counters["stale_frames_dropped"] += 1
                return
            self.peers[v] = (msg["host"], int(msg["port"]))
            self.conns.pop(v, None)  # the old incarnation's socket
            self.mem.last_seen[v] = time.monotonic()
            await self._send_ctrl(v, tp.MSG_WELCOME, tp.encode_json({
                "phase": "hello", "worker": self.wid, "epoch": self.epoch,
                "round": self.cur_round, "ok": True,
            }))
        elif phase == "commit":
            start = int(msg["start_round"])
            ok = self.mem.schedule_admit(v, ep, start, self.cur_round)
            await self._send_ctrl(v, tp.MSG_WELCOME, tp.encode_json({
                "phase": "commit", "worker": self.wid, "epoch": self.epoch,
                "round": self.cur_round, "start": start, "ok": ok,
            }))

    async def _on_state_req(self, msg: Dict):
        """Donor side of cold catch-up: ship the current own-block rows
        (the STATE body reuses the ROWS codec)."""
        from repro.runtime import transport as tp

        v = int(msg["worker"])
        body = tp.encode_rows(
            max(self.cur_round, 0), self.wid, self.own_ids, tp.FMT_FULL_F32,
            epoch=self.epoch, rows=self.X_view[self.lo:self.hi].copy(),
        )
        await self._send_ctrl(v, tp.MSG_STATE, body)

    async def _heartbeat_loop(self):
        from repro.runtime import transport as tp

        beat = tp.encode_peer(self.wid, self.epoch)
        while True:
            await asyncio.sleep(self.hb_interval_s)
            # beacon mid-rejoin peers too: a waiting rejoiner must not
            # mistake our silence for death before its start round
            for v in self.mem.beacon_targets():
                conn = self.conns.get(v)
                if conn is None:
                    continue
                try:
                    conn[1].write(
                        tp._FRAME.pack(tp.MSG_HEARTBEAT, len(beat)) + beat
                    )
                except OSError:
                    pass

    async def _send_rows(self, v: int, rnd: int, body: bytes) -> bool:
        """Per-message send with timeout and the shared exponential
        backoff; exhausting the retry budget declares the peer dead."""
        from repro.runtime import transport as tp

        for attempt in range(self.backoff_cap + 2):
            try:
                if v not in self.conns:
                    self.conns[v] = await asyncio.open_connection(
                        *self.peers[v]
                    )
                await asyncio.wait_for(
                    tp.write_frame(self.conns[v][1], tp.MSG_ROWS, body),
                    timeout=self.send_timeout_s,
                )
                self.wire_bytes += len(body) + 5
                return True
            except (OSError, asyncio.TimeoutError):
                self.conns.pop(v, None)
                self.counters["retry_total"] += 1
                await asyncio.sleep(
                    retry_backoff_delay(attempt, self.backoff_s,
                                        self.backoff_cap)
                )
        self._mark_gone(v, rnd, fault=True)
        return False

    async def _gather(self, rnd: int) -> Dict[int, Dict]:
        """The sync barrier: one ROWS frame per live peer for this round.
        TCP ordering + one frame per (peer, round) means the next frame
        from a peer is this round's — anything else is a protocol error.
        Waits are sliced so heartbeat silence can be detected mid-wait;
        the whole barrier is bounded by the watchdog."""
        out: Dict[int, Dict] = {}
        t0 = time.monotonic()
        for v in list(self.need_from):
            if not len(self.need_from[v]):
                continue  # no edge crosses this worker pair
            while self.mem.is_live(v) and v not in out:
                # BYE is FIFO-ordered after the peer's last ROWS frame, so
                # only honor it once the inbox is drained — a leaver's
                # final-round contribution still counts
                if v in self._pending_bye and self.inbox[v].empty():
                    self._pending_bye.discard(v)
                    self._mark_gone(v, rnd, fault=False)
                    break
                try:
                    msg = await asyncio.wait_for(
                        self.inbox[v].get(), timeout=0.25
                    )
                except asyncio.TimeoutError:
                    now = time.monotonic()
                    if now - self.mem.last_seen.get(v, t0) \
                            > self.dead_timeout_s:
                        self._mark_gone(v, rnd, fault=True)
                    if now - t0 > self.watchdog_s:
                        raise RuntimeError(
                            f"worker {self.wid}: watchdog — round {rnd} "
                            f"barrier stalled > {self.watchdog_s}s on peer "
                            f"{v}"
                        )
                    continue
                if msg["round"] < rnd:
                    continue  # pre-death stragglers of an old round
                if msg["round"] > rnd:
                    raise RuntimeError(
                        f"worker {self.wid}: protocol error — peer {v} "
                        f"sent round {msg['round']} during round {rnd}"
                    )
                out[v] = msg
        return out

    # ------------------------------------------------------------------
    # the round
    # ------------------------------------------------------------------
    async def _round(self, rnd: int):
        import jax

        jnp = self.jnp
        from repro.runtime import transport as tp

        loop = asyncio.get_running_loop()
        t0 = time.monotonic()
        self.cur_round = rnd
        self._process_admissions(rnd)
        idx = self.batcher.round_indices(rnd, self.dl.local_steps)
        bx = self.batcher.x[idx[:, self.lo:self.hi]]
        by = self.batcher.y[idx[:, self.lo:self.hi]]

        def _step():
            p, o, Xo = self._local(
                self.params, self.opt_state, jnp.asarray(bx), jnp.asarray(by)
            )
            return p, o, np.asarray(Xo, np.float32)

        self.params, self.opt_state, X_own = await loop.run_in_executor(
            None, _step
        )
        self.X_view[self.lo:self.hi] = X_own
        self._last_sent = X_own

        # --- emit + send ------------------------------------------------
        if self.payload:
            key = jax.random.fold_in(self._base_key, rnd)

            def _emit():
                i, v = self._emit(key, jnp.asarray(X_own))
                if self.quantize:
                    c, s = self._quant(v)
                    return (np.asarray(i), np.asarray(c),
                            np.asarray(s, np.float32).reshape(-1),
                            np.asarray(self._dequant(c, s), np.float32))
                return np.asarray(i), None, None, np.asarray(v, np.float32)

            idx_own, codes_own, scale_own, val_own = (
                await loop.run_in_executor(None, _emit)
            )
        sends = []
        for v in self._live_peers():
            ids = self.send_to[v]
            if not len(ids):
                continue
            loc = ids - self.lo
            if not self.payload:
                body = tp.encode_rows(
                    rnd, self.wid, ids, tp.FMT_FULL_F32, epoch=self.epoch,
                    rows=X_own[loc],
                )
            elif self.quantize:
                body = tp.encode_rows(
                    rnd, self.wid, ids, tp.FMT_PAYLOAD_I8, epoch=self.epoch,
                    idx=idx_own[loc], codes=codes_own[loc],
                    scale=scale_own[loc],
                )
            else:
                body = tp.encode_rows(
                    rnd, self.wid, ids, tp.FMT_PAYLOAD_F32, epoch=self.epoch,
                    idx=idx_own[loc], val=val_own[loc],
                )
            sends.append(self._send_rows(v, rnd, body))
        if sends:
            await asyncio.gather(*sends)

        # --- barrier gather + aggregate ---------------------------------
        got = await self._gather(rnd)
        if self.payload:
            idx_all = np.zeros((self.dl.n_nodes, self.k), np.int32)
            val_all = np.zeros((self.dl.n_nodes, self.k), np.float32)
            idx_all[self.lo:self.hi] = idx_own
            val_all[self.lo:self.hi] = val_own
            for msg in got.values():
                if msg["fmt"] == tp.FMT_PAYLOAD_I8:
                    val = np.asarray(self._dequant(
                        self.jnp.asarray(msg["codes"]),
                        self.jnp.asarray(msg["scale"][:, None]),
                    ), np.float32)
                else:
                    val = msg["val"]
                idx_all[msg["ids"]] = msg["idx"]
                val_all[msg["ids"]] = val
        else:
            for msg in got.values():
                self.X_view[msg["ids"]] = msg["rows"]

        def _mix():
            Xv = jnp.asarray(self.X_view)
            if self.payload:
                X2 = self._mix_pay(
                    self.topo_eff, jnp.asarray(idx_all), jnp.asarray(val_all),
                    Xv,
                )
            else:
                X2 = self._mix_full(self.topo_eff, Xv)
            return self._unvec(X2), np.asarray(X2, np.float32)

        self.params, X2_own = await loop.run_in_executor(None, _mix)
        self.X_view[self.lo:self.hi] = X2_own
        # round floor: pad so wall-clock rounds are long enough for a
        # killed worker's relaunch to land mid-run (chaos harness knob)
        dt = time.monotonic() - t0
        if self.round_min_s > dt:
            await asyncio.sleep(self.round_min_s - dt)
        self.round_wall.append(time.monotonic() - t0)

    # ------------------------------------------------------------------
    # checkpoint catch-up
    # ------------------------------------------------------------------
    def _ckpt_dir(self) -> str:
        return os.path.join(self.run_dir, f"ckpt_w{self.wid}")

    def _save_checkpoint(self, rnd: int):
        from repro.checkpoint import save_checkpoint

        save_checkpoint(self._ckpt_dir(), rnd, params=self.params,
                        opt_state=self.opt_state)

    def _restore_checkpoint(self) -> Optional[int]:
        """Restore the newest readable checkpoint of this row-block;
        returns its round or None.  Saves are atomic, but stay defensive:
        an unreadable step falls back to the one before it."""
        from repro.checkpoint import load_checkpoint
        from repro.checkpoint.checkpoint import restore_tree

        path = self._ckpt_dir()
        if not os.path.isdir(path):
            return None
        steps = sorted(
            (int(m.group(1)) for f in os.listdir(path)
             if (m := re.match(r"ckpt_(\d+)\.npz$", f))),
            reverse=True,
        )
        for step in steps:
            try:
                _, trees = load_checkpoint(path, step)
                if "params" not in trees:
                    continue
                self.params = restore_tree(self.params, trees["params"])
                # a leafless opt_state (plain SGD) saves no arrays at all
                self.opt_state = restore_tree(
                    self.opt_state, trees.get("opt_state")
                )
            except Exception:
                continue
            self.X_view[self.lo:self.hi] = np.asarray(
                self._vec(self.params), np.float32
            )
            self.counters["catchup_bytes"] += os.path.getsize(
                os.path.join(path, f"ckpt_{step:08d}.npz")
            )
            self.catchup_source = f"checkpoint:{step}"
            return step
        return None

    async def _cold_sync(self, donors: List[int]) -> bool:
        """No checkpoint: pull a live donor's current block over
        STATE_REQ/STATE and map its rows onto ours (cyclically — blocks
        are equal-sized, so this is the identity map in practice); the
        optimizer state restarts fresh."""
        from repro.runtime import transport as tp

        req = tp.encode_json({"worker": self.wid, "epoch": self.epoch})
        for v in donors:
            if not await self._send_ctrl(v, tp.MSG_STATE_REQ, req):
                continue
            try:
                msg, nbytes = await asyncio.wait_for(
                    self._state_q.get(), timeout=self.dead_timeout_s + 2.0
                )
            except asyncio.TimeoutError:
                continue
            rows = np.asarray(msg["rows"], np.float32)
            take = rows[np.arange(self.B) % len(rows)]
            self.params = self._unvec(self.jnp.asarray(take))
            self.opt_state = self._opt_init(self.params)
            self.X_view[self.lo:self.hi] = take
            self.counters["catchup_bytes"] += nbytes
            self.catchup_source = f"donor:{msg['sender']}"
            return True
        return False

    # ------------------------------------------------------------------
    # rejoiner side of the handshake
    # ------------------------------------------------------------------
    async def _rejoin_handshake(self, my_port: int,
                                have_ckpt: bool) -> Optional[int]:
        """Hello every peer, catch up (donor STATE if no checkpoint),
        then commit a start round safely past every survivor's current
        round.  Returns the committed start round, or None when there is
        nothing left to rejoin (no survivors, or the run is ending)."""
        from repro.runtime import transport as tp

        hello = tp.encode_json({
            "phase": "hello", "worker": self.wid, "epoch": self.epoch,
            "host": "127.0.0.1", "port": my_port,
        })
        targets = self.mem.live_peers()
        for v in targets:
            await self._send_ctrl(v, tp.MSG_JOIN, hello)
        welcomes: Dict[int, Dict] = {}
        deadline = time.monotonic() + self.dead_timeout_s + 2.0
        while len(welcomes) < len(targets) and time.monotonic() < deadline:
            try:
                msg = await asyncio.wait_for(self._ctrl_q.get(), timeout=0.25)
            except asyncio.TimeoutError:
                continue
            if msg.get("phase") == "hello" and msg.get("ok"):
                welcomes[int(msg["worker"])] = msg
        for v in targets:
            if v not in welcomes:
                self._mark_gone(v, -1, fault=True)
        if not welcomes:
            return None
        if not have_ckpt:
            await self._cold_sync(sorted(welcomes))
        if self.catchup_source is None:
            self.catchup_source = "fresh"

        # commit: everyone must re-admit us at the same future round
        slack = max(4, int(2.0 / max(self.round_min_s, 0.02)))
        for _attempt in range(6):
            cur = max(int(m["round"]) for m in welcomes.values())
            start = cur + slack
            if start >= self.rounds:
                return None  # the run ends before we could participate
            commit = tp.encode_json({
                "phase": "commit", "worker": self.wid, "epoch": self.epoch,
                "start_round": start,
            })
            for v in list(welcomes):
                await self._send_ctrl(v, tp.MSG_JOIN, commit)
            acks: Dict[int, Dict] = {}
            deadline = time.monotonic() + self.dead_timeout_s + 2.0
            while len(acks) < len(welcomes) \
                    and time.monotonic() < deadline:
                try:
                    msg = await asyncio.wait_for(
                        self._ctrl_q.get(), timeout=0.25
                    )
                except asyncio.TimeoutError:
                    continue
                if msg.get("phase") == "commit" \
                        and int(msg.get("start", -1)) == start:
                    acks[int(msg["worker"])] = msg
            for v in list(welcomes):
                if v not in acks:
                    self._mark_gone(v, -1, fault=True)
                    welcomes.pop(v)
            if not welcomes:
                return None
            if all(m.get("ok") for m in acks.values() if m):
                return start
            # a nack means some survivor's round already passed start:
            # refresh our round knowledge and retry further out
            for v, m in acks.items():
                if v in welcomes:
                    welcomes[v]["round"] = max(
                        int(welcomes[v]["round"]), int(m.get("round", -1))
                    )
            slack *= 2
        return None

    # ------------------------------------------------------------------
    async def main(self):
        from repro.runtime import transport as tp

        server = await asyncio.start_server(
            self._handle_conn, "127.0.0.1", 0
        )
        my_port = server.sockets[0].getsockname()[1]
        loop = asyncio.get_running_loop()
        # compile before joining: peers time liveness, not XLA
        await loop.run_in_executor(None, self._warmup)
        ck = None
        if self.rejoin:
            ck = await loop.run_in_executor(None, self._restore_checkpoint)
        self.peers = await tp.rendezvous_register(
            self.rdv[0], self.rdv[1], self.wid, "127.0.0.1", my_port,
            timeout_s=float(self.spec.get("join_timeout_s", 30.0)),
        )
        now = time.monotonic()
        for v in range(self.K):
            if v == self.wid:
                continue
            self.inbox[v] = asyncio.Queue()
            self.mem.last_seen[v] = now
            try:
                r, w = await tp.open_with_retry(
                    *self.peers[v], attempts=10 if self.rejoin else 40
                )
                self.conns[v] = (r, w)
            except ConnectionError:
                if not self.rejoin:
                    raise
                # a fellow casualty: rejoin with whoever answers
                self._mark_gone(v, -1, fault=True)
        hb = asyncio.create_task(self._heartbeat_loop())
        start = 0
        if self.rejoin:
            start = await self._rejoin_handshake(my_port, ck is not None)
            if start is None:
                hb.cancel()
                server.close()
                self._write_results()
                return
            self.rejoined = True
        self.start_round = start
        t_start = time.monotonic()
        tx, ty = self.batcher.test_batch()
        txj, tyj = self.jnp.asarray(tx), self.jnp.asarray(ty)
        try:
            for rnd in range(start, self.rounds):
                await self._round(rnd)
                # checkpoint *before* the progress marker: any progress
                # the supervisor can see implies a durable checkpoint
                if self.ckpt_every and (rnd + 1) % self.ckpt_every == 0:
                    await loop.run_in_executor(
                        None, self._save_checkpoint, rnd
                    )
                self._write_progress(rnd)
                if rnd % self.ev == 0 or rnd == self.rounds - 1:
                    accs = np.asarray(self._eval(self.params, txj, tyj))
                    self.records.append({
                        "round": rnd,
                        "accs": [float(a) for a in accs],
                        "bytes_wire": float(self.wire_bytes),
                        "wall_s": time.monotonic() - t_start,
                        **{k: int(v) for k, v in self.counters.items()},
                    })
            self.completed = True
        finally:
            hb.cancel()
            bye = tp.encode_peer(self.wid, self.epoch)
            for v in self._live_peers():
                conn = self.conns.get(v)
                if conn is not None:
                    try:
                        await tp.write_frame(conn[1], tp.MSG_BYE, bye)
                    except OSError:
                        pass
            server.close()
        self._write_results()

    # ------------------------------------------------------------------
    def _write_progress(self, rnd: int):
        """Crash-consistent progress marker (the runner's kill trigger and
        liveness probe): temp + rename, like every result file here."""
        path = os.path.join(self.run_dir, f"w{self.wid}.progress")
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            f.write(str(rnd))
        os.replace(tmp, path)

    def _write_results(self):
        out = {
            "worker": self.wid,
            "rows": [int(self.lo), int(self.hi)],
            "n_params": int(self.P),
            "epoch": self.epoch,
            "history": self.records,
            "round_wall_s": self.round_wall,
            "wire_bytes": float(self.wire_bytes),
            "counters": dict(self.counters),
            "detect_rounds": self.detect_rounds,
            "admit_rounds": self.admit_rounds,
            "reweight_row_err": self.reweight_row_err,
            "dead_peers": sorted(self.dead),
            "left_peers": sorted(self.left),
            "rejoined": self.rejoined,
            "start_round": int(self.start_round),
            "catchup_source": self.catchup_source,
            "completed": self.completed,
            "membership": self.mem.snapshot(),
        }
        if self.dump_view:
            out["need_from"] = {
                str(v): [int(i) for i in ids]
                for v, ids in self.need_from.items()
            }
        atomic_write_json(
            os.path.join(self.run_dir, f"worker_{self.wid}.json"), out
        )
        fn = os.path.join(self.run_dir, f"worker_{self.wid}_X.npy")
        tmp = fn + ".tmp.npy"
        np.save(tmp, self.X_view[self.lo:self.hi])
        os.replace(tmp, fn)
        if self.dump_view:
            for tag, arr in (
                ("view", self.X_view),
                ("sent", self._last_sent if self._last_sent is not None
                 else self.X_view[self.lo:self.hi]),
            ):
                fn = os.path.join(
                    self.run_dir, f"worker_{self.wid}_{tag}.npy"
                )
                tmp = fn + ".tmp.npy"
                np.save(tmp, arr)
                os.replace(tmp, fn)


def main(argv: Optional[List[str]] = None):
    ap = argparse.ArgumentParser(
        description="one row-block worker of the processes backend"
    )
    ap.add_argument("--spec", required=True, help="path to the run spec JSON")
    ap.add_argument("--worker", type=int, required=True)
    ap.add_argument("--epoch", type=int, default=0,
                    help="membership epoch (incarnation number)")
    ap.add_argument("--rejoin", action="store_true",
                    help="relaunch after a crash: restore + JOIN handshake")
    args = ap.parse_args(argv)
    with open(args.spec) as f:
        spec = json.load(f)
    worker = PeerWorker(spec, args.worker, epoch=args.epoch,
                        rejoin=args.rejoin)
    asyncio.run(worker.main())


if __name__ == "__main__":
    main()
