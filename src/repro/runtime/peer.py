"""One worker process of the real-network backend.

A worker owns a contiguous row-block of ``B = N/K`` nodes and runs the
synchronous semantics for them on real clocks:

    every round:  local SGD on own rows (jax, in a thread so the event
                  loop keeps pumping heartbeats) -> serialize the payload
                  wire format for exactly the rows each peer's nodes
                  neighbor -> TCP send (per-message timeout, shared
                  exponential-backoff retry) -> barrier-gather peer
                  payloads -> mix through the *same* aggregation code as
                  the simulator (``mixing.apply_W`` / ``mix_payload``)
                  and keep own rows.

Determinism mirrors the engine exactly — params init from
``jax.random.key(seed)`` split over all N nodes (sliced to the block),
batches from the ``NodeBatcher`` PCG64 stream keyed by absolute round,
payload coordinate draws per-node keyed by *global* id
(``sharing._randk_idx(rows=...)``), gossip key ``fold_in(base_key, rnd)``
— which is what makes the loss-free-localhost equivalence oracle
(process trajectory == simulator trajectory at fp32 tolerance) hold.

## Join/leave protocol and failure detection

Workers discover each other through the rendezvous registry, then hold a
full mesh of directed TCP connections.  A heartbeat beacon doubles as
the failure detector: a peer silent for ``dead_timeout_s`` (or whose
sends exhaust the retry budget) is declared dead, its nodes' edges are
reweighted away via ``sharing.edge_reweight_sparse`` — surviving rows
stay row-stochastic, training completes on the survivors.  A graceful
leave announces itself with a BYE frame (counted as a leave, not a
fault); a SIGKILL'd worker never says goodbye, so its silence is counted
in ``faults_detected``.  A per-round watchdog bounds any socket wait so
a hung transport fails fast instead of stalling forever.
"""
from __future__ import annotations

import argparse
import asyncio
import json
import os
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.faults import retry_backoff_delay
from repro.utils.io import atomic_write_json

HB_TAG = "hb"


class PeerWorker:
    def __init__(self, spec: Dict, wid: int):
        # jax / engine imports live here so the module is importable (for
        # the CLI --help and tests) before jax initializes
        import jax
        import jax.numpy as jnp

        from repro.core import mixing, sharing as sharing_lib
        from repro.core.engine import DLConfig, build_graph
        from repro.core.topology import SparseTopology
        from repro.runtime.runner import build_workload
        from repro.utils.pytree import tree_unvector, tree_vector

        self.jax, self.jnp = jax, jnp
        self.spec = spec
        self.wid = wid
        dl = DLConfig(**spec["dl"])
        dl.validate()
        assert dl.backend == "processes"
        self.dl = dl
        self.K = int(spec["workers"])
        n = dl.n_nodes
        self.B = n // self.K
        self.lo, self.hi = wid * self.B, (wid + 1) * self.B
        self.own_ids = np.arange(self.lo, self.hi)
        self.rounds = int(spec.get("rounds", dl.rounds))
        self.ev = max(dl.eval_every, 1)
        # timeouts / retry policy (PR 7's backoff, now on the wall clock)
        self.hb_interval_s = float(spec.get("hb_interval_s", 0.25))
        self.dead_timeout_s = float(spec.get("dead_timeout_s", 3.0))
        self.watchdog_s = float(spec.get("watchdog_s", 60.0))
        self.send_timeout_s = float(spec.get("send_timeout_s", 10.0))
        self.backoff_s = float(spec.get("retry_backoff_s", 0.05))
        self.backoff_cap = int(spec.get("retry_backoff_cap", 5))
        self.run_dir = spec["run_dir"]
        self.rdv = tuple(spec["rendezvous"])

        # --- experiment state (identical derivations to RoundEngine) ----
        init_fn, loss_fn, acc_fn, opt, batcher = build_workload(
            spec["workload"], dl
        )
        self.batcher = batcher
        keys = jax.random.split(jax.random.key(dl.seed), n)
        params_all = jax.vmap(init_fn)(keys)
        self.params = jax.tree_util.tree_map(
            lambda a: a[self.lo:self.hi], params_all
        )
        self.opt_state = jax.vmap(opt.init)(self.params)
        self.template = jax.tree_util.tree_map(lambda a: a[0], self.params)
        X_own = np.asarray(jax.vmap(tree_vector)(self.params), np.float32)
        self.P = X_own.shape[1]
        self.X_view = np.zeros((n, self.P), np.float32)
        self.X_view[self.lo:self.hi] = X_own
        self._base_key = jax.random.key(dl.seed + 17)

        graph = build_graph(dl)
        topo = SparseTopology.from_graph(graph)
        self.nbr = np.asarray(topo.nbr)
        self.w0 = np.asarray(topo.w, np.float32)
        self.w_self0 = np.asarray(topo.w_self, np.float32)
        self._topo_cls = SparseTopology
        self.live_nodes = np.ones(n, np.float32)
        self.topo_eff = SparseTopology(
            jnp.asarray(self.nbr), jnp.asarray(self.w0),
            jnp.asarray(self.w_self0),
        )
        # per-peer send/need sets from the genuine-edge mask (w > 0)
        valid = self.w0 > 0
        need = np.zeros((self.K, n), bool)  # need[v, i]: worker v reads row i
        owner = np.arange(n) // self.B
        for j in range(n):
            need[owner[j], self.nbr[j, valid[j]]] = True
        self.send_to = {
            v: np.array([i for i in self.own_ids if need[v, i]], np.int32)
            for v in range(self.K) if v != wid
        }
        self.need_from = {
            v: np.array(
                [j for j in range(v * self.B, (v + 1) * self.B)
                 if need[wid, j]], np.int32)
            for v in range(self.K) if v != wid
        }

        # --- sharing strategy: full rows or randomk payloads ------------
        self.payload = dl.sharing.lower() in ("randomk", "random")
        self.quantize = self.payload and dl.payload_quant
        self.k = max(1, int(dl.budget * self.P)) if self.payload else 0

        # --- jitted step/mix functions (engine-identical math) ----------
        L, bs = dl.local_steps, dl.batch_size

        def node_grad(p, x, y):
            return jax.grad(loss_fn)(p, x, y)

        def local(params, opt_state, bx, by):
            from repro.optim.optimizers import apply_updates

            for s in range(L):
                grads = jax.vmap(node_grad)(params, bx[s], by[s])
                updates, new_opt = jax.vmap(opt.update)(
                    grads, opt_state, params
                )
                params, opt_state = apply_updates(params, updates), new_opt
            return params, opt_state, jax.vmap(tree_vector)(params)

        self._local = jax.jit(local)

        def mix_full(topo_e, Xv):
            return mixing.apply_W(topo_e, Xv)[self.lo:self.hi]

        def mix_pay(topo_e, idx, val, Xv):
            return mixing.mix_payload(
                topo_e, idx, val, Xv, exact_values=not self.quantize
            )[self.lo:self.hi]

        self._mix_full = jax.jit(mix_full)
        self._mix_pay = jax.jit(mix_pay)

        if self.payload:
            own_rows = jnp.asarray(self.own_ids)

            def emit(key, Xo):
                idx = sharing_lib._randk_idx(
                    key, (self.B, self.P), self.k, rows=own_rows
                )
                return idx, jnp.take_along_axis(Xo, idx, axis=1)

            self._emit = jax.jit(emit)
            if self.quantize:
                from repro.core.compression import (
                    dequantize_int8, quantize_int8,
                )

                self._quant = jax.jit(quantize_int8)
                self._dequant = dequantize_int8

        def unvec(X2):
            return jax.vmap(lambda v: tree_unvector(v, self.template))(X2)

        self._unvec = jax.jit(unvec)
        self._eval = jax.jit(
            lambda p, tx, ty: jax.vmap(lambda q: acc_fn(q, tx, ty))(p)
        )

        # --- runtime state ----------------------------------------------
        self.peers: Dict[int, Tuple[str, int]] = {}
        self.conns: Dict[int, Tuple] = {}
        self.inbox: Dict[int, asyncio.Queue] = {}
        self.last_seen: Dict[int, float] = {}
        self.dead: set = set()
        self.left: set = set()
        self._pending_bye: set = set()
        self.wire_bytes = 0.0
        self.counters = {"faults_detected": 0, "retry_total": 0, "leaves": 0}
        self.detect_rounds: Dict[str, int] = {}
        self.reweight_row_err = 0.0
        self.round_wall: List[float] = []
        self.records: List[Dict] = []

    # ------------------------------------------------------------------
    def _warmup(self):
        """Compile every jitted function before joining the mesh, so no
        peer mistakes our compile stall for death and the steady-state
        round walls that calibration records exclude compilation."""
        jnp = self.jnp
        idx = self.batcher.round_indices(0, self.dl.local_steps)
        bx = jnp.asarray(self.batcher.x[idx[:, self.lo:self.hi]])
        by = jnp.asarray(self.batcher.y[idx[:, self.lo:self.hi]])
        p, o, Xo = self._local(self.params, self.opt_state, bx, by)
        Xv = jnp.asarray(self.X_view)
        if self.payload:
            key = self.jax.random.fold_in(self._base_key, 0)
            i, v = self._emit(key, Xo)
            if self.quantize:
                c, s = self._quant(v)
                v = self._dequant(c, s)
            zi = jnp.zeros((self.dl.n_nodes, self.k), jnp.int32)
            zv = jnp.zeros((self.dl.n_nodes, self.k), jnp.float32)
            zi = zi.at[self.lo:self.hi].set(i)
            zv = zv.at[self.lo:self.hi].set(v)
            X2 = self._mix_pay(self.topo_eff, zi, zv, Xv)
        else:
            X2 = self._mix_full(self.topo_eff, Xv)
        self._unvec(X2)
        tx, ty = self.batcher.test_batch()
        np.asarray(self._eval(p, jnp.asarray(tx), jnp.asarray(ty)))

    # ------------------------------------------------------------------
    # membership
    # ------------------------------------------------------------------
    def _rows_of(self, v: int) -> np.ndarray:
        return np.arange(v * self.B, (v + 1) * self.B)

    def _mark_gone(self, v: int, rnd: int, *, fault: bool):
        """Graceful-degradation path: drop worker v's nodes and return
        their edge mass to the surviving receivers' diagonals
        (``edge_reweight_sparse`` — the PR 7 reweight, reused on real
        deaths), so surviving rows stay row-stochastic."""
        if v in self.dead or v in self.left:
            return
        from repro.core.sharing import edge_reweight_sparse

        (self.dead if fault else self.left).add(v)
        self.live_nodes[self._rows_of(v)] = 0.0
        live_slots = self.live_nodes[self.nbr]
        base = self._topo_cls(
            self.jnp.asarray(self.nbr), self.jnp.asarray(self.w0),
            self.jnp.asarray(self.w_self0),
        )
        self.topo_eff = edge_reweight_sparse(
            base, self.jnp.asarray(live_slots)
        )
        w = np.asarray(self.topo_eff.w)
        ws = np.asarray(self.topo_eff.w_self)
        rows = slice(self.lo, self.hi)
        err = float(np.abs(ws[rows] + w[rows].sum(-1) - 1.0).max())
        self.reweight_row_err = max(self.reweight_row_err, err)
        if fault:
            self.counters["faults_detected"] += 1
        else:
            self.counters["leaves"] += 1
        self.detect_rounds[str(v)] = rnd
        self.conns.pop(v, None)

    def _live_peers(self) -> List[int]:
        return [v for v in range(self.K)
                if v != self.wid and v not in self.dead and v not in self.left]

    # ------------------------------------------------------------------
    # transport
    # ------------------------------------------------------------------
    async def _handle_conn(self, reader, writer):
        from repro.runtime import transport as tp

        try:
            while True:
                ftype, body = await tp.read_frame(reader)
                if ftype == tp.MSG_ROWS:
                    msg = tp.decode_rows(body)
                    v = msg["sender"]
                    self.last_seen[v] = time.monotonic()
                    if v not in self.dead and v not in self.left:
                        self.inbox[v].put_nowait(msg)
                elif ftype == tp.MSG_HEARTBEAT:
                    self.last_seen[tp.decode_wid(body)] = time.monotonic()
                elif ftype == tp.MSG_BYE:
                    # graceful leave: the barrier stops expecting rows from
                    # v (same reweight as a death, counted as a leave)
                    self._pending_bye.add(tp.decode_wid(body))
        except (asyncio.IncompleteReadError, ConnectionError, OSError,
                ValueError):
            return
        finally:
            writer.close()

    async def _heartbeat_loop(self):
        from repro.runtime import transport as tp

        beat = tp.encode_wid(self.wid)
        while True:
            await asyncio.sleep(self.hb_interval_s)
            for v in self._live_peers():
                conn = self.conns.get(v)
                if conn is None:
                    continue
                try:
                    conn[1].write(
                        tp._FRAME.pack(tp.MSG_HEARTBEAT, len(beat)) + beat
                    )
                except OSError:
                    pass

    async def _send_rows(self, v: int, rnd: int, body: bytes) -> bool:
        """Per-message send with timeout and the shared exponential
        backoff; exhausting the retry budget declares the peer dead."""
        from repro.runtime import transport as tp

        for attempt in range(self.backoff_cap + 2):
            try:
                if v not in self.conns:
                    self.conns[v] = await asyncio.open_connection(
                        *self.peers[v]
                    )
                await asyncio.wait_for(
                    tp.write_frame(self.conns[v][1], tp.MSG_ROWS, body),
                    timeout=self.send_timeout_s,
                )
                self.wire_bytes += len(body) + 5
                return True
            except (OSError, asyncio.TimeoutError):
                self.conns.pop(v, None)
                self.counters["retry_total"] += 1
                await asyncio.sleep(
                    retry_backoff_delay(attempt, self.backoff_s,
                                        self.backoff_cap)
                )
        self._mark_gone(v, rnd, fault=True)
        return False

    async def _gather(self, rnd: int) -> Dict[int, Dict]:
        """The sync barrier: one ROWS frame per live peer for this round.
        TCP ordering + one frame per (peer, round) means the next frame
        from a peer is this round's — anything else is a protocol error.
        Waits are sliced so heartbeat silence can be detected mid-wait;
        the whole barrier is bounded by the watchdog."""
        out: Dict[int, Dict] = {}
        t0 = time.monotonic()
        for v in list(self.need_from):
            if not len(self.need_from[v]):
                continue  # no edge crosses this worker pair
            while v in self._live_peers() and v not in out:
                # BYE is FIFO-ordered after the peer's last ROWS frame, so
                # only honor it once the inbox is drained — a leaver's
                # final-round contribution still counts
                if v in self._pending_bye and self.inbox[v].empty():
                    self._mark_gone(v, rnd, fault=False)
                    break
                try:
                    msg = await asyncio.wait_for(
                        self.inbox[v].get(), timeout=0.25
                    )
                except asyncio.TimeoutError:
                    now = time.monotonic()
                    if now - self.last_seen.get(v, t0) > self.dead_timeout_s:
                        self._mark_gone(v, rnd, fault=True)
                    if now - t0 > self.watchdog_s:
                        raise RuntimeError(
                            f"worker {self.wid}: watchdog — round {rnd} "
                            f"barrier stalled > {self.watchdog_s}s on peer "
                            f"{v}"
                        )
                    continue
                if msg["round"] < rnd:
                    continue  # pre-death stragglers of an old round
                if msg["round"] > rnd:
                    raise RuntimeError(
                        f"worker {self.wid}: protocol error — peer {v} "
                        f"sent round {msg['round']} during round {rnd}"
                    )
                out[v] = msg
        return out

    # ------------------------------------------------------------------
    # the round
    # ------------------------------------------------------------------
    async def _round(self, rnd: int):
        import jax

        jnp = self.jnp
        from repro.runtime import transport as tp

        loop = asyncio.get_running_loop()
        t0 = time.monotonic()
        idx = self.batcher.round_indices(rnd, self.dl.local_steps)
        bx = self.batcher.x[idx[:, self.lo:self.hi]]
        by = self.batcher.y[idx[:, self.lo:self.hi]]

        def _step():
            p, o, Xo = self._local(
                self.params, self.opt_state, jnp.asarray(bx), jnp.asarray(by)
            )
            return p, o, np.asarray(Xo, np.float32)

        self.params, self.opt_state, X_own = await loop.run_in_executor(
            None, _step
        )
        self.X_view[self.lo:self.hi] = X_own

        # --- emit + send ------------------------------------------------
        if self.payload:
            key = jax.random.fold_in(self._base_key, rnd)

            def _emit():
                i, v = self._emit(key, jnp.asarray(X_own))
                if self.quantize:
                    c, s = self._quant(v)
                    return (np.asarray(i), np.asarray(c),
                            np.asarray(s, np.float32).reshape(-1),
                            np.asarray(self._dequant(c, s), np.float32))
                return np.asarray(i), None, None, np.asarray(v, np.float32)

            idx_own, codes_own, scale_own, val_own = (
                await loop.run_in_executor(None, _emit)
            )
        sends = []
        for v in self._live_peers():
            ids = self.send_to[v]
            if not len(ids):
                continue
            loc = ids - self.lo
            if not self.payload:
                body = tp.encode_rows(
                    rnd, self.wid, ids, tp.FMT_FULL_F32, rows=X_own[loc]
                )
            elif self.quantize:
                body = tp.encode_rows(
                    rnd, self.wid, ids, tp.FMT_PAYLOAD_I8, idx=idx_own[loc],
                    codes=codes_own[loc], scale=scale_own[loc],
                )
            else:
                body = tp.encode_rows(
                    rnd, self.wid, ids, tp.FMT_PAYLOAD_F32, idx=idx_own[loc],
                    val=val_own[loc],
                )
            sends.append(self._send_rows(v, rnd, body))
        if sends:
            await asyncio.gather(*sends)

        # --- barrier gather + aggregate ---------------------------------
        got = await self._gather(rnd)
        if self.payload:
            idx_all = np.zeros((self.dl.n_nodes, self.k), np.int32)
            val_all = np.zeros((self.dl.n_nodes, self.k), np.float32)
            idx_all[self.lo:self.hi] = idx_own
            val_all[self.lo:self.hi] = val_own
            for msg in got.values():
                if msg["fmt"] == tp.FMT_PAYLOAD_I8:
                    val = np.asarray(self._dequant(
                        self.jnp.asarray(msg["codes"]),
                        self.jnp.asarray(msg["scale"][:, None]),
                    ), np.float32)
                else:
                    val = msg["val"]
                idx_all[msg["ids"]] = msg["idx"]
                val_all[msg["ids"]] = val
        else:
            for msg in got.values():
                self.X_view[msg["ids"]] = msg["rows"]

        def _mix():
            Xv = jnp.asarray(self.X_view)
            if self.payload:
                X2 = self._mix_pay(
                    self.topo_eff, jnp.asarray(idx_all), jnp.asarray(val_all),
                    Xv,
                )
            else:
                X2 = self._mix_full(self.topo_eff, Xv)
            return self._unvec(X2), np.asarray(X2, np.float32)

        self.params, X2_own = await loop.run_in_executor(None, _mix)
        self.X_view[self.lo:self.hi] = X2_own
        self.round_wall.append(time.monotonic() - t0)

    # ------------------------------------------------------------------
    async def main(self):
        from repro.runtime import transport as tp

        server = await asyncio.start_server(
            self._handle_conn, "127.0.0.1", 0
        )
        my_port = server.sockets[0].getsockname()[1]
        loop = asyncio.get_running_loop()
        # compile before joining: peers time liveness, not XLA
        await loop.run_in_executor(None, self._warmup)
        self.peers = await tp.rendezvous_register(
            self.rdv[0], self.rdv[1], self.wid, "127.0.0.1", my_port,
            timeout_s=float(self.spec.get("join_timeout_s", 30.0)),
        )
        now = time.monotonic()
        for v in range(self.K):
            if v == self.wid:
                continue
            self.inbox[v] = asyncio.Queue()
            self.last_seen[v] = now
            r, w = await tp.open_with_retry(*self.peers[v])
            self.conns[v] = (r, w)
        hb = asyncio.create_task(self._heartbeat_loop())
        t_start = time.monotonic()
        tx, ty = self.batcher.test_batch()
        txj, tyj = self.jnp.asarray(tx), self.jnp.asarray(ty)
        try:
            for rnd in range(self.rounds):
                await self._round(rnd)
                self._write_progress(rnd)
                if rnd % self.ev == 0 or rnd == self.rounds - 1:
                    accs = np.asarray(self._eval(self.params, txj, tyj))
                    self.records.append({
                        "round": rnd,
                        "accs": [float(a) for a in accs],
                        "bytes_wire": float(self.wire_bytes),
                        "wall_s": time.monotonic() - t_start,
                        **{k: int(v) for k, v in self.counters.items()},
                    })
        finally:
            hb.cancel()
            bye = tp.encode_wid(self.wid)
            for v in self._live_peers():
                conn = self.conns.get(v)
                if conn is not None:
                    try:
                        await tp.write_frame(conn[1], tp.MSG_BYE, bye)
                    except OSError:
                        pass
            server.close()
        self._write_results()

    # ------------------------------------------------------------------
    def _write_progress(self, rnd: int):
        """Crash-consistent progress marker (the runner's kill trigger and
        liveness probe): temp + rename, like every result file here."""
        path = os.path.join(self.run_dir, f"w{self.wid}.progress")
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            f.write(str(rnd))
        os.replace(tmp, path)

    def _write_results(self):
        out = {
            "worker": self.wid,
            "rows": [int(self.lo), int(self.hi)],
            "n_params": int(self.P),
            "history": self.records,
            "round_wall_s": self.round_wall,
            "wire_bytes": float(self.wire_bytes),
            "counters": dict(self.counters),
            "detect_rounds": self.detect_rounds,
            "reweight_row_err": self.reweight_row_err,
            "dead_peers": sorted(self.dead),
            "left_peers": sorted(self.left),
        }
        atomic_write_json(
            os.path.join(self.run_dir, f"worker_{self.wid}.json"), out
        )
        fn = os.path.join(self.run_dir, f"worker_{self.wid}_X.npy")
        tmp = fn + ".tmp.npy"
        np.save(tmp, self.X_view[self.lo:self.hi])
        os.replace(tmp, fn)


def main(argv: Optional[List[str]] = None):
    ap = argparse.ArgumentParser(
        description="one row-block worker of the processes backend"
    )
    ap.add_argument("--spec", required=True, help="path to the run spec JSON")
    ap.add_argument("--worker", type=int, required=True)
    args = ap.parse_args(argv)
    with open(args.spec) as f:
        spec = json.load(f)
    worker = PeerWorker(spec, args.worker)
    asyncio.run(worker.main())


if __name__ == "__main__":
    main()
