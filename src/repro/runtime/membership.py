"""Elastic membership for the process backend: epoch-stamped views.

One :class:`Membership` instance is a single worker's *view* of the
mesh: which peers are live, dead, or gracefully departed, what each
peer's current **membership epoch** (incarnation number) is, and when
each peer was last heard from.  It is deliberately free of sockets,
topology, and jax so the failure detector and the rejoin admission
rules are testable in isolation (``tests/test_membership.py``).

## Epochs

Every worker incarnation carries a monotone epoch: the first launch is
epoch 0, each supervisor relaunch after a crash bumps it by one.  Every
frame on the wire is stamped with the sender's epoch, and admission is
decided per frame:

* ``epoch < epochs[v]``  — a **zombie frame** from a pre-crash
  incarnation: dropped, counted under ``stale_frames_dropped``.
* ``epoch == epochs[v]`` — current; accepted iff the sender is live (or
  mid-rejoin, see below).  Frames from senders already declared dead or
  left are dropped and counted — a dead peer's late frames must never
  queue into the per-sender inboxes.
* ``epoch > epochs[v]``  — a *future* incarnation whose JOIN has not
  been processed yet (frames are FIFO per connection, so this is a
  transient reorder across connections): ignored without counting.
  Only a JOIN advances a peer's epoch.

## Rejoin state machine

    live --declare_dead/declare_left--> dead/left
    dead --hello(newer epoch)--> dead+pending (beacons refresh liveness,
                                 ROWS may queue, barrier still excludes)
    pending --schedule_admit(start)--> admission due at round `start`
    due --admit()--> live again (caller restores pristine edge weights)

The two-phase hello/commit split exists because survivors run a
synchronous barrier: every survivor must re-admit the rejoiner at the
*same* future round (the rejoiner picks ``start`` past everyone's
current round), otherwise one survivor would wait on rows the rejoiner
never sent.

## Counter schema (PR 7 extension)

``RUNTIME_COUNTER_KEYS`` is the uniform per-worker counter schema the
runtime emits; the conservation invariant checked by the chaos harness
is ``faults_detected == len(dead) + rejoin_total`` for every worker's
final report (each detection either stays dead or was re-admitted).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Set

#: Uniform per-worker counter schema (extends PR 7's fault counters with
#: the elastic-membership triple).
RUNTIME_COUNTER_KEYS = (
    "faults_detected",      # peers this worker declared dead
    "retry_total",          # send retries (shared backoff policy)
    "leaves",               # graceful BYE departures honored
    "rejoin_total",         # dead peers this worker re-admitted
    "stale_frames_dropped",  # zombie/stale-epoch frames rejected
    "catchup_bytes",        # checkpoint/STATE bytes a rejoiner restored
)


def zero_counters() -> Dict[str, int]:
    return {k: 0 for k in RUNTIME_COUNTER_KEYS}


class Membership:
    """One worker's epoch-stamped view of the K-worker mesh."""

    def __init__(self, n_workers: int, wid: int, dead_timeout_s: float):
        self.n = int(n_workers)
        self.wid = int(wid)
        self.dead_timeout_s = float(dead_timeout_s)
        self.epochs: Dict[int, int] = {v: 0 for v in range(self.n)}
        self.dead: Set[int] = set()
        self.left: Set[int] = set()
        # dead peers whose new incarnation said hello (rejoin in flight)
        self.pending_hello: Set[int] = set()
        # v -> first round the re-admitted peer participates in
        self.pending_admit: Dict[int, int] = {}
        self.last_seen: Dict[int, float] = {}

    # -- basic views ----------------------------------------------------
    def peers(self) -> List[int]:
        return [v for v in range(self.n) if v != self.wid]

    def is_live(self, v: int) -> bool:
        return v not in self.dead and v not in self.left

    def live_peers(self) -> List[int]:
        return [v for v in self.peers() if self.is_live(v)]

    def beacon_targets(self) -> List[int]:
        """Who to heartbeat: live peers plus mid-rejoin peers — a
        rejoiner must hear survivors' beacons *before* it is re-admitted
        or its own failure detector would declare every survivor dead
        while it waits for its start round."""
        return [v for v in self.peers()
                if self.is_live(v) or self._pending(v)]

    def _pending(self, v: int) -> bool:
        return v in self.pending_hello or v in self.pending_admit

    # -- frame admission ------------------------------------------------
    def frame_status(self, v: int, epoch: int) -> str:
        """'ok' | 'stale' | 'future' for a data-plane frame (ROWS /
        HEARTBEAT / BYE) stamped with ``epoch``.  'stale' frames are the
        ones the caller counts under ``stale_frames_dropped``."""
        cur = self.epochs.get(v)
        if cur is None:
            return "stale"
        if epoch > cur:
            return "future"
        if epoch < cur:
            return "stale"
        return "ok" if (self.is_live(v) or self._pending(v)) else "stale"

    def heartbeat(self, v: int, epoch: int, now: float) -> str:
        """Process a liveness beacon; refreshes ``last_seen`` only for
        the sender's *current* incarnation (a zombie's beacon must not
        keep its corpse looking alive)."""
        st = self.frame_status(v, epoch)
        if st == "ok":
            self.last_seen[v] = now
        return st

    # -- failure detection ----------------------------------------------
    def silent_too_long(self, v: int, now: float) -> bool:
        """True when a live peer has been silent past the dead timeout.
        Callers feed this into :meth:`declare_dead`."""
        if not self.is_live(v):
            return False
        seen = self.last_seen.get(v)
        return seen is not None and (now - seen) > self.dead_timeout_s

    def declare_dead(self, v: int) -> bool:
        """Declare a peer dead.  Returns True exactly once per
        incarnation — repeated silence checks and retry-budget
        exhaustion on an already-dead peer are no-ops."""
        if v in self.dead or v in self.left:
            return False
        self.dead.add(v)
        self.pending_hello.discard(v)
        self.pending_admit.pop(v, None)
        return True

    def declare_left(self, v: int) -> bool:
        """Graceful-leave twin of :meth:`declare_dead`."""
        if v in self.dead or v in self.left:
            return False
        self.left.add(v)
        return True

    # -- rejoin ----------------------------------------------------------
    def hello(self, v: int, epoch: int) -> str:
        """A (re)JOIN hello from incarnation ``epoch`` of peer v.

        Returns 'rejoin' (a declared-dead/left peer at a strictly newer
        epoch — the dead mark will clear at admission), 'ok' (a live
        peer re-announcing, e.g. the supervisor restarted it before we
        ever noticed the death — the caller should first retire the old
        incarnation), or 'stale' (epoch not newer than what we know for
        a non-live peer: a zombie JOIN)."""
        cur = self.epochs[v]
        if self.is_live(v):
            if epoch < cur:
                return "stale"
            self.epochs[v] = max(cur, epoch)
            return "ok"
        if epoch <= cur:
            return "stale"
        self.epochs[v] = epoch
        self.pending_hello.add(v)
        return "rejoin"

    def schedule_admit(self, v: int, epoch: int, start_round: int,
                       cur_round: int) -> bool:
        """Commit phase: re-admit peer v at the top of ``start_round``.
        Refused when the epoch is stale or the round is not safely in
        the future (the barrier for ``cur_round + 1`` may already be in
        flight)."""
        if epoch != self.epochs[v]:
            return False
        if start_round < cur_round + 2:
            return False
        self.pending_admit[v] = int(start_round)
        self.pending_hello.discard(v)
        return True

    def due_admissions(self, rnd: int) -> List[int]:
        return sorted(v for v, s in self.pending_admit.items() if s <= rnd)

    def admit(self, v: int) -> bool:
        """Make peer v live again.  Returns True when v was declared
        dead (the caller counts it under ``rejoin_total``); re-admitting
        a gracefully-left or never-dead peer returns False."""
        was_dead = v in self.dead
        self.dead.discard(v)
        self.left.discard(v)
        self.pending_hello.discard(v)
        self.pending_admit.pop(v, None)
        return was_dead

    # -- introspection ---------------------------------------------------
    def snapshot(self) -> Dict[str, object]:
        return {
            "epochs": dict(self.epochs),
            "dead": sorted(self.dead),
            "left": sorted(self.left),
            "pending": sorted(set(self.pending_hello)
                              | set(self.pending_admit)),
        }
