"""GN-LeNet CNN — the paper's own CIFAR-10 workload for the faithful
reproduction experiments (D-PSGD, Fig. 3–6 style runs).

Small conv net with GroupNorm (BatchNorm is unusable in DL since each node
sees a non-IID slice; the DecentralizePy experiments use GN-style nets too).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init


def group_norm(x, gamma, beta, groups: int = 8, eps: float = 1e-5):
    B, H, W, C = x.shape
    xg = x.reshape(B, H, W, groups, C // groups).astype(jnp.float32)
    mean = xg.mean(axis=(1, 2, 4), keepdims=True)
    var = xg.var(axis=(1, 2, 4), keepdims=True)
    xg = (xg - mean) * jax.lax.rsqrt(var + eps)
    return (xg.reshape(B, H, W, C) * gamma + beta).astype(x.dtype)


def conv(x, w, b):
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
    )
    return y + b


def cnn_init(key, num_classes: int = 10, channels: int = 3, width: int = 32, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    c1, c2 = width, 2 * width
    return {
        "conv1": {"w": dense_init(ks[0], (5, 5, channels, c1), dtype, scale=(25 * channels) ** -0.5),
                  "b": jnp.zeros((c1,), dtype), "g": jnp.ones((c1,), dtype), "be": jnp.zeros((c1,), dtype)},
        "conv2": {"w": dense_init(ks[1], (5, 5, c1, c2), dtype, scale=(25 * c1) ** -0.5),
                  "b": jnp.zeros((c2,), dtype), "g": jnp.ones((c2,), dtype), "be": jnp.zeros((c2,), dtype)},
        "fc1": {"w": dense_init(ks[2], (c2 * 8 * 8, 128), dtype), "b": jnp.zeros((128,), dtype)},
        "fc2": {"w": dense_init(ks[3], (128, num_classes), dtype), "b": jnp.zeros((num_classes,), dtype)},
    }


def cnn_apply(params, images):
    """images: (B, 32, 32, C) -> logits (B, num_classes)."""
    x = images
    x = conv(x, params["conv1"]["w"], params["conv1"]["b"])
    x = group_norm(x, params["conv1"]["g"], params["conv1"]["be"])
    x = jax.nn.relu(x)
    x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
    x = conv(x, params["conv2"]["w"], params["conv2"]["b"])
    x = group_norm(x, params["conv2"]["g"], params["conv2"]["be"])
    x = jax.nn.relu(x)
    x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ params["fc1"]["w"] + params["fc1"]["b"])
    return x @ params["fc2"]["w"] + params["fc2"]["b"]
