"""Shared layers: inits, RMSNorm, RoPE / M-RoPE, SwiGLU MLP."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def dense_init(key, shape, dtype, scale: Optional[float] = None):
    """Truncated-normal fan-in init (LeCun-style)."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else fan_in**-0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


def rms_norm(x, weight, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(jnp.square(x), axis=-1, keepdims=True) + eps)
    return (x * weight.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies, shape (head_dim // 2,)."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """Rotate pairs. x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    inv = rope_freqs(d, theta)  # (d/2,)
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., S, d/2)
    sin, cos = jnp.sin(ang)[..., None, :], jnp.cos(ang)[..., None, :]  # (..., S, 1, d/2)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, theta: float, sections):
    """Multimodal RoPE (qwen2-vl): 3 position streams (t, h, w), each
    rotating its own slice of the frequency spectrum.

    x: (B, S, H, D); positions3: (3, B, S); sections: (s_t, s_h, s_w),
    sum(sections) == D // 2.
    """
    d = x.shape[-1]
    inv = rope_freqs(d, theta)  # (d/2,)
    # Per-frequency position stream selection.
    sec_ids = jnp.repeat(jnp.arange(3), jnp.array(sections), total_repeat_length=d // 2)
    # positions3: (3, B, S) -> pos_per_freq: (B, S, d/2)
    pos = jnp.take(positions3, sec_ids, axis=0)  # (d/2, B, S) via axis 0 gather
    pos = jnp.moveaxis(pos, 0, -1).astype(jnp.float32)  # (B, S, d/2)
    ang = pos * inv  # (B, S, d/2)
    sin, cos = jnp.sin(ang)[..., None, :], jnp.cos(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def mlp_init(key, d_model: int, d_ff: int, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, (d_model, d_ff), dtype),
        "w_up": dense_init(k2, (d_model, d_ff), dtype),
        "w_down": dense_init(k3, (d_ff, d_model), dtype),
    }


def mlp_apply(p, x):
    h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    return h @ p["w_down"]
