"""Model configuration.

One dataclass covers all six architecture families in the assigned pool
(dense / MoE / SSM / hybrid / audio enc-dec / VLM).  Family-specific fields
are ignored by families that do not use them.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"  # dense | moe | ssm | hybrid | encdec | vlm
    # -- core transformer dims ------------------------------------------------
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: Optional[int] = None  # default d_model // n_heads
    d_ff: int = 1024
    vocab: int = 1024
    # -- attention options ----------------------------------------------------
    qk_norm: bool = False          # qwen3
    qkv_bias: bool = False         # qwen2
    attn_impl: str = "naive"       # naive | chunked (flash-style, O(S·C) HBM)
    #                                | pallas_swa (Pallas sliding-window kernel;
    #                                  requires sliding_window set)
    attn_chunk: int = 512          # kv-chunk for attn_impl='chunked'
    ssm_impl: str = "jnp"          # jnp | pallas (kernels/ssd_chunk intra-chunk)
    rope_theta: float = 10_000.0
    sliding_window: Optional[int] = None  # sub-quadratic dense variant
    mrope_sections: Optional[Tuple[int, int, int]] = None  # qwen2-vl M-RoPE
    # -- MLA (deepseek-v2) ----------------------------------------------------
    mla: bool = False
    kv_lora_rank: int = 512
    q_lora_rank: Optional[int] = None
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    # -- MoE --------------------------------------------------------------
    n_experts: int = 0
    n_shared_experts: int = 0
    moe_top_k: int = 1
    d_expert: Optional[int] = None  # expert FFN hidden size (default d_ff)
    moe_every: int = 1              # MoE layer every k-th layer (llama4: 2)
    first_dense: int = 0            # leading dense layers (deepseek: 1)
    capacity_factor: float = 1.25
    aux_loss_coef: float = 0.01
    # -- SSM (mamba2 SSD) ------------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_chunk: int = 128
    ssm_conv: int = 4
    # -- hybrid (zamba2) ---------------------------------------------------
    attn_every: int = 0  # shared attention block applied every k SSM layers
    # -- enc-dec (whisper) -------------------------------------------------
    n_enc_layers: int = 0
    enc_seq: int = 0          # fixed encoder sequence (1500 for whisper)
    # -- embeddings / misc -------------------------------------------------
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    dtype: str = "float32"          # compute/param dtype ("bfloat16" on TPU)
    remat: bool = False             # activation checkpointing of blocks
    remat_policy: str = "full"      # full | save_comm (save post-all-reduce
                                    # activations: remat recompute skips the
                                    # TP collectives, 1/3 fewer ARs)
    scan_unroll: bool = False       # unroll layer scans (dry-run: XLA's
                                    # cost_analysis counts a while body once,
                                    # so roofline runs must unroll)
    # -- frontend stubs -----------------------------------------------------
    stub_frontend: bool = False     # audio / vlm: inputs are embeddings

    # ----------------------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    @property
    def d_inner(self) -> int:  # SSM inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def validate(self) -> None:
        assert self.n_heads % max(self.n_kv_heads, 1) == 0, "GQA group size"
        if self.family in ("ssm", "hybrid"):
            assert self.ssm_state > 0 and self.d_inner % self.ssm_headdim == 0
        if self.family == "moe":
            assert self.n_experts > 0 and self.moe_top_k >= 1
        if self.family == "encdec":
            assert self.n_enc_layers > 0 and self.enc_seq > 0
        if self.mrope_sections is not None:
            assert sum(self.mrope_sections) == self.hd // 2, "M-RoPE sections cover half head_dim"
