"""Zamba2-style hybrid: Mamba2 backbone + one *shared* attention block
applied every ``attn_every`` SSM layers. [arXiv:2411.15242]

The shared block's weights are reused at every application (Zamba's
parameter-sharing trick); only its KV cache is per-application.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.attention import attn_cache_init
from repro.models.common import embed_init, dense_init, rms_norm
from repro.models.config import ModelConfig
from repro.models.ssm import ssm_apply, ssm_cache_init, ssm_decode_step, ssm_init
from repro.models.transformer import block_apply, block_init, stacked_init


def _plan(cfg: ModelConfig):
    if cfg.attn_every <= 0:
        return 0, 0, cfg.n_layers
    n_seg = cfg.n_layers // cfg.attn_every
    tail = cfg.n_layers - n_seg * cfg.attn_every
    return n_seg, cfg.attn_every, tail


def mamba_block_init(key, cfg: ModelConfig):
    return {"ln": jnp.ones((cfg.d_model,), cfg.jdtype), "ssm": ssm_init(key, cfg)}


def hybrid_init(key, cfg: ModelConfig):
    n_seg, per, tail = _plan(cfg)
    ke, kh, ks, kt, ka = jax.random.split(key, 5)
    params = {
        "embed": embed_init(ke, (cfg.vocab, cfg.d_model), cfg.jdtype),
        "final_norm": jnp.ones((cfg.d_model,), cfg.jdtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(kh, (cfg.d_model, cfg.vocab), cfg.jdtype)
    if n_seg:
        params["mamba_seg"] = stacked_init(
            lambda k: stacked_init(lambda kk: mamba_block_init(kk, cfg), k, per), ks, n_seg
        )
        params["shared_attn"] = block_init(ka, cfg, moe=False)
    if tail:
        params["mamba_tail"] = stacked_init(lambda k: mamba_block_init(k, cfg), kt, tail)
    return params


def _mamba_blk(p, cfg, x):
    return x + ssm_apply(p["ssm"], cfg, rms_norm(x, p["ln"], cfg.norm_eps))


def hybrid_apply(params, cfg: ModelConfig, x, positions):
    n_seg, per, tail = _plan(cfg)

    mblk = _mamba_blk
    if cfg.remat:
        mblk = jax.checkpoint(_mamba_blk, static_argnums=(1,))

    u = True if cfg.scan_unroll else 1
    if n_seg:

        def seg_body(h, seg_params):
            def inner(hh, lp):
                return mblk(lp, cfg, hh), None

            h, _ = jax.lax.scan(inner, h, seg_params, unroll=u)
            h, _, _ = block_apply(params["shared_attn"], cfg, h, positions)
            return h, None

        x, _ = jax.lax.scan(seg_body, x, params["mamba_seg"], unroll=u)
    if tail:
        def inner(hh, lp):
            return mblk(lp, cfg, hh), None

        x, _ = jax.lax.scan(inner, x, params["mamba_tail"], unroll=u)
    return rms_norm(x, params["final_norm"], cfg.norm_eps), jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def hybrid_cache_init(cfg: ModelConfig, batch: int, max_len: int):
    n_seg, per, tail = _plan(cfg)
    cache = {}
    if n_seg:
        seg = ssm_cache_init(cfg, batch, layers=n_seg * per)
        cache["mamba_seg"] = jax.tree_util.tree_map(
            lambda a: a.reshape(n_seg, per, *a.shape[1:]), seg
        )
        cache["shared_attn"] = attn_cache_init(cfg, batch, max_len, layers=n_seg)
    if tail:
        cache["mamba_tail"] = ssm_cache_init(cfg, batch, layers=tail)
    return cache


def hybrid_decode(params, cfg: ModelConfig, cache, x, index):
    n_seg, per, tail = _plan(cfg)
    u = True if cfg.scan_unroll else 1
    positions = jnp.broadcast_to(index, (x.shape[0], 1))
    new_cache = {}

    def mdec(lp, h, c):
        y, nc = ssm_decode_step(lp["ssm"], cfg, rms_norm(h, lp["ln"], cfg.norm_eps), c)
        return h + y, nc

    if n_seg:

        def seg_body(h, xs):
            seg_params, seg_cache, attn_c = xs

            def inner(hh, ixs):
                lp, c = ixs
                y, nc = mdec(lp, hh, c)
                return y, nc

            h, new_m = jax.lax.scan(inner, h, (seg_params, seg_cache), unroll=u)
            h, _, new_a = block_apply(
                params["shared_attn"], cfg, h, positions, cache=attn_c, cache_index=index
            )
            return h, (new_m, new_a)

        x, (nm, na) = jax.lax.scan(
            seg_body, x, (params["mamba_seg"], cache["mamba_seg"], cache["shared_attn"]),
            unroll=u,
        )
        new_cache["mamba_seg"], new_cache["shared_attn"] = nm, na
    if tail:

        def inner(hh, ixs):
            lp, c = ixs
            return mdec(lp, hh, c)

        x, nt = jax.lax.scan(inner, x, (params["mamba_tail"], cache["mamba_tail"]), unroll=u)
        new_cache["mamba_tail"] = nt
    return rms_norm(x, params["final_norm"], cfg.norm_eps), new_cache
