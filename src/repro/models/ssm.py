"""Mamba2 (SSD — state-space duality) block. [arXiv:2405.21060]

Chunked SSD form: intra-chunk attention-like matmuls (MXU-friendly) plus an
inter-chunk state recurrence via ``lax.scan``.  Decode keeps a constant-size
recurrent state -> O(1) per token, which is what makes ``long_500k`` viable.

Layout: n_groups = 1 (B/C shared across SSD heads).
x (B,S,d_model); inner (B,S,H,P) with H = d_inner/headdim, P = headdim,
N = ssm_state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, rms_norm
from repro.models.config import ModelConfig


def ssm_init(key, cfg: ModelConfig):
    d, di, N, H, dt = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_nheads, cfg.jdtype
    conv_ch = di + 2 * N
    ks = jax.random.split(key, 4)
    return {
        "in_proj": dense_init(ks[0], (d, 2 * di + 2 * N + H), dt),
        "conv_w": dense_init(ks[1], (cfg.ssm_conv, conv_ch), dt, scale=cfg.ssm_conv**-0.5),
        "conv_b": jnp.zeros((conv_ch,), dt),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.full((H,), -3.0, jnp.float32),  # softplus^-1-ish small dt
        "gate_norm": jnp.ones((di,), dt),
        "out_proj": dense_init(ks[2], (di, d), dt),
    }


def _causal_conv(xbc, w, b):
    """Depthwise causal conv, width W via shifted adds. xbc: (B,S,C)."""
    W = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(pad[:, i : i + xbc.shape[1], :] * w[i] for i in range(W))
    return jax.nn.silu(out + b)


def _split_zxbcdt(p, cfg: ModelConfig, x):
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_nheads
    zxbcdt = x @ p["in_proj"]
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di : 2 * di + 2 * N]
    dt = zxbcdt[..., 2 * di + 2 * N :]
    return z, xbc, dt


def ssm_apply(p, cfg: ModelConfig, x):
    """Full-sequence chunked SSD. x: (B,S,D) -> (B,S,D)."""
    B, S, _ = x.shape
    di, N, H, P, Lc = cfg.d_inner, cfg.ssm_state, cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_chunk
    assert S % Lc == 0, f"seq {S} not divisible by chunk {Lc}"
    nc = S // Lc

    z, xbc, dtr = _split_zxbcdt(p, cfg, x)
    xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    xs = xbc[..., :di].reshape(B, S, H, P)
    Bm = xbc[..., di : di + N]  # (B,S,N)
    Cm = xbc[..., di + N :]     # (B,S,N)

    dt = jax.nn.softplus(dtr.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    A = -jnp.exp(p["A_log"])  # (H,)
    dA = dt * A  # (B,S,H)

    # chunk
    c = lambda t, tail: t.reshape(B, nc, Lc, *tail)
    xs_c, B_c, C_c = c(xs, (H, P)), c(Bm, (N,)), c(Cm, (N,))
    dt_c, dA_c = c(dt, (H,)), c(dA, (H,))
    cum = jnp.cumsum(dA_c, axis=2)  # (B,nc,Lc,H)

    xdt = xs_c.astype(jnp.float32) * dt_c[..., None]  # (B,nc,Lc,H,P)
    if cfg.ssm_impl == "pallas":
        # Pallas intra-chunk kernel (kernels/ssd_chunk.py): MXU matmuls with
        # the decay matrix built in VMEM
        from repro.kernels import ops as kops

        g = lambda t: t.reshape(B * nc, *t.shape[2:])
        y_intra, state_contrib, chunk_decay = kops.ssd_chunk(
            g(xdt), g(B_c.astype(jnp.float32)), g(C_c.astype(jnp.float32)), g(cum)
        )
        y_intra = y_intra.reshape(B, nc, Lc, H, P)
        state_contrib = state_contrib.reshape(B, nc, H, N, P)
        chunk_decay = chunk_decay.reshape(B, nc, H)
    else:
        # intra-chunk: decay matrix L[i,j] = exp(cum_i - cum_j), j <= i
        diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nc,Lc,Lc,H)
        tri = jnp.tril(jnp.ones((Lc, Lc), bool))
        L = jnp.where(tri[None, None, :, :, None], jnp.exp(diff), 0.0)  # fp32
        cb = jnp.einsum("bcin,bcjn->bcij", C_c, B_c, preferred_element_type=jnp.float32)
        scores = cb[..., None] * L  # (B,nc,Lc,Lc,H)
        y_intra = jnp.einsum("bcijh,bcjhp->bcihp", scores, xdt)

        # chunk boundary states: S_chunk = sum_j exp(cum_last-cum_j) dt_j B_j x_j
        decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)  # (B,nc,Lc,H)
        state_contrib = jnp.einsum(
            "bcjn,bcjhp->bchnp", B_c.astype(jnp.float32), xdt * decay_to_end[..., None]
        )  # (B,nc,H,N,P)
        chunk_decay = jnp.exp(cum[:, :, -1, :])  # (B,nc,H) total decay per chunk

    def scan_fn(h_prev, inp):
        s_c, dec = inp  # (B,H,N,P), (B,H)
        h = h_prev * dec[..., None, None] + s_c
        return h, h_prev

    h0 = jnp.zeros((B, H, N, P), jnp.float32)
    _, h_before = jax.lax.scan(
        scan_fn, h0, (jnp.moveaxis(state_contrib, 1, 0), jnp.moveaxis(chunk_decay, 1, 0))
    )
    h_before = jnp.moveaxis(h_before, 0, 1)  # (B,nc,H,N,P) state entering each chunk

    # inter-chunk: y_i += C_i · h_before * exp(cum_i)
    y_inter = jnp.einsum("bcin,bchnp->bcihp", C_c.astype(jnp.float32), h_before) * jnp.exp(
        cum
    )[..., None]

    y = (y_intra + y_inter).reshape(B, S, H, P) + p["D"][None, None, :, None] * xs.astype(
        jnp.float32
    )
    y = y.reshape(B, S, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    return y @ p["out_proj"]


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def ssm_cache_init(cfg: ModelConfig, batch: int, layers=None):
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_nheads, cfg.ssm_headdim
    conv_ch = di + 2 * N
    shp_c = (batch, cfg.ssm_conv - 1, conv_ch)
    shp_s = (batch, H, N, P)
    if layers is not None:
        shp_c, shp_s = (layers, *shp_c), (layers, *shp_s)
    return {"conv": jnp.zeros(shp_c, cfg.jdtype), "state": jnp.zeros(shp_s, jnp.float32)}


def ssm_decode_step(p, cfg: ModelConfig, x, cache):
    """x: (B,1,D); cache {'conv': (B,W-1,C), 'state': (B,H,N,P)} -> (y, cache)."""
    B = x.shape[0]
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_nheads, cfg.ssm_headdim
    z, xbc, dtr = _split_zxbcdt(p, cfg, x)  # (B,1,*)
    # conv ring: history + current
    hist = jnp.concatenate([cache["conv"], xbc], axis=1)  # (B,W,C)
    w = p["conv_w"]
    conv_out = jax.nn.silu(jnp.einsum("bwc,wc->bc", hist, w) + p["conv_b"])[:, None, :]
    new_conv = hist[:, 1:, :]

    xs = conv_out[..., :di].reshape(B, H, P)
    Bm = conv_out[:, 0, di : di + N]  # (B,N)
    Cm = conv_out[:, 0, di + N :]
    dt = jax.nn.softplus(dtr[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    dA = jnp.exp(dt * -jnp.exp(p["A_log"]))  # (B,H)

    h = cache["state"] * dA[..., None, None] + jnp.einsum(
        "bn,bhp->bhnp", Bm.astype(jnp.float32), xs.astype(jnp.float32) * dt[..., None]
    )
    y = jnp.einsum("bn,bhnp->bhp", Cm.astype(jnp.float32), h) + p["D"][None, :, None] * xs.astype(
        jnp.float32
    )
    y = y.reshape(B, 1, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    return y @ p["out_proj"], {"conv": new_conv, "state": h}
