"""Decoder-only transformer assembly (dense / MoE / VLM backbones).

Layers are *stacked* on a leading L axis and applied with ``lax.scan`` —
essential to keep compile times sane for the 60–88-layer dry-run configs.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.ad_checkpoint
import jax.numpy as jnp

from repro.models.attention import attn_apply, attn_cache_init, attn_init
from repro.models.common import dense_init, embed_init, mlp_apply, mlp_init, rms_norm
from repro.models.config import ModelConfig
from repro.models.moe import moe_apply, moe_init


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def block_init(key, cfg: ModelConfig, moe: bool = False):
    k1, k2 = jax.random.split(key)
    p = {
        "ln1": jnp.ones((cfg.d_model,), cfg.jdtype),
        "attn": attn_init(k1, cfg),
        "ln2": jnp.ones((cfg.d_model,), cfg.jdtype),
    }
    if moe:
        p["moe"] = moe_init(k2, cfg)
    else:
        p["mlp"] = mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.jdtype)
    return p


def block_apply(p, cfg: ModelConfig, x, positions, cache=None, cache_index=None):
    """Pre-norm block. Returns (x, aux_loss, new_attn_cache)."""
    h, new_cache = attn_apply(
        p["attn"], cfg, rms_norm(x, p["ln1"], cfg.norm_eps), positions,
        cache=cache, cache_index=cache_index,
    )
    if cfg.remat_policy == "save_comm":
        # the attention/MLP outputs sit just after the TP all-reduce; saving
        # them means the remat recompute never re-issues those collectives
        h = jax.ad_checkpoint.checkpoint_name(h, "attn_out")
    x = x + h
    aux = jnp.zeros((), jnp.float32)
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    if "moe" in p:
        m, aux = moe_apply(p["moe"], cfg, h2)
    else:
        m = mlp_apply(p["mlp"], h2)
    if cfg.remat_policy == "save_comm":
        m = jax.ad_checkpoint.checkpoint_name(m, "mlp_out")
    return x + m, aux, new_cache


# ---------------------------------------------------------------------------
# layer stacking helpers
# ---------------------------------------------------------------------------

def stacked_init(fn, key, n: int):
    return jax.vmap(fn)(jax.random.split(key, n))


def _layer_plan(cfg: ModelConfig):
    """(n_prefix_dense, n_groups, dense_per_group) — see config.moe_every."""
    if cfg.family not in ("moe",):
        return cfg.n_layers, 0, 0
    rest = cfg.n_layers - cfg.first_dense
    assert rest % cfg.moe_every == 0
    return cfg.first_dense, rest // cfg.moe_every, cfg.moe_every - 1


def transformer_init(key, cfg: ModelConfig):
    kp, kg, ke, kh, kf = jax.random.split(key, 5)
    n_pre, n_grp, dpg = _layer_plan(cfg)
    params = {
        "final_norm": jnp.ones((cfg.d_model,), cfg.jdtype),
    }
    if not cfg.stub_frontend:
        params["embed"] = embed_init(ke, (cfg.vocab, cfg.d_model), cfg.jdtype)
    else:
        # VLM backbone: stub frontend supplies embeddings, but the LM still
        # embeds text tokens; keep the table (used by examples) — inputs may
        # bypass it with precomputed embeddings.
        params["embed"] = embed_init(ke, (cfg.vocab, cfg.d_model), cfg.jdtype)
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(kh, (cfg.d_model, cfg.vocab), cfg.jdtype)
    if n_pre:
        params["dense_layers"] = stacked_init(lambda k: block_init(k, cfg, moe=False), kp, n_pre)
    if n_grp:
        if dpg:
            params["group_dense"] = stacked_init(
                lambda k: stacked_init(lambda kk: block_init(kk, cfg, moe=False), k, dpg), kg, n_grp
            )
        params["group_moe"] = stacked_init(lambda k: block_init(k, cfg, moe=True), kf, n_grp)
    return params


def _scan_stack(fn, stacked, x, extra_xs=None, unroll: bool = False):
    """Scan ``fn(layer_params, x[, extra]) -> (x, aux[, ys])`` over layer axis."""

    def body(carry, xs):
        x, aux = carry
        if extra_xs is None:
            lp = xs
            y, a, ys = fn(lp, x)
        else:
            lp, ex = xs
            y, a, ys = fn(lp, x, ex)
        return (y, aux + a), ys

    xs = stacked if extra_xs is None else (stacked, extra_xs)
    (x, aux), ys = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), xs,
                                unroll=True if unroll else 1)
    return x, aux, ys


def transformer_apply(params, cfg: ModelConfig, x, positions):
    """x: (B,S,D) embedded input -> (hidden (B,S,D), aux)."""
    n_pre, n_grp, dpg = _layer_plan(cfg)

    def blk(p, h):
        y, a, _ = block_apply(p, cfg, h, positions)
        return y, a, None

    if cfg.remat:
        if cfg.remat_policy == "save_comm":
            blk = jax.checkpoint(
                blk,
                policy=jax.checkpoint_policies.save_only_these_names(
                    "attn_out", "mlp_out"
                ),
            )
        else:
            blk = jax.checkpoint(blk)

    aux_total = jnp.zeros((), jnp.float32)
    u = cfg.scan_unroll
    if n_pre:
        x, aux, _ = _scan_stack(blk, params["dense_layers"], x, unroll=u)
        aux_total += aux
    if n_grp:

        def group(gp, h):
            a_tot = jnp.zeros((), jnp.float32)
            if dpg:
                h, a, _ = _scan_stack(blk, gp["group_dense"], h, unroll=u)
                a_tot += a
            h, a, _ = blk(gp["group_moe"], h)
            return h, a_tot + a, None

        gparams = {"group_moe": params["group_moe"]}
        if dpg:
            gparams["group_dense"] = params["group_dense"]
        x, aux, _ = _scan_stack(group, gparams, x, unroll=u)
        aux_total += aux
    return rms_norm(x, params["final_norm"], cfg.norm_eps), aux_total


def lm_head(params, cfg: ModelConfig, h):
    if cfg.tie_embeddings:
        return h @ params["embed"].T
    return h @ params["lm_head"]


def transformer_prefill(params, cfg: ModelConfig, x, positions, max_len: int):
    """Full pass that also RETURNS the populated KV cache (real serving
    prefill, not just logits).  x: (B,S,D); cache padded to max_len.
    Returns (hidden (B,S,D), cache)."""
    n_pre, n_grp, dpg = _layer_plan(cfg)
    S = x.shape[1]
    eff_len = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    assert S <= eff_len, (S, eff_len)

    def blk(p, h):
        return block_apply(p, cfg, h, positions)

    u = cfg.scan_unroll
    cache = {}
    if n_pre:
        x, _, cache["dense_layers"] = _scan_stack(blk, params["dense_layers"], x, unroll=u)
    if n_grp:

        def group(gp, h):
            ys = {}
            a_tot = jnp.zeros((), jnp.float32)
            if dpg:
                h, a, ys["group_dense"] = _scan_stack(blk, gp["group_dense"], h, unroll=u)
                a_tot += a
            h, a, ys["group_moe"] = blk(gp["group_moe"], h)
            return h, a_tot + a, ys

        gparams = {"group_moe": params["group_moe"]}
        if dpg:
            gparams["group_dense"] = params["group_dense"]
        x, _, ys = _scan_stack(group, gparams, x, unroll=u)
        cache.update(ys)

    def pad(path, a):
        # time axis: -3 for k/v (.., S, Hkv, hd); -2 for MLA latents (.., S, c)
        name = str(getattr(path[-1], "key", ""))
        t_axis = a.ndim - 3 if name in ("k", "v") else a.ndim - 2
        widths = [(0, 0)] * a.ndim
        widths[t_axis] = (0, eff_len - a.shape[t_axis])
        return jnp.pad(a, widths)

    cache = jax.tree_util.tree_map_with_path(pad, cache)
    return rms_norm(x, params["final_norm"], cfg.norm_eps), cache


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def transformer_cache_init(cfg: ModelConfig, batch: int, max_len: int):
    n_pre, n_grp, dpg = _layer_plan(cfg)
    cache = {}
    if n_pre:
        cache["dense_layers"] = attn_cache_init(cfg, batch, max_len, layers=n_pre)
    if n_grp:
        if dpg:
            cache["group_dense"] = jax.tree_util.tree_map(
                lambda a: a.reshape(n_grp, dpg, *a.shape[1:]),
                attn_cache_init(cfg, batch, max_len, layers=n_grp * dpg),
            )
        cache["group_moe"] = attn_cache_init(cfg, batch, max_len, layers=n_grp)
    return cache


def transformer_decode(params, cfg: ModelConfig, cache, x, index):
    """x: (B,1,D) embedded token; index: scalar position. -> (h, new_cache)."""
    n_pre, n_grp, dpg = _layer_plan(cfg)
    if cfg.mrope_sections is not None:
        positions = jnp.broadcast_to(index, (3, x.shape[0], 1))
    else:
        positions = jnp.broadcast_to(index, (x.shape[0], 1))

    def blk(p, h, c):
        y, a, nc = block_apply(p, cfg, h, positions, cache=c, cache_index=index)
        return y, a, nc

    new_cache = {}
    u = cfg.scan_unroll
    if n_pre:
        x, _, new_cache["dense_layers"] = _scan_stack(
            blk, params["dense_layers"], x, extra_xs=cache["dense_layers"], unroll=u
        )
    if n_grp:

        def group(gp, h, gc):
            ys = {}
            if dpg:
                h, _, ys["group_dense"] = _scan_stack(blk, gp["group_dense"], h,
                                                      extra_xs=gc["group_dense"], unroll=u)
            h, _, ys["group_moe"] = blk(gp["group_moe"], h, gc["group_moe"])
            return h, jnp.zeros((), jnp.float32), ys

        gparams = {"group_moe": params["group_moe"]}
        gcache = {"group_moe": cache["group_moe"]}
        if dpg:
            gparams["group_dense"] = params["group_dense"]
            gcache["group_dense"] = cache["group_dense"]
        x, _, ys = _scan_stack(group, gparams, x, extra_xs=gcache, unroll=u)
        new_cache.update(ys)
    return rms_norm(x, params["final_norm"], cfg.norm_eps), new_cache
