"""Mixture-of-Experts layer: token-choice top-k router, capacity-based
sort/gather dispatch (no O(T·E·C) one-hots), shared experts, aux
load-balance loss.

Expert weights are stacked on a leading E axis -> expert-parallel sharding
P('model', ...) on the TPU mesh; the gather/scatter around the expert
matmuls lowers to all-to-all style collectives under GSPMD.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init
from repro.models.config import ModelConfig


def moe_init(key, cfg: ModelConfig):
    d, dff, E, dt = cfg.d_model, cfg.d_expert or cfg.d_ff, cfg.n_experts, cfg.jdtype
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, E), dt, scale=d**-0.5),
        "w_gate": dense_init(ks[1], (E, d, dff), dt),
        "w_up": dense_init(ks[2], (E, d, dff), dt),
        "w_down": dense_init(ks[3], (E, dff, d), dt),
    }
    if cfg.n_shared_experts:
        ds = dff * cfg.n_shared_experts
        k1, k2, k3 = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": dense_init(k1, (d, ds), dt),
            "w_up": dense_init(k2, (d, ds), dt),
            "w_down": dense_init(k3, (ds, d), dt),
        }
    return p


def _capacity(T: int, top_k: int, E: int, factor: float) -> int:
    c = int(T * top_k / E * factor)
    return max(8, -(-c // 8) * 8)  # round up to multiple of 8


def moe_apply(p, cfg: ModelConfig, x):
    """x: (B, S, D) -> (out (B,S,D), aux_loss scalar)."""
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.moe_top_k
    T = B * S
    xt = x.reshape(T, D)
    C = _capacity(T, k, E, cfg.capacity_factor)

    logits = (xt @ p["router"]).astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)  # (T, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # --- aux load-balance loss (Switch-style) -----------------------------
    me = probs.mean(axis=0)  # (E,) mean router prob
    ce = jnp.zeros((E,)).at[expert_idx.reshape(-1)].add(1.0) / (T * k)  # frac tokens
    aux = cfg.aux_loss_coef * E * jnp.sum(me * ce)

    # --- capacity dispatch via stable sort --------------------------------
    flat_e = expert_idx.reshape(-1)                       # (T*k,) expert ids
    flat_t = jnp.repeat(jnp.arange(T), k)                 # (T*k,) token ids
    flat_g = gate_vals.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)              # group by expert
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]
    # position of each entry within its expert group
    counts = jnp.bincount(se, length=E)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]])
    pos_in_e = jnp.arange(T * k) - starts[se]
    keep = pos_in_e < C
    # dropped entries scatter to index E*C which mode='drop' discards
    slot = jnp.where(keep, se * C + pos_in_e, E * C)

    # token index per (expert, slot); sentinel T = padded zero row
    dispatch_tok = jnp.full((E * C,), T, jnp.int32).at[slot].set(st.astype(jnp.int32), mode="drop")
    gate_per_slot = jnp.zeros((E * C,), jnp.float32).at[slot].set(sg, mode="drop")

    x_pad = jnp.concatenate([xt, jnp.zeros((1, D), xt.dtype)], axis=0)
    xg = x_pad[dispatch_tok].reshape(E, C, D)

    # --- expert FFN (einsum over stacked experts; E is sharded) -----------
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xg, p["w_gate"])) * jnp.einsum(
        "ecd,edf->ecf", xg, p["w_up"]
    )
    yo = jnp.einsum("ecf,efd->ecd", h, p["w_down"]).reshape(E * C, D)

    # --- combine: scatter-add back to tokens ------------------------------
    yw = yo * gate_per_slot[:, None].astype(yo.dtype)
    out = jnp.zeros((T + 1, D), yo.dtype).at[dispatch_tok].add(yw)[:T]

    if cfg.n_shared_experts:
        sp = p["shared"]
        hs = jax.nn.silu(xt @ sp["w_gate"]) * (xt @ sp["w_up"])
        out = out + hs @ sp["w_down"]
    return out.reshape(B, S, D), aux
