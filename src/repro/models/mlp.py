"""Small MLP classifier — used for the 256/1024-node scalability study
(paper Fig. 6), where the CNN would make CPU emulation of 1024 vmapped
nodes needlessly slow.  Same API shape as cnn.py."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init


def mlp_init(key, in_dim: int = 32 * 32 * 3, hidden: int = 128, num_classes: int = 10,
             dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "fc1": {"w": dense_init(k1, (in_dim, hidden), dtype), "b": jnp.zeros((hidden,), dtype)},
        "fc2": {"w": dense_init(k2, (hidden, hidden), dtype), "b": jnp.zeros((hidden,), dtype)},
        "fc3": {"w": dense_init(k3, (hidden, num_classes), dtype), "b": jnp.zeros((num_classes,), dtype)},
    }


def mlp_apply(params, images):
    x = images.reshape(images.shape[0], -1)
    x = jax.nn.relu(x @ params["fc1"]["w"] + params["fc1"]["b"])
    x = jax.nn.relu(x @ params["fc2"]["w"] + params["fc2"]["b"])
    return x @ params["fc3"]["w"] + params["fc3"]["b"]
