from repro.models.config import ModelConfig
from repro.models.api import (
    init_params,
    forward,
    loss_fn,
    init_cache,
    decode_step,
    param_specs,
    model_flops,
    param_count,
    active_param_count,
)
