"""Whisper-style encoder-decoder backbone. [arXiv:2212.04356]

The mel-spectrogram + conv frontend is a STUB per spec: inputs are
precomputed frame embeddings (B, enc_seq, D).  Everything downstream — the
encoder self-attention stack, the decoder with cross-attention, and the
cross/self KV caches — is fully implemented.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.attention import attn_apply, attn_cache_init, attn_init
from repro.models.common import dense_init, embed_init, mlp_apply, mlp_init, rms_norm
from repro.models.config import ModelConfig
from repro.models.transformer import lm_head, stacked_init


def enc_block_init(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": jnp.ones((cfg.d_model,), cfg.jdtype),
        "attn": attn_init(k1, cfg),
        "ln2": jnp.ones((cfg.d_model,), cfg.jdtype),
        "mlp": mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.jdtype),
    }


def dec_block_init(key, cfg: ModelConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": jnp.ones((cfg.d_model,), cfg.jdtype),
        "attn": attn_init(k1, cfg),
        "lnx": jnp.ones((cfg.d_model,), cfg.jdtype),
        "xattn": attn_init(k2, cfg, cross=True),
        "ln2": jnp.ones((cfg.d_model,), cfg.jdtype),
        "mlp": mlp_init(k3, cfg.d_model, cfg.d_ff, cfg.jdtype),
    }


def encdec_init(key, cfg: ModelConfig):
    ke, kd, kt, kh, kp = jax.random.split(key, 5)
    p = {
        "embed": embed_init(kt, (cfg.vocab, cfg.d_model), cfg.jdtype),
        "enc_pos": embed_init(kp, (cfg.enc_seq, cfg.d_model), cfg.jdtype),
        "enc_layers": stacked_init(lambda k: enc_block_init(k, cfg), ke, cfg.n_enc_layers),
        "enc_norm": jnp.ones((cfg.d_model,), cfg.jdtype),
        "dec_layers": stacked_init(lambda k: dec_block_init(k, cfg), kd, cfg.n_layers),
        "final_norm": jnp.ones((cfg.d_model,), cfg.jdtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(kh, (cfg.d_model, cfg.vocab), cfg.jdtype)
    return p


def encode(params, cfg: ModelConfig, frames):
    """frames: stub frontend embeddings (B, enc_seq, D)."""
    x = frames + params["enc_pos"][None]
    positions = jnp.arange(frames.shape[1])[None]

    def blk(h, p):
        a, _ = attn_apply(p["attn"], cfg, rms_norm(h, p["ln1"], cfg.norm_eps), positions, causal=False)
        h = h + a
        return h + mlp_apply(p["mlp"], rms_norm(h, p["ln2"], cfg.norm_eps)), None

    x, _ = jax.lax.scan(blk, x, params["enc_layers"], unroll=True if cfg.scan_unroll else 1)
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _dec_block(p, cfg, h, positions, enc_out, self_cache=None, cross_cache=None, index=None):
    a, new_self = attn_apply(
        p["attn"], cfg, rms_norm(h, p["ln1"], cfg.norm_eps), positions,
        cache=self_cache, cache_index=index,
    )
    h = h + a
    xa, new_cross = attn_apply(
        p["xattn"], cfg, rms_norm(h, p["lnx"], cfg.norm_eps), positions,
        kv_src=enc_out, cache=cross_cache, cross=True,
    )
    h = h + xa
    return h + mlp_apply(p["mlp"], rms_norm(h, p["ln2"], cfg.norm_eps)), new_self, new_cross


def decode_train(params, cfg: ModelConfig, frames, tokens):
    """Teacher-forced decoder pass -> logits (B, S, V)."""
    enc_out = encode(params, cfg, frames)
    x = params["embed"][tokens]
    positions = jnp.arange(tokens.shape[1])[None]

    def blk(h, p):
        y, _, _ = _dec_block(p, cfg, h, positions, enc_out)
        return y, None

    x, _ = jax.lax.scan(blk, x, params["dec_layers"], unroll=True if cfg.scan_unroll else 1)
    h = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return lm_head(params, cfg, h)


# ---------------------------------------------------------------------------
# decode (serving)
# ---------------------------------------------------------------------------

def encdec_cache_init(params, cfg: ModelConfig, frames, batch: int, max_len: int):
    """Self-attn cache + precomputed cross-attn KV per decoder layer."""
    enc_out = encode(params, cfg, frames)
    Hkv, hd = cfg.n_kv_heads, cfg.hd

    def xkv(p):
        k = (enc_out @ p["xattn"]["w_k"]).reshape(batch, -1, Hkv, hd)
        v = (enc_out @ p["xattn"]["w_v"]).reshape(batch, -1, Hkv, hd)
        return {"k": k, "v": v}

    cross = jax.vmap(xkv)(params["dec_layers"])
    return {
        "self": attn_cache_init(cfg, batch, max_len, layers=cfg.n_layers),
        "cross": cross,
    }


def encdec_cache_specs(cfg: ModelConfig, batch: int, max_len: int):
    """ShapeDtypeStruct-compatible zero cache (for dry-run input_specs)."""
    Hkv, hd, L = cfg.n_kv_heads, cfg.hd, cfg.n_layers
    return {
        "self": attn_cache_init(cfg, batch, max_len, layers=L),
        "cross": {
            "k": jnp.zeros((L, batch, cfg.enc_seq, Hkv, hd), cfg.jdtype),
            "v": jnp.zeros((L, batch, cfg.enc_seq, Hkv, hd), cfg.jdtype),
        },
    }


def encdec_decode(params, cfg: ModelConfig, cache, x, index):
    """x: (B,1,D) embedded token -> (h, new_cache)."""
    positions = jnp.broadcast_to(index, (x.shape[0], 1))

    def blk(h, xs):
        p, sc, cc = xs
        y, new_self, _ = _dec_block(
            p, cfg, h, positions, None, self_cache=sc, cross_cache=cc, index=index
        )
        return y, new_self

    x, new_self = jax.lax.scan(blk, x, (params["dec_layers"], cache["self"], cache["cross"]),
                               unroll=True if cfg.scan_unroll else 1)
    h = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return h, {"self": new_self, "cross": cache["cross"]}
