"""Unified model API across all families.

``init_params / forward / loss_fn / init_cache / decode_step`` dispatch on
``cfg.family``; ``param_specs`` produces the tensor-parallel PartitionSpec
pytree (the node axis is prepended by the DL layer, see core/node.py).
"""
from __future__ import annotations

import re
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models import cnn as _cnn
from repro.models import encdec as _encdec
from repro.models import hybrid as _hybrid
from repro.models.config import ModelConfig
from repro.models.transformer import (
    lm_head,
    transformer_apply,
    transformer_cache_init,
    transformer_decode,
    transformer_init,
)


# ---------------------------------------------------------------------------
# init / forward / loss
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key):
    cfg.validate()
    if cfg.family == "cnn":
        return _cnn.cnn_init(key, num_classes=cfg.vocab, dtype=cfg.jdtype)
    if cfg.family in ("ssm", "hybrid"):
        return _hybrid.hybrid_init(key, cfg)
    if cfg.family == "encdec":
        return _encdec.encdec_init(key, cfg)
    return transformer_init(key, cfg)  # dense / moe / vlm


def _positions(cfg: ModelConfig, B: int, S: int):
    if cfg.mrope_sections is not None:
        return jnp.broadcast_to(jnp.arange(S)[None, None, :], (3, B, S))
    return jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))


def forward(params, cfg: ModelConfig, batch):
    """-> (logits, aux_loss)."""
    if cfg.family == "cnn":
        return _cnn.cnn_apply(params, batch["images"]), jnp.zeros((), jnp.float32)
    if cfg.family == "encdec":
        logits = _encdec.decode_train(params, cfg, batch["frames"], batch["tokens"])
        return logits, jnp.zeros((), jnp.float32)
    if "embeddings" in batch:  # vlm stub frontend
        x = batch["embeddings"]
        B, S = x.shape[:2]
        positions = batch.get("positions", _positions(cfg, B, S))
    else:
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = params["embed"][tokens]
        positions = _positions(cfg, B, S)
    if cfg.family in ("ssm", "hybrid"):
        h, aux = _hybrid.hybrid_apply(params, cfg, x, positions)
        return lm_head(params, cfg, h), aux
    h, aux = transformer_apply(params, cfg, x, positions)
    return lm_head(params, cfg, h), aux


def cross_entropy(logits, labels, ignore: int = -1):
    """Mean CE over valid labels. logits (..., V), labels (...)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None].clip(0), axis=-1)[..., 0]
    valid = labels != ignore
    ce = jnp.where(valid, lse - gold, 0.0)
    return ce.sum() / jnp.maximum(valid.sum(), 1)


def loss_fn(params, cfg: ModelConfig, batch):
    logits, aux = forward(params, cfg, batch)
    return cross_entropy(logits, batch["labels"]) + aux


# ---------------------------------------------------------------------------
# decode (serving)
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    """Zeroed cache (also usable as dry-run ShapeDtypeStruct template)."""
    if cfg.family in ("ssm", "hybrid"):
        return _hybrid.hybrid_cache_init(cfg, batch, max_len)
    if cfg.family == "encdec":
        return _encdec.encdec_cache_specs(cfg, batch, max_len)
    return transformer_cache_init(cfg, batch, max_len)


def prefill(params, cfg: ModelConfig, batch, max_len: int):
    """Real serving prefill for transformer families: one full pass that
    returns (last-position logits (B,V), populated cache).  Decode then
    continues from index = S.  (SSM/hybrid/enc-dec prefill paths live in
    their modules; see encdec.encdec_cache_init.)"""
    from repro.models.transformer import transformer_prefill

    assert cfg.family in ("dense", "moe", "vlm"), cfg.family
    if "embeddings" in batch:
        x = batch["embeddings"]
        B, S = x.shape[:2]
        positions = batch.get("positions", _positions(cfg, B, S))
    else:
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = params["embed"][tokens]
        positions = _positions(cfg, B, S)
    h, cache = transformer_prefill(params, cfg, x, positions, max_len)
    return lm_head(params, cfg, h[:, -1]), cache


def decode_step(params, cfg: ModelConfig, cache, tokens, index):
    """tokens (B, 1) int32; index: scalar int32 position. -> (logits, cache)."""
    x = params["embed"][tokens]
    if cfg.family in ("ssm", "hybrid"):
        h, new_cache = _hybrid.hybrid_decode(params, cfg, cache, x, index)
    elif cfg.family == "encdec":
        h, new_cache = _encdec.encdec_decode(params, cfg, cache, x, index)
    else:
        h, new_cache = transformer_decode(params, cfg, cache, x, index)
    return lm_head(params, cfg, h), new_cache


# ---------------------------------------------------------------------------
# partition specs (tensor-parallel over the 'model' mesh axis)
# ---------------------------------------------------------------------------

_RULES = [
    # (regex on dotted path, base rank, spec for the trailing base dims)
    (r"embed$", 2, ("model", None)),
    (r"enc_pos$", 2, (None, None)),
    (r"lm_head$", 2, (None, "model")),
    (r"(w_q|w_k|w_v)$", 2, (None, "model")),
    (r"(b_q|b_k|b_v)$", 1, ("model",)),
    (r"w_o$", 2, ("model", None)),
    (r"w_dq$", 2, (None, None)),
    (r"w_dkv$", 2, (None, None)),
    (r"(w_uk|w_uv)$", 3, ("model", None, None)),
    (r"moe\.router$", 2, (None, "model")),
    (r"moe\.(w_gate|w_up|w_down)$", 3, ("model", None, None)),
    (r"(w_gate|w_up)$", 2, (None, "model")),
    (r"w_down$", 2, ("model", None)),
    (r"in_proj$", 2, (None, "model")),
    (r"conv_w$", 2, (None, "model")),
    (r"conv_b$", 1, ("model",)),
    (r"gate_norm$", 1, ("model",)),
    (r"out_proj$", 2, ("model", None)),
]


def _path_str(path) -> str:
    return ".".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def param_specs(cfg: ModelConfig, leading=()):
    """PartitionSpec pytree matching ``init_params`` output.

    ``leading`` is prepended to every spec (e.g. the node axis
    ``(('pod','data'),)`` from the DL layer).  Stacked layer dims get None.
    """
    shapes = jax.eval_shape(lambda k: init_params(cfg, k), jax.random.key(0))

    def spec_for(path, leaf):
        name = _path_str(path)
        for pat, base_rank, base_spec in _RULES:
            if re.search(pat, name):
                pad = (None,) * (leaf.ndim - base_rank)
                return P(*leading, *pad, *base_spec)
        return P(*leading, *((None,) * leaf.ndim))

    return jax.tree_util.tree_map_with_path(spec_for, shapes)


def cache_specs(cfg: ModelConfig, batch: int, max_len: int, leading=()):
    """PartitionSpecs for the KV/state cache: batch over node axis, heads/
    channels over 'model' where the dim is head-like."""
    shapes = jax.eval_shape(lambda: init_cache(cfg, batch, max_len))

    def spec_for(path, leaf):
        name = _path_str(path)
        # caches: (layers..., B, ...) — B is the first batch-like dim after
        # stacked layer dims.  k/v: (..., B, S, Hkv, hd) -> heads sharded.
        if re.search(r"(\bk$|\bv$|k$|v$)", name) and leaf.ndim >= 4:
            pad = (None,) * (leaf.ndim - 4)
            return P(*leading, *pad, None, "model", None)
        return P(*leading, *((None,) * leaf.ndim))

    return jax.tree_util.tree_map_with_path(spec_for, shapes)


# ---------------------------------------------------------------------------
# counting
# ---------------------------------------------------------------------------

def param_count(cfg: ModelConfig) -> int:
    shapes = jax.eval_shape(lambda k: init_params(cfg, k), jax.random.key(0))
    return sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(shapes))


def active_param_count(cfg: ModelConfig) -> int:
    """Params touched per token (MoE: only top-k routed experts active)."""
    shapes = jax.eval_shape(lambda k: init_params(cfg, k), jax.random.key(0))
    total = 0

    def walk(path, leaf):
        nonlocal total
        name = _path_str(path)
        n = int(np.prod(leaf.shape))
        if re.search(r"moe\.(w_gate|w_up|w_down)$", name):
            n = int(n * cfg.moe_top_k / cfg.n_experts)
        total += n

    jax.tree_util.tree_map_with_path(walk, shapes)
    return total


def model_flops(cfg: ModelConfig, tokens: int, mode: str = "train") -> float:
    """MODEL_FLOPS = 6·N·D (train) / 2·N·D (inference), N = active params."""
    n = active_param_count(cfg)
    return (6.0 if mode == "train" else 2.0) * n * tokens
