"""Attention: GQA (+ qk-norm, QKV bias, RoPE / M-RoPE, sliding window),
MLA (deepseek-v2 latent attention), and cross-attention — with KV caches.

Layouts: activations (B, S, D); q/k/v (B, S, H, hd); caches (B, S_max, Hkv, hd).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.common import apply_mrope, apply_rope, dense_init, rms_norm
from repro.models.config import ModelConfig

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def attn_init(key, cfg: ModelConfig, cross: bool = False):
    d, H, Hkv, hd, dt = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd, cfg.jdtype
    ks = jax.random.split(key, 8)
    if cfg.mla and not cross:
        p = {
            "w_q": dense_init(ks[0], (d, H * (cfg.qk_nope_dim + cfg.qk_rope_dim)), dt),
            "w_dkv": dense_init(ks[1], (d, cfg.kv_lora_rank + cfg.qk_rope_dim), dt),
            "kv_norm": jnp.ones((cfg.kv_lora_rank,), dt),
            "w_uk": dense_init(ks[2], (H, cfg.kv_lora_rank, cfg.qk_nope_dim), dt),
            "w_uv": dense_init(ks[3], (H, cfg.kv_lora_rank, cfg.v_head_dim), dt),
            "w_o": dense_init(ks[4], (H * cfg.v_head_dim, d), dt),
        }
        if cfg.q_lora_rank:
            p["w_dq"] = dense_init(ks[5], (d, cfg.q_lora_rank), dt)
            p["q_norm"] = jnp.ones((cfg.q_lora_rank,), dt)
            p["w_q"] = dense_init(ks[0], (cfg.q_lora_rank, H * (cfg.qk_nope_dim + cfg.qk_rope_dim)), dt)
        return p
    p = {
        "w_q": dense_init(ks[0], (d, H * hd), dt),
        "w_k": dense_init(ks[1], (d, Hkv * hd), dt),
        "w_v": dense_init(ks[2], (d, Hkv * hd), dt),
        "w_o": dense_init(ks[3], (H * hd, d), dt),
    }
    if cfg.qkv_bias:
        p["b_q"] = jnp.zeros((H * hd,), dt)
        p["b_k"] = jnp.zeros((Hkv * hd,), dt)
        p["b_v"] = jnp.zeros((Hkv * hd,), dt)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dt)
        p["k_norm"] = jnp.ones((hd,), dt)
    return p


def attn_cache_init(cfg: ModelConfig, batch: int, max_len: int, layers: Optional[int] = None):
    """Zeroed KV cache for ``layers`` stacked layers (or unstacked if None)."""
    Hkv, hd, dt = cfg.n_kv_heads, cfg.hd, cfg.jdtype
    if cfg.sliding_window is not None:
        max_len = min(max_len, cfg.sliding_window)
    if cfg.mla:
        shp_c = (batch, max_len, cfg.kv_lora_rank)
        shp_r = (batch, max_len, cfg.qk_rope_dim)
        if layers is not None:
            shp_c, shp_r = (layers, *shp_c), (layers, *shp_r)
        return {"ckv": jnp.zeros(shp_c, dt), "krope": jnp.zeros(shp_r, dt)}
    shp = (batch, max_len, Hkv, hd)
    if layers is not None:
        shp = (layers, *shp)
    return {"k": jnp.zeros(shp, dt), "v": jnp.zeros(shp, dt)}


# ---------------------------------------------------------------------------
# core score/combine
# ---------------------------------------------------------------------------

def _gqa_scores(q, k):
    """q: (B,S,H,hd), k: (B,T,Hkv,hd) -> (B,Hkv,G,S,T) fp32 scores."""
    B, S, H, hd = q.shape
    Hkv = k.shape[2]
    q = q.reshape(B, S, Hkv, H // Hkv, hd)
    return jnp.einsum("bskgd,btkd->bkgst", q, k, preferred_element_type=jnp.float32)


def _gqa_combine(w, v):
    """w: (B,Hkv,G,S,T) fp32, v: (B,T,Hkv,hd) -> (B,S,H*hd)."""
    B, Hkv, G, S, T = w.shape
    o = jnp.einsum("bkgst,btkd->bskgd", w.astype(v.dtype), v)
    return o.reshape(B, S, Hkv * G * v.shape[-1])


def _softmax_masked(scores, mask):
    scores = jnp.where(mask, scores, NEG_INF)
    return jax.nn.softmax(scores, axis=-1)


def _chunked_gqa_attention(q, k, v, scale, *, causal=True, window=None, chunk=512,
                           unroll=False):
    """Flash-style running-softmax attention, scanned over KV chunks.

    Removes the O(S^2) materialized score tensors from HBM: each scan
    iteration's (B,Hkv,G,S,C) scores are fused into the softmax-accumulate
    and never written back.  q: (B,S,H,hd); k/v: (B,T,Hkv,hd).
    """
    B, S, H, hd = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    C = min(chunk, T)
    assert T % C == 0, (T, C)
    nc = T // C
    qr = q.reshape(B, S, Hkv, G, hd)
    ks = jnp.moveaxis(k.reshape(B, nc, C, Hkv, hd), 1, 0)
    vs = jnp.moveaxis(v.reshape(B, nc, C, Hkv, hd), 1, 0)
    q_pos = jnp.arange(S)

    def body(carry, inp):
        m, l, acc = carry
        kc, vc, j = inp
        s = jnp.einsum("bskgd,btkd->bkgst", qr, kc, preferred_element_type=jnp.float32) * scale
        k_pos = j * C + jnp.arange(C)
        mask = jnp.ones((S, C), bool)
        if causal:
            mask &= k_pos[None, :] <= q_pos[:, None]
        if window is not None:
            mask &= k_pos[None, :] > q_pos[:, None] - window
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m - m_new)
        l2 = l * alpha + p.sum(-1, keepdims=True)
        acc2 = acc * alpha + jnp.einsum("bkgst,btkd->bkgsd", p.astype(vc.dtype), vc).astype(
            jnp.float32
        )
        return (m_new, l2, acc2), None

    m0 = jnp.full((B, Hkv, G, S, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, S, 1), jnp.float32)
    a0 = jnp.zeros((B, Hkv, G, S, hd), jnp.float32)
    # dry-run roofline must unroll: XLA cost_analysis counts a while body once
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (ks, vs, jnp.arange(nc)),
                                  unroll=True if unroll else 1)
    out = acc / jnp.maximum(l, 1e-30)
    # (B,Hkv,G,S,hd) -> (B,S,H*hd)
    return jnp.moveaxis(out, 3, 1).reshape(B, S, H * hd).astype(q.dtype)


def causal_mask(S: int, T: int, offset: int = 0, window: Optional[int] = None):
    """(S, T) boolean mask; query i attends key j iff j <= i + offset
    (and j > i + offset - window for sliding window)."""
    qi = jnp.arange(S)[:, None] + offset
    kj = jnp.arange(T)[None, :]
    m = kj <= qi
    if window is not None:
        m &= kj > qi - window
    return m


# ---------------------------------------------------------------------------
# GQA forward (train / prefill / decode)
# ---------------------------------------------------------------------------

def attn_apply(
    p,
    cfg: ModelConfig,
    x,
    positions,
    *,
    cache=None,
    cache_index=None,
    kv_src=None,
    cross: bool = False,
    causal: bool = True,
):
    """General attention.

    cache=None            -> full self-attention over x (train/prefill).
    cache given           -> decode: x is (B,1,D); write kv at cache_index.
    cross=True            -> cross-attention onto kv_src (B,T,D) (no rope);
                             at decode kv_src may be None (kv read from cache).
    Returns (out, new_cache).
    """
    cross = cross or kv_src is not None
    if cfg.mla and not cross:
        return _mla_apply(p, cfg, x, positions, cache=cache, cache_index=cache_index)

    B, S, D = x.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    src = x if kv_src is None else kv_src
    q = x @ p["w_q"]
    if "b_q" in p:
        q = q + p["b_q"]
    q = q.reshape(B, S, H, hd)
    scale = hd**-0.5

    fresh_kv = not (cross and cache is not None)
    if not fresh_kv:
        # cross-attention decode: kv precomputed in cache at prefill time
        k, v = cache["k"], cache["v"]
        new_cache = cache
    else:
        k = src @ p["w_k"]
        v = src @ p["w_v"]
        if "b_k" in p:
            k, v = k + p["b_k"], v + p["b_v"]
        T0 = src.shape[1]
        k = k.reshape(B, T0, Hkv, hd)
        v = v.reshape(B, T0, Hkv, hd)
        new_cache = None

    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        if fresh_kv:
            k = rms_norm(k, p["k_norm"], cfg.norm_eps)

    if not cross:  # self-attention: rope
        if cfg.mrope_sections is not None:
            q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
            k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
        else:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)

    if cache is not None and not cross:
        # decode: write new kv into the cache (ring-buffered for SWA)
        T = cache["k"].shape[1]
        if cfg.sliding_window is not None:
            slot = cache_index % T
        else:
            slot = cache_index
        k_all = jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
        v_all = jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))
        new_cache = {"k": k_all, "v": v_all}
        scores = _gqa_scores(q, k_all) * scale  # (B,Hkv,G,1,T)
        if cfg.sliding_window is not None:
            # ring buffer: slots [0, min(index+1, T)) are valid
            valid = jnp.arange(T) < jnp.minimum(cache_index + 1, T)
        else:
            valid = jnp.arange(T) <= cache_index
        mask = valid[None, None, None, None, :]
        w = _softmax_masked(scores, mask)
        out = _gqa_combine(w, v_all)
        return out @ p["w_o"], new_cache

    T = k.shape[1]
    if cache is None and fresh_kv:
        # full pass: expose the (roped) kv — prefill collects it into the
        # decode cache; train paths simply drop it
        new_cache = {"k": k, "v": v}
    if (cfg.attn_impl == "pallas_swa" and cfg.sliding_window and not cross
            and cache is None and S % 128 == 0 and cfg.sliding_window % 128 == 0):
        # Pallas sliding-window flash kernel (kernels/swa_attention.py)
        from repro.kernels import ops as kops

        G = H // Hkv
        km = jnp.repeat(k, G, axis=2).transpose(0, 2, 1, 3).reshape(B * H, S, hd)
        vm = jnp.repeat(v, G, axis=2).transpose(0, 2, 1, 3).reshape(B * H, S, hd)
        qm = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
        o = kops.swa_attention(qm, km, vm, cfg.sliding_window)
        out = o.reshape(B, H, S, hd).transpose(0, 2, 1, 3).reshape(B, S, H * hd)
        return out @ p["w_o"], new_cache
    if cfg.attn_impl == "chunked" and T % min(cfg.attn_chunk, T) == 0:
        out = _chunked_gqa_attention(
            q, k, v, scale,
            causal=causal and not cross,
            window=cfg.sliding_window if not cross else None,
            chunk=cfg.attn_chunk,
            unroll=cfg.scan_unroll,
        )
        return out @ p["w_o"], new_cache
    scores = _gqa_scores(q, k) * scale
    if cross:
        mask = jnp.ones((S, T), bool)
    else:
        mask = causal_mask(S, T, window=cfg.sliding_window) if causal else jnp.ones((S, T), bool)
    w = _softmax_masked(scores, mask[None, None, None])
    out = _gqa_combine(w, v)
    return out @ p["w_o"], new_cache


# ---------------------------------------------------------------------------
# MLA (deepseek-v2)
# ---------------------------------------------------------------------------

def _mla_qkv(p, cfg: ModelConfig, x, positions):
    B, S, _ = x.shape
    H = cfg.n_heads
    nd, rd = cfg.qk_nope_dim, cfg.qk_rope_dim
    xq = x
    if cfg.q_lora_rank:
        xq = rms_norm(x @ p["w_dq"], p["q_norm"], cfg.norm_eps)
    q = (xq @ p["w_q"]).reshape(B, S, H, nd + rd)
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    ckv_full = x @ p["w_dkv"]
    ckv = rms_norm(ckv_full[..., : cfg.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    k_rope = ckv_full[..., cfg.kv_lora_rank :][:, :, None, :]  # (B,S,1,rd)
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)[:, :, 0, :]
    return q_nope, q_rope, ckv, k_rope


def _mla_apply(p, cfg: ModelConfig, x, positions, *, cache=None, cache_index=None):
    B, S, _ = x.shape
    H = cfg.n_heads
    scale = (cfg.qk_nope_dim + cfg.qk_rope_dim) ** -0.5
    q_nope, q_rope, ckv, k_rope = _mla_qkv(p, cfg, x, positions)

    if cache is None:
        if cfg.attn_impl == "chunked" and S % min(cfg.attn_chunk, S) == 0:
            # flash-style over latent-cache chunks with W_uk/W_uv absorption:
            # never materializes (B,H,S,S) scores nor per-head k/v
            C = min(cfg.attn_chunk, S)
            nc = S // C
            q_eff = jnp.einsum("bshd,hcd->bshc", q_nope, p["w_uk"])
            ckv_s = jnp.moveaxis(ckv.reshape(B, nc, C, -1), 1, 0)
            kr_s = jnp.moveaxis(k_rope.reshape(B, nc, C, -1), 1, 0)
            q_pos = jnp.arange(S)
            cdim = ckv.shape[-1]

            def body(carry, inp):
                m, l, acc = carry
                kc, rc, j = inp
                s = (
                    jnp.einsum("bshc,btc->bhst", q_eff, kc,
                               preferred_element_type=jnp.float32)
                    + jnp.einsum("bshd,btd->bhst", q_rope, rc,
                                 preferred_element_type=jnp.float32)
                ) * scale
                k_pos = j * C + jnp.arange(C)
                mask = k_pos[None, :] <= q_pos[:, None]
                s = jnp.where(mask[None, None], s, NEG_INF)
                m_new = jnp.maximum(m, s.max(-1, keepdims=True))
                pv = jnp.exp(s - m_new)
                alpha = jnp.exp(m - m_new)
                l2 = l * alpha + pv.sum(-1, keepdims=True)
                # acc layout (B,H,S,c): rescale by alpha (B,H,S,1)
                acc2 = acc * alpha + jnp.einsum(
                    "bhst,btc->bhsc", pv.astype(kc.dtype), kc
                ).astype(jnp.float32)
                return (m_new, l2, acc2), None

            m0 = jnp.full((B, H, S, 1), NEG_INF, jnp.float32)
            l0 = jnp.zeros((B, H, S, 1), jnp.float32)
            a0 = jnp.zeros((B, H, S, cdim), jnp.float32)
            (m, l, acc), _ = jax.lax.scan(
                body, (m0, l0, a0), (ckv_s, kr_s, jnp.arange(nc)),
                unroll=True if cfg.scan_unroll else 1,
            )
            o_lat = (acc / jnp.maximum(l, 1e-30)).astype(ckv.dtype)  # (B,H,S,c)
            o = jnp.einsum("bhsc,hcd->bshd", o_lat, p["w_uv"])
            out = o.reshape(B, S, H * cfg.v_head_dim) @ p["w_o"]
            return out, {"ckv": ckv, "krope": k_rope}
        # train / prefill: materialize per-head k/v from the latent
        k_nope = jnp.einsum("btc,hcd->bthd", ckv, p["w_uk"])
        v = jnp.einsum("btc,hcd->bthd", ckv, p["w_uv"])
        scores = (
            jnp.einsum("bshd,bthd->bhst", q_nope, k_nope, preferred_element_type=jnp.float32)
            + jnp.einsum("bshd,btd->bhst", q_rope, k_rope, preferred_element_type=jnp.float32)
        ) * scale
        mask = causal_mask(S, S)
        w = _softmax_masked(scores, mask[None, None])
        o = jnp.einsum("bhst,bthd->bshd", w.astype(v.dtype), v)
        out = o.reshape(B, S, H * cfg.v_head_dim) @ p["w_o"]
        return out, {"ckv": ckv, "krope": k_rope}

    # decode with matrix absorption: attend directly over the latent cache.
    T = cache["ckv"].shape[1]
    ckv_all = jax.lax.dynamic_update_slice(cache["ckv"], ckv, (0, cache_index, 0))
    krope_all = jax.lax.dynamic_update_slice(cache["krope"], k_rope, (0, cache_index, 0))
    new_cache = {"ckv": ckv_all, "krope": krope_all}
    # absorb W_uk into q:  q_eff (B,1,H,c)
    q_eff = jnp.einsum("bshd,hcd->bshc", q_nope, p["w_uk"])
    scores = (
        jnp.einsum("bshc,btc->bhst", q_eff, ckv_all, preferred_element_type=jnp.float32)
        + jnp.einsum("bshd,btd->bhst", q_rope, krope_all, preferred_element_type=jnp.float32)
    ) * scale
    mask = (jnp.arange(T) <= cache_index)[None, None, None, :]
    w = _softmax_masked(scores, mask)
    o_lat = jnp.einsum("bhst,btc->bshc", w.astype(ckv_all.dtype), ckv_all)  # (B,1,H,c)
    o = jnp.einsum("bshc,hcd->bshd", o_lat, p["w_uv"])
    out = o.reshape(B, S, H * cfg.v_head_dim) @ p["w_o"]
    return out, new_cache
