"""End-to-end training driver (deliverable b's "train a ~100M model for a
few hundred steps"): decentralized LM training of any registry arch at
smoke- or full-scale on the available devices.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \\
        --scale smoke --steps 200 --nodes 4

Uses the node-stacked D-PSGD trainer (vmap local grads + gossip) — the
same code path the dry-run lowers for the production mesh — plus the data
pipeline, checkpointing, and per-round JSON results.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_checkpoint, load_checkpoint, save_checkpoint
from repro.configs import ARCHS, get_config, get_smoke_config
from repro.data import make_dataset, sharding_partition
from repro.models.api import init_params
from repro.optim import make_optimizer
from repro.training.trainer import TrainConfig, make_train_step


def build_lm_batcher(cfg, n_nodes: int, batch: int, seq: int, seed: int = 0):
    """Token-stream batcher: synthetic Markov LM data, 2-sharded non-IID by
    document class, reshaped to (N, B, seq)."""
    ds = make_dataset("lm", n_train=n_nodes * 64, n_test=64, seq_len=seq + 1,
                      vocab=min(cfg.vocab, 512), seed=seed)
    parts = sharding_partition(ds.train_y, n_nodes, 2, seed=seed)

    def batch_fn(step: int):
        xs = []
        for i, part in enumerate(parts):
            rng = np.random.default_rng(seed * 999983 + step * 17 + i)
            take = rng.choice(part, batch, replace=len(part) < batch)
            xs.append(ds.train_x[take])
        arr = np.stack(xs)  # (N, B, seq+1)
        return {"tokens": jnp.asarray(arr[:, :, :-1]),
                "labels": jnp.asarray(arr[:, :, 1:])}

    return batch_fn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m", choices=ARCHS)
    ap.add_argument("--scale", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--nodes", type=int, default=4)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--topology", default="regular",
                    choices=["ring", "regular", "fully"])
    ap.add_argument("--degree", type=int, default=5)
    ap.add_argument("--optimizer", default="sgd", choices=["sgd", "momentum", "adamw"])
    ap.add_argument("--chunk-steps", type=int, default=8,
                    help="steps compiled into one lax.scan dispatch "
                         "(RoundEngine-style chunking; 1 = per-step dispatch)")
    ap.add_argument("--ckpt-dir", default="results/train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.scale == "full" else get_smoke_config(args.arch)
    if cfg.family == "cnn":
        raise SystemExit("use examples/quickstart.py for the CNN workload")
    cfg = cfg.replace(dtype="float32")  # CPU
    N = args.nodes
    if args.topology == "regular" and N <= args.degree:
        args.topology = "fully"

    print(f"[train] arch={args.arch} scale={args.scale} N={N} "
          f"topology={args.topology} steps={args.steps}")
    keys = jax.random.split(jax.random.key(0), N)
    params = jax.vmap(lambda k: init_params(cfg, k))(keys)
    opt = make_optimizer(args.optimizer, args.lr)
    opt_state = jax.vmap(opt.init)(params)

    tc = TrainConfig(n_nodes=N, topology=args.topology, degree=args.degree,
                     mixing_impl="roll", grad_clip=1.0)
    step_fn = make_train_step(cfg, opt, tc)
    batch_fn = build_lm_batcher(cfg, N, args.batch, args.seq)

    # RoundEngine-style chunking: scan `chunk` steps per dispatch over
    # host-pre-stacked token batches (tokens are tiny; the models are not).
    # Per-step losses are still collected, so the logging cadence is intact.
    def chunk_fn(params, opt_state, batches):
        def body(carry, batch):
            params, opt_state = carry
            params, opt_state, loss = step_fn(params, opt_state, batch)
            return (params, opt_state), loss

        (params, opt_state), losses = jax.lax.scan(body, (params, opt_state), batches)
        return params, opt_state, losses

    chunk_jit = jax.jit(chunk_fn)
    chunk = max(args.chunk_steps, 1)

    start = 0
    if args.resume and latest_checkpoint(args.ckpt_dir) is not None:
        start, trees = load_checkpoint(args.ckpt_dir)
        params = jax.tree_util.tree_map(
            lambda a, b: jnp.asarray(b, a.dtype), params, trees["params"])
        print(f"[train] resumed from step {start}")

    os.makedirs(args.ckpt_dir, exist_ok=True)
    hist = []
    t0 = time.time()
    step = start
    while step < args.steps:
        r = min(chunk, args.steps - step)
        batches = jax.tree_util.tree_map(
            lambda *bs: jnp.stack(bs), *[batch_fn(step + s) for s in range(r)]
        )
        params, opt_state, losses = chunk_jit(params, opt_state, batches)
        losses = np.asarray(losses)
        for s in range(r):
            gstep = step + s
            if gstep % args.log_every == 0 or gstep == args.steps - 1:
                l = float(losses[s])
                hist.append({"step": gstep, "loss": l, "wall_s": time.time() - t0})
                print(f"[train] step {gstep:5d} loss {l:.4f} "
                      f"({(time.time() - t0) / max(gstep - start + 1, 1):.2f}s/step)",
                      flush=True)
        step += r
        if (step // args.ckpt_every) > ((step - r) // args.ckpt_every) and step < args.steps:
            save_checkpoint(args.ckpt_dir, step, params=params)
    save_checkpoint(args.ckpt_dir, args.steps, params=params)
    with open(os.path.join(args.ckpt_dir, "history.json"), "w") as f:
        json.dump(hist, f, indent=1)
    print(f"[train] done: loss {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f}; "
          f"checkpoint + history in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
