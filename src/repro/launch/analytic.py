"""Analytic fused-HBM model for the memory roofline term.

XLA's ``bytes accessed`` counts every HLO op's operands *unfused* — an
upper bound that cannot show fusion wins (a flash-attention rewrite keeps
the same unfused byte count while eliminating the HBM traffic on real
hardware).  This module provides the complementary *lower-bound-ish*
estimate: what a well-fused TPU program actually moves.

Counted per device (tp = model-parallel degree), train mode:

  params     read fwd + read remat-fwd + grad write+read + update write
  activations L x T x d x K boundary tensors (written fwd, read bwd;
             K ~ 8 post-fusion layer boundaries: x2 residual, qkvo, mlp in/out)
  attention  naive: the O(S^2) score/prob tensors (fp32 write + read, fwd
             and bwd) — this is the term chunked/flash attention deletes;
             chunked: ~0 extra (scores live in VMEM/registers)
  MoE        dispatch gather + combine scatter (E*C*d in/out per MoE layer)
  decode     weights streamed once per step + KV/state cache read+write

All terms are per *node*, divided by tp (activations/params are sharded).
This is a model, not a measurement — treated as the fused bound alongside
the unfused HLO bound; the truth on hardware lies between.
"""
from __future__ import annotations

from repro.configs import INPUT_SHAPES
from repro.models.api import active_param_count, param_count
from repro.models.config import ModelConfig

ACT_BOUNDARY_TENSORS = 8


def fused_hbm_bytes(cfg: ModelConfig, shape_name: str, n_nodes: int,
                    tp: int = 16) -> float:
    shape = INPUT_SHAPES[shape_name]
    b = cfg.jdtype.itemsize
    P = param_count(cfg)
    p_dev = P * b / tp
    B_node = max(shape.global_batch // n_nodes, 1)
    S = shape.seq_len
    L = cfg.n_layers
    d = cfg.d_model

    if shape.mode == "decode":
        # one token: stream active weights once + cache read/write
        pa = active_param_count(cfg) * b / tp
        if cfg.family in ("ssm", "hybrid"):
            cache = L * B_node * cfg.ssm_nheads * cfg.ssm_state * cfg.ssm_headdim * 4
        elif cfg.mla:
            cache = L * B_node * S * (cfg.kv_lora_rank + cfg.qk_rope_dim) * b
        else:
            eff = min(S, cfg.sliding_window or S)
            cache = L * B_node * eff * cfg.n_kv_heads * cfg.hd * b * 2
        return pa + 2.0 * cache / tp

    T = B_node * S  # tokens per node
    passes = 1.0 if shape.mode == "prefill" else (3.0 if cfg.remat else 2.0)
    grad_traffic = 0.0 if shape.mode == "prefill" else 3.0 * p_dev  # g w+r, upd w
    params = passes * p_dev + grad_traffic

    acts_factor = 2.0 if shape.mode == "prefill" else (4.0 if cfg.remat else 3.0)
    acts = L * T * d * b * ACT_BOUNDARY_TENSORS * acts_factor / tp

    attn = 0.0
    if cfg.family not in ("ssm",) and cfg.attn_impl == "naive":
        eff = min(S, cfg.sliding_window or S)
        heads = cfg.n_heads
        n_attn = L if cfg.family != "hybrid" else max(cfg.n_layers // max(cfg.attn_every, 1), 1)
        per_layer = B_node * heads * S * eff * 4 * 2  # scores + probs, fp32
        mult = 2.0 if shape.mode == "prefill" else (6.0 if cfg.remat else 4.0)
        attn = n_attn * per_layer * mult / tp

    moe = 0.0
    if cfg.n_experts:
        n_moe = (cfg.n_layers - cfg.first_dense) // cfg.moe_every
        C = T * cfg.moe_top_k / cfg.n_experts * cfg.capacity_factor
        per_layer = cfg.n_experts * C * d * b * 4  # gather in + ffn out + scatter
        mult = 1.0 if shape.mode == "prefill" else (3.0 if cfg.remat else 2.0)
        moe = n_moe * per_layer * mult / tp

    ssm = 0.0
    if cfg.family in ("ssm", "hybrid"):
        nc = S // cfg.ssm_chunk
        states = B_node * nc * cfg.ssm_nheads * cfg.ssm_state * cfg.ssm_headdim * 4 * 2
        mult = 1.0 if shape.mode == "prefill" else (3.0 if cfg.remat else 2.0)
        ssm = L * states * mult / tp

    return params + acts + attn + moe + ssm
