"""Dry-run input specs: ShapeDtypeStruct stand-ins + NamedShardings for
every (architecture x input-shape) combination — weak-type-correct,
shardable, zero device allocation.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import InputShape, get_config
from repro.models.api import cache_specs, init_cache, param_specs
from repro.models.config import ModelConfig


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def plan_nodes(shape: InputShape, n_slots: int) -> Tuple[int, int]:
    """(n_nodes, batch_per_node): emulated-DL-node count for this input.

    The node axis carries DL nodes; if the global batch cannot fill every
    slot (long-context decode), the surplus slots replicate."""
    n_nodes = min(n_slots, shape.global_batch)
    assert shape.global_batch % n_nodes == 0
    return n_nodes, shape.global_batch // n_nodes


def node_spec(n_nodes: int, n_slots: int, node_axes: tuple):
    """Leading-axis PartitionSpec entry for the node-stacked dimension."""
    if n_nodes == n_slots:
        return node_axes if len(node_axes) > 1 else node_axes[0]
    if len(node_axes) > 1 and n_nodes == 1:
        return None
    if n_nodes == 1:
        return None
    # partial fill: shard over the first node axis only if it divides
    first = node_axes[0]
    return first if n_nodes % 1 == 0 else None


def batch_specs(cfg: ModelConfig, shape: InputShape, n_nodes: int, B: int) -> Tuple[Any, Any]:
    """(ShapeDtypeStruct pytree, PartitionSpec pytree) for the *stacked*
    train batch (leading node axis added by the caller's vmap)."""
    S = shape.seq_len
    tok = _sds((n_nodes, B, S), jnp.int32)
    if cfg.family == "vlm":
        batch = {
            "embeddings": _sds((n_nodes, B, S, cfg.d_model), cfg.jdtype),
            "positions": _sds((n_nodes, 3, B, S), jnp.int32),
            "labels": tok,
        }
    elif cfg.family == "encdec":
        batch = {
            "frames": _sds((n_nodes, B, cfg.enc_seq, cfg.d_model), cfg.jdtype),
            "tokens": tok,
            "labels": tok,
        }
    elif cfg.family == "cnn":
        batch = {
            "images": _sds((n_nodes, B, 32, 32, 3), cfg.jdtype),
            "labels": _sds((n_nodes, B), jnp.int32),
        }
    else:
        batch = {"tokens": tok, "labels": tok}
    return batch


def batch_partition_specs(batch, node_entry):
    return jax.tree_util.tree_map(
        lambda l: P(node_entry, *((None,) * (l.ndim - 1))), batch
    )


def stacked_param_specs(cfg: ModelConfig, node_entry):
    return param_specs(cfg, leading=(node_entry,))


def stacked_param_shapes(cfg: ModelConfig, n_nodes: int):
    shapes = jax.eval_shape(lambda k: __import__("repro.models.api", fromlist=["init_params"]).init_params(cfg, k), jax.random.key(0))
    return jax.tree_util.tree_map(lambda l: _sds((n_nodes, *l.shape), l.dtype), shapes)


def decode_specs(cfg: ModelConfig, shape: InputShape, n_nodes: int, B: int):
    """(cache_sds, tokens_sds, cache_pspecs) for one-token decode with a
    seq_len-deep cache."""
    max_len = shape.seq_len
    cache_shapes = jax.eval_shape(lambda: init_cache(cfg, B, max_len))
    cache_sds = jax.tree_util.tree_map(
        lambda l: _sds((n_nodes, *l.shape), l.dtype), cache_shapes
    )
    tokens = _sds((n_nodes, B, 1), jnp.int32)
    return cache_sds, tokens
