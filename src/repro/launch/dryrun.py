import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

DOC = """Multi-pod dry-run: lower + compile every (architecture x input-shape) on
the production mesh (16x16 single-pod, 2x16x16 multi-pod), print
memory_analysis / cost_analysis, and derive the roofline terms.

The two lines above MUST precede any jax-importing import — jax locks the
device count at first init.  Run one combination per process:

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun
"""

import argparse
import dataclasses
import json
import subprocess
import sys
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, INPUT_SHAPES, get_config, supports_shape
from repro.launch.mesh import make_production_mesh, n_node_slots, node_axes
from repro.launch.roofline import Roofline, parse_collective_bytes
from repro.launch.specs import batch_specs, plan_nodes
from repro.models.api import (
    decode_step,
    forward,
    init_cache,
    init_params,
    model_flops,
    param_specs,
)
from repro.optim import sgd
from repro.training.trainer import TrainConfig, make_train_step


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def sanitize_specs(shapes, specs, mesh):
    """Drop sharding on any dim the mesh axes don't divide (e.g. whisper's
    51865 vocab over model=16)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def fix(sds, spec):
        entries = []
        for dim, entry in zip(sds.shape, tuple(spec) + (None,) * (len(sds.shape) - len(spec))):
            if entry is None:
                entries.append(None)
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            total = int(np.prod([sizes[a] for a in axes]))
            entries.append(entry if dim % total == 0 else None)
        return P(*entries)

    return jax.tree_util.tree_map(fix, shapes, specs)


def _node_entry(n_nodes: int, naxes: tuple, mesh):
    if n_nodes == 1:
        return None
    sizes = [mesh.shape[a] for a in naxes]
    if n_nodes == int(np.prod(sizes)):
        return naxes if len(naxes) > 1 else naxes[0]
    if n_nodes == sizes[0]:
        return naxes[0]
    return None


def build(arch: str, shape_name: str, multi_pod: bool, mixing_impl: str = "roll",
          topology: str = "regular", overrides: Optional[dict] = None):
    """-> (jitted fn, args, meta) ready to .lower()."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    # unroll layer scans: XLA cost_analysis counts a while body once, so the
    # roofline would under-count flops and in-loop collectives by ~n_layers.
    ov = dict(overrides or {})
    gossip_budget = ov.pop("gossip_budget", 0.1)
    cfg = get_config(arch).replace(scan_unroll=True, **ov)
    shape = INPUT_SHAPES[shape_name]
    naxes = node_axes(mesh)
    slots = n_node_slots(mesh)
    n_nodes, B = plan_nodes(shape, slots)
    nentry = _node_entry(n_nodes, naxes, mesh)

    pshapes = jax.eval_shape(lambda k: init_params(cfg, k), jax.random.key(0))
    stacked_pshapes = jax.tree_util.tree_map(
        lambda l: _sds((n_nodes, *l.shape), l.dtype), pshapes
    )
    pspecs = sanitize_specs(stacked_pshapes, param_specs(cfg, leading=(nentry,)), mesh)
    pshard = jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), pspecs)

    def shard_like(sds_tree, node_first=True):
        return jax.tree_util.tree_map(
            lambda l: NamedSharding(mesh, P(nentry, *((None,) * (l.ndim - 1)))), sds_tree
        )

    meta = dict(arch=arch, shape=shape_name, mode=shape.mode,
                mesh="2x16x16" if multi_pod else "16x16",
                n_nodes=n_nodes, batch_per_node=B, n_chips=int(mesh.size))

    if shape.mode == "train":
        tokens_per_step = shape.global_batch * shape.seq_len if cfg.family not in ("cnn",) else shape.global_batch
        meta["model_flops"] = model_flops(cfg, tokens_per_step, "train")
        opt = sgd(1e-2)
        topo = topology if n_nodes > 5 else "fully"
        tc = TrainConfig(n_nodes=n_nodes, topology=topo, degree=5,
                         mixing_impl=mixing_impl, budget=gossip_budget)
        step = make_train_step(cfg, opt, tc, mesh=mesh, node_axes=naxes, pspecs=pspecs)
        batch = batch_specs(cfg, shape, n_nodes, B)
        opt_sds = jax.eval_shape(jax.vmap(opt.init), stacked_pshapes)
        opt_shard = jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s),
                                           sanitize_specs(opt_sds, jax.tree_util.tree_map(lambda l: P(*((None,) * l.ndim)), opt_sds), mesh)) if jax.tree_util.tree_leaves(opt_sds) else opt_sds
        args = (stacked_pshapes, opt_sds, batch)
        in_sh = (pshard, opt_shard, shard_like(batch))
        fn = jax.jit(step, in_shardings=in_sh,
                     out_shardings=(pshard, opt_shard, NamedSharding(mesh, P())))
        return fn, args, meta

    if shape.mode == "prefill":
        meta["model_flops"] = model_flops(cfg, shape.global_batch * shape.seq_len, "infer")

        def prefill(params, batch):
            def one(p, b):
                logits, _ = forward(p, cfg, b)
                return logits[:, -1, :]  # next-token logits only

            return jax.vmap(one)(params, batch)

        batch = batch_specs(cfg, shape, n_nodes, B)
        batch.pop("labels")
        args = (stacked_pshapes, batch)
        fn = jax.jit(prefill, in_shardings=(pshard, shard_like(batch)))
        return fn, args, meta

    # decode
    meta["model_flops"] = model_flops(cfg, shape.global_batch, "infer")
    cache_shapes = jax.eval_shape(lambda: init_cache(cfg, B, shape.seq_len))
    stacked_cache = jax.tree_util.tree_map(
        lambda l: _sds((n_nodes, *l.shape), l.dtype), cache_shapes
    )

    def cache_spec(l):
        # shard the trailing dim over 'model' when divisible (kv-head*hd,
        # MLA latent, SSM channels), node axis in front.
        entries = [nentry] + [None] * (l.ndim - 1)
        if l.shape[-1] % mesh.shape["model"] == 0 and l.shape[-1] >= mesh.shape["model"]:
            entries[-1] = "model"
        return NamedSharding(mesh, P(*entries))

    cache_shard = jax.tree_util.tree_map(cache_spec, stacked_cache)
    tokens = _sds((n_nodes, B, 1), jnp.int32)
    index = _sds((), jnp.int32)

    def serve(params, cache, toks, idx):
        def one(p, c, t):
            return decode_step(p, cfg, c, t, idx)

        return jax.vmap(one)(params, cache, toks)

    args = (stacked_pshapes, stacked_cache, tokens, index)
    in_sh = (pshard, cache_shard, shard_like(tokens), NamedSharding(mesh, P()))
    fn = jax.jit(serve, in_shardings=in_sh)
    return fn, args, meta


def run_one(arch: str, shape_name: str, multi_pod: bool, mixing_impl: str = "roll",
            topology: str = "regular", verbose: bool = True,
            overrides: Optional[dict] = None) -> dict:
    ok, reason = supports_shape(arch, shape_name)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "skipped", "reason": reason}
    t0 = time.time()
    fn, args, meta = build(arch, shape_name, multi_pod, mixing_impl, topology, overrides)
    meta["overrides"] = {**(overrides or {}), "mixing_impl": mixing_impl,
                         "topology": topology}
    lowered = fn.lower(*args)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = parse_collective_bytes(hlo)
    rec = dict(meta)
    rec.update(
        status="ok",
        lower_s=round(t_lower, 1),
        compile_s=round(t_compile, 1),
        flops_dev=float(cost.get("flops", 0.0)),
        hbm_bytes_dev=float(cost.get("bytes accessed", 0.0)),
        coll=coll,
        memory=dict(
            argument_bytes=getattr(mem, "argument_size_in_bytes", None),
            output_bytes=getattr(mem, "output_size_in_bytes", None),
            temp_bytes=getattr(mem, "temp_size_in_bytes", None),
            generated_code_bytes=getattr(mem, "generated_code_size_in_bytes", None),
        ),
    )
    r = Roofline(
        arch=arch, shape=shape_name, mesh=rec["mesh"],
        flops_dev=rec["flops_dev"], hbm_bytes_dev=rec["hbm_bytes_dev"],
        coll_bytes_dev=float(coll["total"]), coll_breakdown=coll,
        model_flops_total=meta["model_flops"], n_chips=meta["n_chips"],
    )
    rec["roofline"] = r.to_dict()
    # complementary fused-HBM memory bound (see launch/analytic.py)
    from repro.launch.analytic import fused_hbm_bytes
    from repro.launch.mesh import HBM_BW

    cfg_ov = {k: v for k, v in (overrides or {}).items() if k != "gossip_budget"}
    cfg_eff = get_config(arch).replace(**cfg_ov)
    fused = fused_hbm_bytes(cfg_eff, shape_name, meta["n_nodes"])
    rec["roofline"]["hbm_bytes_fused"] = fused
    rec["roofline"]["t_memory_fused"] = fused / HBM_BW
    if verbose:
        print(f"[dryrun] {r.row()}")
        print(f"         mem {rec['memory']}  lower {t_lower:.0f}s compile {t_compile:.0f}s")
        print(f"         collectives: " + ", ".join(
            f"{k}={v/1e6:.1f}MB" for k, v in coll.items() if k not in ("count", "total") and v))
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS)
    ap.add_argument("--shape", choices=list(INPUT_SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--mixing", default="roll",
                    choices=["roll", "shard_map", "dense", "sparse", "quant",
                             "sparse+quant"])
    ap.add_argument("--topology", default="regular",
                    choices=["ring", "regular", "fully", "dense"])
    ap.add_argument("--all", action="store_true", help="sweep every combo in subprocesses")
    ap.add_argument("--out", default=None, help="JSON output path (or dir for --all)")
    ap.add_argument("--attn", default=None, choices=["naive", "chunked"],
                    help="attention impl override (perf iteration)")
    ap.add_argument("--attn-chunk", type=int, default=None)
    ap.add_argument("--remat", default=None, choices=["on", "off"])
    ap.add_argument("--remat-policy", default=None, choices=["full", "save_comm"])
    ap.add_argument("--gossip-budget", type=float, default=None)
    args = ap.parse_args(argv)

    if args.all:
        sweep(args.out or "results/dryrun", multi_pod=args.multi_pod)
        return

    assert args.arch and args.shape, "--arch and --shape (or --all)"
    overrides = {}
    if args.attn:
        overrides["attn_impl"] = args.attn
    if args.attn_chunk:
        overrides["attn_chunk"] = args.attn_chunk
    if args.remat:
        overrides["remat"] = args.remat == "on"
    if args.remat_policy:
        overrides["remat_policy"] = args.remat_policy
    if args.gossip_budget is not None:
        overrides["gossip_budget"] = args.gossip_budget
    rec = run_one(args.arch, args.shape, args.multi_pod, args.mixing, args.topology,
                  overrides=overrides)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rec, f, indent=1)


def sweep(out_dir: str, multi_pod: bool = False, jobs: int = 4):
    """Run every (arch x shape) in isolated subprocesses (device-count and
    memory isolation); collect JSONs."""
    import concurrent.futures as cf
    import os as _os

    _os.makedirs(out_dir, exist_ok=True)
    combos = [(a, s) for a in ARCHS if a != "gn-lenet" for s in INPUT_SHAPES] + [
        ("gn-lenet", "train_4k")
    ]

    def run(combo):
        a, s = combo
        tag = f"{a}__{s}__{'mp' if multi_pod else 'sp'}"
        out = _os.path.join(out_dir, tag + ".json")
        if _os.path.exists(out):
            return tag, "cached"
        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", a, "--shape", s,
               "--out", out]
        if multi_pod:
            cmd.append("--multi-pod")
        p = subprocess.run(cmd, capture_output=True, text=True, timeout=3600)
        if p.returncode != 0:
            with open(out + ".err", "w") as f:
                f.write(p.stdout + "\n" + p.stderr)
            return tag, "FAILED"
        return tag, "ok"

    with cf.ThreadPoolExecutor(jobs) as ex:
        for tag, status in ex.map(run, combos):
            print(f"[sweep] {tag}: {status}", flush=True)


if __name__ == "__main__":
    main()
