"""Production mesh: 16x16 (256 chips / pod, TPU v5e) single-pod, plus a
2x16x16 multi-pod variant.  A function — importing this module never
touches jax device state (device count is locked at first jax init)."""
from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_node_mesh(n_devices: int = 0, axis: str = "nodes"):
    """1-D mesh over the first ``n_devices`` local devices (all, if 0) with
    a single node axis — what ``RoundEngine(shard_devices=...)`` shards the
    emulated node dimension over.  On CPU,
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` provides the
    emulated devices; on TPU this is the flat view of the pod slice."""
    devs = jax.devices()
    n = n_devices or len(devs)
    if len(devs) < n:
        raise ValueError(
            f"mesh wants {n} devices but only {len(devs)} are visible "
            "(CPU emulation: set XLA_FLAGS=--xla_force_host_platform_device_count)"
        )
    return jax.sharding.Mesh(np.asarray(devs[:n]), (axis,))


def node_axes(mesh) -> tuple:
    """Mesh axes that form the DL node dimension (everything except TP)."""
    return tuple(a for a in mesh.axis_names if a != "model")


def n_node_slots(mesh) -> int:
    n = 1
    for a in node_axes(mesh):
        n *= mesh.shape[a]
    return n


# TPU v5e constants for the roofline model.
PEAK_FLOPS_BF16 = 197e12      # per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link
