"""Roofline analysis from the compiled dry-run artifact.

``cost_analysis()`` gives the SPMD (per-device) module's FLOPs and HBM
bytes; collective bytes are NOT in cost_analysis, so we parse the HLO text:
build an instruction -> result-bytes map, then sum *operand* bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.

Approximation note (documented, consistent across perf iterations):
operand bytes ~ bytes each device injects into the interconnect per op
(exact for collective-permute & all-to-all; all-reduce moves ~2x(K-1)/K of
operand; all-gather receives (K-1)x operand).
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, Optional

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\(?[^=]+?\)?)\s+([\w\-]+)\(")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(shape_str: str) -> int:
    """Bytes of an HLO shape string (handles tuples)."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective-type operand bytes summed over the module."""
    result_bytes: Dict[str, int] = {}
    # pass 1: result sizes of all instructions
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if m:
            name, shape_str, _op = m.groups()
            result_bytes[name] = _shape_bytes(shape_str)
    out = {c: 0 for c in _COLLECTIVES}
    out["count"] = 0
    # pass 2: operand bytes of collectives
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, shape_str, op = m.groups()
        opc = next((c for c in _COLLECTIVES if op.startswith(c)), None)
        if opc is None:
            continue
        # operands: %names inside the first (...) group
        args = line.split("(", 1)[1]
        depth, end = 1, 0
        for i, ch in enumerate(args):
            depth += ch == "("
            depth -= ch == ")"
            if depth == 0:
                end = i
                break
        operand_names = re.findall(r"%([\w\.\-]+)", args[:end])
        b = sum(result_bytes.get(n, 0) for n in operand_names)
        if b == 0:  # fused formatting: fall back to result size
            b = _shape_bytes(shape_str)
        out[opc] += b
        out["count"] += 1
    out["total"] = sum(out[c] for c in _COLLECTIVES)
    return out


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    flops_dev: float          # per-device HLO flops
    hbm_bytes_dev: float      # per-device HBM traffic
    coll_bytes_dev: float     # per-device collective operand bytes
    coll_breakdown: Dict[str, int]
    model_flops_total: float  # 6·N·D (train) / 2·N·D (inference)
    n_chips: int
    peak_flops: float = PEAK_FLOPS_BF16
    hbm_bw: float = HBM_BW
    ici_bw: float = ICI_BW

    @property
    def t_compute(self) -> float:
        return self.flops_dev / self.peak_flops

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes_dev / self.hbm_bw

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_dev / self.ici_bw

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / total HLO flops (remat/redundancy waste detector)."""
        total_hlo = self.flops_dev * self.n_chips
        return self.model_flops_total / total_hlo if total_hlo else float("nan")

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d.update(
            t_compute=self.t_compute, t_memory=self.t_memory,
            t_collective=self.t_collective, bottleneck=self.bottleneck,
            useful_flops_ratio=self.useful_flops_ratio,
        )
        return d

    def suggestion(self) -> str:
        """One sentence: what would move the dominant term down."""
        b = self.bottleneck
        decode = "decode" in self.shape or "500k" in self.shape
        if b == "collective":
            return ("compress the wire: sparse/int8 gossip for the permutes, "
                    "chunked attention to stop score-tensor reshard ARs (§Perf)")
        if b == "memory":
            if decode:
                return ("decode is weight/cache streaming-bound: batch more "
                        "requests per replica; MLA/SSM-style cache compression "
                        "shrinks the streamed bytes")
            return ("chunked/flash attention deletes the O(S²) score HBM "
                    "traffic that dominates the unfused bound (§Perf pair 2); "
                    "remaining gap is fusion (see fused bound)")
        return ("at the compute roofline: raise arithmetic intensity "
                "(larger per-node batch) or add chips")

    def row(self) -> str:
        return (
            f"{self.arch:26s} {self.shape:12s} {self.mesh:9s} "
            f"C {self.t_compute*1e3:9.3f}ms  M {self.t_memory*1e3:9.3f}ms  "
            f"X {self.t_collective*1e3:9.3f}ms  -> {self.bottleneck:10s} "
            f"useful {self.useful_flops_ratio:6.2%}"
        )
