"""Pallas TPU kernel: Mamba2 SSD intra-chunk compute. [arXiv:2405.21060]

Per (batch, chunk) grid cell, with L = chunk length, N = state dim,
P = head dim, H = heads:

    scores[i,j,h] = (C_i . B_j) * exp(cum_i[h] - cum_j[h]) * tril
    y_intra[i,h]  = sum_j scores[i,j,h] * xdt[j,h]          (MXU matmuls)
    state[h]      = sum_j exp(cum_L - cum_j)[h] B_j (x) xdt[j,h]
    decay_out[h]  = exp(cum_L[h])

TPU adaptation of the paper-family CUDA kernels: L and N are chosen as
multiples of 128 so C.B^T and scores@xdt land on the MXU; the decay matrix
is built in VMEM from the cumsum vector (never touches HBM); heads are a
grid dimension so each cell's working set (L*N + L*L + L*P fp32 ~ 200 KB)
fits VMEM comfortably.

The inter-chunk recurrence (tiny, bandwidth-trivial) stays in jnp
(``lax.scan`` in ssm.py / ops.ssd_scan).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(xdt_ref, b_ref, c_ref, cum_ref, y_ref, st_ref, dec_ref):
    # Blocks carry a leading 1 (grid cell): xdt (1,L,P), b/c (1,L,N),
    # cum (1,L,1) — one (batch*chunk, head) cell.
    xdt = xdt_ref[0].astype(jnp.float32)   # (L, P)
    B = b_ref[0].astype(jnp.float32)       # (L, N)
    C = c_ref[0].astype(jnp.float32)       # (L, N)
    cum = cum_ref[0].astype(jnp.float32)[:, 0]  # (L,)
    L = xdt.shape[0]

    cb = jnp.dot(C, B.T, preferred_element_type=jnp.float32)  # (L, L) MXU
    diff = cum[:, None] - cum[None, :]
    tri = jnp.tril(jnp.ones((L, L), jnp.bool_))
    scores = jnp.where(tri, cb * jnp.exp(diff), 0.0)
    y_ref[0] = jnp.dot(scores, xdt, preferred_element_type=jnp.float32).astype(
        y_ref.dtype
    )  # (L, P) MXU

    decay_end = jnp.exp(cum[-1] - cum)  # (L,)
    st_ref[0] = jnp.dot(
        (B * decay_end[:, None]).T, xdt, preferred_element_type=jnp.float32
    ).astype(st_ref.dtype)  # (N, P) MXU
    dec_ref[0] = jnp.full((1, 1), jnp.exp(cum[-1]), dec_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_chunk(xdt, Bc, Cc, cum, *, interpret: bool = False):
    """Batched intra-chunk SSD.

    xdt: (G, L, H, P) fp32 where G = batch*chunks; Bc/Cc: (G, L, N);
    cum: (G, L, H).  Returns (y (G, L, H, P), state (G, H, N, P),
    decay (G, H))."""
    G, L, H, P = xdt.shape
    N = Bc.shape[-1]
    # move heads next to G for the grid: (G, H, L, ...)
    xdt_t = jnp.moveaxis(xdt, 2, 1).reshape(G * H, L, P)
    cum_t = jnp.moveaxis(cum, 2, 1).reshape(G * H, L, 1)
    # B/C shared across heads -> index_map repeats per head
    grid = (G, H)
    y, st, dec = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, L, P), lambda g, h, H=H: (g * H + h, 0, 0)),
            pl.BlockSpec((1, L, N), lambda g, h: (g, 0, 0)),
            pl.BlockSpec((1, L, N), lambda g, h: (g, 0, 0)),
            pl.BlockSpec((1, L, 1), lambda g, h, H=H: (g * H + h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, L, P), lambda g, h, H=H: (g * H + h, 0, 0)),
            pl.BlockSpec((1, N, P), lambda g, h, H=H: (g * H + h, 0, 0)),
            pl.BlockSpec((1, 1, 1), lambda g, h, H=H: (g * H + h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((G * H, L, P), jnp.float32),
            jax.ShapeDtypeStruct((G * H, N, P), jnp.float32),
            jax.ShapeDtypeStruct((G * H, 1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(xdt_t, Bc, Cc, cum_t)
    y = jnp.moveaxis(y.reshape(G, H, L, P), 1, 2)
    st = st.reshape(G, H, N, P)
    dec = dec.reshape(G, H)
    return y, st, dec
