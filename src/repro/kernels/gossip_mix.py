"""Pallas TPU kernel: fused gossip aggregation.

out[m] = sum_k w_k * neighbors[k, m] — the Metropolis-Hastings weighted
merge of K received neighbor models plus self.  Fusing the K-way weighted
sum reads each operand exactly once from HBM (one pass) instead of K
accumulate passes; the op is purely memory-bound so this is the whole win.

Tiling: flat parameter vector padded to (K, M), blocks (K, BN) in VMEM —
K is small (degree+1 <= ~10), BN = 64k floats -> ~2.5 MB/block fp32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 65536


def _kernel(w_ref, x_ref, o_ref):
    # x_ref: (K, BN); w_ref: (K, 1) in SMEM-ish VMEM; o_ref: (BN,)
    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)  # (K, 1)
    o_ref[...] = jnp.sum(x * w, axis=0).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "block_n"))
def gossip_mix(neighbors, weights, *, interpret: bool = False, block_n: int = BLOCK_N):
    """neighbors: (K, M) any float dtype; weights: (K,) -> (M,)."""
    K, M = neighbors.shape
    pad = (-M) % block_n
    x = jnp.pad(neighbors, ((0, 0), (0, pad)))
    grid = (x.shape[1] // block_n,)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((K, 1), lambda i: (0, 0)),
            pl.BlockSpec((K, block_n), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((x.shape[1],), neighbors.dtype),
        interpret=interpret,
    )(weights[:, None], x)
    return out[:M]


def _kernel_nodes(w_ref, x_ref, o_ref):
    # x_ref: (1, K, BN); w_ref: (1, K, 1); o_ref: (1, BN)
    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    o_ref[...] = jnp.sum(x * w, axis=1).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "block_n"))
def gossip_mix_nodes(neighbors, weights, *, interpret: bool = False,
                     block_n: int = BLOCK_N):
    """Node-batched fused gossip merge — the ``mix_sparse`` backend.

    neighbors: (N, K, M) — for each of N receivers, its K = 1 + degree
    gathered operand rows (self first); weights: (N, K) -> (N, M).
    Grid (N, M/BN): each program fuses one receiver's K-way weighted sum
    over one parameter block, reading every operand once from HBM.  The
    param block adapts down to the (128-aligned) vector length so small
    models don't pad to the full 64k block.
    """
    N, K, M = neighbors.shape
    bn = min(block_n, -(-M // 128) * 128)
    pad = (-M) % bn
    x = jnp.pad(neighbors, ((0, 0), (0, 0), (0, pad)))
    grid = (N, x.shape[2] // bn)
    out = pl.pallas_call(
        _kernel_nodes,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, K, 1), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, K, bn), lambda b, i: (b, 0, i)),
        ],
        out_specs=pl.BlockSpec((1, bn), lambda b, i: (b, i)),
        out_shape=jax.ShapeDtypeStruct((N, x.shape[2]), neighbors.dtype),
        interpret=interpret,
    )(weights[:, :, None], x)
    return out[:, :M]
