"""Pallas TPU kernel: int8 symmetric quantization codec (Compression
module hot path — gossip messages are quantized before hitting the wire).

Two passes over the row: (1) absmax reduce -> scale, (2) scale+round+clip.
Fused here into one kernel per row-block: row fits VMEM (rows are
parameter-shard slices, <= 128k floats each), so one HBM read produces
both scale and codes; stochastic rounding takes pre-drawn uniforms (keeps
the kernel bit-exactly testable against the jnp oracle).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _q_kernel(x_ref, o_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)  # (1, C)
    scale = jnp.maximum(jnp.max(jnp.abs(x)) / 127.0, 1e-12)
    y = jnp.round(x / scale)
    o_ref[...] = jnp.clip(y, -127, 127).astype(jnp.int8)
    s_ref[...] = jnp.full(s_ref.shape, scale, jnp.float32)


def _q_kernel_sr(x_ref, n_ref, o_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x)) / 127.0, 1e-12)
    y = jnp.floor(x / scale + n_ref[...].astype(jnp.float32))
    o_ref[...] = jnp.clip(y, -127, 127).astype(jnp.int8)
    s_ref[...] = jnp.full(s_ref.shape, scale, jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def quantize(x, noise=None, *, interpret: bool = False):
    """x: (R, C) -> (codes (R, C) int8, scale (R, 1) fp32). Row-blocked."""
    R, C = x.shape
    if noise is None:
        return pl.pallas_call(
            _q_kernel,
            grid=(R,),
            in_specs=[pl.BlockSpec((1, C), lambda i: (i, 0))],
            out_specs=[
                pl.BlockSpec((1, C), lambda i: (i, 0)),
                pl.BlockSpec((1, 1), lambda i: (i, 0)),
            ],
            out_shape=[
                jax.ShapeDtypeStruct((R, C), jnp.int8),
                jax.ShapeDtypeStruct((R, 1), jnp.float32),
            ],
            interpret=interpret,
        )(x)
    return pl.pallas_call(
        _q_kernel_sr,
        grid=(R,),
        in_specs=[
            pl.BlockSpec((1, C), lambda i: (i, 0)),
            pl.BlockSpec((1, C), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, C), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R, C), jnp.int8),
            jax.ShapeDtypeStruct((R, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x, noise)


def _dq_kernel(c_ref, s_ref, o_ref):
    o_ref[...] = c_ref[...].astype(jnp.float32) * s_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def dequantize(codes, scale, *, interpret: bool = False):
    R, C = codes.shape
    return pl.pallas_call(
        _dq_kernel,
        grid=(R,),
        in_specs=[
            pl.BlockSpec((1, C), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, C), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, C), jnp.float32),
        interpret=interpret,
    )(codes, scale)
