"""Pallas TPU kernel: fused payload-indexed gossip merge.

The compressed-sharing hot path: each receiver n holds its own row x[n]
(P,) and K = 1 + degree payload operands (self first, then gathered
neighbor payloads) of k coordinates each — ``idx[n, s]`` (k,) int32 and
``val[n, s]`` (k,) fp32.  DecentralizePy's missing-coordinate rule says a
coordinate not present in a neighbor's payload falls back to the
receiver's own value, which reduces to a sparse correction:

    out[n] = x[n] + sum_s w[n, s] * scatter(idx[n, s], val[n, s] - x[n][idx])

This generalizes ``gossip_mix.gossip_mix_nodes`` (dense (N, K, P) operand
stacks) to indexed payloads: O(N·K·k) work instead of O(N·K·P), reading
x once per P-block.  TPU has no fast VMEM scatter, so the kernel applies
payload contributions with a broadcast-compare accumulate (idx == column
one-hot, a VPU-friendly (BN, K·k) outer comparison per block) — exact for
duplicate indices across operands because contributions sum.  Right for
small K·k (sparsified budgets); for K·k approaching P the dense
``gossip_mix_nodes`` form wins.  Interpret mode on CPU; tested against
``kernels.ref.payload_mix_nodes_ref`` and the dense-mask oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 65536


def _kernel(x_ref, idx_ref, val_ref, w_ref, o_ref, *, block_n: int):
    # x: (1, BN) at column block j; idx/val: (1, K, k); w: (1, K, 1)
    j = pl.program_id(1)
    x = x_ref[...].astype(jnp.float32)                   # (1, BN)
    idx = idx_ref[...]                                   # (1, K, k)
    val = val_ref[...].astype(jnp.float32)               # (1, K, k)
    w = w_ref[...].astype(jnp.float32)                   # (1, K, 1)
    K, k = idx.shape[1], idx.shape[2]
    cols = jax.lax.broadcasted_iota(jnp.int32, (1, block_n), 1) + j * block_n
    flat_idx = idx.reshape(1, K * k)                     # (1, K*k)
    flat_val = val.reshape(1, K * k)
    flat_w = jnp.broadcast_to(w, (1, K, k)).reshape(1, K * k)
    # one-hot scatter: hit[e, c] = payload entry e lands on column c
    hit = (flat_idx[0][:, None] == cols[0][None, :]).astype(jnp.float32)  # (K*k, BN)
    own = jnp.sum(hit * x[0][None, :], axis=1)           # x[idx] for in-block hits
    contrib = flat_w[0] * (flat_val[0] - own)            # (K*k,)
    # entries whose idx falls outside this block contribute nothing: their
    # hit row is all zero, so the (K*k, BN) weighted sum drops them.
    delta = jnp.sum(hit * contrib[:, None], axis=0)      # (BN,)
    o_ref[...] = (x + delta[None, :]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "block_n"))
def payload_mix_nodes(x, idx, val, w, *, interpret: bool = False,
                      block_n: int = BLOCK_N):
    """x: (N, P); idx: (N, K, k) int32 in [0, P); val: (N, K, k); w: (N, K)
    -> (N, P).  Grid (N, P/BN); the block adapts down to the (128-aligned)
    row length so small models don't pad to the full 64k block."""
    N, P = x.shape
    _, K, k = idx.shape
    bn = min(block_n, -(-P // 128) * 128)
    pad = (-P) % bn
    xp = jnp.pad(x, ((0, 0), (0, pad)))
    grid = (N, xp.shape[1] // bn)
    out = pl.pallas_call(
        functools.partial(_kernel, block_n=bn),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bn), lambda b, j: (b, j)),
            pl.BlockSpec((1, K, k), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, K, k), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, K, 1), lambda b, j: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bn), lambda b, j: (b, j)),
        out_shape=jax.ShapeDtypeStruct((N, xp.shape[1]), x.dtype),
        interpret=interpret,
    )(xp, idx, val, w[:, :, None])
    return out[:, :P]
