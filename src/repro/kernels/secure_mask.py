"""Pallas TPU kernel: fused secure-aggregation mask apply.

A sender adds one cancellable mask per co-neighbor pair before the message
leaves the chip: out = x + sum_k sign_k * U(bits_k), U mapping uint32 PRF
bits to uniform [-b, b).  Fusing the K mask materializations + adds into
one pass avoids K HBM round-trips of the full parameter vector.  Bits are
produced outside (threefry) so the kernel is bit-exact against the oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 65536


def _kernel(bound_ref, x_ref, bits_ref, signs_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)          # (BN,)
    bits = bits_ref[...]                        # (K, BN) uint32
    signs = signs_ref[...].astype(jnp.float32)  # (K, 1)
    bound = bound_ref[0]
    u01 = (bits >> 8).astype(jnp.float32) * (1.0 / (1 << 24))
    masks = (u01 * 2.0 - 1.0) * bound
    o_ref[...] = (x + jnp.sum(masks * signs, axis=0)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "block_n"))
def secure_mask_apply(x, bits, signs, bound: float = 1.0, *,
                      interpret: bool = False, block_n: int = BLOCK_N):
    """x: (M,); bits: (K, M) uint32; signs: (K,) ±1 -> masked x (M,)."""
    K, M = bits.shape
    pad = (-M) % block_n
    xp = jnp.pad(x, (0, pad))
    bp = jnp.pad(bits, ((0, 0), (0, pad)))
    grid = (xp.shape[0] // block_n,)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((K, block_n), lambda i: (0, i)),
            pl.BlockSpec((K, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0],), x.dtype),
        interpret=interpret,
    )(jnp.asarray(bound, jnp.float32)[None], xp, bp, signs[:, None])
    return out[:M]


def _kernel_nodes(bound_ref, x_ref, bits_ref, signs_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)          # (1, BN)
    bits = bits_ref[...]                        # (1, K, BN) uint32
    signs = signs_ref[...].astype(jnp.float32)  # (1, K, 1)
    bound = bound_ref[0]
    u01 = (bits >> 8).astype(jnp.float32) * (1.0 / (1 << 24))
    masks = (u01 * 2.0 - 1.0) * bound
    o_ref[...] = (x + jnp.sum(masks * signs, axis=1)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "block_n"))
def secure_mask_apply_nodes(x, bits, signs, bound: float = 1.0, *,
                            interpret: bool = False, block_n: int = BLOCK_N):
    """Message-batched fused mask apply — one call masks every message of a
    secure-aggregation round.

    x: (B, M) messages; bits: (B, K, M) uint32 per-pair PRF bits; signs:
    (B, K) in {-1, 0, +1} (0 = inactive pair slot) -> (B, M).  Grid
    (B, M/BN); the block adapts down to the (128-aligned) vector length.
    """
    B, K, M = bits.shape
    bn = min(block_n, -(-M // 128) * 128)
    pad = (-M) % bn
    xp = jnp.pad(x, ((0, 0), (0, pad)))
    bp = jnp.pad(bits, ((0, 0), (0, 0), (0, pad)))
    grid = (B, xp.shape[1] // bn)
    out = pl.pallas_call(
        _kernel_nodes,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b, i: (0,)),
            pl.BlockSpec((1, bn), lambda b, i: (b, i)),
            pl.BlockSpec((1, K, bn), lambda b, i: (b, 0, i)),
            pl.BlockSpec((1, K, 1), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bn), lambda b, i: (b, i)),
        out_shape=jax.ShapeDtypeStruct((B, xp.shape[1]), x.dtype),
        interpret=interpret,
    )(jnp.asarray(bound, jnp.float32)[None], xp, bp, signs[:, :, None])
    return out[:, :M]
