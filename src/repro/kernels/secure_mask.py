"""Pallas TPU kernels: fused secure-aggregation mask apply.

A sender adds one cancellable mask per co-neighbor pair before the message
leaves the chip: out = x + sum_k sign_k * U(bits_k), U mapping uint32 PRF
bits to uniform [-b, b).  Fusing the K mask materializations + adds into
one pass avoids K HBM round-trips of the full parameter vector.

Two bit sources:

* ``secure_mask_apply`` / ``secure_mask_apply_nodes`` — bits produced
  outside (threefry) and staged as (…, K, M) uint32 tensors: simple, but
  the caller pays O(B·K·M) HBM for the bit stacks.
* ``secure_mask_apply_nodes_keyed`` — the fused form: the caller passes
  only the (B, K, 2) uint32 *pair keys* and the kernel runs the
  Threefry-2x32 counter expansion in-body per block, bit-identical to
  ``jax.random.bits(key, (M,))`` (asserted against
  ``kernels.ref.counter_bits_ref``).  Peak staging for a secure round
  drops from O(N·d·P) bits to O(N·d) keys.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 65536


def _kernel(bound_ref, x_ref, bits_ref, signs_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)          # (BN,)
    bits = bits_ref[...]                        # (K, BN) uint32
    signs = signs_ref[...].astype(jnp.float32)  # (K, 1)
    bound = bound_ref[0]
    u01 = (bits >> 8).astype(jnp.float32) * (1.0 / (1 << 24))
    masks = (u01 * 2.0 - 1.0) * bound
    o_ref[...] = (x + jnp.sum(masks * signs, axis=0)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "block_n"))
def secure_mask_apply(x, bits, signs, bound: float = 1.0, *,
                      interpret: bool = False, block_n: int = BLOCK_N):
    """x: (M,); bits: (K, M) uint32; signs: (K,) ±1 -> masked x (M,)."""
    K, M = bits.shape
    pad = (-M) % block_n
    xp = jnp.pad(x, (0, pad))
    bp = jnp.pad(bits, ((0, 0), (0, pad)))
    grid = (xp.shape[0] // block_n,)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((K, block_n), lambda i: (0, i)),
            pl.BlockSpec((K, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0],), x.dtype),
        interpret=interpret,
    )(jnp.asarray(bound, jnp.float32)[None], xp, bp, signs[:, None])
    return out[:M]


def _kernel_nodes(bound_ref, x_ref, bits_ref, signs_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)          # (1, BN)
    bits = bits_ref[...]                        # (1, K, BN) uint32
    signs = signs_ref[...].astype(jnp.float32)  # (1, K, 1)
    bound = bound_ref[0]
    u01 = (bits >> 8).astype(jnp.float32) * (1.0 / (1 << 24))
    masks = (u01 * 2.0 - 1.0) * bound
    o_ref[...] = (x + jnp.sum(masks * signs, axis=1)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "block_n"))
def secure_mask_apply_nodes(x, bits, signs, bound: float = 1.0, *,
                            interpret: bool = False, block_n: int = BLOCK_N):
    """Message-batched fused mask apply — one call masks every message of a
    secure-aggregation round.

    x: (B, M) messages; bits: (B, K, M) uint32 per-pair PRF bits; signs:
    (B, K) in {-1, 0, +1} (0 = inactive pair slot) -> (B, M).  Grid
    (B, M/BN); the block adapts down to the (128-aligned) vector length.
    """
    B, K, M = bits.shape
    bn = min(block_n, -(-M // 128) * 128)
    pad = (-M) % bn
    xp = jnp.pad(x, ((0, 0), (0, pad)))
    bp = jnp.pad(bits, ((0, 0), (0, 0), (0, pad)))
    grid = (B, xp.shape[1] // bn)
    out = pl.pallas_call(
        _kernel_nodes,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b, i: (0,)),
            pl.BlockSpec((1, bn), lambda b, i: (b, i)),
            pl.BlockSpec((1, K, bn), lambda b, i: (b, 0, i)),
            pl.BlockSpec((1, K, 1), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bn), lambda b, i: (b, i)),
        out_shape=jax.ShapeDtypeStruct((B, xp.shape[1]), x.dtype),
        interpret=interpret,
    )(jnp.asarray(bound, jnp.float32)[None], xp, bp, signs[:, :, None])
    return out[:, :M]


def _threefry2x32(k1, k2, x0, x1):
    """In-kernel Threefry-2x32: uint32 adds/rotates/xors only (VPU ops).
    Must stay bit-identical to kernels.ref.threefry2x32_ref."""
    def rotl(x, d):
        return (x << jnp.uint32(d)) | (x >> jnp.uint32(32 - d))

    ks2 = k1 ^ k2 ^ jnp.uint32(0x1BD11BDA)
    ks = (k1, k2, ks2)
    rots = ((13, 15, 26, 6), (17, 29, 16, 24))
    x0 = x0 + k1
    x1 = x1 + k2
    for i in range(5):
        for r in rots[i % 2]:
            x0 = x0 + x1
            x1 = rotl(x1, r) ^ x0
        x0 = x0 + ks[(i + 1) % 3]
        x1 = x1 + ks[(i + 2) % 3] + jnp.uint32(i + 1)
    return x0, x1


def _kernel_nodes_keyed(bound_ref, x_ref, keys_ref, signs_ref, o_ref, *,
                        block_n: int, total: int):
    """One (receiver, param-block) program: expand each pair key's counter
    bits for this block's positions, map to uniform [-b, b), apply signed.

    Positional replication of jax's threefry expansion for a (total,) draw:
    the counter iota is zero-padded at the end to even length S, halved
    into cipher lanes (x0 = v[:S/2], x1 = v[S/2:]), outputs concatenated —
    so position p needs only its own lane pair, computable from p alone.
    """
    j = pl.program_id(1)
    x = x_ref[...].astype(jnp.float32)            # (1, BN)
    keys = keys_ref[...]                          # (1, K, 2) uint32
    signs = signs_ref[...].astype(jnp.float32)    # (1, K, 1)
    bound = bound_ref[0]
    s = total + (total % 2)
    h = s // 2
    q = (jax.lax.broadcasted_iota(jnp.uint32, (1, block_n), 1)
         + (j * block_n).astype(jnp.uint32))      # global positions
    lane = jnp.where(q < h, q, q - jnp.uint32(h))
    x1_pos = lane + jnp.uint32(h)
    x0 = lane                                     # (1, BN)
    x1 = jnp.where(x1_pos < total, x1_pos, jnp.uint32(0))
    k1 = keys[:, :, 0][:, :, None]                # (1, K, 1)
    k2 = keys[:, :, 1][:, :, None]
    y0, y1 = _threefry2x32(k1, k2, x0[:, None, :], x1[:, None, :])  # (1, K, BN)
    bits = jnp.where(q[:, None, :] < h, y0, y1)
    u01 = (bits >> 8).astype(jnp.float32) * (1.0 / (1 << 24))
    masks = (u01 * 2.0 - 1.0) * bound
    o_ref[...] = (x + jnp.sum(masks * signs, axis=1)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "block_n"))
def secure_mask_apply_nodes_keyed(x, keys, signs, bound: float = 1.0, *,
                                  interpret: bool = False, block_n: int = BLOCK_N):
    """Fused mask apply with in-kernel bit generation.

    x: (B, M) messages; keys: (B, K, 2) uint32 pair-PRF key words
    (``jax.random.key_data`` of the folded-in pair keys); signs: (B, K) in
    {-1, 0, +1} -> (B, M).  Equivalent to staging
    ``jax.random.bits(key, (M,))`` per pair and calling
    ``secure_mask_apply_nodes`` — without the (B, K, M) bit tensor.
    """
    B, K, _ = keys.shape
    M = x.shape[1]
    bn = min(block_n, -(-M // 128) * 128)
    pad = (-M) % bn
    xp = jnp.pad(x, ((0, 0), (0, pad)))
    grid = (B, xp.shape[1] // bn)
    out = pl.pallas_call(
        functools.partial(_kernel_nodes_keyed, block_n=bn, total=M),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b, i: (0,)),
            pl.BlockSpec((1, bn), lambda b, i: (b, i)),
            pl.BlockSpec((1, K, 2), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, K, 1), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bn), lambda b, i: (b, i)),
        out_shape=jax.ShapeDtypeStruct((B, xp.shape[1]), x.dtype),
        interpret=interpret,
    )(jnp.asarray(bound, jnp.float32)[None], xp, keys, signs[:, :, None])
    return out[:, :M]
