"""Pallas TPU kernel: fused secure-aggregation mask apply.

A sender adds one cancellable mask per co-neighbor pair before the message
leaves the chip: out = x + sum_k sign_k * U(bits_k), U mapping uint32 PRF
bits to uniform [-b, b).  Fusing the K mask materializations + adds into
one pass avoids K HBM round-trips of the full parameter vector.  Bits are
produced outside (threefry) so the kernel is bit-exact against the oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 65536


def _kernel(bound_ref, x_ref, bits_ref, signs_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)          # (BN,)
    bits = bits_ref[...]                        # (K, BN) uint32
    signs = signs_ref[...].astype(jnp.float32)  # (K, 1)
    bound = bound_ref[0]
    u01 = (bits >> 8).astype(jnp.float32) * (1.0 / (1 << 24))
    masks = (u01 * 2.0 - 1.0) * bound
    o_ref[...] = (x + jnp.sum(masks * signs, axis=0)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "block_n"))
def secure_mask_apply(x, bits, signs, bound: float = 1.0, *,
                      interpret: bool = False, block_n: int = BLOCK_N):
    """x: (M,); bits: (K, M) uint32; signs: (K,) ±1 -> masked x (M,)."""
    K, M = bits.shape
    pad = (-M) % block_n
    xp = jnp.pad(x, (0, pad))
    bp = jnp.pad(bits, ((0, 0), (0, pad)))
    grid = (xp.shape[0] // block_n,)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((K, block_n), lambda i: (0, i)),
            pl.BlockSpec((K, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0],), x.dtype),
        interpret=interpret,
    )(jnp.asarray(bound, jnp.float32)[None], xp, bp, signs[:, None])
    return out[:M]
