"""Jitted public wrappers around the Pallas kernels.

On this CPU container every wrapper defaults to ``interpret=True`` (the
kernel body executes in Python via the Pallas interpreter — bit-faithful to
the TPU program).  On a real TPU, pass ``interpret=False`` (or set
REPRO_PALLAS_COMPILE=1) to run the compiled kernels.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels import ref as ref_ops
from repro.kernels.gossip_mix import gossip_mix as _gossip_mix
from repro.kernels.gossip_mix import gossip_mix_nodes as _gossip_mix_nodes
from repro.kernels.quantize import dequantize as _dequantize
from repro.kernels.quantize import quantize as _quantize
from repro.kernels.scatter_gossip import payload_mix_nodes as _payload_mix_nodes
from repro.kernels.secure_mask import secure_mask_apply as _secure_mask_apply
from repro.kernels.secure_mask import secure_mask_apply_nodes as _secure_mask_apply_nodes
from repro.kernels.secure_mask import (
    secure_mask_apply_nodes_keyed as _secure_mask_apply_nodes_keyed,
)
from repro.kernels.sparsify import abs_histogram as _abs_histogram
from repro.kernels.sparsify import abs_histogram_rows as _abs_histogram_rows
from repro.kernels.sparsify import threshold_mask as _threshold_mask
from repro.kernels.sparsify import topk_threshold as _topk_threshold
from repro.kernels.sparsify import topk_threshold_rows as _topk_threshold_rows
from repro.kernels.ssd_chunk import ssd_chunk as _ssd_chunk
from repro.kernels.swa_attention import swa_attention as _swa_attention

INTERPRET = os.environ.get("REPRO_PALLAS_COMPILE", "0") != "1"


def gossip_mix(neighbors, weights, interpret: bool = None):
    return _gossip_mix(neighbors, weights,
                       interpret=INTERPRET if interpret is None else interpret)


def quantize(x, noise=None, interpret: bool = None):
    return _quantize(x, noise, interpret=INTERPRET if interpret is None else interpret)


def dequantize(codes, scale, interpret: bool = None):
    return _dequantize(codes, scale,
                       interpret=INTERPRET if interpret is None else interpret)


def secure_mask_apply(x, bits, signs, bound: float = 1.0, interpret: bool = None):
    return _secure_mask_apply(x, bits, signs, bound,
                              interpret=INTERPRET if interpret is None else interpret)


def gossip_mix_nodes(neighbors, weights, interpret: bool = None):
    return _gossip_mix_nodes(neighbors, weights,
                             interpret=INTERPRET if interpret is None else interpret)


def secure_mask_apply_nodes(x, bits, signs, bound: float = 1.0, interpret: bool = None):
    return _secure_mask_apply_nodes(x, bits, signs, bound,
                                    interpret=INTERPRET if interpret is None else interpret)


def secure_mask_apply_nodes_keyed(x, keys, signs, bound: float = 1.0,
                                  interpret: bool = None):
    return _secure_mask_apply_nodes_keyed(
        x, keys, signs, bound,
        interpret=INTERPRET if interpret is None else interpret)


def payload_mix_nodes(x, idx, val, w, interpret: bool = None):
    return _payload_mix_nodes(x, idx, val, w,
                              interpret=INTERPRET if interpret is None else interpret)


def abs_histogram(x, edges, interpret: bool = None):
    return _abs_histogram(x, edges,
                          interpret=INTERPRET if interpret is None else interpret)


def abs_histogram_rows(x, edges, interpret: bool = None):
    return _abs_histogram_rows(x, edges,
                               interpret=INTERPRET if interpret is None else interpret)


def topk_threshold_rows(x, k: int, interpret: bool = None):
    """Per-row histogram top-k threshold (N,) for x (N, P)."""
    return _topk_threshold_rows(x, k,
                                interpret=INTERPRET if interpret is None else interpret)


def threshold_mask(x, threshold, interpret: bool = None):
    return _threshold_mask(x, threshold,
                           interpret=INTERPRET if interpret is None else interpret)


def topk_mask_approx(x, k: int, interpret: bool = None):
    """Histogram-threshold approximate top-k: (values, mask, threshold)."""
    it = INTERPRET if interpret is None else interpret
    t, _, _ = _topk_threshold(x, k, interpret=it)
    vals, mask = _threshold_mask(x, t, interpret=it)
    return vals, mask, t


def ssd_chunk(xdt, Bc, Cc, cum, interpret: bool = None):
    return _ssd_chunk(xdt, Bc, Cc, cum,
                      interpret=INTERPRET if interpret is None else interpret)


def swa_attention(q, k, v, window: int, interpret: bool = None):
    return _swa_attention(q, k, v, window,
                          interpret=INTERPRET if interpret is None else interpret)


def ssd_scan(xdt, Bc, Cc, cum, interpret: bool = None):
    """Full SSD over chunks using the Pallas intra-chunk kernel + the jnp
    inter-chunk recurrence.  Mirrors ssm.ssm_apply's core.

    xdt: (B, nc, L, H, P); Bc/Cc: (B, nc, L, N); cum: (B, nc, L, H).
    Returns y (B, nc, L, H, P)."""
    B, nc, L, H, P = xdt.shape
    N = Bc.shape[-1]
    g = lambda t: t.reshape(B * nc, *t.shape[2:])
    y_intra, states, dec = ssd_chunk(g(xdt), g(Bc), g(Cc), g(cum), interpret=interpret)
    y_intra = y_intra.reshape(B, nc, L, H, P)
    states = states.reshape(B, nc, H, N, P)
    dec = dec.reshape(B, nc, H)

    def scan_fn(h_prev, inp):
        s_c, d = inp
        return h_prev * d[..., None, None] + s_c, h_prev

    h0 = jnp.zeros((B, H, N, P), jnp.float32)
    _, h_before = jax.lax.scan(
        scan_fn, h0, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(dec, 1, 0))
    )
    h_before = jnp.moveaxis(h_before, 0, 1)  # (B, nc, H, N, P)
    y_inter = jnp.einsum("bcin,bchnp->bcihp", Cc.astype(jnp.float32), h_before) * jnp.exp(
        cum
    )[..., None]
    return y_intra + y_inter
