"""Pallas TPU kernels for top-k sparsification (sharing-module hot path).

TPU has no fast global sort, so top-k over a multi-million-element
parameter vector is done the TPU-idiomatic way:

  1. ``abs_histogram`` — one HBM pass accumulating a histogram of |x| over
     log-spaced bins (VMEM accumulator, sequential grid);
  2. host/XLA picks the threshold bin so ~k elements survive;
  3. ``threshold_mask`` — one more pass emitting masked values + bool mask.

Both kernels are memory-bound single-pass; the exact-top-k oracle
(lax.top_k) is the test reference: the approximate mask must contain every
element strictly above the chosen bin edge and select k within one bin's
population.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 65536
NBINS = 128


def _hist_kernel(x_ref, edges_ref, o_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = jnp.abs(x_ref[...].astype(jnp.float32))  # (BLOCK,)
    edges = edges_ref[...].astype(jnp.float32)   # (E,)
    # bucket index = #edges <= a  (same as searchsorted right)
    idx = jnp.sum(a[:, None] >= edges[None, :], axis=1)  # (BLOCK,) in [0, E]
    onehot = idx[:, None] == jnp.arange(edges.shape[0] + 1)[None, :]
    o_ref[...] += jnp.sum(onehot, axis=0).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret", "block"))
def abs_histogram(x, edges, *, interpret: bool = False, block: int = BLOCK):
    """x: (M,), edges: (E,) ascending -> (E+1,) int32 counts (pad-aware)."""
    M = x.shape[0]
    pad = (-M) % block
    # pad with +inf so padding lands in the last (overflow) bucket; we
    # subtract it afterwards.
    xp = jnp.pad(x.astype(jnp.float32), (0, pad), constant_values=jnp.inf)
    grid = (xp.shape[0] // block,)
    E = edges.shape[0]
    hist = pl.pallas_call(
        _hist_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((E,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((E + 1,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((E + 1,), jnp.int32),
        interpret=interpret,
    )(xp, edges)
    return hist - jnp.zeros_like(hist).at[E].set(pad)


def _mask_kernel(x_ref, t_ref, v_ref, m_ref):
    x = x_ref[...]
    t = t_ref[0]
    keep = jnp.abs(x.astype(jnp.float32)) >= t
    v_ref[...] = jnp.where(keep, x, jnp.zeros_like(x))
    m_ref[...] = keep


@functools.partial(jax.jit, static_argnames=("interpret", "block"))
def threshold_mask(x, threshold, *, interpret: bool = False, block: int = BLOCK):
    """x: (M,) -> (masked values (M,), mask bool (M,))."""
    M = x.shape[0]
    pad = (-M) % block
    xp = jnp.pad(x, (0, pad))
    grid = (xp.shape[0] // block,)
    vals, mask = pl.pallas_call(
        _mask_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((xp.shape[0],), x.dtype),
            jax.ShapeDtypeStruct((xp.shape[0],), jnp.bool_),
        ],
        interpret=interpret,
    )(xp, jnp.asarray(threshold, jnp.float32)[None])
    return vals[:M], mask[:M]


def _hist_rows_kernel(x_ref, edges_ref, o_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = jnp.abs(x_ref[...].astype(jnp.float32))   # (1, B)
    edges = edges_ref[...].astype(jnp.float32)    # (1, E)
    idx = jnp.sum(a[0][:, None] >= edges[0][None, :], axis=1)  # (B,) in [0, E]
    onehot = idx[:, None] == jnp.arange(edges.shape[1] + 1)[None, :]
    o_ref[...] += jnp.sum(onehot, axis=0).astype(jnp.int32)[None, :]


@functools.partial(jax.jit, static_argnames=("interpret", "block"))
def abs_histogram_rows(x, edges, *, interpret: bool = False, block: int = BLOCK):
    """Row-batched |x| histogram: x (N, P), edges (N, E) per-row ascending
    -> (N, E+1) int32 counts (pad-aware).  Grid (N, P/B): the sharing
    module's per-node threshold pick is one kernel launch instead of N."""
    N, P = x.shape
    b = min(block, -(-P // 128) * 128)
    pad = (-P) % b
    xp = jnp.pad(x.astype(jnp.float32), ((0, 0), (0, pad)),
                 constant_values=jnp.inf)
    E = edges.shape[1]
    grid = (N, xp.shape[1] // b)
    hist = pl.pallas_call(
        _hist_rows_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, b), lambda n, j: (n, j)),
            pl.BlockSpec((1, E), lambda n, j: (n, 0)),
        ],
        out_specs=pl.BlockSpec((1, E + 1), lambda n, j: (n, 0)),
        out_shape=jax.ShapeDtypeStruct((N, E + 1), jnp.int32),
        interpret=interpret,
    )(xp, edges)
    return hist - jnp.zeros_like(hist).at[:, E].set(pad)


def _pick_edge_rows(a, k, edges, interpret):
    """Per-row largest edge with #{|x| >= edge} >= k, and the next edge up.
    a: (N, P) magnitudes, edges: (N, E)."""
    nbins = edges.shape[1]
    hist = abs_histogram_rows(a, edges, interpret=interpret)     # (N, E+1)
    tail = jnp.cumsum(hist[:, ::-1], axis=1)[:, ::-1]
    surv = tail[:, 1:]                                           # (N, E)
    ok = surv >= k
    any_ok = ok.any(axis=1)
    pos = (jnp.arange(nbins)[None, :] * ok).argmax(axis=1)       # (N,)
    t = jnp.where(
        any_ok, jnp.take_along_axis(edges, pos[:, None], axis=1)[:, 0], 0.0
    )
    hi_pos = jnp.minimum(pos + 1, nbins - 1)
    t_hi = jnp.take_along_axis(edges, hi_pos[:, None], axis=1)[:, 0]
    return t, t_hi


def topk_threshold_rows(x, k: int, nbins: int = NBINS, interpret: bool = False):
    """Per-row histogram top-k threshold: x (N, P) -> t (N,) float32 with
    #{|x[n]| >= t[n]} >= k, within one *fine* bin of exactly k.  The
    row-batched form of :func:`topk_threshold` (same coarse-log + linear
    refinement discipline), one pass over x per histogram instead of a
    per-row sort — the sharing module's hot-path selector on TPU."""
    a = jnp.abs(x.astype(jnp.float32))
    hi = jnp.max(a, axis=1)
    lo = jnp.maximum(hi * 1e-7, 1e-30)
    span = jnp.linspace(0.0, 1.0, nbins)[None, :]
    edges = jnp.exp(
        jnp.log(lo)[:, None] * (1.0 - span) + jnp.log(jnp.maximum(hi, 1e-30))[:, None] * span
    )
    t0, t0_hi = _pick_edge_rows(a, k, edges, interpret)
    fine = t0[:, None] * (1.0 - span) + jnp.maximum(t0_hi, t0 + 1e-30)[:, None] * span
    t1, _ = _pick_edge_rows(a, k, fine, interpret)
    return jnp.maximum(t0, t1)


def _pick_edge(x, k, edges, interpret):
    """Largest edge with #{|x| >= edge} >= k, and the next edge above it."""
    nbins = edges.shape[0]
    hist = abs_histogram(x, edges, interpret=interpret)
    tail = jnp.cumsum(hist[::-1])[::-1]  # tail[i] = # >= edges[i-1]
    surv = tail[1:]  # surv[i] = #{a >= edges[i]}
    ok = surv >= k
    idx = jnp.where(ok.any(), (jnp.arange(nbins) * ok).argmax(), 0)
    t = jnp.where(ok.any(), edges[idx], 0.0)
    t_hi = edges[jnp.minimum(idx + 1, nbins - 1)]
    return t, t_hi, hist


def topk_threshold(x, k: int, nbins: int = NBINS, interpret: bool = False):
    """Histogram-based threshold t s.t. #{|x| >= t} ~ k (>= k, within one
    *fine* bin).  Two passes: coarse log bins bracket the threshold, then a
    linear re-binning inside the bracketing bin refines it (the log tail is
    too coarse for small k otherwise).  Returns (threshold, hist, edges)."""
    a = jnp.abs(x.astype(jnp.float32))
    hi = jnp.max(a)
    lo = jnp.maximum(hi * 1e-7, 1e-30)
    edges = jnp.exp(jnp.linspace(jnp.log(lo), jnp.log(hi), nbins))
    t0, t0_hi, hist = _pick_edge(x, k, edges, interpret)
    # refinement: linear bins across the bracketing interval [t0, t0_hi]
    fine = jnp.linspace(t0, jnp.maximum(t0_hi, t0 + 1e-30), nbins)
    t1, _, _ = _pick_edge(x, k, fine, interpret)
    t = jnp.maximum(t0, t1)
    return t, hist, edges
