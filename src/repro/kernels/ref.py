"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth
the shape/dtype sweep tests assert against)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gossip_mix_ref(neighbors, weights):
    """neighbors: (K, M) stacked neighbor shards; weights: (K,).
    out[m] = sum_k w_k * neighbors[k, m] (fp32 accumulate)."""
    return jnp.einsum(
        "k,km->m", weights.astype(jnp.float32), neighbors.astype(jnp.float32)
    ).astype(neighbors.dtype)


def abs_histogram_ref(x, edges):
    """Histogram of |x| over bins defined by ``edges`` (ascending, E,).
    Returns (E+1,) int32 counts; bin i = #{|x| in [edges[i-1], edges[i])}."""
    a = jnp.abs(x.astype(jnp.float32)).reshape(-1)
    idx = jnp.searchsorted(edges.astype(jnp.float32), a, side="right")
    return jnp.zeros((edges.shape[0] + 1,), jnp.int32).at[idx].add(1)


def threshold_mask_ref(x, threshold):
    """Values of |x| >= threshold kept, else 0; plus boolean mask."""
    m = jnp.abs(x.astype(jnp.float32)) >= threshold
    return jnp.where(m, x, jnp.zeros((), x.dtype)), m


def quantize_ref(x, noise=None):
    """Per-row symmetric int8; optional stochastic rounding with uniform
    noise in [0,1). x: (R, C) -> (codes int8, scale (R,1) fp32)."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf), axis=-1, keepdims=True) / 127.0, 1e-12)
    y = xf / scale
    y = jnp.round(y) if noise is None else jnp.floor(y + noise)
    return jnp.clip(y, -127, 127).astype(jnp.int8), scale


def dequantize_ref(codes, scale):
    return codes.astype(jnp.float32) * scale


def mask_bits_to_uniform(bits, bound):
    """uint32 random bits -> uniform float32 in [-bound, bound).
    Mapping: top 24 bits -> [0,1) with 2^-24 quantization (shared by the
    kernel and the oracle so they agree bit-exactly)."""
    u01 = (bits >> 8).astype(jnp.float32) * (1.0 / (1 << 24))
    return (u01 * 2.0 - 1.0) * bound


def secure_mask_apply_ref(x, bits, signs, bound):
    """x: (K, M) pair-lanes? No — x: (M,), bits: (K, M) one row per pair,
    signs: (K,) ±1. out = x + sum_k signs[k] * uniform(bits[k])."""
    masks = mask_bits_to_uniform(bits, bound)  # (K, M) fp32
    return (x.astype(jnp.float32) + jnp.einsum("k,km->m", signs.astype(jnp.float32), masks)).astype(x.dtype)


def gossip_mix_nodes_ref(neighbors, weights):
    """neighbors: (N, K, M); weights: (N, K).  Per-receiver fused merge:
    out[n, m] = sum_k w[n, k] * neighbors[n, k, m] (fp32 accumulate)."""
    return jnp.einsum(
        "nk,nkm->nm", weights.astype(jnp.float32), neighbors.astype(jnp.float32)
    ).astype(neighbors.dtype)


def secure_mask_apply_nodes_ref(x, bits, signs, bound):
    """x: (B, M); bits: (B, K, M); signs: (B, K) in {-1, 0, +1}.
    out[b] = x[b] + sum_k signs[b, k] * uniform(bits[b, k])."""
    masks = mask_bits_to_uniform(bits, bound)  # (B, K, M) fp32
    return (
        x.astype(jnp.float32)
        + jnp.einsum("bk,bkm->bm", signs.astype(jnp.float32), masks)
    ).astype(x.dtype)


def ssd_chunk_ref(xdt, Bc, Cc, cum):
    """One SSD chunk (single batch element).

    xdt: (L, H, P) fp32 (x * dt), Bc/Cc: (L, N), cum: (L, H) cumsum(dt*A).
    Returns (y_intra (L, H, P), state (H, N, P), decay_out (H,)):
      y_intra[i] = sum_{j<=i} (C_i.B_j) exp(cum_i - cum_j) xdt_j
      state      = sum_j exp(cum_L - cum_j) B_j (x) xdt_j
      decay_out  = exp(cum_L)   (total chunk decay for the recurrence)
    """
    L = xdt.shape[0]
    diff = cum[:, None, :] - cum[None, :, :]  # (L, L, H)
    tri = jnp.tril(jnp.ones((L, L), bool))
    Ldec = jnp.where(tri[:, :, None], jnp.exp(diff), 0.0)
    cb = jnp.einsum("in,jn->ij", Cc.astype(jnp.float32), Bc.astype(jnp.float32))
    y = jnp.einsum("ijh,jhp->ihp", cb[:, :, None] * Ldec, xdt.astype(jnp.float32))
    decay_to_end = jnp.exp(cum[-1:, :] - cum)  # (L, H)
    state = jnp.einsum("jn,jhp->hnp", Bc.astype(jnp.float32),
                       xdt.astype(jnp.float32) * decay_to_end[:, :, None])
    return y, state, jnp.exp(cum[-1])


def swa_attention_ref(q, k, v, window: int):
    """Sliding-window causal attention, single head batch-merged.
    q,k,v: (S, D). Query i attends keys (i-window, i]."""
    S = q.shape[0]
    scores = (q.astype(jnp.float32) @ k.astype(jnp.float32).T) * (q.shape[-1] ** -0.5)
    qi = jnp.arange(S)[:, None]
    kj = jnp.arange(S)[None, :]
    mask = (kj <= qi) & (kj > qi - window)
    scores = jnp.where(mask, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    return (w @ v.astype(jnp.float32)).astype(q.dtype)
