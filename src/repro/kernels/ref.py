"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth
the shape/dtype sweep tests assert against)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gossip_mix_ref(neighbors, weights):
    """neighbors: (K, M) stacked neighbor shards; weights: (K,).
    out[m] = sum_k w_k * neighbors[k, m] (fp32 accumulate)."""
    return jnp.einsum(
        "k,km->m", weights.astype(jnp.float32), neighbors.astype(jnp.float32)
    ).astype(neighbors.dtype)


def abs_histogram_ref(x, edges):
    """Histogram of |x| over bins defined by ``edges`` (ascending, E,).
    Returns (E+1,) int32 counts; bin i = #{|x| in [edges[i-1], edges[i])}."""
    a = jnp.abs(x.astype(jnp.float32)).reshape(-1)
    idx = jnp.searchsorted(edges.astype(jnp.float32), a, side="right")
    return jnp.zeros((edges.shape[0] + 1,), jnp.int32).at[idx].add(1)


def threshold_mask_ref(x, threshold):
    """Values of |x| >= threshold kept, else 0; plus boolean mask."""
    m = jnp.abs(x.astype(jnp.float32)) >= threshold
    return jnp.where(m, x, jnp.zeros((), x.dtype)), m


def quantize_ref(x, noise=None):
    """Per-row symmetric int8; optional stochastic rounding with uniform
    noise in [0,1). x: (R, C) -> (codes int8, scale (R,1) fp32)."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf), axis=-1, keepdims=True) / 127.0, 1e-12)
    y = xf / scale
    y = jnp.round(y) if noise is None else jnp.floor(y + noise)
    return jnp.clip(y, -127, 127).astype(jnp.int8), scale


def dequantize_ref(codes, scale):
    return codes.astype(jnp.float32) * scale


def mask_bits_to_uniform(bits, bound):
    """uint32 random bits -> uniform float32 in [-bound, bound).
    Mapping: top 24 bits -> [0,1) with 2^-24 quantization (shared by the
    kernel and the oracle so they agree bit-exactly)."""
    u01 = (bits >> 8).astype(jnp.float32) * (1.0 / (1 << 24))
    return (u01 * 2.0 - 1.0) * bound


def secure_mask_apply_ref(x, bits, signs, bound):
    """x: (K, M) pair-lanes? No — x: (M,), bits: (K, M) one row per pair,
    signs: (K,) ±1. out = x + sum_k signs[k] * uniform(bits[k])."""
    masks = mask_bits_to_uniform(bits, bound)  # (K, M) fp32
    return (x.astype(jnp.float32) + jnp.einsum("k,km->m", signs.astype(jnp.float32), masks)).astype(x.dtype)


def threefry2x32_ref(k1, k2, x0, x1):
    """Elementwise Threefry-2x32 block cipher (the JAX PRNG core), pure jnp.

    k1/k2: uint32 key words (broadcastable against x0/x1); x0/x1: uint32
    counter words.  Returns (y0, y1).  This is the single definition the
    in-kernel bit generation (kernels/secure_mask) and its oracle share —
    it must stay bit-identical to ``jax.random.bits``'s cipher.
    """
    def rotl(x, d):
        return (x << jnp.uint32(d)) | (x >> jnp.uint32(32 - d))

    ks0 = k1
    ks1 = k2
    ks2 = k1 ^ k2 ^ jnp.uint32(0x1BD11BDA)
    ks = [ks0, ks1, ks2]
    rots = ((13, 15, 26, 6), (17, 29, 16, 24))
    x0 = x0 + ks0
    x1 = x1 + ks1
    for i in range(5):
        for r in rots[i % 2]:
            x0 = x0 + x1
            x1 = rotl(x1, r) ^ x0
        x0 = x0 + ks[(i + 1) % 3]
        x1 = x1 + ks[(i + 2) % 3] + jnp.uint32(i + 1)
    return x0, x1


def counter_bits_ref(k1, k2, positions, total: int):
    """uint32 PRF bits at ``positions`` of a ``jax.random.bits(key, (total,))``
    draw, computed positionally (no (total,) materialization).

    Replicates jax's non-partitionable threefry expansion: the counter iota
    is zero-padded *at the end* to even length S, split into halves
    x0 = v[:S/2], x1 = v[S/2:], cipher outputs concatenated and truncated
    back to ``total``.  Elementwise in ``positions``, so a kernel can
    generate exactly its block's bits.  Bit-identity is asserted in
    tests/test_kernels.py against jax.random.bits.
    """
    total = int(total)
    s = total + (total % 2)
    h = s // 2
    q = positions.astype(jnp.uint32)
    lane = jnp.where(q < h, q, q - jnp.uint32(h))
    x1_pos = lane + jnp.uint32(h)
    x0 = lane
    x1 = jnp.where(x1_pos < total, x1_pos, jnp.uint32(0))
    y0, y1 = threefry2x32_ref(k1, k2, x0, x1)
    return jnp.where(q < h, y0, y1)


def secure_mask_apply_nodes_keyed_ref(x, keys, signs, bound):
    """x: (B, M); keys: (B, K, 2) uint32 pair-PRF keys; signs: (B, K).
    out[b] = x[b] + sum_k signs[b, k] * uniform(bits(keys[b, k])), the bits
    being jax.random.bits(key, (M,)) — generated here via counter_bits_ref
    so the fused kernel and jax.random agree bit-exactly."""
    B, K, _ = keys.shape
    M = x.shape[1]
    pos = jnp.arange(M, dtype=jnp.uint32)[None, None, :]
    bits = counter_bits_ref(keys[:, :, 0:1], keys[:, :, 1:2], pos, M)  # (B, K, M)
    masks = mask_bits_to_uniform(bits, bound)
    return (
        x.astype(jnp.float32)
        + jnp.einsum("bk,bkm->bm", signs.astype(jnp.float32), masks)
    ).astype(x.dtype)


def payload_mix_nodes_ref(x, idx, val, w):
    """Payload-indexed gossip merge oracle (missing-coordinate rule).

    x: (N, P); idx: (N, K, k) int32; val: (N, K, k) fp32; w: (N, K).
    out[n] = x[n] + sum_{K,k} w[n, K] * scatter(idx[n, K], val - x[n][idx])
    — each operand slot contributes only its payload coordinates, missing
    coordinates fall back to the receiver's own value.  fp32 accumulate.
    """
    n, K, k = idx.shape
    xf = x.astype(jnp.float32)
    fid = idx.reshape(n, K * k)
    own = jnp.take_along_axis(xf, fid, axis=1)                    # (N, K*k)
    contrib = (val.astype(jnp.float32).reshape(n, K * k) - own) * jnp.repeat(
        w.astype(jnp.float32), k, axis=1
    )
    delta = jnp.zeros_like(xf).at[jnp.arange(n)[:, None], fid].add(contrib)
    return (xf + delta).astype(x.dtype)


def abs_histogram_rows_ref(x, edges):
    """Row-batched abs_histogram_ref: x (N, P), edges (N, E) per-row
    ascending -> (N, E+1) int32 counts."""
    a = jnp.abs(x.astype(jnp.float32))
    idx = jnp.sum(a[:, :, None] >= edges.astype(jnp.float32)[:, None, :], axis=2)
    E = edges.shape[1]
    onehot = idx[:, :, None] == jnp.arange(E + 1)[None, None, :]
    return jnp.sum(onehot, axis=1).astype(jnp.int32)


def gossip_mix_nodes_ref(neighbors, weights):
    """neighbors: (N, K, M); weights: (N, K).  Per-receiver fused merge:
    out[n, m] = sum_k w[n, k] * neighbors[n, k, m] (fp32 accumulate)."""
    return jnp.einsum(
        "nk,nkm->nm", weights.astype(jnp.float32), neighbors.astype(jnp.float32)
    ).astype(neighbors.dtype)


def secure_mask_apply_nodes_ref(x, bits, signs, bound):
    """x: (B, M); bits: (B, K, M); signs: (B, K) in {-1, 0, +1}.
    out[b] = x[b] + sum_k signs[b, k] * uniform(bits[b, k])."""
    masks = mask_bits_to_uniform(bits, bound)  # (B, K, M) fp32
    return (
        x.astype(jnp.float32)
        + jnp.einsum("bk,bkm->bm", signs.astype(jnp.float32), masks)
    ).astype(x.dtype)


def ssd_chunk_ref(xdt, Bc, Cc, cum):
    """One SSD chunk (single batch element).

    xdt: (L, H, P) fp32 (x * dt), Bc/Cc: (L, N), cum: (L, H) cumsum(dt*A).
    Returns (y_intra (L, H, P), state (H, N, P), decay_out (H,)):
      y_intra[i] = sum_{j<=i} (C_i.B_j) exp(cum_i - cum_j) xdt_j
      state      = sum_j exp(cum_L - cum_j) B_j (x) xdt_j
      decay_out  = exp(cum_L)   (total chunk decay for the recurrence)
    """
    L = xdt.shape[0]
    diff = cum[:, None, :] - cum[None, :, :]  # (L, L, H)
    tri = jnp.tril(jnp.ones((L, L), bool))
    Ldec = jnp.where(tri[:, :, None], jnp.exp(diff), 0.0)
    cb = jnp.einsum("in,jn->ij", Cc.astype(jnp.float32), Bc.astype(jnp.float32))
    y = jnp.einsum("ijh,jhp->ihp", cb[:, :, None] * Ldec, xdt.astype(jnp.float32))
    decay_to_end = jnp.exp(cum[-1:, :] - cum)  # (L, H)
    state = jnp.einsum("jn,jhp->hnp", Bc.astype(jnp.float32),
                       xdt.astype(jnp.float32) * decay_to_end[:, :, None])
    return y, state, jnp.exp(cum[-1])


def swa_attention_ref(q, k, v, window: int):
    """Sliding-window causal attention, single head batch-merged.
    q,k,v: (S, D). Query i attends keys (i-window, i]."""
    S = q.shape[0]
    scores = (q.astype(jnp.float32) @ k.astype(jnp.float32).T) * (q.shape[-1] ** -0.5)
    qi = jnp.arange(S)[:, None]
    kj = jnp.arange(S)[None, :]
    mask = (kj <= qi) & (kj > qi - window)
    scores = jnp.where(mask, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    return (w @ v.astype(jnp.float32)).astype(q.dtype)
