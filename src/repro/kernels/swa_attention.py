"""Pallas TPU kernel: sliding-window flash attention (long_500k dense path).

Flash-style running-softmax over key blocks, but the key-block loop is
*bounded by the window*: query block qi only visits key blocks
[qi - W/BK, qi], so total work is O(S * W) instead of O(S^2) — this is what
makes a 512k-token dense decode/prefill shape viable at all.

Grid: (batch*heads, q_blocks, k_blocks_per_window); BQ = BK = 128 (MXU
native).  The running (m, l, acc) state lives in VMEM scratch across the
innermost k-block dimension.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BQ = 128
BK = 128
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *, window, bq, bk):
    qi = pl.program_id(1)
    kj = pl.program_id(2)  # 0 .. kblocks_per_win-1, maps to absolute block
    nkb = pl.num_programs(2)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # absolute key block index = qi - (nkb - 1) + kj  (may be < 0 -> skip)
    abs_kb = qi - (nkb - 1) + kj

    @pl.when(abs_kb >= 0)
    def _compute():
        q = q_ref[0].astype(jnp.float32)  # (BQ, D)
        k = k_ref[0].astype(jnp.float32)  # (BK, D)
        v = v_ref[0].astype(jnp.float32)  # (BK, D)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * (q.shape[-1] ** -0.5)
        q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = abs_kb * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = (k_pos <= q_pos) & (k_pos > q_pos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]  # (BQ, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(kj == nkb - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "interpret", "bq", "bk"))
def swa_attention(q, k, v, window: int, *, interpret: bool = False,
                  bq: int = BQ, bk: int = BK):
    """q, k, v: (BH, S, D) merged batch*heads; causal sliding-window
    attention with the given window. S % bq == 0, window % bk == 0."""
    BH, S, D = q.shape
    assert S % bq == 0 and window % bk == 0
    nq = S // bq
    nkb = window // bk + 1  # window span + the diagonal block
    grid = (BH, nq, nkb)
    return pl.pallas_call(
        functools.partial(_kernel, window=window, bq=bq, bk=bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, qi, kj: (b, qi, 0)),
            pl.BlockSpec(
                (1, bk, D),
                lambda b, qi, kj, nkb=nkb: (b, jnp.maximum(qi - (nkb - 1) + kj, 0), 0),
            ),
            pl.BlockSpec(
                (1, bk, D),
                lambda b, qi, kj, nkb=nkb: (b, jnp.maximum(qi - (nkb - 1) + kj, 0), 0),
            ),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, qi, kj: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
