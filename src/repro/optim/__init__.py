from repro.optim.optimizers import sgd, momentum, adamw, make_optimizer, Optimizer
