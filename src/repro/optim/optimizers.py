"""Optimizers (pure JAX, optax-style (init, update) pairs).

The paper tunes plain SGD without momentum — that is the D-PSGD default
here; momentum/AdamW are provided for the large-model trainer.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable
    update: Callable  # (grads, state, params) -> (updates, state)


def sgd(lr: float) -> Optimizer:
    def init(params):
        return ()

    def update(grads, state, params=None):
        return jax.tree_util.tree_map(lambda g: -lr * g, grads), state

    return Optimizer(init, update)


def momentum(lr: float, beta: float = 0.9, nesterov: bool = False) -> Optimizer:
    def init(params):
        return jax.tree_util.tree_map(jnp.zeros_like, params)

    def update(grads, state, params=None):
        buf = jax.tree_util.tree_map(lambda m, g: beta * m + g, state, grads)
        if nesterov:
            upd = jax.tree_util.tree_map(lambda m, g: -lr * (beta * m + g), buf, grads)
        else:
            upd = jax.tree_util.tree_map(lambda m: -lr * m, buf)
        return upd, buf

    return Optimizer(init, update)


def adamw(lr: float, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        z = jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return {"mu": z, "nu": jax.tree_util.tree_map(jnp.copy, z), "t": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        t = state["t"] + 1
        mu = jax.tree_util.tree_map(
            lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32), state["mu"], grads)
        nu = jax.tree_util.tree_map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)), state["nu"], grads)
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)

        def upd(m, v, p):
            step = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            return (-lr * (step + weight_decay * p.astype(jnp.float32))).astype(p.dtype)

        updates = jax.tree_util.tree_map(upd, mu, nu, params)
        return updates, {"mu": mu, "nu": nu, "t": t}

    return Optimizer(init, update)


def apply_updates(params, updates):
    return jax.tree_util.tree_map(lambda p, u: (p + u.astype(p.dtype)), params, updates)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale.astype(g.dtype), grads)


def make_optimizer(name: str, lr: float, **kw) -> Optimizer:
    name = name.lower()
    if name == "sgd":
        return sgd(lr)
    if name == "momentum":
        return momentum(lr, **kw)
    if name == "adamw":
        return adamw(lr, **kw)
    raise ValueError(f"unknown optimizer {name!r}")
