"""DeepSeek-V2-236B [arXiv:2405.04434]: 60L, d_model 5120, 128 heads with
MLA (kv_lora 512, q_lora 1536, qk_nope 128, qk_rope 64, v 128), MoE with
2 shared + 160 routed experts top-6 (d_expert 1536), first layer dense
(d_ff 12288), vocab 102400."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b",
        family="moe",
        n_layers=60,
        d_model=5120,
        n_heads=128,
        n_kv_heads=128,
        d_ff=12288,           # dense (first) layer FFN
        vocab=102400,
        mla=True,
        kv_lora_rank=512,
        q_lora_rank=1536,
        qk_nope_dim=128,
        qk_rope_dim=64,
        v_head_dim=128,
        n_experts=160,
        n_shared_experts=2,
        moe_top_k=6,
        d_expert=1536,
        moe_every=1,
        first_dense=1,
        dtype="bfloat16",
        remat=True,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=3, d_model=256, n_heads=4, n_kv_heads=4, d_ff=512, vocab=512,
        kv_lora_rank=64, q_lora_rank=96, qk_nope_dim=32, qk_rope_dim=16,
        v_head_dim=32, n_experts=4, n_shared_experts=1, moe_top_k=2,
        d_expert=128, dtype="float32", remat=False,
    )
