"""Qwen2-VL-72B [arXiv:2409.12191]: qwen2-72b dims (80L, d_model 8192,
64H GQA kv=8, d_ff 29568, vocab 152064) + M-RoPE (sections 16/24/24 over
head_dim/2) and dynamic-resolution vision via a STUB frontend —
input_specs supplies pre-projected patch embeddings interleaved with text."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-72b",
        family="vlm",
        n_layers=80,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=29568,
        vocab=152064,
        qkv_bias=True,
        mrope_sections=(16, 24, 24),
        rope_theta=1_000_000.0,
        stub_frontend=True,
        dtype="bfloat16",
        remat=True,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=256, n_heads=8, n_kv_heads=2, d_ff=512, vocab=512,
        mrope_sections=(8, 4, 4), dtype="float32", remat=False,
    )
