"""Llama4-Maverick-400B-A17B [hf:meta-llama/Llama-4-Scout-17B-16E family]:
48L, d_model 5120, 40 heads (GQA kv=8), MoE 128 experts top-1 + 1 shared
expert (d_expert 8192), alternating dense/MoE layers, vocab 202048.
Early-fusion multimodality: the text backbone only (frontend out of scope
for this entry; the VLM stub pattern is exercised by qwen2-vl-72b)."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama4-maverick-400b-a17b",
        family="moe",
        n_layers=48,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        d_ff=8192,
        vocab=202048,
        n_experts=128,
        n_shared_experts=1,
        moe_top_k=1,
        d_expert=8192,
        moe_every=2,          # alternating dense / MoE
        dtype="bfloat16",
        remat=True,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=4, d_model=256, n_heads=8, n_kv_heads=2, d_ff=512, vocab=512,
        n_experts=4, n_shared_experts=1, moe_top_k=1, d_expert=128,
        dtype="float32", remat=False,
    )
