"""Qwen3-32B [hf:Qwen/Qwen3-8B family card; 32B variant dims]:
64L, d_model 5120, 64 heads (GQA kv=8, head_dim 128), d_ff 25600,
vocab 151936, qk-norm."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-32b",
        family="dense",
        n_layers=64,
        d_model=5120,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=25600,
        vocab=151936,
        qk_norm=True,
        rope_theta=1_000_000.0,
        dtype="bfloat16",
        remat=True,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=256, n_heads=8, n_kv_heads=2, head_dim=32,
        d_ff=512, vocab=512, dtype="float32", remat=False,
    )
