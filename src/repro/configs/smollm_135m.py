"""SmolLM-135M [hf:HuggingFaceTB/SmolLM-135M]: llama-arch, 30L, d_model 576,
9 heads (GQA kv=3), d_ff 1536, vocab 49152, tied embeddings.

We additionally build it with a 4096-token sliding window — the
sub-quadratic dense variant that makes the long_500k decode shape runnable
(per spec: dense archs run long_500k only with SWA/block-sparse)."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="smollm-135m",
        family="dense",
        n_layers=30,
        d_model=576,
        n_heads=9,
        n_kv_heads=3,
        d_ff=1536,
        vocab=49152,
        tie_embeddings=True,
        sliding_window=4096,
        dtype="bfloat16",
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=192, n_heads=3, n_kv_heads=3, d_ff=384,
        vocab=512, sliding_window=16, dtype="float32",
    )
