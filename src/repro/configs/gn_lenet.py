"""GN-LeNet — the paper's own CIFAR-10 workload (DecentralizePy §3.1).
Not part of the assigned pool; used by the faithful-reproduction
experiments and benchmarks."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(name="gn-lenet", family="cnn", vocab=10, dtype="float32")


def smoke_config() -> ModelConfig:
    return config()


def supports_shape(shape: str):
    if shape == "train_4k":
        return True, ""
    return False, "CNN classifier: no sequence shapes"
