"""Whisper-tiny [arXiv:2212.04356]: enc-dec, 4+4L, d_model 384, 6 heads,
d_ff 1536, vocab 51865; conv/mel frontend is a STUB (input_specs provides
frame embeddings (B, 1500, 384))."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-tiny",
        family="encdec",
        n_layers=4,
        n_enc_layers=4,
        enc_seq=1500,
        d_model=384,
        n_heads=6,
        n_kv_heads=6,
        d_ff=1536,
        vocab=51865,
        tie_embeddings=True,
        stub_frontend=True,
        dtype="bfloat16",
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=2, n_enc_layers=2, enc_seq=64, d_model=128, n_heads=4,
        n_kv_heads=4, d_ff=256, vocab=512, dtype="float32",
    )
