"""Architecture registry: ``get_config(name)`` / ``get_smoke_config(name)``
plus the assigned input-shape suite.  One module per architecture, each
citing its source model card / paper.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional, Tuple

ARCHS = [
    "qwen3-32b",
    "mamba2-370m",
    "qwen2-72b",
    "mistral-large-123b",
    "whisper-tiny",
    "deepseek-v2-236b",
    "zamba2-1.2b",
    "smollm-135m",
    "llama4-maverick-400b-a17b",
    "qwen2-vl-72b",
    # the paper's own workload
    "gn-lenet",
]


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    mode: str  # 'train' | 'prefill' | 'decode'


INPUT_SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def _module(name: str):
    return importlib.import_module(f"repro.configs.{name.replace('-', '_').replace('.', '_')}")


def get_config(name: str):
    return _module(name).config()


def get_smoke_config(name: str):
    return _module(name).smoke_config()


def supports_shape(name: str, shape: str) -> Tuple[bool, str]:
    """Whether (arch, input-shape) is architecturally meaningful.

    long_500k needs sub-quadratic attention (SSM/hybrid state recurrence or
    a sliding-window dense variant); encoder-only archs have no decode.
    Returns (ok, reason-if-skipped).
    """
    m = _module(name)
    if hasattr(m, "supports_shape"):
        return m.supports_shape(shape)
    cfg = get_config(name)
    if shape == "long_500k":
        if cfg.family in ("ssm", "hybrid") or cfg.sliding_window is not None:
            return True, ""
        return False, "full quadratic attention: 512k dense KV cache is architecturally excluded"
    if name == "gn-lenet" and shape != "train_4k":
        return False, "CNN classifier: no autoregressive decode / long-context shapes"
    return True, ""
