"""Mamba2-370M [arXiv:2405.21060]: 48L, d_model 1024, attention-free SSD,
ssm_state 128, vocab 50280."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-370m",
        family="ssm",
        n_layers=48,
        d_model=1024,
        d_ff=0,
        vocab=50280,
        ssm_state=128,
        ssm_expand=2,
        ssm_headdim=64,
        ssm_chunk=256,
        dtype="bfloat16",
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=256, ssm_state=32, ssm_headdim=32, ssm_chunk=16,
        vocab=512, dtype="float32",
    )
