"""Zamba2-1.2B [arXiv:2411.15242]: 38 Mamba2 layers, d_model 2048,
ssm_state 64, one SHARED attention block (32 heads, d_ff 8192) applied
every 6 SSM layers, vocab 32000."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b",
        family="hybrid",
        n_layers=38,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        d_ff=8192,
        vocab=32000,
        ssm_state=64,
        ssm_expand=2,
        ssm_headdim=64,
        ssm_chunk=256,
        attn_every=6,
        dtype="bfloat16",
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=5, d_model=256, n_heads=4, n_kv_heads=4, d_ff=512,
        ssm_state=16, ssm_headdim=32, ssm_chunk=16, attn_every=2,
        vocab=512, dtype="float32",
    )
