"""Mistral-Large-123B [hf:mistralai/Mistral-Large-Instruct-2407]: 88L,
d_model 12288, 96 heads (GQA kv=8), d_ff 28672, vocab 32768."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mistral-large-123b",
        family="dense",
        n_layers=88,
        d_model=12288,
        n_heads=96,
        n_kv_heads=8,
        d_ff=28672,
        vocab=32768,
        rope_theta=1_000_000.0,
        dtype="bfloat16",
        remat=True,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=384, n_heads=6, n_kv_heads=2, d_ff=768, vocab=512,
        dtype="float32", remat=False,
    )
