"""Qwen2-72B [arXiv:2407.10671]: 80L, d_model 8192, 64 heads (GQA kv=8),
d_ff 29568, vocab 152064, QKV bias."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-72b",
        family="dense",
        n_layers=80,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=29568,
        vocab=152064,
        qkv_bias=True,
        rope_theta=1_000_000.0,
        dtype="bfloat16",
        remat=True,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=256, n_heads=8, n_kv_heads=2, d_ff=512, vocab=512,
        dtype="float32", remat=False,
    )
