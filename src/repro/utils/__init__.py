from repro.utils.pytree import (
    tree_vector,
    tree_unvector,
    tree_size,
    tree_bytes,
    tree_map_with_path_names,
)
