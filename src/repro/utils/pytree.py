"""Pytree <-> flat-vector utilities.

The DL sharing modules (sparsification, secure aggregation, compression)
operate on the *flattened parameter vector* of a node, exactly like
DecentralizePy serializes the full model into one message.  These helpers
convert a parameter pytree into a single 1-D array and back, preserving
structure and dtypes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


def tree_size(tree) -> int:
    """Total number of scalar parameters in a pytree."""
    return sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(tree))


def tree_bytes(tree) -> int:
    """Total bytes of a pytree's leaves."""
    return sum(l.size * l.dtype.itemsize for l in jax.tree_util.tree_leaves(tree))


def tree_vector(tree) -> jax.Array:
    """Flatten a pytree of arrays into a single 1-D fp32 vector."""
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in leaves])


def tree_unvector(vec: jax.Array, like):
    """Inverse of :func:`tree_vector` given a template pytree ``like``."""
    leaves, treedef = jax.tree_util.tree_flatten(like)
    out = []
    off = 0
    for l in leaves:
        n = int(np.prod(l.shape))
        out.append(vec[off : off + n].reshape(l.shape).astype(l.dtype))
        off += n
    return jax.tree_util.tree_unflatten(treedef, out)


def tree_map_with_path_names(fn, tree):
    """tree_map where ``fn(name, leaf)`` receives a dotted path string."""

    def _fn(path, leaf):
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        return fn(name, leaf)

    return jax.tree_util.tree_map_with_path(_fn, tree)


@functools.partial(jax.jit, static_argnums=(1,))
def segment_starts(sorted_ids: jax.Array, num_segments: int) -> jax.Array:
    """Start offset of each segment id in a sorted id vector."""
    counts = jnp.bincount(sorted_ids, length=num_segments)
    return jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)[:-1].astype(jnp.int32)])
