"""Version-compat helpers for the jax API surface.

``jax.shard_map`` graduated from ``jax.experimental.shard_map`` (and its
``check_rep`` kwarg became ``check_vma``) in newer jax releases; the
container pins an older jax.  Import :func:`shard_map` from here instead of
from jax directly so both API generations work.
"""
from __future__ import annotations

import jax

if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:  # jax <= 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map_experimental

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None, **kw):
        if check_vma is not None:
            kw.setdefault("check_rep", check_vma)
        return _shard_map_experimental(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw
        )
