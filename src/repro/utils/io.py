"""Crash-consistent file writes.

A killed process (the whole point of the kill test) must never leave a
truncated/corrupt results file behind: write to a temp file in the same
directory, then ``os.replace`` — atomic on POSIX, so readers observe
either the old complete file or the new complete file, never a partial
one."""
from __future__ import annotations

import json
import os
from typing import Any


def atomic_write_json(path: str, obj: Any, *, indent: int = 1) -> str:
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(obj, f, indent=indent)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    return path
