"""Dataset module (paper §2.2 *Dataset*).

The container is offline, so the CIFAR-10 / CelebA / LEAF workloads are
replaced by *seeded synthetic datasets with the same statistical shape*:
10-class 32x32x3 images (CIFAR-like), 2-class 64-dim attribute vectors
rendered as images (CelebA-like), and a learnable LM token stream.  The
class structure is real (class-conditional generators), so accuracy
*orderings* across topologies/sharing strategies — the paper's findings —
are meaningful; absolute accuracies are not comparable to real CIFAR-10
and EXPERIMENTS.md says so.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np


@dataclasses.dataclass
class SyntheticImages:
    """k-Gaussian-blob image classification, CIFAR-10-shaped by default.

    Each class c has a fixed random prototype image; samples are
    prototype + sigma * noise, making the Bayes classifier non-trivial but
    learnable by a small CNN.
    """

    n_train: int = 12_800
    n_test: int = 2_048
    n_classes: int = 10
    shape: Tuple[int, int, int] = (32, 32, 3)
    sigma: float = 1.0
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self.prototypes = rng.normal(0, 1, (self.n_classes, *self.shape)).astype(np.float32)
        self.train_x, self.train_y = self._gen(rng, self.n_train)
        self.test_x, self.test_y = self._gen(rng, self.n_test)

    def _gen(self, rng, n):
        y = rng.integers(0, self.n_classes, n)
        x = self.prototypes[y] + self.sigma * rng.normal(0, 1, (n, *self.shape)).astype(np.float32)
        # keep unit-ish input variance regardless of sigma so the same lr
        # works across difficulty levels (sigma controls Bayes error only)
        x = x / np.sqrt(1.0 + self.sigma**2)
        return x.astype(np.float32), y.astype(np.int32)

    @property
    def kind(self):
        return "images"


@dataclasses.dataclass
class TeacherImages:
    """Teacher-student image classification: labels come from a fixed random
    2-layer MLP teacher over Gaussian images.  Unlike the blob dataset, the
    decision boundary is non-linear and sample-limited — accuracy climbs
    gradually over hundreds of rounds, which is what the paper's topology /
    sparsification orderings need to be visible (CIFAR-10-like dynamics)."""

    n_train: int = 12_800
    n_test: int = 2_048
    n_classes: int = 10
    shape: Tuple[int, int, int] = (32, 32, 3)
    teacher_hidden: int = 48
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        d = int(np.prod(self.shape))
        self._w1 = rng.normal(0, d**-0.5, (d, self.teacher_hidden)).astype(np.float32)
        self._w2 = rng.normal(0, self.teacher_hidden**-0.5,
                              (self.teacher_hidden, self.n_classes)).astype(np.float32)
        self.train_x, self.train_y = self._gen(rng, self.n_train)
        self.test_x, self.test_y = self._gen(rng, self.n_test)

    def _gen(self, rng, n):
        x = rng.normal(0, 1, (n, *self.shape)).astype(np.float32)
        h = np.tanh(x.reshape(n, -1) @ self._w1)
        y = (h @ self._w2).argmax(-1).astype(np.int32)
        return x, y

    @property
    def kind(self):
        return "images"


@dataclasses.dataclass
class SyntheticLM:
    """Token stream with learnable bigram structure (class-conditional
    Markov chains so non-IID sharding is meaningful)."""

    n_train: int = 4_096      # number of sequences
    n_test: int = 512
    seq_len: int = 64
    vocab: int = 128
    n_classes: int = 8        # distinct Markov chains ("document classes")
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        # sparse-ish transition matrices per class
        trans = rng.dirichlet(np.full(self.vocab, 0.05), (self.n_classes, self.vocab))
        self.trans = trans.astype(np.float64)
        self.train_x, self.train_y = self._gen(rng, self.n_train)
        self.test_x, self.test_y = self._gen(rng, self.n_test)

    def _gen(self, rng, n):
        cls = rng.integers(0, self.n_classes, n)
        seqs = np.zeros((n, self.seq_len), np.int32)
        tok = rng.integers(0, self.vocab, n)
        for t in range(self.seq_len):
            seqs[:, t] = tok
            cum = np.cumsum(self.trans[cls, tok], axis=-1)
            tok = (cum > rng.random((n, 1))).argmax(-1)
        return seqs, cls.astype(np.int32)

    @property
    def kind(self):
        return "lm"


def make_dataset(name: str, **kw):
    name = name.lower()
    if name in ("cifar10", "images", "synthetic-cifar"):
        return SyntheticImages(**kw)
    if name in ("cifar10-hard", "teacher"):
        kw.pop("sigma", None)
        return TeacherImages(**kw)
    if name in ("celeba", "celeba-like"):
        kw.setdefault("n_classes", 2)
        kw.setdefault("shape", (32, 32, 3))
        return SyntheticImages(**kw)
    if name in ("lm", "tokens"):
        return SyntheticLM(**kw)
    raise ValueError(f"unknown dataset {name!r}")
