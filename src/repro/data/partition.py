"""Data partitioners (paper §2.2: IID and non-IID partitioning).

``sharding_partition`` is the 2-sharding non-IID scheme of McMahan et al.
used in the paper's evaluation: sort by label, cut into n_nodes*shards
contiguous shards, deal each node ``shards`` of them — limiting the number
of distinct classes a node sees (≈4 for CIFAR-10 with 2 shards).
"""
from __future__ import annotations

from typing import List

import numpy as np


def iid_partition(labels: np.ndarray, n_nodes: int, seed: int = 0) -> List[np.ndarray]:
    rng = np.random.default_rng(seed)
    idx = rng.permutation(len(labels))
    return [np.sort(s) for s in np.array_split(idx, n_nodes)]


def sharding_partition(
    labels: np.ndarray, n_nodes: int, shards_per_node: int = 2, seed: int = 0
) -> List[np.ndarray]:
    rng = np.random.default_rng(seed)
    order = np.argsort(labels, kind="stable")
    shards = np.array_split(order, n_nodes * shards_per_node)
    shard_ids = rng.permutation(n_nodes * shards_per_node)
    return [
        np.sort(np.concatenate([shards[s] for s in shard_ids[i * shards_per_node : (i + 1) * shards_per_node]]))
        for i in range(n_nodes)
    ]


def classes_per_node(labels: np.ndarray, parts: List[np.ndarray]) -> np.ndarray:
    return np.array([len(np.unique(labels[p])) for p in parts])
