from repro.data.datasets import SyntheticImages, SyntheticLM, make_dataset
from repro.data.partition import iid_partition, sharding_partition
from repro.data.loader import NodeBatcher, node_batch_indices
