"""Per-node batching: produces node-stacked batches (N, B, ...) for the
vmapped local-training step.  Seeded, stateless (round index -> batch), so
runs are reproducible and resumable from a checkpoint round.

Two index derivations coexist (``DLConfig.batch_keying``):

* ``"stream"`` — the original host path: one numpy PCG64 stream per round
  fills a (steps, N, B) uniform block that is gathered/stacked on host and
  shipped to the device each chunk.  O(N·B) host work + transfer per round.
* ``"node"`` — :func:`node_batch_indices`: each (round, node) pair owns an
  independent ``jax.random`` stream (``fold_in`` by round then by global
  node id), so indices are derived **on device** for any subset of rows.
  A gathered cohort of C rows draws bitwise the same samples it would as
  part of the full population — the property the population-scale async
  path needs — and the host stages nothing.  The two keyings draw
  *different* (equally valid) sample streams; a given run must pick one.
"""
from __future__ import annotations

from typing import List

import numpy as np


class NodeBatcher:
    def __init__(self, data_x: np.ndarray, data_y: np.ndarray,
                 parts: List[np.ndarray], batch_size: int, seed: int = 0):
        self.x, self.y = data_x, data_y
        self.parts = parts
        self.bs = batch_size
        self.seed = seed
        self.n_nodes = len(parts)

        lens = np.array([len(p) for p in parts], np.int64)
        if (lens == 0).any():
            raise ValueError(
                f"empty partition for node(s) {np.nonzero(lens == 0)[0].tolist()}: "
                "n_nodes * shards_per_node exceeds the dataset size"
            )
        pad = np.zeros((self.n_nodes, int(lens.max())), np.int64)
        for i, p in enumerate(parts):
            pad[i, : len(p)] = p
            pad[i, len(p):] = p[0]
        self._lens, self._parts_pad = lens, pad

    def batch(self, round_idx: int, step: int = 0):
        """-> (xs (N,B,...), ys (N,B,...)) sampled per node — without
        replacement when the partition holds >= batch_size samples (used by
        the FL runner; the engine paths sample via round_indices)."""
        xs, ys = [], []
        for i, part in enumerate(self.parts):
            rng = np.random.default_rng(
                (self.seed * 1_000_003 + round_idx) * 1_000_003 + step * 65_537 + i
            )
            take = rng.choice(part, self.bs, replace=len(part) < self.bs)
            xs.append(self.x[take])
            ys.append(self.y[take])
        return np.stack(xs), np.stack(ys)

    def round_indices(self, round_idx: int, steps: int = 1) -> np.ndarray:
        """(steps, N, B) int32 global sample indices for one round, drawn
        uniformly (with replacement) from each node's partition with ONE
        vectorized generator.  Deterministic per round — independent of how
        rounds are grouped into chunks — so scanned execution samples the
        same data regardless of chunk size."""
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + round_idx) * 1_000_003 + 99_991
        )
        u = rng.random((steps, self.n_nodes, self.bs))
        loc = (u * self._lens[None, :, None]).astype(np.int64)
        return self._parts_pad[
            np.arange(self.n_nodes)[None, :, None], loc
        ].astype(np.int32)

    def chunk_indices(self, start_round: int, n_rounds: int, steps: int = 1) -> np.ndarray:
        """(R, steps, N, B) int32 indices for rounds [start, start+R) — the
        host side of the engine's pre-stacked-on-device batching: only these
        indices cross to the device; the dataset lives there already."""
        return np.stack(
            [self.round_indices(start_round + r, steps) for r in range(n_rounds)]
        )

    def test_batch(self, max_n: int = 512):
        return self.x[:max_n], self.y[:max_n]

    def device_tables(self):
        """(lens (N,) float32, parts_pad (N, maxlen) int32) as jax arrays —
        the device-resident partition tables ``node_batch_indices`` samples
        from under ``batch_keying='node'``."""
        import jax.numpy as jnp

        return (
            jnp.asarray(self._lens.astype(np.float32)),
            jnp.asarray(self._parts_pad.astype(np.int32)),
        )


def node_batch_indices(base_key, round_idx, ids, lens, parts_pad,
                       local_steps: int, batch_size: int):
    """(L, n, B) int32 global sample indices for the given global node
    ids, derived entirely on device.  Each (round, node) pair owns an
    independent PRNG stream — ``fold_in(fold_in(base_key, round), id)`` —
    so any row subset (a gathered cohort, a shard, the full arange(N))
    draws bitwise the same samples: sampling is a pure function of
    (seed, round, global id, slot), never of which rows happen to be
    materialized.  Uniform draws are float32 in [0, 1); truncation toward
    zero maps them onto each node's padded partition row."""
    import jax
    import jax.numpy as jnp

    rk = jax.random.fold_in(base_key, round_idx)
    keys = jax.vmap(lambda i: jax.random.fold_in(rk, i))(ids)
    u = jax.vmap(
        lambda k: jax.random.uniform(k, (local_steps, batch_size))
    )(keys)                                            # (n, L, B)
    lens_r = jnp.take(lens, ids)
    loc = (u * lens_r[:, None, None]).astype(jnp.int32)
    idx = parts_pad[ids[:, None, None], loc]           # (n, L, B)
    return jnp.moveaxis(idx, 0, 1)
