"""Per-node batching: produces node-stacked batches (N, B, ...) for the
vmapped local-training step.  Seeded, stateless (round index -> batch), so
runs are reproducible and resumable from a checkpoint round.
"""
from __future__ import annotations

from typing import List

import numpy as np


class NodeBatcher:
    def __init__(self, data_x: np.ndarray, data_y: np.ndarray,
                 parts: List[np.ndarray], batch_size: int, seed: int = 0):
        self.x, self.y = data_x, data_y
        self.parts = parts
        self.bs = batch_size
        self.seed = seed
        self.n_nodes = len(parts)

        lens = np.array([len(p) for p in parts], np.int64)
        if (lens == 0).any():
            raise ValueError(
                f"empty partition for node(s) {np.nonzero(lens == 0)[0].tolist()}: "
                "n_nodes * shards_per_node exceeds the dataset size"
            )
        pad = np.zeros((self.n_nodes, int(lens.max())), np.int64)
        for i, p in enumerate(parts):
            pad[i, : len(p)] = p
            pad[i, len(p):] = p[0]
        self._lens, self._parts_pad = lens, pad

    def batch(self, round_idx: int, step: int = 0):
        """-> (xs (N,B,...), ys (N,B,...)) sampled per node — without
        replacement when the partition holds >= batch_size samples (used by
        the FL runner; the engine paths sample via round_indices)."""
        xs, ys = [], []
        for i, part in enumerate(self.parts):
            rng = np.random.default_rng(
                (self.seed * 1_000_003 + round_idx) * 1_000_003 + step * 65_537 + i
            )
            take = rng.choice(part, self.bs, replace=len(part) < self.bs)
            xs.append(self.x[take])
            ys.append(self.y[take])
        return np.stack(xs), np.stack(ys)

    def round_indices(self, round_idx: int, steps: int = 1) -> np.ndarray:
        """(steps, N, B) int32 global sample indices for one round, drawn
        uniformly (with replacement) from each node's partition with ONE
        vectorized generator.  Deterministic per round — independent of how
        rounds are grouped into chunks — so scanned execution samples the
        same data regardless of chunk size."""
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + round_idx) * 1_000_003 + 99_991
        )
        u = rng.random((steps, self.n_nodes, self.bs))
        loc = (u * self._lens[None, :, None]).astype(np.int64)
        return self._parts_pad[
            np.arange(self.n_nodes)[None, :, None], loc
        ].astype(np.int32)

    def chunk_indices(self, start_round: int, n_rounds: int, steps: int = 1) -> np.ndarray:
        """(R, steps, N, B) int32 indices for rounds [start, start+R) — the
        host side of the engine's pre-stacked-on-device batching: only these
        indices cross to the device; the dataset lives there already."""
        return np.stack(
            [self.round_indices(start_round + r, steps) for r in range(n_rounds)]
        )

    def test_batch(self, max_n: int = 512):
        return self.x[:max_n], self.y[:max_n]
