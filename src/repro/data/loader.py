"""Per-node batching: produces node-stacked batches (N, B, ...) for the
vmapped local-training step.  Seeded, stateless (round index -> batch), so
runs are reproducible and resumable from a checkpoint round.
"""
from __future__ import annotations

from typing import List

import numpy as np


class NodeBatcher:
    def __init__(self, data_x: np.ndarray, data_y: np.ndarray,
                 parts: List[np.ndarray], batch_size: int, seed: int = 0):
        self.x, self.y = data_x, data_y
        self.parts = parts
        self.bs = batch_size
        self.seed = seed
        self.n_nodes = len(parts)

    def batch(self, round_idx: int, step: int = 0):
        """-> (xs (N,B,...), ys (N,B,...)) sampled with replacement per node."""
        xs, ys = [], []
        for i, part in enumerate(self.parts):
            rng = np.random.default_rng(
                (self.seed * 1_000_003 + round_idx) * 1_000_003 + step * 65_537 + i
            )
            take = rng.choice(part, self.bs, replace=len(part) < self.bs)
            xs.append(self.x[take])
            ys.append(self.y[take])
        return np.stack(xs), np.stack(ys)

    def test_batch(self, max_n: int = 512):
        return self.x[:max_n], self.y[:max_n]
