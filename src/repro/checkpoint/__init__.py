from repro.checkpoint.checkpoint import (
    save_checkpoint,
    load_checkpoint,
    latest_checkpoint,
    restore_tree,
)
