"""Checkpointing: pytree -> flat .npz + structure JSON.

Decentralized semantics preserved: each node's slice of the stacked state is
self-contained (the leading axis is the node axis), so a node can restore
its own model without the others — mirroring DecentralizePy's per-node local
result/checkpoint files.
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    flat = {}

    def walk(path, leaf):
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[name] = np.asarray(leaf)

    jax.tree_util.tree_map_with_path(walk, tree)
    return flat


def save_checkpoint(path: str, step: int, **trees) -> str:
    """save_checkpoint(dir, 100, params=..., opt_state=...) -> file path.

    Crash-consistent: the .npz lands via temp-file + ``os.replace`` and
    only after its meta JSON, so a process killed mid-save leaves at most
    a stray meta file — never a truncated archive that
    :func:`latest_checkpoint` (which matches only ``.npz`` names) would
    pick up.  The rejoin path relies on this: any step the index reports
    is fully restorable."""
    os.makedirs(path, exist_ok=True)
    fn = os.path.join(path, f"ckpt_{step:08d}.npz")
    payload = {}
    meta = {"step": step, "trees": {}}
    for tname, tree in trees.items():
        flat = _flatten(tree)
        meta["trees"][tname] = {k: [list(v.shape), str(v.dtype)] for k, v in flat.items()}
        payload.update({f"{tname}::{k}": v for k, v in flat.items()})
    with open(os.path.join(path, f"ckpt_{step:08d}.json"), "w") as f:
        json.dump(meta, f)
    tmp = fn + ".tmp.npz"
    np.savez(tmp, **payload)
    os.replace(tmp, fn)
    return fn


def load_checkpoint(path: str, step: Optional[int] = None, like: Optional[dict] = None):
    """Returns (step, {tree_name: pytree-as-nested-dict})."""
    if step is None:
        step = latest_checkpoint(path)
        assert step is not None, f"no checkpoints in {path}"
    data = np.load(os.path.join(path, f"ckpt_{step:08d}.npz"))
    out: dict = {}
    for key in data.files:
        tname, leaf_path = key.split("::", 1)
        node = out.setdefault(tname, {})
        parts = leaf_path.split("/")
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = data[key]
    return step, out


def restore_tree(like: Any, nested: Optional[dict]):
    """Rebuild a pytree with ``like``'s structure from the nested-dict leaf
    form :func:`load_checkpoint` returns — the inverse of ``_flatten``'s
    path-join, so ``restore_tree(t, load(save(t)))`` round-trips any tree
    the engine checkpoints (params / opt_state / share_state).  Leaves come
    back as jnp arrays in their saved dtypes.  ``nested=None`` (a tree with
    no array leaves, e.g. stateless sharing's ``()``) returns ``like``."""
    if nested is None:
        return like
    import jax.numpy as jnp

    def pick(path, _leaf):
        node = nested
        for p in path:
            node = node[str(getattr(p, "key", getattr(p, "idx", p)))]
        return jnp.asarray(node)

    return jax.tree_util.tree_map_with_path(pick, like)


def latest_checkpoint(path: str) -> Optional[int]:
    if not os.path.isdir(path):
        return None
    steps = [
        int(m.group(1))
        for f in os.listdir(path)
        if (m := re.match(r"ckpt_(\d+)\.npz$", f))
    ]
    return max(steps) if steps else None
