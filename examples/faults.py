"""Fault-tolerant gossip — message-level fault injection end-to-end.

Sweeps ``FaultPlan.msg_loss`` against churn and secure aggregation (with
the Bonawitz seed-recovery pass) and prints the traced fault counters each
configuration accumulated in its history records:

* ``msg_loss``: each directed message is lost independently per round;
  the mixing operand renormalizes (rows stay stochastic), the sender
  still pays wire bytes and link time.  Pure loss is *survived by
  design* — counters show injected == survived, detected == 0.
* ``--corrupt``: post-mix payload corruption (NaN bursts); the step
  guard detects the non-finite rows and rolls them back to the
  last-good snapshot — injected == detected == recovered.
* ``--crash N:D:R``: declarative crash/restart windows (node N down for
  rounds [D, R); R=-1 means forever) that AND into the churn mask.
* ``--secure``: secure aggregation stays exact under churn via
  ``secure_recovery=True`` (dropped pairs' PRF masks are re-derived by
  surviving co-neighbors and subtracted); the seed-share traffic shows
  up as ``recovery_bytes``.

    PYTHONPATH=src python examples/faults.py --rounds 40
    PYTHONPATH=src python examples/faults.py --participation 0.7 --secure
    PYTHONPATH=src python examples/faults.py --corrupt 0.05 --crash 3:5:12
"""
import argparse

from repro.core import DLConfig, FaultPlan, RoundEngine
from repro.data import NodeBatcher, make_dataset, sharding_partition
from repro.models.api import cross_entropy
from repro.models.mlp import mlp_apply, mlp_init
from repro.optim import make_optimizer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=40)
    ap.add_argument("--nodes", type=int, default=16)
    ap.add_argument("--participation", type=float, default=1.0)
    ap.add_argument("--secure", action="store_true",
                    help="secure aggregation + Bonawitz seed recovery "
                         "(composes with churn/crashes, not msg_loss)")
    ap.add_argument("--corrupt", type=float, default=0.0,
                    help="per-node payload corruption probability")
    ap.add_argument("--crash", action="append", default=[],
                    metavar="N:D:R", help="crash node N for rounds [D, R)")
    args = ap.parse_args()

    crashes = tuple(tuple(int(v) for v in c.split(":")) for c in args.crash)

    ds = make_dataset("cifar10", n_train=8192, n_test=512)
    parts = sharding_partition(ds.train_y, args.nodes, 2, seed=0)
    batcher = NodeBatcher(ds.train_x, ds.train_y, parts, 8, seed=0)

    loss_fn = lambda p, x, y: cross_entropy(mlp_apply(p, x), y)
    acc_fn = lambda p, x, y: (mlp_apply(p, x).argmax(-1) == y).mean()

    losses = (0.0,) if args.secure else (0.0, 0.05, 0.1, 0.2)
    print(f"{'msg_loss':>9s} {'acc':>8s} {'sim LAN s':>10s} {'injected':>9s} "
          f"{'detected':>9s} {'survived':>9s} {'recovered':>10s} "
          f"{'recovery MB':>12s}")
    for p_loss in losses:
        plan = None
        if p_loss > 0 or args.corrupt > 0 or crashes:
            plan = FaultPlan(msg_loss=p_loss, corrupt_prob=args.corrupt,
                             crashes=crashes)
        dl = DLConfig(n_nodes=args.nodes, topology="regular", degree=5,
                      rounds=args.rounds, eval_every=args.rounds - 1,
                      local_steps=2, participation=args.participation,
                      network="lan", compute_time_s=0.05, faults=plan,
                      secure=args.secure,
                      secure_recovery=args.secure)
        e = RoundEngine(dl, lambda k: mlp_init(k, hidden=128), loss_fn,
                        acc_fn, make_optimizer("sgd", 0.05), batcher)
        hist = e.run(log=False)
        rec = hist[-1]
        print(f"{p_loss:9.2f} {rec['acc_mean']:8.4f} {e.sim_time_s:10.2f} "
              f"{rec.get('faults_injected', 0):9d} "
              f"{rec.get('faults_detected', 0):9d} "
              f"{rec.get('faults_survived', 0):9d} "
              f"{rec.get('faults_recovered', 0):10d} "
              f"{rec.get('recovery_bytes', 0.0) / 1e6:12.3f}")


if __name__ == "__main__":
    main()
