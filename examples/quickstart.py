"""Quickstart — the paper's Fig. 2 node loop, in this framework.

16 nodes, 5-regular static topology, GN-LeNet on the synthetic CIFAR-10
stand-in with 2-sharding non-IID data, plain SGD (the paper's recipe).

Execution goes through the RoundEngine: chunks of rounds are compiled into
a single ``lax.scan`` (batches gathered from the device-resident dataset,
per-round metrics collected on device), so the emulation runs as fast as
the hardware allows.  Optionally attach a simulated network (--network lan)
to also get the paper's simulated wall-clock axis.

    PYTHONPATH=src python examples/quickstart.py [--rounds 60]
"""
import argparse

from repro.core import DLConfig, RoundEngine
from repro.data import NodeBatcher, make_dataset, sharding_partition
from repro.models.api import cross_entropy
from repro.models.cnn import cnn_apply, cnn_init
from repro.optim import make_optimizer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=60)
    ap.add_argument("--nodes", type=int, default=16)
    ap.add_argument("--chunk", type=int, default=10,
                    help="rounds per compiled scan chunk (0 = legacy per-round)")
    ap.add_argument("--network", default="none", choices=["none", "lan", "wan"],
                    help="simulated deployment for the wall-clock axis")
    ap.add_argument("--shard-devices", type=int, default=0,
                    help="shard the node axis over this many devices (CPU: "
                         "set XLA_FLAGS=--xla_force_host_platform_device_count)")
    args = ap.parse_args()

    # Dataset module: read, partition (non-IID 2-sharding), evaluate.
    ds = make_dataset("cifar10", n_train=8192, n_test=512)
    parts = sharding_partition(ds.train_y, args.nodes, shards_per_node=2, seed=0)
    batcher = NodeBatcher(ds.train_x, ds.train_y, parts, batch_size=8, seed=0)

    # Training module: loss/metric over the Model module.
    def loss_fn(p, x, y):
        return cross_entropy(cnn_apply(p, x), y)

    def acc_fn(p, x, y):
        return (cnn_apply(p, x).argmax(-1) == y).mean()

    # Node + Graph + Sharing + Communication, one config object.
    dl = DLConfig(
        n_nodes=args.nodes,
        topology="regular", degree=5,   # Graph module
        sharing="full",                 # Sharing module (D-PSGD full sharing)
        local_steps=2, rounds=args.rounds, eval_every=10,
        chunk_rounds=args.chunk,        # rounds per compiled lax.scan
        network=args.network,           # NetworkModel (simulated time)
        shard_devices=args.shard_devices,  # node axis over a device mesh
        results_dir="results/quickstart",
    )
    engine = RoundEngine(
        dl, lambda k: cnn_init(k, width=16), loss_fn, acc_fn,
        make_optimizer("sgd", 0.05), batcher,
    )
    hist = engine.run()
    print(f"\nfinal: acc {hist[-1]['acc_mean']:.4f} ± {hist[-1]['acc_std']:.4f}, "
          f"{engine.bytes_sent / 1e6:.1f} MB sent/node "
          + (f"simulated {engine.sim_time_s:.1f}s on {args.network}, "
             if args.network != "none" else "")
          + "(results in results/quickstart/results.json)")


if __name__ == "__main__":
    main()
