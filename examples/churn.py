"""Churn / straggler / heterogeneous-time realism — the activation-mask
scenario axes, end-to-end on the RoundEngine.

Three sweeps, all inside the engine's compiled scan:

* participation: each round every node is up with probability
  ``participation`` — iid per node, or *machine-correlated* with
  ``--machines M`` (whole machines fail together, round-robin mapping).
  Down nodes skip their local step, are cut out of the mixing operand
  (freed weight back to the surviving diagonals), and freeze their
  params/optimizer/sharing state until they rejoin with that stale model.
* stragglers: ``--straggler-frac``/``--straggler-factor`` mark a seeded
  fraction of nodes with heavier per-node compute times
  (``network.straggler_compute_times``).
* execution semantics: ``--semantics sync|local|async`` selects the
  scheduler layer — the synchronous round barrier, per-node
  neighborhood-barrier clocks (same trajectories, honest per-node time),
  or event-driven AD-PSGD-style gossip on a virtual clock (staleness +
  per-node wall-clock reported).

    PYTHONPATH=src python examples/churn.py --rounds 40
    PYTHONPATH=src python examples/churn.py --rounds 40 --machines 4
    PYTHONPATH=src python examples/churn.py --rounds 60 --semantics async \\
        --straggler-factor 10 --straggler-frac 0.1
"""
import argparse

from repro.core import DLConfig, RoundEngine
from repro.data import NodeBatcher, make_dataset, sharding_partition
from repro.models.api import cross_entropy
from repro.models.mlp import mlp_apply, mlp_init
from repro.optim import make_optimizer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=40)
    ap.add_argument("--nodes", type=int, default=16)
    ap.add_argument("--semantics", choices=("sync", "local", "async"),
                    default="sync")
    ap.add_argument("--machines", type=int, default=0,
                    help="churn_machines: >0 drops whole machines together")
    ap.add_argument("--compute-time", type=float, default=0.05,
                    help="base per-node compute seconds in the time model")
    ap.add_argument("--straggler-factor", type=float, default=1.0)
    ap.add_argument("--straggler-frac", type=float, default=0.0)
    args = ap.parse_args()

    ds = make_dataset("cifar10", n_train=8192, n_test=512)
    parts = sharding_partition(ds.train_y, args.nodes, 2, seed=0)
    batcher = NodeBatcher(ds.train_x, ds.train_y, parts, 8, seed=0)

    loss_fn = lambda p, x, y: cross_entropy(mlp_apply(p, x), y)
    acc_fn = lambda p, x, y: (mlp_apply(p, x).argmax(-1) == y).mean()

    extra = ""
    if args.semantics != "sync":
        extra = f" {'median node clock':>18s}"
    if args.semantics == "async":
        extra += f" {'staleness':>10s}"
    print(f"{'participation':>14s} {'acc':>8s} {'MB/node':>9s} "
          f"{'sim LAN s':>10s}" + extra)
    for p in (1.0, 0.9, 0.7, 0.5):
        dl = DLConfig(n_nodes=args.nodes, topology="regular", degree=5,
                      rounds=args.rounds, eval_every=args.rounds - 1,
                      local_steps=2 if args.semantics != "async" else 1,
                      participation=p, churn_machines=args.machines,
                      network="lan", semantics=args.semantics,
                      compute_time_s=args.compute_time,
                      straggler_factor=args.straggler_factor,
                      straggler_frac=args.straggler_frac)
        e = RoundEngine(dl, lambda k: mlp_init(k, hidden=128), loss_fn,
                        acc_fn, make_optimizer("sgd", 0.05), batcher)
        hist = e.run(log=False)
        line = (f"{p:14.1f} {hist[-1]['acc_mean']:8.4f} "
                f"{e.bytes_sent / 1e6:9.1f} {e.sim_time_s:10.2f}")
        if args.semantics != "sync":
            line += f" {hist[-1].get('vclock_median_s', float('nan')):18.2f}"
        if args.semantics == "async":
            line += f" {hist[-1].get('staleness_mean', float('nan')):10.2f}"
        print(line)


if __name__ == "__main__":
    main()
