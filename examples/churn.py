"""Churn / straggler dropout — the participation-mask scenario axis.

Each round, every node is up with probability ``participation``; down nodes
skip their local step and are cut out of the mixing matrix on the fly (the
freed weight returns to the surviving diagonals, keeping W doubly
stochastic on the live subgraph).  The engine threads the per-round (R, N)
activity mask through the compiled scan, so churn costs nothing extra.

Sweeps participation on a 5-regular graph and reports accuracy, bytes, and
simulated LAN wall-clock — dropped nodes also send nothing, so churn trades
accuracy-per-round against communication.

    PYTHONPATH=src python examples/churn.py --rounds 40
"""
import argparse

from repro.core import DLConfig, RoundEngine
from repro.data import NodeBatcher, make_dataset, sharding_partition
from repro.models.api import cross_entropy
from repro.models.mlp import mlp_apply, mlp_init
from repro.optim import make_optimizer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=40)
    ap.add_argument("--nodes", type=int, default=16)
    args = ap.parse_args()

    ds = make_dataset("cifar10", n_train=8192, n_test=512)
    parts = sharding_partition(ds.train_y, args.nodes, 2, seed=0)
    batcher = NodeBatcher(ds.train_x, ds.train_y, parts, 8, seed=0)

    loss_fn = lambda p, x, y: cross_entropy(mlp_apply(p, x), y)
    acc_fn = lambda p, x, y: (mlp_apply(p, x).argmax(-1) == y).mean()

    print(f"{'participation':>14s} {'acc':>8s} {'MB/node':>9s} {'sim LAN s':>10s}")
    for p in (1.0, 0.9, 0.7, 0.5):
        dl = DLConfig(n_nodes=args.nodes, topology="regular", degree=5,
                      rounds=args.rounds, eval_every=args.rounds - 1,
                      local_steps=2, participation=p, network="lan")
        e = RoundEngine(dl, lambda k: mlp_init(k, hidden=128), loss_fn,
                        acc_fn, make_optimizer("sgd", 0.05), batcher)
        hist = e.run(log=False)
        print(f"{p:14.1f} {hist[-1]['acc_mean']:8.4f} "
              f"{e.bytes_sent / 1e6:9.1f} {e.sim_time_s:10.2f}")


if __name__ == "__main__":
    main()
