"""Sparsification (paper §3.3): full sharing vs random-sampling vs TopK vs
CHOCO-SGD at a 10% budget — swap the Sharing module, keep everything else.

    PYTHONPATH=src python examples/sparsification.py --rounds 40
"""
import argparse

from repro.core import DLConfig, DecentralizedRunner
from repro.data import NodeBatcher, make_dataset, sharding_partition
from repro.models.api import cross_entropy
from repro.models.mlp import mlp_apply, mlp_init
from repro.optim import make_optimizer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=40)
    ap.add_argument("--budget", type=float, default=0.1)
    args = ap.parse_args()

    ds = make_dataset("cifar10", n_train=8192, n_test=512)
    parts = sharding_partition(ds.train_y, 16, 2, seed=0)
    batcher = NodeBatcher(ds.train_x, ds.train_y, parts, 8, seed=0)
    loss_fn = lambda p, x, y: cross_entropy(mlp_apply(p, x), y)
    acc_fn = lambda p, x, y: (mlp_apply(p, x).argmax(-1) == y).mean()

    print(f"{'sharing':18s} {'acc':>8s} {'MB/node':>9s}")
    for sharing in ("full", "randomk", "topk", "choco"):
        dl = DLConfig(n_nodes=16, topology="regular", degree=5, sharing=sharing,
                      budget=args.budget, rounds=args.rounds,
                      eval_every=args.rounds - 1, local_steps=2)
        r = DecentralizedRunner(dl, lambda k: mlp_init(k, hidden=128), loss_fn,
                                acc_fn, make_optimizer("sgd", 0.05), batcher)
        hist = r.run(log=False)
        print(f"{sharing:18s} {hist[-1]['acc_mean']:8.4f} {r.bytes_sent / 1e6:9.1f}")


if __name__ == "__main__":
    main()
