"""Real-network backend demo: K OS processes, real sockets, a real kill.

Spawns K worker processes that gossip the payload wire format over
localhost TCP (``DLConfig.backend="processes"``), SIGKILLs one of them
mid-run, and shows the survivors detecting the death (heartbeat failure
detector), reweighting the dead nodes' edges away
(``sharing.edge_reweight_sparse`` — surviving rows stay row-stochastic),
and finishing training.  Prints the merged history, survivor fault
counters, and the final consensus error over surviving rows.

With ``--rejoin`` the supervisor relaunches the killed worker with a
bumped membership epoch: it restores its row-block from the last
checkpoint (or cold-syncs from a live donor over STATE frames), runs the
two-phase JOIN handshake, and the survivors re-admit it with pristine
edge weights — the run ends with every row live again.

    PYTHONPATH=src python examples/processes.py --nodes 16 --workers 4 \\
        --rounds 12 --kill-worker 3 --kill-at-round 4
    PYTHONPATH=src python examples/processes.py --sharing randomk --quant
    PYTHONPATH=src python examples/processes.py --rejoin
"""
import argparse

from repro.core import DLConfig
from repro.runtime import ProcessRunner


def main():
    ap = argparse.ArgumentParser(description="processes-backend kill demo")
    ap.add_argument("--nodes", type=int, default=16)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=12)
    ap.add_argument("--degree", type=int, default=5)
    ap.add_argument("--sharing", default="full", choices=["full", "randomk"])
    ap.add_argument("--budget", type=float, default=0.1)
    ap.add_argument("--quant", action="store_true",
                    help="int8 + scale payload wire format")
    ap.add_argument("--kill-worker", type=int, default=None)
    ap.add_argument("--kill-at-round", type=int, default=None)
    ap.add_argument("--rejoin", action="store_true",
                    help="relaunch the killed worker and re-admit it "
                         "(crash-rejoin demo: more rounds, slower rounds)")
    ap.add_argument("--watchdog", type=float, default=60.0)
    ap.add_argument("--eval-every", type=int, default=4)
    args = ap.parse_args()
    if args.rejoin and args.rounds == 12:
        # the relaunch is a fresh python+jax boot (seconds); give the run
        # enough slow rounds for the rejoiner to land mid-run
        args.rounds = 30
    if args.kill_worker is None and args.kill_at_round is None:
        # default demo: kill the last worker a third of the way in
        args.kill_worker = args.workers - 1
        args.kill_at_round = max(1, args.rounds // 3) if not args.rejoin else 3

    dl = DLConfig(
        n_nodes=args.nodes, topology="regular", degree=args.degree,
        sharing=args.sharing, budget=args.budget,
        payload_quant=args.quant, rounds=args.rounds,
        eval_every=args.eval_every, backend="processes",
    )
    workload = {"dataset": "cifar10", "model": "mlp", "width": 2,
                "n_train": 512, "n_test": 256, "lr": 0.05}
    if args.rejoin:
        runner = ProcessRunner(
            dl, workload, workers=args.workers,
            watchdog_s=max(args.watchdog, 120.0),
            chaos_plan=[{"worker": args.kill_worker,
                         "kill_at_round": args.kill_at_round,
                         "rejoin": True}],
            ckpt_every=4, round_min_s=0.35,
            dump_view=True, keep_run_dir=True,
        )
    else:
        runner = ProcessRunner(
            dl, workload, workers=args.workers, watchdog_s=args.watchdog,
            kill_worker=args.kill_worker, kill_at_round=args.kill_at_round,
        )
    runner.run(log=True)

    print("\n--- workers ---")
    for w, res in sorted(runner.worker_results.items()):
        c = res["counters"]
        extra = ""
        if res.get("rejoined"):
            extra = (f" REJOINED epoch={res['epoch']} "
                     f"start_round={res['start_round']} "
                     f"catchup={res['catchup_source']} "
                     f"({c['catchup_bytes']} B)")
        print(f"worker {w}: rows {res['rows']}  "
              f"faults_detected={c['faults_detected']} "
              f"retries={c['retry_total']} leaves={c['leaves']} "
              f"stale_dropped={c['stale_frames_dropped']} "
              f"dead_peers={res['dead_peers']} "
              f"row_err={res['reweight_row_err']:.2e}{extra}")
    print(f"\nkilled worker {args.kill_worker} after round "
          f"{runner.killed_at_round}; surviving rows "
          f"{int(runner.live_rows.sum())}/{args.nodes}")
    print(f"merged counters: {runner.counters}")
    print(f"max |row_sum - 1| after reweight: {runner.reweight_row_err:.2e}")
    print(f"final acc: {runner.history[-1]['acc_mean']:.4f}")
    print(f"final consensus error: {runner.consensus_error():.4f}")
    assert runner.counters["faults_detected"] >= 1, "no survivor detected the kill"
    assert runner.reweight_row_err < 1e-5, "reweighted rows must stay stochastic"
    if args.rejoin:
        views = runner.verify_rejoin_views()
        print(f"rejoin conservation ok: {runner.conservation['ok']}; "
              f"bitwise views: {views}")
        assert runner.workers_rejoined == 1, "the killed worker never rejoined"
        assert runner.conservation["ok"], runner.conservation
        assert all(views.values()), "rejoiner row-block diverged from survivors"


if __name__ == "__main__":
    main()
