"""Real-network backend demo: K OS processes, real sockets, a real kill.

Spawns K worker processes that gossip the payload wire format over
localhost TCP (``DLConfig.backend="processes"``), SIGKILLs one of them
mid-run, and shows the survivors detecting the death (heartbeat failure
detector), reweighting the dead nodes' edges away
(``sharing.edge_reweight_sparse`` — surviving rows stay row-stochastic),
and finishing training.  Prints the merged history, survivor fault
counters, and the final consensus error over surviving rows.

    PYTHONPATH=src python examples/processes.py --nodes 16 --workers 4 \\
        --rounds 12 --kill-worker 3 --kill-at-round 4
    PYTHONPATH=src python examples/processes.py --sharing randomk --quant
"""
import argparse

from repro.core import DLConfig
from repro.runtime import ProcessRunner


def main():
    ap = argparse.ArgumentParser(description="processes-backend kill demo")
    ap.add_argument("--nodes", type=int, default=16)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=12)
    ap.add_argument("--degree", type=int, default=5)
    ap.add_argument("--sharing", default="full", choices=["full", "randomk"])
    ap.add_argument("--budget", type=float, default=0.1)
    ap.add_argument("--quant", action="store_true",
                    help="int8 + scale payload wire format")
    ap.add_argument("--kill-worker", type=int, default=None)
    ap.add_argument("--kill-at-round", type=int, default=None)
    ap.add_argument("--watchdog", type=float, default=60.0)
    ap.add_argument("--eval-every", type=int, default=4)
    args = ap.parse_args()
    if args.kill_worker is None and args.kill_at_round is None:
        # default demo: kill the last worker a third of the way in
        args.kill_worker = args.workers - 1
        args.kill_at_round = max(1, args.rounds // 3)

    dl = DLConfig(
        n_nodes=args.nodes, topology="regular", degree=args.degree,
        sharing=args.sharing, budget=args.budget,
        payload_quant=args.quant, rounds=args.rounds,
        eval_every=args.eval_every, backend="processes",
    )
    workload = {"dataset": "cifar10", "model": "mlp", "width": 2,
                "n_train": 512, "n_test": 256, "lr": 0.05}
    runner = ProcessRunner(
        dl, workload, workers=args.workers, watchdog_s=args.watchdog,
        kill_worker=args.kill_worker, kill_at_round=args.kill_at_round,
    )
    runner.run(log=True)

    print("\n--- survivors ---")
    for w, res in sorted(runner.worker_results.items()):
        c = res["counters"]
        print(f"worker {w}: rows {res['rows']}  "
              f"faults_detected={c['faults_detected']} "
              f"retries={c['retry_total']} leaves={c['leaves']} "
              f"dead_peers={res['dead_peers']} "
              f"row_err={res['reweight_row_err']:.2e}")
    print(f"\nkilled worker {args.kill_worker} after round "
          f"{runner.killed_at_round}; surviving rows "
          f"{int(runner.live_rows.sum())}/{args.nodes}")
    print(f"merged counters: {runner.counters}")
    print(f"max |row_sum - 1| after reweight: {runner.reweight_row_err:.2e}")
    print(f"final acc over survivors: {runner.history[-1]['acc_mean']:.4f}")
    print(f"final consensus error: {runner.consensus_error():.4f}")
    assert runner.counters["faults_detected"] >= 1, "no survivor detected the kill"
    assert runner.reweight_row_err < 1e-5, "reweighted rows must stay stochastic"


if __name__ == "__main__":
    main()
