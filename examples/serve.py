"""Batched serving demo: load (or init) a smoke-scale model from the arch
registry and serve a batch of requests through the KV-cache decode path.

    PYTHONPATH=src python examples/serve.py --arch smollm-135m --batch 4
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_smoke_config
from repro.models.api import init_params
from repro.serving import ServeConfig, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m",
                    choices=[a for a in ARCHS if a != "gn-lenet"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    if cfg.family in ("encdec",):
        print("serve.py demos decoder-only archs; whisper decode is covered "
              "by tests/test_decode_consistency.py")
        return
    params = init_params(cfg, jax.random.key(0))
    engine = ServingEngine(cfg, ServeConfig(batch=args.batch, max_len=128), params)
    prompts = jax.random.randint(jax.random.key(1), (args.batch, 8), 1, cfg.vocab)
    out = engine.generate(prompts, max_new=args.max_new)
    print(f"arch={args.arch} (smoke config, family={cfg.family})")
    for b in range(args.batch):
        print(f"  request {b}: prompt={list(map(int, prompts[b]))} -> "
              f"generated={list(map(int, out[b]))}")


if __name__ == "__main__":
    main()
